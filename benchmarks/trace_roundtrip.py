"""Trace round-trip benchmark + correctness gate (BENCH_trace.json).

Exercises the trace subsystem end to end, the way a real validation
session would:

  roundtrip          simulate -> export Chrome trace -> ingest -> align ->
                     validate.  Must report 100% node alignment and ~0%
                     end-to-end error (the subsystem's self-consistency
                     contract); timings per stage in us.
  cluster_roundtrip  same through an 8-rank ``simulate_cluster`` with a
                     straggler profile (per-rank processes in the trace).
  calibration        trace generated under deliberately perturbed hbm_bw /
                     link scale; coordinate-descent calibration must
                     recover both within 5% and shrink the rms span error.

check_regression.py gates the recorded floors (benchmarks/thresholds.json
section "trace"): roundtrip match/accuracy, calibration recovery and
error-reduction ratio.  No jax required — runs in seconds.
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, write_json
from benchmarks.hetero_cluster import fsdp_stack

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import (RankProfile, build_topology, simulate,
                                  simulate_cluster)
from repro.trace import (calibrate, ingest_chrome_trace, to_chrome_trace,
                         validate)


def _timed(fn, iters: int):
    fn()                                   # warm caches
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn()
    dt = (time.perf_counter() - t0) / iters
    return out, dt * 1e6                   # us/call


def calibration_stack(n_layers: int, ranks: int) -> chakra.Graph:
    """fsdp_stack plus HBM-bound COMP nodes so hbm_bw is identifiable
    independently of compute_derate."""
    g = fsdp_stack(n_layers, ranks)
    for i in range(n_layers):
        g.add(f"mem{i}", chakra.COMP, deps=[4 * i + 1], flops=1e8,
              bytes=5e8)
    return g


def bench_roundtrip(sysc, topo, n_layers: int, iters: int):
    g = fsdp_stack(n_layers, topo.n_ranks)
    res = simulate(g, sysc, topo, keep_timeline=True)
    trace, t_export = _timed(lambda: to_chrome_trace(res, graph=g), iters)
    tl, t_ingest = _timed(lambda: ingest_chrome_trace(trace), iters)
    rep, t_validate = _timed(lambda: validate(g, tl, sysc, topo), iters)
    assert rep.match_fraction == 1.0, rep.match_fraction
    assert rep.e2e_error < 1e-9, rep.e2e_error
    emit("trace.export", t_export, f"{len(trace['traceEvents'])}_events")
    emit("trace.ingest", t_ingest, f"{len(tl.events)}_spans")
    emit("trace.validate", t_validate,
         f"{rep.e2e_error * 100:.4f}%_e2e_err")
    return {"n_nodes": len(g), "export_us": t_export, "ingest_us": t_ingest,
            "validate_us": t_validate,
            "roundtrip_match": rep.match_fraction,
            "roundtrip_accuracy": 1.0 - rep.e2e_error}


def bench_cluster_roundtrip(sysc, topo, n_layers: int, ranks: int,
                            iters: int):
    g = fsdp_stack(n_layers, ranks)
    profs = {ranks - 1: RankProfile(compute_scale=0.7)}
    cr = simulate_cluster(g, sysc, topo, n_ranks=ranks, rank_profiles=profs,
                          keep_timeline=True)
    trace, t_export = _timed(lambda: to_chrome_trace(cr, graph=g), iters)
    tl = ingest_chrome_trace(trace)
    rep, t_validate = _timed(
        lambda: validate(g, tl, sysc, topo, rank_profiles=profs), iters)
    assert rep.n_ranks == ranks
    assert rep.match_fraction == 1.0, rep.match_fraction
    assert rep.e2e_error < 1e-9, rep.e2e_error
    emit(f"trace.cluster_export_{ranks}r", t_export,
         f"{len(trace['traceEvents'])}_events")
    emit(f"trace.cluster_validate_{ranks}r", t_validate,
         f"{rep.match_fraction * 100:.0f}%_matched")
    return {"n_ranks": ranks, "export_us": t_export,
            "validate_us": t_validate,
            "cluster_match": rep.match_fraction,
            "cluster_accuracy": 1.0 - rep.e2e_error}


def bench_calibration(sysc, topo, n_layers: int):
    g = calibration_stack(n_layers, topo.n_ranks)
    hbm_f, link_f = 0.65, 0.7
    true_sys = sysc.replace(hbm_bw=sysc.hbm_bw * hbm_f,
                            link_bw=sysc.link_bw * link_f)
    res = simulate(g, true_sys, build_topology(true_sys, topo.n_ranks),
                   keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(res, graph=g))
    t0 = time.perf_counter()
    cal = calibrate(g, tl, sysc, topo)
    t_fit = (time.perf_counter() - t0) * 1e6
    err_hbm = abs(cal.params["hbm_bw"] / (sysc.hbm_bw * hbm_f) - 1.0)
    err_link = abs(cal.params["link_bw_scale"] / link_f - 1.0)
    recovery = 1.0 - max(err_hbm, err_link)
    reduction = cal.initial_error / max(cal.fitted_error, 1e-12)
    assert recovery >= 0.95, (err_hbm, err_link)
    before = validate(g, tl, sysc, topo)
    after = validate(g, tl, cal.system, cal.topology,
                     compute_derate=cal.compute_derate)
    assert after.e2e_error < before.e2e_error
    emit("trace.calibrate", t_fit,
         f"{recovery * 100:.2f}%_param_recovery")
    emit("trace.calibrate_err_reduction", reduction,
         f"{cal.initial_error * 100:.2f}%->{cal.fitted_error * 100:.2f}%_rms")
    return {"fit_us": t_fit, "calib_recovery": recovery,
            "calib_error_reduction": reduction,
            "hbm_err": err_hbm, "link_err": err_link,
            "e2e_before": before.e2e_error, "e2e_after": after.e2e_error}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller graphs / fewer timing iters (CI gate)")
    args = ap.parse_args(argv)
    layers, iters = (12, 3) if args.smoke else (48, 10)
    ranks = 8
    sysc = SystemConfig(chips=ranks, topology="switch")
    topo = build_topology(sysc, ranks)

    payload = {"smoke": bool(args.smoke), "n_layers": layers}
    rt = bench_roundtrip(sysc, topo, layers, iters)
    cl = bench_cluster_roundtrip(sysc, topo, layers, ranks, iters)
    cal = bench_calibration(sysc, topo, layers)
    payload.update({k: v for k, v in rt.items()})
    payload["cluster"] = cl
    payload["cluster_match"] = cl["cluster_match"]
    payload["cluster_accuracy"] = cl["cluster_accuracy"]
    payload["calibration"] = cal
    payload["calib_recovery"] = cal["calib_recovery"]
    payload["calib_error_reduction"] = cal["calib_error_reduction"]
    path = write_json("BENCH_trace.json", payload)
    emit("trace.bench_json", 0.0, path)


if __name__ == "__main__":
    main()
