"""Benchmark regression gate: fail if BENCH_sim speedup ratios, the trace
subsystem's round-trip/calibration figures, the search subsystem's
sample-efficiency figures, the MPMD engine's exactness/coalescing figures,
the fault subsystem's segmented-resim/Young-Daly figures, the
parallel/delta DSE figures, the obs instrumentation's
overhead/blame-identity figures, the memory-timeline
identity/overhead/OOM-sweep figures or the pipeline-schedule
bubble-recovery/coalescing figures fall outside the bounds recorded in
benchmarks/thresholds.json.  A plain-number threshold is a floor;
``{"max": v}`` is a ceiling (the obs and memory overhead percentages
gate from above).  Every gated key is printed as one PASS/FAIL/SKIP
table row and the table is written to artifacts/bench/BENCH_summary.json.

Usage (the verify recipe's perf gate):

    PYTHONPATH=.:src python -m benchmarks.sim_bench --smoke
    PYTHONPATH=.:src python -m benchmarks.trace_roundtrip --smoke
    PYTHONPATH=.:src python -m benchmarks.search_bench --smoke
    PYTHONPATH=.:src python -m benchmarks.mpmd_pipeline --smoke
    PYTHONPATH=.:src python -m benchmarks.fault_scenarios --smoke
    PYTHONPATH=.:src python -m benchmarks.parallel_dse --smoke
    PYTHONPATH=.:src python -m benchmarks.obs_overhead --smoke
    PYTHONPATH=.:src python -m benchmarks.memory_timeline --smoke
    PYTHONPATH=.:src python -m benchmarks.pipeline_schedules --smoke
    PYTHONPATH=.:src python -m benchmarks.check_regression

or in one shot::

    PYTHONPATH=.:src python -m benchmarks.check_regression --run-smoke

Reads artifacts/bench/BENCH_sim.json, BENCH_trace.json, BENCH_search.json,
BENCH_mpmd.json, BENCH_fault.json, BENCH_parallel.json, BENCH_obs.json,
BENCH_memory.json and BENCH_pipeline.json (``--bench`` /
``--trace-bench`` / ``--search-bench`` / ``--mpmd-bench`` /
``--fault-bench`` / ``--parallel-bench`` / ``--obs-bench`` /
``--memory-bench`` / ``--pipeline-bench`` to override).
The speedup floors are deliberately conservative — they hold for both the
full and ``--smoke`` matrices on a loaded machine — so a failure means the
engine actually regressed, not that the box was busy; the trace floors are
correctness contracts (alignment, round-trip accuracy, calibration
recovery), the search floors are the PR-4 acceptance bound
(bayesian/evolutionary within 2% of the exhaustive grid optimum on <= 25%
of its trials), the mpmd floors are the PR-5 acceptance contract
(K-identical-graph bit-identity, 64-rank two-pool coalescing speedup), the
fault floors are the PR-6 acceptance contract (segmented horizon
re-simulation >= 3x over naive, simulated optimal checkpoint interval
within 15% of Young/Daly, goodput monotone in fault rate), and the
parallel floors gate the process-pool + delta re-simulation PR
(pool_identity/delta_identity are exactness contracts enforced
everywhere; the ``pool_speedup`` floor only applies when the box reports
>= 4 usable cores, since a smaller box physically cannot show pool
scaling), and the memory floors gate the memory-timeline PR
(occupancy-curve identity and blame coverage are bit-exactness
contracts, the overhead ceiling bounds the observability-attributable
cost of a lean simulate, and oom_sweep_ok requires an
hbm_bytes-constrained search to record OOM-infeasible trials without
crashing), and the pipeline floors gate the microbatched-schedule PR
(simulated bubble within 10% of the analytic (p-1)/(m+p-1) for GPipe
and 1F1B, cross-replica graph sharing >= 3x over literal per-replica
graphs with bit-identity required, and m=1 identical to the legacy
split under every schedule name).  Exit code 1 on regression, 2 on
missing inputs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(__file__)
DEFAULT_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                             "BENCH_sim.json")
DEFAULT_TRACE_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                   "BENCH_trace.json")
DEFAULT_SEARCH_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                    "BENCH_search.json")
DEFAULT_MPMD_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                  "BENCH_mpmd.json")
DEFAULT_FAULT_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                   "BENCH_fault.json")
DEFAULT_PARALLEL_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                      "BENCH_parallel.json")
DEFAULT_OBS_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                 "BENCH_obs.json")
DEFAULT_MEMORY_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                    "BENCH_memory.json")
DEFAULT_PIPELINE_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                      "BENCH_pipeline.json")
DEFAULT_THRESH = os.path.join(HERE, "thresholds.json")


def _within(measured: float, thr) -> bool:
    """A plain number is a floor (measured >= thr); a ``{"max": v}`` /
    ``{"min": v}`` dict bounds from above / below (ceilings gate e.g. the
    obs overhead percentage, where *small* is good)."""
    if isinstance(thr, dict):
        if "max" in thr and measured > thr["max"]:
            return False
        if "min" in thr and measured < thr["min"]:
            return False
        return True
    return measured >= thr


def evaluate(bench: dict, thresholds: dict) -> list:
    """Every gated (key, measured, threshold, status) row, status in
    PASS / FAIL / SKIP — the consolidated table ``main`` renders and
    writes to BENCH_summary.json."""
    rows = []

    def one(section: str, key: str, thr, measured, skip: bool = False):
        k = f"{section}.{key}"
        if skip:
            rows.append((k, measured, thr, "SKIP"))
        elif measured is None or not _within(measured, thr):
            rows.append((k, measured, thr, "FAIL"))
        else:
            rows.append((k, measured, thr, "PASS"))

    sim_floors = thresholds.get("simulate", {})
    for size, row in sorted(bench.get("simulate", {}).items()):
        for key, thr in sim_floors.items():
            one(f"simulate.{size}", key, thr, row.get(key))
    for section in ("straggler", "explore", "trace", "search", "mpmd",
                    "fault", "obs", "memory", "pipeline"):
        for key, thr in thresholds.get(section, {}).items():
            one(section, key, thr, bench.get(section, {}).get(key))
    par = bench.get("parallel", {})
    for key, thr in thresholds.get("parallel", {}).items():
        # a < 4-core box cannot show process-pool scaling; the identity
        # and delta floors still apply unconditionally
        skip = key.startswith("pool_speedup") and par.get("cpus", 1) < 4
        one("parallel", key, thr, par.get(key), skip=skip)
    return rows


def check(bench: dict, thresholds: dict) -> list:
    """Return a list of (key, measured, threshold) violations."""
    return [(k, m, thr) for k, m, thr, st in evaluate(bench, thresholds)
            if st == "FAIL"]


def _fmt_thr(thr) -> str:
    if isinstance(thr, dict):
        parts = []
        if "min" in thr:
            parts.append(f">= {thr['min']:g}")
        if "max" in thr:
            parts.append(f"<= {thr['max']:g}")
        return " and ".join(parts) or "?"
    return f">= {thr:g}"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default=DEFAULT_BENCH,
                    help="BENCH_sim.json path")
    ap.add_argument("--trace-bench", default=DEFAULT_TRACE_BENCH,
                    help="BENCH_trace.json path")
    ap.add_argument("--search-bench", default=DEFAULT_SEARCH_BENCH,
                    help="BENCH_search.json path")
    ap.add_argument("--mpmd-bench", default=DEFAULT_MPMD_BENCH,
                    help="BENCH_mpmd.json path")
    ap.add_argument("--fault-bench", default=DEFAULT_FAULT_BENCH,
                    help="BENCH_fault.json path")
    ap.add_argument("--parallel-bench", default=DEFAULT_PARALLEL_BENCH,
                    help="BENCH_parallel.json path")
    ap.add_argument("--obs-bench", default=DEFAULT_OBS_BENCH,
                    help="BENCH_obs.json path")
    ap.add_argument("--memory-bench", default=DEFAULT_MEMORY_BENCH,
                    help="BENCH_memory.json path")
    ap.add_argument("--pipeline-bench", default=DEFAULT_PIPELINE_BENCH,
                    help="BENCH_pipeline.json path")
    ap.add_argument("--thresholds", default=DEFAULT_THRESH)
    ap.add_argument("--run-smoke", action="store_true",
                    help="run every bench module with --smoke first to "
                         "produce the bench files")
    args = ap.parse_args(argv)

    if args.run_smoke:
        from benchmarks import (fault_scenarios, memory_timeline,
                                mpmd_pipeline, obs_overhead, parallel_dse,
                                pipeline_schedules, search_bench,
                                sim_bench, trace_roundtrip)
        sim_bench.main(["--smoke"])
        trace_roundtrip.main(["--smoke"])
        search_bench.main(["--smoke"])
        mpmd_pipeline.main(["--smoke"])
        fault_scenarios.main(["--smoke"])
        parallel_dse.main(["--smoke"])
        obs_overhead.main(["--smoke"])
        memory_timeline.main(["--smoke"])
        pipeline_schedules.main(["--smoke"])

    bench = {}
    for path, key, producer in ((args.bench, None, "sim_bench"),
                                (args.trace_bench, "trace",
                                 "trace_roundtrip"),
                                (args.search_bench, "search",
                                 "search_bench"),
                                (args.mpmd_bench, "mpmd",
                                 "mpmd_pipeline"),
                                (args.fault_bench, "fault",
                                 "fault_scenarios"),
                                (args.parallel_bench, "parallel",
                                 "parallel_dse"),
                                (args.obs_bench, "obs",
                                 "obs_overhead"),
                                (args.memory_bench, "memory",
                                 "memory_timeline"),
                                (args.pipeline_bench, "pipeline",
                                 "pipeline_schedules")):
        if not os.path.exists(path):
            print(f"check_regression: no bench file at {path} "
                  f"(run benchmarks.{producer} first, or pass --run-smoke)")
            return 2
        with open(path) as f:
            payload = json.load(f)
        if key is None:
            bench.update(payload)
        else:
            bench[key] = payload
    with open(args.thresholds) as f:
        thresholds = {k: v for k, v in json.load(f).items()
                      if not k.startswith("_")}

    rows = evaluate(bench, thresholds)
    mode = "smoke" if bench.get("smoke") else "full"
    n_fail = sum(1 for r in rows if r[3] == "FAIL")
    n_skip = sum(1 for r in rows if r[3] == "SKIP")

    width = max((len(r[0]) for r in rows), default=10)
    print(f"check_regression — {mode} run, {len(rows)} gated keys")
    for key, measured, thr, st in rows:
        shown = "missing" if measured is None else f"{measured:10.3f}"
        print(f"  {st:<4} {key:<{width}} {shown:>10}  bound {_fmt_thr(thr)}")

    from benchmarks.common import write_json
    summary_path = write_json("BENCH_summary.json", {
        "mode": mode,
        "n_pass": len(rows) - n_fail - n_skip,
        "n_fail": n_fail, "n_skip": n_skip,
        "rows": [{"key": k, "measured": m, "threshold": thr, "status": st}
                 for k, m, thr, st in rows]})
    print(f"wrote {summary_path}")

    if n_fail:
        print(f"check_regression: FAIL — {n_fail} of {len(rows)} gated "
              f"keys out of bounds ({mode} run)")
        return 1
    print(f"check_regression: OK — all {len(rows)} gated keys within "
          f"bounds ({mode} run"
          + (f", {n_skip} skipped" if n_skip else "") + ")")
    return 0


if __name__ == "__main__":
    sys.exit(main())
