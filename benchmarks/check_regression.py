"""Benchmark regression gate: fail if BENCH_sim speedup ratios or the
trace subsystem's round-trip/calibration figures fall below the floors
recorded in benchmarks/thresholds.json.

Usage (the verify recipe's perf gate):

    PYTHONPATH=.:src python -m benchmarks.sim_bench --smoke
    PYTHONPATH=.:src python -m benchmarks.trace_roundtrip --smoke
    PYTHONPATH=.:src python -m benchmarks.check_regression

or in one shot::

    PYTHONPATH=.:src python -m benchmarks.check_regression --run-smoke

Reads artifacts/bench/BENCH_sim.json and BENCH_trace.json (``--bench`` /
``--trace-bench`` to override).  The speedup floors are deliberately
conservative — they hold for both the full and ``--smoke`` matrices on a
loaded machine — so a failure means the engine actually regressed, not
that the box was busy; the trace floors are correctness contracts
(alignment, round-trip accuracy, calibration recovery).  Exit code 1 on
regression, 2 on missing inputs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(__file__)
DEFAULT_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                             "BENCH_sim.json")
DEFAULT_TRACE_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                   "BENCH_trace.json")
DEFAULT_THRESH = os.path.join(HERE, "thresholds.json")


def check(bench: dict, thresholds: dict) -> list:
    """Return a list of (key, measured, floor) violations."""
    bad = []

    def one(section: str, key: str, floor: float, measured):
        if measured is None:
            bad.append((f"{section}.{key}", None, floor))
        elif measured < floor:
            bad.append((f"{section}.{key}", measured, floor))

    sim_floors = thresholds.get("simulate", {})
    for size, row in sorted(bench.get("simulate", {}).items()):
        for key, floor in sim_floors.items():
            one(f"simulate.{size}", key, floor, row.get(key))
    for section in ("straggler", "explore", "trace"):
        for key, floor in thresholds.get(section, {}).items():
            one(section, key, floor, bench.get(section, {}).get(key))
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default=DEFAULT_BENCH,
                    help="BENCH_sim.json path")
    ap.add_argument("--trace-bench", default=DEFAULT_TRACE_BENCH,
                    help="BENCH_trace.json path")
    ap.add_argument("--thresholds", default=DEFAULT_THRESH)
    ap.add_argument("--run-smoke", action="store_true",
                    help="run `sim_bench --smoke` + `trace_roundtrip "
                         "--smoke` first to produce the bench files")
    args = ap.parse_args(argv)

    if args.run_smoke:
        from benchmarks import sim_bench, trace_roundtrip
        sim_bench.main(["--smoke"])
        trace_roundtrip.main(["--smoke"])

    if not os.path.exists(args.bench):
        print(f"check_regression: no bench file at {args.bench} "
              "(run benchmarks.sim_bench first, or pass --run-smoke)")
        return 2
    with open(args.bench) as f:
        bench = json.load(f)
    if os.path.exists(args.trace_bench):
        with open(args.trace_bench) as f:
            bench["trace"] = json.load(f)
    else:
        print(f"check_regression: no trace bench at {args.trace_bench} "
              "(run benchmarks.trace_roundtrip first, or pass --run-smoke)")
        return 2
    with open(args.thresholds) as f:
        thresholds = {k: v for k, v in json.load(f).items()
                      if not k.startswith("_")}

    bad = check(bench, thresholds)
    mode = "smoke" if bench.get("smoke") else "full"
    if bad:
        for key, measured, floor in bad:
            shown = "missing" if measured is None else f"{measured:.2f}x"
            print(f"check_regression: FAIL {key}: {shown} < floor "
                  f"{floor:.2f}x ({mode} run)")
        return 1
    print(f"check_regression: OK — all speedup floors hold ({mode} run, "
          f"{len(thresholds)} sections)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
