"""Benchmark regression gate: fail if BENCH_sim speedup ratios, the trace
subsystem's round-trip/calibration figures, the search subsystem's
sample-efficiency figures, the MPMD engine's exactness/coalescing figures,
the fault subsystem's segmented-resim/Young-Daly figures or the
parallel/delta DSE figures fall below the floors recorded in
benchmarks/thresholds.json.

Usage (the verify recipe's perf gate):

    PYTHONPATH=.:src python -m benchmarks.sim_bench --smoke
    PYTHONPATH=.:src python -m benchmarks.trace_roundtrip --smoke
    PYTHONPATH=.:src python -m benchmarks.search_bench --smoke
    PYTHONPATH=.:src python -m benchmarks.mpmd_pipeline --smoke
    PYTHONPATH=.:src python -m benchmarks.fault_scenarios --smoke
    PYTHONPATH=.:src python -m benchmarks.parallel_dse --smoke
    PYTHONPATH=.:src python -m benchmarks.check_regression

or in one shot::

    PYTHONPATH=.:src python -m benchmarks.check_regression --run-smoke

Reads artifacts/bench/BENCH_sim.json, BENCH_trace.json, BENCH_search.json,
BENCH_mpmd.json, BENCH_fault.json and BENCH_parallel.json (``--bench`` /
``--trace-bench`` / ``--search-bench`` / ``--mpmd-bench`` /
``--fault-bench`` / ``--parallel-bench`` to override).
The speedup floors are deliberately conservative — they hold for both the
full and ``--smoke`` matrices on a loaded machine — so a failure means the
engine actually regressed, not that the box was busy; the trace floors are
correctness contracts (alignment, round-trip accuracy, calibration
recovery), the search floors are the PR-4 acceptance bound
(bayesian/evolutionary within 2% of the exhaustive grid optimum on <= 25%
of its trials), the mpmd floors are the PR-5 acceptance contract
(K-identical-graph bit-identity, 64-rank two-pool coalescing speedup), the
fault floors are the PR-6 acceptance contract (segmented horizon
re-simulation >= 3x over naive, simulated optimal checkpoint interval
within 15% of Young/Daly, goodput monotone in fault rate), and the
parallel floors gate the process-pool + delta re-simulation PR
(pool_identity/delta_identity are exactness contracts enforced
everywhere; the ``pool_speedup`` floor only applies when the box reports
>= 4 usable cores, since a smaller box physically cannot show pool
scaling).  Exit code 1 on regression, 2 on missing inputs.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

HERE = os.path.dirname(__file__)
DEFAULT_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                             "BENCH_sim.json")
DEFAULT_TRACE_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                   "BENCH_trace.json")
DEFAULT_SEARCH_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                    "BENCH_search.json")
DEFAULT_MPMD_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                  "BENCH_mpmd.json")
DEFAULT_FAULT_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                   "BENCH_fault.json")
DEFAULT_PARALLEL_BENCH = os.path.join(HERE, "..", "artifacts", "bench",
                                      "BENCH_parallel.json")
DEFAULT_THRESH = os.path.join(HERE, "thresholds.json")


def check(bench: dict, thresholds: dict) -> list:
    """Return a list of (key, measured, floor) violations."""
    bad = []

    def one(section: str, key: str, floor: float, measured):
        if measured is None:
            bad.append((f"{section}.{key}", None, floor))
        elif measured < floor:
            bad.append((f"{section}.{key}", measured, floor))

    sim_floors = thresholds.get("simulate", {})
    for size, row in sorted(bench.get("simulate", {}).items()):
        for key, floor in sim_floors.items():
            one(f"simulate.{size}", key, floor, row.get(key))
    for section in ("straggler", "explore", "trace", "search", "mpmd",
                    "fault"):
        for key, floor in thresholds.get(section, {}).items():
            one(section, key, floor, bench.get(section, {}).get(key))
    par = bench.get("parallel", {})
    for key, floor in thresholds.get("parallel", {}).items():
        if key.startswith("pool_speedup") and par.get("cpus", 1) < 4:
            # a < 4-core box cannot show process-pool scaling; the
            # identity and delta floors still apply unconditionally
            continue
        one("parallel", key, floor, par.get(key))
    return bad


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--bench", default=DEFAULT_BENCH,
                    help="BENCH_sim.json path")
    ap.add_argument("--trace-bench", default=DEFAULT_TRACE_BENCH,
                    help="BENCH_trace.json path")
    ap.add_argument("--search-bench", default=DEFAULT_SEARCH_BENCH,
                    help="BENCH_search.json path")
    ap.add_argument("--mpmd-bench", default=DEFAULT_MPMD_BENCH,
                    help="BENCH_mpmd.json path")
    ap.add_argument("--fault-bench", default=DEFAULT_FAULT_BENCH,
                    help="BENCH_fault.json path")
    ap.add_argument("--parallel-bench", default=DEFAULT_PARALLEL_BENCH,
                    help="BENCH_parallel.json path")
    ap.add_argument("--thresholds", default=DEFAULT_THRESH)
    ap.add_argument("--run-smoke", action="store_true",
                    help="run `sim_bench --smoke` + `trace_roundtrip "
                         "--smoke` + `search_bench --smoke` + "
                         "`mpmd_pipeline --smoke` + `fault_scenarios "
                         "--smoke` + `parallel_dse --smoke` first to "
                         "produce the bench files")
    args = ap.parse_args(argv)

    if args.run_smoke:
        from benchmarks import (fault_scenarios, mpmd_pipeline, parallel_dse,
                                search_bench, sim_bench, trace_roundtrip)
        sim_bench.main(["--smoke"])
        trace_roundtrip.main(["--smoke"])
        search_bench.main(["--smoke"])
        mpmd_pipeline.main(["--smoke"])
        fault_scenarios.main(["--smoke"])
        parallel_dse.main(["--smoke"])

    bench = {}
    for path, key, producer in ((args.bench, None, "sim_bench"),
                                (args.trace_bench, "trace",
                                 "trace_roundtrip"),
                                (args.search_bench, "search",
                                 "search_bench"),
                                (args.mpmd_bench, "mpmd",
                                 "mpmd_pipeline"),
                                (args.fault_bench, "fault",
                                 "fault_scenarios"),
                                (args.parallel_bench, "parallel",
                                 "parallel_dse")):
        if not os.path.exists(path):
            print(f"check_regression: no bench file at {path} "
                  f"(run benchmarks.{producer} first, or pass --run-smoke)")
            return 2
        with open(path) as f:
            payload = json.load(f)
        if key is None:
            bench.update(payload)
        else:
            bench[key] = payload
    with open(args.thresholds) as f:
        thresholds = {k: v for k, v in json.load(f).items()
                      if not k.startswith("_")}

    bad = check(bench, thresholds)
    mode = "smoke" if bench.get("smoke") else "full"
    if bad:
        for key, measured, floor in bad:
            shown = "missing" if measured is None else f"{measured:.2f}x"
            print(f"check_regression: FAIL {key}: {shown} < floor "
                  f"{floor:.2f}x ({mode} run)")
        return 1
    print(f"check_regression: OK — all speedup floors hold ({mode} run, "
          f"{len(thresholds)} sections)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
