"""Heterogeneous-cluster simulation benchmark (BENCH_hetero.json).

Exercises the rank-asymmetric engine on the scenarios the rank-symmetric
model could not express:

  straggler       32-rank FSDP layer stack with ONE rank's compute slowed.
                  Collectives gate on the straggler, but compute ahead of
                  each barrier still overlaps, so a 1.5x single-rank
                  slowdown must inflate step time *strictly between* 1.0x
                  and 1.5x (the acceptance bound) — the old single-timeline
                  proxy could only scale the whole step.
  mixed_gen       DSE sweep over ``slow_chip_ratio`` (a fraction of ranks
                  from an older/derated chip generation) via dse.explore's
                  hetero hardware knobs — step time grows with the ratio.
  pod_degraded    second half of the cluster behind a degraded pod uplink
                  (``pod_link_scale``): collectives spanning both pods are
                  priced by the weakest member and barrier on the slow pod.
  coalescing      the cluster-free scaling story: a 256-rank straggler
                  cluster coalesces to a handful of rank classes, so the
                  asymmetric sim costs ~2 event loops instead of 256
                  (coalesce=False is the naive executable spec).

No jax required — graphs are built directly; runs in seconds.
"""
from __future__ import annotations

import time

from benchmarks.common import emit, write_json

from repro.configs.base import SystemConfig
from repro.core import chakra, dse
from repro.core.costmodel import (build_topology, simulate, simulate_cluster,
                                  straggler_analysis)


def fsdp_stack(n_layers: int, ranks: int) -> chakra.Graph:
    """FSDP layer stack (all-gather -> fwd -> bwd -> all-reduce per layer)
    with world-spanning collective groups."""
    g = chakra.Graph()
    group = list(range(ranks))
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=8e6, out_bytes=8e6, group=group,
                   ctrl_deps=[prev] if prev is not None else [])
        fwd = g.add(f"f{i}", chakra.COMP,
                    deps=[ag] + ([prev] if prev is not None else []),
                    flops=5e10, bytes=1e8, out_bytes=1e6)
        bwd = g.add(f"b{i}", chakra.COMP, deps=[fwd], flops=1e11,
                    bytes=2e8, out_bytes=1e6)
        g.add(f"ar{i}", chakra.COMM_COLL, deps=[bwd],
              comm_kind="all-reduce", comm_bytes=4e6, group=group)
        prev = bwd
    return g


def bench_straggler(sysc, topo, ranks: int, n_layers: int = 48):
    g = fsdp_stack(n_layers, ranks)
    slow = (1.0, 1.1, 1.25, 1.5, 2.0)
    rows = straggler_analysis(g, sysc, topo, slowdowns=slow, n_ranks=ranks)
    realized = [r["slowdown_realized"] for r in rows]
    assert realized == sorted(realized), realized
    by_f = {r["slowdown"]: r for r in rows}
    infl = by_f[1.5]["slowdown_realized"]
    # acceptance: barrier-gated but partially overlapped
    assert 1.0 < infl < 1.5, infl
    for r in rows:
        emit(f"hetero.straggler_{ranks}.x{r['slowdown']:.2f}",
             r["step_time"] * 1e6, f"{r['slowdown_realized']:.3f}x_realized")
    emit(f"hetero.straggler_{ranks}.victim_wait_ms",
         by_f[1.5]["victim_wait"] * 1e6,
         f"{by_f[1.5]['victim_wait'] * 1e3:.3f}")
    return {"n_ranks": ranks, "n_layers": n_layers, "rows": rows,
            "inflation_1p5x": infl}


def bench_mixed_generations(sysc, ranks: int, n_layers: int = 32):
    g = fsdp_stack(n_layers, ranks)
    knobs = [
        dse.Knob("slow_chip_ratio", [0.0, 0.125, 0.25, 0.5],
                 layer="hardware"),
        dse.Knob("slow_chip_scale", [0.7], layer="hardware"),
        dse.Knob("cluster_ranks", [ranks], layer="hardware"),
    ]
    trials = dse.explore(lambda cfg: g, sysc, knobs)
    by_ratio = {t.config["slow_chip_ratio"]: t for t in trials}
    steps = [by_ratio[r].objective for r in (0.0, 0.125, 0.25, 0.5)]
    assert steps == sorted(steps), steps        # more old chips -> slower
    for r, t in sorted(by_ratio.items()):
        emit(f"hetero.mixed_gen.ratio{int(r * 1000):03d}",
             t.objective * 1e6,
             f"{t.objective / steps[0]:.3f}x_vs_uniform")
    return {"n_ranks": ranks,
            "steps": {str(r): by_ratio[r].result.as_dict()
                      for r in (0.0, 0.125, 0.25, 0.5)},
            "slowdown_at_half": steps[-1] / steps[0]}


def bench_pod_degraded(sysc, topo, ranks: int, n_layers: int = 32):
    g = fsdp_stack(n_layers, ranks)
    out = {}
    prev_t = 0.0
    for scale in (1.0, 0.7, 0.5, 0.3):
        profs = dse.rank_profiles_for(ranks, {"pod_link_scale": scale})
        cr = simulate_cluster(g, sysc, topo, n_ranks=ranks,
                              rank_profiles=profs)
        out[str(scale)] = cr.as_dict()
        assert cr.step_time >= prev_t - 1e-15, (scale, cr.step_time, prev_t)
        prev_t = cr.step_time
        emit(f"hetero.pod_scale{int(scale * 100):03d}",
             cr.step_time * 1e6, f"classes={cr.n_classes}")
    return out


def bench_coalescing(sysc, ranks: int = 256, n_layers: int = 48):
    g = fsdp_stack(n_layers, ranks)
    topo = build_topology(sysc, ranks)
    from repro.core.costmodel import compile_graph
    base = compile_graph(g).durations(sysc, topo)
    comp = [n.id for n in g.nodes if n.type == chakra.COMP]
    # one straggler: rank 0's compute slowed 1.5x
    cg_durs = {0: {nid: base[nid] * 1.5 for nid in comp}}

    def run(coalesce, fresh=True):
        if fresh:                        # measure the engine, not the
            compile_graph(g)._result_cache.clear()   # per-config result memo
        return simulate_cluster(g, sysc, topo, n_ranks=ranks,
                                rank_durations=cg_durs, coalesce=coalesce)

    a = run(True)                        # warm structure/duration caches
    b = run(False)
    assert a.step_time == b.step_time and a.rank_times == b.rank_times
    t_co = min(_timed(lambda: run(True)) for _ in range(3))
    t_naive = min(_timed(lambda: run(False)) for _ in range(2))
    run(True)
    t_hit = min(_timed(lambda: run(True, fresh=False)) for _ in range(3))
    emit(f"hetero.coalesce_{ranks}", t_co * 1e6,
         f"{t_naive / t_co:.1f}x_vs_naive_{a.n_classes}_classes")
    emit(f"hetero.cluster_memo_{ranks}", t_hit * 1e6,
         f"{t_co / t_hit:.1f}x_vs_engine_cache_hit")
    return {"n_ranks": ranks, "n_classes": a.n_classes,
            "coalesced_ms": t_co * 1e3, "naive_ms": t_naive * 1e3,
            "speedup": t_naive / t_co, "memo_hit_ms": t_hit * 1e3,
            "memo_speedup": t_co / t_hit}


def _timed(fn) -> float:
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def main():
    ranks = 32
    sysc = SystemConfig(chips=ranks, topology="switch", link_bw=12.5e9)
    topo = build_topology(sysc, ranks)
    # sanity: the symmetric cluster is the plain simulate() (cluster-free)
    g = fsdp_stack(8, ranks)
    assert simulate_cluster(g, sysc, topo, n_ranks=ranks).step_time == \
        simulate(g, sysc, topo).total_time
    payload = {
        "straggler": bench_straggler(sysc, topo, ranks),
        "mixed_gen": bench_mixed_generations(sysc, ranks),
        "pod_degraded": bench_pod_degraded(sysc, topo, ranks),
        "coalescing": bench_coalescing(sysc),
    }
    path = write_json("BENCH_hetero.json", payload)
    emit("hetero.done", 0.0, path)


if __name__ == "__main__":
    main()
