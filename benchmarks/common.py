"""Shared bench plumbing: FSDP-workload capture + graph caching.

Each bench module is run in its own process (benchmarks.run spawns them) so
it can set XLA_FLAGS before importing jax.
"""
from __future__ import annotations

import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "bench")
os.makedirs(ART, exist_ok=True)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def fsdp_layer_stack_capture(n_layers: int, d_model: int, d_ff: int,
                             batch_tokens: int, ranks: int, cache_tag: str):
    """Capture an FSDP transformer-MLP-stack train step on `ranks` fake
    devices (weights sharded over data = the paper's SS6.1 workload) and
    return the Chakra graph.  Cached on disk by tag."""
    from repro.core import chakra
    path = os.path.join(ART, f"graph_{cache_tag}.json")
    if os.path.exists(path):
        return chakra.Graph.load(path)

    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import capture_step
    from repro.parallel.mesh import make_mesh

    mesh = make_mesh((ranks,), ("data",))

    def step(stack, x):
        def body(h, w):
            w1, w2 = w
            h = h + jax.nn.silu(h @ w1) @ w2
            return h, None
        h, _ = jax.lax.scan(body, x, stack)
        return jnp.mean(h.astype(jnp.float32) ** 2)

    g = jax.value_and_grad(step)
    ss = (jax.ShapeDtypeStruct((n_layers, d_model, d_ff), jnp.bfloat16),
          jax.ShapeDtypeStruct((n_layers, d_ff, d_model), jnp.bfloat16))
    xs = jax.ShapeDtypeStruct((batch_tokens, d_model), jnp.bfloat16)
    sh = ((NamedSharding(mesh, P(None, "data", None)),
           NamedSharding(mesh, P(None, "data", None))),
          NamedSharding(mesh, P("data", None)))
    cap = capture_step(g, (ss, xs), sh, mesh,
                       meta={"tag": cache_tag, "ranks": ranks})
    cap.graph.save(path)
    return cap.graph


# model-size presets for the paper's case studies (Llama-8B / 70B analogues)
PRESET_8B = dict(n_layers=32, d_model=4096, d_ff=14336)
PRESET_70B = dict(n_layers=80, d_model=8192, d_ff=28672)


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.3f},{derived}")


def write_json(name: str, payload) -> str:
    """Write a benchmark result file under artifacts/bench; returns path."""
    path = os.path.join(ART, name)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
