import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Paper Fig 8 analogue: end-to-end duration — Ground Truth vs Flint+sim.

Ground Truth = real execution on 8 host devices (wall clock).
Flint        = pre-execution capture -> Chakra graph -> event simulator with
               *CPU-calibrated* constants (matmul + collective
               microbenchmarks stand in for the paper's offline profiling,
               SS4.3).
The claim being validated: the pre-execution graph + cost model tracks the
real per-iteration duration (here: within a small factor and correct
ordering across two parallelization configs).
"""
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit  # noqa: E402


def _calibrate():
    """Measure host 'peak' flops and effective collective bandwidth."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.mesh import make_mesh

    n = 1024
    x = jnp.ones((n, n), jnp.float32)
    mm = jax.jit(lambda a: a @ a)
    mm(x).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(8):
        r = mm(x)
    r.block_until_ready()
    t_mm = (time.perf_counter() - t0) / 8
    flops = 2 * n ** 3 / t_mm                       # per-process total

    mesh = make_mesh((8,), ("data",))
    big = jax.device_put(jnp.ones((8 * 1 << 20,), jnp.float32),
                         NamedSharding(mesh, P("data")))
    ps = jax.jit(lambda v: jax.lax.with_sharding_constraint(
        jnp.broadcast_to(v.sum(), (1,)), NamedSharding(mesh, P())))
    # all-reduce-ish: sum a sharded vector to a replicated scalar is too
    # small; use a sharded->replicated all-gather instead
    ag = jax.jit(lambda v: jax.lax.with_sharding_constraint(
        v, NamedSharding(mesh, P())))
    ag(big).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(4):
        r = ag(big)
    r.block_until_ready()
    t_ag = (time.perf_counter() - t0) / 4
    bw = big.nbytes / max(t_ag, 1e-9)               # effective AG bandwidth
    return flops, bw


def _measure_real(mesh_shape, axes, shardings_fn, steps=5):
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.parallel.mesh import make_mesh

    mesh = make_mesh(mesh_shape, axes)
    L, D, F, B = 4, 1024, 3072, 256

    def step(stack, x):
        def body(h, w):
            w1, w2 = w
            h = h + jax.nn.silu(h @ w1) @ w2
            return h, None
        h, _ = jax.lax.scan(body, x, stack)
        return jnp.mean(h.astype(jnp.float32) ** 2)

    gfn = jax.value_and_grad(step)
    rng = np.random.RandomState(0)
    w_sh, x_sh = shardings_fn(mesh)
    stack = (jax.device_put(rng.randn(L, D, F).astype(np.float32) * 0.02,
                            w_sh),
             jax.device_put(rng.randn(L, F, D).astype(np.float32) * 0.02,
                            w_sh))
    x = jax.device_put(rng.randn(B, D).astype(np.float32), x_sh)
    jitted = jax.jit(gfn)
    jitted(stack, x)[0].block_until_ready()
    t0 = time.perf_counter()
    for _ in range(steps):
        l, g = jitted(stack, x)
    jax.block_until_ready(g)
    t_real = (time.perf_counter() - t0) / steps

    # capture the same program (f32 to match execution)
    from repro.core import capture_step
    ss = (jax.ShapeDtypeStruct((L, D, F), jnp.float32),
          jax.ShapeDtypeStruct((L, F, D), jnp.float32))
    xs = jax.ShapeDtypeStruct((B, D), jnp.float32)
    cap = capture_step(gfn, (ss, xs), (tuple([w_sh, w_sh]), x_sh), mesh)
    return t_real, cap.graph


def main():
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.configs.base import SystemConfig
    from repro.core.costmodel import build_topology, simulate

    flops, bw = _calibrate()
    emit("e2e.calibrated_gflops", 0.0, f"{flops / 1e9:.1f}")
    emit("e2e.calibrated_bw_gbps", 0.0, f"{bw / 1e9:.2f}")

    configs = {
        "dp8": ((8,), ("data",),
                lambda m: (NamedSharding(m, P(None, "data", None)),
                           NamedSharding(m, P("data", None)))),
        "dp4_tp2": ((4, 2), ("data", "model"),
                    lambda m: (NamedSharding(m, P(None, None, "model")),
                               NamedSharding(m, P("data", None)))),
    }
    sysc = SystemConfig(chips=8, peak_flops=flops, hbm_bw=bw * 4,
                        link_bw=bw, link_latency=20e-6, topology="switch")
    topo = build_topology(sysc, 8)
    rows = []
    for name, (shape, axes, sh_fn) in configs.items():
        t_real, graph = _measure_real(shape, axes, sh_fn)
        r = simulate(graph, sysc, topo, compute_derate=1.0)
        rows.append((name, t_real, r.total_time))
        emit(f"e2e.{name}.ground_truth_ms", t_real * 1e6, f"{t_real * 1e3:.2f}")
        emit(f"e2e.{name}.flint_sim_ms", r.total_time * 1e6,
             f"{r.total_time * 1e3:.2f}")
        emit(f"e2e.{name}.ratio", 0.0, f"{r.total_time / t_real:.2f}")
    # ordering check: sim must rank the two configs like reality
    real_order = rows[0][1] < rows[1][1]
    sim_order = rows[0][2] < rows[1][2]
    emit("e2e.ordering_preserved", 0.0, str(real_order == sim_order))


if __name__ == "__main__":
    main()
