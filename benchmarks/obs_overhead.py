"""Obs instrumentation gate: disabled overhead + blame exactness.

Two figures, gated by benchmarks/thresholds.json ``obs``:

``overhead_pct`` (ceiling, < 3%) — cost of the disabled instrumentation
primitives as a percentage of a 10k-node ``simulate``.  The primitives
early-return on one module-global load when recording is off, and they
sit at per-call granularity (per compile / engine run / trial), never
inside the per-node event loop — so the honest model is *measured
disabled primitive cost* x *primitives actually reached during one
simulate* (counted by ``Recorder.n_events`` on an enabled run) over the
simulate's wall time.  Measuring the <0.1% difference of two full
simulate timings directly would drown in scheduler noise; the model
bounds the same quantity without the noise floor.

``blame_identity`` (= 1.0) — ``obs.explain``'s component blame
(compute busy + exposed comm + barrier wait + stall) must sum to the
makespan **bit-exactly** for every rank of every randomized DAG (both
overlap modes) and of the 2-stage MPMD pipeline.

Writes artifacts/bench/BENCH_obs.json; ``--smoke`` shrinks the matrix
for CI gating.
"""
from __future__ import annotations

import argparse
import random
import time

from benchmarks.common import emit, write_json
from benchmarks.sim_bench import best_of, layered_graph

from repro.configs.base import SystemConfig
from repro.core import chakra, convert
from repro.core.costmodel.compiled import compile_graph
from repro.core.costmodel.simulator import simulate, simulate_cluster
from repro.core.costmodel.topology import build_topology
from repro.obs import record as obs
from repro.obs.explain import explain


def rand_graph(rng: random.Random, n: int) -> chakra.Graph:
    """Random DAG over all node types (the test-suite shape)."""
    g = chakra.Graph()
    for i in range(n):
        k = min(i, 4)
        deps = rng.sample(range(i), rng.randint(0, k)) if i else []
        ctrl = rng.sample(range(i), rng.randint(0, k)) if i else []
        r = rng.random()
        if r < 0.5 or i == 0:
            g.add(f"n{i}", chakra.COMP, deps=deps, ctrl_deps=ctrl,
                  flops=rng.uniform(0, 1e9), bytes=rng.uniform(0, 1e8),
                  out_bytes=rng.choice([0.0, rng.uniform(1, 100)]))
        elif r < 0.8:
            g.add(f"c{i}", chakra.COMM_COLL, deps=deps, ctrl_deps=ctrl,
                  comm_kind=rng.choice(["all-gather", "all-reduce",
                                        "reduce-scatter"]),
                  comm_bytes=rng.uniform(1, 1e7), out_bytes=8.0,
                  group=list(range(rng.choice([2, 4, 8, 16]))))
        else:
            g.add(f"m{i}", chakra.MEM, deps=deps, ctrl_deps=ctrl,
                  out_bytes=4.0)
    return g


def _disabled_primitive_ns(reps: int = 3, n: int = 100_000) -> float:
    """Worst of counter / gauge / span per-call cost while disabled, ns."""
    assert not obs.recording()

    def counters():
        for _ in range(n):
            obs.counter("bench.noop")

    def gauges():
        for _ in range(n):
            obs.gauge("bench.noop", 1.0)

    def spans():
        for _ in range(n):
            with obs.span("bench.noop"):
                pass

    return max(best_of(fn, reps=reps) for fn in
               (counters, gauges, spans)) / n * 1e9


def bench_overhead(sysc, topo, n_nodes: int = 10_000) -> dict:
    """Modeled disabled-instrumentation overhead of one n-node simulate."""
    g = layered_graph(n_nodes)
    simulate(g, sysc, topo)                       # warm all caches
    cg = compile_graph(g)
    base = cg.durations(sysc, topo)

    t_sim = best_of(lambda: cg.run(base), reps=5)

    # count the primitives one engine run actually reaches
    rec = obs.enable()
    cg.run(base)
    n_events = rec.n_events
    obs.disable()

    prim_ns = _disabled_primitive_ns()
    overhead_pct = (n_events * prim_ns * 1e-9) / t_sim * 100.0
    emit(f"obs_overhead/{n_nodes}", t_sim * 1e6,
         f"events={n_events} prim={prim_ns:.1f}ns "
         f"overhead={overhead_pct:.4f}%")
    return {"n_nodes": n_nodes, "t_sim_us": t_sim * 1e6,
            "n_events_per_sim": n_events, "primitive_ns": prim_ns,
            "overhead_pct": overhead_pct}


def bench_blame(sysc, topo, n_graphs: int, n_nodes: int, seed: int = 0) -> dict:
    """blame_identity: 1.0 iff every component blame sums to the makespan
    bit-exactly — randomized DAGs x overlap modes + a 2-stage pipeline."""
    rng = random.Random(seed)
    checked = 0
    ok = True
    for i in range(n_graphs):
        g = rand_graph(rng, n_nodes)
        for overlap in (True, False):
            res = simulate(g, sysc, topo, overlap=overlap,
                           keep_timeline=True)
            e = explain(res, graph=g, with_critical_path=False)
            ok = ok and e.identity_ok()
            checked += len(e.ranks)

    stack = layered_graph(240)
    prog = convert.split_pipeline_stages(stack, 2)
    cres = simulate_cluster(prog, sysc, topo, keep_timeline=True)
    ec = explain(cres, graph=prog, with_critical_path=False)
    ok = ok and ec.identity_ok()
    checked += len(ec.ranks)

    emit("obs_blame", 0.0,
         f"graphs={n_graphs} ranks_checked={checked} identity={ok}")
    return {"n_graphs": n_graphs, "ranks_checked": checked,
            "blame_identity": 1.0 if ok else 0.0}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced matrix for CI gating (seconds)")
    args = ap.parse_args(argv)
    sysc = SystemConfig(chips=16)
    topo = build_topology(sysc)
    t0 = time.perf_counter()
    if args.smoke:
        payload = {"smoke": True,
                   **bench_overhead(sysc, topo, n_nodes=10_000),
                   **bench_blame(sysc, topo, n_graphs=6, n_nodes=120)}
    else:
        payload = {"smoke": False,
                   **bench_overhead(sysc, topo, n_nodes=10_000),
                   **bench_blame(sysc, topo, n_graphs=25, n_nodes=300)}
    payload["elapsed_s"] = time.perf_counter() - t0
    path = write_json("BENCH_obs.json", payload)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
