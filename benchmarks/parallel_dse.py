"""Process-pool + delta re-simulation benchmark (PR "raw DSE speed").

Two scenarios, written to BENCH_parallel.json:

  pool    the 64-trial explore grid from sim_bench, serial vs
          ``parallel=4`` / ``parallel=8`` on the fork process pool, with a
          bit-identity check against the serial trial list.  ``cpus``
          records the usable core count: on a < 4-core box a process pool
          physically cannot reach the 2.5x floor, so check_regression
          enforces ``pool_speedup`` only when ``cpus >= 4`` (identity is
          enforced everywhere).

  delta   a 10k-node layered graph with 1% of duration rows perturbed.
          ``delta_speedup`` measures the tail-window scenario — changed
          rows drawn from the *late* part of the base schedule, the shape
          of transient-straggler / fault-window / optimizer-phase sweeps —
          where suffix-resume skips ~99% of the replay.  The
          scattered-uniform case is reported as
          ``delta_speedup_scattered`` for honesty: a uniformly-early
          changed row forces a near-full replay, so it hovers near 1x;
          delta's win is shape-dependent, its correctness is not.
          ``delta_identity`` is the fraction of randomized perturbation
          subsets whose delta result equals the full re-run bit for bit
          (gated at 1.0).

``--smoke`` trims reps and the identity matrix; every gated figure holds
in both modes.  No jax required; runs in seconds.
"""
from __future__ import annotations

import argparse
import random

from benchmarks.common import emit, write_json
from benchmarks.sim_bench import best_of, layered_graph

from repro.configs.base import SystemConfig
from repro.core import dse, pool
from repro.core.costmodel import DeltaBase, build_topology, compile_graph
from repro.core.costmodel.simulator import _override


def bench_pool(sysc, n: int, reps: int) -> dict:
    g = layered_graph(n)
    knobs = [
        dse.Knob("fsdp_sync", [True, False], layer="software"),
        dse.Knob("prefetch", [0, 1, 2, 4], layer="software"),
        dse.Knob("bucket_bytes", [0, 16e6], layer="software"),
        dse.Knob("link_bw", [25e9, 50e9, 100e9, 400e9], layer="hardware"),
    ]

    def run(par):
        return dse.explore(lambda cfg: g, sysc, knobs, budget=64,
                           parallel=par)

    serial = run(None)                                 # warm every cache
    identical = 1.0
    for par in (4, 8):
        got = run(par)
        if [(t.config, t.objective) for t in got] \
                != [(t.config, t.objective) for t in serial]:
            identical = 0.0
    t_ser = best_of(lambda: run(None), reps=reps)
    t_p4 = best_of(lambda: run(4), reps=reps)
    t_p8 = best_of(lambda: run(8), reps=reps)
    emit("parallel_dse.pool4", t_p4 * 1e6, f"{t_ser / t_p4:.2f}x_vs_serial")
    emit("parallel_dse.pool8", t_p8 * 1e6, f"{t_ser / t_p8:.2f}x_vs_serial")
    return {"n_nodes": n, "n_trials": 64,
            "serial_ms": t_ser * 1e3, "parallel4_ms": t_p4 * 1e3,
            "parallel8_ms": t_p8 * 1e3,
            "pool_speedup": t_ser / t_p4,
            "pool_speedup_8": t_ser / t_p8,
            "pool_identity": identical}


def bench_delta(sysc, n: int, reps: int, n_identity: int) -> dict:
    g = layered_graph(n)
    topo = build_topology(sysc)
    cg = compile_graph(g)
    base = cg.durations(sysc, topo, "auto", 0.6)
    db = DeltaBase(cg, base, n_checkpoints=64)
    n_changed = max(1, cg.n // 100)                    # 1% of rows

    # tail window: a transient straggler late in the step — the last 1%
    # of the base schedule slowed 1.3x
    tail = {nid: base[nid] * 1.3 for nid in db.schedule[-n_changed:]}
    # scattered: the same row count, uniform over the whole schedule
    rng = random.Random(0)
    scat = {nid: base[nid] * 1.3
            for nid in rng.sample(range(cg.n), n_changed)}

    t_full = best_of(lambda: cg.run(_override(base, tail)), reps=reps)
    t_tail = best_of(lambda: db.run(tail), reps=reps)
    t_fscat = best_of(lambda: cg.run(_override(base, scat)), reps=reps)
    t_scat = best_of(lambda: db.run(scat), reps=reps)

    assert db.run(tail) == cg.run(_override(base, tail))
    ok = total = 0
    for seed in range(n_identity):
        r = random.Random(100 + seed)
        for k in (0, 1, n_changed, cg.n):
            ov = {nid: base[nid] * r.uniform(0.5, 2.0)
                  for nid in r.sample(range(cg.n), k)}
            total += 1
            if db.run(ov) == cg.run(_override(base, ov)):
                ok += 1

    emit("parallel_dse.delta_tail", t_tail * 1e6,
         f"{t_full / t_tail:.1f}x_vs_full")
    emit("parallel_dse.delta_scattered", t_scat * 1e6,
         f"{t_fscat / t_scat:.2f}x_vs_full")
    return {"n_nodes": cg.n, "rows_changed": n_changed,
            "n_checkpoints": db.n_checkpoints,
            "full_ms": t_full * 1e3, "delta_tail_ms": t_tail * 1e3,
            "delta_scattered_ms": t_scat * 1e3,
            "delta_speedup": t_full / t_tail,
            "delta_speedup_scattered": t_fscat / t_scat,
            "delta_identity": ok / total, "identity_checks": total}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced matrix for CI gating (seconds)")
    args = ap.parse_args(argv)
    sysc = SystemConfig(chips=16)
    if args.smoke:
        pool_part = bench_pool(sysc, n=1_000, reps=2)
        delta_part = bench_delta(sysc, n=10_000, reps=3, n_identity=3)
    else:
        pool_part = bench_pool(sysc, n=2_000, reps=3)
        delta_part = bench_delta(sysc, n=10_000, reps=5, n_identity=10)
    payload = {"cpus": pool.cpu_count(),
               "fork_available": pool.pool_available(),
               "smoke": bool(args.smoke)}
    payload.update(pool_part)
    payload.update(delta_part)
    # n_nodes collides across the two parts; keep them distinct
    payload["n_nodes"] = {"pool": pool_part["n_nodes"],
                          "delta": delta_part["n_nodes"]}
    path = write_json("BENCH_parallel.json", payload)
    print(f"wrote {path}")
    return payload


if __name__ == "__main__":
    main()
