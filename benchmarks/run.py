# One function per paper table/figure. Prints ``name,us_per_call,derived`` CSV.
"""Benchmark harness — each module reproduces one paper figure:

  opcounts         Fig 7   operator-count validation of captured graphs
  e2e_validation   Fig 8   ground-truth vs Flint+simulator duration
  fsdp_reorder     Fig 9   AllGather reordering: duration/memory tradeoff
  bandwidth_sweep  Fig 10  reordering benefit vs interconnect bandwidth
  wafer_tacos      Fig 11  synthesized collectives on wafer-scale 2-D mesh
  nic_degradation  Fig 12  degraded-NIC detection from the workload graph
  roofline         (ours)  40-cell roofline table from the dry-run
  sim_bench        (ours)  compiled simulator/DSE engine vs seed reference
  hetero_cluster   (ours)  rank-asymmetric cluster sim: stragglers, mixed
                           chip generations, degraded pods, coalescing
  trace_roundtrip  (ours)  trace subsystem: export->ingest->validate
                           round-trip exactness + calibration recovery
  search_bench     (ours)  search strategies: trials-to-within-2%-of-grid
                           sample efficiency per strategy
  mpmd_pipeline    (ours)  true-MPMD cluster engine: K-identical-graph
                           exactness, pipeline-split step ratios,
                           64-rank two-pool coalescing speedup
  fault_scenarios  (ours)  fault-scenario subsystem: segmented-resim
                           speedup vs naive, Monte-Carlo throughput,
                           Young/Daly interval recovery, goodput
                           monotonicity
  parallel_dse     (ours)  process-pool explore speedup at 4/8 workers
                           vs serial (bit-identity checked) + delta
                           re-simulation speedup/exactness on a 10k-node
                           graph with 1% of rows perturbed
  obs_overhead     (ours)  obs instrumentation: modeled disabled-primitive
                           overhead of a 10k-node simulate (< 3% ceiling)
                           + explain() blame-sums-to-makespan exactness
  memory_timeline  (ours)  memory-timeline subsystem: bit-exact occupancy
                           curve/blame identities across engines, lean-run
                           observability overhead (< 3% ceiling), and the
                           hbm_bytes OOM-infeasible search sweep
  pipeline_schedules (ours) microbatched pipeline schedules: simulated
                           bubble vs analytic (p-1)/(m+p-1) recovery,
                           cross-replica graph-sharing speedup with
                           bit-identity, m=1 legacy-split identity
  check_regression (gate)  fails if BENCH_sim speedups, BENCH_trace
                           round-trip/calibration, BENCH_search
                           sample-efficiency, BENCH_mpmd
                           exactness/coalescing, BENCH_fault
                           segmented/recovery, BENCH_parallel pool/delta,
                           BENCH_obs overhead/blame, BENCH_memory
                           identity/overhead/OOM-sweep or BENCH_pipeline
                           bubble/coalescing figures fall
                           outside benchmarks/thresholds.json bounds;
                           writes the consolidated PASS/FAIL table to
                           BENCH_summary.json

Each bench runs in its own subprocess so it controls its fake-device count
before importing jax."""
import os
import subprocess
import sys
import time

BENCHES = ["opcounts", "e2e_validation", "fsdp_reorder", "bandwidth_sweep",
           "wafer_tacos", "nic_degradation", "roofline", "sim_bench",
           "hetero_cluster", "trace_roundtrip", "search_bench",
           "mpmd_pipeline", "fault_scenarios", "parallel_dse",
           "obs_overhead", "memory_timeline", "pipeline_schedules",
           "check_regression"]


def main() -> None:
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [root, os.path.join(root, "src"), env.get("PYTHONPATH", "")])
    failures = []
    for name in BENCHES:
        t0 = time.time()
        r = subprocess.run([sys.executable, "-m", f"benchmarks.{name}"],
                           capture_output=True, text=True, env=env,
                           cwd=root, timeout=3600)
        dt = time.time() - t0
        for line in r.stdout.splitlines():
            if line.strip():
                print(line)
        if r.returncode != 0:
            failures.append(name)
            print(f"{name}.FAILED,0,see_stderr")
            sys.stderr.write(r.stderr[-3000:] + "\n")
        print(f"{name}.wall_s,{dt * 1e6:.0f},{dt:.1f}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
