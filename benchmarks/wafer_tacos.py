import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
"""Paper Fig 11: custom collectives on wafer-scale 2-D mesh (SS6.2).

Workload: 70B-model FSDP=16 training graph.  Three system configs:
  baseline    switch fabric (NIC-class bandwidth), ring collectives
  wafer+ring  wafer 2-D mesh links (much faster), still one long ring
  wafer+tacos wafer links + topology-aware synthesized collectives
              (dimension-ordered rings; Chakra p2p expansion available)
Reported: total communication time and normalized e2e runtime.  Expected
shape: technology gives a big comm-time cut, synthesis another large factor,
but e2e gains flatten once communication stops being the bottleneck."""
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import PRESET_70B, emit, fsdp_layer_stack_capture  # noqa: E402


def main():
    from repro.configs.base import SystemConfig
    from repro.core.costmodel import build_topology, simulate
    from repro.core.costmodel.collectives import synthesize_2d_p2p
    from repro.core.costmodel.topology import Wafer2D

    ranks = 16
    g = fsdp_layer_stack_capture(
        n_layers=PRESET_70B["n_layers"], d_model=PRESET_70B["d_model"],
        d_ff=PRESET_70B["d_ff"], batch_tokens=4096 * ranks, ranks=ranks,
        cache_tag=f"70b_wafer_r{ranks}")

    cases = {
        # 100 Gbps NIC-class scale-out, flat switch
        "baseline": SystemConfig(chips=ranks, topology="switch",
                                 link_bw=12.5e9, collective_algo="ring"),
        # wafer-scale links (~50x), but a single long ring snaking the mesh
        "wafer_ring": SystemConfig(chips=ranks, topology="wafer2d",
                                   link_bw=625e9, collective_algo="ring"),
        # wafer + dimension-ordered synthesized collectives (TACOS-like)
        "wafer_tacos": SystemConfig(chips=ranks, topology="wafer2d",
                                    link_bw=625e9, collective_algo="2d_synth"),
    }
    results = {}
    for name, sysc in cases.items():
        topo = build_topology(sysc, ranks)
        r = simulate(g, sysc, topo, algo=sysc.collective_algo)
        results[name] = r
        emit(f"wafer.{name}.comm_time_ms", r.comm_time * 1e6,
             f"{r.comm_time * 1e3:.3f}")
        emit(f"wafer.{name}.total_ms", r.total_time * 1e6,
             f"{r.total_time * 1e3:.3f}")
    base = results["baseline"]
    for name, r in results.items():
        emit(f"wafer.{name}.norm_runtime", 0.0,
             f"{r.total_time / base.total_time:.4f}")
        emit(f"wafer.{name}.comm_reduction_x", 0.0,
             f"{base.comm_time / max(r.comm_time, 1e-12):.1f}")
    # paper-shape assertions
    assert results["wafer_ring"].comm_time < base.comm_time / 10
    assert results["wafer_tacos"].comm_time <= results["wafer_ring"].comm_time
    # diminishing returns: e2e gain much smaller than comm gain
    e2e_gain = base.total_time / results["wafer_tacos"].total_time
    comm_gain = base.comm_time / max(results["wafer_tacos"].comm_time, 1e-12)
    emit("wafer.e2e_gain_x", 0.0, f"{e2e_gain:.2f}")
    emit("wafer.diminishing_returns", 0.0, str(e2e_gain < comm_gain / 4))

    # p2p expansion artifact (the separate Chakra representation)
    w = Wafer2D(n_ranks=16, link_bw=625e9, link_latency=1e-6, dims=(4, 4))
    msgs = synthesize_2d_p2p("all-reduce", 1e8, list(range(16)), w)
    emit("wafer.tacos_p2p_messages", 0.0, str(len(msgs)))


if __name__ == "__main__":
    main()
