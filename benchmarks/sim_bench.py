"""Simulator/DSE engine benchmark: compiled substrate vs seed reference path.

Synthetic FSDP-layer-stack graphs (all-gather -> fwd -> bwd -> all-reduce per
layer) at 1k/10k/50k nodes.  Three scenarios, each timed best-of-reps:

  simulate.cached     repeated identical simulate() calls — the compiled
                      engine memoizes structure, durations AND the SimResult
                      (the DSE inner-loop pattern), vs the reference engine
                      which rebuilds everything per call.
  simulate.loop       duration-override calls that force a full event-loop
                      replay per call (lower bound on engine speedup: no
                      result/duration caching, only structural reuse).
  straggler           straggler_analysis (5 slowdown factors) — batched
                      duration-override replays on one compiled graph vs the
                      per-factor reference re-simulation the seed did.
  explore.64          64-trial software+hardware DSE grid via dse.explore()
                      (memoized passes + compiled engine, serial) vs the
                      seed explore loop (re-applies passes and re-simulates
                      with the reference engine per trial).

Writes BENCH_sim.json (scenario -> times and speedups) via common.write_json
and prints the usual ``name,us_per_call,derived`` CSV lines.

``--smoke`` runs a reduced matrix (1k/5k nodes, fewer reps, smaller
straggler/explore problems) in a few seconds — the payload gets
``"smoke": true`` and the same speedup keys, sized so the floors in
benchmarks/thresholds.json hold in either mode (the check_regression gate).

Note the straggler scenario compares the *cluster-barrier* analysis (one
slowed rank gating collectives, a handful of coalesced event loops per
factor) against the seed's per-factor reference resimulation of the old
single-timeline proxy — engine speedup net of the added model fidelity.

No jax required — graphs are built directly; runs in seconds.
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, write_json

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core import dse
from repro.core.costmodel import build_topology, simulate, straggler_analysis
from repro.core.costmodel.compiled import compile_graph
from repro.core.costmodel.simulator import _simulate_reference, node_duration
from repro.core.costmodel.topology import Topology


def layered_graph(n_nodes: int) -> chakra.Graph:
    """FSDP-ish layer stack, 4 nodes per layer."""
    g = chakra.Graph()
    prev = None
    for i in range(n_nodes // 4):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=8e6, out_bytes=8e6, group=list(range(16)),
                   ctrl_deps=[prev] if prev is not None else [])
        fwd = g.add(f"f{i}", chakra.COMP,
                    deps=[ag] + ([prev] if prev is not None else []),
                    flops=5e10, bytes=1e8, out_bytes=1e6)
        bwd = g.add(f"b{i}", chakra.COMP, deps=[fwd], flops=1e11,
                    bytes=2e8, out_bytes=1e6)
        g.add(f"ar{i}", chakra.COMM_COLL, deps=[bwd],
              comm_kind="all-reduce", comm_bytes=4e6, group=list(range(16)))
        prev = bwd
    return g


def best_of(fn, reps: int = 5, inner: int = 1) -> float:
    ts = []
    for _ in range(reps):
        t0 = time.perf_counter()
        for _ in range(inner):
            fn()
        ts.append((time.perf_counter() - t0) / inner)
    return min(ts)


def bench_simulate(sysc, topo: Topology, sizes=(1_000, 10_000, 50_000)):
    out = {}
    for n in sizes:
        g = layered_graph(n)
        r = simulate(g, sysc, topo)                  # warm all caches
        assert r == _simulate_reference(g, sysc, topo), "engine mismatch"
        cg = compile_graph(g)
        base = cg.durations(sysc, topo)
        ov = {0: base[0]}                            # forces event-loop run

        inner = max(1, 200_000 // n)
        t_cached = best_of(lambda: simulate(g, sysc, topo), inner=inner * 5)
        t_loop = best_of(lambda: simulate(g, sysc, topo, durations=ov),
                         inner=inner)
        t_ref = best_of(lambda: _simulate_reference(g, sysc, topo),
                        reps=3, inner=1)
        out[f"{n}"] = {
            "n_nodes": len(g),
            "reference_ms": t_ref * 1e3,
            "compiled_cached_ms": t_cached * 1e3,
            "compiled_loop_ms": t_loop * 1e3,
            "speedup_cached": t_ref / t_cached,
            "speedup_loop": t_ref / t_loop,
        }
        emit(f"sim_bench.simulate_{n}.cached", t_cached * 1e6,
             f"{t_ref / t_cached:.1f}x_vs_ref")
        emit(f"sim_bench.simulate_{n}.loop", t_loop * 1e6,
             f"{t_ref / t_loop:.1f}x_vs_ref")
    return out


def _straggler_reference(g, sysc, topo, slowdowns):
    """The seed straggler path: full reference re-simulation per factor."""
    nominal = _simulate_reference(g, sysc, topo).total_time
    rows = []
    for f in slowdowns:
        dur = {n.id: node_duration(n, sysc, topo) * f
               for n in g.nodes if n.type == chakra.COMP}
        t = _simulate_reference(g, sysc, topo, durations=dur).total_time
        rows.append(t / nominal)
    return rows


def bench_straggler(sysc, topo, n=10_000):
    g = layered_graph(n)
    slow = (1.0, 1.1, 1.25, 1.5, 2.0)
    straggler_analysis(g, sysc, topo, slowdowns=slow)      # warm
    t_new = best_of(lambda: straggler_analysis(g, sysc, topo,
                                               slowdowns=slow), reps=3)
    t_ref = best_of(lambda: _straggler_reference(g, sysc, topo, slow),
                    reps=2)
    emit(f"sim_bench.straggler_{n // 1000}k", t_new * 1e6,
         f"{t_ref / t_new:.1f}x_vs_ref")
    return {"n_nodes": n, "n_factors": len(slow),
            "reference_ms": t_ref * 1e3, "batched_ms": t_new * 1e3,
            "speedup": t_ref / t_new}


def _seed_explore(g, sysc, cfgs, objective="total_time"):
    """The seed explore loop: per-trial pass application + reference sim."""
    trials = []
    for cfg in cfgs:
        sys2 = dse._system_for(sysc, cfg)
        g2 = dse.apply_software_knobs(g, cfg)
        topo = build_topology(sys2)
        res = _simulate_reference(g2, sys2, topo, algo=sys2.collective_algo)
        trials.append(dse.Trial(cfg, res, getattr(res, objective)))
    trials.sort(key=lambda t: t.objective)
    return trials


def bench_explore(sysc, n=2_000):
    g = layered_graph(n)
    knobs = [
        dse.Knob("fsdp_sync", [True, False], layer="software"),
        dse.Knob("prefetch", [0, 1, 2, 4], layer="software"),
        dse.Knob("bucket_bytes", [0, 16e6], layer="software"),
        dse.Knob("link_bw", [25e9, 50e9, 100e9, 400e9], layer="hardware"),
    ]
    n_trials = 2 * 4 * 2 * 4
    assert n_trials == 64
    import itertools
    cfgs = [dict(c) for c in itertools.product(
        *[[(k.name, v) for v in k.values] for k in knobs])]

    def new():
        return dse.explore(lambda cfg: g, sysc, knobs, budget=n_trials)

    ref_trials = _seed_explore(g, sysc, cfgs)
    new_trials = new()                                     # warm + check
    assert [t.objective for t in new_trials] == \
        [t.objective for t in ref_trials], "explore result drift vs seed"
    t_new = best_of(new, reps=3)
    t_par = best_of(lambda: dse.explore(lambda cfg: g, sysc, knobs,
                                        budget=n_trials, parallel=4), reps=3)
    t_ref = best_of(lambda: _seed_explore(g, sysc, cfgs), reps=2)
    emit("sim_bench.explore_64", t_new * 1e6, f"{t_ref / t_new:.1f}x_vs_ref")
    return {"n_nodes": n, "n_trials": n_trials,
            "reference_ms": t_ref * 1e3, "compiled_ms": t_new * 1e3,
            "compiled_parallel4_ms": t_par * 1e3,
            "speedup": t_ref / t_new,
            "speedup_parallel4": t_ref / t_par}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced matrix for CI gating (seconds)")
    args = ap.parse_args(argv)
    sysc = SystemConfig(chips=16)
    topo = build_topology(sysc)
    if args.smoke:
        payload = {
            "smoke": True,
            "simulate": bench_simulate(sysc, topo, sizes=(1_000, 5_000)),
            "straggler": bench_straggler(sysc, topo, n=2_000),
            "explore": bench_explore(sysc, n=1_000),
        }
    else:
        payload = {
            "smoke": False,
            "simulate": bench_simulate(sysc, topo),
            "straggler": bench_straggler(sysc, topo),
            "explore": bench_explore(sysc),
        }
    path = write_json("BENCH_sim.json", payload)
    emit("sim_bench.done", 0.0, path)


if __name__ == "__main__":
    main()
