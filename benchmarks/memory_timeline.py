"""Memory-timeline gate: bit-exact identities + disabled-path overhead.

Four figures, gated by benchmarks/thresholds.json ``memory``:

``memory_identity`` (= 1.0) — both occupancy-curve contracts of
``repro.obs.memory`` must hold bit-exactly on every randomized DAG
(both overlap modes), on a heterogeneous cluster run and on the 2-stage
MPMD pipeline: (a) the weights/activations/comm class decomposition
sums to the total occupancy at every breakpoint, and (b) the curve max
equals the engine's schedule-aware ``peak_bytes``.

``overhead_pct`` (ceiling, < 3%) — cost *attributable to the
observability layer* in a lean (``keep_timeline=False``) simulate.
The engine has always run alloc/free liveness events plus a peak scan,
and the transient comm-buffer events are part of the schedule-aware
``peak_bytes`` semantics that every lean DSE trial consumes with or
without observability (their engine cost is gated by sim_bench's
wall-time floors, not here).  What the timeline feature itself adds to
a lean run is (1) the ``nid`` tag carried in every event tuple — only
blame/curve correlation needs it, the peak scan does not — and (2)
``exact_peak``'s premium over the plain float scan (~zero on the
certified integral fast path).  Measuring two full simulates differs
below the scheduler noise floor, so the model is *measured
tuple-arity delta* x *events* plus the *measured scan premium*, over
the simulate's wall time (same modeling approach as ``obs_overhead``).

``blame_coverage`` (= 1.0) — ``memory_blame``'s live tensors must fsum
to the peak bit-exactly on every checked run (coverage is total, not
best-effort).

``oom_sweep_ok`` (= 1.0) — an ``hbm_bytes``-constrained ``SearchRun``
sweep must record OOM-infeasible trials as failed (``OOMInfeasible``
error string) without crashing, exclude them from the Pareto front, and
still produce a best feasible trial.

Writes artifacts/bench/BENCH_memory.json; ``--smoke`` shrinks the
matrix for CI gating.
"""
from __future__ import annotations

import argparse
import random
import time

from benchmarks.common import emit, write_json
from benchmarks.obs_overhead import rand_graph
from benchmarks.sim_bench import best_of, layered_graph

from repro.configs.base import SystemConfig
from repro.core import convert
from repro.core.costmodel.compiled import compile_graph, exact_peak
from repro.core.costmodel.simulator import simulate, simulate_cluster
from repro.core.costmodel.topology import RankProfile, build_topology
from repro.obs.memory import memory_blame, memory_timeline


def bench_identity(sysc, topo, n_graphs: int, n_nodes: int,
                   seed: int = 0) -> dict:
    """memory_identity / blame_coverage: 1.0 iff every curve satisfies
    both bit-exact contracts and every blame covers its peak exactly —
    randomized DAGs x overlap modes, a hetero cluster, and a 2-stage
    MPMD pipeline."""
    rng = random.Random(seed)
    curves = blames = 0
    identity = coverage = True
    for _ in range(n_graphs):
        g = rand_graph(rng, n_nodes)
        for overlap in (True, False):
            res = simulate(g, sysc, topo, overlap=overlap,
                           keep_timeline=True)
            tl = memory_timeline(res, graph=g)
            identity = identity and tl.identity_ok() \
                and tl.peak_bytes == res.peak_bytes
            curves += len(tl.ranks)
            bl = memory_blame(tl, g)
            coverage = coverage and bl.identity_ok()
            blames += 1

    g = rand_graph(rng, n_nodes)
    cr = simulate_cluster(g, sysc, topo, n_ranks=8,
                          rank_profiles={1: RankProfile(compute_scale=0.5)},
                          keep_timeline=True)
    tl = memory_timeline(cr, graph=g)
    identity = identity and tl.identity_ok() and tl.peak_bytes == cr.peak_bytes
    curves += len(tl.ranks)
    coverage = coverage and memory_blame(tl, g).identity_ok()
    blames += 1

    prog = convert.split_pipeline_stages(layered_graph(240), 2)
    pr = simulate_cluster(prog, sysc, topo, keep_timeline=True)
    tlp = memory_timeline(pr, graph=prog)
    identity = identity and tlp.identity_ok() \
        and tlp.peak_bytes == pr.peak_bytes
    curves += len(tlp.ranks)
    coverage = coverage and memory_blame(tlp, prog).identity_ok()
    blames += 1

    emit("memory_identity", 0.0,
         f"graphs={n_graphs} curves={curves} identity={identity} "
         f"blame_coverage={coverage}")
    return {"n_graphs": n_graphs, "curves_checked": curves,
            "blames_checked": blames,
            "memory_identity": 1.0 if identity else 0.0,
            "blame_coverage": 1.0 if coverage else 0.0}


def _tag_ns(reps: int = 5, n: int = 200_000) -> float:
    """Per-event cost of carrying the ``nid`` tag: (t, delta, nid) triple
    vs (t, delta) pair construct+append delta, ns.  Both loops vary the
    first element so neither tuple constant-folds; the shared loop
    overhead cancels in the subtraction."""
    vals = [float(i) for i in range(n)]

    def triples():
        out = []
        ap = out.append
        for t in vals:
            ap((t, 8e6, 5))

    def pairs():
        out = []
        ap = out.append
        for t in vals:
            ap((t, 8e6))

    t3 = best_of(triples, reps=reps)
    t2 = best_of(pairs, reps=reps)
    return max(0.0, (t3 - t2) / n * 1e9)


def bench_overhead(sysc, topo, n_nodes: int = 10_000) -> dict:
    """Modeled observability-attributable overhead of one lean
    (keep_timeline=False) n-node simulate: the nid tag carried in every
    liveness event tuple + exact_peak's premium over the plain float
    scan (see module docstring for why transient comm events are
    *engine* semantics gated by sim_bench's floors instead)."""
    g = layered_graph(n_nodes)
    simulate(g, sysc, topo)                       # warm all caches
    cg = compile_graph(g)
    base = cg.durations(sysc, topo)
    t_sim = best_of(lambda: cg.run(base), reps=5)

    events = simulate(g, sysc, topo, keep_timeline=True).mem_events
    t_scan = best_of(lambda: exact_peak(events, cg._mem_integral), reps=5)

    def plain_scan():                     # the pre-exactness peak scan
        live = peak = 0.0
        for e in sorted(events):
            live += e[1]
            if live > peak:
                peak = live
        return peak

    t_plain = best_of(plain_scan, reps=5)
    n_transient = sum(1 for e in events if e[2] < 0)
    tag_ns = _tag_ns()
    marginal_s = len(events) * tag_ns * 1e-9 + max(0.0, t_scan - t_plain)
    overhead_pct = marginal_s / t_sim * 100.0
    emit(f"memory_overhead/{n_nodes}", t_sim * 1e6,
         f"events={len(events)} transient={n_transient} "
         f"tag={tag_ns:.1f}ns scan={t_scan * 1e6:.1f}us "
         f"plain={t_plain * 1e6:.1f}us overhead={overhead_pct:.3f}%")
    return {"n_nodes": n_nodes, "t_sim_us": t_sim * 1e6,
            "n_mem_events": len(events), "n_transient_events": n_transient,
            "tag_ns": tag_ns, "scan_us": t_scan * 1e6,
            "plain_scan_us": t_plain * 1e6, "overhead_pct": overhead_pct}


def bench_oom_sweep(sysc) -> dict:
    """oom_sweep_ok: an hbm_bytes-constrained search records infeasible
    trials (error, no crash), keeps them off the Pareto front, and still
    ranks the feasible ones."""
    from repro.core.dse import Knob
    from repro.search.run import SearchRun

    def graph_for(cfg):
        return layered_graph(60)

    knobs = [Knob("prefetch", [0, 2, 4]),
             Knob("hbm_bytes", [1e3, 1e15], layer="hardware")]
    r = SearchRun(graph_for, sysc, knobs, strategy="grid", budget=6,
                  objectives=("total_time", "peak_memory_bytes")).run()
    failed = r.failed_trials
    ok = (len(r.trials) == 6 and len(failed) == 3
          and all(t.error.startswith("OOMInfeasible:") for t in failed)
          and all(t.config["hbm_bytes"] == 1e15 for t in r.pareto_trials())
          and r.best is not None and r.best.ok)
    emit("memory_oom_sweep", 0.0,
         f"trials={len(r.trials)} infeasible={len(failed)} ok={ok}")
    return {"oom_trials": len(failed),
            "oom_sweep_ok": 1.0 if ok else 0.0}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced matrix for CI gating (seconds)")
    args = ap.parse_args(argv)
    sysc = SystemConfig(chips=16)
    topo = build_topology(sysc)
    t0 = time.perf_counter()
    if args.smoke:
        payload = {"smoke": True,
                   **bench_identity(sysc, topo, n_graphs=6, n_nodes=120),
                   **bench_overhead(sysc, topo, n_nodes=10_000),
                   **bench_oom_sweep(sysc)}
    else:
        payload = {"smoke": False,
                   **bench_identity(sysc, topo, n_graphs=25, n_nodes=300),
                   **bench_overhead(sysc, topo, n_nodes=10_000),
                   **bench_oom_sweep(sysc)}
    payload["elapsed_s"] = time.perf_counter() - t0
    path = write_json("BENCH_memory.json", payload)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
