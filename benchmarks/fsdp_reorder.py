import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=64")
"""Paper Fig 9: FSDP AllGather reordering — duration/memory tradeoff across
model size and parallelization degree.

For each (model size, ranks) we capture ONE workload graph (true data deps),
then apply the two schedules as graph passes:
  sync    = original FSDP (AllGather serialized after previous compute)
  reorder = SimpleFSDP prefetch (AllGathers hoisted k layers early)
and report duration reduction % vs memory increase % from the simulator.
Paper's claims to reproduce: large benefit at small-model/high-rank (50%
at 8B x 64), small benefit at large-model (7% at 70B x 8), always at a
modest memory cost.
"""
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import (PRESET_70B, PRESET_8B, emit,
                               fsdp_layer_stack_capture)  # noqa: E402


def run_case(tag, preset, ranks, tokens_per_rank=4096):
    from repro.configs.base import SystemConfig
    from repro.core import passes
    from repro.core.costmodel import build_topology, simulate

    g = fsdp_layer_stack_capture(
        n_layers=preset["n_layers"], d_model=preset["d_model"],
        d_ff=preset["d_ff"], batch_tokens=tokens_per_rank * ranks,
        ranks=ranks, cache_tag=f"{tag}_r{ranks}")
    # the paper's cluster: H100 nodes over one 100 Gbps IB HCA per node
    sysc = SystemConfig(chips=ranks, topology="switch", link_bw=12.5e9)
    topo = build_topology(sysc, ranks)
    g_sync = passes.inject_fsdp_sync(g)
    r_sync = simulate(g_sync, sysc, topo)
    out = {}
    for pf, label in ((2, "reorder"), (10 ** 6, "full_prefetch")):
        g_re = passes.reorder_prefetch(g_sync, prefetch=pf)
        r_re = simulate(g_re, sysc, topo)
        dur_red = (r_sync.total_time - r_re.total_time) \
            / r_sync.total_time * 100
        mem_inc = (r_re.peak_bytes - r_sync.peak_bytes) / max(
            r_sync.peak_bytes, 1.0) * 100
        emit(f"fsdp_reorder.{tag}_r{ranks}.{label}.duration_reduction_pct",
             0.0, f"{dur_red:.1f}")
        emit(f"fsdp_reorder.{tag}_r{ranks}.{label}.memory_increase_pct",
             0.0, f"{mem_inc:.1f}")
        out[label] = (dur_red, mem_inc)
    emit(f"fsdp_reorder.{tag}_r{ranks}.sync_ms", r_sync.total_time * 1e6,
         f"{r_sync.total_time * 1e3:.2f}")
    return out


def main():
    res = {}
    for tag, preset, ranks_list in (("8b", PRESET_8B, (8, 64)),
                                    ("70b", PRESET_70B, (8, 64))):
        for ranks in ranks_list:
            res[(tag, ranks)] = run_case(tag, preset, ranks)
    # paper-shape assertions (Fig 9): the reorder schedule buys a large
    # duration cut for a small memory cost; prefetching *everything* buys
    # much more memory for less benefit (why SimpleFSDP bounds prefetch)
    d, m = res[("8b", 64)]["reorder"]
    assert d > 10.0 and m < 10.0, (d, m)
    d70, m70 = res[("70b", 8)]["reorder"]
    assert d70 > 0.0, d70
    for key, case in res.items():
        assert case["full_prefetch"][1] > case["reorder"][1], key
    emit("fsdp_reorder.tradeoff_reproduced", 0.0, "True")


if __name__ == "__main__":
    main()
