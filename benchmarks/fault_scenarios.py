"""Fault-scenario subsystem benchmark: segmented re-simulation speedup,
Monte-Carlo throughput, Young/Daly optimal-interval recovery, and the
goodput-monotonicity contract.

  fault.segmented_speedup   wall-time win of segmented horizon simulation
                            (signature + engine memos) over the naive
                            baseline that re-runs the cluster engine for
                            every same-rate segment (``memoize=False``)
  fault.mc_trials_per_sec   seeded Monte-Carlo horizon trials per second
                            on the 16-rank FSDP stack
  fault.young_daly_recovery min over (MTBF, checkpoint-cost) settings of
                            1 - |tau_sim - tau_YD| / tau_YD: how closely
                            the simulated optimal checkpoint interval
                            recovers the Young/Daly closed form
  fault.goodput_monotone    1.0 iff expected goodput is non-increasing
                            along a fault-rate ladder (rate-coupled
                            scenario sampling makes this exact)

Writes BENCH_fault.json; ``check_regression.py`` floors the figures via
the ``fault`` section of thresholds.json (segmented_speedup >= 3x is the
ISSUE acceptance bound, young_daly_recovery >= 0.85 is the 15% tolerance).
``--smoke`` shrinks horizons/trial counts, not the contracts — the floors
hold in both modes.  No jax required; runs in seconds.
"""
from __future__ import annotations

import argparse
import math
import time

from benchmarks.common import emit, write_json
from benchmarks.hetero_cluster import fsdp_stack

from repro.configs.base import SystemConfig
from repro.core.costmodel import build_topology, simulate_cluster
from repro.faults import (CheckpointPolicy, FaultEvent, FaultRates,
                          FaultScenario, monte_carlo, simulate_horizon,
                          young_daly_interval)

RANKS = 16
SEED = 3


def _windowed_scenario(s0: float, n_steps: int) -> FaultScenario:
    """Alternating slowdown / link-degrade windows: many segments, few
    distinct signatures — the segmented engine's best case and the naive
    engine's worst."""
    evs = []
    t = 5 * s0
    for i in range(n_steps // 20):
        if i % 2 == 0:
            evs.append(FaultEvent(t, "slowdown", rank=i % RANKS,
                                  duration=8 * s0, magnitude=2.0))
        else:
            evs.append(FaultEvent(t, "link_degrade", rank=i % RANKS,
                                  duration=8 * s0, magnitude=0.5))
        t += 20 * s0
    return FaultScenario(evs, horizon=1e12, n_ranks=RANKS)


def bench_segmented(g, sysc, topo, s0, n_steps):
    sc = _windowed_scenario(s0, n_steps)
    pol = CheckpointPolicy(interval=50, write_cost=s0)
    kw = dict(topo=topo, n_ranks=RANKS, n_steps=n_steps)
    ref = simulate_horizon(g, sysc, sc, pol, **kw)          # warm the memos
    t0 = time.perf_counter()
    seg = simulate_horizon(g, sysc, sc, pol, **kw)
    t_seg = time.perf_counter() - t0
    t0 = time.perf_counter()
    naive = simulate_horizon(g, sysc, sc, pol, memoize=False, **kw)
    t_naive = time.perf_counter() - t0
    assert naive.as_dict() == seg.as_dict() == ref.as_dict(), \
        "memoization changed the physics"
    return t_naive / t_seg, seg.n_segments, seg.n_signatures


def bench_monte_carlo(g, sysc, topo, s0, n_trials, n_steps):
    rates = FaultRates(fail_rate=1.0 / (200 * s0), fail_downtime=50 * s0,
                       slowdown_rate=1.0 / (100 * s0))
    pol = CheckpointPolicy(interval=20, write_cost=s0, restore_cost=2 * s0)
    t0 = time.perf_counter()
    mc = monte_carlo(g, sysc, rates, pol, topo=topo, n_ranks=RANKS,
                     n_steps=n_steps, n_trials=n_trials, seed=SEED)
    dt = time.perf_counter() - t0
    return n_trials / dt, mc


def bench_young_daly(g, sysc, topo, s0, n_trials):
    """Simulated optimal interval vs the closed form, two (MTBF, C)
    settings, common random numbers across every interval arm."""
    worst = 1.0
    rows = {}
    for mtbf_steps, c_steps in ((400, 2), (1600, 8)):
        mtbf, cost = mtbf_steps * s0, c_steps * s0
        horizon = 30.0 * mtbf
        rates = FaultRates(fail_rate=1.0 / mtbf, fail_downtime=0.5 * cost)
        scen = [FaultScenario.sample(rates, horizon, RANKS, seed=(SEED, i))
                for i in range(n_trials)]
        i_yd = young_daly_interval(cost, mtbf) / s0
        grid = sorted({max(1, round(i_yd * 1.08 ** k))
                       for k in range(-9, 10)})
        best_i, best_g = None, -1.0
        for interval in grid:
            mc = monte_carlo(g, sysc, rates,
                             CheckpointPolicy(interval=interval,
                                              write_cost=cost,
                                              restore_cost=2 * cost),
                             topo=topo, n_ranks=RANKS, wall_limit=horizon,
                             scenarios=scen)
            if mc.expected_goodput > best_g:
                best_g, best_i = mc.expected_goodput, interval
        err = abs(best_i - i_yd) / i_yd
        worst = min(worst, 1.0 - err)
        rows[f"mtbf{mtbf_steps}_c{c_steps}"] = {
            "young_daly_interval": i_yd, "simulated_interval": best_i,
            "error": err, "expected_goodput": best_g}
    return worst, rows


def bench_monotone(g, sysc, topo, s0, n_trials, n_steps):
    pol = CheckpointPolicy(interval=20, write_cost=s0, restore_cost=2 * s0)
    last = math.inf
    ladder = []
    for r in (1e-9, 1e-3, 1e-2, 0.05, 0.1):
        mc = monte_carlo(g, sysc,
                         FaultRates(fail_rate=r / s0,
                                    fail_downtime=50 * s0),
                         pol, topo=topo, n_ranks=RANKS, n_steps=n_steps,
                         n_trials=n_trials, seed=7)
        ladder.append((r, mc.expected_goodput))
        if mc.expected_goodput > last + 1e-12:
            return 0.0, ladder
        last = mc.expected_goodput
    return 1.0, ladder


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="shorter horizons + fewer MC trials (seconds)")
    args = ap.parse_args(argv)

    seg_steps = 400 if args.smoke else 2000
    mc_trials = 8 if args.smoke else 16
    yd_trials = 24 if args.smoke else 32

    g = fsdp_stack(8 if args.smoke else 16, ranks=RANKS)
    sysc = SystemConfig(chips=RANKS, topology="switch")
    topo = build_topology(sysc)
    s0 = float(simulate_cluster(g, sysc, topo, n_ranks=RANKS).total_time)
    emit("fault.nominal_step_ms", s0 * 1e6, f"{s0 * 1e3:.3f}")

    speedup, n_seg, n_sig = bench_segmented(g, sysc, topo, s0, seg_steps)
    emit("fault.segments", 0.0, str(n_seg))
    emit("fault.signatures", 0.0, str(n_sig))
    emit("fault.segmented_speedup", 0.0, f"{speedup:.1f}x")

    tps, mc = bench_monte_carlo(g, sysc, topo, s0, mc_trials,
                                200 if args.smoke else 400)
    emit("fault.mc_trials_per_sec", 0.0, f"{tps:.1f}")
    emit("fault.mc_expected_goodput", 0.0, f"{mc.expected_goodput:.4f}")
    emit("fault.mc_p99_step_ms", mc.p99_step_time * 1e6,
         f"{mc.p99_step_time * 1e3:.3f}")

    recovery, yd_rows = bench_young_daly(g, sysc, topo, s0, yd_trials)
    for name, row in yd_rows.items():
        emit(f"fault.young_daly.{name}", 0.0,
             f"sim={row['simulated_interval']}"
             f"_yd={row['young_daly_interval']:.1f}"
             f"_err={row['error']:.1%}")
    emit("fault.young_daly_recovery", 0.0, f"{recovery:.3f}")

    monotone, ladder = bench_monotone(g, sysc, topo, s0, 6,
                                      60 if args.smoke else 100)
    emit("fault.goodput_monotone", 0.0, f"{monotone:.0f}")

    payload = {"smoke": bool(args.smoke), "seed": SEED,
               "nominal_step_time": s0,
               "segmented_speedup": speedup,
               "n_segments": n_seg, "n_signatures": n_sig,
               "mc_trials_per_sec": tps,
               "mc": mc.as_dict(),
               "young_daly": yd_rows,
               "young_daly_recovery": recovery,
               "goodput_monotone": monotone,
               "goodput_ladder": ladder}
    path = write_json("BENCH_fault.json", payload)
    emit("fault.bench_json", 0.0, path)


if __name__ == "__main__":
    main()
