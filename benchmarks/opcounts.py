import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")
"""Paper Fig 7 analogue: operator-count validation of the captured graph.

PyTorch-Flint compares FX-captured graphs against post-execution Chakra
traces.  In JAX the compiled module *is* what executes, so the equivalent
check validates the capture/conversion chain itself:
  source-level op counts (jaxpr/StableHLO, per layer, analytic)
vs
  Flint-parsed per-device counts from the compiled HLO (trip-count-aware).
Ratios ~1.0 for the op classes that matter (GeMM, collectives); bars that
deviate correspond to backend decomposition differences — mirroring the
paper's 'miscellaneous op' deltas (SS5.2).
"""
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import emit  # noqa: E402


def main():
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import capture_step, stablehlo_op_counts
    from repro.parallel.mesh import make_mesh

    mesh = make_mesh((4, 4), ("data", "model"))
    L, D, F, B = 6, 512, 1536, 64

    def step(stack, x):
        def body(h, w):
            w1, w2 = w
            h = h + jax.nn.silu(h @ w1) @ w2
            return h, None
        h, _ = jax.lax.scan(body, x, stack)
        return jnp.mean(h.astype(jnp.float32) ** 2)

    g = jax.value_and_grad(step)
    ss = (jax.ShapeDtypeStruct((L, D, F), jnp.bfloat16),
          jax.ShapeDtypeStruct((L, F, D), jnp.bfloat16))
    xs = jax.ShapeDtypeStruct((B, D), jnp.bfloat16)
    sh = ((NamedSharding(mesh, P(None, None, "model")),
           NamedSharding(mesh, P(None, "model", None))),
          NamedSharding(mesh, P("data", None)))
    cap = capture_step(g, (ss, xs), sh, mesh, build_graph=True)

    # source level: jaxpr counts (scan body x L)
    src = stablehlo_op_counts(cap.lowered_text)
    src_dots_per_layer = 2          # w1 and w2 matmuls (fwd)
    expected_dots = L * src_dots_per_layer * 3   # fwd + dgrad + wgrad

    parsed_dots = 0
    parsed_colls = {}
    from repro.core.hlo_parse import parse_hlo, walk_instructions
    mod = parse_hlo(cap.compiled_text)
    for ins, mult, comp in walk_instructions(mod):
        if ins.opcode == "dot":
            parsed_dots += mult
        if ins.is_collective:
            k = ins.collective_kind
            parsed_colls[k] = parsed_colls.get(k, 0) + mult

    ratio_gemm = parsed_dots / expected_dots
    # TP fwd: 1 all-reduce per layer (row-parallel w2 output) = L; bwd adds
    # the mirrored reductions -> expect ~2L..3L total among model-axis ARs
    ar = parsed_colls.get("all-reduce", 0)
    emit("opcounts.gemm_ratio", 0.0, f"{ratio_gemm:.3f}")
    emit("opcounts.dots_expected", 0.0, str(expected_dots))
    emit("opcounts.dots_parsed", 0.0, str(parsed_dots))
    emit("opcounts.allreduce_per_layer", 0.0, f"{ar / L:.2f}")
    emit("opcounts.src_stablehlo_dots", 0.0,
         str(src.get("dot_general", 0)))
    ok = 0.9 <= ratio_gemm <= 1.4
    emit("opcounts.validated", 0.0, str(ok))
    assert ok, f"gemm ratio {ratio_gemm}"


if __name__ == "__main__":
    main()
