"""Search-strategy sample efficiency on the FSDP-reorder space.

Exhaustive grid over (prefetch x bucket_bytes x link_bw) — the paper Fig 9
software/hardware co-design space, 96 configs on a synthetic FSDP layer
stack — establishes the true optimum; each registered strategy then gets a
budget of 25% of the grid and is scored on

  best_gap_pct     best-found objective vs the grid optimum (%)
  trials_to_2pct   evaluations (any fidelity) until within 2% of optimum
  efficiency       grid_size / trials_to_2pct (x fewer trials than grid;
                   0 when the budget never got within 2%)
  within_2pct      1.0 if the budgeted run reached the 2% band

Writes BENCH_search.json; ``check_regression.py`` floors
``bayesian_*``/``evolutionary_*`` at the ISSUE acceptance bound (within 2%
of the grid optimum using <= 25% of grid's trials => efficiency >= 4).
``random`` and ``halving`` are reported unfloored: random is luck (seeded
here), halving spends most of its budget on proxy-fidelity rungs by design.

``--smoke`` shrinks the graph (not the space), so the floors hold in both
modes.  No jax required; runs in seconds.
"""
from __future__ import annotations

import argparse

from benchmarks.common import emit, write_json
from benchmarks.hetero_cluster import fsdp_stack

from repro.configs.base import SystemConfig
from repro.core import dse
from repro.search import SearchRun

SEED = 2
STRATEGIES = ("random", "bayesian", "evolutionary", "halving")


def fsdp_reorder_knobs():
    return [dse.Knob("fsdp_sync", [True]),
            dse.Knob("prefetch", [0, 1, 2, 4, 8, 16]),
            dse.Knob("bucket_bytes", [None, 16e6, 64e6, 256e6]),
            dse.Knob("link_bw", [12.5e9, 25e9, 50e9, 100e9],
                     layer="hardware")]


def score_strategy(strategy: str, g, sysc, knobs, budget: int,
                   optimum: float, grid_size: int):
    run = SearchRun(lambda cfg: g, sysc, knobs, strategy=strategy,
                    budget=budget, seed=SEED)
    res = run.run()
    band = optimum * 1.02
    best = min((t.objectives["total_time"] for t in res.full_trials),
               default=float("inf"))
    trials_to = 0
    for i, t in enumerate(res.trials):
        if t.is_full and t.objectives["total_time"] <= band:
            trials_to = i + 1            # count every evaluation spent
            break
    return {
        "best": best,
        "best_gap_pct": (best - optimum) / optimum * 100.0,
        "n_trials": len(res.trials),
        "n_full_trials": len(res.full_trials),
        "trials_to_2pct": trials_to,
        "within_2pct": 1.0 if trials_to else 0.0,
        "efficiency": (grid_size / trials_to) if trials_to else 0.0,
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="smaller graph, same space (seconds)")
    args = ap.parse_args(argv)

    n_layers = 8 if args.smoke else 24
    g = fsdp_stack(n_layers, ranks=16)   # the canonical FSDP layer stack
    sysc = SystemConfig(chips=16, topology="switch")
    knobs = fsdp_reorder_knobs()

    grid = dse.explore(lambda cfg: g, sysc, knobs)
    grid_size = len(grid)
    optimum = grid[0].objective
    budget = grid_size // 4
    emit("search.grid.size", 0.0, str(grid_size))
    emit("search.grid.best_ms", optimum * 1e6, f"{optimum * 1e3:.3f}")
    emit("search.budget", 0.0, str(budget))

    payload = {"smoke": bool(args.smoke), "seed": SEED,
               "grid_size": grid_size, "grid_best": optimum,
               "budget": budget, "per_strategy": {}}
    for strat in STRATEGIES:
        row = score_strategy(strat, g, sysc, knobs, budget, optimum,
                             grid_size)
        payload["per_strategy"][strat] = row
        payload[f"{strat}_within_2pct"] = row["within_2pct"]
        payload[f"{strat}_efficiency"] = row["efficiency"]
        emit(f"search.{strat}.best_gap_pct", 0.0,
             f"{row['best_gap_pct']:.2f}")
        emit(f"search.{strat}.trials_to_2pct", 0.0,
             str(row["trials_to_2pct"]))
        emit(f"search.{strat}.efficiency_x", 0.0,
             f"{row['efficiency']:.1f}")

    # acceptance bound (also floored by check_regression): bayesian and
    # evolutionary reach within 2% of the exhaustive optimum on <= 25% of
    # grid's trial count
    for strat in ("bayesian", "evolutionary"):
        row = payload["per_strategy"][strat]
        assert row["within_2pct"] == 1.0, (strat, row)
        assert row["efficiency"] >= 4.0, (strat, row)

    path = write_json("BENCH_search.json", payload)
    emit("search.bench_json", 0.0, path)


if __name__ == "__main__":
    main()
