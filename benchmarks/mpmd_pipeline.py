"""MPMD cluster-engine benchmark -> BENCH_mpmd.json (gated by
benchmarks/check_regression.py "mpmd" floors).

Three figures:

  identity          1.0 iff K identical graphs under the MPMD engine are
                    bit-identical to single-graph ``simulate_cluster`` and
                    to ``simulate()`` (the PR's acceptance contract — an
                    exactness gate, not a speedup).
  split_ratio_S     pipeline-split step time vs the 1-stage baseline for
                    S in {2, 4}: the same chips repartitioned into S
                    stages x (ranks/S) DP replicas via
                    ``convert.split_pipeline_stages`` (recorded for the
                    EXPERIMENTS narrative; workload-dependent, no floor).
  coalesce_speedup  wall-time speedup of graph+profile rank coalescing on
                    a 64-rank two-pool MPMD program (32 training ranks +
                    32 serving ranks stitched by a cluster-wide sync
                    collective) vs the naive one-row-per-rank engine.

Usage: python -m benchmarks.mpmd_pipeline [--smoke]
"""
from __future__ import annotations

import argparse
import time

from benchmarks.common import emit, write_json


def fsdp_stack(n_layers: int, group, flops: float = 5e10):
    """FSDP-style layer stack whose collectives span `group` (literal rank
    ids — the MPMD reading)."""
    from repro.core import chakra

    g = chakra.Graph()
    group = list(group)
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=8e6, out_bytes=8e6, group=group,
                   ctrl_deps=[prev] if prev is not None else [])
        fwd = g.add(f"f{i}", chakra.COMP,
                    deps=[ag] + ([prev] if prev is not None else []),
                    flops=flops, bytes=1e8, out_bytes=1e6)
        bwd = g.add(f"b{i}", chakra.COMP, deps=[fwd], flops=2 * flops,
                    bytes=2e8, out_bytes=1e6)
        g.add(f"ar{i}", chakra.COMM_COLL, deps=[bwd],
              comm_kind="all-reduce", comm_bytes=4e6, group=group)
        prev = bwd
    return g


def two_pool_program(n_layers: int, K: int):
    """Ranks [0, K/2) train, ranks [K/2, K) serve a lighter stack; one
    cluster-wide all-reduce per program stitches the pools (weight sync)."""
    from repro.core import chakra
    from repro.core.costmodel import MPMDProgram

    half = K // 2
    g_train = fsdp_stack(n_layers, range(half))
    g_serve = fsdp_stack(n_layers, range(half, K), flops=5e8)
    for g in (g_train, g_serve):
        last = len(g.nodes) - 1
        g.add("pool_sync", chakra.COMM_COLL, deps=[last],
              comm_kind="all-reduce", comm_bytes=1e6, group=list(range(K)))
    return MPMDProgram([g_train] * half + [g_serve] * (K - half))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for the CI gate")
    args = ap.parse_args(argv)

    from repro.configs.base import SystemConfig
    from repro.core.convert import split_pipeline_stages
    from repro.core.costmodel import build_topology, simulate, simulate_cluster

    n_layers = 8 if args.smoke else 24
    reps = 3 if args.smoke else 5
    ranks = 8
    sysc = SystemConfig(chips=ranks, topology="switch")
    topo = build_topology(sysc, ranks)
    payload = {"smoke": bool(args.smoke)}

    # -- identity: K identical graphs == SPMD engine == simulate() ---------
    g = fsdp_stack(n_layers, range(ranks))
    ref = simulate(g, sysc, topo, keep_timeline=True)
    identical = 1.0
    for K in (2, 4):
        mp = simulate_cluster([g] * K, sysc, topo, keep_timeline=True)
        sp = simulate_cluster(g, sysc, topo, n_ranks=K, keep_timeline=True)
        for r in range(K):
            mr = mp.rank_result(r)
            ok = (mr.total_time == ref.total_time == sp.step_time
                  and mr.timeline == ref.timeline
                  and mp.step_time == sp.step_time)
            if not ok:
                identical = 0.0
    payload["identity"] = identical
    emit("mpmd.identity", 0.0, f"{identical:.0f}")

    # -- pipeline split ratio vs 1-stage baseline --------------------------
    base = simulate(g, sysc, topo).total_time
    for S in (2, 4):
        t0 = time.perf_counter()
        prog = split_pipeline_stages(g, S, replicas=ranks // S)
        cr = simulate_cluster(prog, sysc, topo)
        dt = (time.perf_counter() - t0) * 1e6
        ratio = cr.step_time / base
        payload[f"split_ratio_{S}"] = ratio
        payload[f"split_step_ms_{S}"] = cr.step_time * 1e3
        emit(f"mpmd.pipeline_{S}stage", dt,
             f"step={cr.step_time * 1e3:.3f}ms ratio={ratio:.3f}")
    payload["baseline_step_ms"] = base * 1e3

    # -- coalescing speedup on a 64-rank two-pool MPMD program -------------
    K = 64
    prog = two_pool_program(n_layers, K)
    simulate_cluster(prog, sysc, topo)         # warm compile/duration caches

    def timed(coalesce):
        t0 = time.perf_counter()
        for _ in range(reps):
            cr = simulate_cluster(prog, sysc, topo, coalesce=coalesce)
        return (time.perf_counter() - t0) / reps, cr

    t_co, cr_co = timed(True)
    t_naive, cr_naive = timed(False)
    assert cr_co.rank_times == cr_naive.rank_times, "coalesce != naive!"
    speedup = t_naive / t_co if t_co > 0 else 0.0
    payload["coalesce_speedup"] = speedup
    payload["coalesce_n_classes"] = cr_co.n_classes
    payload["coalesce_ms"] = t_co * 1e3
    payload["naive_ms"] = t_naive * 1e3
    emit("mpmd.coalescing_64rank", t_co * 1e6,
         f"{speedup:.1f}x_vs_naive_classes={cr_co.n_classes}")

    path = write_json("BENCH_mpmd.json", payload)
    emit("mpmd.bench_file", 0.0, path)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
