"""Pipeline-schedule gate: analytic-bubble recovery, cross-replica graph
sharing speedup, and m=1 bit-identity.

Three figures, gated by benchmarks/thresholds.json ``pipeline``:

``bubble_recovery`` (>= 0.9) — worst-case agreement between the
*simulated* aggregate bubble fraction of a balanced explicit f/b chain
pipeline and the textbook (p-1)/(m+p-1), over a (p, m) grid x
{gpipe, 1f1b}, scored as min(sim, analytic) / max(sim, analytic).  The
schedule semantics are emergent (lowering + MPMD engine, no formula in
the hot path), so this is the PR-10 conformance acceptance bound: every
grid point within ~10%.

``coalesce_speedup`` (>= 3.0) — wall-time win of cross-replica graph
sharing (``share_replica_graphs=True``: R replicas of a p-stage pipeline
= p graphs with relative p2p addressing, coalesced to p event-loop rows)
vs literal per-replica graphs (p*R graphs / rows) on an R=16, p=4, m=8
GPipe pipeline, memoization off.  Results must be bit-identical — the
speedup only counts if ``coalesce_identity`` holds.

``m1_identity`` (= 1.0) — ``num_microbatches=1`` under EVERY schedule
name must produce node-by-node identical rank graphs to the legacy
one-wave split and the same simulated step time, bit-exactly.

Writes artifacts/bench/BENCH_pipeline.json; ``--smoke`` shrinks the
grid for CI gating.
"""
from __future__ import annotations

import argparse
import math
import time

from benchmarks.common import emit, write_json
from benchmarks.sim_bench import best_of

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.convert import split_pipeline_stages
from repro.core.costmodel import build_topology, simulate_cluster
from repro.core.costmodel.schedule import (SCHEDULES,
                                           analytic_bubble_fraction,
                                           bubble_fraction)


def fb_chain(p, f_flops=1e12, b_flops=2e12, payload=8.0):
    """Balanced explicit f/b chain (one forward + one backward node per
    stage, uniform cost, near-zero payloads) — the workload shape the
    analytic bubble formula assumes."""
    g = chakra.Graph()
    f = []
    for s in range(p):
        f.append(g.add(f"f{s}", chakra.COMP, deps=[f[-1]] if f else [],
                       flops=f_flops, out_bytes=payload))
    b_prev = None
    for s in reversed(range(p)):
        deps = [f[s]] + ([b_prev] if b_prev is not None else [])
        b_prev = g.add(f"b{s}", chakra.COMP, deps=deps,
                       flops=b_flops, out_bytes=payload)
    return g, list(range(p)) + list(reversed(range(p)))


def layer_chain(n, flops=1e11, payload=1e4):
    g = chakra.Graph()
    prev = None
    for i in range(n):
        prev = g.add(f"L{i}", chakra.COMP,
                     deps=[prev] if prev is not None else [],
                     flops=flops, out_bytes=payload)
    return g


def bench_bubble(sysc, topo, grid) -> dict:
    """bubble_recovery: worst-case sim-vs-analytic agreement on the grid."""
    worst = 1.0
    points = []
    for p, m in grid:
        g, assign = fb_chain(p)
        for sched in ("gpipe", "1f1b"):
            prog = split_pipeline_stages(g, p, assignment=assign,
                                         num_microbatches=m, schedule=sched)
            res = simulate_cluster(prog, sysc, topo=topo)
            sim = bubble_fraction(res)
            ana = analytic_bubble_fraction(p, m)
            score = min(sim, ana) / max(sim, ana) if max(sim, ana) else 1.0
            worst = min(worst, score)
            points.append({"p": p, "m": m, "schedule": sched,
                           "sim": sim, "analytic": ana, "score": score})
    emit("pipeline_bubble", 0.0,
         f"grid={len(points)} worst_recovery={worst:.4f}")
    return {"bubble_grid": points, "bubble_recovery": worst}


def bench_coalesce(p=4, R=16, m=8, reps=3) -> dict:
    """coalesce_speedup: shared stage graphs (p rows) vs literal
    per-replica graphs (p*R rows), bit-identical results required.

    Uses a switch (uniform) topology: on a structured topology each
    replica's p2p pair can price differently, and the engine then
    *correctly* refuses to coalesce them (the per-instance pricing
    signature splits the classes) — sharing's row win only exists where
    replicas are genuinely symmetric."""
    sysc = SystemConfig(chips=p * R, topology="switch")
    topo = build_topology(sysc)
    g = layer_chain(4 * p)
    shared = split_pipeline_stages(g, p, replicas=R, num_microbatches=m,
                                   schedule="gpipe",
                                   share_replica_graphs=True)
    literal = split_pipeline_stages(g, p, replicas=R, num_microbatches=m,
                                    schedule="gpipe",
                                    share_replica_graphs=False)

    def run(prog):
        return simulate_cluster(prog, sysc, topo=topo, memoize=False)

    rs, rl = run(shared), run(literal)
    identity = rs.step_time == rl.step_time and all(
        rs.rank_result(r).total_time == rl.rank_result(r).total_time
        for r in range(rs.n_ranks))
    t_shared = best_of(lambda: run(shared), reps=reps)
    t_literal = best_of(lambda: run(literal), reps=reps)
    speedup = t_literal / t_shared if t_shared > 0 else 0.0
    emit("pipeline_coalesce", t_shared * 1e6,
         f"p={p} R={R} m={m} literal={t_literal * 1e6:.0f}us "
         f"speedup={speedup:.2f}x identity={identity}")
    return {"coalesce_p": p, "coalesce_replicas": R,
            "coalesce_t_shared_us": t_shared * 1e6,
            "coalesce_t_literal_us": t_literal * 1e6,
            "coalesce_identity": 1.0 if identity else 0.0,
            "coalesce_speedup": speedup if identity else 0.0}


def bench_m1_identity(sysc, topo, p=4) -> dict:
    """m1_identity: every schedule at m=1 == the legacy split, node by
    node and in simulated step time."""
    def rep(g):
        return [(n.name, n.type, tuple(n.deps), tuple(n.ctrl_deps),
                 tuple(sorted(n.attrs.items(), key=lambda kv: kv[0])))
                for n in g.nodes]

    ok = True
    # forward-only chains: the workload shape the legacy one-wave split
    # supports (explicit-backward graphs need the microbatched lowering)
    for g in (layer_chain(4 * p), layer_chain(6 * p, flops=3e11)):
        legacy = split_pipeline_stages(g, p)
        ref = simulate_cluster(legacy, sysc, topo=topo)
        for sched in SCHEDULES:
            prog = split_pipeline_stages(g, p, num_microbatches=1,
                                         schedule=sched)
            same = all(rep(prog.graph_for(r)) == rep(legacy.graph_for(r))
                       for r in range(prog.n_ranks))
            res = simulate_cluster(prog, sysc, topo=topo)
            ok = ok and same and res.step_time == ref.step_time
    emit("pipeline_m1_identity", 0.0, f"identity={ok}")
    return {"m1_identity": 1.0 if ok else 0.0}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced grid for CI gating (seconds)")
    args = ap.parse_args(argv)
    sysc = SystemConfig(chips=32)
    topo = build_topology(sysc)
    t0 = time.perf_counter()
    if args.smoke:
        grid = [(2, 4), (4, 8), (4, 16)]
        payload = {"smoke": True,
                   **bench_bubble(sysc, topo, grid),
                   **bench_coalesce(reps=3),
                   **bench_m1_identity(sysc, topo)}
    else:
        grid = [(2, 2), (2, 4), (2, 8), (4, 4), (4, 8), (4, 16),
                (8, 8), (8, 16), (8, 32)]
        payload = {"smoke": False,
                   **bench_bubble(sysc, topo, grid),
                   **bench_coalesce(reps=5),
                   **bench_m1_identity(sysc, topo)}
    payload["elapsed_s"] = time.perf_counter() - t0
    path = write_json("BENCH_pipeline.json", payload)
    print(f"wrote {path}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
