import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
"""Paper Fig 10: reordering benefit vs interconnect bandwidth (70B, 8 ranks).

Same Chakra graph (workload fixed), hardware knob swept — the cost-model-only
leg of the DSE loop (no recapture).  Expected shape: clear benefit at high
bandwidth, vanishing at low bandwidth where communication dominates and
there is no compute left to hide it behind (paper SS6.1)."""
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import PRESET_70B, emit, fsdp_layer_stack_capture  # noqa: E402


def main():
    from repro.configs.base import SystemConfig
    from repro.core import passes
    from repro.core.costmodel import build_topology, simulate

    ranks = 8
    g = fsdp_layer_stack_capture(
        n_layers=PRESET_70B["n_layers"], d_model=PRESET_70B["d_model"],
        d_ff=PRESET_70B["d_ff"], batch_tokens=8192 * ranks, ranks=ranks,
        cache_tag=f"70b_r{ranks}")
    g_sync = passes.inject_fsdp_sync(g)
    g_re = passes.reorder_prefetch(g_sync, prefetch=2)

    benefits = []
    for bw_gb in (400, 200, 100, 50, 25, 12.5, 6.25, 3.125, 1.5):
        sysc = SystemConfig(chips=ranks, link_bw=bw_gb * 1e9)
        topo = build_topology(sysc, ranks)
        t_sync = simulate(g_sync, sysc, topo).total_time
        t_re = simulate(g_re, sysc, topo).total_time
        ben = (t_sync - t_re) / t_sync * 100
        benefits.append((bw_gb, ben))
        emit(f"bw_sweep.{bw_gb}gbps.norm_sync", t_sync * 1e6, "1.000")
        emit(f"bw_sweep.{bw_gb}gbps.norm_reorder", t_re * 1e6,
             f"{t_re / t_sync:.3f}")
        emit(f"bw_sweep.{bw_gb}gbps.benefit_pct", 0.0, f"{ben:.2f}")
    # The paper sees ~7% benefit at its "high bandwidth" point (100 Gbps IB)
    # dropping to marginal one octave lower.  The exact bandwidth where the
    # hump peaks depends on the workload's comm/compute ratio, so assert the
    # *shape* in the paper's IB-class window rather than one anchor:
    #   - some bw in [12.5, 100] GB/s shows a ~4-16% benefit,
    #   - an adjacent lower octave is marginal (< peak/1.8),
    #   - NVLink-class bw shows near-zero benefit.
    # Far below the window a second-order effect appears (the sync baseline
    # also exposes compute) — discussed in EXPERIMENTS.md.
    by_bw = dict(benefits)
    window = [(bw, b) for bw, b in benefits if 12.5 <= bw <= 100]
    peak_bw, peak = max(window, key=lambda t: t[1])
    assert 4.0 <= peak <= 16.0, (peak_bw, peak)          # paper: ~7%
    lower = [b for bw, b in benefits if peak_bw / 4 <= bw < peak_bw]
    assert lower and min(lower) < peak / 1.8, (peak_bw, peak, lower)
    assert by_bw[400] < peak / 4, by_bw                  # vanishes at NVLink
    emit("bw_sweep.paper_window_reproduced", 0.0, "True")
    emit("bw_sweep.peak_benefit_pct_at_gbps", 0.0, f"{peak:.2f}@{peak_bw}")


if __name__ == "__main__":
    main()
