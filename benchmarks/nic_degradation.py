import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=32")
"""Paper Fig 12 (SS6.3): surfacing degraded network hardware from the
workload graph — the Genie use case.

Genie replays Chakra graphs as real RDMA traffic on CPU nodes; here the
role of the physical testbed is played by the event simulator's multipod
DCN links, and 'NIC degradation' by background traffic consuming a fraction
of link bandwidth (the paper's ib_write_bw rate-limit stand-in).  Expected:
per-iteration duration rises monotonically with degradation, i.e. the
workload graph is sensitive enough to expose a flapping NIC *before* GPUs
are attached.

The sweep is a duration-override batch (same shape as stragglers): the
graph is compiled once, each degradation level is one
``CompiledGraph.comm_overrides`` dict repricing COMM nodes at the scaled
NIC bandwidth, and one ``simulate_batch`` call replays them all — no
per-level recompilation or duration rebuild."""
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from benchmarks.common import PRESET_70B, emit, fsdp_layer_stack_capture  # noqa: E402


def main():
    from repro.configs.base import SystemConfig
    from repro.core.costmodel import (build_topology, compile_graph,
                                      simulate_batch)

    ranks = 32                    # paper: Llama3-70B DP=32 over scale-out
    g = fsdp_layer_stack_capture(
        n_layers=PRESET_70B["n_layers"], d_model=PRESET_70B["d_model"],
        d_ff=PRESET_70B["d_ff"], batch_tokens=2048 * ranks, ranks=ranks,
        cache_tag=f"70b_dp{ranks}")

    nic_bw = 12.5e9               # 100 Gbps InfiniBand
    sysc = SystemConfig(chips=ranks, topology="switch", link_bw=nic_bw)
    topo = build_topology(sysc, ranks)
    cg = compile_graph(g)
    levels = (0.0, 0.1, 0.3, 0.5, 0.7, 0.9)
    overrides = [None if d == 0.0 else
                 cg.comm_overrides(sysc, topo, bw_scale=1.0 - d)
                 for d in levels]
    results = simulate_batch(g, sysc, overrides, topo=topo)
    durations = []
    for degradation, r in zip(levels, results):
        durations.append(r.total_time)
        emit(f"nic.degr{int(degradation * 100):02d}.iter_ms",
             r.total_time * 1e6, f"{r.total_time * 1e3:.2f}")
    assert all(b >= a - 1e-12 for a, b in zip(durations, durations[1:])), \
        durations
    emit("nic.monotonic_degradation", 0.0, "True")
    emit("nic.slowdown_at_90pct", 0.0,
         f"{durations[-1] / durations[0]:.2f}x")


if __name__ == "__main__":
    main()
