import os
"""Roofline table builder: aggregates the dry-run artifacts into the
EXPERIMENTS.md SSRoofline tables (40 cells, single-pod; baseline and
optimized variants)."""
import glob
import json
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

ROOT = os.path.join(os.path.dirname(__file__), "..", "artifacts")


def art_dir(variant="baseline"):
    d = os.path.join(ROOT, f"dryrun_{variant}")
    if os.path.isdir(d) and glob.glob(os.path.join(d, "*.json")):
        return d
    return os.path.join(ROOT, "dryrun")


def load_cells(mesh_tag="singlepod", variant="baseline"):
    cells = []
    for f in sorted(glob.glob(os.path.join(art_dir(variant), "*.json"))):
        if "_index" in f or "BASELINE" in f:
            continue
        r = json.load(open(f))
        if r.get("mesh") == mesh_tag or (r.get("status") == "skipped"
                                         and mesh_tag in r.get("cell", "")):
            cells.append(r)
    return cells


def fraction(r):
    rl = r["roofline"]
    dom = max(rl["compute_s"], rl["memory_s"], rl["collective_s"])
    if dom <= 0:
        return 0.0
    useful_s = rl["model_flops"] / 197e12
    return useful_s / dom


def table(mesh_tag="singlepod", variant="baseline"):
    rows = []
    for r in load_cells(mesh_tag, variant):
        if r.get("status") == "skipped":
            rows.append({"cell": r["cell"], "status": "skipped",
                         "reason": r["reason"]})
            continue
        rl = r["roofline"]
        rows.append({
            "cell": r["cell"], "status": "ok",
            "arch": r["arch"], "shape": r["shape"],
            "compute_ms": rl["compute_s"] * 1e3,
            "memory_ms": rl["memory_s"] * 1e3,
            "collective_ms": rl["collective_s"] * 1e3,
            "bound": rl["bound"],
            "useful_ratio": rl["useful_ratio"],
            "roofline_fraction": fraction(r),
            "hbm_temp_gb": r["memory_analysis"].get(
                "temp_size_in_bytes", 0) / 1e9,
        })
    return rows


def main():
    for variant in ("baseline", "opt"):
        rows = table(variant=variant)
        ok = [r for r in rows if r["status"] == "ok"]
        if not ok:
            continue
        print(f"# === {variant} ===")
        print("cell,compute_ms,memory_ms,collective_ms,bound,useful_ratio,"
              "roofline_fraction")
        for r in sorted(ok, key=lambda x: x["roofline_fraction"]):
            print(f"{r['cell']},{r['compute_ms']:.2f},{r['memory_ms']:.2f},"
                  f"{r['collective_ms']:.2f},{r['bound']},"
                  f"{r['useful_ratio']:.3f},{r['roofline_fraction']:.3f}")
        for r in rows:
            if r["status"] == "skipped":
                print(f"{r['cell']},skipped,,,,{r['reason']},")
        bounds = {}
        for r in ok:
            bounds[r["bound"]] = bounds.get(r["bound"], 0) + 1
        print(f"# bounds: {bounds}")
        worst = min(ok, key=lambda r: r["roofline_fraction"])
        print(f"# worst fraction: {worst['cell']} "
              f"{worst['roofline_fraction']:.3f}")
        tr = [r for r in ok if r["shape"] == "train_4k"]
        if tr:
            import statistics
            print(f"# train_4k median fraction: "
                  f"{statistics.median(r['roofline_fraction'] for r in tr):.3f}")


if __name__ == "__main__":
    main()
