"""Pipeline-schedule walkthrough: GPipe vs 1F1B vs interleaved on a real
registry arch, priced cluster-free by the MPMD engine.

  python examples/pipeline_schedules_walkthrough.py

Covers, without any accelerator:
  1. pipeline_program(): one call from a registry arch name to a
     microbatched pipeline MPMDProgram
  2. the fill/drain bubble: simulated bubble_fraction vs the textbook
     (p-1)/(m+p-1), and how it shrinks as num_microbatches grows
  3. the memory story: GPipe stashes ~m per-microbatch activations on
     the first stage, 1F1B caps the stash near p (memory_timeline)
  4. blame: where the bubble shows up in the makespan decomposition
  5. a schedule DSE: num_microbatches x schedule as search knobs with
     bubble_fraction as an objective, bad values as failed trials
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SystemConfig  # noqa: E402
from repro.configs.workload import pipeline_program  # noqa: E402
from repro.core.costmodel import build_topology, simulate_cluster  # noqa: E402
from repro.core.costmodel.schedule import (analytic_bubble_fraction,  # noqa: E402
                                           bubble_fraction)
from repro.obs.explain import explain  # noqa: E402
from repro.obs.memory import memory_timeline  # noqa: E402
from repro.search.run import SearchRun  # noqa: E402
from repro.search.space import Dim, SearchSpace  # noqa: E402

ARCH = "qwen3-8b"
P = 4                                   # pipeline stages


def main():
    sysc = SystemConfig(chips=8)
    topo = build_topology(sysc)

    # -- 1/2. the bubble and how microbatching shrinks it ------------------
    print(f"=== {ARCH}, {P} stages: bubble vs num_microbatches ===")
    print(f"{'m':>4} {'schedule':>12} {'step_time':>12} {'bubble':>8} "
          f"{'analytic':>9}")
    for m in (1, 4, 8, 16):
        for sched in ("gpipe", "1f1b"):
            prog = pipeline_program(ARCH, P, num_microbatches=m,
                                    schedule=sched)
            cr = simulate_cluster(prog, sysc, topo=topo)
            print(f"{m:>4} {sched:>12} {cr.step_time:>12.6f} "
                  f"{bubble_fraction(cr):>8.3f} "
                  f"{analytic_bubble_fraction(P, m):>9.3f}")

    # -- 3. activation stash: GPipe ~m per-mb units, 1F1B ~p ---------------
    # the stash effect needs per-stage forward outputs that live until the
    # same stage's backward consumes them — an explicit f/b chain (the
    # registry chain's segments keep their activations within each task,
    # so its schedules tie on memory)
    from repro.core import chakra
    from repro.core.convert import split_pipeline_stages

    def fb_chain(p):
        g = chakra.Graph()
        f = []
        for s in range(p):
            f.append(g.add(f"f{s}", chakra.COMP,
                           deps=[f[-1]] if f else [],
                           flops=1e12, out_bytes=1e6))
        b_prev = None
        for s in reversed(range(p)):
            deps = [f[s]] + ([b_prev] if b_prev is not None else [])
            b_prev = g.add(f"b{s}", chakra.COMP, deps=deps,
                           flops=2e12, out_bytes=1e6)
        return g, list(range(p)) + list(reversed(range(p)))

    print("\n=== first-stage activation peak (m=16 > p=4, f/b chain) ===")
    g_fb, assign = fb_chain(P)
    peaks = {}
    for sched in ("gpipe", "1f1b"):
        prog = split_pipeline_stages(g_fb, P, assignment=assign,
                                     num_microbatches=16, schedule=sched)
        cr = simulate_cluster(prog, sysc, topo=topo, keep_timeline=True)
        tl = memory_timeline(cr, graph=prog)
        assert tl.identity_ok()          # decomposition stays bit-exact
        peaks[sched] = tl.ranks[0].class_peak("activations")
        print(f"  {sched:>6}: {peaks[sched]:.3e} B")
    print(f"  ratio gpipe/1f1b = {peaks['gpipe'] / peaks['1f1b']:.1f}x "
          f"(~ m/p = {16 / P:.1f})")

    # -- 4. the bubble in the blame decomposition --------------------------
    print("\n=== makespan blame (1f1b, m=8) ===")
    prog = pipeline_program(ARCH, P, num_microbatches=8, schedule="1f1b")
    cr = simulate_cluster(prog, sysc, topo=topo, keep_timeline=True)
    ex = explain(cr, graph=prog)
    assert ex.identity_ok()              # components sum to the makespan
    for comp, secs in sorted(ex.components().items(),
                             key=lambda kv: -kv[1]):
        if secs:
            print(f"  {comp:>10}: {secs:.6f} s")

    # -- 5. schedule DSE with failed-trial knob validation -----------------
    print("\n=== schedule DSE (bad knob values become failed trials) ===")
    space = SearchSpace([
        Dim.finite("num_stages", [P]),
        Dim.finite("num_microbatches", [0, 4, 8, 16]),   # 0 is invalid
        Dim.finite("schedule", ["gpipe", "1f1b"]),
    ])
    from repro.configs.registry import get_config
    from repro.configs.workload import workload_graph
    run = SearchRun(lambda cfg: workload_graph(get_config(ARCH)),
                    sysc, space, strategy="grid",
                    objectives=("total_time", "bubble_fraction"), budget=16)
    res = run.run()
    for t in sorted(res.trials, key=lambda t: (not t.ok,
                                               t.objectives.get(
                                                   "total_time", 0.0))):
        cfg = {k: t.config[k] for k in ("num_microbatches", "schedule")}
        if t.ok:
            print(f"  ok   {cfg}  total_time={t.objectives['total_time']:.6f}"
                  f"  bubble={t.objectives['bubble_fraction']:.3f}")
        else:
            print(f"  FAIL {cfg}  {t.error.splitlines()[0][:72]}")


if __name__ == "__main__":
    main()
