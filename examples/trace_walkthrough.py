"""Trace subsystem walkthrough: simulate -> export -> validate -> calibrate.

The full Flint loop is capture -> simulate -> export -> validate ->
calibrate; this example starts from a hand-built FSDP-style graph so it
runs in seconds with no jax.  It plays both sides of the validation story:

  1. simulate the graph and export a Chrome trace (open it in Perfetto);
  2. pretend the *measured* cluster has degraded HBM and links by
     generating a second trace under perturbed hardware;
  3. validate the nominal model against that "measured" trace — see the
     error and the worst offenders;
  4. calibrate: fit hbm_bw / link scale / latency from the trace, then
     re-validate with the fitted model and feed it to dse.explore.

Equivalent CLI session (graph.json from chakra.Graph.save):

    python -m repro.trace export graph.json -o sim_trace.json --ranks 8
    python -m repro.trace validate graph.json measured_trace.json
    python -m repro.trace calibrate graph.json measured_trace.json \
        -o calibrated.json --validate
    python -m repro.trace validate graph.json measured_trace.json \
        --system calibrated.json
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SystemConfig           # noqa: E402
from repro.core import chakra, dse                    # noqa: E402
from repro.core.costmodel import (build_topology, simulate,   # noqa: E402
                                  simulate_cluster)
from repro.trace import (calibrate, export_chrome_trace,      # noqa: E402
                         ingest_chrome_trace, to_chrome_trace, validate)

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "trace")
os.makedirs(ART, exist_ok=True)


def build_graph(n_layers=16, ranks=8):
    """FSDP layer stack with both compute- and HBM-bound kernels."""
    g = chakra.Graph(meta={"workload": "trace_walkthrough"})
    group = list(range(ranks))
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=8e6, out_bytes=8e6, group=group,
                   ctrl_deps=[prev] if prev is not None else [])
        fwd = g.add(f"f{i}", chakra.COMP,
                    deps=[ag] + ([prev] if prev is not None else []),
                    flops=5e10, bytes=1e8, out_bytes=1e6)
        bwd = g.add(f"b{i}", chakra.COMP, deps=[fwd], flops=1e11,
                    bytes=2e8, out_bytes=1e6)
        g.add(f"opt{i}", chakra.COMP, deps=[bwd], flops=1e8, bytes=5e8)
        g.add(f"ar{i}", chakra.COMM_COLL, deps=[bwd],
              comm_kind="all-reduce", comm_bytes=4e6 * (1 + i % 3),
              group=group)
        prev = bwd
    return g


def main():
    ranks = 8
    sysc = SystemConfig(chips=ranks, topology="switch")
    topo = build_topology(sysc, ranks)
    g = build_graph(ranks=ranks)

    # 1. simulate and export a per-rank Chrome trace ------------------------
    cr = simulate_cluster(g, sysc, topo, n_ranks=ranks, keep_timeline=True)
    sim_path = os.path.join(ART, "sim_trace.json")
    export_chrome_trace(cr, sim_path, graph=g)
    print(f"[1] exported {ranks}-rank trace -> {sim_path} "
          f"(step {cr.step_time * 1e3:.3f} ms) — open in "
          "https://ui.perfetto.dev")

    # 2. a "measured" trace: same workload, degraded hardware ---------------
    true_sys = sysc.replace(hbm_bw=sysc.hbm_bw * 0.65,
                            link_bw=sysc.link_bw * 0.7)
    measured = simulate(g, true_sys, build_topology(true_sys, ranks),
                        keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(measured, graph=g))
    print(f"[2] 'measured' step time {measured.total_time * 1e3:.3f} ms "
          f"(hbm x0.65, links x0.70)")

    # 3. validate the nominal model against it ------------------------------
    before = validate(g, tl, sysc, topo)
    print("[3] nominal model vs measured trace:")
    print("    " + before.summary().replace("\n", "\n    "))

    # 4. calibrate, re-validate, and sweep with the fitted model ------------
    cal = calibrate(g, tl, sysc, topo)
    print("[4] " + cal.summary().replace("\n", "\n    "))
    after = validate(g, tl, cal.system, cal.topology,
                     compute_derate=cal.compute_derate)
    print(f"    validation e2e error {before.e2e_error * 100:.2f}% -> "
          f"{after.e2e_error * 100:.2f}%")
    assert after.e2e_error < before.e2e_error

    trials = dse.explore(lambda cfg: g, cal.system,
                         [dse.Knob("prefetch", [None, 2, 4]),
                          dse.Knob("bucket_bytes", [None, 32e6])],
                         compute_derate=cal.compute_derate,
                         topo=cal.topology)
    best = trials[0]
    print(f"    calibrated DSE over {len(trials)} configs: best "
          f"{best.objective * 1e3:.3f} ms with {best.config}")


if __name__ == "__main__":
    main()
