"""Memory-timeline walkthrough: schedule-resolved occupancy curves,
bit-exact peak blame, peak-delta attribution, and an OOM-aware search.

  python examples/memory_walkthrough.py

Covers, without any accelerator:
  1. memory_timeline(): per-rank weights/activations/comm occupancy
     curves whose class decomposition sums to the total bit-exactly and
     whose max IS the engine's schedule-aware peak_bytes
  2. memory_blame(): the live tensors at the peak (they fsum to it)
  3. memory_diff(): which tensors/classes moved the peak between configs
  4. Chrome-trace export with per-rank memory_bytes counter tracks
  5. hbm_bytes capacity in a SearchRun: OOM-infeasible trials recorded,
     excluded from the Pareto front, sweep never crashes
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SystemConfig  # noqa: E402
from repro.core import chakra  # noqa: E402
from repro.core.costmodel import build_topology, simulate  # noqa: E402
from repro.core.dse import Knob  # noqa: E402
from repro.obs.memory import (export_memory_trace, memory_blame,  # noqa: E402
                              memory_diff, memory_timeline)
from repro.search.run import SearchRun  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "obs")
os.makedirs(OUT, exist_ok=True)


def layer_stack(n_layers=24, act_bytes=4e7, comm=2e7):
    """FSDP-ish stack: all-gather weights, matmul, free after backward."""
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=comm, out_bytes=8e6, group=list(range(16)),
                   ctrl_deps=[prev] if prev is not None else [])
        mm = g.add(f"mm{i}", chakra.COMP,
                   deps=[ag] + ([prev] if prev is not None else []),
                   flops=2e10, bytes=1e8, out_bytes=act_bytes)
        prev = mm
    return g


def main():
    sysc = SystemConfig(chips=16)
    topo = build_topology(sysc)
    g = layer_stack()

    # -- 1. the occupancy curve --------------------------------------------
    print("=== memory_timeline: where do the bytes live? ===")
    res = simulate(g, sysc, topo, keep_timeline=True)
    tl = memory_timeline(res, graph=g, hbm_bytes=1.5e9)
    print(tl.table())
    rm = tl.ranks[tl.peak_rank]
    assert tl.peak_bytes == res.peak_bytes          # bit-exact, not approx
    assert tl.identity_ok()                          # classes sum to total
    print(f"  utilization vs 1.5 GB HBM: {rm.utilization():.1%}, "
          f"time above 90% of capacity: {rm.time_above(0.9 * 1.5e9):.2e} s\n")

    # -- 2. blame the peak -------------------------------------------------
    print("=== memory_blame: what do I evict to fit? ===")
    bl = memory_blame(tl, g)
    print(bl.table())
    print()

    # -- 3. diff two configurations ----------------------------------------
    print("=== memory_diff: 2x activation bytes ===")
    g2 = layer_stack(act_bytes=8e7)
    res2 = simulate(g2, sysc, topo, keep_timeline=True)
    d = memory_diff(res, res2, graph_a=g, graph_b=g2)
    print(d.table())
    print()

    # -- 4. chrome counter tracks ------------------------------------------
    trace_path = os.path.join(OUT, "memory_trace.json")
    export_memory_trace(res, trace_path, graph=g)
    print(f"chrome trace (memory_bytes counter tracks) -> {trace_path}\n")

    # -- 5. OOM-aware search -----------------------------------------------
    print("=== hbm_bytes-gated SearchRun ===")
    knobs = [Knob("prefetch", [0, 2, 4]),
             Knob("hbm_bytes", [1e7, 1e12], layer="hardware")]
    run = SearchRun(lambda cfg: layer_stack(), sysc, knobs,
                    strategy="grid", budget=6,
                    objectives=("total_time", "peak_memory_bytes")).run()
    print(f"  {len(run.trials)} trials, "
          f"{len(run.failed_trials)} OOM-infeasible:")
    for t in run.failed_trials:
        print(f"    {t.config['prefetch']=} -> {t.error}")
    print(f"  best feasible: {run.best.config} "
          f"peak={run.best.result.peak_bytes:.3e} B")
    assert all(t.config["hbm_bytes"] == 1e12 for t in run.pareto_trials())


if __name__ == "__main__":
    main()
