"""Observability walkthrough: instrument a sweep, explain a simulated run,
and attribute a step-time delta between two configurations.

  python examples/explain_walkthrough.py

Covers, without any accelerator:
  1. obs.enable() + a pooled SearchRun -> metrics JSON you can inspect
     with `python -m repro.obs report`
  2. explain(): critical path + bit-exact blame (compute busy / exposed
     comm / barrier wait / fault stall sum to the makespan)
  3. explain_diff(): which node classes and ranks a config change moved
  4. Chrome-trace export with per-rank utilization counter tracks
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs.base import SystemConfig  # noqa: E402
from repro.core import chakra, convert  # noqa: E402
from repro.core.costmodel import build_topology, simulate  # noqa: E402
from repro.core.costmodel.simulator import simulate_cluster  # noqa: E402
from repro.core.dse import Knob  # noqa: E402
from repro.obs import record as obs  # noqa: E402
from repro.obs.explain import explain, explain_diff  # noqa: E402
from repro.obs.explain import export_explain_trace  # noqa: E402
from repro.search.run import SearchRun  # noqa: E402

OUT = os.path.join(os.path.dirname(__file__), "..", "artifacts", "obs")
os.makedirs(OUT, exist_ok=True)


def layer_stack(n_layers=24, flops=2e10, comm=2e7):
    """FSDP-ish stack: matmul + all-reduce per layer."""
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        c = g.add(f"mm{i}", chakra.COMP,
                  deps=[prev] if prev is not None else [], flops=flops,
                  bytes=1e8, out_bytes=1e5)
        a = g.add(f"ar{i}", chakra.COMM_COLL, deps=[c],
                  comm_kind="all-reduce", comm_bytes=comm,
                  group=list(range(16)))
        prev = a
    return g


def main():
    sysc = SystemConfig(chips=16)
    topo = build_topology(sysc)

    # -- 1. an instrumented sweep ------------------------------------------
    print("=== instrumented sweep ===")
    obs.enable()
    knobs = [Knob("prefetch", [0, 2, 4, 8]),
             Knob("bucket_bytes", [None, 32e6, 64e6])]
    res = SearchRun(lambda cfg: layer_stack(), sysc, knobs,
                    strategy="grid", budget=12, jobs=4,
                    progress=lambda p: print(
                        f"  {p['trials']}/{p['budget']} trials, "
                        f"best={p['best']}"),
                    progress_interval=0.0).run()
    metrics_path = os.path.join(OUT, "sweep_metrics.json")
    obs.dump_metrics(metrics_path)
    obs.disable()
    print(res.summary())
    print(f"metrics -> {metrics_path}")
    print(f"  (inspect with: python -m repro.obs report {metrics_path})\n")

    # -- 2. explain one run ------------------------------------------------
    print("=== explain: slow-interconnect pipeline ===")
    g = layer_stack()
    prog = convert.split_pipeline_stages(g, 2)
    cres = simulate_cluster(prog, sysc, topo, keep_timeline=True)
    e = explain(cres, graph=prog)
    print(e.table())
    trace_path = os.path.join(OUT, "pipeline_trace.json")
    export_explain_trace(cres, trace_path, graph=prog)
    print(f"chrome trace (slices + utilization tracks) -> {trace_path}\n")

    # -- 3. diff two configurations ----------------------------------------
    print("=== explain_diff: 4x slower collectives ===")
    a = simulate(g, sysc, topo, keep_timeline=True)
    g2 = layer_stack(comm=8e7)                    # 4x the all-reduce bytes
    b = simulate(g2, sysc, topo, keep_timeline=True)
    d = explain_diff(a, b, graph_a=g, graph_b=g2)
    print(d.table())


if __name__ == "__main__":
    main()
