"""Design-space exploration with Flint (the paper's Fig 5 feedback loop).

Capture ONE workload graph cluster-free, then explore software knobs
(FSDP AllGather prefetch depth, gradient bucketing) x hardware knobs
(interconnect bandwidth) through the cost model, and report the best
configuration per hardware point — paper SS6.1 end to end.

  XLA_FLAGS=--xla_force_host_platform_device_count=32 \
      python examples/dse_fsdp_reorder.py
"""
import os

if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=32"

import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
from jax.sharding import NamedSharding, PartitionSpec as P  # noqa: E402

from repro.configs.base import SystemConfig  # noqa: E402
from repro.core import capture_step, passes  # noqa: E402
from repro.core.dse import Knob, explore  # noqa: E402
from repro.parallel.mesh import make_mesh  # noqa: E402


def capture_fsdp_workload(ranks=32, n_layers=16, d=2048, f=8192,
                          tokens_per_rank=2048):
    mesh = make_mesh((ranks,), ("data",))

    def step(stack, x):
        def body(h, w):
            w1, w2 = w
            h = h + jax.nn.silu(h @ w1) @ w2
            return h, None
        h, _ = jax.lax.scan(body, x, stack)
        return jnp.mean(h.astype(jnp.float32) ** 2)

    g = jax.value_and_grad(step)
    ss = (jax.ShapeDtypeStruct((n_layers, d, f), jnp.bfloat16),
          jax.ShapeDtypeStruct((n_layers, f, d), jnp.bfloat16))
    xs = jax.ShapeDtypeStruct((tokens_per_rank * ranks, d), jnp.bfloat16)
    sh = ((NamedSharding(mesh, P(None, "data", None)),
           NamedSharding(mesh, P(None, "data", None))),
          NamedSharding(mesh, P("data", None)))
    cap = capture_step(g, (ss, xs), sh, mesh, meta={"case": "dse-fsdp"})
    print(f"[dse] captured: {len(cap.graph)} nodes, "
          f"{cap.summary['comm_bytes'] / 1e9:.1f} GB collectives/device, "
          f"{cap.summary['parsed_flops'] / 1e12:.2f} TFLOP/device")
    return cap.graph


def main():
    graph = capture_fsdp_workload()

    def graph_for(cfg):          # workload fixed -> captured exactly once
        return graph

    knobs = [
        Knob("fsdp_sync", [True], layer="software"),
        Knob("prefetch", [0, 1, 2, 4, 16], layer="software"),
        Knob("bucket_bytes", [None, 64e6], layer="software"),
        Knob("link_bw", [12.5e9, 50e9, 200e9], layer="hardware"),
    ]
    trials = explore(graph_for, SystemConfig(chips=32, topology="switch"),
                     knobs, objective="total_time")

    print(f"[dse] explored {len(trials)} configurations")
    for bw in (12.5e9, 50e9, 200e9):
        best = next(t for t in trials if t.config["link_bw"] == bw)
        base = next(t for t in trials
                    if t.config["link_bw"] == bw
                    and t.config["prefetch"] == 0
                    and t.config["bucket_bytes"] is None)
        gain = (base.objective - best.objective) / base.objective * 100
        print(f"  link_bw {bw / 1e9:5.1f} GB/s: best prefetch="
              f"{best.config['prefetch']} bucket={best.config['bucket_bytes']}"
              f" -> {best.objective * 1e3:.1f} ms ({gain:+.1f}% vs no-reorder,"
              f" peak {best.result.peak_bytes / 1e9:.2f} GB)")


if __name__ == "__main__":
    main()
