"""Quickstart: train a small LM end-to-end with the public API.

  python examples/quickstart.py                 # ~100M params, 300 steps
  python examples/quickstart.py --preset tiny   # seconds on CPU

Covers: config -> model -> data -> train step -> checkpoint -> eval, with
loss visibly decreasing on the structured synthetic stream.
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs.base import ModelConfig, ParallelConfig  # noqa: E402
from repro.configs.registry import get_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train import (DataConfig, DataIterator, OptConfig,  # noqa: E402
                         init_train_state, make_eval_step, make_train_step,
                         save_checkpoint)


def preset_100m() -> ModelConfig:
    return get_config("qwen3-8b").replace(
        name="quickstart-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32_000,
        sb_repeat=8)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=("100m", "tiny"), default="100m")
    ap.add_argument("--steps", type=int, default=0)
    args = ap.parse_args()

    if args.preset == "100m":
        cfg = preset_100m()
        steps = args.steps or 300
        seq, batch = 256, 8
    else:
        cfg = get_config("qwen3-8b", smoke=True)
        steps = args.steps or 40
        seq, batch = 64, 8

    model = build_model(cfg)
    print(f"[quickstart] {cfg.name}: {cfg.param_count() / 1e6:.1f}M params")

    par = ParallelConfig()
    opt = OptConfig(lr=3e-3, warmup_steps=max(10, steps // 20),
                    total_steps=steps)
    state = init_train_state(model, jax.random.PRNGKey(0), par)
    train = jax.jit(make_train_step(model, opt, par))
    evaluate = jax.jit(make_eval_step(model, par))
    it = DataIterator(DataConfig(vocab_size=cfg.vocab_size, seq_len=seq,
                                 global_batch=batch))

    t0 = time.time()
    first = None
    for step in range(steps):
        state, metrics = train(state, next(it))
        if step == 0:
            first = float(metrics["loss"])
        if step % max(1, steps // 10) == 0 or step == steps - 1:
            print(f"  step {step:4d}  loss {float(metrics['loss']):.4f}  "
                  f"lr {float(metrics['lr']):.2e}")
    dt = time.time() - t0
    final = float(metrics["loss"])

    eval_metrics = evaluate(state.params, next(it))
    print(f"[quickstart] {steps} steps in {dt:.1f}s "
          f"({steps * batch * seq / dt:.0f} tok/s)")
    print(f"[quickstart] loss {first:.3f} -> {final:.3f} "
          f"(eval {float(eval_metrics['loss']):.3f})")
    save_checkpoint(os.path.join("checkpoints", cfg.name), steps, state)
    print("[quickstart] checkpoint saved")
    assert final < first, "loss should decrease"


if __name__ == "__main__":
    main()
