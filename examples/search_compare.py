"""Grid vs random vs bayesian sample efficiency on the FSDP-reorder space.

The paper's Fig 9 co-design space — AllGather prefetch depth x gradient
bucketing x interconnect bandwidth — explored three ways over one synthetic
FSDP layer-stack graph (no jax, no cluster; seconds):

  * exhaustive grid (the ground truth, 96 simulator calls),
  * seeded random sampling at 25% of the budget,
  * Gaussian-process + expected-improvement at 25% of the budget,

printing each strategy's best-so-far curve — how fast it closes on the true
optimum — plus a multi-objective run whose Pareto front trades step time
against the analytical peak-memory proxy.

    PYTHONPATH=src python examples/search_compare.py
"""
import os
import sys

_ROOT = os.path.join(os.path.dirname(__file__), "..")
sys.path.insert(0, os.path.join(_ROOT, "src"))
sys.path.insert(0, _ROOT)                # for the shared benchmark builders

from benchmarks.hetero_cluster import fsdp_stack  # noqa: E402
from benchmarks.search_bench import fsdp_reorder_knobs  # noqa: E402

from repro.configs.base import SystemConfig  # noqa: E402
from repro.core.dse import explore  # noqa: E402
from repro.search import SearchRun  # noqa: E402


def main():
    g = fsdp_stack(n_layers=16, ranks=16)
    sysc = SystemConfig(chips=16, topology="switch")
    knobs = fsdp_reorder_knobs()

    grid = explore(lambda cfg: g, sysc, knobs)
    optimum = grid[0].objective
    budget = len(grid) // 4
    print(f"[search] grid: {len(grid)} trials, optimum "
          f"{optimum * 1e3:.3f} ms at {grid[0].config}")
    print(f"[search] budget for model-guided strategies: {budget} trials "
          f"(25% of grid)\n")

    for strategy in ("random", "bayesian"):
        res = SearchRun(lambda cfg: g, sysc, knobs, strategy=strategy,
                        budget=budget, seed=0).run()
        curve, best = [], float("inf")
        for t in res.full_trials:
            best = min(best, t.objectives["total_time"])
            curve.append(best)
        marks = {1, 4, 8, 16, budget}
        steps = "  ".join(f"@{i + 1}:{v / optimum:.3f}x"
                          for i, v in enumerate(curve) if i + 1 in marks)
        hit = next((i + 1 for i, v in enumerate(curve)
                    if v <= optimum * 1.02), None)
        print(f"[search] {strategy:<10} best-so-far vs optimum: {steps}")
        print(f"[search] {strategy:<10} within 2% after "
              f"{hit if hit else '>' + str(budget)} trials "
              f"(grid needs up to {len(grid)})\n")

    # multi-objective: step time vs the analytical peak-memory proxy —
    # the front is the artifact, not a single winner
    res = SearchRun(lambda cfg: g, sysc, knobs, strategy="random",
                    objectives=("total_time", "peak_memory_proxy"),
                    budget=budget, seed=0).run()
    front = sorted(res.pareto_trials(),
                   key=lambda t: t.objectives["total_time"])
    print(f"[search] pareto front (time vs memory proxy), "
          f"{len(front)} configs:")
    for t in front:
        print(f"    prefetch={t.config['prefetch']:<3} "
              f"bucket={t.config['bucket_bytes']!s:<12} "
              f"time {t.objectives['total_time'] * 1e3:7.3f} ms   "
              f"mem {t.objectives['peak_memory_proxy'] / 1e6:7.1f} MB")


if __name__ == "__main__":
    main()
