"""Batched serving example: prefill a batch of prompts, decode with a
ring-buffer KV cache, sample continuations.

  python examples/serve_batched.py --arch gemma3-4b
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ParallelConfig  # noqa: E402
from repro.configs.registry import ARCH_NAMES, get_config  # noqa: E402
from repro.models import build_model  # noqa: E402
from repro.train.serve_step import (make_decode_step,  # noqa: E402
                                    make_prefill_step, sample_token)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gemma3-4b", choices=ARCH_NAMES)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=48)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    cfg = get_config(args.arch, smoke=True)     # reduced config: CPU-friendly
    model = build_model(cfg)
    par = ParallelConfig()
    cache_len = args.prompt_len + args.gen

    rng = jax.random.PRNGKey(0)
    params = model.init(rng)
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.batch, args.prompt_len), 0,
                                 cfg.vocab_size)
    memory = None
    if model.memory_len():
        memory = jax.random.normal(
            jax.random.PRNGKey(2),
            (args.batch, model.memory_len(), cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(model, par, cache_len=cache_len))
    decode = jax.jit(make_decode_step(model, par), donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, prompts, memory)
    jax.block_until_ready(logits)
    print(f"[serve] prefill {args.batch}x{args.prompt_len}: "
          f"{(time.perf_counter() - t0) * 1e3:.0f} ms")

    tok = sample_token(logits, rng, args.temperature)
    generated = [tok]
    t0 = time.perf_counter()
    for _ in range(args.gen - 1):
        rng, k = jax.random.split(rng)
        logits, cache = decode(params, tok, cache)
        tok = sample_token(logits, k, args.temperature)
        generated.append(tok)
    jax.block_until_ready(tok)
    dt = time.perf_counter() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"[serve] decoded {args.gen - 1} steps x {args.batch} seqs: "
          f"{dt * 1e3:.0f} ms ({args.batch * (args.gen - 1) / dt:.1f} tok/s)")
    for b in range(min(2, args.batch)):
        print(f"  seq{b}: {out[b, :16].tolist()} ...")


if __name__ == "__main__":
    main()
