"""Delta re-simulation (costmodel.delta): suffix-resume results are
bit-identical to full re-runs — the property the whole optimization
rests on.  Randomized DAGs x random perturbation subsets (including the
zero-changed and all-changed edges), every (overlap, keep_timeline)
mode, plus the simulate_batch / simulate / simulate_cluster routing."""
import random

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import (DeltaBase, build_topology, compile_graph,
                                  delta_base, simulate, simulate_batch,
                                  simulate_cluster)
from repro.core.costmodel.simulator import _override
from test_compiled_sim import rand_graph

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)

FIELDS = ("total_time", "compute_time", "comm_time", "exposed_comm",
          "peak_bytes", "n_nodes")


def assert_identical(got, want):
    for f in FIELDS:
        assert getattr(got, f) == getattr(want, f), \
            f"{f}: {getattr(got, f)!r} != {getattr(want, f)!r}"
    assert got.timeline == want.timeline


def perturb(rng: random.Random, base, k: int):
    """k random rows changed by random factors (occasionally to zero)."""
    picks = rng.sample(range(len(base)), k)
    return {nid: (0.0 if rng.random() < 0.1
                  else base[nid] * rng.uniform(0.3, 3.0))
            for nid in picks}


def test_delta_bit_identical_on_randomized_dags():
    """>= 50 seeded random DAGs x random duration-subset perturbations:
    makespan, per-node finish times (spans), exposed comm and the full
    timeline all match the full re-run bit for bit."""
    checked = 0
    for seed in range(52):
        rng = random.Random(seed)
        n = rng.randint(20, 120)
        g = rand_graph(rng, n)
        cg = compile_graph(g)
        base = cg.durations(SYS, TOPO, "auto", 0.6)
        overlap = seed % 3 != 0
        db = DeltaBase(cg, base, overlap=overlap, keep_timeline=True,
                       n_checkpoints=rng.choice([1, 3, 16, 10 ** 6]))
        # the base run itself is bit-identical to a plain run()
        assert_identical(db.result, cg.run(base, overlap=overlap,
                                           keep_timeline=True))
        for k in {0, 1, rng.randint(1, n), n}:     # incl. zero/all-changed
            ov = perturb(rng, base, k)
            want = cg.run(_override(base, ov), overlap=overlap,
                          keep_timeline=True)
            assert_identical(db.run(ov), want)
            checked += 1
        # per-node finish times of the base run match its own spans
        ends = {s.nid: s.end for s in db.result.spans()}
        assert all(db.finish[nid] == e for nid, e in ends.items())
    assert checked >= 200


def test_delta_noop_override_is_base_copy():
    """Overrides equal to base values (or out of range) are not changes —
    same semantics as simulator._override — and return a fresh result."""
    rng = random.Random(7)
    g = rand_graph(rng, 60)
    cg = compile_graph(g)
    base = cg.durations(SYS, TOPO, "auto", 0.6)
    db = DeltaBase(cg, base)
    same = {3: base[3], 10: base[10], -1: 99.0, cg.n + 5: 99.0}
    assert db.earliest_decision(same) == cg.n
    r1, r2 = db.run(same), db.run({})
    assert r1 == r2 == db.result
    assert r1 is not db.result and r1 is not r2


def test_delta_base_memo_and_peek():
    g = rand_graph(random.Random(11), 40)
    cg = compile_graph(g)
    base = cg.durations(SYS, TOPO, "auto", 0.6)
    assert delta_base(cg, base, build=False) is None      # cold peek: None
    db = delta_base(cg, base)
    assert delta_base(cg, base) is db                     # memo hit
    assert delta_base(cg, base, build=False) is db        # warm peek
    assert delta_base(cg, base, overlap=False) is not db  # keyed on mode


def test_simulate_batch_delta_modes_identical():
    rng = random.Random(21)
    g = rand_graph(rng, 80)
    cg = compile_graph(g)
    base = cg.durations(SYS, TOPO, "auto", 0.6)
    ovs = [None, {}, perturb(rng, base, 1), perturb(rng, base, 9),
           perturb(rng, base, len(base))]
    full = simulate_batch(g, SYS, ovs, TOPO, delta=False)
    for mode in ("auto", True):
        got = simulate_batch(g, SYS, ovs, TOPO, delta=mode)
        assert got == full, mode


def test_simulate_reuses_batch_delta_base():
    """simulate(durations=...) picks up a base an earlier simulate_batch
    memoized — and stays bit-identical to the delta-off path."""
    rng = random.Random(33)
    g = rand_graph(rng, 70)
    cg = compile_graph(g)
    base = cg.durations(SYS, TOPO, "auto", 0.6)
    ov = perturb(rng, base, 5)
    cold = simulate(g, SYS, TOPO, durations=ov)     # no base memoized yet
    simulate_batch(g, SYS, [ov, perturb(rng, base, 3)], TOPO)
    assert delta_base(cg, base, build=False) is not None
    warm = simulate(g, SYS, TOPO, durations=ov)     # delta="auto" hits it
    off = simulate(g, SYS, TOPO, durations=ov, delta=False)
    assert cold == warm == off


def test_simulate_cluster_delta_single_class():
    """Uniform rank overrides coalesce to one class with no barriers —
    the delta-eligible shape; forced-on delta matches the engine."""
    rng = random.Random(41)
    g = rand_graph(rng, 60)
    cg = compile_graph(g)
    base = cg.durations(SYS, TOPO, "auto", 0.6)
    ov = perturb(rng, base, 6)
    rd = {r: ov for r in range(8)}
    want = simulate_cluster(g, SYS, TOPO, n_ranks=8, rank_durations=rd,
                            delta=False, memoize=False)
    got = simulate_cluster(g, SYS, TOPO, n_ranks=8, rank_durations=rd,
                           delta=True, memoize=False)
    assert got.step_time == want.step_time
    assert [r.total_time for r in got.results] \
        == [r.total_time for r in want.results]
    assert got.results[0] == want.results[0]
    assert got.class_barrier_wait == want.class_barrier_wait


def test_simulate_cluster_delta_skips_multi_class():
    """A straggler rank splits classes; delta=True must fall through to
    the barrier engine (and still match the delta-off run)."""
    rng = random.Random(43)
    g = rand_graph(rng, 60)
    cg = compile_graph(g)
    base = cg.durations(SYS, TOPO, "auto", 0.6)
    rd = {0: {nid: base[nid] * 2.0 for nid in range(0, cg.n, 3)}}
    want = simulate_cluster(g, SYS, TOPO, n_ranks=8, rank_durations=rd,
                            delta=False, memoize=False)
    got = simulate_cluster(g, SYS, TOPO, n_ranks=8, rank_durations=rd,
                           delta=True, memoize=False)
    assert got.step_time == want.step_time
    assert got.class_barrier_wait == want.class_barrier_wait


def test_delta_rejects_wrong_length():
    g = rand_graph(random.Random(5), 20)
    cg = compile_graph(g)
    with pytest.raises(ValueError, match="entries"):
        DeltaBase(cg, [1.0] * (cg.n - 1))
