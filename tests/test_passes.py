"""Graph passes: the data-dependency-preservation invariant (hypothesis),
plus behavioural checks mirroring paper Fig 3b."""
try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:          # container without hypothesis: deterministic stub
    import _hypothesis_stub as st
    from _hypothesis_stub import given, settings

from repro.core import chakra, passes


def _fsdp_like_graph(n_layers=6):
    """AG_i -> compute_i chain (weights AGs have no data deps, like FSDP)."""
    g = chakra.Graph()
    prev_comp = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=100.0, out_bytes=100.0, group=[0, 1, 2, 3])
        deps = [ag] + ([prev_comp] if prev_comp is not None else [])
        prev_comp = g.add(f"comp{i}", chakra.COMP, deps=deps, flops=1e9,
                          bytes=1e6, out_bytes=10.0)
    return g


# -- hypothesis: random DAGs ------------------------------------------------

@st.composite
def random_dag(draw):
    n = draw(st.integers(4, 30))
    g = chakra.Graph()
    for i in range(n):
        maxdeps = min(i, 3)
        deps = draw(st.lists(st.integers(0, i - 1), max_size=maxdeps,
                             unique=True)) if i else []
        if draw(st.booleans()) and i > 0:
            g.add(f"c{i}", chakra.COMM_COLL, deps=deps,
                  comm_kind=draw(st.sampled_from(
                      ["all-gather", "all-reduce"])),
                  comm_bytes=float(draw(st.integers(1, 10_000))),
                  out_bytes=8.0, group=[0, 1])
        else:
            g.add(f"n{i}", chakra.COMP, deps=deps,
                  flops=float(draw(st.integers(0, 10**9))), out_bytes=8.0)
    return g


@given(random_dag(), st.integers(0, 5))
@settings(max_examples=40, deadline=None)
def test_passes_preserve_data_deps(g, prefetch):
    data_deps_before = [(n.id, tuple(n.deps)) for n in g.nodes]
    for p in (passes.inject_fsdp_sync(g),
              passes.reorder_prefetch(passes.inject_fsdp_sync(g), prefetch),
              passes.strip_ctrl_deps(g)):
        p.validate()
        for (nid, deps), n in zip(data_deps_before, p.nodes):
            if n.type != chakra.MEM:     # bucketing may neutralize nodes
                assert tuple(n.deps) == deps


@given(random_dag(), st.floats(8, 1e5))
@settings(max_examples=40, deadline=None)
def test_bucketing_conserves_comm_bytes(g, bucket):
    before = g.totals()["comm"].get("all-reduce", {"bytes": 0})["bytes"]
    g2 = passes.bucket_allreduce(g, bucket_bytes=bucket)
    after = g2.totals()["comm"].get("all-reduce", {"bytes": 0})["bytes"]
    assert abs(before - after) < 1e-6
    g2.validate()


# -- behavioural --------------------------------------------------------------

def test_sync_injection_adds_only_ctrl_deps():
    g = _fsdp_like_graph()
    g2 = passes.inject_fsdp_sync(g)
    extra = sum(len(n.ctrl_deps) for n in g2.nodes) \
        - sum(len(n.ctrl_deps) for n in g.nodes)
    assert extra == 5                       # all but the first AG get an edge


def test_reorder_prefetch_all_removes_sync():
    g = passes.inject_fsdp_sync(_fsdp_like_graph())
    g2 = passes.reorder_prefetch(g, prefetch=100)
    ags = [n for n in g2.by_type(chakra.COMM_COLL)]
    assert all(not n.ctrl_deps for n in ags)


def test_bucketing_merges_small_ars():
    g = chakra.Graph()
    c = g.add("c", chakra.COMP, flops=1)
    for i in range(8):
        g.add(f"ar{i}", chakra.COMM_COLL, deps=[c], comm_kind="all-reduce",
              comm_bytes=10.0, group=[0, 1])
    g2 = passes.bucket_allreduce(g, bucket_bytes=40.0)
    live = [n for n in g2.by_type(chakra.COMM_COLL)]
    assert len(live) == 2                   # 8 x 10B into 40B buckets
    assert all(n.attrs["comm_bytes"] == 40.0 for n in live)
