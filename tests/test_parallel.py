"""Process-pool DSE: map_fork ordering/error contracts, explore's pool
path vs serial, SearchRun generation batching + gen-tagged checkpoint
replay, and monte_carlo trial fan-out — all bit-identical to serial."""
import os
import random

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra, dse, pool
from repro.search.run import SearchRun
from test_compiled_sim import rand_graph

SYS = SystemConfig(chips=16)


def simple_graph():
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=1e9, bytes=1e7)
    b = g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-reduce",
              comm_bytes=1e6, group=list(range(8)), out_bytes=8.0)
    g.add("b", chakra.COMP, deps=[b], flops=2e9, bytes=1e7)
    return g


KNOBS = [dse.Knob("link_bw", [25e9, 50e9, 100e9, 200e9], layer="hardware"),
         dse.Knob("prefetch", [None, 2], layer="software")]


# -- map_fork ----------------------------------------------------------------

def _square_or_boom(x):
    if x == 5:
        raise ValueError(f"boom {x}")
    return x * x


def test_map_fork_order_and_errors():
    """Results come back in item order (never completion order), with
    per-item stringified errors; the serial fallback is byte-identical."""
    want = [(None, "ValueError: boom 5") if i == 5 else (i * i, None)
            for i in range(11)]
    assert pool.map_fork(_square_or_boom, range(11), jobs=3) == want
    assert pool.map_fork(_square_or_boom, range(11), jobs=1) == want


def test_map_fork_inherits_closures():
    """Fork workers see the parent's heap — the whole reason the pool is
    fork-based: graph_for lambdas and memo caches never cross a pickle
    boundary."""
    big = {"k": [10, 20, 30]}
    got = pool.map_fork(lambda i: big["k"][i] + i, range(3), jobs=2)
    assert got == [(10, None), (21, None), (32, None)]


def test_map_fork_empty_and_single():
    assert pool.map_fork(lambda x: x, [], jobs=4) == []
    assert pool.map_fork(lambda x: x + 1, [41], jobs=4) == [(42, None)]


# -- explore -----------------------------------------------------------------

def test_explore_pool_matches_serial_and_raises():
    g = rand_graph(random.Random(9), 40)
    serial = dse.explore(lambda cfg: g, SYS, KNOBS)
    pooled = dse.explore(lambda cfg: g, SYS, KNOBS, parallel=4)
    assert [t.config for t in pooled] == [t.config for t in serial]
    assert [t.objective for t in pooled] == [t.objective for t in serial]

    # an evaluation-time error (invalid pipeline split) surfaces from the
    # worker as RuntimeError naming the config and the original error
    bad = [dse.Knob("num_stages", [1, 64], layer="workload")]
    with pytest.raises(RuntimeError, match="failed in worker.*exceeds"):
        dse.explore(lambda cfg: g, SYS, bad, parallel=4)


# -- SearchRun jobs ----------------------------------------------------------

def test_searchrun_jobs_identical_for_tell_independent():
    """grid/random asks don't depend on tells, so a batched run IS the
    serial trial sequence, objectives and all."""
    g = simple_graph()
    for strat in ("grid", "random"):
        r1 = SearchRun(lambda cfg: g, SYS, KNOBS, strategy=strat, budget=8,
                       seed=3, jobs=1).run()
        rn = SearchRun(lambda cfg: g, SYS, KNOBS, strategy=strat, budget=8,
                       seed=3, jobs=3).run()
        assert [t.config for t in rn.trials] == [t.config for t in r1.trials]
        assert [t.objective for t in rn.trials] \
            == [t.objective for t in r1.trials]
        assert [t.gen for t in rn.trials][:6] == [0, 0, 0, 3, 3, 3]
        assert all(t.gen is None for t in r1.trials)


@pytest.mark.parametrize("strategy", ["bayesian", "evolutionary", "halving"])
def test_searchrun_batched_checkpoint_replays(tmp_path, strategy):
    """A jobs>1 checkpoint resumes under any jobs value: gen tags let
    replay reproduce the ask-all-then-tell-all interleaving, so even
    tell-dependent strategies verify every recorded config."""
    g = simple_graph()
    ck = str(tmp_path / "ck.jsonl")
    first = SearchRun(lambda cfg: g, SYS, KNOBS, strategy=strategy,
                      budget=8, seed=1, checkpoint=ck, jobs=3).run()
    assert first.n_evaluated == len(first.trials)
    for jobs in (1, 3):
        again = SearchRun(lambda cfg: g, SYS, KNOBS, strategy=strategy,
                          budget=8, seed=1, checkpoint=ck, jobs=jobs).run()
        assert again.n_resumed == len(first.trials)
        assert [t.config for t in again.trials] \
            == [t.config for t in first.trials]
        assert [t.gen for t in again.trials] == [t.gen for t in first.trials]


def test_searchrun_batch_records_failures(tmp_path):
    """A config that explodes inside a pool worker is recorded as a failed
    trial (error string + penalty objective), not a dead sweep — the
    exact serial semantics."""
    g = simple_graph()

    def graph_for(cfg):
        if cfg.get("arch") == "bad":
            raise RuntimeError("no such arch")
        return g

    knobs = KNOBS + [dse.Knob("arch", ["ok", "bad"], layer="workload")]
    ck = str(tmp_path / "ck.jsonl")
    res = SearchRun(graph_for, SYS, knobs, strategy="grid", budget=16,
                    seed=0, checkpoint=ck, jobs=4).run()
    failed = res.failed_trials
    assert len(failed) == 8
    assert all("no such arch" in t.error for t in failed)
    assert all(t.objective == 1e6 for t in failed)
    resumed = SearchRun(graph_for, SYS, knobs, strategy="grid", budget=16,
                        seed=0, checkpoint=ck, jobs=1).run()
    assert resumed.n_resumed == 16


# -- monte_carlo -------------------------------------------------------------

def test_monte_carlo_jobs_bit_identical():
    from repro.faults.montecarlo import monte_carlo
    from repro.faults.scenario import CheckpointPolicy, FaultRates

    g = simple_graph()
    rates = FaultRates(fail_rate=2e-4, fail_downtime=2.0,
                       slowdown_rate=5e-4)
    pol = CheckpointPolicy(interval=10, write_cost=0.5, restore_cost=1.0)
    r1 = monte_carlo(g, SYS, rates, pol, n_steps=40, n_trials=6, seed=4)
    rj = monte_carlo(g, SYS, rates, pol, n_steps=40, n_trials=6, seed=4,
                     jobs=3)
    assert r1.as_dict() == rj.as_dict()
