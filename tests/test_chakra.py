"""Chakra graph schema: construction, validation, serialization, conversion."""
import json

import pytest

from repro.core import chakra
from repro.core.convert import expand_collective_p2p, hlo_to_chakra
from repro.core.hlo_parse import parse_hlo


def _diamond():
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=10, out_bytes=4)
    b = g.add("b", chakra.COMP, deps=[a], flops=5, out_bytes=4)
    c = g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-reduce",
              comm_bytes=100, group=[0, 1])
    d = g.add("d", chakra.COMP, deps=[b, c], flops=1, out_bytes=4)
    return g, (a, b, c, d)


def test_topo_and_validate():
    g, (a, b, c, d) = _diamond()
    order = g.topo_order()
    assert order.index(a) < order.index(b) < order.index(d)
    assert g.validate()


def test_cycle_detection():
    g, (a, b, c, d) = _diamond()
    g.node(a).deps.append(d)
    with pytest.raises(ValueError):
        g.topo_order()


def test_json_roundtrip():
    g, _ = _diamond()
    g2 = chakra.Graph.from_json(g.to_json())
    assert len(g2) == len(g)
    assert g2.node(2).attrs["comm_kind"] == "all-reduce"
    assert g2.node(3).deps == [1, 2]


def test_totals():
    g, _ = _diamond()
    t = g.totals()
    assert t["flops"] == 16
    assert t["comm"]["all-reduce"]["bytes"] == 100


WHILE_HLO = """
HloModule m, num_partitions=4

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %n = s32[] constant(3)
  ROOT %lt = pred[] compare(%i, %n), direction=LT
}

%body (p2: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p2 = (s32[], f32[4]) parameter(0)
  %i2 = s32[] get-tuple-element(%p2), index=0
  %x = f32[4]{0} get-tuple-element(%p2), index=1
  %one = s32[] constant(1)
  %nxt = s32[] add(%i2, %one)
  %ar = f32[4]{0} all-reduce(%x), channel_id=1, replica_groups=[2,2]<=[4], to_apply=%add
  ROOT %t = (s32[], f32[4]) tuple(%nxt, %ar)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[4]) tuple(%z, %a)
  %w = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
  ROOT %o = f32[4]{0} get-tuple-element(%w), index=1
}
"""


def test_while_expansion_chains_iterations():
    mod = parse_hlo(WHILE_HLO)
    g = hlo_to_chakra(mod)
    ars = [n for n in g.by_type(chakra.COMM_COLL)]
    assert len(ars) == 3                      # expanded 3 iterations
    # carried dep: iteration t's AR depends on iteration t-1's AR
    by_name = {n.name: n for n in ars}
    it1 = by_name["w.it1/ar"]
    it0 = by_name["w.it0/ar"]
    assert it0.id in it1.deps
    g.validate()


def test_collapsed_while_without_collectives():
    hlo = WHILE_HLO.replace(
        "%ar = f32[4]{0} all-reduce(%x), channel_id=1, "
        "replica_groups=[2,2]<=[4], to_apply=%add",
        "%ar = f32[4]{0} multiply(%x, %x)")
    mod = parse_hlo(hlo)
    g = hlo_to_chakra(mod)
    col = [n for n in g.nodes if n.attrs.get("op") == "while.collapsed"]
    assert len(col) == 1 and col[0].attrs["trips"] == 3
    assert not g.by_type(chakra.COMM_COLL)


def test_p2p_expansion_ring():
    msgs = expand_collective_p2p("all-reduce", 1000, [0, 1, 2, 3], "ring")
    assert len(msgs) == 4 * 6                  # 2(n-1) rounds x n msgs
    assert all(abs(m[2] - 250) < 1e-9 for m in msgs)


def test_p2p_expansion_hd():
    msgs = expand_collective_p2p("all-gather", 1024, list(range(8)), "hd")
    assert len(msgs) == 8 * 3                  # log2(8) rounds x n
