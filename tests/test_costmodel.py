"""Cost models: collective time formulas, topologies, simulator, roofline."""
import math

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import (MultiPod, Ring, Switch, Torus2D, Wafer2D,
                                  build_topology, collective_time,
                                  model_flops_per_step, roofline, simulate,
                                  synthesize_2d_p2p, synthesize_2d_time)


SYS = SystemConfig(chips=16)


def test_ring_allreduce_formula():
    topo = Switch(n_ranks=8, link_bw=100e9, link_latency=1e-6)
    n, size = 8, 1e9
    t = collective_time("all-reduce", size, list(range(n)), topo, "ring")
    expect = 2 * (n - 1) / n * size / 100e9 + 2 * (n - 1) * 1e-6
    assert abs(t - expect) / expect < 1e-9


def test_allgather_half_of_allreduce():
    topo = Switch(n_ranks=8, link_bw=100e9, link_latency=0.0)
    ar = collective_time("all-reduce", 1e9, list(range(8)), topo, "ring")
    ag = collective_time("all-gather", 1e9, list(range(8)), topo, "ring")
    assert abs(ar - 2 * ag) < 1e-12


def test_hd_fewer_latency_terms():
    topo = Switch(n_ranks=16, link_bw=100e9, link_latency=10e-6)
    ring = collective_time("all-gather", 1e6, list(range(16)), topo, "ring")
    hd = collective_time("all-gather", 1e6, list(range(16)), topo, "hd")
    assert hd < ring                     # log(n) vs n-1 latency terms


def test_torus_axis_groups():
    t = Torus2D(n_ranks=16, link_bw=50e9, link_latency=1e-6, dims=(4, 4))
    assert t.group_is_axis([0, 1, 2, 3])          # one row
    assert t.group_is_axis([0, 4, 8, 12])         # one column
    assert not t.group_is_axis([0, 1, 4, 5])
    assert t.hop_distance(0, 3) == 1              # wrap
    assert Wafer2D(n_ranks=16, link_bw=50e9, link_latency=1e-6,
                   dims=(4, 4)).hop_distance(0, 3) == 3   # no wrap


def test_2d_synth_beats_long_ring_on_wafer():
    w = Wafer2D(n_ranks=64, link_bw=50e9, link_latency=1e-6, dims=(8, 8))
    group = list(range(64))
    ring = collective_time("all-reduce", 1e9, group, w, "ring")
    synth = synthesize_2d_time("all-reduce", 1e9, group, w)
    assert synth < ring


def test_2d_synth_p2p_messages_ride_axes():
    w = Wafer2D(n_ranks=16, link_bw=50e9, link_latency=1e-6, dims=(4, 4))
    msgs = synthesize_2d_p2p("all-reduce", 1e6, list(range(16)), w)
    assert msgs
    for src, dst, size, rnd in msgs:
        assert w.hop_distance(src, dst) <= w.dims[0] - 1


def test_multipod_cross_pod_limited_by_dcn():
    inner = Torus2D(n_ranks=8, link_bw=50e9, link_latency=1e-6, dims=(2, 4))
    mp = MultiPod(n_ranks=16, link_bw=50e9, link_latency=1e-6, inner=inner,
                  n_pods=2, dcn_bw=10e9)
    assert mp.ring_bw(list(range(16))) == 10e9
    assert mp.ring_bw([0, 1, 2, 3]) > 10e9       # intra-pod


def test_simulator_chain_vs_parallel_overlap():
    sysc = SystemConfig(chips=4, peak_flops=1e12, hbm_bw=1e12, link_bw=100e9)
    topo = build_topology(sysc, 4)
    # comp(1ms) -> comm(1ms) -> comp(1ms): serial = 3ms-ish
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=0.6e9)          # 1ms at derate 0.6
    c = g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-gather",
              comm_bytes=2e8 / 1.5, group=[0, 1, 2, 3])
    b = g.add("b", chakra.COMP, deps=[c], flops=0.6e9)
    r = simulate(g, sysc, topo)
    assert r.total_time == pytest.approx(r.compute_time + r.comm_time, rel=1e-6)
    # same comm with no dependency on compute -> fully overlapped
    g2 = chakra.Graph()
    a2 = g2.add("a", chakra.COMP, flops=0.6e9)
    g2.add("c", chakra.COMM_COLL, comm_kind="all-gather",
           comm_bytes=2e8 / 1.5, group=[0, 1, 2, 3])
    g2.add("b", chakra.COMP, deps=[a2], flops=0.6e9)
    r2 = simulate(g2, sysc, topo)
    assert r2.total_time < r.total_time
    assert r2.exposed_comm < r.exposed_comm


def test_simulator_memory_liveness():
    sysc = SystemConfig(chips=2)
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=1e9, out_bytes=100.0)
    b = g.add("b", chakra.COMP, deps=[a], flops=1e9, out_bytes=50.0)
    c = g.add("c", chakra.COMP, deps=[b], flops=1e9, out_bytes=10.0)
    r = simulate(g, sysc, build_topology(sysc, 2))
    # a freed once b (its only consumer) finishes; peak = a+b live together
    assert r.peak_bytes == pytest.approx(150.0)


def test_roofline_terms_and_bound():
    sysc = SystemConfig()
    summary = {"parsed_flops": 1.97e14, "parsed_hbm_bytes_tpu": 8.19e10,
               "comm_bytes_tpu": 5e10, "comm_bytes": 1e11}
    rl = roofline(summary, {"flops": 1e13, "bytes accessed": 1e10}, sysc,
                  model_flops_per_device=1e14)
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.memory_s == pytest.approx(0.1)
    assert rl.collective_s == pytest.approx(1.0)
    assert rl.bound in ("compute", "collective")
    assert rl.useful_ratio == pytest.approx(1e14 / 1.97e14)


def test_model_flops_train_vs_decode():
    from repro.configs.registry import get_config, get_shape
    cfg = get_config("qwen3-8b")
    tr = model_flops_per_step(cfg, get_shape("train_4k"), 256)
    dec = model_flops_per_step(cfg, get_shape("decode_32k"), 256)
    assert tr / dec == pytest.approx(
        3 * 256 * 4096 / 128)        # 6ND*tokens vs 2ND*batch
