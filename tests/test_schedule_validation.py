"""Up-front validation of the pipeline-schedule knobs (ISSUE 10 bugfix).

A bad ``num_microbatches`` / ``schedule`` / ``virtual_stages`` must fail
*fast* with a diagnostic listing the valid choices — and because
``PipelineConfigError`` is a ``ValueError``, a sweep records it as a
failed trial instead of crashing the whole search.
"""
import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.convert import split_pipeline_stages
from repro.core.costmodel.schedule import (SCHEDULES, PipelineConfigError,
                                           validate_pipeline_schedule)


def chain(n=8):
    g = chakra.Graph()
    prev = None
    for i in range(n):
        prev = g.add(f"L{i}", chakra.COMP,
                     deps=[prev] if prev is not None else [],
                     flops=1e11, out_bytes=1e4)
    return g


# ------------------------------------------------------- direct validation

def test_error_is_a_value_error():
    assert issubclass(PipelineConfigError, ValueError)


def test_normalization_defaults():
    assert validate_pipeline_schedule(4) == (1, "gpipe", 1)
    assert validate_pipeline_schedule(4, 8, "1F1B") == (8, "1f1b", 1)
    # interleaved defaults to 2 chunks per rank once there is scheduling
    assert validate_pipeline_schedule(4, 8, "interleaved") == \
        (8, "interleaved", 2)
    assert validate_pipeline_schedule(4, 1, "interleaved") == \
        (1, "interleaved", 1)


@pytest.mark.parametrize("bad_m", [0, -1, 2.5, "four"])
def test_bad_microbatch_count(bad_m):
    with pytest.raises(PipelineConfigError, match="integer >= 1"):
        validate_pipeline_schedule(4, bad_m)


def test_unknown_schedule_lists_choices():
    with pytest.raises(PipelineConfigError) as ei:
        validate_pipeline_schedule(4, 4, "pipedream")
    msg = str(ei.value)
    assert "pipedream" in msg
    for s in SCHEDULES:
        assert s in msg


def test_interleaved_divisibility():
    with pytest.raises(PipelineConfigError, match="divisible"):
        validate_pipeline_schedule(4, 6, "interleaved")
    # the diagnostic suggests valid counts
    with pytest.raises(PipelineConfigError, match="4, 8, 12"):
        validate_pipeline_schedule(4, 6, "interleaved")
    validate_pipeline_schedule(4, 8, "interleaved")      # ok


def test_virtual_stages_needs_interleaved():
    with pytest.raises(PipelineConfigError, match="interleaved"):
        validate_pipeline_schedule(4, 4, "gpipe", virtual_stages=2)
    with pytest.raises(PipelineConfigError, match=">= 1"):
        validate_pipeline_schedule(4, 4, "interleaved", virtual_stages=0)


def test_m1_accepts_every_schedule():
    for s in SCHEDULES:
        m, sched, v = validate_pipeline_schedule(4, 1, s)
        assert m == 1 and sched == s


# ------------------------------------------------- split rejects up front

def test_split_validates_before_lowering():
    g = chain()
    with pytest.raises(PipelineConfigError, match="integer >= 1"):
        split_pipeline_stages(g, 4, num_microbatches=0)
    with pytest.raises(PipelineConfigError, match="valid schedules"):
        split_pipeline_stages(g, 4, num_microbatches=4, schedule="nope")
    with pytest.raises(PipelineConfigError, match="divisible"):
        split_pipeline_stages(g, 4, num_microbatches=6,
                              schedule="interleaved")


# ------------------------------------------- sweeps record failed trials

def test_search_records_bad_knobs_as_failed_trials():
    from repro.search.run import SearchRun
    from repro.search.space import Dim, SearchSpace

    space = SearchSpace([
        Dim.finite("num_stages", [4]),
        Dim.finite("num_microbatches", [0, 4]),
        Dim.finite("schedule", ["gpipe", "nonsense"]),
    ])
    run = SearchRun(lambda cfg: chain(), SystemConfig(chips=8), space,
                    strategy="grid",
                    objectives=("total_time", "bubble_fraction"), budget=8)
    res = run.run()
    by_cfg = {(t.config["num_microbatches"], t.config["schedule"]): t
              for t in res.trials}
    assert len(by_cfg) == 4            # the sweep survived every bad combo
    ok = by_cfg[(4, "gpipe")]
    assert ok.ok and ok.objectives["bubble_fraction"] >= 0.0
    bad_m = by_cfg[(0, "gpipe")]
    assert not bad_m.ok and "num_microbatches=0" in bad_m.error
    bad_s = by_cfg[(4, "nonsense")]
    assert not bad_s.ok and "nonsense" in bad_s.error
    assert "gpipe" in bad_s.error      # diagnostic lists valid schedules
