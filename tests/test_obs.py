"""Observability layer: disabled-overhead bound, fork-safe counter
identity, bit-exact blame attribution, explain diffs, flow events and the
report CLI."""
import json
import random

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra, convert
from repro.core.costmodel import build_topology, compile_graph, simulate
from repro.core.costmodel.simulator import simulate_cluster
from repro.core.dse import Knob
from repro.obs import record as obs
from repro.obs.explain import (COMPONENTS, blame, critical_path, explain,
                               explain_diff, utilization_counters)
from repro.search.run import SearchRun

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)


@pytest.fixture(autouse=True)
def _no_leaked_recorder():
    """Tests must not leak a live recorder into the rest of the suite."""
    obs.disable()
    yield
    obs.disable()


def rand_graph(rng: random.Random, n: int) -> chakra.Graph:
    """Random DAG over all node types (mirrors test_compiled_sim)."""
    g = chakra.Graph()
    for i in range(n):
        k = min(i, 4)
        deps = rng.sample(range(i), rng.randint(0, k)) if i else []
        ctrl = rng.sample(range(i), rng.randint(0, k)) if i else []
        r = rng.random()
        if r < 0.5 or i == 0:
            g.add(f"n{i}", chakra.COMP, deps=deps, ctrl_deps=ctrl,
                  flops=rng.uniform(0, 1e9), bytes=rng.uniform(0, 1e8),
                  out_bytes=rng.choice([0.0, rng.uniform(1, 100)]))
        elif r < 0.8:
            g.add(f"c{i}", chakra.COMM_COLL, deps=deps, ctrl_deps=ctrl,
                  comm_kind=rng.choice(["all-gather", "all-reduce",
                                        "reduce-scatter"]),
                  comm_bytes=rng.uniform(1, 1e7), out_bytes=8.0,
                  group=list(range(rng.choice([2, 4, 8, 16]))))
        else:
            g.add(f"m{i}", chakra.MEM, deps=deps, ctrl_deps=ctrl,
                  out_bytes=4.0)
    return g


def layer_stack(n_layers: int) -> chakra.Graph:
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        c = g.add(f"mm{i}", chakra.COMP,
                  deps=[prev] if prev is not None else [], flops=1e9,
                  bytes=1e7, out_bytes=1e4)
        a = g.add(f"ar{i}", chakra.COMM_COLL, deps=[c],
                  comm_kind="all-reduce", comm_bytes=4e6,
                  group=list(range(16)))
        prev = a
    return g


# ---------------------------------------------------------------------------
# recording primitives
# ---------------------------------------------------------------------------

def test_disabled_primitives_are_noops_and_cheap():
    import time
    assert not obs.recording()
    obs.counter("x")
    obs.gauge("x", 1.0)
    with obs.span("x"):
        pass
    assert obs.current() is None

    # modeled overhead bound (<3% of a 10k-node simulate): primitives
    # reached per engine run x measured disabled cost per primitive
    g = layer_stack(2500)
    simulate(g, SYS, TOPO)                        # warm
    cg = compile_graph(g)
    dur = cg.durations(SYS, TOPO)
    t0 = time.perf_counter()
    cg.run(dur)
    t_sim = time.perf_counter() - t0

    rec = obs.enable()
    cg.run(dur)
    n_events = rec.n_events
    obs.disable()
    assert n_events > 0

    n = 50_000
    t0 = time.perf_counter()
    for _ in range(n):
        obs.counter("noop")
    per_call = (time.perf_counter() - t0) / n
    overhead = n_events * per_call / t_sim * 100.0
    assert overhead < 3.0


def test_counters_spans_and_hit_rates():
    rec = obs.enable()
    obs.counter("a.hit")
    obs.counter("a.hit")
    obs.counter("a.miss")
    obs.counter("weighted", 2.5)
    obs.gauge("g", 7.0)
    with obs.span("work"):
        pass
    assert rec.counters["a.hit"] == 2.0
    assert rec.counters["weighted"] == 2.5
    hr = obs.hit_rates(rec.counters)
    assert hr["a"]["rate"] == pytest.approx(2.0 / 3.0)
    m = obs.metrics_dict()
    assert m["schema"] == obs.METRICS_SCHEMA
    assert m["gauges"]["g"] == 7.0
    assert m["spans"]["by_name"]["work"]["n"] == 1
    obs.disable()


def test_span_cap_drops_and_counts():
    rec = obs.enable(span_cap=3)
    for _ in range(5):
        with obs.span("s"):
            pass
    assert len(rec.spans) == 3
    assert rec.dropped_spans == 2
    obs.disable()


def test_sim_stack_counters():
    """The instrumented engine paths produce the advertised counters."""
    g = layer_stack(10)
    rec = obs.enable()
    simulate(g, SYS, TOPO)
    simulate(g, SYS, TOPO)                        # second run hits the memo
    c = rec.counters
    assert c["compile.graphs"] == 1.0
    assert c["engine.runs"] == 1.0
    assert c["sim.result_cache.miss"] == 1.0
    assert c["sim.result_cache.hit"] == 1.0
    assert any(s[0] == "engine.run" for s in rec.spans)
    obs.disable()


def test_counter_identity_serial_vs_pooled():
    """A pooled sweep reports the same counter totals as a serial one."""
    knobs = [Knob("prefetch", [0, 2, 4]), Knob("bucket_bytes", [None, 64e6])]

    def sweep(jobs: int):
        # fresh graphs per run so neither sweep sees the other's caches
        def graph_for(cfg):
            return layer_stack(8)
        rec = obs.enable()
        SearchRun(graph_for, SYS, knobs, strategy="grid", budget=6,
                  seed=0, jobs=jobs).run()
        obs.disable()
        return rec

    serial = sweep(1)
    pooled = sweep(4)
    # generation *count* is a batching observable (6x1 serial vs 4+2
    # pooled) — every work counter must match exactly
    sc = {k: v for k, v in serial.counters.items()
          if k != "search.generations"}
    pc = {k: v for k, v in pooled.counters.items()
          if k != "search.generations"}
    assert sc == pc
    assert serial.counters["search.gen_trials"] == \
        pooled.counters["search.gen_trials"] == 6.0
    # pool/worker stats live outside counters; a forked run records them
    from repro.core.pool import pool_available
    if pool_available():
        assert pooled.pool.get("sections")
        assert pooled.workers
        assert sum(w["items"] for w in pooled.workers.values()) == 6
    assert serial.pool == {}


def test_search_and_fault_counters():
    from repro.faults import CheckpointPolicy, FaultRates
    from repro.faults.montecarlo import monte_carlo
    g = layer_stack(6)
    rec = obs.enable()
    s0 = float(simulate_cluster(g, SYS, TOPO).total_time)
    rates = FaultRates(fail_rate=1.0 / (100 * s0), fail_downtime=20 * s0)
    pol = CheckpointPolicy(interval=10, write_cost=s0, restore_cost=s0)
    monte_carlo(g, SYS, rates, pol, topo=TOPO, n_steps=40, n_trials=3,
                seed=1)
    c = rec.counters
    assert c.get("faults.segment_sim", 0) >= 1
    assert c.get("faults.memo_served", 0) >= 1
    obs.disable()


# ---------------------------------------------------------------------------
# blame attribution
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", [0, 1, 2, 3])
@pytest.mark.parametrize("overlap", [True, False])
def test_blame_sums_to_makespan_random_dags(seed, overlap):
    rng = random.Random(seed)
    g = rand_graph(rng, 120)
    res = simulate(g, SYS, TOPO, overlap=overlap, keep_timeline=True)
    e = explain(res, graph=g, with_critical_path=False)
    b = e.blame()
    assert b.total() == res.total_time            # bit-exact, not approx
    assert b.identity_ok()
    assert all(v >= 0.0 for v in b.components.values())
    # per-class terms are the same partition
    import math
    assert math.fsum(v for v in b.by_class.values()) == \
        pytest.approx(res.total_time, rel=1e-12)


def test_blame_identity_mpmd_pipeline():
    g = layer_stack(24)
    prog = convert.split_pipeline_stages(g, 2)
    res = simulate_cluster(prog, SYS, TOPO, keep_timeline=True)
    e = explain(res, graph=prog)
    assert e.identity_ok()
    for r, b in e.ranks.items():
        assert b.makespan == res.step_time
        assert b.total() == res.step_time         # every rank, bit-exact
    # a pipeline run has cross-stage dependencies: someone waits or stalls
    total_idle = sum(b.barrier_wait + b.stall for b in e.ranks.values())
    assert total_idle > 0.0


def test_blame_wait_split_and_stall():
    # hand-built spans: comp [0,2), comm with 3s wait [2,6), stall to 10
    from repro.core.costmodel.simulator import Span
    spans = [Span(0, "a", "comp", 0.0, 2.0),
             Span(1, "b", "comm", 2.0, 6.0, 3.0)]
    b = blame(spans, 10.0)
    assert b.components["compute_busy"] == 2.0
    assert b.components["barrier_wait"] == 3.0
    assert b.components["exposed_comm"] == 1.0
    assert b.components["stall"] == 4.0
    assert b.total() == 10.0


def test_explain_diff_identity():
    g = layer_stack(20)
    a = simulate(g, SYS, TOPO, keep_timeline=True)
    b = simulate(g, SYS, TOPO, keep_timeline=True, compute_derate=0.3)
    d = explain_diff(a, b, graph_a=g, graph_b=g)
    assert d.total() == b.total_time - a.total_time
    assert d.identity_ok()
    assert set(d.by_component) == set(COMPONENTS)
    # slower compute shows up as a positive compute/class delta
    assert d.delta_makespan > 0
    assert max(d.by_class.values()) > 0


def test_critical_path_terminates_and_chains():
    g = layer_stack(15)
    res = simulate(g, SYS, TOPO, keep_timeline=True)
    cp = critical_path(res, graph=g)
    assert 0 < len(cp) <= 2 * 15
    assert cp[-1].end == pytest.approx(res.total_time)
    for prev, cur in zip(cp, cp[1:]):
        assert cur.start >= prev.end - 1e-12

    prog = convert.split_pipeline_stages(g, 2)
    cres = simulate_cluster(prog, SYS, TOPO, keep_timeline=True)
    cpc = critical_path(cres, graph=prog)
    assert cpc
    assert cpc[-1].rank == cres.slowest_rank


def test_utilization_counters():
    g = layer_stack(10)
    res = simulate(g, SYS, TOPO, keep_timeline=True)
    evs = utilization_counters(res)
    assert evs
    names = {e["name"] for e in evs}
    assert "util_compute" in names
    assert all(e["ph"] == "C" for e in evs)


# ---------------------------------------------------------------------------
# trace export: metadata ordering + p2p flow events
# ---------------------------------------------------------------------------

def test_chrome_trace_metadata_sorted_first_and_p2p_flows():
    from repro.trace.export import to_chrome_trace
    g = layer_stack(16)
    prog = convert.split_pipeline_stages(g, 2)
    res = simulate_cluster(prog, SYS, TOPO, keep_timeline=True)
    tr = to_chrome_trace(res, graph=prog)
    evs = tr["traceEvents"]
    n_meta = sum(1 for e in evs if e["ph"] == "M")
    assert all(e["ph"] == "M" for e in evs[:n_meta])
    assert not any(e["ph"] == "M" for e in evs[n_meta:])
    meta_pids = [e["pid"] for e in evs[:n_meta]]
    assert meta_pids == sorted(meta_pids)
    assert any(e["name"] == "process_sort_index" for e in evs[:n_meta])

    flows = [e for e in evs if e["ph"] in ("s", "f")]
    assert flows, "pipeline trace must carry p2p flow events"
    starts = {e["id"]: e for e in flows if e["ph"] == "s"}
    finishes = {e["id"]: e for e in flows if e["ph"] == "f"}
    assert set(starts) == set(finishes)
    for fid, s in starts.items():
        f = finishes[fid]
        assert s["pid"] != f["pid"]               # crosses ranks
        assert f["bp"] == "e"
        assert s["cat"] == f["cat"] == "p2p"


def test_obs_chrome_trace_roundtrip(tmp_path):
    obs.enable()
    with obs.span("outer"):
        with obs.span("inner"):
            pass
    obs.counter("k.hit")
    path = str(tmp_path / "trace.json")
    obs.dump_trace(path)
    obs.disable()
    tr = json.load(open(path))
    names = [e["name"] for e in tr["traceEvents"] if e["ph"] == "X"]
    assert set(names) == {"outer", "inner"}
    assert tr["metadata"]["counters"]["k.hit"] == 1.0
    assert tr["traceEvents"][0]["ph"] == "M"


# ---------------------------------------------------------------------------
# progress callbacks + report CLI
# ---------------------------------------------------------------------------

def test_searchrun_progress_callback():
    knobs = [Knob("prefetch", [0, 2, 4, 8])]
    calls = []
    r = SearchRun(lambda cfg: layer_stack(6), SYS, knobs, strategy="grid",
                  budget=4, seed=0, progress=calls.append,
                  progress_interval=0.0)
    res = r.run()
    assert calls, "progress must fire"
    assert calls[-1]["done"] is True
    assert calls[-1]["trials"] == len(res.trials) == 4
    assert calls[-1]["best"] == res.best.objective
    assert all(c["budget"] == 4 for c in calls)
    # rate limiting: a huge interval suppresses all but the final call
    calls2 = []
    SearchRun(lambda cfg: layer_stack(6), SYS, knobs, strategy="grid",
              budget=4, seed=0, progress=calls2.append,
              progress_interval=3600.0).run()
    assert len(calls2) == 1 and calls2[0]["done"] is True


def test_monte_carlo_progress_callback():
    from repro.faults import CheckpointPolicy, FaultRates
    from repro.faults.montecarlo import monte_carlo
    g = layer_stack(6)
    s0 = float(simulate_cluster(g, SYS, TOPO).total_time)
    rates = FaultRates(fail_rate=1.0 / (100 * s0))
    calls = []
    monte_carlo(g, SYS, rates, CheckpointPolicy(), topo=TOPO, n_steps=20,
                n_trials=3, seed=0, progress=calls.append,
                progress_interval=0.0)
    assert calls[-1] == {"trials": 3, "total": 3,
                         "elapsed": calls[-1]["elapsed"], "done": True}
    assert [c["trials"] for c in calls[:-1]] == sorted(
        c["trials"] for c in calls[:-1])


def test_report_cli_renders_real_sweep(tmp_path, capsys):
    """`python -m repro.obs report` on metrics from a pooled SearchRun
    shows cache hit rates and (when a pool ran) worker utilization."""
    from repro.obs.report import main as report_main
    knobs = [Knob("prefetch", [0, 2, 4]), Knob("bucket_bytes", [None, 64e6])]
    obs.enable()
    SearchRun(lambda cfg: layer_stack(8), SYS, knobs, strategy="grid",
              budget=6, seed=0, jobs=3).run()
    path = str(tmp_path / "metrics.json")
    obs.dump_metrics(path)
    obs.disable()
    assert report_main(["report", path]) == 0
    out = capsys.readouterr().out
    assert "top spans by total time" in out
    assert "cache hit rates" in out
    assert "sim.result_cache" in out
    from repro.core.pool import pool_available
    if pool_available():
        assert "pool utilization" in out


def test_search_cli_progress_and_obs(tmp_path, capsys):
    from repro.search.cli import main as cli_main
    gpath = str(tmp_path / "g.json")
    layer_stack(6).save(gpath)
    mpath = str(tmp_path / "m.json")
    rc = cli_main(["run", gpath, "--knob", "prefetch=0,2", "--budget", "2",
                   "--strategy", "grid", "--progress", "--obs", mpath])
    assert rc == 0
    err = capsys.readouterr().err
    assert "progress:" in err
    m = json.load(open(mpath))
    assert m["counters"]["search.gen_trials"] == 2.0
    assert not obs.recording()                    # CLI cleaned up
