"""Randomized pipeline-schedule property suite (ISSUE 10 satellite).

Generates random pipelineable workload graphs (forward layer chains with
fan-in, and explicit forward/backward chains) and asserts the microbatched
lowering's core invariants over >= 50 seeded cases:

  * ``num_microbatches=1`` reduces node-by-node bit-identically to the
    legacy one-wave split for EVERY schedule name (and simulates to the
    same step time);
  * every schedule of the same (graph, p, m) conserves total compute work
    exactly — the cluster-wide flops sum equals the source graph's — and
    gpipe/1f1b (same segmentation) agree per rank;
  * the GPipe makespan is monotone non-increasing in m on compute-dominated
    graphs (more microbatches can only shrink the fill/drain bubble);
  * per-channel send/recv FIFO pairing: within every (channel, side) the
    emission order is strictly ascending in microbatch index, and the send
    sequence on the source rank mirrors the recv sequence on the
    destination rank exactly;
  * ``share_replica_graphs`` is bit-identical to literal per-replica
    graphs and really does share (num_stages graph objects, not S*R).
"""
import math
import random
import re

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:          # container without hypothesis: deterministic stub
    import _hypothesis_stub as st
    from _hypothesis_stub import given, settings

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.convert import split_pipeline_stages
from repro.core.costmodel import build_topology, simulate_cluster
from repro.core.costmodel.schedule import SCHEDULES

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)


# ---------------------------------------------------------------------------
# graph generators
# ---------------------------------------------------------------------------

def layer_chain(rng, n_layers, fan_in=True, payload=1e6):
    """Forward-only layer chain; optional side-input nodes feeding layers
    (same-stage fan-in) keep the DAG from being a pure path."""
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        deps = [prev] if prev is not None else []
        if fan_in and prev is not None and rng.random() < 0.3:
            side = g.add(f"side{i}", chakra.COMP, deps=[prev],
                         flops=rng.uniform(1e9, 1e10),
                         out_bytes=rng.uniform(1.0, payload))
            deps.append(side)
        prev = g.add(f"L{i}", chakra.COMP, deps=deps,
                     flops=rng.uniform(1e10, 1e12),
                     bytes=rng.uniform(0.0, 1e6),
                     out_bytes=rng.uniform(1.0, payload))
    return g


def fb_chain(rng, p, payload=1e6):
    """Explicit forward/backward chain: one f and one b node per stage,
    backward edges b_{s+1} -> b_s, with an explicit stage map."""
    g = chakra.Graph()
    f = []
    for s in range(p):
        deps = [f[-1]] if f else []
        f.append(g.add(f"f{s}", chakra.COMP, deps=deps,
                       flops=rng.uniform(1e11, 1e12),
                       out_bytes=rng.uniform(1.0, payload)))
    b_prev = None
    for s in reversed(range(p)):
        deps = [f[s]] + ([b_prev] if b_prev is not None else [])
        b_prev = g.add(f"b{s}", chakra.COMP, deps=deps,
                       flops=rng.uniform(1e11, 2e12),
                       out_bytes=rng.uniform(1.0, payload))
    assign = list(range(p)) + list(reversed(range(p)))
    return g, assign


def valid_m(rng, sched, p):
    """A microbatch count the schedule accepts (interleaved needs m % p == 0)."""
    if sched == "interleaved":
        return p * rng.randint(1, 3)
    return rng.randint(2, 8)


# ---------------------------------------------------------------------------
# m == 1: every schedule is the legacy split, bit-identically
# ---------------------------------------------------------------------------

def _graph_repr(g):
    return [(n.name, n.type, tuple(n.deps), tuple(n.ctrl_deps),
             tuple(sorted(n.attrs.items(), key=lambda kv: kv[0])))
            for n in g.nodes]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_m1_reduces_to_legacy(seed):
    rng = random.Random(seed)
    p = rng.choice([2, 3, 4])
    g = layer_chain(rng, rng.randint(2 * p, 3 * p))
    legacy = split_pipeline_stages(g, p)
    for sched in SCHEDULES:
        prog = split_pipeline_stages(g, p, num_microbatches=1, schedule=sched)
        assert prog.n_ranks == legacy.n_ranks
        for r in range(prog.n_ranks):
            assert _graph_repr(prog.graph_for(r)) == \
                _graph_repr(legacy.graph_for(r)), \
                f"schedule={sched} rank={r} differs from legacy at m=1"
        res = simulate_cluster(prog, SYS, topo=TOPO)
        ref = simulate_cluster(legacy, SYS, topo=TOPO)
        assert res.step_time == ref.step_time


# ---------------------------------------------------------------------------
# work conservation across schedules
# ---------------------------------------------------------------------------

def _rank_flops(prog):
    return [math.fsum(float(n.attrs.get("flops", 0.0))
                      for n in prog.graph_for(r).nodes)
            for r in range(prog.n_ranks)]


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_schedules_conserve_total_work(seed):
    rng = random.Random(seed)
    p = rng.choice([2, 4])
    g = layer_chain(rng, rng.randint(2 * p, 4 * p))
    src = math.fsum(float(n.attrs.get("flops", 0.0)) for n in g.nodes)
    per_rank = {}
    for sched in SCHEDULES:
        m = valid_m(rng, sched, p)
        prog = split_pipeline_stages(g, p, num_microbatches=m, schedule=sched)
        rf = _rank_flops(prog)
        total = math.fsum(rf)
        assert abs(total - src) <= 1e-6 * src, \
            f"schedule={sched} m={m}: total work {total} != source {src}"
        per_rank[sched] = rf
    # gpipe and 1f1b share the segmentation: identical per-rank totals too
    for a, b in zip(per_rank["gpipe"], per_rank["1f1b"]):
        assert abs(a - b) <= 1e-6 * max(a, b, 1.0)


# ---------------------------------------------------------------------------
# GPipe makespan monotone non-increasing in m
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_gpipe_makespan_monotone_in_m(seed):
    rng = random.Random(seed)
    p = rng.choice([2, 4])
    # compute-dominated: tiny payloads so per-message overhead can't mask
    # the shrinking bubble
    g = layer_chain(rng, rng.randint(2 * p, 3 * p), payload=8.0)
    prev = None
    for m in (1, 2, 4, 8):
        prog = split_pipeline_stages(g, p, num_microbatches=m,
                                     schedule="gpipe")
        t = simulate_cluster(prog, SYS, topo=TOPO).step_time
        if prev is not None:
            assert t <= prev * (1 + 1e-9), \
                f"gpipe makespan rose from {prev} (m/2) to {t} (m={m})"
        prev = t


# ---------------------------------------------------------------------------
# per-channel send/recv FIFO pairing
# ---------------------------------------------------------------------------

_MB = re.compile(r"@[fb](\d+)[<>]")


def _channel_sides(prog):
    """{(channel, src, dst): {"send": [j...], "recv": [j...]}} with the j
    sequences in each graph's emission (program) order."""
    out = {}
    seen = set()
    for r in range(prog.n_ranks):
        g_r = prog.graph_for(r)
        if id(g_r) in seen:            # shared graphs: count once
            continue
        seen.add(id(g_r))
        for n in g_r.nodes:
            if n.attrs.get("comm_kind") != "p2p":
                continue
            src, dst = n.attrs["group"]
            key = (tuple(n.attrs["p2p_channel"]), src, dst)
            side = "send" if "send" in n.name else "recv"
            j = int(_MB.search(n.name).group(1))
            out.setdefault(key, {"send": [], "recv": []})[side].append(j)
    return out


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 10**6))
def test_fifo_pairing_per_channel(seed):
    rng = random.Random(seed)
    p = rng.choice([2, 3, 4])
    sched = rng.choice(SCHEDULES)
    m = valid_m(rng, sched, p)
    if rng.random() < 0.5:
        g = layer_chain(rng, rng.randint(2 * p, 4 * p))
        prog = split_pipeline_stages(g, p, num_microbatches=m,
                                     schedule=sched)
    else:                              # explicit-backward graphs too
        g, assign = fb_chain(rng, p)
        v = 2 if sched == "interleaved" else 1
        if v > 1:                      # explicit map must cover p*v vstages
            return
        prog = split_pipeline_stages(g, p, assignment=assign,
                                     num_microbatches=m, schedule=sched)
    chans = _channel_sides(prog)
    assert chans, "lowering emitted no p2p channels"
    for (chan, src, dst), sides in chans.items():
        sends, recvs = sides["send"], sides["recv"]
        assert len(sends) == len(recvs) == m, \
            f"channel {chan} {src}->{dst}: {len(sends)} sends vs " \
            f"{len(recvs)} recvs (expected {m})"
        assert sends == sorted(sends) and len(set(sends)) == m, \
            f"channel {chan}: send order {sends} not strictly j-ascending"
        assert sends == recvs, \
            f"channel {chan}: send js {sends} != recv js {recvs}"


# ---------------------------------------------------------------------------
# cross-replica graph sharing
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 10**6))
def test_shared_replica_graphs_bit_identical(seed):
    rng = random.Random(seed)
    p = rng.choice([2, 4])
    R = rng.choice([2, 4])
    sched = rng.choice(["gpipe", "1f1b"])
    m = valid_m(rng, sched, p)
    g = layer_chain(rng, rng.randint(2 * p, 3 * p))
    shared = split_pipeline_stages(g, p, replicas=R, num_microbatches=m,
                                   schedule=sched, share_replica_graphs=True)
    literal = split_pipeline_stages(g, p, replicas=R, num_microbatches=m,
                                    schedule=sched,
                                    share_replica_graphs=False)
    # sharing is real: p graph objects, not p * R
    assert len({id(shared.graph_for(r)) for r in range(shared.n_ranks)}) == p
    assert len({id(literal.graph_for(r))
                for r in range(literal.n_ranks)}) == p * R
    rs = simulate_cluster(shared, SYS, topo=TOPO, memoize=False)
    rl = simulate_cluster(literal, SYS, topo=TOPO, memoize=False)
    assert rs.step_time == rl.step_time
    for r in range(rs.n_ranks):
        assert rs.rank_result(r).total_time == rl.rank_result(r).total_time
