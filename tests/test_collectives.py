"""int8-compressed DP gradient all-reduce (error feedback) on fake devices."""
import pytest

pytestmark = pytest.mark.skip(
    reason="pre-existing at seed: parallel/collectives.py's shard_map-based "
           "compressed all-reduce fails on jax 0.4.37 — see ROADMAP "
           "'jax 0.4.37 compat'")


def test_compressed_allreduce_matches_mean(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel.mesh import make_mesh, mesh_context
from repro.parallel.collectives import (make_compressed_value_and_grad,
                                        init_error_state)
mesh = make_mesh((4, 2), ("data", "model"))
D, F, B = 16, 8, 32
def loss_fn(w, batch):
    y = batch["x"] @ w
    l = jnp.mean(y ** 2)
    return l, {"l2": l}
w = jax.device_put(np.random.RandomState(0).randn(D, F).astype(np.float32),
                   NamedSharding(mesh, P(None, "model")))
x = jax.device_put(np.random.RandomState(1).randn(B, D).astype(np.float32),
                   NamedSharding(mesh, P("data", None)))
batch = {"x": x}
run = make_compressed_value_and_grad(loss_fn, mesh, ("data",))
err = init_error_state(w, 4)
with mesh_context(mesh):
    loss, met, g, err = jax.jit(run)(w, batch, err)
(ref_loss, _), ref_g = jax.value_and_grad(loss_fn, has_aux=True)(w, batch)
assert abs(float(loss) - float(ref_loss)) < 1e-5
rel = float(jnp.linalg.norm(g - ref_g) / jnp.linalg.norm(ref_g))
assert rel < 0.02, rel
print("compressed ok", rel)
""")
    assert "compressed ok" in out


def test_error_feedback_reduces_bias_over_steps(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel.mesh import make_mesh, mesh_context
from repro.parallel.collectives import (make_compressed_value_and_grad,
                                        init_error_state)
mesh = make_mesh((8,), ("data",))
D = 64
def loss_fn(w, batch):
    l = jnp.mean((batch["x"] - w) ** 2)
    return l, {}
w = jnp.zeros((D,), jnp.float32)
x = jax.device_put(np.random.RandomState(0).randn(64, D).astype(np.float32) * 0.01,
                   NamedSharding(mesh, P("data")))
run = jax.jit(make_compressed_value_and_grad(loss_fn, mesh, ("data",)))
err = init_error_state(w, 8)
accum_c = jnp.zeros((D,))
accum_r = jnp.zeros((D,))
with mesh_context(mesh):
    for i in range(20):
        loss, met, g, err = run(w, {"x": x}, err)
        (_, _), gr = jax.value_and_grad(loss_fn, has_aux=True)(w, {"x": x})
        accum_c += g
        accum_r += gr
# with error feedback the accumulated compressed grads track the true sum
rel = float(jnp.linalg.norm(accum_c - accum_r) / jnp.linalg.norm(accum_r))
assert rel < 0.01, rel
print("errfb ok", rel)
""")
    assert "errfb ok" in out


def test_train_step_with_compression_learns(subproc):
    out = subproc("""
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.mesh import make_mesh, mesh_context
from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config
from repro.models import build_model
from repro.train import (OptConfig, DataConfig, DataIterator,
                         init_train_state, make_train_step)
from repro.parallel.collectives import init_error_state
mesh = make_mesh((4,), ("data",))
cfg = get_config("qwen3-8b", smoke=True)
m = build_model(cfg)
par = ParallelConfig(grad_compression=True, fsdp=False)
state = init_train_state(m, jax.random.PRNGKey(0), par)
state = state._replace(err=init_error_state(state.params, 4))
step = jax.jit(make_train_step(m, OptConfig(lr=1e-2, warmup_steps=5,
                                            total_steps=50), par, mesh))
it = DataIterator(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                             global_batch=8))
losses = []
with mesh_context(mesh):
    for i in range(30):
        state, metrics = step(state, next(it))
        losses.append(float(metrics["loss"]))
assert losses[-1] < losses[0] - 0.5, (losses[0], losses[-1])
print("comp train ok", losses[0], losses[-1])
""")
    assert "comp train ok" in out
