"""Search subsystem: spaces, strategies, budgets, checkpoints, Pareto.

Property-level contracts against grid ground truth:
  * grid adapter bit-identity with the historical dse.explore walk,
  * deterministic seeding (same seed => same trial sequence),
  * bayesian/evolutionary within 2% of the exhaustive optimum at <= 25%
    of grid's trial count (the ISSUE acceptance bound),
  * checkpoint resume lands exactly where an uninterrupted run would,
    without re-evaluating completed trials,
  * hetero cluster-knob spaces route through simulate_cluster and beat
    truncated grid at equal budget.
"""
import itertools
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.dse import Knob, apply_software_knobs, explore, json_value
from repro.core.costmodel.simulator import (peak_memory_proxy, simulate,
                                            simulate_analytic)
from repro.search import (Dim, FIDELITY_FULL, SearchRun, SearchSpace,
                          available_strategies, get_strategy, pareto_front)

SYS = SystemConfig(chips=16, topology="switch")


def _graph(n_layers=8, comm_mb=8.0, group=16):
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=comm_mb * 1e6, out_bytes=comm_mb * 1e6,
                   group=list(range(group)))
        deps = [ag] + ([prev] if prev is not None else [])
        prev = g.add(f"comp{i}", chakra.COMP, deps=deps, flops=5e10,
                     out_bytes=1e6)
    return g


def _fsdp_knobs():
    """The FSDP-reorder benchmark space (96 configs) — imported from the
    bench so the acceptance bound asserted here and the CI-gated
    BENCH_search floors always validate the same space."""
    from benchmarks.search_bench import fsdp_reorder_knobs
    return fsdp_reorder_knobs()


# ---------------------------------------------------------------------------
# SearchSpace
# ---------------------------------------------------------------------------

def test_space_grid_matches_itertools_product_order():
    knobs = _fsdp_knobs()
    space = SearchSpace.from_knobs(knobs)
    expect = [dict(c) for c in itertools.product(
        *[[(k.name, v) for v in k.values] for k in knobs])]
    assert list(space.grid_configs()) == expect
    assert space.grid_size == len(expect) == 96
    assert list(space.grid_configs(limit=7)) == expect[:7]


def test_dim_kinds_and_encoding():
    ordinal = Dim.finite("p", [0, 2, 8])
    assert ordinal.kind == "ordinal"
    assert ordinal.encode(0) == 0.0 and ordinal.encode(8) == 1.0
    cat = Dim.finite("b", [None, 64e6])          # mixed -> categorical
    assert cat.kind == "categorical"
    boolean = Dim.finite("s", [True, False])     # bools are categorical
    assert boolean.kind == "categorical"
    cont = Dim.continuous("lr", 1e-4, 1e-1, log=True)
    assert abs(cont.encode(1e-4)) < 1e-12 and abs(cont.encode(1e-1) - 1) < 1e-12

    import numpy as np
    rng = np.random.default_rng(0)
    for d in (ordinal, cat, cont):
        v = d.sample(rng)
        assert 0.0 <= d.encode(v) <= 1.0
    # mutation moves whenever there is anywhere to go
    for _ in range(20):
        assert ordinal.mutate(2, rng) != 2
        assert cat.mutate(None, rng) is not None


def test_space_mutate_always_differs_despite_single_choice_dims():
    """A single-choice dim (fsdp_sync=[True]) must never absorb the forced
    mutation — the child differs from the parent whenever any dim has > 1
    choice."""
    import numpy as np
    space = SearchSpace.from_knobs(_fsdp_knobs())   # includes fsdp_sync=[True]
    rng = np.random.default_rng(0)
    parent = {"fsdp_sync": True, "prefetch": 2, "bucket_bytes": None,
              "link_bw": 25e9}
    for _ in range(50):
        child = space.mutate(parent, rng)
        assert child != parent
    # a space of ONLY single-choice dims is the identity
    solo = SearchSpace([Dim.finite("a", [1])])
    assert solo.mutate({"a": 1}, rng) == {"a": 1}


def test_grid_over_continuous_raises():
    space = SearchSpace([Dim.continuous("x", 0.0, 1.0)])
    with pytest.raises(ValueError, match="continuous"):
        list(space.grid_configs())
    assert space.grid_size is None


# ---------------------------------------------------------------------------
# strategy registry + explore adapter
# ---------------------------------------------------------------------------

def test_unknown_strategy_lists_registry():
    space = SearchSpace.from_knobs(_fsdp_knobs())
    with pytest.raises(ValueError) as ei:
        get_strategy("annealing", space)
    for name in available_strategies():
        assert name in str(ei.value)

    g = _graph()
    with pytest.raises(ValueError) as ei:
        explore(lambda cfg: g, SYS, _fsdp_knobs(), strategy="annealing")
    assert "bayesian" in str(ei.value) and "grid" in str(ei.value)


def test_grid_adapter_bit_identical_to_manual_walk():
    """explore(strategy='grid') must reproduce the historical semantics
    exactly: product order, budget truncation, simulate per config, sorted
    by objective."""
    g = _graph()
    knobs = [Knob("fsdp_sync", [True]),
             Knob("prefetch", [0, 2, 8]),
             Knob("link_bw", [25e9, 100e9], layer="hardware")]
    trials = explore(lambda cfg: g, SYS, knobs)

    expect = []
    for c in itertools.product(*[[(k.name, v) for v in k.values]
                                 for k in knobs]):
        cfg = dict(c)
        g2 = apply_software_knobs(g, cfg)
        res = simulate(g2, SYS.replace(link_bw=cfg["link_bw"]))
        expect.append((cfg, res.total_time))
    expect.sort(key=lambda t: t[1])
    assert len(trials) == len(expect)
    for t, (cfg, obj) in zip(trials, expect):
        assert t.config == cfg
        assert t.objective == obj        # bit-identical, not approx


def test_explore_nongrid_returns_sorted_budgeted_trials():
    g = _graph()
    trials = explore(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                     budget=12, seed=1)
    assert len(trials) == 12
    objs = [t.objective for t in trials]
    assert objs == sorted(objs)
    assert all(t.result is not None for t in trials)


# ---------------------------------------------------------------------------
# satellite: Trial.as_dict JSON-native round trip
# ---------------------------------------------------------------------------

def test_trial_as_dict_round_trips_types():
    g = _graph()
    trials = explore(lambda cfg: g, SYS,
                     [Knob("fsdp_sync", [True]),
                      Knob("bucket_bytes", [None, 64e6]),
                      Knob("prefetch", [2])])
    seen = {repr(t.config["bucket_bytes"]) for t in trials}
    assert seen == {"None", "64000000.0"}
    for t in trials:
        d = json.loads(json.dumps(t.as_dict()))
        assert d["config"]["fsdp_sync"] is True
        assert d["config"]["prefetch"] == 2
        bb = d["config"]["bucket_bytes"]
        assert bb is None or isinstance(bb, float)


def test_json_value_edge_cases():
    import numpy as np
    assert json_value(np.float64(2.5)) == 2.5
    assert isinstance(json_value(np.int64(3)), int)
    assert json_value(float("inf")) == "inf"
    assert json_value((1, "a", None)) == [1, "a", None]
    assert json_value(SYS) == str(SYS)


# ---------------------------------------------------------------------------
# satellite: deterministic seeding
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["random", "bayesian", "evolutionary",
                                      "halving"])
def test_seed_determinism_property(strategy):
    """Same seed + same space => identical trial sequence; different seeds
    diverge."""
    g = _graph()

    def run(seed):
        r = SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy=strategy,
                      budget=16, seed=seed).run()
        return [(t.config, t.fidelity) for t in r.trials]

    runs = {}
    for seed in (0, 1, 2):
        runs[seed] = run(seed)
        assert runs[seed] == run(seed)
    assert runs[0] != runs[1] and runs[1] != runs[2] and runs[0] != runs[2]


def test_random_is_duplicate_free_on_finite_space():
    g = _graph()
    r = SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=96, seed=0).run()
    space = SearchSpace.from_knobs(_fsdp_knobs())
    keys = [space.config_key(t.config) for t in r.trials]
    assert len(keys) == len(set(keys)) == 96   # exhausts without repeats


# ---------------------------------------------------------------------------
# acceptance: sample efficiency vs exhaustive grid
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("strategy", ["bayesian", "evolutionary"])
def test_within_2pct_of_grid_optimum_at_quarter_budget(strategy):
    g = _graph()
    knobs = _fsdp_knobs()
    grid = explore(lambda cfg: g, SYS, knobs)            # 96 configs
    optimum = grid[0].objective
    budget = len(grid) // 4                              # 24 trials
    trials = explore(lambda cfg: g, SYS, knobs, strategy=strategy,
                     budget=budget, seed=2)
    assert len(trials) <= budget
    best = trials[0].objective
    assert best <= optimum * 1.02, \
        f"{strategy}: {best} vs optimum {optimum} (> 2% off)"


# ---------------------------------------------------------------------------
# hetero cluster knob spaces
# ---------------------------------------------------------------------------

def _hetero_knobs():
    # grid order deliberately worst-first: truncated grid never reaches the
    # healthy-cluster corner
    return [Knob("cluster_ranks", [8], layer="hardware"),
            Knob("degraded_fraction", [0.5, 0.375, 0.25, 0.125, 0.0],
                 layer="hardware"),
            Knob("pod_link_scale", [0.4, 0.6, 0.8, 1.0], layer="hardware")]


@pytest.mark.parametrize("strategy", ["random", "bayesian"])
def test_hetero_space_beats_grid_at_equal_budget(strategy):
    g = _graph(n_layers=6, group=8)
    sysc = SystemConfig(chips=8, topology="switch")
    knobs = _hetero_knobs()
    budget = 8
    grid_trunc = explore(lambda cfg: g, sysc, knobs, budget=budget)
    trials = explore(lambda cfg: g, sysc, knobs, strategy=strategy,
                     budget=budget, seed=0)
    # exercises the cluster engine: results are per-rank ClusterSimResults
    assert all(hasattr(t.result, "n_ranks") and t.result.n_ranks == 8
               for t in trials)
    assert trials[0].objective < grid_trunc[0].objective


def test_hetero_search_degraded_knob_moves_objective():
    g = _graph(n_layers=6, group=8)
    sysc = SystemConfig(chips=8, topology="switch")
    r = SearchRun(lambda cfg: g, sysc, _hetero_knobs(), strategy="random",
                  budget=20, seed=0).run()
    by_frac = {}
    for t in r.trials:
        if t.config["pod_link_scale"] == 1.0:
            by_frac[t.config["degraded_fraction"]] = t.objectives["total_time"]
    if 0.0 in by_frac and 0.5 in by_frac:
        assert by_frac[0.0] < by_frac[0.5]


# ---------------------------------------------------------------------------
# multi-objective + Pareto
# ---------------------------------------------------------------------------

def test_pareto_front_extraction():
    names = ("a", "b")
    pts = [{"a": 1.0, "b": 5.0}, {"a": 2.0, "b": 2.0}, {"a": 5.0, "b": 1.0},
           {"a": 3.0, "b": 3.0},                       # dominated by (2,2)
           {"a": 2.0, "b": 2.0}]                       # duplicate survives
    assert pareto_front(pts, names) == [0, 1, 2, 4]


def test_multi_objective_time_memory_tradeoff():
    """Prefetch trades step time against peak memory: the Pareto front over
    (total_time, peak_bytes) keeps both ends of the knob."""
    g = _graph()
    knobs = [Knob("fsdp_sync", [True]),
             Knob("prefetch", [0, 2, 16])]
    r = SearchRun(lambda cfg: g, SYS, knobs, strategy="grid",
                  objectives=("total_time", "peak_bytes"), budget=None).run()
    assert len(r.full_trials) == 3
    front = r.pareto_trials()
    times = {t.config["prefetch"]: t.objectives["total_time"]
             for t in r.full_trials}
    mems = {t.config["prefetch"]: t.objectives["peak_bytes"]
            for t in r.full_trials}
    assert times[16] < times[0] and mems[16] > mems[0]  # a real tradeoff
    # both extremes of the front survive: the fastest config and the
    # leanest config (lexicographic argmins handle objective ties)
    tmin = min(r.full_trials, key=lambda t: (t.objectives["total_time"],
                                             t.objectives["peak_bytes"]))
    mmin = min(r.full_trials, key=lambda t: (t.objectives["peak_bytes"],
                                             t.objectives["total_time"]))
    assert tmin in front and mmin in front
    assert len(front) >= 2 and tmin is not mmin


def test_peak_memory_proxy_objective_no_event_loop():
    g = _graph()
    knobs = [Knob("fsdp_sync", [True]), Knob("prefetch", [0, 8])]
    r = SearchRun(lambda cfg: g, SYS, knobs, strategy="grid",
                  objectives=("total_time", "peak_memory_proxy"),
                  budget=None).run()
    proxies = {t.config["prefetch"]: t.objectives["peak_memory_proxy"]
               for t in r.full_trials}
    assert proxies[8] > proxies[0] > 0   # prefetch hoists allocations


# ---------------------------------------------------------------------------
# proxy fidelities (halving's rungs)
# ---------------------------------------------------------------------------

def test_simulate_analytic_is_lower_bound():
    g = _graph()
    full = simulate(g, SYS)
    lo = simulate_analytic(g, SYS)
    assert lo.total_time <= full.total_time + 1e-15
    assert lo.total_time == pytest.approx(
        max(lo.compute_time, lo.comm_time))
    assert lo.compute_time == pytest.approx(full.compute_time)
    assert lo.comm_time == pytest.approx(full.comm_time)
    assert lo.peak_bytes == peak_memory_proxy(g) > 0
    # memoized: identical result object contents on repeat
    again = simulate_analytic(g, SYS)
    assert again.total_time == lo.total_time


def test_halving_prices_proxies_then_promotes():
    g = _graph()
    r = SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="halving",
                  budget=26, seed=0).run()
    fids = [t.fidelity for t in r.trials]
    assert 0.0 in fids and 1.0 in fids           # proxy rungs + full rung
    assert len(r.full_trials) < len(r.trials) / 2
    assert r.best is not None and r.best.is_full
    # the driver priced sub-full fidelities without the cluster engine:
    # analytic trials report the roofline bound (<= their symmetric sibling
    # for the same config when both exist)
    grid = explore(lambda cfg: g, SYS, _fsdp_knobs())
    assert r.best.objectives["total_time"] <= grid[0].objective * 1.10


# ---------------------------------------------------------------------------
# budgets + checkpoint/resume
# ---------------------------------------------------------------------------

def test_wall_clock_budget_stops(tmp_path):
    g = _graph()
    r = SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=10_000, wall_clock=0.0, seed=0).run()
    assert len(r.trials) == 0            # deadline hit before first ask


def _truncate_checkpoint(path: str, n_trials: int) -> None:
    """Simulate a kill: keep the header and the first `n_trials` lines."""
    with open(path) as f:
        lines = f.read().splitlines()
    with open(path, "w") as f:
        f.write("\n".join(lines[:1 + n_trials]) + "\n")


def test_checkpoint_resume_without_reevaluation(tmp_path, monkeypatch):
    g = _graph()
    knobs = _fsdp_knobs()

    ref = SearchRun(lambda cfg: g, SYS, knobs, strategy="bayesian",
                    budget=14, seed=7).run()
    ref_seq = [t.config for t in ref.trials]

    ck = str(tmp_path / "run.jsonl")
    r1 = SearchRun(lambda cfg: g, SYS, knobs, strategy="bayesian",
                   budget=14, seed=7, checkpoint=ck).run()
    assert (r1.n_evaluated, r1.n_resumed) == (14, 0)
    _truncate_checkpoint(ck, 5)          # killed after 5 trials

    evals = []
    orig = SearchRun._evaluate

    def counting(self, cfg, fid):
        evals.append(dict(cfg))
        return orig(self, cfg, fid)

    monkeypatch.setattr(SearchRun, "_evaluate", counting)
    r2 = SearchRun(lambda cfg: g, SYS, knobs, strategy="bayesian",
                   budget=14, seed=7, checkpoint=ck).run()
    assert (r2.n_evaluated, r2.n_resumed) == (9, 5)
    assert len(evals) == 9               # completed trials NOT re-simulated
    assert [t.config for t in r2.trials] == ref_seq  # == uninterrupted run

    # a third run is a no-op
    r3 = SearchRun(lambda cfg: g, SYS, knobs, strategy="bayesian",
                   budget=14, seed=7, checkpoint=ck).run()
    assert (r3.n_evaluated, r3.n_resumed) == (0, 14)


def test_checkpoint_torn_tail_tolerated_and_repaired(tmp_path):
    g = _graph()
    ck = str(tmp_path / "run.jsonl")
    SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
              budget=8, seed=0, checkpoint=ck).run()
    _truncate_checkpoint(ck, 6)
    with open(ck, "a") as f:
        f.write('{"index": 99, "config": {"pref')   # killed mid-write
    r = SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=8, seed=0, checkpoint=ck).run()
    assert (r.n_resumed, r.n_evaluated) == (6, 2)
    # the torn fragment was repaired, not appended onto: the file is clean
    # JSONL again and a further resume replays all 8 trials
    with open(ck) as f:
        lines = [json.loads(ln) for ln in f.read().splitlines() if ln]
    assert len(lines) == 1 + 8
    r2 = SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                   budget=8, seed=0, checkpoint=ck).run()
    assert (r2.n_resumed, r2.n_evaluated) == (8, 0)


def test_checkpoint_header_mismatch_refuses(tmp_path):
    g = _graph()
    ck = str(tmp_path / "run.jsonl")
    SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
              budget=8, seed=0, checkpoint=ck).run()
    with pytest.raises(ValueError, match="seed"):
        SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=8, seed=1, checkpoint=ck).run()
    with pytest.raises(ValueError, match="strategy"):
        SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="evolutionary",
                  budget=8, seed=0, checkpoint=ck).run()
    # budget shapes the ask sequence (init designs, populations, brackets)
    # so resuming under a different budget is refused, not silently wrong
    with pytest.raises(ValueError, match="budget"):
        SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=16, seed=0, checkpoint=ck).run()


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_run_resume_front(tmp_path, capsys):
    from repro.search.cli import main
    gpath = str(tmp_path / "g.json")
    _graph().save(gpath)
    ck = str(tmp_path / "ck.jsonl")
    args = ["run", gpath, "--knob", "prefetch=0,2,4,8",
            "--knob", "bucket_bytes=null,64e6",
            "--knob", "link_bw=12.5e9,50e9@hardware",
            "--strategy", "bayesian", "--seed", "3", "--budget", "9",
            "--objectives", "total_time,peak_memory_proxy",
            "--checkpoint", ck]
    assert main(args) == 0
    out1 = capsys.readouterr().out
    assert "9 trials" in out1 and "best" in out1

    _truncate_checkpoint(ck, 5)          # simulate a kill after 5 trials
    assert main(args) == 0
    out2 = capsys.readouterr().out
    assert "5 resumed, 4 evaluated" in out2

    assert main(["front", ck]) == 0
    out3 = capsys.readouterr().out
    assert "strategy=bayesian" in out3 and "front #" in out3

    # knob values arrived typed, not stringified
    with open(ck) as f:
        lines = [json.loads(ln) for ln in f if ln.strip()]
    for t in lines[1:]:
        bb = t["config"]["bucket_bytes"]
        assert bb is None or isinstance(bb, float)


def test_cli_system_handoff(tmp_path, capsys):
    """--system cal.json (the trace calibrator's output format) prices the
    search on the calibrated model."""
    from repro.search.cli import main
    gpath = str(tmp_path / "g.json")
    _graph().save(gpath)
    cal = {"system": {"link_bw": 5e9, "chips": 16, "topology": "switch"},
           "compute_derate": 0.3}
    cpath = str(tmp_path / "cal.json")
    with open(cpath, "w") as f:
        json.dump(cal, f)
    base = ["run", gpath, "--knob", "prefetch=0,4", "--strategy", "grid",
            "--budget", "4", "--out"]
    assert main(base + [str(tmp_path / "a.json")]) == 0
    assert main(base + [str(tmp_path / "b.json"), "--system", cpath]) == 0
    a = json.load(open(tmp_path / "a.json"))
    b = json.load(open(tmp_path / "b.json"))
    # derated compute + slower links => strictly slower best step
    assert b["best"]["objectives"]["total_time"] > \
        a["best"]["objectives"]["total_time"]


def test_cli_front_tolerates_torn_tail(tmp_path, capsys):
    from repro.search.cli import main
    gpath = str(tmp_path / "g.json")
    _graph().save(gpath)
    ck = str(tmp_path / "ck.jsonl")
    assert main(["run", gpath, "--knob", "prefetch=0,2,4",
                 "--strategy", "random", "--budget", "3",
                 "--checkpoint", ck]) == 0
    capsys.readouterr()
    with open(ck, "a") as f:
        f.write('{"index": 99, "config": {"pref')     # killed mid-write
    assert main(["front", ck]) == 0
    out = capsys.readouterr().out
    assert "trials=3" in out and "best" in out


def test_cli_rejects_workload_knobs(tmp_path, capsys):
    from repro.search.cli import main
    gpath = str(tmp_path / "g.json")
    _graph().save(gpath)
    rc = main(["run", gpath, "--knob", "n_layers=8,16@workload",
               "--knob", "prefetch=0,2", "--budget", "4"])
    assert rc == 2
    assert "workload" in capsys.readouterr().err


def test_checkpoint_version_mismatch_refuses(tmp_path):
    g = _graph()
    ck = str(tmp_path / "run.jsonl")
    SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
              budget=4, seed=0, checkpoint=ck).run()
    with open(ck) as f:
        lines = f.read().splitlines()
    head = json.loads(lines[0])
    head["search"] = 99
    with open(ck, "w") as f:
        f.write("\n".join([json.dumps(head, sort_keys=True)] + lines[1:])
                + "\n")
    with pytest.raises(ValueError, match="version"):
        SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=4, seed=0, checkpoint=ck).run()


def test_cli_user_errors_exit_2_not_traceback(tmp_path, capsys):
    from repro.search.cli import main
    gpath = str(tmp_path / "g.json")
    _graph().save(gpath)
    assert main(["run", gpath, "--knob", "noequals"]) == 2
    assert "error" in capsys.readouterr().err
    ck = str(tmp_path / "ck.jsonl")
    base = ["run", gpath, "--knob", "prefetch=0,2", "--budget", "2",
            "--checkpoint", ck]
    assert main(base + ["--seed", "0"]) == 0
    capsys.readouterr()
    assert main(base + ["--seed", "1"]) == 2     # header mismatch, no traceback
    assert "mismatch" in capsys.readouterr().err


def test_cli_parse_knob():
    from repro.search.cli import parse_knob
    k = parse_knob("bucket_bytes=null,64e6,1.5@hardware")
    assert k.name == "bucket_bytes" and k.layer == "hardware"
    assert k.values == [None, 64e6, 1.5]
    k2 = parse_knob("algo=ring,hd")
    assert k2.values == ["ring", "hd"] and k2.layer == "software"
    with pytest.raises(ValueError):
        parse_knob("noequals")
    with pytest.raises(ValueError):
        parse_knob("a=1@badlayer")


# ---------------------------------------------------------------------------
# failed trials (crash-proof sweep)
# ---------------------------------------------------------------------------

def _poisoned_graph_for(cfg):
    """Capture that crashes for half the space — a config whose workload
    build dies, like an OOMing capture job."""
    if cfg.get("poison"):
        raise RuntimeError("capture exploded")
    return _graph()


_POISON_KNOBS = [Knob("poison", [0, 1], layer="workload"),
                 Knob("prefetch", [0, 2, 4, 8], layer="software"),
                 Knob("bucket_bytes", [None, 64e6], layer="software")]


def test_failed_trials_recorded_and_sweep_completes(tmp_path):
    from repro.search.run import FAILED_OBJECTIVE
    ck = str(tmp_path / "run.jsonl")
    r = SearchRun(_poisoned_graph_for, SYS, _POISON_KNOBS, strategy="random",
                  budget=6, seed=0, checkpoint=ck).run()
    assert len(r.trials) == 6            # the sweep burned its full budget
    failed = r.failed_trials
    good = [t for t in r.trials if t.ok]
    assert failed and good
    for t in failed:
        assert "RuntimeError: capture exploded" in t.error
        assert t.objective == FAILED_OBJECTIVE and t.objectives == {}
    # failures never compete for best / the front
    assert r.best is not None and r.best.ok
    assert all(t.ok for t in r.full_trials)
    assert f"{len(failed)} failed" in r.summary()
    # ...and are persisted with their error string
    recs = [json.loads(ln) for ln in open(ck).read().splitlines()][1:]
    assert [bool(rec.get("error")) for rec in recs] == \
           [not t.ok for t in r.trials]


def test_failed_trials_resume_bit_identical(tmp_path, monkeypatch):
    ref = SearchRun(_poisoned_graph_for, SYS, _POISON_KNOBS,
                    strategy="bayesian", budget=10, seed=4).run()
    assert ref.failed_trials             # the poison actually fired
    ck = str(tmp_path / "run.jsonl")
    SearchRun(_poisoned_graph_for, SYS, _POISON_KNOBS, strategy="bayesian",
              budget=10, seed=4, checkpoint=ck).run()
    _truncate_checkpoint(ck, 5)          # killed mid-sweep

    evals = []
    orig = SearchRun._evaluate

    def counting(self, cfg, fid):
        evals.append(dict(cfg))
        return orig(self, cfg, fid)

    monkeypatch.setattr(SearchRun, "_evaluate", counting)
    r2 = SearchRun(_poisoned_graph_for, SYS, _POISON_KNOBS,
                   strategy="bayesian", budget=10, seed=4,
                   checkpoint=ck).run()
    assert (r2.n_resumed, r2.n_evaluated) == (5, 5)
    assert len(evals) == 5
    # resumed run == uninterrupted run, error strings included
    assert [(t.config, t.objective, t.error) for t in r2.trials] == \
           [(t.config, t.objective, t.error) for t in ref.trials]
    assert r2.best is not None and r2.best.config == ref.best.config


def test_corrupted_trial_record_names_field_and_line(tmp_path):
    g = _graph()
    ck = str(tmp_path / "run.jsonl")
    SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
              budget=4, seed=0, checkpoint=ck).run()
    lines = open(ck).read().splitlines()

    def rewrite(i, mutate):
        rec = json.loads(lines[i])
        mutate(rec)
        out = list(lines)
        out[i] = json.dumps(rec)
        with open(ck, "w") as f:
            f.write("\n".join(out) + "\n")

    # drop 'objective' from the 2nd trial (file line 3)
    rewrite(2, lambda rec: rec.pop("objective"))
    with pytest.raises(ValueError, match=r":3.*'objective'"):
        SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=4, seed=0, checkpoint=ck).run()
    # a record that is valid JSON but not an object
    with open(ck, "w") as f:
        f.write(lines[0] + "\n" + json.dumps([1, 2]) + "\n")
    with pytest.raises(ValueError, match=r":2.*expected an object"):
        SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=4, seed=0, checkpoint=ck).run()
    # 'objectives' gone without an error marker: refused with a hint
    with open(ck, "w") as f:
        f.write("\n".join(lines) + "\n")
    rewrite(1, lambda rec: rec.pop("objectives"))
    with pytest.raises(ValueError, match=r":2.*objectives"):
        SearchRun(lambda cfg: g, SYS, _fsdp_knobs(), strategy="random",
                  budget=4, seed=0, checkpoint=ck).run()
