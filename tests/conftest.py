import os
import subprocess
import sys

import pytest

# NOTE: do NOT set --xla_force_host_platform_device_count here; smoke tests
# and benches must see 1 device (multi-device tests use subprocesses).

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
sys.path.insert(0, SRC)


def run_subprocess(code: str, devices: int = 8, timeout: int = 600) -> str:
    """Run python code in a fresh process with N fake devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-c", code], capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"subprocess failed:\n{r.stdout}\n{r.stderr}"
    return r.stdout


@pytest.fixture
def subproc():
    return run_subprocess
