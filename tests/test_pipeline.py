"""GPipe pipeline over a stage axis: forward equivalence + trainability."""
import pytest

pytestmark = pytest.mark.skip(
    reason="pre-existing at seed: parallel/pipeline.py's shard_map+ppermute "
           "stage loop fails on jax 0.4.37 — see ROADMAP 'jax 0.4.37 compat'")


def test_pipeline_matches_sequential(subproc):
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.mesh import make_mesh
from repro.parallel.pipeline import pipeline_apply

mesh = make_mesh((4,), ("pod",))
L, D, M, mb = 8, 16, 6, 4
rng = np.random.RandomState(0)
w = jnp.asarray(rng.randn(L, D, D).astype(np.float32) * 0.3)
x = jnp.asarray(rng.randn(M, mb, D).astype(np.float32))

def block(p, h):
    return jnp.tanh(h @ p)

y_pipe = pipeline_apply(block, w, x, mesh, "pod")
# sequential reference
def seq(h):
    for i in range(L):
        h = block(w[i], h)
    return h
y_ref = jax.vmap(seq)(x)
np.testing.assert_allclose(np.asarray(y_pipe), np.asarray(y_ref), atol=1e-5)
print("pipeline fwd ok")

# differentiable: grad wrt stacked params flows through ppermute
def loss(w):
    y = pipeline_apply(block, w, x, mesh, "pod")
    return jnp.mean(y ** 2)
g = jax.grad(loss)(w)
def loss_ref(w):
    def seq(h):
        for i in range(L):
            h = jnp.tanh(h @ w[i])
        return h
    return jnp.mean(jax.vmap(seq)(x) ** 2)
g_ref = jax.grad(loss_ref)(w)
np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=1e-5)
print("pipeline grad ok")
""", devices=4)
    assert "pipeline fwd ok" in out and "pipeline grad ok" in out


def test_pipeline_two_stage_multipod_shape(subproc):
    """2-stage pipeline on the multi-pod production mesh's pod axis."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.mesh import make_mesh
from repro.parallel.pipeline import pipeline_apply
mesh = make_mesh((2, 2, 2), ("pod", "data", "model"))
L, D, M, mb = 4, 8, 4, 2
w = jnp.ones((L, D, D), jnp.float32) * 0.1
x = jnp.ones((M, mb, D), jnp.float32)
y = pipeline_apply(lambda p, h: jnp.tanh(h @ p), w, x, mesh, "pod")
assert y.shape == (M, mb, D)
assert np.isfinite(np.asarray(y)).all()
print("multipod pipeline ok", y.shape)
""", devices=8)
    assert "multipod pipeline ok" in out
