"""Sharding rule resolution: divisibility fallbacks, two-pass seq, EP-vs-TP."""
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.parallel.sharding import (activation_rules, param_rules,
                                     resolve_spec)


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_batch_prefers_pod_data():
    r = activation_rules(ParallelConfig())
    assert resolve_spec(("batch", "seq"), (256, 4096), r, MESH3) == \
        P(("pod", "data"), "model")
    assert resolve_spec(("batch", "seq"), (256, 4096), r, MESH) == \
        P("data", "model")


def test_batch_divisibility_fallback():
    r = activation_rules(ParallelConfig())
    # batch=1 (long_500k): nothing divides -> replicated
    spec = resolve_spec(("batch", None), (1, 1), r, MESH)
    assert spec == P()


def test_seq_is_low_priority():
    r = activation_rules(ParallelConfig(seq_shard=True))
    # residual (batch, seq, embed): seq gets model
    assert resolve_spec(("batch", "seq", "embed"), (256, 4096, 4096), r,
                        MESH) == P("data", "model")
    # q (batch, seq, heads, hd): heads wins model, seq left unsharded
    assert resolve_spec(("batch", "seq", "heads", None),
                        (256, 4096, 32, 128), r, MESH) == \
        P("data", None, "model")


def test_heads_divisibility_fallback():
    r = activation_rules(ParallelConfig(seq_shard=False))
    # gemma3-4b: 8 q-heads on 16-way model axis -> replicated heads
    assert resolve_spec(("batch", None, "heads", None), (256, 1, 8, 256), r,
                        MESH) == P("data")


def test_ep_vs_tp_falls_out_of_divisibility():
    r = param_rules(ParallelConfig(fsdp=False))
    # dbrx: 16 experts -> EP on model; ff blocked (axis used)
    assert resolve_spec(("experts", "embed", "ff"), (16, 6144, 10752), r,
                        MESH) == P("model")
    # mixtral: 8 experts don't divide 16 -> ff gets model (TP)
    assert resolve_spec(("experts", "embed", "ff"), (8, 4096, 14336), r,
                        MESH) == P(None, None, "model")


def test_fsdp_shards_embed_dim_of_params():
    rp = param_rules(ParallelConfig(fsdp=True))
    assert resolve_spec(("embed", "ff"), (4096, 12288), rp, MESH) == \
        P("data", "model")
    # activations never FSDP-shard embed (no "data" in the embed slot)
    ra = activation_rules(ParallelConfig(seq_shard=False))
    spec = resolve_spec(("batch", "seq", "embed"), (32, 128, 4096), ra, MESH)
    assert spec == P("data")


def test_no_duplicate_axis_in_one_tensor():
    r = activation_rules(ParallelConfig(seq_shard=True))
    spec = resolve_spec(("vocab", "embed", "ff"), (256 * 16, 4096, 12288), r,
                        MESH)
    flat = [a for a in spec if a]
    assert len(flat) == len(set(flat))


def test_cache_sharding_only_when_enabled():
    r_on = activation_rules(ParallelConfig(seq_shard_cache=True))
    r_off = activation_rules(ParallelConfig(seq_shard_cache=False))
    axes = ("batch", "cache", "kv_heads", None)
    shape = (1, 524288, 8, 256)
    assert resolve_spec(axes, shape, r_on, MESH) == P(None, "data")
    assert resolve_spec(axes, shape, r_off, MESH) == P()
