"""Analytic conformance of the microbatched pipeline lowering (ISSUE 10).

The textbook pipeline-bubble fraction for GPipe and 1F1B on a p-stage,
m-microbatch pipeline with balanced stages and negligible comm is
(p - 1) / (m + p - 1).  These tests drive balanced explicit
forward/backward chain workloads (one f and one b node per stage, uniform
cost, near-zero payloads) through ``split_pipeline_stages`` and the MPMD
engine, and assert the *simulated* bubble lands within 10% of the analytic
value across a (p, m) grid — the schedule semantics are emergent from the
lowering + engine, not hard-coded.

The memory side checks the schedules' signature footprints on the PR-9
occupancy timeline: GPipe stashes all m per-microbatch activations on the
first stage before the backward wave drains them, while 1F1B's
alternation caps the stash near p — so 1F1B's activation peak must sit
well below GPipe's whenever m > p, while the bit-exact decomposition
identities (class sums == total, curve max == engine peak, blame sums ==
makespan with a ``bubble`` component) keep holding.
"""
import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.convert import split_pipeline_stages
from repro.core.costmodel import build_topology, simulate_cluster
from repro.core.costmodel.schedule import (analytic_bubble_fraction,
                                           bubble_fraction)
from repro.obs.explain import COMPONENTS, explain
from repro.obs.memory import memory_timeline

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)


def fb_chain(p, f_flops=1e12, b_flops=2e12, payload=8.0):
    """Balanced explicit f/b chain: one forward and one backward node per
    stage (uniform cost), backward edges b_{s+1} -> b_s, explicit stage
    map — the workload shape the analytic bubble formula assumes."""
    g = chakra.Graph()
    f = []
    for s in range(p):
        deps = [f[-1]] if f else []
        f.append(g.add(f"f{s}", chakra.COMP, deps=deps,
                       flops=f_flops, out_bytes=payload))
    b_prev = None
    for s in reversed(range(p)):
        deps = [f[s]] + ([b_prev] if b_prev is not None else [])
        b_prev = g.add(f"b{s}", chakra.COMP, deps=deps,
                       flops=b_flops, out_bytes=payload)
    assign = list(range(p)) + list(reversed(range(p)))
    return g, assign


def run(p, m, schedule, payload=8.0, keep_timeline=False):
    g, assign = fb_chain(p, payload=payload)
    prog = split_pipeline_stages(g, p, assignment=assign,
                                 num_microbatches=m, schedule=schedule)
    res = simulate_cluster(prog, SYS, topo=TOPO,
                           keep_timeline=keep_timeline)
    return prog, res


GRID = [(2, 2), (2, 4), (2, 8), (4, 4), (4, 8), (4, 16)]


@pytest.mark.parametrize("schedule", ["gpipe", "1f1b"])
@pytest.mark.parametrize("p,m", GRID)
def test_bubble_within_10pct_of_analytic(schedule, p, m):
    _prog, res = run(p, m, schedule)
    sim = bubble_fraction(res)
    ana = analytic_bubble_fraction(p, m)
    assert abs(sim - ana) <= 0.10 * ana + 1e-3, \
        f"{schedule} p={p} m={m}: simulated bubble {sim:.4f} vs " \
        f"analytic {ana:.4f}"


def test_bubble_shrinks_with_m():
    # the whole point of microbatching: fixed p, growing m -> smaller bubble
    fracs = [bubble_fraction(run(4, m, "gpipe")[1]) for m in (2, 4, 8, 16)]
    assert all(a > b for a, b in zip(fracs, fracs[1:])), fracs
    assert fracs[-1] < 0.2 < fracs[0]


def test_1f1b_matches_gpipe_makespan_on_balanced_chain():
    # same total work, same fill/drain structure: the two schedules differ
    # in memory, not speed, on a balanced chain
    for p, m in GRID:
        tg = run(p, m, "gpipe")[1].step_time
        t1 = run(p, m, "1f1b")[1].step_time
        assert t1 <= tg * 1.05, (p, m, tg, t1)


# ------------------------------------------------------------------- memory

def test_1f1b_peak_activation_below_gpipe():
    p, m = 4, 16                       # m > p: the regime 1F1B exists for
    peaks = {}
    for sched in ("gpipe", "1f1b"):
        prog, res = run(p, m, sched, payload=1e6, keep_timeline=True)
        mt = memory_timeline(res, graph=prog)
        assert mt.identity_ok()        # bit-exact decomposition still sums
        peaks[sched] = mt.ranks[0].class_peak("activations")
    assert 0 < peaks["1f1b"] < peaks["gpipe"]
    # GPipe stashes ~m per-microbatch activations, 1F1B ~p: the ratio
    # should reflect m/p = 4 with generous slack for boundary effects
    assert peaks["gpipe"] / peaks["1f1b"] > 0.5 * (m / p)


def test_gpipe_stash_scales_with_m():
    p = 4
    prev = None
    for m in (4, 8, 16):
        prog, res = run(p, m, "gpipe", payload=1e6, keep_timeline=True)
        mt = memory_timeline(res, graph=prog)
        pk = mt.ranks[0].class_peak("activations")
        if prev is not None:
            # per-mb size halves when m doubles but the stash count
            # doubles -> GPipe's first-stage activation peak stays ~flat,
            # while 1F1B's (below) halves.  Flat within slack:
            assert 0.7 <= pk / prev <= 1.3, (m, prev, pk)
        prev = pk


def test_1f1b_stash_shrinks_with_m():
    p = 4
    prev = None
    for m in (4, 8, 16):
        prog, res = run(p, m, "1f1b", payload=1e6, keep_timeline=True)
        mt = memory_timeline(res, graph=prog)
        pk = mt.ranks[0].class_peak("activations")
        if prev is not None:
            # stash capped near p, per-mb size halves -> peak ~halves
            assert pk < prev * 0.8, (m, prev, pk)
        prev = pk


# -------------------------------------------------------------------- blame

def test_blame_has_bubble_component_and_identities_hold():
    assert "bubble" in COMPONENTS
    for sched in ("gpipe", "1f1b"):
        prog, res = run(4, 4, sched, keep_timeline=True)
        ex = explain(res, graph=prog)
        assert ex.identity_ok()        # per-rank components sum to makespan
        bubble = sum(b.components["bubble"] for b in ex.ranks.values())
        assert bubble > 0.0, f"{sched}: no p2p wait attributed to bubble"
        # the pipeline spends a nontrivial share of rank-seconds off the
        # compute stream; blame must see it somewhere (bubble + stall)
        idle = sum(b.components["bubble"] + b.components["stall"]
                   for b in ex.ranks.values())
        assert idle / (len(ex.ranks) * ex.makespan) > 0.2
