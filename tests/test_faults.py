"""Fault-scenario subsystem: scenario sampling (seeded, rate-coupled),
horizon simulation semantics (lost work, spares, elastic rescale, MPMD
stalls), segmented re-simulation caching, Monte-Carlo determinism, the
goodput-monotone-in-fault-rate property, Young/Daly optimal-interval
recovery, and the DSE/objectives integration."""
import math

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import MPMDProgram, build_topology, simulate_cluster
from repro.core.dse import Knob, explore
from repro.faults import (CheckpointPolicy, FaultEvent, FaultRates,
                          FaultScenario, FaultSimResult, analytic_goodput,
                          fault_metrics, monte_carlo, simulate_horizon,
                          young_daly_interval)

SYS = SystemConfig(chips=16, topology="switch")
TOPO = build_topology(SYS)
K = 16


def _graph(n_layers=4, comm_mb=4.0, group=K):
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=comm_mb * 1e6, out_bytes=comm_mb * 1e6,
                   group=list(range(group)))
        deps = [ag] + ([prev] if prev is not None else [])
        prev = g.add(f"comp{i}", chakra.COMP, deps=deps, flops=5e10,
                     out_bytes=1e6)
    return g


G = _graph()
S0 = float(simulate_cluster(G, SYS, TOPO, n_ranks=K).total_time)


# ---------------------------------------------------------------------------
# scenario DSL + sampling
# ---------------------------------------------------------------------------

def test_event_and_policy_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultEvent(1.0, "meteor", rank=0)
    with pytest.raises(ValueError, match="time"):
        FaultEvent(-1.0, "stall")
    with pytest.raises(ValueError, match="target rank"):
        FaultEvent(1.0, "fail_stop")
    with pytest.raises(ValueError, match="slowdown magnitude"):
        FaultEvent(1.0, "slowdown", rank=0, magnitude=0.5)
    with pytest.raises(ValueError, match="bandwidth"):
        FaultEvent(1.0, "link_degrade", rank=0, magnitude=1.5)
    with pytest.raises(ValueError, match="interval"):
        CheckpointPolicy(interval=0)
    with pytest.raises(ValueError, match="costs"):
        CheckpointPolicy(write_cost=-1.0)
    with pytest.raises(ValueError, match="horizon"):
        FaultScenario([], horizon=0.0)
    with pytest.raises(ValueError, match="outside cluster"):
        FaultScenario([FaultEvent(1.0, "fail_stop", rank=9)],
                      horizon=10.0, n_ranks=8)
    with pytest.raises(ValueError, match="young_daly"):
        young_daly_interval(0.0, 100.0)


def test_scenario_sampling_deterministic_and_rate_coupled():
    rates = FaultRates(fail_rate=0.5, slowdown_rate=1.0, stall_rate=0.25)
    a = FaultScenario.sample(rates, horizon=40.0, n_ranks=K, seed=11)
    b = FaultScenario.sample(rates, horizon=40.0, n_ranks=K, seed=11)
    assert [dataclasses_tuple(e) for e in a.events] == \
           [dataclasses_tuple(e) for e in b.events]
    c = FaultScenario.sample(rates, horizon=40.0, n_ranks=K, seed=12)
    assert [dataclasses_tuple(e) for e in a.events] != \
           [dataclasses_tuple(e) for e in c.events]
    # coupling: doubling a rate exactly halves the shared arrival times and
    # keeps the per-event target ranks (inverse-CDF on the same uniforms)
    lo = FaultScenario.sample(FaultRates(fail_rate=0.5), 40.0, K, seed=3)
    hi = FaultScenario.sample(FaultRates(fail_rate=1.0), 40.0, K, seed=3)
    los = [e for e in lo.events]
    his = [e for e in hi.events]
    assert len(his) >= len(los)
    for el, eh in zip(los, his[:len(los)]):
        assert eh.time == pytest.approx(el.time / 2.0)
        assert eh.rank == el.rank


def dataclasses_tuple(e):
    return (e.time, e.kind, e.rank, e.duration, e.magnitude)


# ---------------------------------------------------------------------------
# horizon semantics
# ---------------------------------------------------------------------------

def test_fault_free_horizon_is_ideal():
    sc = FaultScenario([], horizon=1e9)
    hr = simulate_horizon(G, SYS, sc, CheckpointPolicy(interval=10),
                          topo=TOPO, n_ranks=K, n_steps=100)
    assert hr.useful_steps == 100
    assert hr.goodput == pytest.approx(1.0)
    assert hr.makespan_inflation == pytest.approx(1.0)
    assert hr.p50_step_time == hr.p99_step_time == pytest.approx(S0)
    assert hr.n_failures == 0 and hr.lost_steps == 0
    assert hr.n_signatures == 1


def test_slowdown_window_p99_and_segment_caching():
    # two identical 2x-slowdown windows -> 2 distinct signatures even
    # though the timeline has >2 segments (repeats hit the cache)
    evs = [FaultEvent(10 * S0, "slowdown", rank=3, duration=20 * S0,
                      magnitude=2.0),
           FaultEvent(60 * S0, "slowdown", rank=3, duration=20 * S0,
                      magnitude=2.0)]
    sc = FaultScenario(evs, horizon=1e9, n_ranks=K)
    hr = simulate_horizon(G, SYS, sc, CheckpointPolicy(interval=1000),
                          topo=TOPO, n_ranks=K, n_steps=100,
                          keep_segments=True)
    assert hr.useful_steps == 100
    assert hr.goodput < 1.0
    assert hr.n_signatures == 2
    assert hr.n_segments >= 3
    assert hr.p50_step_time == pytest.approx(S0)
    # a 2x compute slowdown on the critical path at least slows the step
    assert hr.p99_step_time > hr.p50_step_time
    # memoize=False is the naive baseline: identical physics, no caches
    naive = simulate_horizon(G, SYS, sc, CheckpointPolicy(interval=1000),
                             topo=TOPO, n_ranks=K, n_steps=100,
                             memoize=False)
    assert naive.as_dict() == hr.as_dict()


def test_fail_stop_lost_work_and_wall_accounting():
    pol = CheckpointPolicy(interval=10, write_cost=0.5 * S0,
                           restore_cost=3.0 * S0)
    ev = FaultEvent(4.5 * S0, "fail_stop", rank=2)    # never returns
    sc = FaultScenario([ev], horizon=1e9, n_ranks=K)
    hr = simulate_horizon(G, SYS, sc, pol, topo=TOPO, n_ranks=K, n_steps=50)
    assert hr.n_failures == 1
    assert hr.lost_steps == 5            # steps 0..4 re-run from checkpoint 0
    assert hr.useful_steps == 50
    assert hr.restore_s == pytest.approx(pol.restore_cost)   # one rescale
    # conservation: wall == executed step time + checkpoints + restores
    executed = sum(s * c for s, c in hr.step_records)
    assert hr.wall_time == pytest.approx(
        executed + hr.checkpoint_s + hr.restore_s + hr.stall_s)
    assert hr.goodput < 1.0
    # the post-failure cluster runs on 15 survivors -> a second signature
    assert hr.n_signatures == 2


def test_spare_absorbs_failure_keeps_full_cluster():
    pol = CheckpointPolicy(interval=10, restore_cost=2.0 * S0)
    sc = FaultScenario([FaultEvent(4.5 * S0, "fail_stop", rank=2)],
                       horizon=1e9, n_ranks=K)
    spare = simulate_horizon(G, SYS, sc, pol, topo=TOPO, n_ranks=K,
                             n_steps=50, spare_ranks=1)
    rescale = simulate_horizon(G, SYS, sc, pol, topo=TOPO, n_ranks=K,
                               n_steps=50, spare_ranks=0)
    assert spare.n_signatures == 1       # never leaves the K-rank profile
    assert rescale.n_signatures == 2
    assert spare.goodput >= rescale.goodput
    assert spare.p99_step_time == pytest.approx(S0)


def test_stall_event_adds_wall_without_progress():
    sc = FaultScenario([FaultEvent(2.0 * S0, "stall", duration=7.0)],
                       horizon=1e9)
    hr = simulate_horizon(G, SYS, sc, CheckpointPolicy(interval=1000),
                          topo=TOPO, n_ranks=K, n_steps=20)
    assert hr.stall_s == pytest.approx(7.0)
    assert hr.useful_steps == 20
    assert hr.wall_time == pytest.approx(20 * S0 + 7.0)


def test_mpmd_fail_stop_stalls_until_return():
    g = _graph(group=4)
    prog = MPMDProgram([g, g, g, g])
    s0 = float(simulate_cluster(prog, SYS, TOPO).total_time)
    pol = CheckpointPolicy(interval=100, restore_cost=s0)
    down = 10 * s0
    sc = FaultScenario([FaultEvent(3.5 * s0, "fail_stop", rank=1,
                                   duration=down)], horizon=1e9, n_ranks=4)
    hr = simulate_horizon(prog, SYS, sc, pol, n_steps=50)
    # the program cannot drop rank 1: it waits out the downtime, restores,
    # and finishes its step budget
    assert hr.downtime_s == pytest.approx(down, rel=0.3)
    assert hr.restore_s == pytest.approx(pol.restore_cost)
    assert hr.n_failures == 1
    # permanent failure without a wall limit is a hard error, not a hang
    forever = FaultScenario([FaultEvent(3.5 * s0, "fail_stop", rank=1)],
                            horizon=1e9, n_ranks=4)
    with pytest.raises(RuntimeError, match="stalled"):
        simulate_horizon(prog, SYS, forever, pol, n_steps=50)
    # ...but a wall limit bounds it cleanly
    hr2 = simulate_horizon(prog, SYS, forever, pol, n_steps=50,
                           wall_limit=20 * s0)
    assert hr2.useful_steps < 50
    assert hr2.wall_time == pytest.approx(20 * s0)


# ---------------------------------------------------------------------------
# Monte-Carlo layer
# ---------------------------------------------------------------------------

def test_monte_carlo_deterministic_in_seed():
    rates = FaultRates(fail_rate=1.0 / (200 * S0), fail_downtime=50 * S0)
    pol = CheckpointPolicy(interval=20, write_cost=S0, restore_cost=2 * S0)
    kw = dict(topo=TOPO, n_ranks=K, n_steps=100, n_trials=4)
    a = monte_carlo(G, SYS, rates, pol, seed=5, **kw)
    b = monte_carlo(G, SYS, rates, pol, seed=5, **kw)
    assert a.as_dict() == b.as_dict()
    c = monte_carlo(G, SYS, rates, pol, seed=6, **kw)
    assert a.as_dict() != c.as_dict()
    assert a.n_trials == 4


@pytest.mark.parametrize("seed", [0, 7, 23])
def test_goodput_monotone_nonincreasing_in_fault_rate(seed):
    """The DSE contract: raising fault_rate never raises expected goodput.
    Rate-coupled sampling makes this exact (same arrival sequence,
    compressed), not just true on average."""
    pol = CheckpointPolicy(interval=20, write_cost=S0, restore_cost=2 * S0)
    rates_per_step = [1e-9, 1e-3, 1e-2, 0.05, 0.1]
    last = math.inf
    for r in rates_per_step:
        mc = monte_carlo(
            G, SYS, FaultRates(fail_rate=r / S0, fail_downtime=50 * S0),
            pol, topo=TOPO, n_ranks=K, n_steps=60, n_trials=6, seed=seed)
        assert mc.expected_goodput <= last + 1e-12, \
            f"goodput rose at rate {r}/step (seed {seed})"
        last = mc.expected_goodput
    assert last < 1.0                    # the ladder actually bites


def _recover_interval(mtbf_steps, c_steps, n_trials=32, seed=3):
    """Best checkpoint interval by simulated expected goodput, on a log
    grid around the Young/Daly optimum, with common random numbers (the
    same sampled scenarios) across every interval arm."""
    mtbf, C = mtbf_steps * S0, c_steps * S0
    R = 2 * C
    horizon = 30.0 * mtbf
    rates = FaultRates(fail_rate=1.0 / mtbf, fail_downtime=0.5 * C)
    scen = [FaultScenario.sample(rates, horizon, K, seed=(seed, i))
            for i in range(n_trials)]
    i_yd = young_daly_interval(C, mtbf) / S0
    grid = sorted({max(1, round(i_yd * 1.08 ** k)) for k in range(-9, 10)})
    best_i, best_g = None, -1.0
    for interval in grid:
        mc = monte_carlo(G, SYS, rates,
                         CheckpointPolicy(interval=interval, write_cost=C,
                                          restore_cost=R),
                         topo=TOPO, n_ranks=K, wall_limit=horizon,
                         scenarios=scen)
        if mc.expected_goodput > best_g:
            best_g, best_i = mc.expected_goodput, interval
    return best_i, i_yd


@pytest.mark.parametrize("mtbf_steps,c_steps", [(400, 2), (1600, 8)])
def test_simulated_optimum_recovers_young_daly(mtbf_steps, c_steps):
    best_i, i_yd = _recover_interval(mtbf_steps, c_steps)
    err = abs(best_i - i_yd) / i_yd
    assert err <= 0.15, (f"MTBF={mtbf_steps} C={c_steps}: simulated optimum "
                         f"{best_i} vs Young/Daly {i_yd:.1f} ({err:.0%} off)")


def test_analytic_goodput_peaks_at_young_daly():
    C, mtbf = 2 * S0, 400 * S0
    i_yd = young_daly_interval(C, mtbf) / S0
    grid = range(1, 200)
    best = max(grid, key=lambda i: analytic_goodput(S0, i, C, 2 * C,
                                                    1.0 / mtbf))
    assert abs(best - i_yd) / i_yd <= 0.05


# ---------------------------------------------------------------------------
# DSE + objectives integration
# ---------------------------------------------------------------------------

def test_fault_sim_result_delegates_to_base():
    base = simulate_cluster(G, SYS, TOPO, n_ranks=K)
    fr = FaultSimResult(base, expected_goodput=0.9,
                        p99_step_time_under_faults=2 * S0,
                        makespan_inflation=1.1)
    assert fr.total_time == base.total_time          # delegated
    assert fr.expected_goodput == 0.9
    d = fr.as_dict()
    assert d["expected_goodput"] == 0.9 and "total_time" in d
    with pytest.raises(AttributeError):
        fr.no_such_metric
    with pytest.raises(AttributeError):
        fr._no_private_delegation


def test_explore_routes_fault_knobs_and_sorts_by_sense():
    knobs = [Knob("checkpoint_interval", [5, 40], layer="software"),
             Knob("fault_rate", [1.0 / (300 * S0)], layer="software"),
             Knob("fault_trials", [4], layer="software"),
             Knob("fault_steps", [60], layer="software")]
    trials = explore(lambda cfg: G, SYS, knobs,
                     objective="expected_goodput")
    assert len(trials) == 2
    assert all(isinstance(t.result, FaultSimResult) for t in trials)
    # maximized objective: best (highest goodput) sorts first
    assert trials[0].objective >= trials[1].objective
    # fault-free trials stay plain results
    plain = explore(lambda cfg: G, SYS,
                    [Knob("prefetch", [0, 2], layer="software")])
    assert not any(isinstance(t.result, FaultSimResult) for t in plain)


def test_spare_ranks_goodput_normalized_per_provisioned_rank():
    cfg = {"checkpoint_interval": 20, "fault_rate": 0.0, "fault_trials": 1,
           "fault_steps": 40}
    base = simulate_cluster(G, SYS, TOPO, n_ranks=K)
    no_spare = fault_metrics(G, SYS, TOPO, cfg, base, n_ranks=K)
    with_spares = fault_metrics(G, SYS, TOPO, {**cfg, "spare_ranks": 4},
                                base, n_ranks=K)
    # fault-free: spares are pure provisioning overhead, K/(K+4) exactly
    assert with_spares.expected_goodput == pytest.approx(
        no_spare.expected_goodput * K / (K + 4))


def test_objective_sense_scalarize_dominates():
    from repro.search.objectives import dominates, scalarize, sense
    assert sense("total_time") == 1.0
    assert sense("expected_goodput") == -1.0
    ref = {"expected_goodput": 0.5}
    hi = scalarize({"expected_goodput": 0.9}, ["expected_goodput"], [1.0],
                   ref)
    lo = scalarize({"expected_goodput": 0.6}, ["expected_goodput"], [1.0],
                   ref)
    assert hi < lo                       # higher goodput = better (smaller)
    names = ["expected_goodput", "p99_step_time_under_faults"]
    a = {"expected_goodput": 0.9, "p99_step_time_under_faults": 1.0}
    b = {"expected_goodput": 0.8, "p99_step_time_under_faults": 1.5}
    assert dominates(a, b, names) and not dominates(b, a, names)
