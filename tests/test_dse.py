"""DSE loop: knob exploration, capture caching, greedy descent."""
from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.dse import Knob, explore, greedy_descent


def _graph(n_layers=8, comm_mb=8.0):
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=comm_mb * 1e6, out_bytes=comm_mb * 1e6,
                   group=list(range(16)))
        deps = [ag] + ([prev] if prev is not None else [])
        prev = g.add(f"comp{i}", chakra.COMP, deps=deps, flops=5e10,
                     out_bytes=1e6)
    return g


def test_explore_grid_and_caching():
    captures = []

    def graph_for(cfg):
        captures.append(cfg.get("layers"))
        return _graph(cfg.get("layers", 8))

    knobs = [
        Knob("layers", [4, 8], layer="workload"),
        Knob("fsdp_sync", [True], layer="software"),
        Knob("prefetch", [0, 2, 8], layer="software"),
        Knob("link_bw", [25e9, 100e9], layer="hardware"),
    ]
    trials = explore(graph_for, SystemConfig(chips=16), knobs)
    assert len(trials) == 2 * 3 * 2
    # workload captured once per distinct workload config
    assert len(captures) == 2
    # best trial is sorted first
    assert trials[0].objective == min(t.objective for t in trials)
    # more prefetch never slower at same layers+bw
    by = {(t.config["layers"], t.config["prefetch"], t.config["link_bw"]):
          t.objective for t in trials}
    for L in (4, 8):
        for bw in (25e9, 100e9):
            assert by[(L, 8, bw)] <= by[(L, 0, bw)] + 1e-12


def test_greedy_descent_improves():
    def graph_for(cfg):
        return _graph(8)

    knobs = [
        Knob("fsdp_sync", [True], layer="software"),
        Knob("prefetch", [0, 1, 4, 8], layer="software"),
        Knob("collective_algo", ["ring", "2d_synth"], layer="hardware"),
    ]
    best = greedy_descent(graph_for, SystemConfig(chips=16), knobs)
    base = explore(graph_for, SystemConfig(chips=16),
                   [Knob("fsdp_sync", [True]), Knob("prefetch", [0])])[0]
    assert best.objective <= base.objective + 1e-12


def test_hardware_knob_changes_objective():
    def graph_for(cfg):
        return _graph(8, comm_mb=64.0)

    trials = explore(graph_for, SystemConfig(chips=16),
                     [Knob("link_bw", [10e9, 200e9], layer="hardware")])
    objs = {t.config["link_bw"]: t.objective for t in trials}
    assert objs[200e9] < objs[10e9]


def test_unknown_strategy_raises_with_registry():
    import pytest
    with pytest.raises(ValueError) as ei:
        explore(lambda cfg: _graph(4), SystemConfig(chips=16),
                [Knob("prefetch", [0, 2])], strategy="simulated_annealing")
    msg = str(ei.value)
    assert "simulated_annealing" in msg
    for name in ("grid", "random", "bayesian", "evolutionary", "halving"):
        assert name in msg


def test_trial_as_dict_json_native():
    import json
    trials = explore(lambda cfg: _graph(4), SystemConfig(chips=16),
                     [Knob("fsdp_sync", [True]),
                      Knob("bucket_bytes", [None, 64e6]),
                      Knob("prefetch", [2])])
    for t in trials:
        d = json.loads(json.dumps(t.as_dict()))
        assert d["config"]["fsdp_sync"] is True          # not "True"
        assert d["config"]["prefetch"] == 2              # not "2"
        bb = d["config"]["bucket_bytes"]
        assert bb is None or bb == 64e6                  # not "None"/"64000000.0"
