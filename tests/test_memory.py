"""Memory-timeline observability (repro.obs.memory) + OOM-aware DSE.

Bit-exact contracts, property-tested over randomized DAGs x overlap
modes x all three engines (simulate / simulate_cluster / MPMD):
  * per-breakpoint class decomposition (weights/activations/comm) sums
    to the total occupancy bit-exactly,
  * the curve max equals the engine's schedule-aware ``peak_bytes``,
  * ``memory_blame``'s live tensors fsum to the peak exactly,
  * ``memory_diff``'s signed terms fsum to the IEEE peak delta exactly,
  * coalesced and naive cluster runs produce identical per-rank curves,
  * the static ``peak_memory_proxy`` relation documented on
    ``simulate_analytic`` (equality under overlap=False, out_bytes only).
Plus the DSE surface: objective-name validation, the
``peak_memory_bytes`` objective, OOM-infeasible trials recorded (not
crashed) and excluded from the Pareto front, and the fault layer's
survivor-occupancy inflation under elastic rescale.
"""
import json
import math
import random

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra, convert
from repro.core.costmodel.compiled import ExactSum, exact_peak
from repro.core.costmodel.simulator import (peak_memory_proxy, simulate,
                                            simulate_analytic,
                                            simulate_cluster)
from repro.core.costmodel.topology import RankProfile, build_topology
from repro.obs.memory import (memory_blame, memory_counters, memory_diff,
                              memory_timeline, export_memory_trace)

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)


def rand_graph(rng, n):
    """Random DAG over all node types (the test-suite shape; float bytes
    so the exact-arithmetic identities are actually exercised)."""
    g = chakra.Graph()
    for i in range(n):
        k = min(i, 4)
        deps = rng.sample(range(i), rng.randint(0, k)) if i else []
        ctrl = rng.sample(range(i), rng.randint(0, k)) if i else []
        r = rng.random()
        if r < 0.5 or i == 0:
            g.add(f"n{i}", chakra.COMP, deps=deps, ctrl_deps=ctrl,
                  flops=rng.uniform(0, 1e9), bytes=rng.uniform(0, 1e8),
                  out_bytes=rng.choice([0.0, rng.uniform(1, 100)]))
        elif r < 0.8:
            g.add(f"c{i}", chakra.COMM_COLL, deps=deps, ctrl_deps=ctrl,
                  comm_kind=rng.choice(["all-gather", "all-reduce",
                                        "reduce-scatter"]),
                  comm_bytes=rng.uniform(1, 1e7), out_bytes=8.0,
                  group=list(range(rng.choice([2, 4, 8, 16]))))
        else:
            g.add(f"m{i}", chakra.MEM, deps=deps, ctrl_deps=ctrl,
                  out_bytes=4.0)
    return g


def chain_graph(n_layers=12, group=16, comm_mb=8.0):
    """FSDP-ish chain: all-gather feeding a compute per layer."""
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=comm_mb * 1e6, out_bytes=comm_mb * 1e6,
                   group=list(range(group)))
        deps = [ag] + ([prev] if prev is not None else [])
        prev = g.add(f"comp{i}", chakra.COMP, deps=deps, flops=5e10,
                     out_bytes=1e6)
    return g


# ---------------------------------------------------------------------------
# occupancy-curve identities
# ---------------------------------------------------------------------------

def test_identity_randomized_dags_both_overlap_modes():
    """Class decomposition == total and curve max == engine peak_bytes,
    bit-exactly, on every randomized DAG in both overlap modes."""
    for seed in range(12):
        rng = random.Random(seed)
        g = rand_graph(rng, rng.randint(5, 150))
        for overlap in (True, False):
            res = simulate(g, SYS, TOPO, overlap=overlap, keep_timeline=True)
            tl = memory_timeline(res, graph=g)
            assert tl.identity_ok(), f"seed={seed} overlap={overlap}"
            assert tl.peak_bytes == res.peak_bytes
            rm = tl.ranks[0]
            # spot-check the decomposition at every breakpoint via fsum
            # of raw class values too (weaker than the partials check the
            # builder does, but catches sign/placement bugs)
            for i in range(len(rm.times)):
                by = [vs[i] for vs in rm.by_class.values()]
                assert abs(math.fsum(by) - rm.total[i]) <= \
                    1e-9 * max(1.0, abs(rm.total[i]))


def test_identity_cluster_hetero_and_mpmd_pipeline():
    """Same identities through the cluster engine (hetero profiles) and
    the 2-stage MPMD pipeline; every rank's curve max equals its own
    engine peak."""
    rng = random.Random(3)
    g = rand_graph(rng, 80)
    profs = {1: RankProfile(compute_scale=0.5),
             5: RankProfile(link_scale=0.25)}
    cr = simulate_cluster(g, SYS, TOPO, n_ranks=8, rank_profiles=profs,
                          keep_timeline=True)
    tl = memory_timeline(cr, graph=g)
    assert tl.identity_ok()
    assert len(tl.ranks) == 8
    for r, rm in tl.ranks.items():
        assert rm.peak_bytes == cr.rank_result(r).peak_bytes
    assert tl.peak_bytes == cr.peak_bytes

    prog = convert.split_pipeline_stages(chain_graph(8), 2)
    cres = simulate_cluster(prog, SYS, TOPO, keep_timeline=True)
    tlp = memory_timeline(cres, graph=prog)
    assert tlp.identity_ok()
    assert tlp.peak_bytes == cres.peak_bytes


def test_coalesced_equals_naive_per_rank_curves():
    """Coalescing is invisible to the memory timeline: per-rank curves
    (breakpoints, totals, every class series) are identical between the
    coalesced and naive cluster engines."""
    rng = random.Random(7)
    g = rand_graph(rng, 60)
    profs = {2: RankProfile(compute_scale=0.5)}
    a = simulate_cluster(g, SYS, TOPO, n_ranks=6, rank_profiles=profs,
                         coalesce=True, keep_timeline=True)
    b = simulate_cluster(g, SYS, TOPO, n_ranks=6, rank_profiles=profs,
                         coalesce=False, keep_timeline=True)
    ta, tb = memory_timeline(a, graph=g), memory_timeline(b, graph=g)
    for r in range(6):
        ra, rb = ta.ranks[r], tb.ranks[r]
        assert ra.times == rb.times
        assert ra.total == rb.total
        assert ra.by_class == rb.by_class
        assert ra.peak_bytes == rb.peak_bytes


def test_blame_covers_peak_exactly():
    """Live tensors at the peak fsum to peak_bytes bit-exactly; class
    split of the blame agrees with the curve's class values at peak."""
    for seed in range(8):
        rng = random.Random(100 + seed)
        g = rand_graph(rng, rng.randint(10, 120))
        for overlap in (True, False):
            res = simulate(g, SYS, TOPO, overlap=overlap, keep_timeline=True)
            bl = memory_blame(res, graph=g)
            assert bl.identity_ok(), f"seed={seed} overlap={overlap}"
            if res.peak_bytes > 0:
                assert bl.tensors
            for t in bl.tensors:
                assert t.bytes > 0


def test_memory_diff_identity():
    """memory_diff terms fsum to the IEEE peak difference bit-exactly —
    including when per-run class sums carry a rounding residual (float
    byte sizes)."""
    saw_nonzero = False
    for seed in range(8):
        rng = random.Random(200 + seed)
        ga, gb = rand_graph(rng, 70), rand_graph(rng, 90)
        ra = simulate(ga, SYS, TOPO, keep_timeline=True)
        rb = simulate(gb, SYS, TOPO, keep_timeline=True)
        d = memory_diff(ra, rb, graph_a=ga, graph_b=gb)
        assert d.identity_ok()
        assert d.delta_peak == rb.peak_bytes - ra.peak_bytes
        saw_nonzero = saw_nonzero or d.delta_peak != 0.0
        # self-diff is exactly zero everywhere
        z = memory_diff(ra, ra, graph_a=ga, graph_b=ga)
        assert z.delta_peak == 0.0 and z.identity_ok()
        assert not z.gained and not z.lost
    assert saw_nonzero


def test_exact_sum_and_exact_peak_primitives():
    rng = random.Random(0)
    xs = [rng.uniform(-1e9, 1e9) for _ in range(500)]
    acc = ExactSum()
    for x in xs:
        acc.add(x)
    assert acc.value() == math.fsum(xs)
    # exact_peak: breakpoint max with a 0.0 floor, frees-before-allocs
    assert exact_peak([]) == 0.0
    assert exact_peak([(0.0, -5.0, 0), (1.0, 5.0, 0)]) == 0.0
    assert exact_peak([(0.0, 3.0, 0), (1.0, -3.0, 0), (1.0, 2.0, 1)]) == 3.0


# ---------------------------------------------------------------------------
# proxy relation (satellite: peak_bytes vs peak_memory_proxy)
# ---------------------------------------------------------------------------

def int_chain(n=10):
    """Integer byte sizes + strictly positive durations: the regime where
    the documented proxy equality is exact."""
    g = chakra.Graph()
    prev = None
    rng = random.Random(5)
    for i in range(n):
        deps = [prev] if prev is not None else []
        if i % 3 == 2:
            prev = g.add(f"c{i}", chakra.COMM_COLL, deps=deps,
                         comm_kind="all-gather", comm_bytes=float(2 ** 20),
                         out_bytes=float(rng.randint(1, 64) * 1024),
                         group=list(range(8)))
        else:
            prev = g.add(f"n{i}", chakra.COMP, deps=deps, flops=1e9,
                         bytes=1e6,
                         out_bytes=float(rng.randint(1, 64) * 1024))
    return g


def test_analytic_peak_equals_proxy():
    g = int_chain(12)
    assert simulate_analytic(g, SYS, TOPO).peak_bytes == peak_memory_proxy(g)


def test_no_overlap_out_bytes_peak_equals_proxy():
    """Under overlap=False the engine visits the canonical topo order, so
    its out_bytes-only occupancy peak equals the static proxy exactly and
    its full peak (which adds transient comm buffers) is >= it."""
    g = int_chain(14)
    res = simulate(g, SYS, TOPO, overlap=False, keep_timeline=True)
    tensors_only = [e for e in res.mem_events if e[2] >= 0]
    assert exact_peak(tensors_only) == peak_memory_proxy(g)
    assert res.peak_bytes >= peak_memory_proxy(g)


# ---------------------------------------------------------------------------
# mem_events plumbing
# ---------------------------------------------------------------------------

def test_mem_events_gated_on_keep_timeline():
    g = chain_graph(4)
    lean = simulate(g, SYS, TOPO)
    assert lean.mem_events is None
    with pytest.raises(ValueError, match="keep_timeline"):
        memory_timeline(lean, graph=g)
    full = simulate(g, SYS, TOPO, keep_timeline=True)
    assert full.mem_events
    assert lean.peak_bytes == full.peak_bytes      # same exact scan
    d = full.as_dict()
    assert "mem_events" not in d and "timeline" not in d


def test_comm_transients_encoded_as_complement_ids():
    g = chain_graph(4)
    res = simulate(g, SYS, TOPO, keep_timeline=True)
    neg = [e for e in res.mem_events if e[2] < 0]
    assert neg, "all-gathers must record transient comm buffers"
    for t, delta, nid in neg:
        assert g.node(~nid).type == chakra.COMM_COLL


# ---------------------------------------------------------------------------
# objectives + OOM-aware search
# ---------------------------------------------------------------------------

def test_objective_validation_lists_known_names():
    from repro.search.objectives import (KNOWN_OBJECTIVES,
                                         validate_objectives)
    validate_objectives(("total_time", "peak_memory_bytes"))
    with pytest.raises(ValueError) as ei:
        validate_objectives(("total_tiem",))
    assert "total_tiem" in str(ei.value)
    for name in ("total_time", "peak_bytes", "expected_goodput"):
        assert name in KNOWN_OBJECTIVES
        assert name in str(ei.value)


def test_searchrun_rejects_typo_objective_up_front():
    from repro.core.dse import Knob
    from repro.search.run import SearchRun
    with pytest.raises(ValueError, match="unknown objective"):
        SearchRun(lambda cfg: chain_graph(2), SYS,
                  [Knob("prefetch", [0, 2])], objectives=("total_tiem",))


def test_peak_memory_bytes_objective_is_schedule_aware():
    from repro.search.objectives import trial_objectives
    g = chain_graph(6)
    res = simulate(g, SYS, TOPO)
    vals = trial_objectives(res, ("peak_memory_bytes", "peak_memory_proxy"),
                            graph=g)
    assert vals["peak_memory_bytes"] == res.peak_bytes
    assert vals["peak_memory_proxy"] == peak_memory_proxy(g)


def test_oom_infeasible_trials_recorded_not_crashed():
    """An hbm_bytes capacity knob makes over-budget trials fail cleanly:
    recorded with an OOMInfeasible error, excluded from best / full /
    Pareto, while feasible trials complete normally."""
    from repro.core.dse import Knob, OOMInfeasible, evaluate
    from repro.search.run import SearchRun
    g = chain_graph(6)
    with pytest.raises(OOMInfeasible, match="exceeds hbm_bytes"):
        evaluate(g, SYS, {"hbm_bytes": 1e3})
    evaluate(g, SYS, {"hbm_bytes": 1e15})          # feasible: no raise

    knobs = [Knob("prefetch", [0, 2]),
             Knob("hbm_bytes", [1e3, 1e15], layer="hardware")]
    r = SearchRun(lambda cfg: chain_graph(6), SYS, knobs, strategy="grid",
                  budget=4, objectives=("total_time",)).run()
    assert len(r.trials) == 4
    failed = r.failed_trials
    assert len(failed) == 2
    for t in failed:
        assert t.error.startswith("OOMInfeasible:")
        assert t.config["hbm_bytes"] == 1e3
    assert len(r.full_trials) == 2
    assert all(t.config["hbm_bytes"] == 1e15 for t in r.pareto_trials())
    assert r.best is not None and r.best.ok


def test_rank_profile_hbm_bytes_is_capacity_only():
    """A capacity-only profile is still 'default': it must not affect
    timing or break the symmetric/coalesced path."""
    p = RankProfile(hbm_bytes=96e9)
    assert p.is_default()
    g = chain_graph(4)
    ref = simulate(g, SYS, TOPO, keep_timeline=True)
    cr = simulate_cluster(g, SYS, TOPO, n_ranks=4,
                          rank_profiles={r: p for r in range(4)},
                          keep_timeline=True)
    assert cr.n_classes == 1
    assert cr.step_time == ref.total_time
    assert cr.rank_result(0).peak_bytes == ref.peak_bytes


# ---------------------------------------------------------------------------
# trace counters, report, gauges
# ---------------------------------------------------------------------------

def test_memory_counters_and_chrome_export(tmp_path):
    g = chain_graph(4)
    res = simulate(g, SYS, TOPO, keep_timeline=True)
    evs = memory_counters(res, graph=g)
    assert evs and all(e["ph"] == "C" and e["name"] == "memory_bytes"
                       for e in evs)
    classes = set().union(*(e["args"].keys() for e in evs))
    assert "comm" in classes

    path = tmp_path / "mem_trace.json"
    trace = export_memory_trace(res, str(path), graph=g)
    on_disk = json.loads(path.read_text())
    assert on_disk == trace
    counters = [e for e in on_disk["traceEvents"] if e.get("ph") == "C"
                and e.get("name") == "memory_bytes"]
    assert counters
    meta = [e for e in on_disk["traceEvents"] if e.get("ph") == "M"]
    assert any(e.get("name") == "process_sort_index" for e in meta)


def test_memory_gauges_and_report_section(tmp_path, capsys):
    from repro.obs import record as obs
    from repro.obs.report import main as report_main, render_memory
    g = chain_graph(4)
    res = simulate(g, SYS, TOPO, keep_timeline=True)
    cap = 2 * res.peak_bytes
    obs.enable()
    try:
        tl = memory_timeline(res, graph=g, hbm_bytes=cap)
        metrics = obs.metrics_dict()
    finally:
        obs.disable()
    assert metrics["gauges"]["memory.rank0.peak_bytes"] == tl.peak_bytes
    text = render_memory(metrics)
    assert "rank 0" in text and "of HBM" in text

    mpath = tmp_path / "metrics.json"
    mpath.write_text(json.dumps(metrics))
    assert report_main(["report", str(mpath), "--memory"]) == 0
    out = capsys.readouterr().out
    assert "memory occupancy" in out and ">90% for" in out
    # utilization / time_above helpers agree with what was published
    rm = tl.ranks[0]
    assert rm.utilization() == pytest.approx(0.5)
    assert metrics["gauges"]["memory.rank0.time_at_90pct"] == \
        rm.time_above(0.9 * cap)


# ---------------------------------------------------------------------------
# faults: elastic rescale inflates survivor occupancy
# ---------------------------------------------------------------------------

def test_horizon_survivor_mem_inflation():
    from repro.faults.horizon import simulate_horizon
    from repro.faults.scenario import CheckpointPolicy, FaultEvent, \
        FaultScenario
    sysc = SystemConfig(chips=4, topology="switch")
    g = chain_graph(4, group=4)
    pol = CheckpointPolicy(interval=10, write_cost=1e-4, restore_cost=1e-4)
    sc = FaultScenario(events=[FaultEvent(time=0.01, kind="fail_stop",
                                          rank=1, duration=0.5)],
                       horizon=2.0, n_ranks=4)
    hr = simulate_horizon(g, sysc, sc, pol, n_ranks=4, n_steps=200)
    assert hr.survivor_mem_inflation == pytest.approx(4.0 / 3.0)
    assert "survivor_mem_inflation" in hr.as_dict()
    # a provisioned spare absorbs the failure: no rescale, no inflation
    hr2 = simulate_horizon(g, sysc, sc, pol, n_ranks=4, n_steps=200,
                           spare_ranks=1)
    assert hr2.survivor_mem_inflation == 1.0
    # fault-free horizon is the 1.0 baseline
    hr3 = simulate_horizon(g, sysc, FaultScenario(events=[], horizon=1.0,
                                                  n_ranks=4),
                           pol, n_ranks=4, n_steps=50)
    assert hr3.survivor_mem_inflation == 1.0
