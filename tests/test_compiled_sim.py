"""Compiled simulator substrate: bit-exact equivalence vs the reference
engine on randomized DAGs, batched/duration-override paths, cache
invalidation, overlap=False accounting, and parallel DSE determinism."""
import random

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import (build_topology, compile_graph, simulate,
                                  simulate_batch, straggler_analysis)
from repro.core.costmodel.simulator import _simulate_reference
from repro.core.dse import Knob, explore

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)

FIELDS = ("total_time", "compute_time", "comm_time", "exposed_comm",
          "peak_bytes", "n_nodes")


def rand_graph(rng: random.Random, n: int) -> chakra.Graph:
    """Random DAG over all node types, with duplicate/absent attrs, dup
    edges across dep kinds, and varying fanin."""
    g = chakra.Graph()
    for i in range(n):
        k = min(i, 4)
        deps = rng.sample(range(i), rng.randint(0, k)) if i else []
        ctrl = rng.sample(range(i), rng.randint(0, k)) if i else []
        if deps and rng.random() < 0.3:
            ctrl = ctrl + [deps[0]]          # same edge in both kinds
        r = rng.random()
        if r < 0.5 or i == 0:
            g.add(f"n{i}", chakra.COMP, deps=deps, ctrl_deps=ctrl,
                  flops=rng.uniform(0, 1e9), bytes=rng.uniform(0, 1e8),
                  out_bytes=rng.choice([0.0, rng.uniform(1, 100)]))
        elif r < 0.75:
            g.add(f"c{i}", chakra.COMM_COLL, deps=deps, ctrl_deps=ctrl,
                  comm_kind=rng.choice(["all-gather", "all-reduce",
                                        "reduce-scatter"]),
                  comm_bytes=rng.uniform(1, 1e7), out_bytes=8.0,
                  group=list(range(rng.choice([2, 4, 8, 16]))))
        elif r < 0.85:
            g.add(f"s{i}", rng.choice([chakra.COMM_SEND, chakra.COMM_RECV]),
                  deps=deps, ctrl_deps=ctrl, comm_bytes=rng.uniform(1, 1e6))
        else:
            g.add(f"m{i}", chakra.MEM, deps=deps, ctrl_deps=ctrl,
                  out_bytes=4.0)
    return g


def assert_identical(rc, rr):
    for f in FIELDS:
        assert getattr(rc, f) == getattr(rr, f), \
            f"{f}: {getattr(rc, f)!r} != {getattr(rr, f)!r}"
    assert rc.timeline == rr.timeline


def test_equivalence_on_randomized_dags():
    """>= 50 random DAGs x (overlap on/off) x (with/without duration
    overrides), all SimResult fields exactly equal, timeline included."""
    for seed in range(55):
        rng = random.Random(seed)
        g = rand_graph(rng, rng.randint(5, 120))
        durs = None
        if seed % 2 == 0:
            picks = rng.sample(range(len(g)), max(1, len(g) // 4))
            durs = {nid: rng.uniform(0.0, 1e-3) for nid in picks}
        for overlap in (True, False):
            rc = simulate(g, SYS, TOPO, overlap=overlap, durations=durs,
                          keep_timeline=True)
            rr = _simulate_reference(g, SYS, TOPO, overlap=overlap,
                                     durations=durs, keep_timeline=True)
            assert_identical(rc, rr)


def test_equivalence_other_algos_and_derates():
    for seed in (1000, 1001, 1002):
        g = rand_graph(random.Random(seed), 60)
        for algo in ("ring", "hd"):
            for derate in (0.4, 1.0):
                rc = simulate(g, SYS, TOPO, algo=algo,
                              compute_derate=derate, keep_timeline=True)
                rr = _simulate_reference(g, SYS, TOPO, algo=algo,
                                         compute_derate=derate,
                                         keep_timeline=True)
                assert_identical(rc, rr)


def test_overlap_false_accounting():
    """Regression: without overlap, exposed/compute/comm must still be
    meaningful (busy time split by node type, not by stream)."""
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=0.6e9)               # 1 ms at derate .6
    c = g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-gather",
              comm_bytes=1e8, group=list(range(16)))
    g.add("b", chakra.COMP, deps=[c], flops=0.6e9)
    sysc = SystemConfig(chips=16, peak_flops=1e12, hbm_bw=1e12)
    r = simulate(g, sysc, overlap=False)
    assert r.compute_time == pytest.approx(2e-3)           # COMP only
    assert r.comm_time > 0.0
    assert r.exposed_comm == pytest.approx(r.total_time - r.compute_time)
    assert r.exposed_comm > 0.0                            # was always 0
    # serial chain: both engines agree and total = comp + comm
    assert r.total_time == pytest.approx(r.compute_time + r.comm_time)
    assert_identical(r, _simulate_reference(g, sysc, overlap=False))


def test_simulate_batch_matches_individual_calls():
    g = rand_graph(random.Random(7), 80)
    cg = compile_graph(g)
    base = cg.durations(SYS, TOPO)
    overrides = [None,
                 {0: base[0] * 2.0},
                 {nid: base[nid] * 1.5 for nid in range(0, len(g), 3)}]
    batch = simulate_batch(g, SYS, overrides, topo=TOPO)
    for ov, rb in zip(overrides, batch):
        ri = simulate(g, SYS, TOPO, durations=ov)
        for f in FIELDS:
            assert getattr(rb, f) == getattr(ri, f)


def test_straggler_analysis_batched_matches_reference_math():
    """straggler_analysis now runs the cluster-barrier model (one slowed
    rank gating collectives); on this graph the straggler is the last
    arrival at every barrier it joins, so its timeline — and the cluster
    step — degenerates to exactly the old single-timeline proxy, which the
    reference engine cross-checks bit-for-bit."""
    g = rand_graph(random.Random(11), 60)
    rows = straggler_analysis(g, SYS, TOPO, slowdowns=(1.0, 1.5, 2.0))
    assert rows[0]["slowdown_realized"] == pytest.approx(1.0)
    assert rows[-1]["step_time"] >= rows[0]["step_time"]
    assert rows[-1]["slowest_rank"] == 0 and rows[-1]["n_ranks"] == 16
    # cross-check one factor against a hand-built reference-engine run
    from repro.core.costmodel.simulator import node_duration
    dur = {n.id: node_duration(n, SYS, TOPO) * 1.5
           for n in g.nodes if n.type == chakra.COMP}
    ref = _simulate_reference(g, SYS, TOPO, durations=dur).total_time
    assert rows[1]["step_time"] == ref


def test_compiled_cache_invalidation_on_mutation():
    g = rand_graph(random.Random(3), 40)
    r1 = simulate(g, SYS, TOPO)
    assert compile_graph(g) is compile_graph(g)       # cache hit
    cg_before = compile_graph(g)
    tail = g.add("late", chakra.COMP, deps=[0], flops=1e12, bytes=0.0)
    assert compile_graph(g) is not cg_before          # token changed
    r2 = simulate(g, SYS, TOPO)
    assert r2.n_nodes == r1.n_nodes + 1
    assert r2.total_time > r1.total_time
    assert_identical(simulate(g, SYS, TOPO, keep_timeline=True),
                     _simulate_reference(g, SYS, TOPO, keep_timeline=True))
    # repeated identical calls hit the result cache but hand back a fresh
    # instance each time — mutating a returned result must not poison it
    ra = simulate(g, SYS, TOPO)
    assert ra is not simulate(g, SYS, TOPO)
    ra.total_time = -1.0
    assert simulate(g, SYS, TOPO).total_time == r2.total_time
    # ... and a changed config misses the cache
    assert simulate(g, SYS, TOPO, compute_derate=0.5).total_time != \
        simulate(g, SYS, TOPO).total_time
    del tail


def _dse_graph(n_layers=8, comm_mb=8.0):
    g = chakra.Graph()
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=comm_mb * 1e6, out_bytes=comm_mb * 1e6,
                   group=list(range(16)))
        deps = [ag] + ([prev] if prev is not None else [])
        prev = g.add(f"comp{i}", chakra.COMP, deps=deps, flops=5e10,
                     out_bytes=1e6)
        g.add(f"ar{i}", chakra.COMM_COLL, deps=[prev],
              comm_kind="all-reduce", comm_bytes=2e6, group=list(range(16)))
    return g


def test_explore_parallel_matches_serial():
    def graph_for(cfg):
        return _dse_graph(cfg.get("layers", 8))

    knobs = [
        Knob("layers", [4, 8], layer="workload"),
        Knob("fsdp_sync", [True, False], layer="software"),
        Knob("prefetch", [0, 2, 8], layer="software"),
        Knob("bucket_bytes", [0, 8e6], layer="software"),
        Knob("link_bw", [25e9, 100e9], layer="hardware"),
    ]
    serial = explore(graph_for, SYS, knobs)
    par = explore(graph_for, SYS, knobs, parallel=4)
    assert len(serial) == len(par) == 2 * 2 * 3 * 2 * 2
    for a, b in zip(serial, par):
        assert a.config == b.config
        assert a.objective == b.objective
        for f in FIELDS:
            assert getattr(a.result, f) == getattr(b.result, f)


def test_explore_memoizes_software_passes():
    applied = []
    import repro.core.dse as dse_mod
    orig = dse_mod.apply_software_knobs

    def counting(g, cfg):
        applied.append(dict(cfg))
        return orig(g, cfg)

    dse_mod.apply_software_knobs = counting
    try:
        knobs = [Knob("prefetch", [0, 2], layer="software"),
                 Knob("link_bw", [25e9, 50e9, 100e9], layer="hardware")]
        trials = explore(lambda cfg: _dse_graph(6), SYS, knobs)
    finally:
        dse_mod.apply_software_knobs = orig
    assert len(trials) == 6
    assert len(applied) == 2          # once per distinct software config
