"""Per-rank Chakra ET export + straggler cost-model analysis."""
import json
import os

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import build_topology, simulate
from repro.core.costmodel.simulator import straggler_analysis
from repro.core.export import expand_ranks, write_et


def _spmd_graph(num_ranks=8, group_size=4):
    g = chakra.Graph(meta={"num_partitions": num_ranks})
    a = g.add("mm0", chakra.COMP, flops=1e9, out_bytes=1e6)
    c = g.add("ar0", chakra.COMM_COLL, deps=[a], comm_kind="all-reduce",
              comm_bytes=1e6, group=list(range(group_size)),
              group_size=group_size, n_groups=num_ranks // group_size,
              out_bytes=1e6)
    g.add("mm1", chakra.COMP, deps=[c], flops=1e9, out_bytes=1e6)
    return g


def test_expand_ranks_rank_local_groups():
    g = _spmd_graph()
    per_rank = expand_ranks(g)
    assert len(per_rank) == 8
    for rank, gr in enumerate(per_rank):
        assert gr.meta["rank"] == rank
        coll = gr.by_type(chakra.COMM_COLL)[0]
        assert rank in coll.attrs["group"]
        assert len(coll.attrs["group"]) == 4
    # ranks 0-3 share a group; 4-7 the other
    g0 = per_rank[0].by_type(chakra.COMM_COLL)[0].attrs["group"]
    g5 = per_rank[5].by_type(chakra.COMM_COLL)[0].attrs["group"]
    assert g0 == [0, 1, 2, 3] and g5 == [4, 5, 6, 7]


def test_expand_ranks_strided_groups():
    g = chakra.Graph(meta={"num_partitions": 8})
    a = g.add("x", chakra.COMP, flops=1, out_bytes=8)
    g.add("ag", chakra.COMM_COLL, deps=[a], comm_kind="all-gather",
          comm_bytes=64, group=[0, 2, 4, 6], group_size=4, n_groups=2,
          out_bytes=64)
    per_rank = expand_ranks(g)
    assert per_rank[3].by_type(chakra.COMM_COLL)[0].attrs["group"] == \
        [1, 3, 5, 7]


def test_p2p_expansion_per_rank():
    g = _spmd_graph()
    per_rank = expand_ranks(g, ranks=[1], p2p_algo="ring")
    gr = per_rank[0]
    sends = gr.by_type(chakra.COMM_SEND)
    recvs = gr.by_type(chakra.COMM_RECV)
    # ring all-reduce over 4 ranks: 2(n-1) = 6 rounds, one send + one recv
    # touching rank 1 per round
    assert len(sends) + len(recvs) == 12
    gr.validate()


def test_write_et_files(tmp_path):
    g = _spmd_graph()
    paths = write_et(g, str(tmp_path), ranks=[0, 3, 7])
    assert len(paths) == 3
    man = json.load(open(os.path.join(tmp_path, "manifest.json")))
    assert man["ranks"] == [0, 3, 7]
    g0 = chakra.Graph.load(paths[0])
    assert g0.meta["rank"] == 0
    g0.validate()


def test_straggler_analysis_monotone_and_backup():
    g = _spmd_graph()
    sysc = SystemConfig(chips=8)
    rows = straggler_analysis(g, sysc, build_topology(sysc, 8),
                              slowdowns=(1.0, 1.5, 2.0, 4.0))
    times = [r["step_time"] for r in rows]
    assert times == sorted(times)
    assert not rows[0]["backup_wins"]          # no straggler: backup is waste
    assert rows[-1]["backup_wins"]             # 4x straggler: spare pays off
