"""MPMD engine plumbing: program construction, ragged-group diagnostics
(``ClusterProgramError``), the pipeline-stage splitter, DSE pipeline knobs
and the workload-zoo conformance sweep (every registry arch must build,
split into pipeline stages and run on the MPMD engine with consistent
stage/collective accounting)."""
import random

import pytest

from repro.configs.base import SystemConfig
from repro.configs.registry import ARCH_NAMES, get_config
from repro.configs.workload import workload_graph
from repro.core import chakra, dse
from repro.core.convert import split_pipeline_stages
from repro.core.costmodel import (ClusterProgramError, MPMDProgram,
                                  build_topology, collective_fingerprint,
                                  collective_time, simulate, simulate_cluster)

from test_compiled_sim import rand_graph

SYS = SystemConfig(chips=8, topology="switch")
TOPO = build_topology(SYS)


def chain_graph(group, n_colls=1, kind="all-reduce"):
    """comp -> collective(s) over `group` -> comp."""
    g = chakra.Graph()
    prev = g.add("a", chakra.COMP, flops=1.0)
    for i in range(n_colls):
        prev = g.add(f"c{i}", chakra.COMM_COLL, deps=[prev], comm_kind=kind,
                     comm_bytes=1e6, group=list(group))
    g.add("b", chakra.COMP, deps=[prev], flops=1.0)
    return g


# ---------------------------------------------------------------------------
# program construction + diagnostics
# ---------------------------------------------------------------------------

def test_program_construction_and_dedup():
    g1, g2 = chain_graph([0, 1]), chain_graph([0, 1])
    prog = MPMDProgram([g1, g1, g2, g2])
    assert prog.n_ranks == 4 and prog.n_graphs == 2
    assert prog.graph_for(0) is g1 and prog.graph_for(3) is g2
    # dict form must be dense
    assert MPMDProgram({0: g1, 1: g2}).n_ranks == 2
    with pytest.raises(ValueError, match="densely"):
        MPMDProgram({0: g1, 2: g2})
    with pytest.raises(ValueError):
        MPMDProgram([])
    with pytest.raises(TypeError):
        MPMDProgram([g1, "not a graph"])
    with pytest.raises(ValueError, match="disagrees"):
        simulate_cluster(prog, SYS, TOPO, n_ranks=8)


def test_ragged_group_raises_cluster_program_error():
    """Regression (ISSUE 5 bugfix): a group that claims a rank whose graph
    omits the collective instance must raise a ClusterProgramError naming
    the rank, fingerprint and program index — not KeyError or a hang."""
    gA = chain_graph([0, 1], n_colls=2)
    gB = chain_graph([0, 1], n_colls=1)       # rank 1 misses instance 1
    with pytest.raises(ClusterProgramError) as ei:
        simulate_cluster(MPMDProgram([gA, gB]), SYS, TOPO)
    e = ei.value
    assert e.rank == 1
    assert e.index == 1
    assert e.fingerprint == collective_fingerprint("all-reduce", [0, 1])
    assert "rank 1" in str(e) and "all-reduce|0,1" in str(e)
    # a rank with NO instance at all reports index 0
    gC = chakra.Graph()
    gC.add("solo", chakra.COMP, flops=1.0)
    with pytest.raises(ClusterProgramError) as ei:
        simulate_cluster(MPMDProgram([gA, gC]), SYS, TOPO)
    assert ei.value.rank == 1 and ei.value.index == 0


def test_mismatched_collective_kinds_raise():
    gA = chain_graph([0, 1], kind="all-reduce")
    gB = chain_graph([0, 1], kind="all-gather")
    with pytest.raises(ClusterProgramError, match="mismatched collective"):
        simulate_cluster(MPMDProgram([gA, gB]), SYS, TOPO)


def test_nonmember_rank_runs_collective_locally():
    """Ragged participation: a collective whose group omits a rank never
    blocks that rank, even if the node appears in its graph."""
    gA = chain_graph([0, 1])
    gB = chain_graph([0, 1])                   # rank 2 carries the node...
    prog = MPMDProgram([gA, gA, gB])           # ...but group = [0, 1]
    a_nid = 0
    rd = {0: {a_nid: 5e-3}}                    # straggle a group member
    cr = simulate_cluster(prog, SYS, TOPO, rank_durations=rd,
                          keep_timeline=True)
    # rank 2 never waits for the [0,1] barrier
    assert cr.barrier_wait[2] == 0.0
    assert cr.rank_result(2).total_time < cr.rank_result(1).total_time
    # ranks 0/1 synchronize
    e0 = next(s for s in cr.rank_spans(0) if s.name == "c0")
    e1 = next(s for s in cr.rank_spans(1) if s.name == "c0")
    assert e0.end == e1.end


def test_mpmd_asymmetric_pools_step_accounting():
    """Two pools running different programs, stitched by one cross-pool
    collective: the step is gated by the slower pool on every member."""
    group = [0, 1, 2, 3]
    g_train = chakra.Graph()
    a = g_train.add("fwd", chakra.COMP, flops=5e10)
    g_train.add("sync", chakra.COMM_COLL, deps=[a], comm_kind="all-reduce",
                comm_bytes=4e6, group=group)
    g_serve = chakra.Graph()
    b = g_serve.add("decode", chakra.COMP, flops=5e8)
    g_serve.add("sync", chakra.COMM_COLL, deps=[b], comm_kind="all-reduce",
                comm_bytes=4e6, group=group)
    prog = MPMDProgram([g_train, g_train, g_serve, g_serve])
    cr = simulate_cluster(prog, SYS, TOPO, keep_timeline=True)
    assert cr.n_classes == 2
    coll = collective_time("all-reduce", 4e6, group, TOPO)
    slow_arrival = max(s.start for r in group for s in cr.rank_spans(r)
                      if s.name == "sync")
    for r in group:
        sp = next(s for s in cr.rank_spans(r) if s.name == "sync")
        assert sp.end == slow_arrival + coll
    # the fast serving pool carries the barrier wait
    assert cr.barrier_wait[2] > 0.0 and cr.barrier_wait[0] == 0.0


# ---------------------------------------------------------------------------
# pipeline splitter
# ---------------------------------------------------------------------------

def test_splitter_structure_and_accounting():
    g = workload_graph(get_config("gemma3-4b", smoke=True),
                       batch_tokens=512, ranks=8)
    for S in (2, 3, 4, 8):
        prog = split_pipeline_stages(g, S)
        assert prog.n_ranks == S and prog.n_graphs == S
        meta = prog.meta
        assert meta["num_stages"] == S and meta["source_nodes"] == len(g)
        assert sorted(set(meta["stage_of"])) == list(range(S))
        # node accounting: every source node lands in exactly one stage;
        # each cross-stage transfer adds one send + one recv
        total = sum(len(prog.graph_for(r)) for r in range(S))
        assert total == len(g) + 2 * meta["p2p_pairs"]
        # collective accounting: original collectives survive per stage,
        # plus the p2p pairs
        n_colls = sum(len(prog.graph_for(r).by_type(chakra.COMM_COLL))
                      for r in range(S))
        n_src = len(g.by_type(chakra.COMM_COLL))
        assert n_colls == n_src + 2 * meta["p2p_pairs"]
        for r in range(S):
            sg = prog.graph_for(r)
            sg.validate()
            # rewritten groups: stage-internal collectives span exactly the
            # stage's (single) rank; p2p groups pair two stage ranks
            for n in sg.by_type(chakra.COMM_COLL):
                if n.attrs["comm_kind"] == "p2p":
                    assert len(n.attrs["group"]) == 2
                    assert r in n.attrs["group"]
                else:
                    assert n.attrs["group"] == [r]


def test_splitter_replicas_and_dp_groups():
    g = workload_graph(get_config("qwen3-8b", smoke=True),
                       batch_tokens=512, ranks=8)
    prog = split_pipeline_stages(g, 2, replicas=2)
    assert prog.n_ranks == 4 and prog.n_graphs == 4
    # stage-major layout: stage s owns ranks [s*R, (s+1)*R)
    for r in range(4):
        sg = prog.graph_for(r)
        s = sg.meta["pipeline_stage"]
        assert r in (s * 2, s * 2 + 1)
        for n in sg.by_type(chakra.COMM_COLL):
            if n.attrs["comm_kind"] != "p2p":
                assert n.attrs["group"] == [s * 2, s * 2 + 1]
    cr = simulate_cluster(prog, SYS, TOPO)
    assert cr.n_ranks == 4
    assert cr.step_time > 0.0


def test_splitter_stage_boundaries_respect_dataflow():
    g = rand_graph(random.Random(7), 60)
    prog = split_pipeline_stages(g, 4, assignment="nodes")
    stage_of = prog.meta["stage_of"]
    for n in g.nodes:
        for d in n.all_deps:
            assert stage_of[d] <= stage_of[n.id], (d, n.id)


def test_splitter_explicit_assignment_and_errors():
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=1.0, out_bytes=64.0)
    b = g.add("b", chakra.COMP, deps=[a], flops=1.0)
    c = g.add("c", chakra.COMP, deps=[b], flops=1.0)
    prog = split_pipeline_stages(g, 2, assignment=[0, 0, 1])
    assert prog.meta["assignment"] == "explicit"
    assert prog.meta["stage_of"] == [0, 0, 1]
    assert prog.meta["p2p_pairs"] == 1
    with pytest.raises(ValueError, match="backward"):
        split_pipeline_stages(g, 2, assignment=[1, 0, 1])
    with pytest.raises(ValueError, match="omits"):
        split_pipeline_stages(g, 2, assignment={0: 0, 2: 1})
    with pytest.raises(ValueError, match="covers 2"):
        split_pipeline_stages(g, 2, assignment=[0, 1])
    with pytest.raises(ValueError, match="empty"):
        split_pipeline_stages(g, 2, assignment=[0, 0, 0])
    with pytest.raises(ValueError, match="outside"):
        split_pipeline_stages(g, 2, assignment=[0, 0, 5])
    with pytest.raises(ValueError):
        split_pipeline_stages(g, 9)            # more stages than nodes
    with pytest.raises(ValueError, match="policy"):
        split_pipeline_stages(g, 2, assignment="bogus")


def test_pipeline_stage_barrier_timing():
    """2-stage split of a two-layer chain: stage 1 parks at its recv until
    stage 0's send arrives — the p2p pair is a real cross-rank barrier."""
    g = chakra.Graph()
    f0 = g.add("f0", chakra.COMP, flops=1.0, out_bytes=1e6)
    g.add("f1", chakra.COMP, deps=[f0], flops=1.0, out_bytes=1e6)
    prog = split_pipeline_stages(g, 2, assignment=[0, 1])
    rd = {0: {0: 3e-3}}                        # slow stage 0's compute
    cr = simulate_cluster(prog, SYS, TOPO, rank_durations=rd,
                          keep_timeline=True)
    send = next(s for s in cr.rank_spans(0) if s.name.startswith("send"))
    recv = next(s for s in cr.rank_spans(1) if s.name.startswith("recv"))
    assert send.start == 3e-3                  # after slowed f0
    assert recv.start == 0.0                   # stage 1 arrives immediately
    assert recv.end == send.end                # released by the send
    assert cr.barrier_wait[1] == pytest.approx(3e-3)
    f1 = next(s for s in cr.rank_spans(1) if s.name == "f1")
    assert f1.start >= recv.end


def test_pipeline_p2p_pairs_never_cross_wires():
    """Regression: two transfers on the same (src, dst) channel whose
    sends complete in the opposite order from their creation must NOT
    cross-pair — every consumer starts only after its own producer's send
    (the FIFO ctrl chain pins both sides to creation order)."""
    g = chakra.Graph()
    # producer A: huge COMP (finishes late); producer B: stage-local
    # collective committed on the comm stream at t~0 (finishes early)
    a = g.add("A", chakra.COMP, flops=1e15, out_bytes=1e6)
    b = g.add("B", chakra.COMM_COLL, comm_kind="all-reduce", comm_bytes=1e6,
              out_bytes=1e6, group=[0])
    ca = g.add("cA", chakra.COMP, deps=[a], flops=1.0)
    cb = g.add("cB", chakra.COMP, deps=[b], flops=1.0)
    prog = split_pipeline_stages(g, 2, assignment=[0, 0, 1, 1])
    cr = simulate_cluster(prog, SYS, TOPO, keep_timeline=True)
    fin = {s.name: s.end for s in cr.rank_spans(0)}
    starts = {s.name: s.start for s in cr.rank_spans(1)}
    assert starts["cA"] >= fin["A"], (starts["cA"], fin["A"])
    assert starts["cB"] >= fin["B"], (starts["cB"], fin["B"])
    # the channel is FIFO: sends commit in creation order on rank 0
    sends = [s for s in cr.rank_spans(0) if s.name.startswith("send")]
    assert [s.name for s in sorted(sends, key=lambda s: s.start)] \
        == [s.name for s in sends]


# ---------------------------------------------------------------------------
# DSE pipeline knobs
# ---------------------------------------------------------------------------

def test_dse_num_stages_knob_routes_to_mpmd():
    g = workload_graph(get_config("granite-3-8b", smoke=True),
                       batch_tokens=512, ranks=8)
    trials = dse.explore(lambda cfg: g, SYS,
                         [dse.Knob("num_stages", [1, 2, 4],
                                   layer="software")])
    assert len(trials) == 3
    by_ns = {t.config["num_stages"]: t for t in trials}
    # the 1-stage trial is the plain simulate() path, bit-identical
    assert by_ns[1].result.total_time == simulate(g, SYS, TOPO).total_time
    assert "n_classes" not in by_ns[1].result.as_dict()
    for ns in (2, 4):
        d = by_ns[ns].result.as_dict()
        assert d["n_ranks"] == ns * (TOPO.n_ranks // ns)
    # stage_assignment is a sweepable knob too
    trials = dse.explore(
        lambda cfg: g, SYS,
        [dse.Knob("num_stages", [2], layer="software"),
         dse.Knob("stage_assignment", ["flops", "nodes"], layer="software")])
    assert len(trials) == 2
    assert {t.config["stage_assignment"] for t in trials} \
        == {"flops", "nodes"}


def test_dse_num_stages_cannot_exceed_cluster_ranks():
    """num_stages > cluster ranks would model phantom hardware (S ranks on
    a T-chip topology) and unfairly win any sweep — it must raise."""
    g = workload_graph(get_config("mamba2-780m", smoke=True),
                       batch_tokens=512, ranks=8)
    with pytest.raises(ValueError, match="exceeds the cluster"):
        dse.evaluate(g, SYS, {"num_stages": 16})
    with pytest.raises(ValueError, match="exceeds the cluster"):
        dse.evaluate(g, SYS, {"num_stages": 8, "cluster_ranks": 4})
    # uneven splits idle the remainder instead of inflating hardware
    r = dse.evaluate(g, SYS, {"num_stages": 3, "cluster_ranks": 8})
    assert r.as_dict()["n_ranks"] == 3 * (8 // 3)


def test_dse_pipeline_composes_with_hetero_knobs():
    g = workload_graph(get_config("mamba2-780m", smoke=True),
                       batch_tokens=512, ranks=8)
    r = dse.evaluate(g, SYS, {"num_stages": 2, "cluster_ranks": 8,
                              "slow_chip_ratio": 0.25})
    d = r.as_dict()
    assert d["n_ranks"] == 8
    nominal = dse.evaluate(g, SYS, {"num_stages": 2, "cluster_ranks": 8})
    assert r.step_time > nominal.step_time     # the slow chips bite


# ---------------------------------------------------------------------------
# workload-zoo conformance (ISSUE 5 satellite)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("arch", ARCH_NAMES)
def test_workload_zoo_pipeline_conformance(arch):
    """Every registry entry must build an analytic graph, split into 2
    pipeline stages, and run through the MPMD engine with consistent
    stage-count and collective-count accounting — a new config cannot
    silently break the splitter."""
    cfg = get_config(arch, smoke=True)
    g = workload_graph(cfg, batch_tokens=512, ranks=8)
    g.validate()
    assert len(g.by_type(chakra.COMM_COLL)) >= 2 * cfg.num_layers
    if cfg.num_experts:
        assert any(n.attrs["comm_kind"] == "all-to-all"
                   for n in g.by_type(chakra.COMM_COLL))
    base = simulate(g, SYS, TOPO)
    assert base.total_time > 0.0
    for replicas in (1, 2):
        prog = split_pipeline_stages(g, 2, replicas=replicas)
        assert prog.n_ranks == 2 * replicas
        assert sorted(set(prog.meta["stage_of"])) == [0, 1]
        total = sum(len(prog.graph_for(r)) for r in range(prog.n_ranks))
        assert total == replicas * (len(g) + 2 * prog.meta["p2p_pairs"])
        n_colls = sum(len(prog.graph_for(r).by_type(chakra.COMM_COLL))
                      for r in range(prog.n_ranks))
        assert n_colls == replicas * (len(g.by_type(chakra.COMM_COLL))
                                      + 2 * prog.meta["p2p_pairs"])
        cr = simulate_cluster(prog, SYS, TOPO)
        assert cr.n_ranks == prog.n_ranks
        assert cr.step_time > 0.0
        # per-stage p2p sends match recvs one-to-one
        sends = sum(1 for r in range(prog.n_ranks)
                    for n in prog.graph_for(r).by_type(chakra.COMM_COLL)
                    if n.attrs["comm_kind"] == "p2p"
                    and n.name.startswith("send"))
        recvs = sum(1 for r in range(prog.n_ranks)
                    for n in prog.graph_for(r).by_type(chakra.COMM_COLL)
                    if n.attrs["comm_kind"] == "p2p"
                    and n.name.startswith("recv"))
        assert sends == recvs == replicas * prog.meta["p2p_pairs"]
