"""End-to-end behaviour tests for the whole system (paper pipeline +
training/serving drivers on CPU)."""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# launch/mesh.py imports jax.sharding.AxisType, absent from jax 0.4.37, so
# the dryrun driver cannot even import in a fresh subprocess
_DRYRUN_SKIP = pytest.mark.skip(
    reason="pre-existing at seed: launch/mesh.py needs jax.sharding.AxisType "
           "(absent in jax 0.4.37) — see ROADMAP 'jax 0.4.37 compat'")


def _run(args, timeout=900):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    r = subprocess.run([sys.executable, "-m"] + args, capture_output=True,
                       text=True, env=env, timeout=timeout)
    assert r.returncode == 0, f"{args}:\n{r.stdout[-2000:]}\n{r.stderr[-3000:]}"
    return r.stdout


def test_train_driver_smoke(tmp_path):
    out = _run(["repro.launch.train", "--arch", "gemma3-4b", "--smoke",
                "--steps", "25", "--ckpt-every", "10", "--log-every", "5",
                "--ckpt-dir", str(tmp_path)])
    assert "done" in out
    m = json.load(open(tmp_path / "metrics.json"))
    assert m[-1]["loss"] < m[0]["loss"] + 0.1


def test_train_driver_fault_recovery(tmp_path):
    out = _run(["repro.launch.train", "--arch", "granite-3-8b", "--smoke",
                "--steps", "12", "--ckpt-every", "5", "--ckpt-dir",
                str(tmp_path), "--inject-fault-at", "7"])
    assert "retry" in out and "done" in out


def test_train_driver_resume(tmp_path):
    _run(["repro.launch.train", "--arch", "qwen3-8b", "--smoke", "--steps",
          "10", "--ckpt-every", "5", "--ckpt-dir", str(tmp_path)])
    out = _run(["repro.launch.train", "--arch", "qwen3-8b", "--smoke",
                "--steps", "14", "--ckpt-every", "5", "--ckpt-dir",
                str(tmp_path), "--resume"])
    assert "resumed from step 10" in out


def test_serve_driver_smoke():
    out = _run(["repro.launch.serve", "--arch", "mamba2-780m", "--smoke",
                "--batch", "2", "--prompt-len", "16", "--steps", "6"])
    assert "decode" in out and "tok/s" in out


@_DRYRUN_SKIP
def test_dryrun_single_cell_small_arch():
    """The dry-run entry point itself (512 fake devices, real cell)."""
    out = _run(["repro.launch.dryrun", "--arch", "seamless-m4t-medium",
                "--shape", "decode_32k", "--out",
                os.path.join("artifacts", "test_dryrun")])
    assert "OK" in out and "roofline" in out


@_DRYRUN_SKIP
def test_dryrun_skip_cell():
    out = _run(["repro.launch.dryrun", "--arch", "qwen3-8b", "--shape",
                "long_500k", "--out", os.path.join("artifacts", "test_dryrun")])
    assert "SKIPPED" in out
