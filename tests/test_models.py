"""Per-arch smoke tests: reduced configs, one forward/train step on CPU,
shape + finiteness assertions; prefill/decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCH_NAMES, get_config
from repro.models import Ctx, build_model


def _mk(name):
    cfg = get_config(name, smoke=True)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    return cfg, m, params


def _batch(cfg, m, B=2, S=48):
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S + 1), 0,
                                cfg.vocab_size)
    mem = None
    ml = m.memory_len()
    if ml:
        mem = jax.random.normal(jax.random.PRNGKey(2), (B, ml, cfg.d_model),
                                jnp.bfloat16)
    return tokens, mem


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_forward_shapes_and_finite(name):
    cfg, m, params = _mk(name)
    tokens, mem = _batch(cfg, m)
    ctx = Ctx()
    logits, aux = m.apply(params, tokens[:, :-1], ctx, memory=mem)
    assert logits.shape == (2, 48, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_one_train_step(name):
    from repro.configs.base import ParallelConfig
    from repro.train import OptConfig, init_train_state, make_train_step
    cfg, m, params = _mk(name)
    tokens, mem = _batch(cfg, m)
    batch = {"tokens": tokens[:, :-1], "labels": tokens[:, 1:]}
    if mem is not None:
        batch["memory"] = mem
    state = init_train_state(m, jax.random.PRNGKey(0), ParallelConfig())
    step = jax.jit(make_train_step(m, OptConfig(lr=1e-3, warmup_steps=1,
                                                total_steps=10),
                                   ParallelConfig()))
    state2, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and 0 < loss < 20
    # params actually changed
    d0 = jax.tree_util.tree_leaves(state.params)[1]
    d1 = jax.tree_util.tree_leaves(state2.params)[1]
    assert not np.allclose(np.asarray(d0, np.float32),
                           np.asarray(d1, np.float32))


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_prefill_decode_matches_forward(name):
    if name == "mixtral-8x7b":
        pytest.skip("pre-existing at seed: MoE prefill/decode routing "
                    "diverges from full forward on jax 0.4.37 — see "
                    "ROADMAP 'jax 0.4.37 compat'")
    cfg, m, params = _mk(name)
    S, cache_len = 48, 64
    tokens, mem = _batch(cfg, m, S=S)
    ctx = Ctx()
    logits_full, _ = m.apply(params, tokens, ctx, memory=mem)
    last, cache = m.prefill(params, tokens[:, :S], ctx, cache_len, memory=mem)
    np.testing.assert_allclose(np.asarray(last),
                               np.asarray(logits_full[:, S - 1]),
                               atol=1e-3, rtol=1e-2)
    dl, cache = m.decode_step(params, tokens[:, S:S + 1], cache, ctx,
                              memory=mem)
    err = float(jnp.max(jnp.abs(dl - logits_full[:, S])))
    assert err < 0.15, f"{name} decode mismatch {err}"


@pytest.mark.parametrize("name", ["recurrentgemma-9b", "mamba2-780m",
                                  "qwen3-8b", "gemma3-4b"])
def test_kernel_impl_matches_xla(name):
    # (MoE archs excluded: capacity-based routing amplifies bf16 noise into
    # discrete expert-assignment flips, so logit comparison is ill-posed)
    cfg, m, params = _mk(name)
    tokens, mem = _batch(cfg, m)
    lx, _ = m.apply(params, tokens[:, :-1], Ctx(attn_impl="xla"), memory=mem)
    lk, _ = m.apply(params, tokens[:, :-1], Ctx(attn_impl="interpret"),
                    memory=mem)
    assert float(jnp.max(jnp.abs(lx - lk))) < 0.3


def test_kernel_impl_matches_xla_swa_dense():
    """Sliding-window flash kernel vs XLA banded attention on a dense model
    (mixtral layer pattern with the MoE router disabled)."""
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        name="swa-dense", num_experts=0, experts_per_token=0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    tokens, _ = _batch(cfg, m)
    lx, _ = m.apply(params, tokens[:, :-1], Ctx(attn_impl="xla"))
    lk, _ = m.apply(params, tokens[:, :-1], Ctx(attn_impl="interpret"))
    assert float(jnp.max(jnp.abs(lx - lk))) < 0.3


def test_multi_token_decode_loop():
    """Decode 8 tokens sequentially == full forward on the whole sequence."""
    cfg, m, params = _mk("granite-3-8b")
    S, n_dec = 24, 8
    tokens, _ = _batch(cfg, m, S=S + n_dec)
    ctx = Ctx()
    logits_full, _ = m.apply(params, tokens, ctx)
    _, cache = m.prefill(params, tokens[:, :S], ctx, S + n_dec + 1)
    for i in range(n_dec):
        dl, cache = m.decode_step(params, tokens[:, S + i:S + i + 1], cache,
                                  ctx)
        err = float(jnp.max(jnp.abs(dl - logits_full[:, S + i])))
        assert err < 0.2, f"step {i}: {err}"


def test_local_attention_masks_long_range():
    """A local-attn model's logits at position t must not depend on tokens
    more than `window` behind t (MoE disabled: capacity routing couples
    tokens globally by design)."""
    cfg = get_config("mixtral-8x7b", smoke=True).replace(
        local_window=8, num_experts=0, experts_per_token=0)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    ctx = Ctx()
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 64), 0, cfg.vocab_size)
    t2 = t1.at[:, :8].set((t1[:, :8] + 7) % cfg.vocab_size)
    l1, _ = m.apply(params, t1, ctx)
    l2, _ = m.apply(params, t2, ctx)
    # 3 layers x window 8 -> receptive field 24; position 63 sees >= 40 only
    np.testing.assert_allclose(np.asarray(l1[:, -1]), np.asarray(l2[:, -1]),
                               atol=1e-2)
