"""train.fault hardening: seeded exponential backoff with injectable
sleep/clock, retryable-exception filtering, deadlines, bounded straggler
history, and preemption handlers that restore prior signal handlers."""
import signal

import pytest

from repro.train.fault import (FaultInjector, PreemptionHandler,
                               SimulatedFault, StragglerMonitor,
                               run_with_retry)


class _Clock:
    """Fake time: sleep() advances it, so backoff tests run instantly."""

    def __init__(self):
        self.t = 0.0
        self.sleeps = []

    def sleep(self, d):
        self.sleeps.append(d)
        self.t += d

    def now(self):
        return self.t


def _flaky(n_failures, exc=ValueError):
    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        if calls["n"] <= n_failures:
            raise exc(f"boom {calls['n']}")
        return "ok"

    fn.calls = calls
    return fn


def test_retry_plain_still_works():
    fn = _flaky(2)
    assert run_with_retry(fn, retries=2) == "ok"
    assert fn.calls["n"] == 3
    with pytest.raises(ValueError):
        run_with_retry(_flaky(5), retries=2)


def test_backoff_grows_and_jitter_is_seeded():
    ck = _Clock()
    fn = _flaky(3)
    run_with_retry(fn, retries=5, backoff=0.1, factor=2.0, jitter=0.5,
                   seed=7, sleep=ck.sleep, clock=ck.now)
    assert len(ck.sleeps) == 3
    assert ck.sleeps[0] < ck.sleeps[1] < ck.sleeps[2]   # exponential growth
    assert ck.sleeps[0] >= 0.1                          # jitter only adds
    ck2 = _Clock()
    run_with_retry(_flaky(3), retries=5, backoff=0.1, factor=2.0, jitter=0.5,
                   seed=7, sleep=ck2.sleep, clock=ck2.now)
    assert ck.sleeps == ck2.sleeps                      # same seed, same jitter
    ck3 = _Clock()
    run_with_retry(_flaky(3), retries=5, backoff=0.1, factor=2.0, jitter=0.5,
                   seed=8, sleep=ck3.sleep, clock=ck3.now)
    assert ck.sleeps != ck3.sleeps


def test_backoff_caps_at_max():
    ck = _Clock()
    run_with_retry(_flaky(4), retries=5, backoff=1.0, factor=10.0,
                   max_backoff=5.0, sleep=ck.sleep, clock=ck.now)
    assert max(ck.sleeps) == 5.0


def test_retryable_filter_class_tuple_predicate():
    fn = _flaky(5, exc=ValueError)
    with pytest.raises(ValueError):                     # wrong class: no retry
        run_with_retry(fn, retries=5, retryable=KeyError)
    assert fn.calls["n"] == 1
    assert run_with_retry(_flaky(2), retries=5,
                          retryable=(ValueError, OSError)) == "ok"
    assert run_with_retry(_flaky(2), retries=5,
                          retryable=lambda e: "boom" in str(e)) == "ok"
    with pytest.raises(ValueError):
        run_with_retry(_flaky(2), retries=5,
                       retryable=lambda e: False)


def test_deadline_stops_retrying():
    ck = _Clock()
    with pytest.raises(ValueError):
        # first sleep (10s) would blow the 5s deadline: re-raise instead
        run_with_retry(_flaky(5), retries=5, backoff=10.0, deadline=5.0,
                       sleep=ck.sleep, clock=ck.now)
    assert ck.sleeps == []


def test_on_failure_sees_each_attempt():
    seen = []
    run_with_retry(_flaky(2), retries=3,
                   on_failure=lambda e, a: seen.append((str(e), a)))
    assert [a for _, a in seen] == [0, 1]


def test_fault_injector_transient_fires_once():
    inj = FaultInjector(fail_steps=[3], transient=True)
    with pytest.raises(SimulatedFault):
        inj.check(3)
    inj.check(3)                                        # second pass clean


def test_straggler_history_bounded_at_window():
    m = StragglerMonitor(window=10, threshold=2.0)
    for i in range(1000):
        m.record(i, 1.0)
    assert len(m.times) == 10                           # O(window), not O(steps)
    assert m.median == 1.0
    assert m.record(1000, 5.0) is True
    assert m.straggler_steps[-1][0] == 1000
    # an ancient slow era beyond the window no longer skews the median
    m2 = StragglerMonitor(window=10)
    for i in range(20):
        m2.record(i, 100.0)
    for i in range(20, 40):
        m2.record(i, 1.0)
    assert m2.median == 1.0


def test_preemption_handler_restores_previous_handlers():
    sentinel = []
    prev = signal.signal(signal.SIGTERM, lambda s, f: sentinel.append(s))
    try:
        h = PreemptionHandler().install()
        assert signal.getsignal(signal.SIGTERM) == h._handle
        h._handle(signal.SIGTERM, None)
        assert h.should_stop
        h.uninstall()
        cur = signal.getsignal(signal.SIGTERM)
        cur(signal.SIGTERM, None)
        assert sentinel == [signal.SIGTERM]             # our handler is back
        with PreemptionHandler() as h2:                 # context-manager form
            assert not h2.should_stop
            assert signal.getsignal(signal.SIGTERM) == h2._handle
        assert signal.getsignal(signal.SIGTERM) == cur
    finally:
        signal.signal(signal.SIGTERM, prev)
