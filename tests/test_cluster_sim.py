"""Cluster-level asymmetric simulation: rank-symmetry bit-identity vs
simulate(), coalesced == naive equivalence on heterogeneous profiles,
directed barrier semantics with slowed ranks, per-link pricing, hetero DSE
knobs, and the benchmark regression gate."""
import random

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import (RankProfile, build_topology, compile_graph,
                                  simulate, simulate_cluster,
                                  straggler_analysis, collective_time)
from repro.core.costmodel.topology import Switch
from repro.core.dse import Knob, explore, greedy_descent, rank_profiles_for

from test_compiled_sim import FIELDS, rand_graph

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)


def assert_rank_identical(cr, rank, ref):
    rr = cr.rank_result(rank)
    for f in FIELDS:
        assert getattr(rr, f) == getattr(ref, f), \
            f"rank {rank} {f}: {getattr(rr, f)!r} != {getattr(ref, f)!r}"
    assert rr.timeline == ref.timeline


def test_symmetric_cluster_bit_identical_to_simulate():
    """A symmetric K-rank cluster must reproduce single-rank simulate()
    bit-for-bit — every field, every rank, K in {1, 2, 4, 8}, with and
    without coalescing, overlap on/off (the cluster-free property)."""
    for seed in range(25):
        rng = random.Random(seed)
        g = rand_graph(rng, rng.randint(5, 120))
        for overlap in (True, False):
            ref = simulate(g, SYS, TOPO, overlap=overlap, keep_timeline=True)
            for K in (1, 2, 4, 8):
                for coalesce in (True, False):
                    cr = simulate_cluster(g, SYS, TOPO, n_ranks=K,
                                          overlap=overlap, coalesce=coalesce,
                                          keep_timeline=True)
                    assert cr.n_classes == (1 if coalesce else K)
                    for r in range(K):
                        assert_rank_identical(cr, r, ref)
                    assert cr.step_time == ref.total_time
                    assert all(w == 0.0 for w in cr.class_barrier_wait)
                    assert cr.slowest_rank == 0


def test_coalesced_matches_naive_on_hetero_profiles():
    """Rank coalescing is an optimization, not a model change: per-rank
    results must equal the naive (one row per rank) engine exactly, for
    mixed compute/link/absolute-override profiles."""
    profs = {0: RankProfile(compute_scale=0.6),
             3: RankProfile(link_scale=0.5),
             5: RankProfile(peak_flops=1e14, hbm_bw=5e11)}
    for seed in range(12):
        rng = random.Random(1000 + seed)
        g = rand_graph(rng, rng.randint(10, 100))
        for overlap in (True, False):
            a = simulate_cluster(g, SYS, TOPO, n_ranks=8, rank_profiles=profs,
                                 coalesce=True, overlap=overlap)
            b = simulate_cluster(g, SYS, TOPO, n_ranks=8, rank_profiles=profs,
                                 coalesce=False, overlap=overlap)
            assert a.n_classes < b.n_classes
            for r in range(8):
                ra, rb = a.rank_result(r), b.rank_result(r)
                for f in FIELDS:
                    assert getattr(ra, f) == getattr(rb, f), (seed, r, f)
                assert a.barrier_wait[r] == b.barrier_wait[r]
            assert a.step_time == b.step_time
            assert a.slowest_rank == b.slowest_rank


def test_coalesced_matches_naive_with_rank_durations():
    for seed in (3, 7):
        g = rand_graph(random.Random(seed), 60)
        rd = {2: {i: 1e-4 for i in range(0, 60, 7)}}
        a = simulate_cluster(g, SYS, TOPO, n_ranks=4, rank_durations=rd)
        b = simulate_cluster(g, SYS, TOPO, n_ranks=4, rank_durations=rd,
                             coalesce=False)
        assert a.rank_times == b.rank_times
        assert a.barrier_wait == b.barrier_wait


def _chain_graph(K):
    """comp a -> world collective c -> comp b."""
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=1.0)
    c = g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-reduce",
              comm_bytes=1e6, group=list(range(K)))
    g.add("b", chakra.COMP, deps=[c], flops=1.0)
    return g, a, c


def test_barrier_gates_on_slowest_rank():
    """Directed semantics: the collective starts at the slowest rank's
    arrival; fast ranks' barrier wait is exactly the arrival skew."""
    K = 2
    g, a, c = _chain_graph(K)
    sysc = SystemConfig(chips=K, topology="switch")
    topo = build_topology(sysc, K)
    coll = collective_time("all-reduce", 1e6, list(range(K)), topo)
    t_fast, t_slow = 1e-3, 5e-3
    rd = {0: {a: t_fast}, 1: {a: t_slow}}
    cr = simulate_cluster(g, sysc, topo, n_ranks=K, rank_durations=rd,
                          keep_timeline=True)
    tl_fast = cr.rank_result(0).timeline
    tl_slow = cr.rank_result(1).timeline
    # collective entry: (nid, name, stream, start, end)
    ce_fast = next(e for e in tl_fast if e[0] == c)
    ce_slow = next(e for e in tl_slow if e[0] == c)
    assert ce_fast[3] == t_fast            # fast rank arrives early...
    assert ce_slow[3] == t_slow
    assert ce_fast[4] == ce_slow[4] == t_slow + coll   # ...completes together
    assert cr.barrier_wait[0] == t_slow - t_fast
    assert cr.barrier_wait[1] == 0.0
    assert cr.slowest_rank in (0, 1)
    # both ranks end at the same step time (synchronous step)
    assert cr.rank_result(0).total_time == cr.rank_result(1).total_time


def test_subgroup_collective_gates_only_its_block():
    """A collective over consecutive blocks of its group size: a straggler
    in the last block leaves the other blocks' ranks at nominal."""
    K = 4
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=1.0)
    c = g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-gather",
              comm_bytes=1e6, group=[0, 1])          # group size 2 -> 2 blocks
    g.add("b", chakra.COMP, deps=[c], flops=1.0)
    sysc = SystemConfig(chips=K, topology="switch")
    topo = build_topology(sysc, K)
    nominal = simulate(g, sysc, topo).total_time
    rd = {3: {a: 7e-3}}                              # straggler in block {2,3}
    cr = simulate_cluster(g, sysc, topo, n_ranks=K, rank_durations=rd)
    assert cr.rank_result(0).total_time == nominal   # block {0,1} untouched
    assert cr.rank_result(1).total_time == nominal
    assert cr.rank_result(2).total_time > nominal    # gated by rank 3
    assert cr.rank_result(3).total_time > nominal
    assert cr.barrier_wait[2] > 0.0
    assert cr.slowest_rank in (2, 3)


def test_straggler_analysis_cluster_semantics():
    """One slowed rank gating barriers: inflation strictly between 1x and
    fx (compute partially overlapped), monotone in f, with wait/slowest-rank
    attribution."""
    g = chakra.Graph()
    prev = None
    K = 32
    for i in range(24):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=8e6, out_bytes=8e6, group=list(range(K)),
                   ctrl_deps=[prev] if prev is not None else [])
        prev = g.add(f"f{i}", chakra.COMP,
                     deps=[ag] + ([prev] if prev is not None else []),
                     flops=5e10, bytes=1e8, out_bytes=1e6)
        g.add(f"ar{i}", chakra.COMM_COLL, deps=[prev],
              comm_kind="all-reduce", comm_bytes=4e6, group=list(range(K)))
    sysc = SystemConfig(chips=K, topology="switch", link_bw=12.5e9)
    topo = build_topology(sysc, K)
    rows = straggler_analysis(g, sysc, topo, slowdowns=(1.0, 1.5, 2.0),
                              n_ranks=K)
    assert rows[0]["slowdown_realized"] == pytest.approx(1.0)
    assert rows[0]["victim_wait"] == 0.0
    realized = [r["slowdown_realized"] for r in rows]
    assert realized == sorted(realized)
    mid = rows[1]
    assert 1.0 < mid["slowdown_realized"] < 1.5      # barrier-gated, overlapped
    assert mid["slowest_rank"] == 0
    assert mid["victim_wait"] > 0.0
    assert mid["n_ranks"] == K


def test_straggler_nominal_reuses_cached_result():
    """The f=1.0 row must come from the compiled graph's memoized symmetric
    result, not a separate engine run."""
    g = rand_graph(random.Random(2), 50)
    r0 = simulate(g, SYS, TOPO)                      # warms the result cache
    cg = compile_graph(g)
    calls = []
    orig = cg.run

    def counting_run(*a, **k):
        calls.append(1)
        return orig(*a, **k)

    cg.run = counting_run
    try:
        rows = straggler_analysis(g, SYS, TOPO, slowdowns=(1.0,))
    finally:
        cg.run = orig
    assert rows[0]["step_time"] == r0.total_time
    assert not calls                                 # pure cache reuse


def test_per_link_overrides_price_weakest_member():
    topo = Switch(n_ranks=8, link_bw=1e9, link_latency=0.0,
                  link_scales={2: 0.5})
    t_clean = collective_time("all-gather", 1e6, [0, 1], topo)
    t_degraded = collective_time("all-gather", 1e6, [1, 2], topo)
    assert t_degraded == pytest.approx(2.0 * t_clean)
    # explicit bw_scale overrides the derived group scale
    assert collective_time("all-gather", 1e6, [1, 2], topo, bw_scale=1.0) \
        == t_clean


def test_uniform_link_scales_symmetric_bit_identity():
    """Uniformly degraded links are still a *symmetric* cluster: the
    single-rank view prices every link-bound node (collectives AND p2p) by
    the weakest link, so simulate() and simulate_cluster stay bit-identical
    — both engines included."""
    from repro.core.costmodel.simulator import _simulate_reference
    for seed in (4, 8):
        g = rand_graph(random.Random(seed), 80)
        topo = Switch(n_ranks=16, link_bw=50e9, link_latency=1e-6,
                      link_scales={r: 0.5 for r in range(16)})
        ref = simulate(g, SYS, topo, keep_timeline=True)
        assert_rank_identical(
            simulate_cluster(g, SYS, topo, n_ranks=4, keep_timeline=True), 2,
            ref)
        rr = _simulate_reference(g, SYS, topo, keep_timeline=True)
        for f in FIELDS:
            assert getattr(ref, f) == getattr(rr, f), f
        # and the degradation actually bites (vs a clean topo)
        clean = Switch(n_ranks=16, link_bw=50e9, link_latency=1e-6)
        assert ref.total_time > simulate(g, SYS, clean).total_time


def test_nominal_scale_knobs_stay_on_plain_path():
    """pod_link_scale=1.0 (or a *_scale knob without its fraction/ratio) is
    a homogeneous cluster — it must take the memoized simulate() path, not
    the cluster engine."""
    from repro.core.costmodel import SimResult
    from repro.core.dse import _is_hetero, evaluate
    assert not _is_hetero({"pod_link_scale": 1.0})
    assert not _is_hetero({"degraded_link_scale": 0.5, "slow_chip_scale": 0.7})
    assert not _is_hetero({"degraded_fraction": 0.0, "slow_chip_ratio": 0.0})
    assert _is_hetero({"pod_link_scale": 0.7})
    assert _is_hetero({"degraded_fraction": 0.25})
    assert _is_hetero({"cluster_ranks": 8})        # explicit opt-in
    g = rand_graph(random.Random(1), 40)
    r = evaluate(g, SYS, {"pod_link_scale": 1.0})
    assert isinstance(r, SimResult)
    assert r.total_time == evaluate(g, SYS, {}).total_time


def test_topology_link_scales_cluster_consistency():
    g = rand_graph(random.Random(5), 60)
    topo = Switch(n_ranks=16, link_bw=50e9, link_latency=1e-6,
                  link_scales={1: 0.25})
    a = simulate_cluster(g, SYS, topo, n_ranks=16)
    b = simulate_cluster(g, SYS, topo, n_ranks=16, coalesce=False)
    assert a.rank_times == b.rank_times
    clean = Switch(n_ranks=16, link_bw=50e9, link_latency=1e-6)
    assert a.step_time > simulate_cluster(g, SYS, clean, n_ranks=16).step_time


def test_cluster_result_api():
    g = rand_graph(random.Random(9), 40)
    cr = simulate_cluster(g, SYS, TOPO, n_ranks=4,
                          rank_profiles={1: RankProfile(compute_scale=0.5)})
    assert cr.total_time == cr.step_time
    assert len(cr.rank_times) == 4 and len(cr.barrier_wait) == 4
    d = cr.as_dict()
    for key in ("total_time", "step_time", "compute_time", "comm_time",
                "exposed_comm", "peak_bytes", "n_nodes", "n_ranks",
                "n_classes", "slowest_rank", "max_barrier_wait",
                "mean_barrier_wait"):
        assert key in d, key
    assert d["n_ranks"] == 4 and d["n_classes"] >= 2
    with pytest.raises(ValueError):
        simulate_cluster(g, SYS, TOPO, n_ranks=0)
    with pytest.raises(ValueError):
        simulate_cluster(g, SYS, TOPO, n_ranks=2,
                         rank_profiles={5: RankProfile(compute_scale=0.5)})


def test_dse_hetero_knobs_route_to_cluster():
    g = chakra.Graph()
    prev = None
    for i in range(6):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=8e6, out_bytes=8e6, group=list(range(32)))
        deps = [ag] + ([prev] if prev is not None else [])
        prev = g.add(f"c{i}", chakra.COMP, deps=deps, flops=5e10,
                     out_bytes=1e6)
    sysc = SystemConfig(chips=32)
    knobs = [Knob("prefetch", [0, 2], layer="software"),
             Knob("degraded_fraction", [0.0, 0.25], layer="hardware"),
             Knob("degraded_link_scale", [0.5], layer="hardware")]
    trials = explore(lambda cfg: g, sysc, knobs)
    assert len(trials) == 4
    best, worst = trials[0], trials[-1]
    assert best.config["degraded_fraction"] == 0.0
    assert worst.config["degraded_fraction"] == 0.25
    # baseline trial stays on the memoized simulate() path; degraded trial
    # carries cluster attribution
    assert "n_classes" not in best.result.as_dict()
    assert worst.result.as_dict()["n_classes"] >= 2
    # symmetric hetero trial == plain simulate path (bit-identical)
    plain = explore(lambda cfg: g, sysc,
                    [Knob("prefetch", [best.config["prefetch"]],
                          layer="software")])[0]
    assert best.objective == plain.objective
    # greedy descent sweeps the same space to the same optimum
    assert greedy_descent(lambda cfg: g, sysc, knobs).objective \
        == best.objective


def test_rank_profiles_for_builders():
    profs = rank_profiles_for(8, {"slow_chip_ratio": 0.25,
                                  "slow_chip_scale": 0.8,
                                  "degraded_fraction": 0.25,
                                  "degraded_link_scale": 0.4})
    assert set(profs) == {0, 1, 6, 7}
    assert profs[0].compute_scale == 0.8 and profs[0].link_scale == 1.0
    assert profs[7].link_scale == 0.4 and profs[7].compute_scale == 1.0
    pod = rank_profiles_for(8, {"pod_link_scale": 0.5})
    assert set(pod) == {4, 5, 6, 7}
    assert all(p.link_scale == 0.5 for p in pod.values())
    assert rank_profiles_for(8, {}) is None
    assert rank_profiles_for(8, {"degraded_fraction": 0.0}) is None


def test_check_regression_gate():
    from benchmarks.check_regression import check
    thresholds = {"simulate": {"speedup_cached": 10.0},
                  "straggler": {"speedup": 1.5}}
    good = {"simulate": {"1000": {"speedup_cached": 40.0}},
            "straggler": {"speedup": 3.0}}
    assert check(good, thresholds) == []
    bad = {"simulate": {"1000": {"speedup_cached": 2.0}},
           "straggler": {}}
    violations = check(bad, thresholds)
    assert ("simulate.1000.speedup_cached", 2.0, 10.0) in violations
    assert ("straggler.speedup", None, 1.5) in violations
