"""Pallas kernel validation: shape/dtype sweeps vs ref.py oracles
(interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("S,causal,window", [
    (64, True, 0), (96, True, 0), (64, True, 16), (128, False, 0),
    (80, True, 24),
])
@pytest.mark.parametrize("dtype", [np.float32, jnp.bfloat16])
def test_flash_attention_sweep(S, causal, window, dtype):
    rng = np.random.RandomState(0)
    B, KV, G, hd = 2, 2, 2, 32
    q = rng.randn(B, S, KV, G, hd).astype(np.float32)
    k = rng.randn(B, S, KV, hd).astype(np.float32)
    v = rng.randn(B, S, KV, hd).astype(np.float32)
    qj, kj, vj = (jnp.asarray(x, dtype) for x in (q, k, v))
    o = ops.flash_attention(qj, kj, vj, causal=causal, window=window,
                            interpret=True, block_q=32, block_k=32)
    qf = np.moveaxis(q, 1, 3).reshape(B * KV * G, S, hd)
    kf = np.moveaxis(k, 1, 2).reshape(B * KV, S, hd)
    vf = np.moveaxis(v, 1, 2).reshape(B * KV, S, hd)
    oref = ref.flash_attention_oracle(jnp.asarray(qf), jnp.asarray(kf),
                                      jnp.asarray(vf), causal=causal,
                                      window=window)
    oref = np.moveaxis(np.asarray(oref, np.float32).reshape(B, KV, G, S, hd),
                       3, 1)
    atol = 2e-5 if dtype == np.float32 else 3e-2
    np.testing.assert_allclose(np.asarray(o, np.float32), oref, atol=atol)


@pytest.mark.parametrize("mqa_kv", [1, 2, 4])
def test_flash_attention_gqa_ratios(mqa_kv):
    rng = np.random.RandomState(1)
    B, S, H, hd = 2, 64, 4, 16
    G = H // mqa_kv
    q = rng.randn(B, S, mqa_kv, G, hd).astype(np.float32)
    k = rng.randn(B, S, mqa_kv, hd).astype(np.float32)
    v = rng.randn(B, S, mqa_kv, hd).astype(np.float32)
    o = ops.flash_attention(jnp.asarray(q), jnp.asarray(k), jnp.asarray(v),
                            causal=True, interpret=True, block_q=32,
                            block_k=32)
    qf = np.moveaxis(q, 1, 3).reshape(B * mqa_kv * G, S, hd)
    kf = np.moveaxis(k, 1, 2).reshape(B * mqa_kv, S, hd)
    vf = np.moveaxis(v, 1, 2).reshape(B * mqa_kv, S, hd)
    oref = ref.flash_attention_oracle(jnp.asarray(qf), jnp.asarray(kf),
                                      jnp.asarray(vf), causal=True)
    oref = np.moveaxis(np.asarray(oref).reshape(B, mqa_kv, G, S, hd), 3, 1)
    np.testing.assert_allclose(np.asarray(o), oref, atol=2e-5)


@pytest.mark.parametrize("S,C,bt,bc", [(100, 48, 32, 16), (64, 64, 64, 64),
                                       (33, 7, 8, 8)])
def test_rglru_scan_sweep(S, C, bt, bc):
    rng = np.random.RandomState(2)
    B = 2
    a = 0.4 + 0.5 * jax.nn.sigmoid(
        jnp.asarray(rng.randn(B, S, C), jnp.float32))
    b = jnp.asarray(rng.randn(B, S, C), jnp.float32) * 0.1
    h = ops.rglru_scan(a, b, interpret=True, block_t=bt, block_c=bc)
    href = ref.rglru_scan_oracle(a, b)
    np.testing.assert_allclose(np.asarray(h), np.asarray(href), atol=1e-5)


def test_rglru_matches_associative_scan_path():
    from repro.models.rglru import rglru_scan_ref
    rng = np.random.RandomState(3)
    a = jax.nn.sigmoid(jnp.asarray(rng.randn(2, 50, 16), jnp.float32))
    b = jnp.asarray(rng.randn(2, 50, 16), jnp.float32)
    np.testing.assert_allclose(np.asarray(rglru_scan_ref(a, b)),
                               np.asarray(ref.rglru_scan_oracle(a, b)),
                               atol=1e-5)


@pytest.mark.parametrize("s,chunk", [(80, 32), (64, 64), (96, 16)])
def test_ssd_sweep(s, chunk):
    rng = np.random.RandomState(4)
    b, h, p, n = 2, 3, 16, 8
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.randn(b, s, h), jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.randn(h), jnp.float32) * 0.3)
    B_ = jnp.asarray(rng.randn(b, s, n), jnp.float32) * 0.5
    C_ = jnp.asarray(rng.randn(b, s, n), jnp.float32) * 0.5
    y, sf = ops.ssd(x, dt, A, B_, C_, chunk=chunk, interpret=True)
    yr, sfr = ref.ssd_oracle(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sfr), atol=2e-3)


def test_ssd_chunked_model_path_matches_oracle():
    from repro.models.ssm import ssd_chunked
    rng = np.random.RandomState(5)
    b, s, h, p, n = 1, 48, 2, 8, 4
    x = jnp.asarray(rng.randn(b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.randn(b, s, h), jnp.float32))
    A = -jnp.exp(jnp.asarray(rng.randn(h), jnp.float32) * 0.3)
    B_ = jnp.asarray(rng.randn(b, s, n), jnp.float32) * 0.5
    C_ = jnp.asarray(rng.randn(b, s, n), jnp.float32) * 0.5
    y, sf = ssd_chunked(x, dt, A, B_, C_, 16)
    yr, sfr = ref.ssd_oracle(x, dt, A, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), atol=2e-3)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(sfr), atol=2e-3)


def test_flash_ref_matches_oracle_property():
    """Property-style sweep of the jnp chunked-flash used in the XLA path."""
    from repro.models.attention import flash_attention_ref
    rng = np.random.RandomState(6)
    for trial in range(5):
        S = int(rng.choice([32, 48, 64, 96]))
        qb = int(rng.choice([16, 32]))
        kb = int(rng.choice([16, 32]))
        w = int(rng.choice([0, 8, 24]))
        B, KV, G, hd = 1, 2, 2, 8
        q = jnp.asarray(rng.randn(B, S, KV, G, hd), jnp.float32)
        k = jnp.asarray(rng.randn(B, S, KV, hd), jnp.float32)
        v = jnp.asarray(rng.randn(B, S, KV, hd), jnp.float32)
        o = flash_attention_ref(q, k, v, scale=0.3, causal=True, window=w,
                                q_block=qb, kv_block=kb)
        qf = jnp.moveaxis(q, 1, 3).reshape(B * KV * G, S, hd)
        kf = jnp.moveaxis(k, 1, 2).reshape(B * KV, S, hd)
        vf = jnp.moveaxis(v, 1, 2).reshape(B * KV, S, hd)
        oref = ref.flash_attention_oracle(qf, kf, vf, scale=0.3, causal=True,
                                          window=w)
        oref = jnp.moveaxis(oref.reshape(B, KV, G, S, hd), 3, 1)
        np.testing.assert_allclose(np.asarray(o), np.asarray(oref), atol=1e-5)
