"""Config/registry invariants: the 10 assigned archs x 4 shapes grid."""
import pytest

from repro.configs.base import ALL_SHAPES, ModelConfig, GLOBAL_ATTN
from repro.configs.registry import (ARCH_NAMES, all_cells, cell_applicable,
                                    get_config, get_shape)

EXPECTED_PARAMS_B = {
    "recurrentgemma-9b": (7.5, 10.0),
    "seamless-m4t-medium": (0.4, 1.0),
    "llama-3.2-vision-90b": (80.0, 95.0),
    "mamba2-780m": (0.7, 0.9),
    "gemma3-4b": (3.3, 4.5),
    "qwen3-8b": (7.0, 8.8),
    "granite-3-8b": (7.3, 9.0),
    "gemma3-12b": (10.5, 13.0),
    "mixtral-8x7b": (44.0, 49.0),
    "dbrx-132b": (125.0, 140.0),
}


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_param_counts_match_advertised(name):
    cfg = get_config(name)
    lo, hi = EXPECTED_PARAMS_B[name]
    n = cfg.param_count() / 1e9
    assert lo <= n <= hi, f"{name}: {n:.2f}B params not in [{lo},{hi}]"


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_layer_pattern_covers_depth(name):
    cfg = get_config(name)
    assert len(cfg.layer_kinds) == cfg.num_layers


@pytest.mark.parametrize("name", ARCH_NAMES)
def test_smoke_config_is_small(name):
    cfg = get_config(name, smoke=True)
    assert cfg.param_count() < 5e6


def test_grid_is_40_cells_35_applicable():
    cells = list(all_cells())
    assert len(cells) == 40
    assert sum(1 for c in cells if c[3]) == 35


def test_long_context_skips_are_pure_full_attention():
    for name in ARCH_NAMES:
        cfg = get_config(name)
        ok, why = cell_applicable(cfg, get_shape("long_500k"))
        if not ok:
            assert not cfg.sub_quadratic
            assert "full-attention" in why


def test_moe_active_params_less_than_total():
    for name in ("mixtral-8x7b", "dbrx-132b"):
        cfg = get_config(name)
        assert cfg.active_param_count() < 0.5 * cfg.param_count()


def test_shapes_exact():
    s = get_shape("train_4k")
    assert (s.seq_len, s.global_batch, s.kind) == (4096, 256, "train")
    s = get_shape("long_500k")
    assert (s.seq_len, s.global_batch, s.kind) == (524288, 1, "decode")
