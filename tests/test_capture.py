"""End-to-end Flint capture: cluster-free lower/compile -> Chakra graph ->
passes -> simulator (the paper's pipeline on an 8-fake-device mesh)."""
import pytest


@pytest.mark.skip(reason="pre-existing at seed: jax 0.4.37 capture-fidelity "
                         "gap (per-layer all-gather deps not recovered from "
                         "scanned HLO) — see ROADMAP 'jax 0.4.37 compat'")
def test_capture_pipeline_end_to_end(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel.mesh import make_mesh
from repro.core import capture_step, passes
from repro.core.costmodel import simulate, build_topology
from repro.configs.base import SystemConfig

mesh = make_mesh((8,), ("data",))
L = 4
def step(stack, x):
    def body(h, w):
        return jax.nn.relu(h @ w), None
    h, _ = jax.lax.scan(body, x, stack)
    return jnp.mean(h ** 2)
g = jax.value_and_grad(step)
ss = jax.ShapeDtypeStruct((L, 512, 512), jnp.bfloat16)
xs = jax.ShapeDtypeStruct((512, 512), jnp.bfloat16)
sh = (NamedSharding(mesh, P(None, "data", None)),   # FSDP weights
      NamedSharding(mesh, P("data", None)))
cap = capture_step(g, (ss, xs), sh, mesh, meta={"case": "test"})

# graph has per-layer weight all-gathers with true deps
ags = [n for n in cap.graph.by_type("COMM_COLL")
       if n.attrs["comm_kind"] == "all-gather"]
assert len(ags) >= L, len(ags)
assert cap.summary["parsed_flops"] > 0
assert cap.summary["comm_bytes"] > 0
assert cap.meta["num_partitions"] == 8
cap.graph.validate()

# memory/cost analyses present
assert "temp_size_in_bytes" in cap.memory_analysis
assert cap.cost_analysis.get("flops", 0) > 0

# passes + sim: sync version must not be faster than prefetched
sysc = SystemConfig(chips=8, link_bw=400e9)
topo = build_topology(sysc, 8)
g_sync = passes.inject_fsdp_sync(cap.graph)
g_pre = passes.reorder_prefetch(g_sync, prefetch=4)
r_sync = simulate(g_sync, sysc, topo)
r_pre = simulate(g_pre, sysc, topo)
assert r_pre.total_time <= r_sync.total_time + 1e-12
assert r_pre.peak_bytes > 0 and r_sync.peak_bytes > 0
print("capture ok", len(cap.graph), r_sync.total_time, r_pre.total_time)
""")
    assert "capture ok" in out


def test_stablehlo_op_counts(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.core import stablehlo_op_counts
def f(x, w):
    return jnp.tanh(x @ w).sum()
low = jax.jit(f).lower(jax.ShapeDtypeStruct((4, 8), jnp.float32),
                       jax.ShapeDtypeStruct((8, 4), jnp.float32))
c = stablehlo_op_counts(low.as_text())
assert c.get("dot_general", 0) == 1, c
assert c.get("tanh", 0) == 1, c
print("stablehlo ok")
""", devices=1)
    assert "stablehlo ok" in out


def test_capture_counts_match_model_structure(subproc):
    """Paper SS5.2 analogue: captured per-layer collective counts must track
    the layer count when depth doubles."""
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel.mesh import make_mesh
from repro.core import capture_step

mesh = make_mesh((2, 4), ("data", "model"))
def make(L):
    def step(stack, x):
        def body(h, w):
            return jax.nn.relu(h @ w), None
        h, _ = jax.lax.scan(body, x, stack)
        return jnp.mean(h ** 2)
    g = jax.value_and_grad(step)
    ss = jax.ShapeDtypeStruct((L, 256, 256), jnp.bfloat16)
    xs = jax.ShapeDtypeStruct((64, 256), jnp.bfloat16)
    sh = (NamedSharding(mesh, P(None, None, "model")),
          NamedSharding(mesh, P("data", None)))
    return capture_step(g, (ss, xs), sh, mesh, build_graph=False)

c4 = make(4).summary
c8 = make(8).summary
r = c8["parsed_flops"] / c4["parsed_flops"]
assert 1.9 < r < 2.1, r
ar4 = c4["comm"].get("all-reduce", {"count": 0})["count"]
ar8 = c8["comm"].get("all-reduce", {"count": 0})["count"]
assert ar8 > ar4
print("structure ok", r, ar4, ar8)
""")
    assert "structure ok" in out
