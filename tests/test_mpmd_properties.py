"""Randomized MPMD property suite (ISSUE 5 satellite).

Generates random per-rank DAGs that share a cluster-wide collective
schedule (each rank weaves its subset of the schedule into its own random
compute DAG, chained in launch order like a real comm stream) and asserts
the engine's core invariants over >= 50 seeded cases:

  * K identical graphs are bit-identical to the single-graph
    ``simulate_cluster`` and to ``simulate()`` for K in {1, 2, 4, 8};
  * a collective barrier never completes before its slowest participant
    arrives, completes simultaneously on every participant, and barrier
    waits are non-negative;
  * the cluster makespan is monotone non-decreasing when any rank slows;
  * coalesced == naive (``coalesce=False``) per-rank results, graph pools
    included.
"""
import random

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:          # container without hypothesis: deterministic stub
    import _hypothesis_stub as st
    from _hypothesis_stub import given, settings

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import (MPMDProgram, build_topology, compile_graph,
                                  simulate, simulate_cluster)

from test_compiled_sim import FIELDS, rand_graph

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)

KINDS = ("all-gather", "all-reduce", "reduce-scatter")


def shared_schedule(rng, K):
    """Cluster-wide collective launch order: (name, kind, group, payload)."""
    sched = []
    for k in range(rng.randint(1, 5)):
        size = rng.randint(2, K)
        group = sorted(rng.sample(range(K), size))
        sched.append((f"coll{k}", rng.choice(KINDS), group,
                      rng.uniform(1e5, 1e7)))
    return sched


def rank_dag(rng, rank, sched, pool_ranks=None):
    """One rank's graph: random COMP DAG + its slice of the shared schedule,
    collectives chained in launch order (a real program serializes launches
    on the comm stream; this also pins the canonical order to the schedule).

    `pool_ranks` (for graph-sharing pools): the graph carries a schedule
    entry iff ANY pool member participates — members barrier on it, the
    others run it locally (ragged participation)."""
    members_of = pool_ranks if pool_ranks is not None else [rank]
    g = chakra.Graph()
    nids = []

    def rand_deps(k=3):
        if not nids:
            return []
        return rng.sample(nids, rng.randint(0, min(len(nids), k)))

    for i in range(rng.randint(2, 8)):
        nids.append(g.add(f"p{i}", chakra.COMP, deps=rand_deps(),
                          flops=rng.uniform(1e6, 1e9),
                          bytes=rng.uniform(0.0, 1e7),
                          out_bytes=rng.choice([0.0, rng.uniform(1, 100)])))
    prev_coll = None
    for name, kind, group, payload in sched:
        if not any(r in group for r in members_of):
            nids.append(g.add(f"x{name}", chakra.COMP, deps=rand_deps(),
                              flops=rng.uniform(1e6, 1e9)))
            continue
        c = g.add(name, chakra.COMM_COLL, deps=rand_deps(),
                  ctrl_deps=[prev_coll] if prev_coll is not None else [],
                  comm_kind=kind, comm_bytes=payload, out_bytes=8.0,
                  group=group)
        prev_coll = c
        nids.append(c)
        for j in range(rng.randint(0, 2)):
            nids.append(g.add(f"c{name}_{j}", chakra.COMP,
                              deps=rand_deps() + [c],
                              flops=rng.uniform(1e6, 1e9),
                              out_bytes=rng.choice([0.0, 16.0])))
    return g


def mpmd_cluster(rng, K):
    sched = shared_schedule(rng, K)
    graphs = [rank_dag(rng, r, sched) for r in range(K)]
    return MPMDProgram(graphs), sched


def slowdown_overrides(prog, rank, factor):
    """rank_durations scaling every node of `rank`'s graph by `factor`."""
    cg = compile_graph(prog.graph_for(rank))
    base = cg.durations(SYS, TOPO)
    return {rank: {nid: base[nid] * factor for nid in range(cg.n)}}


@settings(max_examples=15)
@given(st.integers(0, 10**6))
def test_identical_graphs_bit_identical_to_spmd_and_simulate(seed):
    """K copies of one graph under the MPMD engine == today's single-graph
    simulate_cluster == simulate(), every field, timeline included."""
    rng = random.Random(seed)
    g = rand_graph(rng, rng.randint(5, 80))
    for overlap in (True, False):
        ref = simulate(g, SYS, TOPO, overlap=overlap, keep_timeline=True)
        for K in (1, 2, 4, 8):
            spmd = simulate_cluster(g, SYS, TOPO, n_ranks=K, overlap=overlap,
                                    keep_timeline=True)
            mpmd = simulate_cluster([g] * K, SYS, TOPO, overlap=overlap,
                                    keep_timeline=True)
            assert mpmd.n_ranks == K
            for r in range(K):
                mr, sr = mpmd.rank_result(r), spmd.rank_result(r)
                for f in FIELDS:
                    assert getattr(mr, f) == getattr(ref, f), (K, r, f)
                    assert getattr(mr, f) == getattr(sr, f), (K, r, f)
                assert mr.timeline == ref.timeline
            assert mpmd.step_time == spmd.step_time == ref.total_time
            assert all(w == 0.0 for w in mpmd.class_barrier_wait)
            assert mpmd.slowest_rank == 0


@settings(max_examples=20)
@given(st.integers(0, 10**6))
def test_barrier_completes_at_slowest_participant(seed):
    """Every shared collective ends simultaneously on all participants, no
    earlier than the slowest participant's arrival; per-rank barrier waits
    are >= 0."""
    rng = random.Random(seed)
    K = rng.choice([2, 4, 8])
    prog, sched = mpmd_cluster(rng, K)
    straggler = rng.randrange(K)
    rd = slowdown_overrides(prog, straggler, rng.uniform(1.5, 4.0))
    cr = simulate_cluster(prog, SYS, TOPO, rank_durations=rd,
                          keep_timeline=True)
    assert all(w >= 0.0 for w in cr.class_barrier_wait)
    for name, kind, group, payload in sched:
        spans = {}
        for r in group:
            sp = [s for s in cr.rank_spans(r) if s.name == name]
            assert len(sp) == 1, (name, r)
            spans[r] = sp[0]
        ends = {s.end for s in spans.values()}
        assert len(ends) == 1, (name, ends)          # synchronous completion
        end = ends.pop()
        slowest_arrival = max(s.start for s in spans.values())
        assert end >= slowest_arrival                # barrier gates on it
        # each participant's span covers [own arrival, shared end]: no
        # start after the barrier fires, every span closes at `end`
        for r, s in spans.items():
            assert s.start <= slowest_arrival, (name, r)
            assert s.end - s.start >= end - slowest_arrival, (name, r)


@settings(max_examples=15)
@given(st.integers(0, 10**6))
def test_makespan_monotone_when_any_rank_slows(seed):
    """step_time is monotone non-decreasing in any single rank's slowdown
    factor (1.0 -> 1.5 -> 2.5)."""
    rng = random.Random(seed)
    K = rng.choice([2, 4])
    prog, _ = mpmd_cluster(rng, K)
    victim = rng.randrange(K)
    base = simulate_cluster(prog, SYS, TOPO).step_time
    prev = base
    for f in (1.5, 2.5):
        step = simulate_cluster(
            prog, SYS, TOPO,
            rank_durations=slowdown_overrides(prog, victim, f)).step_time
    # slowed victim gates its barriers: never faster than nominal, and
    # monotone across increasing factors
        assert step >= prev - 1e-15, (seed, victim, f, prev, step)
        prev = step
    assert prev >= base


@settings(max_examples=15)
@given(st.integers(0, 10**6))
def test_coalesced_equals_naive(seed):
    """Graph-pool coalescing is an optimization, not a model change: ranks
    sharing a graph coalesce (when unskewed) yet produce exactly the naive
    per-rank engine's results."""
    rng = random.Random(seed)
    K = rng.choice([4, 8])
    n_pools = rng.choice([1, 2])
    sched = shared_schedule(rng, K)
    pools = [[r for r in range(K) if r % n_pools == p]
             for p in range(n_pools)]
    pool_graphs = [rank_dag(rng, pool[0], sched, pool_ranks=pool)
                   for pool in pools]
    prog = MPMDProgram([pool_graphs[r % n_pools] for r in range(K)])
    rd = None
    if rng.random() < 0.6:               # skew a strict subset of ranks
        rd = slowdown_overrides(prog, rng.randrange(K),
                                rng.uniform(1.2, 3.0))
    a = simulate_cluster(prog, SYS, TOPO, rank_durations=rd)
    b = simulate_cluster(prog, SYS, TOPO, rank_durations=rd, coalesce=False)
    assert b.n_classes == K
    assert a.n_classes <= b.n_classes
    for r in range(K):
        ra, rb = a.rank_result(r), b.rank_result(r)
        for f in FIELDS:
            assert getattr(ra, f) == getattr(rb, f), (seed, r, f)
        assert a.barrier_wait[r] == b.barrier_wait[r], (seed, r)
    assert a.step_time == b.step_time
    assert a.slowest_rank == b.slowest_rank
