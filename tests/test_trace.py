"""Trace subsystem: Chrome-trace export/ingest round-trip (single-rank and
cluster must re-validate at ~0% error with full node alignment), external
B/E-pair ingestion, calibration recovery of perturbed hardware parameters,
the CLI verbs, and the satellite behaviors (cluster result memoization,
group-attr participant mapping, the DSE GIL warning)."""
import json
import random

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra, dse
from repro.core.costmodel import (RankProfile, build_topology, compile_graph,
                                  simulate, simulate_cluster)
from repro.core.costmodel.simulator import Span, _group_instances
from repro.trace import (align, align_rank, calibrate, export_chrome_trace,
                         ingest_chrome_trace, to_chrome_trace, validate)
from repro.trace.cli import main as trace_cli

from test_compiled_sim import rand_graph

SYS = SystemConfig(chips=8, topology="switch")
TOPO = build_topology(SYS)


def fsdp_stack(n_layers, ranks, with_membound=True):
    """FSDP-ish layer stack; `with_membound` adds HBM-bound COMP nodes so
    calibration can identify hbm_bw independently of compute_derate."""
    g = chakra.Graph()
    group = list(range(ranks))
    prev = None
    for i in range(n_layers):
        ag = g.add(f"ag{i}", chakra.COMM_COLL, comm_kind="all-gather",
                   comm_bytes=8e6, out_bytes=8e6, group=group,
                   ctrl_deps=[prev] if prev is not None else [])
        fwd = g.add(f"f{i}", chakra.COMP,
                    deps=[ag] + ([prev] if prev is not None else []),
                    flops=5e10, bytes=1e8, out_bytes=1e6)
        bwd = g.add(f"b{i}", chakra.COMP, deps=[fwd], flops=1e11,
                    bytes=2e8, out_bytes=1e6)
        if with_membound:
            g.add(f"mem{i}", chakra.COMP, deps=[fwd], flops=1e8, bytes=5e8)
        g.add(f"ar{i}", chakra.COMM_COLL, deps=[bwd],
              comm_kind="all-reduce", comm_bytes=4e6 * (1 + i % 3),
              group=group)
        prev = bwd
    return g


# ---------------------------------------------------------------------------
# round-trip: export -> ingest -> align -> validate
# ---------------------------------------------------------------------------

def test_roundtrip_single_rank_zero_error():
    g = fsdp_stack(12, 8)
    res = simulate(g, SYS, TOPO, keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(res, graph=g))
    al = align_rank(g, tl, 0)
    assert al.match_fraction == 1.0
    assert not al.unmatched_nodes and not al.unmatched_events
    rep = validate(g, tl, SYS, TOPO)
    assert rep.n_ranks == 1
    assert rep.match_fraction == 1.0
    assert rep.e2e_error < 1e-9
    assert rep.critical_path_overlap == 1.0
    assert not rep.worst
    for row in rep.per_class.values():
        assert row["mean_rel_err"] < 1e-9 and row["max_rel_err"] < 1e-9


def test_roundtrip_cluster_4rank_zero_error(tmp_path):
    """4-rank cluster with a straggler profile: per-rank processes in the
    trace, full alignment and ~0% error when validated under the same
    profiles (file round-trip included)."""
    g = fsdp_stack(10, 4)
    profs = {3: RankProfile(compute_scale=0.7)}
    cr = simulate_cluster(g, SYS, TOPO, n_ranks=4, rank_profiles=profs,
                          keep_timeline=True)
    path = str(tmp_path / "trace.json")
    export_chrome_trace(cr, path, graph=g)
    tl = ingest_chrome_trace(path)
    assert tl.ranks() == [0, 1, 2, 3]
    rep = validate(g, tl, SYS, TOPO, rank_profiles=profs)
    assert rep.n_ranks == 4
    assert rep.match_fraction == 1.0
    assert rep.e2e_error < 1e-9
    # the straggler actually skews the trace (rank 3 slower than rank 0)
    assert tl.total_time(3) >= tl.total_time(0)


def test_partial_cluster_trace_keeps_rank_identity():
    """A trace covering only a subset of ranks must still score each pid
    against *that* simulated rank — pid 3's straggler timeline validates
    at ~0% error even when pids 0-1 are missing from the capture."""
    import dataclasses as _dc

    g = fsdp_stack(8, 4)
    profs = {3: RankProfile(compute_scale=0.6)}
    cr = simulate_cluster(g, SYS, TOPO, n_ranks=4, rank_profiles=profs,
                          keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(cr, graph=g))
    partial = _dc.replace(tl, events=[e for e in tl.events
                                      if e.rank in (2, 3)])
    rep = validate(g, partial, SYS, TOPO, n_ranks=4, rank_profiles=profs)
    assert rep.match_fraction == 1.0
    assert rep.e2e_error < 1e-9
    assert {row["rank"] for row in rep.per_rank} == {2, 3}
    # a trace with no duration events reports cleanly, not a crash
    empty = _dc.replace(tl, events=[])
    rep0 = validate(g, empty, SYS, TOPO, n_ranks=4, rank_profiles=profs)
    assert rep0.n_matched == 0 and not rep0.per_rank


def test_roundtrip_random_graphs():
    for seed in (0, 7, 21):
        g = rand_graph(random.Random(seed), 80)
        res = simulate(g, SYS, TOPO, keep_timeline=True)
        rep = validate(g, ingest_chrome_trace(to_chrome_trace(res, graph=g)),
                       SYS, TOPO)
        assert rep.match_fraction == 1.0, seed
        assert rep.e2e_error < 1e-9, seed


def test_export_trace_structure():
    g = fsdp_stack(4, 4)
    cr = simulate_cluster(g, SYS, TOPO, n_ranks=2, keep_timeline=True)
    tr = to_chrome_trace(cr, graph=g)
    evs = tr["traceEvents"]
    assert tr["metadata"]["schema"] == "flint-trace-v1"
    # one process_name per rank, compute+comm thread names each
    pnames = [e for e in evs if e["ph"] == "M" and e["name"] == "process_name"]
    assert {e["pid"] for e in pnames} == {0, 1}
    tnames = [e["args"]["name"] for e in evs
              if e["ph"] == "M" and e["name"] == "thread_name"]
    assert tnames.count("compute") == 2 and tnames.count("comm") == 2
    xs = [e for e in evs if e["ph"] == "X"]
    assert len(xs) == 2 * len(g)
    assert all(e["tid"] in (0, 1) and "nid" in e["args"]
               and "fingerprint" in e["args"] for e in xs)
    # exposed-comm counter track present and returns to zero
    cs = [e for e in evs if e["ph"] == "C"]
    assert cs and all(e["name"] == "exposed_comm" for e in cs)
    assert cs[-1]["args"]["bytes"] == 0.0


def test_ingest_external_begin_end_pairs():
    """A foreign trace (B/E pairs, epoch timestamps, no nid/fingerprint
    args) still ingests, aligns by name, and validates."""
    g = chakra.Graph()
    a = g.add("matmul", chakra.COMP, flops=1e10)
    g.add("allreduce", chakra.COMM_COLL, deps=[a], comm_kind="all-reduce",
          comm_bytes=1e6, group=list(range(8)))
    base = 1.7e15                           # epoch-like offset, us
    t_mm = 90.0
    t_ar = 50.0
    raw = [
        {"ph": "M", "pid": 7, "tid": 0, "name": "thread_name",
         "args": {"name": "MainCompute"}},
        {"ph": "M", "pid": 7, "tid": 9, "name": "thread_name",
         "args": {"name": "CommStream"}},
        {"ph": "B", "pid": 7, "tid": 0, "name": "matmul", "ts": base},
        {"ph": "E", "pid": 7, "tid": 0, "name": "matmul", "ts": base + t_mm},
        {"ph": "B", "pid": 7, "tid": 9, "name": "allreduce",
         "ts": base + t_mm},
        {"ph": "E", "pid": 7, "tid": 9, "name": "allreduce",
         "ts": base + t_mm + t_ar},
    ]
    tl = ingest_chrome_trace(raw)
    assert tl.ranks() == [7]
    evs = tl.rank_events(7)
    assert [e.name for e in evs] == ["matmul", "allreduce"]
    assert evs[0].stream == "comp" and evs[1].stream == "comm"
    assert evs[0].start == 0.0 and evs[0].dur == pytest.approx(t_mm * 1e-6)
    al = align_rank(g, tl, 7)
    assert al.match_fraction == 1.0
    rep = validate(g, tl, SYS, TOPO)
    assert rep.n_matched == 2
    assert 0.0 <= rep.critical_path_overlap <= 1.0


def test_validation_detects_perturbation():
    """A trace measured on different hardware must show up as error, with
    offenders attributed to the right op class."""
    g = fsdp_stack(8, 8)
    slow = SYS.replace(link_bw=SYS.link_bw * 0.4)
    res = simulate(g, slow, build_topology(slow), keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(res, graph=g))
    rep = validate(g, tl, SYS, TOPO)
    assert rep.match_fraction == 1.0          # alignment is error-agnostic
    assert rep.e2e_error > 0.02
    assert rep.per_class["COMM_COLL"]["mean_rel_err"] > 0.1
    assert rep.per_class["COMP"]["mean_rel_err"] < 1e-9
    assert rep.worst and all(w["type"] == "COMM_COLL" for w in rep.worst)


# ---------------------------------------------------------------------------
# calibration
# ---------------------------------------------------------------------------

def test_calibration_recovers_perturbed_params():
    """Acceptance: trace generated under perturbed hbm_bw and link scale;
    coordinate descent recovers both within 5%, and the calibrated model
    validates strictly better than the nominal one."""
    g = fsdp_stack(12, 8)
    hbm_f, link_f = 0.65, 0.7
    true_sys = SYS.replace(hbm_bw=SYS.hbm_bw * hbm_f,
                           link_bw=SYS.link_bw * link_f)
    res = simulate(g, true_sys, build_topology(true_sys), keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(res, graph=g))
    cal = calibrate(g, tl, SYS, TOPO)
    assert cal.params["hbm_bw"] == pytest.approx(SYS.hbm_bw * hbm_f,
                                                 rel=0.05)
    assert cal.params["link_bw_scale"] == pytest.approx(link_f, rel=0.05)
    assert cal.fitted_error < cal.initial_error / 5
    before = validate(g, tl, SYS, TOPO)
    after = validate(g, tl, cal.system, cal.topology,
                     compute_derate=cal.compute_derate)
    assert after.e2e_error < before.e2e_error
    assert after.e2e_error < 0.01


def test_calibration_recovers_compute_derate():
    g = fsdp_stack(10, 8)
    res = simulate(g, SYS, TOPO, compute_derate=0.45, keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(res, graph=g))
    cal = calibrate(g, tl, SYS, TOPO)          # starts from 0.6
    assert cal.compute_derate == pytest.approx(0.45, rel=0.05)


def test_calibrated_params_plug_into_dse():
    """cal.system/.topology/.compute_derate feed dse.explore directly; on
    an identical config the trial must reproduce the calibrated model's
    prediction."""
    g = fsdp_stack(6, 8)
    true_sys = SYS.replace(hbm_bw=SYS.hbm_bw * 0.7)
    res = simulate(g, true_sys, build_topology(true_sys), keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(res, graph=g))
    cal = calibrate(g, tl, SYS, TOPO)
    trials = dse.explore(lambda cfg: g, cal.system,
                         [dse.Knob("prefetch", [None, 2])],
                         compute_derate=cal.compute_derate,
                         topo=cal.topology)
    assert len(trials) == 2
    direct = simulate(g, cal.system, cal.topology,
                      compute_derate=cal.compute_derate).total_time
    base = next(t for t in trials if t.config["prefetch"] is None)
    assert base.result.total_time == direct
    # a trial that sweeps a topology knob rebuilds the topology
    sweep = dse.explore(lambda cfg: g, cal.system,
                        [dse.Knob("link_bw", [cal.system.link_bw * 0.5],
                                  layer="hardware")],
                        compute_derate=cal.compute_derate,
                        topo=cal.topology)
    assert sweep[0].result.total_time > direct


def test_calibrate_rejects_unalignable_trace():
    g = fsdp_stack(2, 4)
    with pytest.raises(ValueError):
        calibrate(g, ingest_chrome_trace([]), SYS, TOPO)


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_export_validate_calibrate_roundtrip(tmp_path, capsys):
    g = fsdp_stack(6, 4)
    gpath = str(tmp_path / "graph.json")
    tpath = str(tmp_path / "trace.json")
    cpath = str(tmp_path / "cal.json")
    rpath = str(tmp_path / "report.json")
    g.save(gpath)
    common = ["--chips", "8", "--topology", "switch"]
    assert trace_cli(["export", gpath, "-o", tpath, "--ranks", "4"]
                     + common) == 0
    assert trace_cli(["validate", gpath, tpath, "--json", rpath,
                      "--max-error", "0.01"] + common) == 0
    rep = json.load(open(rpath))
    assert rep["match_fraction"] == 1.0 and rep["n_ranks"] == 4
    assert trace_cli(["calibrate", gpath, tpath, "-o", cpath, "--validate"]
                     + common) == 0
    cal = json.load(open(cpath))
    assert "system" in cal and "compute_derate" in cal
    # calibrated-system file round-trips through --system
    assert trace_cli(["validate", gpath, tpath, "--system", cpath,
                      "--max-error", "0.01"]) == 0
    # a wrong hardware model trips the --max-error gate
    assert trace_cli(["validate", gpath, tpath, "--link-bw", "1e9",
                      "--max-error", "0.01"] + common) == 1
    capsys.readouterr()


# ---------------------------------------------------------------------------
# satellites: cluster memoization, group-attr mapping, GIL warning
# ---------------------------------------------------------------------------

def test_simulate_cluster_result_memoized():
    """Identical (config, profile-set) cluster calls must reuse the cached
    result instead of re-running the K-rank engine, and the cached copy
    must be isolated from caller mutation."""
    g = rand_graph(random.Random(11), 60)
    profs = {1: RankProfile(compute_scale=0.5)}
    a = simulate_cluster(g, SYS, TOPO, n_ranks=4, rank_profiles=profs)
    cg = compile_graph(g)
    calls = []
    orig = cg.run_cluster

    def counting(*args, **kw):
        calls.append(1)
        return orig(*args, **kw)

    cg.run_cluster = counting
    try:
        b = simulate_cluster(g, SYS, TOPO, n_ranks=4, rank_profiles=profs)
        assert not calls                      # pure cache hit
        # different profile set, K, or keep_timeline are distinct entries
        simulate_cluster(g, SYS, TOPO, n_ranks=4)
        assert len(calls) == 1
        simulate_cluster(g, SYS, TOPO, n_ranks=8, rank_profiles=profs)
        assert len(calls) == 2
        simulate_cluster(g, SYS, TOPO, n_ranks=4, rank_profiles=profs,
                         keep_timeline=True)
        assert len(calls) == 3                # timelines are never cached
    finally:
        cg.run_cluster = orig
    assert b.step_time == a.step_time
    assert b.rank_times == a.rank_times
    # mutating a returned result must not poison the cache
    b.results[0].total_time = -1.0
    c = simulate_cluster(g, SYS, TOPO, n_ranks=4, rank_profiles=profs)
    assert c.step_time == a.step_time
    assert c.results[0].total_time >= 0.0


def test_straggler_sweep_reuses_cluster_cache():
    """Repeating an identical hetero DSE config costs zero extra engine
    runs (the ROADMAP open item this satellite closes)."""
    g = fsdp_stack(6, 8)
    cfg = {"degraded_fraction": 0.25, "degraded_link_scale": 0.5}
    dse.evaluate(g, SYS, cfg)
    cg = compile_graph(g)
    calls = []
    orig = cg.run_cluster
    cg.run_cluster = lambda *a, **k: (calls.append(1), orig(*a, **k))[1]
    try:
        dse.evaluate(g, SYS, cfg)
    finally:
        cg.run_cluster = orig
    assert not calls


def test_group_instances_mapping():
    # consecutive: historical block tiling
    assert _group_instances([0, 1], 4) == [(0, 1), (0, 1), (2, 3), (2, 3)]
    # whole world
    assert _group_instances(list(range(8)), 4) == [tuple(range(4))] * 4
    # strided: interleaved instances (cross-pod DP groups)
    m = _group_instances([0, 2, 4, 6], 8)
    assert m[0] == m[2] == m[4] == m[6] == (0, 2, 4, 6)
    assert m[1] == m[3] == m[5] == m[7] == (1, 3, 5, 7)
    # strided tiling beyond one span
    m = _group_instances([0, 2], 8)
    assert m[0] == m[2] == (0, 2) and m[1] == m[3] == (1, 3)
    assert m[4] == m[6] == (4, 6) and m[5] == m[7] == (5, 7)
    # stride lattice anchored at the listed group: [5, 9, 13] must form
    # one instance even though 5 is not span-aligned
    m = _group_instances([5, 9, 13], 24)
    assert m[5] == m[9] == m[13] == (5, 9, 13)
    assert m[17] == m[21] == (17, 21)             # partial tail translate
    assert m[6] == m[10] == m[14] == (6, 10, 14)  # phase translate
    assert m[1] is None and m[2] is None          # below the anchor
    # arbitrary explicit list: translated by span; uncovered ranks solo
    m = _group_instances([0, 1, 4], 10)
    assert m[0] == m[1] == m[4] == (0, 1, 4)
    assert m[5] == m[6] == m[9] == (5, 6, 9)
    assert m[2] is m[3] is m[7] is m[8] is None
    # degenerate
    assert _group_instances([3], 4) == [None] * 4


def test_strided_group_barrier_gates_only_its_instance():
    """group=[0,2,4,6] on 8 ranks: a straggler on an odd rank gates only
    the odd instance; even ranks stay nominal.  Coalesced == naive."""
    K = 8
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=1.0)
    c = g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-gather",
              comm_bytes=1e6, group=[0, 2, 4, 6])
    g.add("b", chakra.COMP, deps=[c], flops=1.0)
    sysc = SystemConfig(chips=K, topology="switch")
    topo = build_topology(sysc, K)
    nominal = simulate(g, sysc, topo).total_time
    rd = {1: {a: 7e-3}}
    cr = simulate_cluster(g, sysc, topo, n_ranks=K, rank_durations=rd)
    for r in (0, 2, 4, 6):
        assert cr.rank_result(r).total_time == nominal, r
    for r in (1, 3, 5, 7):
        assert cr.rank_result(r).total_time > nominal, r
    assert cr.barrier_wait[3] > 0.0 and cr.barrier_wait[0] == 0.0
    naive = simulate_cluster(g, sysc, topo, n_ranks=K, rank_durations=rd,
                             coalesce=False)
    assert cr.rank_times == naive.rank_times
    assert cr.barrier_wait == naive.barrier_wait


def test_explicit_group_barrier_and_uncovered_ranks():
    """An arbitrary explicit group gates its translated instances; ranks
    outside every translate never wait."""
    K = 10
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=1.0)
    c = g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-reduce",
              comm_bytes=1e6, group=[0, 1, 4])
    g.add("b", chakra.COMP, deps=[c], flops=1.0)
    sysc = SystemConfig(chips=K, topology="switch")
    topo = build_topology(sysc, K)
    nominal = simulate(g, sysc, topo).total_time
    rd = {9: {a: 7e-3}}                       # straggler in instance {5,6,9}
    cr = simulate_cluster(g, sysc, topo, n_ranks=K, rank_durations=rd)
    for r in (0, 1, 2, 3, 4, 7, 8):
        assert cr.rank_result(r).total_time == nominal, r
    for r in (5, 6):
        assert cr.rank_result(r).total_time > nominal, r
    naive = simulate_cluster(g, sysc, topo, n_ranks=K, rank_durations=rd,
                             coalesce=False)
    assert cr.rank_times == naive.rank_times


def test_strided_groups_roundtrip_through_trace():
    """Cluster trace export keeps per-instance skew: the strided-group
    barrier shows up in the ingested timeline's per-rank totals."""
    K = 4
    g = chakra.Graph()
    a = g.add("a", chakra.COMP, flops=1e9)
    g.add("c", chakra.COMM_COLL, deps=[a], comm_kind="all-reduce",
          comm_bytes=1e6, group=[0, 2])
    profs = {0: RankProfile(compute_scale=0.5)}
    cr = simulate_cluster(g, SYS, TOPO, n_ranks=K, rank_profiles=profs,
                          keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(cr, graph=g))
    rep = validate(g, tl, SYS, TOPO, rank_profiles=profs)
    assert rep.match_fraction == 1.0 and rep.e2e_error < 1e-9
    assert tl.total_time(2) == tl.total_time(0)   # gated by rank 0
    assert tl.total_time(1) < tl.total_time(0)    # odd instance unaffected


def test_mpmd_pipeline_roundtrip_4rank_2stage(tmp_path):
    """ISSUE 5 satellite: a 4-rank, 2-stage pipeline MPMD run exports to
    Chrome trace, re-ingests and validates at ~0% e2e error with 100% node
    match per rank — each rank scored against its *own* stage graph."""
    from repro.configs.registry import get_config
    from repro.configs.workload import workload_graph
    from repro.core.convert import split_pipeline_stages

    g = workload_graph(get_config("gemma3-4b", smoke=True),
                       batch_tokens=512, ranks=8)
    prog = split_pipeline_stages(g, 2, replicas=2)     # 4 ranks, 2 stages
    assert prog.n_ranks == 4
    cr = simulate_cluster(prog, SYS, TOPO, keep_timeline=True)
    path = str(tmp_path / "mpmd_trace.json")
    export_chrome_trace(cr, path, graph=prog)
    tl = ingest_chrome_trace(path)
    assert tl.ranks() == [0, 1, 2, 3]
    rep = validate(prog, tl, SYS, TOPO)
    assert rep.n_ranks == 4
    assert rep.match_fraction == 1.0                   # 100% per-rank match
    for row in rep.per_rank:
        assert row["match_fraction"] == 1.0, row
        assert row["e2e_error"] < 1e-9, row
    assert rep.e2e_error < 1e-9                        # ~0% round-trip error
    assert not rep.worst
    # the trace carries per-rank distinct graphs: stage 0 and stage 1
    # processes expose different node sets
    names0 = {e.name for e in tl.rank_events(0)}
    names1 = {e.name for e in tl.rank_events(2)}
    assert names0 != names1
    assert any(n.startswith("send") for n in names0)
    assert any(n.startswith("recv") for n in names1)
    tr = to_chrome_trace(cr, graph=prog)
    assert tr["metadata"]["mpmd"] is True


def test_mpmd_roundtrip_with_straggler_profile():
    """Per-rank profiles skew an MPMD pipeline run; validating under the
    same profiles still reproduces it exactly."""
    from repro.core.convert import split_pipeline_stages

    g = fsdp_stack(6, 2)
    prog = split_pipeline_stages(g, 2, replicas=2)
    profs = {1: RankProfile(compute_scale=0.6)}
    cr = simulate_cluster(prog, SYS, TOPO, rank_profiles=profs,
                          keep_timeline=True)
    tl = ingest_chrome_trace(to_chrome_trace(cr, graph=prog))
    rep = validate(prog, tl, SYS, TOPO, rank_profiles=profs)
    assert rep.match_fraction == 1.0
    assert rep.e2e_error < 1e-9


def test_explore_parallel_warns_gil_only_on_thread_fallback(monkeypatch):
    """With a working fork pool, parallel=N is silent; the one-shot GIL
    warning fires only when the platform forces the thread fallback."""
    import warnings

    from repro.core import pool as poolmod

    g = rand_graph(random.Random(3), 30)
    knobs = [dse.Knob("prefetch", [None, 2])]
    dse.reset_pool_warning()
    try:
        with warnings.catch_warnings():        # pool path never warns GIL
            warnings.simplefilter("error")
            # jax (when loaded by other tests) warns from its at-fork
            # hook; that is not the warning under test
            warnings.filterwarnings("ignore", message=".*os.fork.*")
            dse.explore(lambda cfg: g, SYS, knobs, parallel=2)
        monkeypatch.setattr(poolmod, "pool_available", lambda: False)
        with pytest.warns(RuntimeWarning, match="GIL"):
            dse.explore(lambda cfg: g, SYS, knobs, parallel=2)
        with warnings.catch_warnings():        # second fallback stays silent
            warnings.simplefilter("error")
            dse.explore(lambda cfg: g, SYS, knobs, parallel=2)
            dse.explore(lambda cfg: g, SYS, knobs)   # serial never warns
    finally:
        dse.reset_pool_warning()


def test_span_accessors():
    g = fsdp_stack(3, 4)
    res = simulate(g, SYS, TOPO, keep_timeline=True)
    spans = res.spans()
    assert all(isinstance(s, Span) for s in spans)
    assert all(s.duration == s.end - s.start for s in spans)
    with pytest.raises(ValueError):
        simulate(g, SYS, TOPO).spans()
    cr = simulate_cluster(g, SYS, TOPO, n_ranks=2, keep_timeline=True)
    flat = cr.spans()
    assert {r for r, _ in flat} == {0, 1}
    assert len(flat) == 2 * len(g)
    assert g.node(0).fingerprint() == f"{g.node(0).name}|{g.node(0).type}"
