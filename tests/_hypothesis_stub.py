"""Minimal deterministic stand-in for the ``hypothesis`` API surface used by
this test suite, for containers where hypothesis isn't installed.

Only what ``test_passes.py`` needs: ``integers``, ``floats``, ``booleans``,
``sampled_from``, ``lists(unique=...)``, ``composite``, ``given`` and
``settings``.  ``given`` replays each test ``max_examples`` times with a
seeded ``random.Random`` so failures reproduce across runs (no shrinking).
"""
from __future__ import annotations

import random


class _Strategy:
    def __init__(self, fn):
        self._fn = fn

    def sample(self, rng: random.Random):
        return self._fn(rng)


def integers(min_value, max_value):
    return _Strategy(lambda r: r.randint(min_value, max_value))


def floats(min_value, max_value):
    return _Strategy(lambda r: r.uniform(min_value, max_value))


def booleans():
    return _Strategy(lambda r: r.random() < 0.5)


def sampled_from(seq):
    seq = list(seq)
    return _Strategy(lambda r: r.choice(seq))


def lists(elements: _Strategy, max_size: int = 10, unique: bool = False):
    def gen(r):
        k = r.randint(0, max_size)
        out, seen = [], set()
        for _ in range(k):
            v = elements.sample(r)
            if unique:
                if v in seen:
                    continue
                seen.add(v)
            out.append(v)
        return out
    return _Strategy(gen)


def composite(fn):
    def make(*args, **kwargs):
        def gen(r):
            return fn(lambda s: s.sample(r), *args, **kwargs)
        return _Strategy(gen)
    return make


def given(*strategies):
    def deco(test):
        # NB: expose a zero-arg signature so pytest doesn't read the test's
        # parameters as fixture requests (no functools.wraps here).
        def run():
            n = getattr(test, "_max_examples", 40)
            for i in range(n):
                rng = random.Random(0xF1A7 + i)
                vals = [s.sample(rng) for s in strategies]
                test(*vals)
        run.__name__ = test.__name__
        run.__doc__ = test.__doc__
        run.__module__ = test.__module__
        return run
    return deco


def settings(max_examples: int = 40, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn
    return deco
