"""Process-pool serialization contract: CompiledGraph / RowSpec /
MPMDProgram / Trial survive pickle round-trips with bit-identical run()
results, dropped volatile caches, and preserved memo-key semantics —
what ``repro.core.pool`` workers rely on when shipping results back."""
import pickle
import random

import pytest

from repro.configs.base import SystemConfig
from repro.core import chakra
from repro.core.costmodel import (MPMDProgram, build_topology, compile_graph,
                                  simulate_cluster)
from repro.core.costmodel.compiled import RowSpec, run_rows
from repro.core.dse import Knob, Trial, explore

SYS = SystemConfig(chips=16)
TOPO = build_topology(SYS)


def fsdp_stack(layers: int, width: int = 4,
               scale: float = 1.0) -> chakra.Graph:
    g = chakra.Graph()
    prev = []
    for i in range(layers):
        c = g.add(f"comp{i}", chakra.COMP, deps=prev,
                  flops=(i + 1) * 1e9 * scale, bytes=(width + i) * 1e6)
        a = g.add(f"ar{i}", chakra.COMM_COLL, deps=[c],
                  comm_kind="all-reduce", comm_bytes=(i + 1) * 1e6,
                  group=list(range(16)), out_bytes=8.0)
        prev = [c, a]
    return g


def roundtrip(obj):
    return pickle.loads(pickle.dumps(obj))


def test_compiled_graph_roundtrip_bit_identical():
    g = fsdp_stack(12)
    cg = compile_graph(g)
    dur = cg.durations(SYS, TOPO, "auto", 0.6)
    want = cg.run(dur, keep_timeline=True)
    cg2 = roundtrip(cg)
    dur2 = cg2.durations(SYS, TOPO, "auto", 0.6)
    assert dur2 == dur
    assert cg2.run(dur2, keep_timeline=True) == want
    assert cg2.run(dur2, overlap=False) == cg.run(dur, overlap=False)


def test_compiled_graph_roundtrip_drops_volatile_caches():
    """Workers re-fill their own memo caches; the pickled payload ships
    none of the parent's (smaller payloads, no id()-keyed staleness)."""
    from repro.core.costmodel.simulator import simulate

    g = fsdp_stack(6)
    cg = compile_graph(g)
    simulate(g, SYS, TOPO)                       # warm result + dur caches
    cg.canonical_coll_order(cg.durations(SYS, TOPO, "auto", 0.6))
    assert cg._dur_cache and cg._result_cache
    cg2 = roundtrip(cg)
    for cache in ("_dur_cache", "_result_cache", "_canon_cache",
                  "_delta_cache"):
        assert getattr(cg2, cache) == {}, cache
    # memo-KEY semantics survive: config_key is repr-based, not identity-
    # based, so the unpickled copy keys the same config identically
    assert (cg2.config_key(SYS, TOPO, "auto", 0.6)
            == cg.config_key(SYS, TOPO, "auto", 0.6))


def test_rowspec_roundtrip_preserves_barrier_sharing():
    """A barrier is one shared mutable list across member rows; pickling
    the row list together must keep it shared (pickle's reference
    preservation) or the cluster engine would deadlock."""
    g = fsdp_stack(5)
    cg = compile_graph(g)
    base = cg.durations(SYS, TOPO, "auto", 0.6)
    slow = [d * 1.5 for d in base]
    coll = list(cg._coll_ids)
    assert coll, "stack must have collectives"
    order = cg.canonical_coll_order(base)
    bmap0, bmap1 = {}, {}
    for nid in coll:
        bar = [2, 0.0, (0, 1), max(base[nid], slow[nid]), {},
               {0: nid, 1: nid}]
        bmap0[nid] = bar
        bmap1[nid] = bar
    rows = [RowSpec(cg, base, bmap0, order),
            RowSpec(cg, slow, bmap1, order)]

    # pickle BEFORE running: the engine consumes barrier state in place
    rows2 = roundtrip(rows)
    for nid in coll:
        assert rows2[0].bmap[nid] is rows2[1].bmap[nid]
        assert rows2[0].bmap[nid] is not rows[0].bmap[nid]
    assert rows2[0].cg is rows2[1].cg             # shared graph too
    assert run_rows(rows2) == run_rows(rows)


def test_mpmd_program_roundtrip():
    # same collective program per rank (an MPMD contract), different compute
    ga, gb = fsdp_stack(4), fsdp_stack(4, scale=2.5)
    prog = MPMDProgram([ga, ga, gb, gb])
    want = simulate_cluster(prog, SYS, TOPO)
    prog.meta["x"] = 1
    prog2 = roundtrip(prog)
    assert prog2.n_ranks == 4 and prog2.n_graphs == 2
    assert prog2.graph_for(0) is prog2.graph_for(1)   # dedup survives
    assert prog2._result_cache == {}                  # volatile memo dropped
    assert prog2.meta == {"x": 1}
    got = simulate_cluster(prog2, SYS, TOPO)
    assert got.step_time == want.step_time
    assert [r.total_time for r in got.results] \
        == [r.total_time for r in want.results]


def test_trial_roundtrip():
    g = fsdp_stack(4)
    knobs = [Knob("prefetch", [None, 2])]
    t = explore(lambda cfg: g, SYS, knobs)[0]
    t2 = roundtrip(t)
    assert isinstance(t2, Trial)
    assert t2.config == t.config and t2.objective == t.objective
    assert t2.result.total_time == t.result.total_time
    assert t2.result == t.result
