"""HLO text parser: shapes, replica groups, trip counts, flops, walking."""
import numpy as np
import pytest

from repro.core.hlo_parse import (parse_hlo, parse_replica_groups,
                                  parse_shape_str, while_trip_count,
                                  walk_instructions, instruction_flops)

SAMPLE = """
HloModule jit_f, num_partitions=16

%cond.1 (p: (s32[], f32[8,8])) -> pred[] {
  %p = (s32[], f32[8,8]{1,0}) parameter(0)
  %gte = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(12)
  ROOT %lt = pred[] compare(%gte, %c), direction=LT
}

%body.1 (p2: (s32[], f32[8,8])) -> (s32[], f32[8,8]) {
  %p2 = (s32[], f32[8,8]{1,0}) parameter(0)
  %g0 = s32[] get-tuple-element(%p2), index=0
  %g1 = f32[8,8]{1,0} get-tuple-element(%p2), index=1
  %one = s32[] constant(1)
  %next = s32[] add(%g0, %one)
  %d = f32[8,8]{1,0} dot(%g1, %g1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %ar = f32[8,8]{1,0} all-reduce(%d), channel_id=1, replica_groups=[4,4]<=[16], to_apply=%add
  ROOT %t = (s32[], f32[8,8]) tuple(%next, %ar)
}

ENTRY %main (a: f32[8,8]) -> f32[8,8] {
  %a = f32[8,8]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8,8]) tuple(%z, %a)
  %w = (s32[], f32[8,8]) while(%t0), condition=%cond.1, body=%body.1
  %ag = f32[32,8]{1,0} all-gather(%a), channel_id=2, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  ROOT %out = f32[8,8]{1,0} get-tuple-element(%w), index=1
}
"""


def test_parse_shapes():
    s = parse_shape_str("(f32[2,3]{1,0}, bf16[4]{0})")
    assert [(x.dtype, x.dims) for x in s] == [("f32", (2, 3)), ("bf16", (4,))]
    assert parse_shape_str("s32[]")[0].dims == ()
    assert parse_shape_str("bf16[4]")[0].bytes == 8
    assert parse_shape_str("f32[4]")[0].tpu_bytes == 8   # normalized to bf16


def test_parse_module_structure():
    mod = parse_hlo(SAMPLE)
    assert mod.num_partitions == 16
    assert mod.entry == "main"
    assert set(mod.computations) == {"cond.1", "body.1", "main"}
    w = mod.entry_computation.find("w")
    assert w.opcode == "while"
    assert w.attrs["condition"].lstrip("%") == "cond.1"


def test_trip_count_and_walk_multiplier():
    mod = parse_hlo(SAMPLE)
    assert while_trip_count(mod, "cond.1") == 12
    mults = {ins.name: m for ins, m, _ in walk_instructions(mod)}
    assert mults["d"] == 12
    assert mults["ag"] == 1


def test_dot_flops_with_trip():
    mod = parse_hlo(SAMPLE)
    total = sum(instruction_flops(mod, ins, c) * m
                for ins, m, c in walk_instructions(mod))
    assert total == 12 * 2 * 8 * 8 * 8   # 12 trips x 2MNK


def test_replica_groups_explicit():
    g = parse_replica_groups("{{0,1,2,3},{4,5,6,7}}", 8)
    assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]


def test_replica_groups_iota():
    g = parse_replica_groups("[4,4]<=[16]", 16)
    assert g[0] == [0, 1, 2, 3] and g[3] == [12, 13, 14, 15]


def test_replica_groups_iota_transposed():
    g = parse_replica_groups("[4,4]<=[4,4]T(1,0)", 16)
    # transpose: groups are strided (column groups of the 4x4 device grid)
    assert g[0] == [0, 4, 8, 12]


def test_replica_groups_default():
    assert parse_replica_groups("", 4) == [[0, 1, 2, 3]]


def test_collective_detection():
    mod = parse_hlo(SAMPLE)
    colls = [ins for ins, m, _ in walk_instructions(mod) if ins.is_collective]
    kinds = {c.collective_kind for c in colls}
    assert kinds == {"all-reduce", "all-gather"}


def test_real_compiled_module_roundtrip(subproc):
    """Parse a real compiled module at 8 fake devices; flops must match the
    hand-computed dot count (trip-aware)."""
    out = subproc("""
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.parallel.mesh import make_mesh
from repro.core.hlo_parse import parse_hlo, walk_instructions, instruction_flops
mesh = make_mesh((2, 4), ("data", "model"))
L = 5
def f(stack, x):
    def body(h, w):
        return jax.nn.relu(h @ w), None
    h, _ = jax.lax.scan(body, x, stack)
    return h.sum()
ss = jax.ShapeDtypeStruct((L, 64, 64), jnp.float32)
xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
sh = (NamedSharding(mesh, P(None, None, "model")), NamedSharding(mesh, P("data", None)))
c = jax.jit(f, in_shardings=sh).lower(ss, xs).compile()
mod = parse_hlo(c.as_text())
fl = sum(instruction_flops(mod, i, cn) * m for i, m, cn in walk_instructions(mod))
expect = 5 * 2 * (32 // 2) * 64 * (64 // 4)
assert fl == expect, (fl, expect)
print("flops ok", fl)
""")
    assert "flops ok" in out
