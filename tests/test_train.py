"""Training substrate: optimizer math, microbatching, data, convergence."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config
from repro.models import build_model
from repro.train import (DataConfig, DataIterator, OptConfig, init_train_state,
                         make_batch, make_train_step)
from repro.train.optimizer import (OptState, adamw_update, init_opt_state,
                                   lr_at)


def test_adamw_matches_numpy_reference():
    cfg = OptConfig(lr=1e-2, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.0,
                    grad_clip=1e9, warmup_steps=0, total_steps=10**9,
                    min_lr_ratio=1.0)
    rng = np.random.RandomState(0)
    p = {"w": jnp.asarray(rng.randn(4, 3), jnp.float32)}
    g = {"w": jnp.asarray(rng.randn(4, 3) * 0.1, jnp.float32)}
    st = init_opt_state(p)
    newp, st2, met = adamw_update(cfg, p, g, st)
    # numpy reference (step 1, bias-corrected)
    gn = np.asarray(g["w"])
    mu = 0.1 * gn
    nu = 0.05 * gn ** 2
    mhat = mu / (1 - 0.9)
    nhat = nu / (1 - 0.95)
    ref = np.asarray(p["w"]) - 1e-2 * mhat / (np.sqrt(nhat) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]), ref, rtol=1e-5)


def test_grad_clip_caps_update():
    cfg = OptConfig(lr=1.0, grad_clip=1e-3, warmup_steps=0, total_steps=10**9,
                    min_lr_ratio=1.0, weight_decay=0.0)
    p = {"w": jnp.ones((8, 8), jnp.float32)}
    g = {"w": jnp.full((8, 8), 100.0)}
    _, _, met = adamw_update(cfg, p, g, init_opt_state(p))
    assert float(met["gnorm"]) > 100


def test_lr_schedule_warmup_and_cosine():
    cfg = OptConfig(lr=1.0, warmup_steps=10, total_steps=110,
                    min_lr_ratio=0.1)
    assert float(lr_at(cfg, jnp.asarray(5))) == 0.5
    assert abs(float(lr_at(cfg, jnp.asarray(10))) - 1.0) < 1e-6
    assert abs(float(lr_at(cfg, jnp.asarray(110))) - 0.1) < 1e-3


def test_microbatch_grad_equivalence():
    """microbatches=2 ~= microbatches=1 on the same batch."""
    cfg = get_config("granite-3-8b", smoke=True)
    m = build_model(cfg)
    opt = OptConfig(lr=1e-3, warmup_steps=0, total_steps=100)
    b = make_batch(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                              global_batch=8), 0)
    s1 = init_train_state(m, jax.random.PRNGKey(0), ParallelConfig())
    s2 = init_train_state(m, jax.random.PRNGKey(0), ParallelConfig())
    st1 = jax.jit(make_train_step(m, opt, ParallelConfig(microbatches=1)))
    st2 = jax.jit(make_train_step(m, opt, ParallelConfig(microbatches=2)))
    n1, m1 = st1(s1, b)
    n2, m2 = st2(s2, b)
    l1 = jax.tree_util.tree_leaves(n1.params)
    l2 = jax.tree_util.tree_leaves(n2.params)
    for a, bb in zip(l1, l2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(bb, np.float32), atol=3e-2)


def test_loss_decreases_100M_scale():
    """End-to-end driver contract: a small model learns the synthetic data."""
    cfg = get_config("qwen3-8b", smoke=True)
    m = build_model(cfg)
    par = ParallelConfig()
    step = jax.jit(make_train_step(
        m, OptConfig(lr=1e-2, warmup_steps=5, total_steps=60), par))
    state = init_train_state(m, jax.random.PRNGKey(0), par)
    it = DataIterator(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                 global_batch=8))
    first = last = None
    for i in range(40):
        state, metrics = step(state, next(it))
        if i == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first - 0.5, (first, last)


def test_data_determinism_and_resume():
    dc = DataConfig(vocab_size=1000, seq_len=32, global_batch=4)
    b1 = make_batch(dc, 7)
    b2 = make_batch(dc, 7)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]),
                                  np.asarray(b2["tokens"]))
    it1 = DataIterator(dc, start_step=0)
    for _ in range(5):
        next(it1)
    b_at_5 = next(it1)
    it2 = DataIterator(dc, start_step=5)   # resumed iterator
    b_resumed = next(it2)
    np.testing.assert_array_equal(np.asarray(b_at_5["tokens"]),
                                  np.asarray(b_resumed["tokens"]))


def test_labels_are_shifted_tokens():
    dc = DataConfig(vocab_size=1000, seq_len=32, global_batch=2)
    b = make_batch(dc, 3)
    np.testing.assert_array_equal(np.asarray(b["tokens"][:, 1:]),
                                  np.asarray(b["labels"][:, :-1]))
