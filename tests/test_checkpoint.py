"""Checkpointing + fault tolerance: round-trip, keep-k, resume replay,
failure injection, straggler detection."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ParallelConfig
from repro.configs.registry import get_config
from repro.models import build_model
from repro.train import (DataConfig, DataIterator, OptConfig,
                         init_train_state, latest_step, make_train_step,
                         restore_checkpoint, save_checkpoint)
from repro.train.fault import (FaultInjector, SimulatedFault,
                               StragglerMonitor, run_with_retry)


def _setup():
    cfg = get_config("granite-3-8b", smoke=True)
    m = build_model(cfg)
    par = ParallelConfig()
    step = jax.jit(make_train_step(
        m, OptConfig(lr=1e-3, warmup_steps=2, total_steps=50), par))
    state = init_train_state(m, jax.random.PRNGKey(0), par)
    it = DataIterator(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                 global_batch=4))
    return m, step, state, it


def test_roundtrip(tmp_path):
    m, step, state, it = _setup()
    state, _ = step(state, next(it))
    save_checkpoint(str(tmp_path), 1, state)
    restored, meta = restore_checkpoint(str(tmp_path), 1, state)
    assert meta["step"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(state),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_k(tmp_path):
    m, step, state, it = _setup()
    for s in range(1, 6):
        save_checkpoint(str(tmp_path), s, state, keep=2)
    steps = sorted(d for d in os.listdir(tmp_path) if d.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]
    assert latest_step(str(tmp_path)) == 5


def test_async_save_joins(tmp_path):
    m, step, state, it = _setup()
    t = save_checkpoint(str(tmp_path), 3, state, async_save=True)
    t.join(timeout=60)
    assert latest_step(str(tmp_path)) == 3


def test_crash_resume_replays_exact_stream(tmp_path):
    """Train 6 steps straight vs train 3 + crash + resume 3: same params."""
    m, step, s_a, it_a = _setup()
    for _ in range(6):
        s_a, _ = step(s_a, next(it_a))

    _, step_b, s_b, it_b = _setup()
    for _ in range(3):
        s_b, _ = step_b(s_b, next(it_b))
    save_checkpoint(str(tmp_path), 3, s_b)
    # "crash"; restore into fresh state and a resumed iterator
    _, step_c, s_c, _ = _setup()
    s_c, meta = restore_checkpoint(str(tmp_path), 3, s_c)
    it_c = DataIterator(DataConfig(vocab_size=get_config(
        "granite-3-8b", smoke=True).vocab_size, seq_len=32, global_batch=4),
        start_step=meta["step"])
    for _ in range(3):
        s_c, _ = step_c(s_c, next(it_c))
    for a, b in zip(jax.tree_util.tree_leaves(s_a.params),
                    jax.tree_util.tree_leaves(s_c.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=1e-5)


def test_elastic_restore_across_device_counts(subproc):
    """Checkpoint on 4 devices, restore+step on 8 (DESIGN.md SS7 elasticity)."""
    code_save = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.mesh import make_mesh
from repro.train import save_checkpoint
mesh = make_mesh((4,), ("data",))
x = jax.device_put(np.arange(64, dtype=np.float32).reshape(8, 8),
                   NamedSharding(mesh, P("data")))
save_checkpoint("{d}", 1, {{"x": x}})
print("saved")
"""
    code_load = """
import jax, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.parallel.mesh import make_mesh
from repro.train import restore_checkpoint
mesh = make_mesh((8,), ("data",))
tpl = {{"x": jax.ShapeDtypeStruct((8, 8), "float32")}}
sh = {{"x": NamedSharding(mesh, P("data"))}}
st, meta = restore_checkpoint("{d}", 1, tpl, sh)
assert st["x"].sharding.num_devices == 8
np.testing.assert_array_equal(np.asarray(st["x"]),
                              np.arange(64, dtype=np.float32).reshape(8, 8))
print("elastic ok")
"""
    import tempfile
    d = tempfile.mkdtemp()
    out = subproc(code_save.format(d=d), devices=4)
    assert "saved" in out
    out = subproc(code_load.format(d=d), devices=8)
    assert "elastic ok" in out


def test_fault_injection_and_retry():
    calls = []

    def flaky():
        calls.append(1)
        if len(calls) < 3:
            raise SimulatedFault("boom")
        return "ok"

    assert run_with_retry(flaky, retries=3) == "ok"
    assert len(calls) == 3
    with pytest.raises(SimulatedFault):
        run_with_retry(lambda: (_ for _ in ()).throw(SimulatedFault("x")),
                       retries=1)


def test_injector_transient_fires_once():
    inj = FaultInjector(fail_steps=(5,))
    with pytest.raises(SimulatedFault):
        inj.check(5)
    inj.check(5)  # second attempt passes


def test_straggler_monitor():
    mon = StragglerMonitor(window=20, threshold=2.0)
    for s in range(10):
        assert not mon.record(s, 0.1)
    assert mon.record(10, 0.5)
    assert mon.straggler_steps[0][0] == 10


def test_state_nbytes_and_fault_policy_bridge():
    from repro.faults import CheckpointPolicy
    from repro.train.checkpoint import (checkpoint_policy_for_state,
                                        state_nbytes)
    state = {"w": jnp.ones((8, 4), jnp.float32),
             "b": jnp.ones((4,), jnp.bfloat16)}
    assert state_nbytes(state) == 8 * 4 * 4 + 4 * 2
    pol = checkpoint_policy_for_state(state, interval=16, write_bw=136.0,
                                      restore_bw=68.0)
    assert isinstance(pol, CheckpointPolicy)
    assert pol.interval == 16
    assert pol.write_cost == pytest.approx(1.0)     # 136 B at 136 B/s
    assert pol.restore_cost == pytest.approx(2.0)
