"""mixtral-8x7b [moe]: 8 experts top-2, sliding-window attention.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000 [arXiv:2401.04088].
8 experts do not divide the 16-way model axis, so expert weights are
tensor-parallel over d_ff (moe_strategy="tp"); see DESIGN.md SS5.
"""
from repro.configs.base import ModelConfig, LOCAL_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b",
        family="moe",
        num_layers=32,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=14336,
        vocab_size=32_000,
        superblock=(LOCAL_ATTN,),     # SWA on every layer
        sb_repeat=32,
        local_window=4096,
        num_experts=8,
        experts_per_token=2,
        rope_theta=1_000_000.0,
        act="silu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="mixtral-smoke",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sb_repeat=3,
        local_window=32,
        num_experts=4,
        experts_per_token=2,
    )
