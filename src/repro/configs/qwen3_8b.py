"""qwen3-8b [dense]: qk_norm, GQA.

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936 [hf:Qwen/Qwen3-8B].
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="qwen3-8b",
        family="dense",
        num_layers=36,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12288,
        vocab_size=151_936,
        superblock=(GLOBAL_ATTN,),
        sb_repeat=36,
        qk_norm=True,
        rope_theta=1_000_000.0,
        act="silu",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="qwen3-smoke",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sb_repeat=3,
    )
