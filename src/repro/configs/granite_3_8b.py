"""granite-3-8b [dense]: GQA.

40L d_model=4096 32H (GQA kv=8) d_ff=12800 vocab=49155 [hf:ibm-granite family].
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b",
        family="dense",
        num_layers=40,
        d_model=4096,
        num_heads=32,
        num_kv_heads=8,
        head_dim=128,
        d_ff=12800,
        vocab_size=49_155,
        superblock=(GLOBAL_ATTN,),
        sb_repeat=40,
        rope_theta=10_000.0,
        act="silu",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="granite-smoke",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sb_repeat=3,
    )
