"""gemma3-4b [dense]: 5:1 local:global attention, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144 [hf:google/gemma-3 family].
Pattern: (5 local + 1 global) x5 + 4 local remainder (34 layers).
"""
from repro.configs.base import ModelConfig, LOCAL_ATTN, GLOBAL_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-4b",
        family="dense",
        num_layers=34,
        d_model=2560,
        num_heads=8,
        num_kv_heads=4,
        head_dim=256,
        d_ff=10240,
        vocab_size=262_144,
        superblock=(LOCAL_ATTN,) * 5 + (GLOBAL_ATTN,),
        sb_repeat=5,
        remainder=(LOCAL_ATTN,) * 4,
        local_window=1024,
        rope_theta=10_000.0,
        rope_theta_global=1_000_000.0,
        qk_norm=True,
        act="gelu",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="gemma3-4b-smoke",
        num_layers=4,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        superblock=(LOCAL_ATTN, LOCAL_ATTN, GLOBAL_ATTN),
        sb_repeat=1,
        remainder=(LOCAL_ATTN,),
        local_window=32,
    )
