"""gemma3-12b [dense]: 5:1 local:global attention, 128k context.

48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 [hf:google/gemma-3 family].
Pattern: (5 local + 1 global) x8 (48 layers).
"""
from repro.configs.base import ModelConfig, LOCAL_ATTN, GLOBAL_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="gemma3-12b",
        family="dense",
        num_layers=48,
        d_model=3840,
        num_heads=16,
        num_kv_heads=8,
        head_dim=256,
        d_ff=15360,
        vocab_size=262_144,
        superblock=(LOCAL_ATTN,) * 5 + (GLOBAL_ATTN,),
        sb_repeat=8,
        local_window=1024,
        rope_theta=10_000.0,
        rope_theta_global=1_000_000.0,
        qk_norm=True,
        act="gelu",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="gemma3-12b-smoke",
        num_layers=6,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        superblock=(LOCAL_ATTN, LOCAL_ATTN, GLOBAL_ATTN),
        sb_repeat=2,
        remainder=(),
        local_window=32,
    )
