"""llama-3.2-vision-90b [vlm]: cross-attn image layers.

100L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=128256
[hf:meta-llama/Llama-3.2-11B-Vision scaled family].
Pattern: every 5th layer cross-attends to vision-patch embeddings; the
vision tower is a STUB (input_specs() provides precomputed patch embeddings).
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN, CROSS_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="llama-3.2-vision-90b",
        family="vlm",
        num_layers=100,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=28672,
        vocab_size=128_256,
        superblock=(GLOBAL_ATTN,) * 4 + (CROSS_ATTN,),
        sb_repeat=20,
        context_tokens=1601,    # stubbed vision tokens (1600 patches + CLS)
        rope_theta=500_000.0,
        act="silu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="llama-vision-smoke",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sb_repeat=1,
        context_tokens=17,
    )
