"""Config schema for Flint-JAX.

Three layers of configuration, mirroring the paper's Fig. 2 split:
  * ModelConfig     -- the workload (green box): architecture dims + layer pattern.
  * ShapeConfig     -- the input shape cell (train_4k / prefill_32k / ...).
  * ParallelConfig  -- the software-system knobs (red box): sharding, remat, ...
  * SystemConfig    -- the hardware-system knobs (yellow box): used by cost models.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional

# Layer kinds used in ``layer_pattern``. A model is a stack of "superblocks";
# each superblock is a tuple of layer kinds that repeats ``repeat`` times,
# optionally followed by a remainder pattern. scan-over-superblocks keeps the
# lowered HLO small and compile times flat regardless of depth.
GLOBAL_ATTN = "global"      # full causal attention
LOCAL_ATTN = "local"        # sliding-window causal attention
CROSS_ATTN = "cross"        # cross-attention to encoder/vision memory
RGLRU = "rglru"             # RG-LRU recurrent block (recurrentgemma)
SSD = "ssd"                 # Mamba2 state-space duality block
ENC_ATTN = "enc"            # bidirectional encoder self-attention

ATTENTION_KINDS = (GLOBAL_ATTN, LOCAL_ATTN, CROSS_ATTN, ENC_ATTN)
RECURRENT_KINDS = (RGLRU, SSD)


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int                  # decoder/backbone layers
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int

    # Layer pattern: ``superblock`` repeated ``sb_repeat`` times then
    # ``remainder``. len(superblock)*sb_repeat + len(remainder) == num_layers.
    superblock: tuple = (GLOBAL_ATTN,)
    sb_repeat: int = 0
    remainder: tuple = ()

    # attention details
    local_window: int = 0            # sliding window size for LOCAL_ATTN
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    rope_theta_global: float = 0.0   # gemma3 uses a different theta for global layers
    logits_soft_cap: float = 0.0

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25

    # SSM (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    conv_width: int = 4
    ssm_chunk: int = 256

    # RG-LRU (recurrentgemma)
    rnn_width: int = 0               # recurrence width (d_rnn); 0 -> d_model
    rglru_conv_width: int = 4

    # encoder-decoder (seamless) -- encoder is its own uniform stack
    encoder_layers: int = 0
    encoder_len: int = 0             # stubbed audio-frame count

    # vlm -- cross-attention context from the (stubbed) vision frontend
    context_tokens: int = 0          # image tokens per sample

    act: str = "silu"                # mlp activation: silu (SwiGLU) | gelu (GeGLU)
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    # ------------------------------------------------------------------
    def __post_init__(self):
        got = len(self.superblock) * self.sb_repeat + len(self.remainder)
        if got != self.num_layers:
            raise ValueError(
                f"{self.name}: layer pattern covers {got} layers, "
                f"config says num_layers={self.num_layers}")

    @property
    def layer_kinds(self) -> tuple:
        return tuple(self.superblock) * self.sb_repeat + tuple(self.remainder)

    @property
    def d_inner(self) -> int:        # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def d_rnn(self) -> int:
        return self.rnn_width or self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """True if the arch is not *pure* full attention (long_500k applicable).

        Local/sliding-window or recurrent (SSM / RG-LRU) layers bound the
        per-layer cache; the few interleaved global layers (gemma3) are linear
        in cache length at decode time and get a sequence-sharded cache.
        """
        kinds = set(self.layer_kinds)
        return bool(kinds & {LOCAL_ATTN, RGLRU, SSD})

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def param_count(self) -> int:
        """Analytic parameter count (used for 6ND model flops + sanity checks)."""
        d, h, kv, hd, ff, v = (self.d_model, self.num_heads, self.num_kv_heads,
                               self.head_dim, self.d_ff, self.vocab_size)
        n = v * d                                     # embeddings
        if not self.tie_embeddings:
            n += v * d
        glu = 3 if self.act in ("silu", "gelu") else 2

        def attn_params():
            return d * h * hd + 2 * d * kv * hd + h * hd * d

        def mlp_params(e=1):
            return e * glu * d * ff

        for kind in self.layer_kinds:
            n += 2 * d                                # pre-norms (attn + mlp)
            if kind in (GLOBAL_ATTN, LOCAL_ATTN, ENC_ATTN):
                n += attn_params()
                n += mlp_params(self.num_experts or 1)
                if self.num_experts:
                    n += d * self.num_experts         # router
            elif kind == CROSS_ATTN:
                n += attn_params() + mlp_params()
            elif kind == RGLRU:
                dr = self.d_rnn
                n += 2 * d * dr + dr * d              # in(x2)/out proj
                n += self.rglru_conv_width * dr       # temporal conv
                n += 2 * dr                           # gates (a, input)
                n += mlp_params()
            elif kind == SSD:
                di, ns, nh = self.d_inner, self.ssm_state, self.ssm_heads
                n += d * (2 * di + 2 * ns + nh)       # in_proj (x,z,B,C,dt)
                n += self.conv_width * (di + 2 * ns)  # conv
                n += 2 * nh                           # A_log, D
                n += di * d                           # out_proj
        # encoder stack (uniform enc layers: self-attn + mlp)
        n += self.encoder_layers * (attn_params() + mlp_params() + 2 * self.d_model)
        n += self.d_model                              # final norm
        return n

    def active_param_count(self) -> int:
        """MoE: params touched per token (for 6·N_active·D)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        glu = 3
        per_layer_moe = self.num_experts * glu * self.d_model * self.d_ff
        active_moe = self.experts_per_token * glu * self.d_model * self.d_ff
        n_moe_layers = sum(1 for k in self.layer_kinds if k in (GLOBAL_ATTN, LOCAL_ATTN))
        return full - n_moe_layers * (per_layer_moe - active_moe)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def is_serve(self) -> bool:
        return self.kind in ("prefill", "decode")


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)
ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES = {s.name: s for s in ALL_SHAPES}


@dataclass(frozen=True)
class ParallelConfig:
    """Software-system knobs (sharding strategy etc.)."""
    fsdp: bool = True                # shard big params over the data axis too
    model_axis: str = "tp"           # tp | zero3 (what the model axis does)
    seq_shard: bool = True           # sequence-parallel activation constraints
    remat: str = "dots"              # none | dots | full
    microbatches: int = 1            # gradient-accumulation microbatches
    grad_compression: bool = False   # int8 all-reduce with error feedback
    attn_impl: str = "xla"           # xla | pallas | interpret
    moe_strategy: str = "auto"       # auto | ep | tp
    pipeline_stages: int = 1         # >1: GPipe over the "pod" axis
    scan_layers: bool = True
    # decode-time
    seq_shard_cache: bool = False    # shard KV cache over data axis (long ctx)

    def replace(self, **kw) -> "ParallelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class SystemConfig:
    """Hardware-system knobs consumed by the cost models (paper Fig 2 bottom).

    Defaults = TPU v5e.
    """
    peak_flops: float = 197e12       # bf16 FLOP/s per chip
    hbm_bw: float = 819e9            # bytes/s per chip
    link_bw: float = 50e9            # bytes/s per ICI link (per direction)
    link_latency: float = 1e-6       # seconds per hop
    dcn_bw: float = 12.5e9           # bytes/s per host cross-pod (DCN)
    dcn_latency: float = 10e-6
    topology: str = "torus2d"        # switch | ring | torus2d | torus3d | wafer2d
    collective_algo: str = "auto"    # auto | ring | hd | 2d_synth
    chips: int = 256

    def replace(self, **kw) -> "SystemConfig":
        return dataclasses.replace(self, **kw)
