"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

from repro.configs import (recurrentgemma_9b, seamless_m4t_medium,
                           llama32_vision_90b, mamba2_780m, gemma3_4b,
                           qwen3_8b, granite_3_8b, gemma3_12b, mixtral_8x7b,
                           dbrx_132b)
from repro.configs.base import (ModelConfig, ShapeConfig, SHAPES, ALL_SHAPES,
                                GLOBAL_ATTN)

_MODULES = {
    "recurrentgemma-9b": recurrentgemma_9b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "llama-3.2-vision-90b": llama32_vision_90b,
    "mamba2-780m": mamba2_780m,
    "gemma3-4b": gemma3_4b,
    "qwen3-8b": qwen3_8b,
    "granite-3-8b": granite_3_8b,
    "gemma3-12b": gemma3_12b,
    "mixtral-8x7b": mixtral_8x7b,
    "dbrx-132b": dbrx_132b,
}

ARCH_NAMES = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; available: {ARCH_NAMES}")
    mod = _MODULES[name]
    return mod.smoke() if smoke else mod.full()


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]


def cell_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether an (arch x shape) cell runs, and why not if it doesn't.

    long_500k needs sub-quadratic attention: a pure full-attention arch would
    need a dense 524k-token KV cache per global layer with batch=1 -- skipped
    per the assignment and DESIGN.md SSArch-applicability.
    """
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: long_500k skipped (see DESIGN.md)"
    return True, ""


def all_cells(smoke: bool = False):
    """Yield (arch_name, ModelConfig, ShapeConfig, applicable, reason)."""
    for name in ARCH_NAMES:
        cfg = get_config(name, smoke=smoke)
        for shape in ALL_SHAPES:
            ok, why = cell_applicable(get_config(name), shape)
            yield name, cfg, shape, ok, why
