"""Analytic workload-zoo graph builder: ``ModelConfig`` -> Chakra graph.

The capture pipeline (``repro.core.capture``) produces exact graphs but
needs jax + fake devices; the zoo conformance suite and the MPMD pipeline
machinery need *a* faithful graph for every registry arch without either.
``workload_graph`` emits the standard FSDP train-step skeleton straight
from the config's analytic dimensions:

  per layer:  all-gather(weights)  ->  fwd COMP  [-> all-to-all for MoE
              layers]  ->  bwd COMP  ->  all-reduce(grads)

with flops from the 6·N·D rule split 2·N·D forward / 4·N·D backward (plus
the quadratic attention term for attention layers), per-layer parameter
bytes as the collective payloads, and activation ``out_bytes`` so memory
liveness and the pipeline splitter's P2P payloads are meaningful.  The
resulting graph exercises every node type the cost model prices and splits
cleanly into 2–8 pipeline stages (``convert.split_pipeline_stages``).
"""
from __future__ import annotations

from repro.configs.base import ATTENTION_KINDS, ModelConfig
from repro.core import chakra

_BF16 = 2.0


def workload_graph(cfg: ModelConfig, batch_tokens: int = 2048,
                   ranks: int = 8, with_backward: bool = True) -> chakra.Graph:
    """FSDP train-step (or forward-only) graph for one registry arch.

    `ranks` is the data-parallel group the collectives span; the graph is
    the rank-symmetric SPMD view (feed it to ``simulate``/
    ``simulate_cluster`` directly, or through ``split_pipeline_stages`` for
    an MPMD pipeline program).
    """
    if ranks < 1:
        raise ValueError(f"ranks must be >= 1, got {ranks}")
    g = chakra.Graph(meta={"source": "configs.workload", "arch": cfg.name,
                           "ranks": ranks, "batch_tokens": batch_tokens})
    group = list(range(ranks))
    kinds = cfg.layer_kinds or ("global",)
    L = len(kinds)
    params_layer = cfg.param_count() / max(L, 1)
    pbytes = _BF16 * params_layer
    act = _BF16 * batch_tokens * cfg.d_model
    prev = None
    for i, kind in enumerate(kinds):
        ag = g.add(f"ag{i}_{kind}", chakra.COMM_COLL,
                   ctrl_deps=[prev] if prev is not None else [],
                   comm_kind="all-gather", comm_bytes=pbytes,
                   out_bytes=pbytes, group=group, group_size=ranks)
        f_flops = 2.0 * params_layer * batch_tokens
        if kind in ATTENTION_KINDS:
            # QK^T and PV matmuls: 2 * 2 * T^2 * n_heads * head_dim
            f_flops += 4.0 * float(batch_tokens) ** 2 \
                * cfg.num_heads * cfg.head_dim
        fwd = g.add(f"f{i}_{kind}", chakra.COMP,
                    deps=[ag] + ([prev] if prev is not None else []),
                    flops=f_flops, bytes=pbytes + act, out_bytes=act)
        last = fwd
        if cfg.num_experts:
            # expert-parallel dispatch: tokens cross the group twice; one
            # all-to-all stands in for dispatch+combine payload-wise
            last = g.add(f"a2a{i}", chakra.COMM_COLL, deps=[fwd],
                         comm_kind="all-to-all", comm_bytes=2.0 * act,
                         out_bytes=act, group=group, group_size=ranks)
        if with_backward:
            bwd = g.add(f"b{i}_{kind}", chakra.COMP, deps=[last],
                        flops=2.0 * f_flops, bytes=pbytes + 2.0 * act,
                        out_bytes=act)
            g.add(f"ar{i}_{kind}", chakra.COMM_COLL, deps=[bwd],
                  comm_kind="all-reduce", comm_bytes=pbytes, group=group,
                  group_size=ranks)
            prev = bwd
        else:
            prev = last
    g.add("logits", chakra.COMP, deps=[prev],
          flops=2.0 * batch_tokens * cfg.d_model * cfg.vocab_size,
          bytes=act + _BF16 * cfg.d_model * cfg.vocab_size,
          out_bytes=_BF16 * batch_tokens * min(cfg.vocab_size, 4096))
    return g


def pipeline_program(cfg, num_stages: int, *, num_microbatches: int = 1,
                     schedule: str = "gpipe", virtual_stages=None,
                     replicas: int = 1, batch_tokens: int = 2048,
                     assignment="flops", share_replica_graphs=None,
                     with_backward: bool = True):
    """One-call pipeline program for a registry arch: ``workload_graph``
    followed by ``convert.split_pipeline_stages``.

    `cfg` is a ``ModelConfig`` or a registry arch name.  The remaining
    knobs mirror ``split_pipeline_stages``: `replicas` data-parallel copies
    of the pipeline (stage-major ranks), `num_microbatches`/`schedule`/
    `virtual_stages` select the microbatched lowering ("gpipe", "1f1b",
    "interleaved" — see ``repro.core.costmodel.schedule``).  Returns an
    ``MPMDProgram`` over ``num_stages * replicas`` ranks ready for
    ``simulate_cluster``."""
    from repro.core.convert import split_pipeline_stages
    if isinstance(cfg, str):
        from repro.configs.registry import get_config
        cfg = get_config(cfg)
    g = workload_graph(cfg, batch_tokens=batch_tokens, ranks=replicas,
                       with_backward=with_backward)
    return split_pipeline_stages(g, num_stages, assignment=assignment,
                                 replicas=replicas,
                                 num_microbatches=num_microbatches,
                                 schedule=schedule,
                                 virtual_stages=virtual_stages,
                                 share_replica_graphs=share_replica_graphs)
