"""dbrx-132b [moe]: 16 experts top-4, fine-grained MoE.

40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352 [hf:databricks/dbrx-base].
16 experts exactly match the 16-way model axis -> expert parallelism (EP=16).
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="dbrx-132b",
        family="moe",
        num_layers=40,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=10752,
        vocab_size=100_352,
        superblock=(GLOBAL_ATTN,),
        sb_repeat=40,
        num_experts=16,
        experts_per_token=4,
        rope_theta=500_000.0,
        act="silu",
        tie_embeddings=False,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="dbrx-smoke",
        num_layers=3,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sb_repeat=3,
        num_experts=4,
        experts_per_token=2,
    )
