"""seamless-m4t-medium [audio]: encoder-decoder, multimodal.

12L d_model=1024 16H (MHA kv=16) d_ff=4096 vocab=256206 [arXiv:2308.11596].
The speech frontend is a STUB: input_specs() provides precomputed frame
embeddings (batch, encoder_len, d_model); the 12-layer bidirectional encoder
and the 12-layer causal decoder (with cross-attention) are real.
"""
from repro.configs.base import ModelConfig, GLOBAL_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium",
        family="audio",
        num_layers=12,
        d_model=1024,
        num_heads=16,
        num_kv_heads=16,
        head_dim=64,
        d_ff=4096,
        vocab_size=256_206,
        superblock=(GLOBAL_ATTN,),
        sb_repeat=12,
        encoder_layers=12,
        encoder_len=1536,       # ~30 s of speech frames after downsampling (stub)
        act="gelu",
        tie_embeddings=True,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="seamless-smoke",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sb_repeat=2,
        encoder_layers=2,
        encoder_len=24,
    )
