"""mamba2-780m [ssm]: SSD (state-space duality), attention-free.

48L d_model=1536 d_ff=0 vocab=50280 ssm_state=128 [arXiv:2405.21060].
Pure Mamba2 blocks (in_proj -> conv -> SSD scan -> gated out_proj); no MLP.
"""
from repro.configs.base import ModelConfig, SSD


def full() -> ModelConfig:
    return ModelConfig(
        name="mamba2-780m",
        family="ssm",
        num_layers=48,
        d_model=1536,
        num_heads=0,
        num_kv_heads=0,
        head_dim=0,
        d_ff=0,
        vocab_size=50_280,
        superblock=(SSD,),
        sb_repeat=48,
        ssm_state=128,
        ssm_head_dim=64,
        ssm_expand=2,
        conv_width=4,
        ssm_chunk=256,
        act="silu",
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="mamba2-smoke",
        num_layers=3,
        d_model=64,
        vocab_size=512,
        sb_repeat=3,
        ssm_state=16,
        ssm_head_dim=16,
        ssm_chunk=32,
    )
