"""recurrentgemma-9b [hybrid]: RG-LRU + local attention, 1:2 attn:recurrent.

38L d_model=4096 16H (MQA kv=1) d_ff=12288 vocab=256000 [arXiv:2402.19427].
Pattern: (RG-LRU, RG-LRU, local-attn) x12 + 2 RG-LRU remainder.
"""
from repro.configs.base import ModelConfig, RGLRU, LOCAL_ATTN


def full() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256_000,
        superblock=(RGLRU, RGLRU, LOCAL_ATTN),
        sb_repeat=12,
        remainder=(RGLRU, RGLRU),
        local_window=2048,
        rnn_width=4096,
        act="gelu",
        logits_soft_cap=30.0,
    )


def smoke() -> ModelConfig:
    return full().replace(
        name="recurrentgemma-smoke",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=512,
        sb_repeat=1,
        remainder=(RGLRU, RGLRU),
        local_window=32,
        rnn_width=64,
    )
