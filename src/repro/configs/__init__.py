from repro.configs.base import (ModelConfig, ShapeConfig, ParallelConfig,
                                SystemConfig, SHAPES, ALL_SHAPES, TRAIN_4K,
                                PREFILL_32K, DECODE_32K, LONG_500K)
from repro.configs.workload import workload_graph

__all__ = [
    "ModelConfig", "ShapeConfig", "ParallelConfig", "SystemConfig",
    "SHAPES", "ALL_SHAPES", "TRAIN_4K", "PREFILL_32K", "DECODE_32K",
    "LONG_500K", "workload_graph",
]
