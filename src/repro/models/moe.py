"""Mixture-of-Experts with top-k routing (grouped, gather-based, capacity-bounded).

Dispatch follows the Switch-Transformer *group* formulation: tokens are split
into G groups (G = the data-parallel degree), each group routes its own
tokens with per-group capacity C_g = ceil(T_g * k * cf / E).  Because the
group dim is sharded over the data axis and the expert dim over the model
axis, the (G, E, C, D) dispatch tensor's shard transition is exactly the EP
all-to-all — no global token gather (which would all-gather the full
activation per layer).

Combine is gather-based (each token reads its k expert outputs), so no
scatter-add appears on the backward-unfriendly path.

Sharding: "experts"->model when E divides it (EP, dbrx 16e); otherwise
experts replicate and "ff" is tensor-parallel (mixtral 8e on a 16-way axis).
See DESIGN.md SS5/SS6.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec


def moe_specs(cfg):
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    return {
        "router": ParamSpec((d, e), ("embed", None), dtype=jnp.float32),
        "wi": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "wg": ParamSpec((e, d, f), ("experts", "embed", "ff")),
        "wo": ParamSpec((e, f, d), ("experts", "ff", "embed")),
    }


def capacity(tokens: int, num_experts: int, k: int, cf: float) -> int:
    c = int(tokens * k * cf / num_experts)
    return max(8, -(-c // 8) * 8)           # round up to multiple of 8


def moe_apply(p, x, cfg, ctx):
    """x (B,S,D) -> (out (B,S,D), aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    G = ctx.moe_groups if T % max(ctx.moe_groups, 1) == 0 else 1
    G = max(G, 1)
    Tg = T // G
    C = capacity(Tg, E, K, cfg.capacity_factor)
    xt = x.reshape(G, Tg, D)
    xt = ctx.shard(xt, "groups", None, "embed_nos")

    logits = jnp.einsum("gtd,de->gte", xt.astype(jnp.float32), p["router"])
    gates = jax.nn.softmax(logits, axis=-1)                 # (G,Tg,E)
    top_w, top_i = jax.lax.top_k(gates, K)                  # (G,Tg,K)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # load-balancing aux loss (Switch-style)
    density = jnp.mean(jax.nn.one_hot(top_i[..., 0], E), axis=(0, 1))
    density_proxy = jnp.mean(gates, axis=(0, 1))
    aux_loss = E * jnp.sum(density * density_proxy)

    # position of each assignment within its expert, per group
    flat_e = top_i.reshape(G, Tg * K)                       # expert ids
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)     # (G,Tg*K,E)
    pos = jnp.cumsum(onehot, axis=1) - onehot
    pos = jnp.take_along_axis(pos, flat_e[..., None], axis=2)[..., 0]
    keep = pos < C                                          # (G,Tg*K)

    tok_of = jnp.broadcast_to(
        (jnp.arange(Tg * K, dtype=jnp.int32) // K)[None], (G, Tg * K))
    slot = flat_e * C + pos
    slot_safe = jnp.where(keep, slot, E * C)

    def build_tables(slots, toks, keeps):
        idx = jnp.zeros((E * C + 1,), jnp.int32).at[slots].set(toks, mode="drop")
        valid = jnp.zeros((E * C + 1,), bool).at[slots].set(keeps, mode="drop")
        return idx[:-1], valid[:-1]

    idx, valid = jax.vmap(build_tables)(slot_safe, tok_of, keep)  # (G,E*C)

    def gather_tokens(xx, ii):
        return jnp.take(xx, ii, axis=0)

    xg = jax.vmap(gather_tokens)(xt, idx).reshape(G, E, C, D)
    xg = xg * valid.reshape(G, E, C, 1).astype(xg.dtype)
    # EP transition: (groups->data, experts->model) = the dispatch all-to-all
    xg = ctx.shard(xg, "groups", "experts", None, "embed_nos")

    h = jnp.einsum("gecd,edf->gecf", xg, p["wi"])
    g = jnp.einsum("gecd,edf->gecf", xg, p["wg"])
    g = jax.nn.silu(g) if cfg.act == "silu" else jax.nn.gelu(g, approximate=True)
    h = ctx.shard(h * g, "groups", "experts", None, "ff")
    y = jnp.einsum("gecf,efd->gecd", h, p["wo"])            # (G,E,C,D)
    y = ctx.shard(y, "groups", "experts", None, "embed_nos")

    # combine: each (token, k) reads its slot's output (gather, no scatter)
    def read_slots(yy, slots, keeps):
        return jnp.take(yy, jnp.where(keeps, slots, 0), axis=0) \
            * keeps[:, None].astype(yy.dtype)

    yt = jax.vmap(read_slots)(y.reshape(G, E * C, D), slot, keep)
    out = (yt.reshape(G, Tg, K, D)
           * top_w.reshape(G, Tg, K, 1).astype(yt.dtype)).sum(axis=2)
    return out.reshape(B, S, D), aux_loss
