"""Mamba2 block: in_proj -> causal conv -> SSD (state-space duality) -> gated out.

The SSD scan is the chunked algorithm of arXiv:2405.21060 SS6 — quadratic
attention-like compute within chunks, linear recurrence between chunk states.
A Pallas TPU kernel implements the same contraction (kernels/ssd.py); this
module is the jnp implementation used for lowering and as the oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec


def ssd_specs(cfg):
    """in_proj is split (x/z/B/C/dt) so each output dim keeps a cleanly
    shardable logical axis (the fused 2*di+2n+nh dim is not divisible by a
    16-way model axis)."""
    d, di, n, nh = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * n
    return {
        "in_x": ParamSpec((d, di), ("embed", "inner")),
        "in_z": ParamSpec((d, di), ("embed", "inner")),
        "in_B": ParamSpec((d, n), ("embed", None)),
        "in_C": ParamSpec((d, n), ("embed", None)),
        "in_dt": ParamSpec((d, nh), ("embed", "heads")),
        "conv_w": ParamSpec((cfg.conv_width, conv_dim), (None, "inner")),
        "conv_b": ParamSpec((conv_dim,), ("inner",), init="zeros"),
        "dt_bias": ParamSpec((nh,), (None,), init="zeros", dtype=jnp.float32),
        "A_log": ParamSpec((nh,), (None,), init="ones", dtype=jnp.float32),
        "D": ParamSpec((nh,), (None,), init="ones", dtype=jnp.float32),
        "out_proj": ParamSpec((di, d), ("inner", "embed")),
    }


def _causal_conv(x, w, b):
    """Depthwise causal conv via shifted adds.  x (B,S,C); w (W,C)."""
    W = w.shape[0]
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :-i or None][:, :x.shape[1]]
        out = out + shifted * w[W - 1 - i]
    return out + b


def ssd_chunked(x, dt, A, B, C, chunk):
    """SSD scan.  x (b,s,h,p); dt (b,s,h); A (h,); B,C (b,s,n) (one group).

    Returns y (b,s,h,p).  Everything in f32.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S = s + pad
    nc = S // chunk
    xc = x.reshape(b, nc, chunk, h, p)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, n)
    Cc = C.reshape(b, nc, chunk, n)

    # named_scope: VMEM-resident in the Pallas SSD kernel (kernels/ssd.py)
    with jax.named_scope("ssd_vmem"):
        a = dtc * A[None, None, None, :]                  # (b,nc,Q,h) log-decay
        cum = jnp.cumsum(a, axis=2)                       # inclusive
        # intra-chunk: scores[i,j] = (C_i . B_j) * exp(cum_i - cum_j) * dt_j
        seg = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (b,nc,Q,Q,h)
        causal = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(causal[None, None, :, :, None], jnp.exp(seg), 0.0)
        cb = jnp.einsum("bcin,bcjn->bcij", Cc, Bc)        # (b,nc,Q,Q)
        scores = cb[..., None] * L * dtc[:, :, None, :, :]    # (b,nc,Q,Q,h)
        y_intra = jnp.einsum("bcijh,bcjhp->bcihp", scores, xc)

        # chunk states: S_c = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
        decay_end = jnp.exp(cum[:, :, -1:, :] - cum)      # (b,nc,Q,h)
        wB = Bc[:, :, :, None, :] * (dtc * decay_end)[..., None]  # (b,nc,Q,h,n)
        S_c = jnp.einsum("bcjhn,bcjhp->bchnp", wB, xc)    # (b,nc,h,n,p)

    # inter-chunk recurrence: S_{c} passed with decay exp(sum a over chunk)
    chunk_decay = jnp.exp(cum[:, :, -1, :])               # (b,nc,h)

    def scan_fn(S_prev, inp):
        dec, S_new = inp                                  # (b,h), (b,h,n,p)
        S_out = S_prev * dec[:, :, None, None] + S_new
        return S_out, S_prev

    S0 = jnp.zeros((b, h, n, p), jnp.float32)
    S_final, S_prevs = jax.lax.scan(
        scan_fn, S0, (jnp.moveaxis(chunk_decay, 1, 0), jnp.moveaxis(S_c, 1, 0)))
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)                 # (b,nc,h,n,p) state entering chunk

    # inter-chunk contribution: y_i += C_i . (exp(cum_i) * S_prev)
    y_inter = jnp.einsum("bcin,bchnp->bcihp", Cc, S_prevs) \
        * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(b, S, h, p)[:, :s]
    return y, S_final


def ssd_block_apply(p, x, cfg, ctx, collect_cache=False):
    """Full mamba2 mixer.  x (B,S,D) -> (out (B,S,D), cache|None)."""
    B_, S_, D_ = x.shape
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z = jnp.einsum("bsd,de->bse", x, p["in_z"])
    xBC_raw = jnp.concatenate([
        jnp.einsum("bsd,de->bse", x, p["in_x"]),
        jnp.einsum("bsd,dn->bsn", x, p["in_B"]),
        jnp.einsum("bsd,dn->bsn", x, p["in_C"])], axis=-1)
    dt = jnp.einsum("bsd,dh->bsh", x, p["in_dt"])
    xBC = jax.nn.silu(_causal_conv(xBC_raw, p["conv_w"], p["conv_b"]))
    xs, Bs, Cs = jnp.split(xBC, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    xh = xs.reshape(B_, S_, nh, hp).astype(jnp.float32)
    if ctx.attn_impl in ("pallas", "interpret"):
        from repro.kernels import ops as kops
        y, S_final = kops.ssd(xh, dt, A, Bs.astype(jnp.float32),
                              Cs.astype(jnp.float32), chunk=cfg.ssm_chunk,
                              interpret=(ctx.attn_impl == "interpret"))
    else:
        y, S_final = ssd_chunked(xh, dt, A, Bs.astype(jnp.float32),
                                 Cs.astype(jnp.float32), cfg.ssm_chunk)
    cache = None
    if collect_cache:
        cw = cfg.conv_width
        conv_buf = xBC_raw[:, -(cw - 1):]
        if S_ < cw - 1:
            conv_buf = jnp.pad(xBC_raw, ((0, 0), (cw - 1 - S_, 0), (0, 0)))
        cache = {"state": S_final, "conv": conv_buf.astype(jnp.bfloat16)}
    y = y + p["D"][None, None, :, None] * xh
    y = y.reshape(B_, S_, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = ctx.shard(y, "batch", "seq", "inner")
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), cache


# ---------------------------------------------------------------------------
# decode (single-token recurrence)
# ---------------------------------------------------------------------------

def init_ssd_cache(cfg, batch):
    di, n = cfg.d_inner, cfg.ssm_state
    conv_dim = di + 2 * n
    return {
        "state": jnp.zeros((batch, cfg.ssm_heads, n, cfg.ssm_head_dim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim), jnp.bfloat16),
    }


def ssd_block_decode(p, x, cache, cfg, ctx):
    """x (B,1,D); single-step SSM recurrence."""
    B_ = x.shape[0]
    di, n, nh, hp = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    x1 = x[:, 0]
    z = jnp.einsum("bd,de->be", x1, p["in_z"])
    xBC = jnp.concatenate([
        jnp.einsum("bd,de->be", x1, p["in_x"]),
        jnp.einsum("bd,dn->bn", x1, p["in_B"]),
        jnp.einsum("bd,dn->bn", x1, p["in_C"])], axis=-1)
    dt = jnp.einsum("bd,dh->bh", x1, p["in_dt"])
    # conv over buffer + current
    hist = jnp.concatenate([cache["conv"].astype(xBC.dtype), xBC[:, None, :]], axis=1)
    conv_out = jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
    xBC = jax.nn.silu(conv_out)
    new_conv = hist[:, 1:].astype(cache["conv"].dtype)
    xs, Bs, Cs = jnp.split(xBC, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])       # (B,nh)
    A = -jnp.exp(p["A_log"])
    a = jnp.exp(dt * A)                                               # (B,nh)
    xh = xs.reshape(B_, nh, hp).astype(jnp.float32)
    dBx = jnp.einsum("bn,bhp->bhnp", Bs.astype(jnp.float32), xh) \
        * dt[:, :, None, None]
    state = cache["state"] * a[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhnp->bhp", Cs.astype(jnp.float32), state)
    y = y + p["D"][None, :, None] * xh
    y = y.reshape(B_, di).astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("be,ed->bd", y, p["out_proj"])[:, None, :]
    return out, {"state": state, "conv": new_conv}
