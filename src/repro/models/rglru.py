"""Griffin / RecurrentGemma recurrent block with RG-LRU.

Block: x -> [gelu gate branch | conv1d -> RG-LRU branch] -> multiply -> out.
RG-LRU (diagonal gated linear recurrence):
    r_t = sigmoid(w_a * u_t + b_a)
    i_t = sigmoid(w_i * u_t + b_i)
    log a_t = -c * r_t * softplus(Lambda)        (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * u_t)

The full-sequence path uses an associative scan (log-time in jnp; the Pallas
kernel kernels/rglru.py does a VMEM-blocked sequential scan, the TPU-native
form).  Gates are elementwise (the paper's block-diagonal projections reduced
to their diagonal; parameter count matches configs/base.py accounting).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec

RGLRU_C = 8.0


def rglru_specs(cfg):
    d, dr = cfg.d_model, cfg.d_rnn
    return {
        "w_x": ParamSpec((d, dr), ("embed", "inner")),
        "w_y": ParamSpec((d, dr), ("embed", "inner")),
        "conv_w": ParamSpec((cfg.rglru_conv_width, dr), (None, "inner")),
        "conv_b": ParamSpec((dr,), ("inner",), init="zeros"),
        "w_a": ParamSpec((dr,), ("inner",), dtype=jnp.float32),
        "b_a": ParamSpec((dr,), ("inner",), init="zeros", dtype=jnp.float32),
        "w_i": ParamSpec((dr,), ("inner",), dtype=jnp.float32),
        "b_i": ParamSpec((dr,), ("inner",), init="zeros", dtype=jnp.float32),
        "lam": ParamSpec((dr,), ("inner",), init="rglru_a", dtype=jnp.float32),
        "w_o": ParamSpec((dr, d), ("inner", "embed")),
    }


def _causal_conv(x, w, b):
    W = w.shape[0]
    out = x * w[-1]
    for i in range(1, W):
        shifted = jnp.pad(x, ((0, 0), (i, 0), (0, 0)))[:, :x.shape[1]]
        out = out + shifted * w[W - 1 - i]
    return out + b


def rglru_gates(u, p):
    """u (..., dr) f32 -> (a, b) recurrence coefficients."""
    u = u.astype(jnp.float32)
    r = jax.nn.sigmoid(u * p["w_a"] + p["b_a"])
    i = jax.nn.sigmoid(u * p["w_i"] + p["b_i"])
    log_a = -RGLRU_C * r * jax.nn.softplus(p["lam"])
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * u)
    return a, b


def rglru_scan_ref(a, b, h0=None):
    """Linear recurrence h_t = a_t h_{t-1} + b_t via associative scan.

    a, b: (B, S, dr) f32.  h0 (B, dr) optional initial state.
    """
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, b1 * a2 + b2
    # VMEM-resident in the Pallas kernel (kernels/rglru.py does a blocked
    # sequential scan; the log-depth materializations here are XLA-only)
    with jax.named_scope("rglru_vmem"):
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def rglru_block_apply(p, x, cfg, ctx, collect_cache=False):
    """x (B,S,D) -> (out (B,S,D), cache|None)."""
    y = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_y"]), approximate=True)
    u_raw = jnp.einsum("bsd,de->bse", x, p["w_x"])
    u = _causal_conv(u_raw, p["conv_w"], p["conv_b"])
    a, b = rglru_gates(u, p)
    if ctx.attn_impl in ("pallas", "interpret"):
        from repro.kernels import ops as kops
        h = kops.rglru_scan(a, b, interpret=(ctx.attn_impl == "interpret"))
    else:
        h = rglru_scan_ref(a, b)
    cache = None
    if collect_cache:
        cw = cfg.rglru_conv_width
        conv_buf = u_raw[:, -(cw - 1):]
        S = u_raw.shape[1]
        if S < cw - 1:
            conv_buf = jnp.pad(u_raw, ((0, 0), (cw - 1 - S, 0), (0, 0)))
        cache = {"h": h[:, -1].astype(jnp.float32),
                 "conv": conv_buf.astype(jnp.bfloat16)}
    h = (h.astype(x.dtype) * y)
    h = ctx.shard(h, "batch", "seq", "inner")
    return jnp.einsum("bse,ed->bsd", h, p["w_o"]), cache


def init_rglru_cache(cfg, batch):
    dr = cfg.d_rnn
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rglru_conv_width - 1, dr), jnp.bfloat16),
    }


def rglru_block_decode(p, x, cache, cfg, ctx):
    """x (B,1,D) single-step."""
    y = jax.nn.gelu(jnp.einsum("bsd,de->bse", x, p["w_y"])[:, 0], approximate=True)
    u = jnp.einsum("bsd,de->bse", x, p["w_x"])[:, 0]
    hist = jnp.concatenate([cache["conv"].astype(u.dtype), u[:, None]], axis=1)
    u = jnp.einsum("bwc,wc->bc", hist, p["conv_w"]) + p["conv_b"]
    new_conv = hist[:, 1:].astype(cache["conv"].dtype)
    a, b = rglru_gates(u, p)
    h = a * cache["h"] + b
    out = jnp.einsum("be,ed->bd", (h.astype(x.dtype) * y), p["w_o"])[:, None]
    return out, {"h": h, "conv": new_conv}
