"""Attention: global (causal), local (sliding window), cross; train + decode.

Implementations (ParallelConfig.attn_impl):
  * "xla"       -- chunked-scan flash attention in pure jnp: O(S * block)
                   memory, lowers on any backend, used for the dry-run.
  * "pallas"    -- TPU Pallas kernel (kernels/flash_attention.py).
  * "interpret" -- same kernel, interpret=True (CPU tests).
  * "naive"     -- materialized scores; tiny shapes only (oracle).

Layouts: q (B, S, H, hd); k/v (B, S, KV, hd).  GQA is expressed by grouping
q as (B, S, KV, G, hd) inside the score einsums so that k/v broadcast over G
without materializing repeated heads.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.models.layers import ParamSpec, rms_norm, rms_norm_specs, rope

NEG_INF = -2.0e38


# ---------------------------------------------------------------------------
# specs
# ---------------------------------------------------------------------------

def attention_specs(cfg, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    s = {
        "wq": ParamSpec((d, h, hd), ("embed", "heads", "head_dim")),
        "wk": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wv": ParamSpec((d, kv, hd), ("embed", "kv_heads", "head_dim")),
        "wo": ParamSpec((h, hd, d), ("heads", "head_dim", "embed")),
    }
    if cfg.qk_norm:
        s["qnorm"] = rms_norm_specs(hd)
        s["knorm"] = rms_norm_specs(hd)
    if cross:
        # gated cross-attention (llama-3.2-vision style tanh gate)
        s["gate"] = ParamSpec((), (), init="zeros", dtype=jnp.float32)
    return s


def _group(q, kv_heads):
    """(B, S, H, hd) -> (B, S, KV, G, hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, kv_heads, h // kv_heads, hd)


def _ungroup(o):
    b, s, kvh, g, hd = o.shape
    return o.reshape(b, s, kvh * g, hd)


def _project_qkv(p, x, memory, cfg, ctx, rope_theta, positions, kind):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    src = x if memory is None else memory
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"]["scale"], cfg.norm_eps)
        k = rms_norm(k, p["knorm"]["scale"], cfg.norm_eps)
    if kind != "cross" and rope_theta:
        q = rope(q, positions, rope_theta)
        k = rope(k, positions, rope_theta)
    # NOTE: seq stays unsharded here (None, not "seq") — sequence parallelism
    # applies to the residual stream only; re-sharding blocked flash scans
    # over a seq-sharded operand makes GSPMD re-gather every scan step.
    q = ctx.shard(q, "batch", None, "heads", None)
    k = ctx.shard(k, "batch", None, "kv_heads", None)
    v = ctx.shard(v, "batch", None, "kv_heads", None)
    return q, k, v


def _out_proj(p, o, ctx, gated=False):
    out = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if gated:
        out = out * jnp.tanh(p["gate"]).astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# score-level attention primitives (jnp)
# ---------------------------------------------------------------------------

def naive_attention(q, k, v, mask, scale):
    """q (B,Sq,KV,G,hd), k/v (B,Sk,KV,hd), mask broadcastable to (B,KV,G,Sq,Sk)."""
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k).astype(jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v.dtype), v)
    return o


def flash_attention_ref(q, k, v, *, scale, causal=True, window=0,
                        q_block=1024, kv_block=1024, q_offset=0):
    """Chunked-scan flash attention (pure jnp, any backend).

    q (B,Sq,KV,G,hd); k/v (B,Sk,KV,hd).  Sequential scan over q blocks; inner
    scan over kv blocks with running (m, l, acc).  q_offset: absolute position
    of q[0] relative to k[0] (for cached decode-prefill continuation).
    """
    B, Sq, KV, G, hd = q.shape
    Sk = k.shape[1]
    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    # pad to block multiples
    pq = (-Sq) % q_block
    pk = (-Sk) % kv_block
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))
    nq, nk = (Sq + pq) // q_block, (Sk + pk) // kv_block

    qs = jnp.moveaxis(q.reshape(B, nq, q_block, KV, G, hd), 1, 0)
    ks = jnp.moveaxis(k.reshape(B, nk, kv_block, KV, hd), 1, 0)
    vs = jnp.moveaxis(v.reshape(B, nk, kv_block, KV, hd), 1, 0)
    qpos = jnp.arange(nq * q_block).reshape(nq, q_block) + q_offset
    kpos = jnp.arange(nk * kv_block).reshape(nk, kv_block)

    def q_body(_, xq):
        q_i, pq_i = xq

        def kv_body(carry, xk):
            # named_scope marks the VMEM-resident region of the Pallas flash
            # kernel: the roofline's fused-kernel accounting drops HBM bytes
            # for ops inside it (kernels/flash_attention.py is the TPU impl)
            with jax.named_scope("flash_vmem"):
                m, l, acc = carry
                k_j, v_j, pk_j = xk
                s = jnp.einsum("bqkgh,bskh->bkgqs", q_i, k_j).astype(jnp.float32) * scale
                msk = pk_j[None, :] <= pq_i[:, None]            # causal
                if window:
                    msk &= (pq_i[:, None] - pk_j[None, :]) < window
                msk &= pk_j[None, :] < Sk                        # kv padding
                s = jnp.where(msk[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, s.max(axis=-1))
                alpha = jnp.exp(m - m_new)
                pr = jnp.exp(s - m_new[..., None])
                l_new = l * alpha + pr.sum(axis=-1)
                acc_new = (acc * alpha[..., None]
                           + jnp.einsum("bkgqs,bskh->bkgqh", pr.astype(v_j.dtype), v_j)
                           .astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        init = (jnp.full((B, KV, G, q_block), NEG_INF, jnp.float32),
                jnp.zeros((B, KV, G, q_block), jnp.float32),
                jnp.zeros((B, KV, G, q_block, hd), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(kv_body, init, (ks, vs, kpos))
        with jax.named_scope("flash_vmem"):      # kernel epilogue (VMEM)
            o = acc / jnp.maximum(l, 1e-37)[..., None]
            o = o.astype(q.dtype)
        return None, o

    _, outs = jax.lax.scan(q_body, None, (qs, qpos))         # (nq,B,KV,G,qb,hd)
    o = jnp.moveaxis(outs, 0, 3)                             # (B,KV,G,nq,qb,hd)
    o = o.reshape(B, KV, G, nq * q_block, hd)
    o = jnp.moveaxis(o, 3, 1)[:, :Sq]                        # (B,Sq,KV,G,hd)
    return o


def local_block_attention(q, k, v, *, scale, window):
    """Banded local attention: block size == window, each q block attends to
    its own + previous block.  Exact for sliding window `window`.
    q (B,S,KV,G,hd); k/v (B,S,KV,hd)."""
    B, S, KV, G, hd = q.shape
    w = window
    pad = (-S) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    Sp = S + pad
    nb = Sp // w
    qb = q.reshape(B, nb, w, KV, G, hd)
    kb = k.reshape(B, nb, w, KV, hd)
    vb = v.reshape(B, nb, w, KV, hd)
    # previous block (zeros for block 0)
    shift = lambda x: jnp.pad(x, ((0, 0), (1, 0)) + ((0, 0),) * (x.ndim - 2))[:, :-1]
    k2 = jnp.concatenate([shift(kb), kb], axis=2)            # (B,nb,2w,KV,hd)
    v2 = jnp.concatenate([shift(vb), vb], axis=2)
    with jax.named_scope("flash_vmem"):
        s = jnp.einsum("bnqkgh,bnskh->bnkgqs", qb, k2).astype(jnp.float32) * scale
        qpos = jnp.arange(nb * w).reshape(nb, w)
        # absolute key positions per block row: previous block then own block
        kpos = (jnp.arange(nb)[:, None] - 1) * w + jnp.arange(2 * w)[None, :]
        msk = (kpos[:, None, :] <= qpos[:, :, None]) \
            & (qpos[:, :, None] - kpos[:, None, :] < w) \
            & (kpos[:, None, :] >= 0) & (kpos[:, None, :] < S)
        s = jnp.where(msk[None, :, None, None], s, NEG_INF)
        pr = jax.nn.softmax(s, axis=-1)
        o = jnp.einsum("bnkgqs,bnskh->bnqkgh", pr.astype(v2.dtype), v2)
    o = o.reshape(B, Sp, KV, G, hd)[:, :S]
    return o


# ---------------------------------------------------------------------------
# full-sequence layer entry (train / prefill)
# ---------------------------------------------------------------------------

def attention_apply(p, x, cfg, ctx, kind, memory=None, positions=None):
    """x (B,S,D).  kind in {global, local, cross, enc}.

    Returns (out (B,S,D), (k, v)) — roped keys/values so callers can build a
    decode cache from a prefill pass.
    """
    B, S, D = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    theta = cfg.rope_theta
    if kind == "global" and cfg.rope_theta_global:
        theta = cfg.rope_theta_global
    q, k, v = _project_qkv(p, x, memory, cfg, ctx, theta, positions, kind)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    qg = _group(q, cfg.num_kv_heads)

    impl = ctx.attn_impl
    causal = kind in ("global", "local")
    window = cfg.local_window if kind == "local" else 0

    if impl in ("pallas", "interpret"):
        from repro.kernels import ops as kops
        o = kops.flash_attention(qg, k, v, causal=causal, window=window,
                                 scale=scale, interpret=(impl == "interpret"))
    elif impl == "naive" or not causal:
        # cross / encoder attention: full (no mask or memory-length mask)
        Sk = k.shape[1]
        if causal:
            msk = jnp.tril(jnp.ones((S, Sk), bool))[None, None, None]
        else:
            msk = jnp.ones((1, 1, 1, 1, 1), bool)
        if impl == "naive" and causal and window:
            qp = jnp.arange(S)[:, None]
            kp = jnp.arange(Sk)[None, :]
            msk = ((kp <= qp) & (qp - kp < window))[None, None, None]
        o = naive_attention(qg, k, v, msk, scale)
    elif kind == "local":
        o = local_block_attention(qg, k, v, scale=scale, window=window)
    else:
        o = flash_attention_ref(qg, k, v, scale=scale, causal=True,
                                q_block=ctx.q_block, kv_block=ctx.kv_block)
    o = _ungroup(o)
    o = ctx.shard(o, "batch", None, "heads", None)
    return _out_proj(p, o, ctx, gated=(kind == "cross")), (k, v)


def pack_prefill_cache(k, v, kind, cfg, cache_len):
    """Arrange full-sequence roped (k, v) (B,S,KV,hd) into the decode cache
    layout of init_attn_cache (ring order for local windows)."""
    B, S = k.shape[:2]
    if kind == "local":
        W = min(cfg.local_window, cache_len)
        if S >= W:
            k_t, v_t = k[:, S - W:], v[:, S - W:]
            # position p lands at slot p % W; first kept position is S-W
            shift = S % W
            k_c = jnp.roll(k_t, shift, axis=1)
            v_c = jnp.roll(v_t, shift, axis=1)
        else:
            pad = ((0, 0), (0, W - S), (0, 0), (0, 0))
            k_c, v_c = jnp.pad(k, pad), jnp.pad(v, pad)
        return {"k": k_c.astype(jnp.bfloat16), "v": v_c.astype(jnp.bfloat16)}
    L = cache_len if kind != "cross" else k.shape[1]
    if S < L:
        pad = ((0, 0), (0, L - S), (0, 0), (0, 0))
        k, v = jnp.pad(k, pad), jnp.pad(v, pad)
    else:
        k, v = k[:, :L], v[:, :L]
    return {"k": k.astype(jnp.bfloat16), "v": v.astype(jnp.bfloat16)}


# ---------------------------------------------------------------------------
# decode (single token, cached)
# ---------------------------------------------------------------------------

def init_attn_cache(cfg, kind, batch, cache_len, ctx=None):
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if kind == "local":
        L = min(cfg.local_window, cache_len)
    elif kind == "cross":
        L = cfg.context_tokens or cfg.encoder_len
    else:
        L = cache_len
    z = lambda: jnp.zeros((batch, L, kv, hd), jnp.bfloat16)
    return {"k": z(), "v": z()}


def attention_decode(p, x, cache, pos, cfg, ctx, kind, memory=None):
    """x (B,1,D); cache {"k","v"} (B,L,KV,hd); pos scalar int32 (tokens so far).

    Returns (out (B,1,D), new_cache).  For "cross", cache holds the static
    memory KV (written at prefill; here just read).
    """
    B = x.shape[0]
    theta = cfg.rope_theta
    if kind == "global" and cfg.rope_theta_global:
        theta = cfg.rope_theta_global
    positions = jnp.full((B, 1), pos, jnp.int32)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    if cfg.qk_norm:
        q = rms_norm(q, p["qnorm"]["scale"], cfg.norm_eps)
    if kind != "cross" and theta:
        q = rope(q, positions, theta)
    scale = 1.0 / math.sqrt(cfg.head_dim)
    qg = _group(q, cfg.num_kv_heads)                    # (B,1,KV,G,hd)

    if kind == "cross":
        k, v = cache["k"], cache["v"]
        msk = jnp.ones((1, 1, 1, 1, 1), bool)
        o = naive_attention(qg, k, v, msk, scale)
        o = _ungroup(o)
        return _out_proj(p, o, ctx, gated=True), cache

    k_new = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v_new = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qk_norm:
        k_new = rms_norm(k_new, p["knorm"]["scale"], cfg.norm_eps)
    if theta:
        k_new = rope(k_new, positions, theta)

    L = cache["k"].shape[1]
    slot = pos % L if kind == "local" else pos
    k_cache = jax.lax.dynamic_update_slice(cache["k"], k_new.astype(cache["k"].dtype),
                                           (0, slot, 0, 0))
    v_cache = jax.lax.dynamic_update_slice(cache["v"], v_new.astype(cache["v"].dtype),
                                           (0, slot, 0, 0))
    k_cache = ctx.shard(k_cache, "batch", "cache", "kv_heads", None)
    v_cache = ctx.shard(v_cache, "batch", "cache", "kv_heads", None)

    slots = jnp.arange(L)
    if kind == "local":
        # slot s holds absolute position pos - ((pos - s) mod L); valid if >= 0
        p_slot = pos - ((pos - slots) % L)
        valid = (p_slot >= 0) & (p_slot <= pos) & (pos - p_slot < cfg.local_window)
    else:
        valid = slots <= pos
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_cache).astype(jnp.float32) * scale
    s = jnp.where(valid[None, None, None, None, :], s, NEG_INF)
    # flash-decoding across chips: keep the score vector sharded along the
    # cache dim; GSPMD turns the softmax stats into small all-reduces instead
    # of re-gathering the (huge) cache shards (long_500k)
    s = ctx.shard(s, "batch", "kv_heads", None, None, "cache")
    w = jax.nn.softmax(s, axis=-1)
    w = ctx.shard(w, "batch", "kv_heads", None, None, "cache")
    o = jnp.einsum("bkgqs,bskh->bqkgh", w.astype(v_cache.dtype), v_cache)
    o = _ungroup(o)
    out = _out_proj(p, o, ctx)
    return out, {"k": k_cache, "v": v_cache}
