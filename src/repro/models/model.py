"""Unified model assembly + API for all 10 assigned architectures.

Structure: embed -> [encoder stack (enc-dec only)] -> scan over superblocks
(+ unrolled remainder layers) -> final norm -> unembed.

The layer pattern comes from ModelConfig.superblock/remainder; each slot is a
residual block: ln -> mixer (attention | rglru | ssd) [+ cross-attn sub-layer
for enc-dec] [+ ln -> mlp/moe].  Scanned layers hold parameters stacked along
a leading "layers" axis so the lowered HLO stays small at any depth.

API (all pure functions of pytrees — pjit-ready):
  param_specs / init / abstract_params
  apply(params, tokens, ...)            full-sequence forward -> logits
  loss(params, batch)                   next-token CE (+ MoE aux)
  init_cache / cache_specs              decode cache pytrees
  prefill(params, tokens, ...)          forward + packed decode cache
  decode_step(params, token, cache)     one-token serving step
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ModelConfig, ATTENTION_KINDS, GLOBAL_ATTN,
                                LOCAL_ATTN, CROSS_ATTN, RGLRU, SSD, ENC_ATTN)
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (ParamSpec, abstract_from_specs, embed_apply,
                                 embed_specs, init_from_specs, is_spec,
                                 logical_axes_from_specs, mlp_apply, mlp_specs,
                                 rms_norm, rms_norm_specs, soft_cap,
                                 unembed_apply)


@dataclasses.dataclass
class Ctx:
    """Per-call context: sharding hook + implementation choices."""
    attn_impl: str = "xla"             # xla | pallas | interpret | naive
    q_block: int = 512
    kv_block: int = 1024
    remat: str = "none"                # none | dots | full
    shard_fn: Optional[Callable] = None
    moe_groups: int = 1                # MoE dispatch groups (= DP degree)

    def shard(self, x, *axes):
        if self.shard_fn is None:
            return x
        return self.shard_fn(x, axes)


# ---------------------------------------------------------------------------
# per-layer specs / apply
# ---------------------------------------------------------------------------

def layer_specs(cfg: ModelConfig, kind: str):
    d = cfg.d_model
    s: dict = {"ln1": rms_norm_specs(d, ("embed",))}
    if kind in ATTENTION_KINDS:
        s["attn"] = attn.attention_specs(cfg, cross=(kind == CROSS_ATTN))
    elif kind == RGLRU:
        s["mixer"] = rglru_mod.rglru_specs(cfg)
    elif kind == SSD:
        s["mixer"] = ssm_mod.ssd_specs(cfg)
    else:
        raise ValueError(kind)
    if cfg.is_encdec and kind == GLOBAL_ATTN:
        s["ln_x"] = rms_norm_specs(d, ("embed",))
        s["xattn"] = attn.attention_specs(cfg, cross=True)
    if cfg.d_ff:
        s["ln2"] = rms_norm_specs(d, ("embed",))
        if cfg.num_experts and kind != CROSS_ATTN:
            s["moe"] = moe_mod.moe_specs(cfg)
        else:
            s["mlp"] = mlp_specs(d, cfg.d_ff)
    return s


def apply_layer(p, h, kind, cfg, ctx, memory=None, positions=None,
                collect_cache=False, cache_len=0):
    """Residual block.  Returns (h, aux_loss, cache|None)."""
    aux = jnp.zeros((), jnp.float32)
    cache = {}
    a_in = rms_norm(h, p["ln1"]["scale"], cfg.norm_eps)
    if kind in ATTENTION_KINDS:
        mem = memory if kind == CROSS_ATTN else None
        out, (k, v) = attn.attention_apply(p["attn"], a_in, cfg, ctx, kind,
                                           memory=mem, positions=positions)
        if collect_cache:
            cache["attn"] = attn.pack_prefill_cache(k, v, kind, cfg, cache_len)
    elif kind == RGLRU:
        out, c = rglru_mod.rglru_block_apply(p["mixer"], a_in, cfg, ctx,
                                             collect_cache)
        if collect_cache:
            cache["mixer"] = c
    elif kind == SSD:
        out, c = ssm_mod.ssd_block_apply(p["mixer"], a_in, cfg, ctx,
                                         collect_cache)
        if collect_cache:
            cache["mixer"] = c
    h = h + out

    if cfg.is_encdec and kind == GLOBAL_ATTN and memory is not None:
        x_in = rms_norm(h, p["ln_x"]["scale"], cfg.norm_eps)
        out, (xk, xv) = attn.attention_apply(p["xattn"], x_in, cfg, ctx,
                                             CROSS_ATTN, memory=memory)
        if collect_cache:
            cache["xattn"] = attn.pack_prefill_cache(xk, xv, CROSS_ATTN, cfg, 0)
        h = h + out

    if cfg.d_ff:
        m_in = rms_norm(h, p["ln2"]["scale"], cfg.norm_eps)
        if "moe" in p:
            m, aux = moe_mod.moe_apply(p["moe"], m_in, cfg, ctx)
        else:
            m = mlp_apply(p["mlp"], m_in, cfg.act, ctx)
        h = h + m
    h = ctx.shard(h, "batch", "seq", "embed")
    return h, aux, (cache if collect_cache else None)


def apply_layer_decode(p, h, layer_cache, pos, kind, cfg, ctx, memory=None):
    """One-token residual block.  h (B,1,D).  Returns (h, new_cache)."""
    new_cache = dict(layer_cache)
    a_in = rms_norm(h, p["ln1"]["scale"], cfg.norm_eps)
    if kind in ATTENTION_KINDS:
        out, new_cache["attn"] = attn.attention_decode(
            p["attn"], a_in, layer_cache["attn"], pos, cfg, ctx,
            "cross" if kind == CROSS_ATTN else kind)
    elif kind == RGLRU:
        out, new_cache["mixer"] = rglru_mod.rglru_block_decode(
            p["mixer"], a_in, layer_cache["mixer"], cfg, ctx)
    elif kind == SSD:
        out, new_cache["mixer"] = ssm_mod.ssd_block_decode(
            p["mixer"], a_in, layer_cache["mixer"], cfg, ctx)
    h = h + out

    if cfg.is_encdec and kind == GLOBAL_ATTN and "xattn" in p:
        x_in = rms_norm(h, p["ln_x"]["scale"], cfg.norm_eps)
        out, new_cache["xattn"] = attn.attention_decode(
            p["xattn"], x_in, layer_cache["xattn"], pos, cfg, ctx, "cross")
        h = h + out

    if cfg.d_ff:
        m_in = rms_norm(h, p["ln2"]["scale"], cfg.norm_eps)
        if "moe" in p:
            m, _ = moe_mod.moe_apply(p["moe"], m_in, cfg, ctx)
        else:
            m = mlp_apply(p["mlp"], m_in, cfg.act, ctx)
        h = h + m
    return h, new_cache


def init_layer_cache_specs(cfg, kind, batch, cache_len):
    """ParamSpec tree for one layer's decode cache."""
    c: dict = {}
    kv, hd = cfg.num_kv_heads, cfg.head_dim
    if kind in ATTENTION_KINDS:
        if kind == LOCAL_ATTN:
            L = min(cfg.local_window, cache_len)
        elif kind == CROSS_ATTN:
            L = cfg.context_tokens or cfg.encoder_len
        else:
            L = cache_len
        kvspec = ParamSpec((batch, L, kv, hd), ("batch", "cache", "kv_heads", None),
                           init="zeros")
        c["attn"] = {"k": kvspec, "v": kvspec}
    elif kind == RGLRU:
        dr = cfg.d_rnn
        c["mixer"] = {
            "h": ParamSpec((batch, dr), ("batch", "inner"), init="zeros",
                           dtype=jnp.float32),
            "conv": ParamSpec((batch, cfg.rglru_conv_width - 1, dr),
                              ("batch", None, "inner"), init="zeros"),
        }
    elif kind == SSD:
        conv_dim = cfg.d_inner + 2 * cfg.ssm_state
        c["mixer"] = {
            "state": ParamSpec((batch, cfg.ssm_heads, cfg.ssm_state,
                                cfg.ssm_head_dim),
                               ("batch", "heads", None, None), init="zeros",
                               dtype=jnp.float32),
            "conv": ParamSpec((batch, cfg.conv_width - 1, conv_dim),
                              ("batch", None, "inner"), init="zeros"),
        }
    if cfg.is_encdec and kind == GLOBAL_ATTN:
        M = cfg.encoder_len
        kvspec = ParamSpec((batch, M, kv, hd), ("batch", "cache", "kv_heads", None),
                           init="zeros")
        c["xattn"] = {"k": kvspec, "v": kvspec}
    return c


# ---------------------------------------------------------------------------
# spec stacking (scan-over-superblocks)
# ---------------------------------------------------------------------------

def stack_specs(specs, n):
    def f(s: ParamSpec):
        return ParamSpec((n,) + tuple(s.shape), ("layers",) + tuple(s.logical_axes),
                         dtype=s.dtype, init=s.init, scale=s.scale)
    return jax.tree_util.tree_map(f, specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # -- parameters ---------------------------------------------------------
    def param_specs(self):
        cfg = self.cfg
        specs: dict = {"embed": embed_specs(cfg.vocab_size, cfg.d_model),
                       "final_norm": rms_norm_specs(cfg.d_model, ("embed",))}
        if not cfg.tie_embeddings:
            specs["unembed"] = {
                "table": ParamSpec((cfg.vocab_size, cfg.d_model),
                                   ("vocab", "embed"))}
        blocks: dict = {}
        if cfg.sb_repeat:
            sb = {f"slot{i}": layer_specs(cfg, k)
                  for i, k in enumerate(cfg.superblock)}
            blocks["sb"] = stack_specs(sb, cfg.sb_repeat)
        for i, k in enumerate(cfg.remainder):
            blocks[f"rem{i}"] = layer_specs(cfg, k)
        specs["blocks"] = blocks
        if cfg.is_encdec:
            enc = {"slot0": layer_specs(cfg, ENC_ATTN)}
            specs["encoder"] = {
                "sb": stack_specs(enc, cfg.encoder_layers),
                "final_norm": rms_norm_specs(cfg.d_model, ("embed",)),
            }
        return specs

    def init(self, rng):
        return init_from_specs(self.param_specs(), rng)

    def abstract_params(self):
        return abstract_from_specs(self.param_specs())

    def param_logical_axes(self):
        return logical_axes_from_specs(self.param_specs())

    # -- encoder (enc-dec only) ---------------------------------------------
    def encode(self, params, memory_embeds, ctx):
        cfg = self.cfg
        h = memory_embeds

        def body(carry, p_sb):
            x, _ = carry
            x, _, _ = apply_layer(p_sb["slot0"], x, ENC_ATTN, cfg, ctx)
            return (x, 0.0), None

        body = _maybe_remat(body, ctx)
        (h, _), _ = jax.lax.scan(body, (h, 0.0), params["encoder"]["sb"])
        return rms_norm(h, params["encoder"]["final_norm"]["scale"], cfg.norm_eps)

    # -- full-sequence forward ----------------------------------------------
    def apply(self, params, tokens, ctx, memory=None, collect_cache=False,
              cache_len=0):
        """tokens (B,S) -> logits (B,S,V).  memory: stub frontend embeddings.

        With collect_cache=True also returns the packed decode cache
        (pos field excluded; see prefill())."""
        cfg = self.cfg
        B, S = tokens.shape
        h = embed_apply(params["embed"], tokens, cfg.d_model)
        h = ctx.shard(h, "batch", "seq", "embed")
        positions = jnp.arange(S)[None, :]
        if cfg.is_encdec:
            memory = self.encode(params, memory, ctx)

        caches: dict = {}

        def sb_body(carry, p_sb):
            x, aux = carry
            cs = {}
            for i, kind in enumerate(cfg.superblock):
                x, a, c = apply_layer(p_sb[f"slot{i}"], x, kind, cfg, ctx,
                                      memory=memory, positions=positions,
                                      collect_cache=collect_cache,
                                      cache_len=cache_len)
                aux = aux + a
                if collect_cache:
                    cs[f"slot{i}"] = c
            return (x, aux), (cs if collect_cache else None)

        aux = jnp.zeros((), jnp.float32)
        if cfg.sb_repeat:
            body = _maybe_remat(sb_body, ctx)
            (h, aux), sb_caches = jax.lax.scan(body, (h, aux),
                                               params["blocks"]["sb"])
            if collect_cache:
                caches["sb"] = sb_caches
        for i, kind in enumerate(cfg.remainder):
            h, a, c = apply_layer(params["blocks"][f"rem{i}"], h, kind, cfg, ctx,
                                  memory=memory, positions=positions,
                                  collect_cache=collect_cache,
                                  cache_len=cache_len)
            aux = aux + a
            if collect_cache:
                caches[f"rem{i}"] = c

        h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["unembed"]["table"])
        logits = unembed_apply(table, h, cfg.logits_soft_cap)
        if collect_cache:
            return logits, aux, caches
        return logits, aux

    # -- loss ----------------------------------------------------------------
    def loss(self, params, batch, ctx):
        """batch: {tokens (B,S), labels (B,S) (-1 = pad), [memory]}."""
        logits, aux = self.apply(params, batch["tokens"], ctx,
                                 memory=batch.get("memory"))
        labels = batch["labels"]
        # gather-free CE: with vocab sharded over the model axis, a
        # take_along_axis gather lowers to collective-permute chains; the
        # iota-select-reduce form fuses into the (sharded) softmax reduction.
        logits = logits.astype(jnp.float32)
        lse = jax.scipy.special.logsumexp(logits, axis=-1)        # (B,S)
        viota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
        sel = jnp.where(viota == jnp.maximum(labels, 0)[..., None],
                        logits, 0.0).sum(axis=-1)                 # label logit
        nll = lse - sel
        mask = (labels >= 0).astype(jnp.float32)
        ntok = jnp.maximum(mask.sum(), 1.0)
        ce = (nll * mask).sum() / ntok
        # z-loss for stability (also keeps the softmax normalizer bounded)
        zloss = 1e-4 * ((lse ** 2) * mask).sum() / ntok
        total = ce + zloss + 0.01 * aux
        return total, {"ce": ce, "aux": aux, "zloss": zloss, "ntok": ntok}

    # -- decode cache ---------------------------------------------------------
    def cache_specs(self, batch, cache_len):
        cfg = self.cfg
        c: dict = {"pos": ParamSpec((), (), init="zeros", dtype=jnp.int32)}
        blocks: dict = {}
        if cfg.sb_repeat:
            sb = {f"slot{i}": init_layer_cache_specs(cfg, k, batch, cache_len)
                  for i, k in enumerate(cfg.superblock)}
            blocks["sb"] = stack_specs(sb, cfg.sb_repeat)
        for i, k in enumerate(cfg.remainder):
            blocks[f"rem{i}"] = init_layer_cache_specs(cfg, k, batch, cache_len)
        c["blocks"] = blocks
        return c

    def init_cache(self, batch, cache_len, rng=None):
        rng = jax.random.PRNGKey(0) if rng is None else rng
        return init_from_specs(self.cache_specs(batch, cache_len), rng)

    def abstract_cache(self, batch, cache_len):
        return abstract_from_specs(self.cache_specs(batch, cache_len))

    def cache_logical_axes(self, batch, cache_len):
        return logical_axes_from_specs(self.cache_specs(batch, cache_len))

    # -- prefill --------------------------------------------------------------
    def prefill(self, params, tokens, ctx, cache_len, memory=None):
        """Full forward + packed decode cache.  Returns (last_logits, cache)."""
        logits, _, caches = self.apply(params, tokens, ctx, memory=memory,
                                       collect_cache=True, cache_len=cache_len)
        cache = {"pos": jnp.asarray(tokens.shape[1], jnp.int32),
                 "blocks": caches}
        return logits[:, -1], cache

    # -- decode ---------------------------------------------------------------
    def decode_step(self, params, token, cache, ctx, memory=None):
        """token (B,1) int32; cache from init_cache/prefill.

        Returns (logits (B,V), new_cache)."""
        cfg = self.cfg
        pos = cache["pos"]
        h = embed_apply(params["embed"], token, cfg.d_model)
        new_blocks: dict = {}

        def sb_body(x, xs):
            p_sb, c_sb = xs
            cs = {}
            for i, kind in enumerate(cfg.superblock):
                x, cs[f"slot{i}"] = apply_layer_decode(
                    p_sb[f"slot{i}"], x, c_sb[f"slot{i}"], pos, kind, cfg, ctx,
                    memory=memory)
            return x, cs

        if cfg.sb_repeat:
            h, new_blocks["sb"] = jax.lax.scan(
                sb_body, h, (params["blocks"]["sb"], cache["blocks"]["sb"]))
        for i, kind in enumerate(cfg.remainder):
            h, new_blocks[f"rem{i}"] = apply_layer_decode(
                params["blocks"][f"rem{i}"], h, cache["blocks"][f"rem{i}"],
                pos, kind, cfg, ctx, memory=memory)

        h = rms_norm(h, params["final_norm"]["scale"], cfg.norm_eps)
        table = (params["embed"]["table"] if cfg.tie_embeddings
                 else params["unembed"]["table"])
        logits = unembed_apply(table, h, cfg.logits_soft_cap)[:, 0]
        return logits, {"pos": pos + 1, "blocks": new_blocks}

    # -- stub frontends --------------------------------------------------------
    def memory_len(self):
        cfg = self.cfg
        if cfg.family == "vlm":
            return cfg.context_tokens
        if cfg.is_encdec:
            return cfg.encoder_len
        return 0


def _maybe_remat(body, ctx):
    if ctx.remat == "none":
        return body
    if ctx.remat == "full":
        return jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
    return jax.checkpoint(
        body, policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg)
