from repro.models.model import Model, Ctx, build_model

__all__ = ["Model", "Ctx", "build_model"]
