"""Common layers + the ParamSpec system.

Every parameter is declared once as a ParamSpec carrying (shape, dtype,
logical_axes, init).  From the same spec tree we derive:
  * materialized parameters      (init_params)
  * NamedShardings for pjit      (parallel/sharding.py maps logical -> mesh)
  * ShapeDtypeStructs            (abstract init for the cluster-free dry-run)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: tuple
    logical_axes: tuple           # logical axis name (or None) per dim
    dtype: jnp.dtype = jnp.bfloat16
    init: str = "normal"          # normal | zeros | ones | rglru_a
    scale: float = 1.0            # stddev multiplier for "normal"

    def __post_init__(self):
        assert len(self.shape) == len(self.logical_axes), (self.shape, self.logical_axes)

    def abstract(self) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct(self.shape, self.dtype)

    def materialize(self, key) -> jax.Array:
        if self.init == "zeros":
            return jnp.zeros(self.shape, self.dtype)
        if self.init == "ones":
            return jnp.ones(self.shape, self.dtype)
        if self.init == "rglru_a":
            # Griffin: a = sigmoid(lambda) in [0.9, 0.999] -> init lambda accordingly
            u = jax.random.uniform(key, self.shape, jnp.float32, 0.9, 0.999)
            return jnp.log(u / (1 - u)).astype(self.dtype)
        fan_in = self.shape[0] if self.shape else 1
        std = self.scale / math.sqrt(max(fan_in, 1))
        return (jax.random.truncated_normal(key, -2.0, 2.0, self.shape, jnp.float32)
                * std).astype(self.dtype)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_paths(tree):
    return jax.tree_util.tree_flatten_with_path(tree, is_leaf=is_spec)


def init_from_specs(specs, rng):
    """Materialize a ParamSpec tree into a parameter pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(specs, is_leaf=is_spec)
    keys = jax.random.split(rng, len(leaves))
    vals = [s.materialize(k) for s, k in zip(leaves, keys)]
    return jax.tree_util.tree_unflatten(treedef, vals)


def abstract_from_specs(specs):
    return jax.tree_util.tree_map(lambda s: s.abstract(), specs, is_leaf=is_spec)


def logical_axes_from_specs(specs):
    return jax.tree_util.tree_map(lambda s: s.logical_axes, specs, is_leaf=is_spec)


# ---------------------------------------------------------------------------
# functional layers
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps=1e-6):
    # variance reduction in f32, elementwise product in the input dtype:
    # keeps the tensor crossing GSPMD sharding boundaries bf16 (f32 residual
    # activations would double every SP all-gather/reduce-scatter payload).
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale).astype(x.dtype)


def rms_norm_specs(dim, axes=(None,)):
    return {"scale": ParamSpec((dim,), axes, init="zeros")}


def soft_cap(x, cap):
    if not cap:
        return x
    return jnp.tanh(x / cap) * cap


def rope(x, positions, theta):
    """Rotary embedding.  x: (..., S, H, hd); positions: (..., S)."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., :, None].astype(jnp.float32) * freq      # (..., S, half)
    ang = ang[..., :, None, :]                                    # (..., S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# -- gated MLP (SwiGLU / GeGLU) ---------------------------------------------

def mlp_specs(d_model, d_ff):
    return {
        "wi": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "wg": ParamSpec((d_model, d_ff), ("embed", "ff")),
        "wo": ParamSpec((d_ff, d_model), ("ff", "embed")),
    }


def mlp_apply(p, x, act, ctx):
    h = jnp.einsum("bsd,df->bsf", x, p["wi"])
    g = jnp.einsum("bsd,df->bsf", x, p["wg"])
    g = jax.nn.silu(g) if act == "silu" else jax.nn.gelu(g, approximate=True)
    h = h * g
    h = ctx.shard(h, "batch", "seq", "ff")
    return jnp.einsum("bsf,fd->bsd", h, p["wo"])


# -- embedding ---------------------------------------------------------------

def embed_specs(vocab, d_model):
    return {"table": ParamSpec((vocab, d_model), ("vocab", "embed"), scale=1.0)}


def embed_apply(p, tokens, d_model):
    h = jnp.take(p["table"], tokens, axis=0)
    return (h.astype(jnp.float32) * math.sqrt(d_model)).astype(p["table"].dtype)


def unembed_apply(table, h, cap=0.0):
    logits = jnp.einsum("bsd,vd->bsv", h, table).astype(jnp.float32)
    return soft_cap(logits, cap)
