"""Pluggable search strategies: protocol, registry, four built-in families.

A strategy proposes configurations one at a time through an ask/tell loop:

    s = get_strategy("bayesian", space, seed=0, budget=64)
    while (sug := s.ask()) is not None:
        config, fidelity = sug
        objective, objectives = evaluate(config, fidelity)
        s.tell(config, objective, objectives, fidelity)

The driver (``repro.search.run.SearchRun``) owns evaluation, budgets and
checkpointing; strategies own *which config next*.  Two contracts make
budgeted + resumable runs work:

  * **Synchronous**: exactly one ``tell`` follows each ``ask`` before the
    next ``ask`` (the driver guarantees it).
  * **Deterministic**: ``ask`` is a pure function of (space, seed, options,
    tell-history).  All randomness flows through ``self._rng(*salt)`` —
    ``np.random.default_rng`` seeded by (seed, salt), never global state —
    so the same seed replays the same trial sequence, and a resumed run
    re-asks its way through the checkpoint to land in exactly the state an
    uninterrupted run would have reached.

Built-ins (see ``available_strategies()``):

``grid``          exhaustive enumeration in declaration order — bit-identical
                  to the historical ``dse.explore`` walk.
``random``        seeded uniform sampling, duplicate-free on finite spaces.
``bayesian``      Gaussian-process surrogate (RBF kernel over the encoded
                  [0,1]^d knob vectors, pure numpy) with expected-improvement
                  acquisition over a sampled candidate pool + local mutations
                  of the incumbent.
``evolutionary``  tournament selection, uniform crossover, per-dim mutation
                  over knob assignments.
``halving``       successive halving: price a wide pool at cheap proxy
                  fidelities (analytic roofline, then symmetric event loop)
                  and promote the top 1/eta to full evaluation.

Fidelity levels are floats the evaluator interprets (``run.SearchRun``):
0.0 = analytic roofline bound (no event loop), 0.5 = full event loop but
symmetric-cluster coalescing (hetero knobs priced at the baseline), 1.0 =
full evaluation.  Only ``halving`` emits sub-1.0 fidelities.
"""
from __future__ import annotations

import math
import zlib
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.search.space import SearchSpace

FIDELITY_ANALYTIC = 0.0
FIDELITY_SYMMETRIC = 0.5
FIDELITY_FULL = 1.0

#: name -> Strategy subclass
STRATEGIES: Dict[str, type] = {}


def register_strategy(name: str):
    def deco(cls):
        cls.name = name
        STRATEGIES[name] = cls
        return cls
    return deco


def available_strategies() -> List[str]:
    return sorted(STRATEGIES)


def get_strategy(name: str, space: SearchSpace, seed: int = 0,
                 budget: Optional[int] = None, **opts) -> "Strategy":
    """Instantiate a registered strategy; unknown names list the registry."""
    cls = STRATEGIES.get(name)
    if cls is None:
        raise ValueError(
            f"unknown search strategy {name!r}: available strategies are "
            f"{available_strategies()}")
    return cls(space, seed=seed, budget=budget, **opts)


class Strategy:
    """Base class: seeded RNG streams, duplicate tracking, tell-history."""
    name = "?"

    def __init__(self, space: SearchSpace, seed: int = 0,
                 budget: Optional[int] = None):
        self.space = space
        self.seed = int(seed)
        self.budget = budget
        self._told: List[Tuple[Dict, float, float]] = []  # (cfg, obj, fid)
        self._seen: set = set()          # config keys this strategy proposed
        self._n_asked = 0

    # -- seeded randomness ---------------------------------------------------
    def _rng(self, *salt) -> np.random.Generator:
        """Deterministic RNG stream named by (seed, *salt); strings hash via
        crc32 so stream names are stable across runs and platforms."""
        parts = [self.seed & 0xFFFFFFFF]
        for s in salt:
            parts.append(zlib.crc32(str(s).encode()) if isinstance(s, str)
                         else int(s) & 0xFFFFFFFF)
        return np.random.default_rng(parts)

    # -- protocol ------------------------------------------------------------
    def ask(self) -> Optional[Tuple[Dict, float]]:
        """Next (config, fidelity) suggestion, or None when exhausted."""
        raise NotImplementedError

    def tell(self, config: Dict, objective: float,
             objectives: Optional[Dict] = None,
             fidelity: float = FIDELITY_FULL) -> None:
        """Report the evaluated (scalarized) objective for `config`."""
        self._told.append((dict(config), float(objective), float(fidelity)))
        self._seen.add(self.space.config_key(config))

    # -- shared sampling helpers --------------------------------------------
    def _mark(self, config: Dict) -> Dict:
        self._seen.add(self.space.config_key(config))
        return config

    def _random_unseen(self, *salt) -> Optional[Dict]:
        """A seeded uniform sample no previous ask proposed; falls back to a
        grid scan on finite spaces, None once the space is exhausted."""
        rng = self._rng("rand", *salt)
        cfg = None
        for _ in range(64):
            cfg = self.space.sample(rng)
            if self.space.config_key(cfg) not in self._seen:
                return self._mark(cfg)
        if self.space.grid_size is None:
            return self._mark(cfg)       # continuous: collisions are measure-0
        for gc in self.space.grid_configs():
            if self.space.config_key(gc) not in self._seen:
                return self._mark(gc)
        return None

    def _full_told(self) -> List[Tuple[Dict, float]]:
        return [(c, o) for c, o, f in self._told if f >= FIDELITY_FULL]


@register_strategy("grid")
class GridStrategy(Strategy):
    """Exhaustive cartesian enumeration in knob declaration order — the
    executable spec the ``dse.explore`` adapter preserves bit-identically."""

    def __init__(self, space, seed: int = 0, budget: Optional[int] = None):
        super().__init__(space, seed=seed, budget=budget)
        self._iter = space.grid_configs()

    def ask(self):
        for cfg in self._iter:
            self._n_asked += 1
            return self._mark(cfg), FIDELITY_FULL
        return None


@register_strategy("random")
class RandomStrategy(Strategy):
    """Seeded uniform sampling without replacement (on finite spaces)."""

    def ask(self):
        i = self._n_asked
        self._n_asked += 1
        cfg = self._random_unseen(i)
        return None if cfg is None else (cfg, FIDELITY_FULL)


def _norm_cdf(z: float) -> float:
    return 0.5 * (1.0 + math.erf(z / math.sqrt(2.0)))


def _norm_pdf(z: float) -> float:
    return math.exp(-0.5 * z * z) / math.sqrt(2.0 * math.pi)


@register_strategy("bayesian")
class BayesianStrategy(Strategy):
    """GP surrogate + expected improvement, pure numpy.

    The surrogate is a zero-mean GP with an isotropic RBF kernel over the
    space's [0,1]^d encoding (y standardized per fit).  Acquisition
    maximizes EI over a seeded candidate pool — uniform samples plus local
    mutations of the incumbent — restricted to configs not yet proposed.
    The first ``init`` asks are random (seeded) design points."""

    def __init__(self, space, seed: int = 0, budget: Optional[int] = None,
                 init: Optional[int] = None, pool: int = 96,
                 n_mutants: int = 8, length_scale: float = 0.35,
                 noise: float = 1e-6):
        super().__init__(space, seed=seed, budget=budget)
        if init is None:
            init = max(4, min(8, (budget or 32) // 4))
        self.init = init
        self.pool = pool
        self.n_mutants = n_mutants
        self.length_scale = length_scale
        self.noise = noise

    def _fit(self, X: np.ndarray, y: np.ndarray):
        """Cholesky GP fit with jitter escalation; returns a predict(Xc)
        closure yielding (mu, sigma) arrays."""
        n, d = X.shape
        ls = self.length_scale * math.sqrt(max(1, d))
        d2 = ((X[:, None, :] - X[None, :, :]) ** 2).sum(-1)
        K = np.exp(-0.5 * d2 / (ls * ls))
        jitter = self.noise
        for _ in range(8):
            try:
                L = np.linalg.cholesky(K + jitter * np.eye(n))
                break
            except np.linalg.LinAlgError:
                jitter *= 10.0
        else:                            # pathological: give up on the GP
            return None
        alpha = np.linalg.solve(L.T, np.linalg.solve(L, y))

        def predict(Xc: np.ndarray):
            d2c = ((Xc[:, None, :] - X[None, :, :]) ** 2).sum(-1)
            Kc = np.exp(-0.5 * d2c / (ls * ls))
            mu = Kc @ alpha
            v = np.linalg.solve(L, Kc.T)
            var = np.maximum(1e-12, 1.0 - (v * v).sum(0))
            return mu, np.sqrt(var)

        return predict

    def ask(self):
        i = self._n_asked
        self._n_asked += 1
        told = self._full_told()
        if len(told) < self.init:
            cfg = self._random_unseen(i)
            return None if cfg is None else (cfg, FIDELITY_FULL)

        y_raw = np.array([o for _, o in told], dtype=np.float64)
        y_std = float(y_raw.std())
        if y_std < 1e-15:                # flat landscape: nothing to model
            cfg = self._random_unseen(i)
            return None if cfg is None else (cfg, FIDELITY_FULL)
        y = (y_raw - y_raw.mean()) / y_std
        X = np.stack([self.space.encode(c) for c, _ in told])
        predict = self._fit(X, y)
        if predict is None:
            cfg = self._random_unseen(i)
            return None if cfg is None else (cfg, FIDELITY_FULL)

        rng = self._rng("pool", len(told))
        best_cfg = min(told, key=lambda t: t[1])[0]
        cands, keys = [], set()
        for _ in range(self.pool):
            c = self.space.sample(rng)
            k = self.space.config_key(c)
            if k not in self._seen and k not in keys:
                cands.append(c)
                keys.add(k)
        for _ in range(self.n_mutants):
            c = self.space.mutate(best_cfg, rng)
            k = self.space.config_key(c)
            if k not in self._seen and k not in keys:
                cands.append(c)
                keys.add(k)
        if not cands:
            cfg = self._random_unseen(i)
            return None if cfg is None else (cfg, FIDELITY_FULL)

        Xc = np.stack([self.space.encode(c) for c in cands])
        mu, sigma = predict(Xc)
        y_best = float(y.min())
        ei = np.empty(len(cands))
        for j in range(len(cands)):
            s = float(sigma[j])
            z = (y_best - float(mu[j])) / s
            ei[j] = s * (z * _norm_cdf(z) + _norm_pdf(z))
        return self._mark(cands[int(np.argmax(ei))]), FIDELITY_FULL


@register_strategy("evolutionary")
class EvolutionaryStrategy(Strategy):
    """(mu + lambda)-style evolution over knob assignments: seeded random
    init population, then tournament-selected parents, uniform crossover and
    per-dim mutation; children are duplicate-free on finite spaces."""

    def __init__(self, space, seed: int = 0, budget: Optional[int] = None,
                 population: Optional[int] = None, tournament: int = 3,
                 crossover_prob: float = 0.6,
                 mutation_rate: Optional[float] = None):
        super().__init__(space, seed=seed, budget=budget)
        if population is None:
            population = max(4, min(16, (budget or 48) // 4))
        self.population = population
        self.tournament = tournament
        self.crossover_prob = crossover_prob
        self.mutation_rate = mutation_rate

    def _tournament(self, pool, rng) -> Dict:
        idx = rng.integers(len(pool), size=min(self.tournament, len(pool)))
        return min((pool[int(j)] for j in idx), key=lambda t: t[1])[0]

    def ask(self):
        i = self._n_asked
        self._n_asked += 1
        pool = self._full_told()
        if i < self.population or not pool:
            cfg = self._random_unseen(i)
            return None if cfg is None else (cfg, FIDELITY_FULL)
        rng = self._rng("evo", len(self._told))
        for _ in range(32):
            p1 = self._tournament(pool, rng)
            if rng.random() < self.crossover_prob and len(pool) > 1:
                p2 = self._tournament(pool, rng)
                child = self.space.crossover(p1, p2, rng)
            else:
                child = dict(p1)
            child = self.space.mutate(child, rng, rate=self.mutation_rate)
            if self.space.config_key(child) not in self._seen:
                return self._mark(child), FIDELITY_FULL
        cfg = self._random_unseen(i)
        return None if cfg is None else (cfg, FIDELITY_FULL)


@register_strategy("halving")
class HalvingStrategy(Strategy):
    """Successive halving over proxy fidelities.

    Each bracket samples ``n0`` fresh configs and prices them at the
    cheapest fidelity (analytic roofline — no event loop); the top
    ``1/eta`` survive to the next fidelity (symmetric event loop, hetero
    knobs coalesced to the baseline), and the top of *those* graduate to
    full evaluation.  ``n0`` is sized so one bracket's total evaluation
    count fits the remaining budget; brackets repeat while budget remains.
    Only full-fidelity trials compete for best/Pareto in the driver."""

    def __init__(self, space, seed: int = 0, budget: Optional[int] = None,
                 eta: int = 4,
                 fidelities: Tuple[float, ...] = (FIDELITY_ANALYTIC,
                                                  FIDELITY_SYMMETRIC,
                                                  FIDELITY_FULL)):
        super().__init__(space, seed=seed, budget=budget)
        if eta < 2:
            raise ValueError(f"halving needs eta >= 2, got {eta}")
        if not fidelities or list(fidelities) != sorted(fidelities):
            raise ValueError("fidelities must be ascending and non-empty")
        self.eta = eta
        self.fidelities = tuple(fidelities)
        self._bracket = 0
        self._rung = 0
        self._queue: List[Dict] = []     # configs awaiting ask at this rung
        self._results: List[Tuple[float, int, Dict]] = []  # rung tells
        self._rung_size = 0

    def _bracket_cost(self, n0: int) -> int:
        n, cost = n0, 0
        for _ in self.fidelities:
            cost += n
            n = max(1, n // self.eta)
        return cost

    def _start_bracket(self) -> bool:
        spent = len(self._told)
        remaining = (self.budget - spent) if self.budget else None
        if remaining is not None and remaining < 1:
            return False
        n0 = 1
        if remaining is None:
            n0 = self.eta ** (len(self.fidelities) - 1)
        else:
            while self._bracket_cost(n0 + 1) <= remaining:
                n0 += 1
        rng = self._rng("halving", self._bracket)
        queue, keys = [], set()
        for _ in range(64 * n0):
            if len(queue) >= n0:
                break
            c = self.space.sample(rng)
            k = self.space.config_key(c)
            if k not in self._seen and k not in keys:
                queue.append(c)
                keys.add(k)
        if len(queue) < n0 and self.space.grid_size is not None:
            for gc in self.space.grid_configs():
                if len(queue) >= n0:
                    break
                k = self.space.config_key(gc)
                if k not in self._seen and k not in keys:
                    queue.append(gc)
                    keys.add(k)
        if not queue:
            return False
        for c in queue:
            self._mark(c)
        self._bracket += 1
        self._rung = 0
        self._queue = queue
        self._results = []
        self._rung_size = len(queue)
        return True

    def _promote(self) -> bool:
        """Current rung complete: queue the survivors at the next fidelity;
        False when this was the top rung (bracket over)."""
        if self._rung + 1 >= len(self.fidelities):
            return False
        self._results.sort(key=lambda t: (t[0], t[1]))
        k = max(1, self._rung_size // self.eta)
        self._queue = [cfg for _, _, cfg in self._results[:k]]
        self._results = []
        self._rung += 1
        self._rung_size = len(self._queue)
        return True

    def ask(self):
        if not self._queue and len(self._results) >= self._rung_size:
            if not (self._rung_size and self._promote()):
                if not self._start_bracket():
                    return None
        if not self._queue:
            return None
        self._n_asked += 1
        return self._queue.pop(0), self.fidelities[self._rung]

    def tell(self, config, objective, objectives=None,
             fidelity: float = FIDELITY_FULL):
        super().tell(config, objective, objectives, fidelity)
        self._results.append((float(objective), len(self._results),
                              dict(config)))
