"""Multi-objective support: metric extraction, scalarization, Pareto front.

A search minimizes a tuple of named objectives per trial.  Objective names
resolve against the ``SimResult`` / ``ClusterSimResult`` the cost model
returned (``total_time``, ``exposed_comm``, ``comm_time``, ``peak_bytes``,
``max_barrier_wait``, ...) plus two derived metrics: ``peak_memory_proxy``
— the analytical per-rank liveness bound priced straight off the
(transformed) graph, so the memory axis costs nothing even at proxy
fidelities where no event loop ran — and ``bubble_fraction``, the
aggregate non-compute fraction of cluster rank-seconds
(``costmodel.schedule.bubble_fraction``), the natural objective for the
pipeline-schedule knobs (``num_microbatches`` / ``schedule``).

Objective *sense*: everything is minimized except the names in
``MAXIMIZE_OBJECTIVES`` (goodput-style metrics from the fault subsystem,
``repro.faults``).  ``scalarize`` negates their normalized contribution and
``dominates`` flips their comparisons, so "high goodput, low p99" Pareto
fronts come out right without callers hand-negating values — checkpoint
records and reports keep the natural (positive) readings.

Strategies need one scalar to rank candidates, so multi-objective values are
scalarized: a weighted sum of objectives normalized by a reference point
(the first completed trial's values, recorded in the checkpoint header's
position — deterministic and resume-stable).  The *report* keeps the full
vectors: ``pareto_front`` extracts the non-dominated set, which is the
artifact a step-time / exposed-comm / peak-memory DSE actually wants.
"""
from __future__ import annotations

from typing import Dict, List, Sequence

DEFAULT_OBJECTIVES = ("total_time",)

#: objective names that do not live on the sim result
_GRAPH_METRICS = ("peak_memory_proxy",)

#: result-attribute aliases: ``peak_memory_bytes`` is the schedule-aware
#: peak (``SimResult.peak_bytes`` — exact occupancy-curve max including
#: transient comm buffers), named to sit unambiguously beside the static
#: ``peak_memory_proxy``.
OBJECTIVE_ALIASES = {"peak_memory_bytes": "peak_bytes"}

#: the vetted objective names a search may request: SimResult /
#: ClusterSimResult fields, graph metrics, aliases, and the fault
#: subsystem's FaultSimResult attributes.  ``validate_objectives``
#: checks requests against this set up front so a typo fails at
#: SearchRun construction, not deep inside the first evaluation.
KNOWN_OBJECTIVES = frozenset({
    "total_time", "step_time", "compute_time", "comm_time",
    "exposed_comm", "peak_bytes", "peak_memory_bytes",
    "peak_memory_proxy", "max_barrier_wait", "bubble_fraction",
    "expected_goodput", "goodput", "worst_goodput", "goodput_std",
    "p99_step_time_under_faults", "makespan_inflation",
})


def validate_objectives(names: Sequence[str]) -> None:
    """Raise ``ValueError`` listing the valid options if any requested
    objective name is not in ``KNOWN_OBJECTIVES``."""
    unknown = [n for n in names if n not in KNOWN_OBJECTIVES]
    if unknown:
        raise ValueError(
            f"unknown objective(s) {sorted(unknown)!r}: valid names are "
            f"{sorted(KNOWN_OBJECTIVES)}")

#: objectives that are maximized (larger is better); everything else is
#: minimized.  These live on ``FaultSimResult`` (repro.faults) — a trial
#: config needs a fault knob (checkpoint_interval / fault_rate /
#: spare_ranks) for the evaluator to produce them.
MAXIMIZE_OBJECTIVES = frozenset({"expected_goodput", "goodput",
                                 "worst_goodput"})


def sense(name: str) -> float:
    """-1.0 for maximized objectives, +1.0 for minimized ones: multiplying
    a value by its sense yields a quantity that is always minimized."""
    return -1.0 if name in MAXIMIZE_OBJECTIVES else 1.0


def trial_objectives(result, names: Sequence[str], graph=None) -> Dict:
    """Extract the named objective values for one evaluated trial.

    `result` is whatever the simulator returned (SimResult /
    ClusterSimResult duck-type the same scalar fields); `graph` is the
    transformed graph the trial simulated — required only for
    ``peak_memory_proxy``."""
    out: Dict[str, float] = {}
    for name in names:
        if name == "peak_memory_proxy":
            if graph is None:
                raise ValueError("peak_memory_proxy objective needs the "
                                 "transformed trial graph")
            from repro.core.costmodel.simulator import peak_memory_proxy
            out[name] = float(peak_memory_proxy(graph))
        elif name == "bubble_fraction":
            # aggregate non-compute fraction of rank-seconds (the pipeline
            # fill/drain bubble + exposed comm) — pairs with the
            # num_microbatches / schedule DSE knobs
            from repro.core.costmodel.schedule import bubble_fraction
            out[name] = float(bubble_fraction(result))
        else:
            try:
                out[name] = float(getattr(result,
                                          OBJECTIVE_ALIASES.get(name, name)))
            except AttributeError:
                hint = ""
                if name in ("expected_goodput",
                            "p99_step_time_under_faults",
                            "makespan_inflation", "goodput_std"):
                    hint = (" (fault objectives need a fault knob — "
                            "checkpoint_interval / fault_rate / "
                            "spare_ranks — in the trial config so the "
                            "evaluator runs the fault Monte-Carlo)")
                raise ValueError(
                    f"unknown objective {name!r}: not a field of "
                    f"{type(result).__name__} and not one of "
                    f"{_GRAPH_METRICS}{hint}") from None
    return out


def scalarize(values: Dict, names: Sequence[str],
              weights: Sequence[float], ref: Dict) -> float:
    """Weighted sum of ``sense(name) * values[name] / ref[name]`` —
    minimized.

    Normalizing by the reference point puts seconds and bytes on one scale;
    a zero reference component falls back to 1.0 (the raw value).
    Maximized objectives contribute negatively, so improving goodput lowers
    the scalar exactly like lowering step time does."""
    total = 0.0
    for name, w in zip(names, weights):
        r = ref.get(name) or 1.0
        total += w * sense(name) * values[name] / r
    return total


def default_weights(names: Sequence[str]) -> List[float]:
    n = len(names)
    return [1.0 / n] * n


def dominates(a: Dict, b: Dict, names: Sequence[str]) -> bool:
    """a dominates b: no worse on every objective, strictly better on one
    (respecting each objective's sense)."""
    better = False
    for name in names:
        s = sense(name)
        av, bv = s * a[name], s * b[name]
        if av > bv:
            return False
        if av < bv:
            better = True
    return better


def pareto_front(values: Sequence[Dict], names: Sequence[str]) -> List[int]:
    """Indices of the non-dominated entries of `values` (each objective
    taken with its sense), in input order; duplicate points all survive."""
    n = len(values)
    keep = []
    for i in range(n):
        vi = values[i]
        dominated = False
        for j in range(n):
            if j != i and dominates(values[j], vi, names):
                dominated = True
                break
        if not dominated:
            keep.append(i)
    return keep
