"""``python -m repro.search`` — budgeted model-guided DSE from the shell.

    # bayesian search over software + hardware knobs of a captured graph,
    # checkpointed so a killed run resumes where it left off
    python -m repro.search run graph.json --strategy bayesian --budget 64 \\
        --knob "prefetch=0,2,4,8" --knob "bucket_bytes=null,64e6" \\
        --knob "link_bw=12.5e9,50e9,100e9@hardware" \\
        --checkpoint run.jsonl

    # multi-objective Pareto search on a trace-calibrated cost model
    python -m repro.search run graph.json --system calibrated.json \\
        --objectives total_time,peak_memory_proxy \\
        --knob "prefetch=0,1,2,4,8,16" --strategy random --budget 48

    # inspect a finished / interrupted run
    python -m repro.search front run.jsonl
    python -m repro.search strategies

Knob syntax: ``name=v1,v2,...[@layer]`` — values parse as JSON (``null``,
``true``, numbers, strings), layer defaults to software; ``hardware``
covers system + hetero cluster knobs.  ``workload`` knobs are rejected
here: they need recapture per value, which only the Python API
(``SearchRun`` with a ``graph_for`` callable) can do.
``--system cal.json`` loads the output of ``python -m repro.trace
calibrate -o cal.json`` so the search prices against fitted hardware.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.core import chakra
from repro.core.dse import Knob
from repro.search.run import SearchRun
from repro.search.strategies import available_strategies
from repro.trace.calibrate import system_from_flags


def _parse_value(tok: str):
    try:
        return json.loads(tok)
    except json.JSONDecodeError:
        try:
            return float(tok)            # bare 64e6 etc.
        except ValueError:
            return tok


def parse_knob(spec: str) -> Knob:
    """``name=v1,v2,...[@layer]`` -> Knob."""
    if "=" not in spec:
        raise ValueError(f"bad --knob {spec!r}: expected name=v1,v2[@layer]")
    name, rest = spec.split("=", 1)
    layer = "software"
    if "@" in rest:
        rest, layer = rest.rsplit("@", 1)
        if layer not in ("workload", "software", "hardware"):
            raise ValueError(f"bad --knob layer {layer!r}")
    values = [_parse_value(t) for t in rest.split(",") if t != ""]
    if not values:
        raise ValueError(f"bad --knob {spec!r}: no values")
    return Knob(name.strip(), values, layer=layer)


def _print_progress(p: dict) -> None:
    """Default ``--progress`` sink: one status line per report, stderr so
    result output stays parseable."""
    total = p["budget"] if p["budget"] is not None else "?"
    best = f"{p['best']:.4g}" if p["best"] is not None else "-"
    failed = f" failed={p['failed']}" if p["failed"] else ""
    tail = " done" if p.get("done") else ""
    print(f"progress: {p['trials']}/{total} trials best={best}{failed} "
          f"elapsed={p['elapsed']:.1f}s{tail}", file=sys.stderr)


def _cmd_run(args) -> int:
    try:
        return _run_checked(args)
    except ValueError as e:
        # bad --knob specs, unknown strategies/objectives, checkpoint
        # header mismatches: user errors, not tracebacks
        print(f"error: {e}", file=sys.stderr)
        return 2


def _run_checked(args) -> int:
    g = chakra.Graph.load(args.graph)
    sysc, derate = system_from_flags(
        args, flags=("chips", "topology", "link_bw", "peak_flops",
                     "hbm_bw"))
    knobs = [parse_knob(s) for s in args.knob]
    if not knobs:
        print("error: need at least one --knob", file=sys.stderr)
        return 2
    wl = [k.name for k in knobs if k.layer == "workload"]
    if wl:
        # the CLI evaluates ONE pre-captured graph; a workload knob needs
        # graph_for to recapture per value, which only the Python API
        # (SearchRun(graph_for=...)) can do — searching it here would
        # silently sweep a no-op axis
        print(f"error: workload-layer knobs {wl} need recapture per value; "
              "use the Python API (repro.search.SearchRun with a graph_for "
              "callable) — the CLI searches one captured graph "
              "(software/hardware layers only)", file=sys.stderr)
        return 2
    objectives = [o.strip() for o in args.objectives.split(",") if o.strip()]
    weights = None
    if args.weights:
        weights = [float(w) for w in args.weights.split(",")]
    progress = _print_progress if args.progress else None
    if args.obs:
        from repro.obs import record as obsrec
        obsrec.enable()
    run = SearchRun(lambda cfg: g, sysc, knobs, strategy=args.strategy,
                    objectives=objectives, weights=weights,
                    budget=args.budget, wall_clock=args.wall_clock,
                    seed=args.seed, checkpoint=args.checkpoint,
                    compute_derate=derate, jobs=args.jobs,
                    progress=progress)
    try:
        res = run.run()
    finally:
        if args.obs:
            from repro.obs import record as obsrec
            obsrec.dump_metrics(args.obs)
            obsrec.disable()
            print(f"wrote obs metrics to {args.obs}", file=sys.stderr)
    print(res.summary())
    if len(objectives) > 1:
        for t in sorted(res.pareto_trials(), key=lambda t: t.objective):
            obj = ", ".join(f"{k}={v:.4g}" for k, v in t.objectives.items())
            print(f"  front #{t.index}: {t.config} -> {obj}")
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"strategy": res.strategy,
                       "objectives": list(res.objective_names),
                       "best": res.best.as_dict() if res.best else None,
                       "pareto": [t.as_dict() for t in res.pareto_trials()],
                       "trials": [t.as_dict() for t in res.trials]},
                      f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out}")
    return 0


def _cmd_front(args) -> int:
    """Best + Pareto front straight from a checkpoint JSONL (no re-run)."""
    from repro.search.objectives import pareto_front
    from repro.search.run import read_checkpoint
    try:
        head, trials, _ = read_checkpoint(args.checkpoint)
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if head is None:
        print(f"error: {args.checkpoint} is empty", file=sys.stderr)
        return 2
    names = head["objectives"]
    failed = [t for t in trials if t.get("error")]
    full = [t for t in trials
            if t.get("fidelity", 1.0) >= 1.0 and not t.get("error")]
    print(f"{args.checkpoint}: strategy={head['strategy']} "
          f"seed={head['seed']} trials={len(trials)} full={len(full)} "
          + (f"failed={len(failed)} " if failed else "")
          + f"objectives={names}")
    if not full:
        return 0
    best = min(full, key=lambda t: t["objective"])
    print(f"best #{best['index']}: {best['config']} -> {best['objectives']}")
    for i in pareto_front([t["objectives"] for t in full], names):
        t = full[i]
        print(f"  front #{t['index']}: {t['config']} -> {t['objectives']}")
    return 0


def _cmd_strategies(args) -> int:
    for name in available_strategies():
        print(name)
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.search", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    rn = sub.add_parser("run", help="search a knob space over a graph")
    rn.add_argument("graph", help="chakra graph JSON (Graph.save output)")
    rn.add_argument("--knob", action="append", default=[],
                    metavar="NAME=V1,V2[@LAYER]",
                    help="repeatable; JSON values, layer in "
                         "workload|software|hardware")
    rn.add_argument("--strategy", default="random",
                    help=f"one of {available_strategies()}")
    rn.add_argument("--budget", type=int, default=64,
                    help="max evaluations, resumed trials included")
    rn.add_argument("--jobs", type=int, default=1, metavar="N",
                    help="evaluate each generation of up to N pending "
                         "asks on a fork process pool (1 = serial)")
    rn.add_argument("--wall-clock", type=float, default=None,
                    help="max seconds of search time")
    rn.add_argument("--objectives", default="total_time",
                    help="comma-separated, minimized (SimResult fields "
                         "or peak_memory_proxy)")
    rn.add_argument("--weights", default=None,
                    help="comma-separated scalarization weights")
    rn.add_argument("--seed", type=int, default=0)
    rn.add_argument("--checkpoint", default=None, metavar="JSONL",
                    help="append trials here; an existing file resumes "
                         "without re-evaluating (same strategy/seed/"
                         "budget/knobs required)")
    rn.add_argument("--out", default=None, help="write result JSON")
    rn.add_argument("--progress", action="store_true",
                    help="print a rate-limited status line per generation "
                         "to stderr")
    rn.add_argument("--obs", default=None, metavar="JSON",
                    help="record instrumentation (repro.obs) around the "
                         "run and write the metrics JSON here; inspect "
                         "with `python -m repro.obs report`")
    rn.add_argument("--system", default=None, metavar="JSON",
                    help="calibrated system from `repro.trace calibrate -o`")
    rn.add_argument("--chips", type=int, default=None)
    rn.add_argument("--topology", default=None)
    rn.add_argument("--link-bw", type=float, default=None, dest="link_bw")
    rn.add_argument("--peak-flops", type=float, default=None,
                    dest="peak_flops")
    rn.add_argument("--hbm-bw", type=float, default=None, dest="hbm_bw")
    rn.add_argument("--derate", type=float, default=None)
    rn.set_defaults(fn=_cmd_run)

    fr = sub.add_parser("front", help="print best + Pareto front of a "
                                      "checkpoint")
    fr.add_argument("checkpoint")
    fr.set_defaults(fn=_cmd_front)

    st = sub.add_parser("strategies", help="list registered strategies")
    st.set_defaults(fn=_cmd_strategies)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
