import sys

from repro.search.cli import main

if __name__ == "__main__":
    sys.exit(main())
