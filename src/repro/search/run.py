"""``SearchRun``: the budgeted, resumable driver of one DSE search.

Owns everything a strategy does not: evaluation (reusing the dse layer's
capture / software-pass / compiled-simulator caching and its hetero-knob
routing onto ``simulate_cluster``), proxy-fidelity routing for successive
halving, multi-objective extraction + scalarization, trial and wall-clock
budgets, and a JSONL checkpoint that makes a killed run resumable without
re-evaluating completed trials.

Checkpoint format (append-only JSONL)
-------------------------------------
Line 1 is a header binding the run's identity — strategy name, seed,
budget, objective names + weights, and the space signature (budget included
because it sizes init designs / populations / halving brackets, i.e. the
ask sequence itself); every following line is
one completed trial ``{index, config, objectives, objective, fidelity}``
with JSON-native config values.  Trials evaluated as one ``jobs > 1``
pool generation additionally carry ``gen`` — the trial index the
generation started at — because a batched run asks the whole generation
*before* telling any of it, and replay must reproduce that exact
ask/tell interleaving for tell-dependent strategies.  On resume the
header must match and the trials are *replayed through the strategy*:
the driver re-asks generation by generation, checks each suggestion
against the recorded config (asks are deterministic in seed + tell
history, see ``strategies``), and tells the recorded result — landing
the strategy in exactly the state an uninterrupted run would have
reached, at zero simulation cost, regardless of the current ``jobs``
value.  A partially-written last line (the kill case) is ignored.

Fidelities (successive halving's cheap rungs):
  1.0  full evaluation — hetero knobs route to ``simulate_cluster``;
       fault knobs run the seeded fault Monte-Carlo (``repro.faults``)
  0.5  symmetric event loop — hetero knobs coalesced to the baseline rank;
       fault knobs priced by the Young/Daly closed form
  0.0  analytic roofline bound — no event loop at all
Only full-fidelity trials compete for ``best`` and the Pareto front.

Failed trials
-------------
An exception inside ``_evaluate`` (a config whose capture or simulation
raises) does NOT kill the sweep: the trial is recorded with an ``error``
string and the fixed penalty objective ``FAILED_OBJECTIVE``, the strategy
is told that penalty (deterministically — resume replays the exact same
tell), and the loop moves on.  Failed trials are excluded from ``best``,
``full_trials`` and the Pareto front but count against the budget, exactly
like a crashed job would burn its cluster allocation.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core import chakra, dse
from repro.core.costmodel.simulator import simulate, simulate_analytic
from repro.core.costmodel.topology import Topology, build_topology
from repro.obs import record as obs
from repro.search import objectives as objmod
from repro.search.space import SearchSpace
from repro.search.strategies import (FIDELITY_FULL, FIDELITY_SYMMETRIC,
                                     get_strategy)

CHECKPOINT_VERSION = 1

# scalarized objective recorded for a trial whose evaluation raised: huge
# enough that no surviving config ranks behind it, finite so surrogate
# models (GP fit, tournament scores) stay well-conditioned
FAILED_OBJECTIVE = 1e6


@dataclasses.dataclass
class SearchTrial:
    """One evaluated configuration."""
    index: int
    config: Dict
    objectives: Dict                 # name -> measured value ({} if failed)
    objective: float                 # scalarized (normalized weighted sum)
    fidelity: float = FIDELITY_FULL
    result: object = None            # SimResult/ClusterSimResult (not resumed)
    error: Optional[str] = None      # "ExcType: message" for a failed trial
    gen: Optional[int] = None        # start index of this trial's pool
                                     # generation (None = serial singleton)

    @property
    def is_full(self) -> bool:
        return self.fidelity >= FIDELITY_FULL

    @property
    def ok(self) -> bool:
        return self.error is None

    def as_dict(self) -> Dict:
        d = {"index": self.index,
             "config": {k: dse.json_value(v)
                        for k, v in self.config.items()},
             "objectives": self.objectives,
             "objective": self.objective,
             "fidelity": self.fidelity}
        if self.error is not None:
            d["error"] = self.error
        if self.gen is not None:
            d["gen"] = self.gen
        return d


@dataclasses.dataclass
class SearchResult:
    """Outcome of one ``SearchRun.run()`` call."""
    trials: List[SearchTrial]
    objective_names: Tuple[str, ...]
    strategy: str
    n_evaluated: int                 # simulated in THIS call
    n_resumed: int                   # replayed from the checkpoint
    elapsed: float

    @property
    def full_trials(self) -> List[SearchTrial]:
        return [t for t in self.trials if t.is_full and t.ok]

    @property
    def failed_trials(self) -> List[SearchTrial]:
        return [t for t in self.trials if not t.ok]

    @property
    def best(self) -> Optional[SearchTrial]:
        full = self.full_trials
        return min(full, key=lambda t: t.objective) if full else None

    def pareto_trials(self) -> List[SearchTrial]:
        """Non-dominated full-fidelity trials (all objectives minimized)."""
        full = self.full_trials
        idx = objmod.pareto_front([t.objectives for t in full],
                                  self.objective_names)
        return [full[i] for i in idx]

    def best_curve(self) -> List[float]:
        """Best-so-far scalarized objective after each full trial — the
        sample-efficiency curve benchmarks compare strategies on."""
        out, best = [], float("inf")
        for t in self.full_trials:
            if t.objective < best:
                best = t.objective
            out.append(best)
        return out

    def summary(self) -> str:
        b = self.best
        failed = len(self.failed_trials)
        lines = [f"search[{self.strategy}]: {len(self.trials)} trials "
                 f"({self.n_resumed} resumed, {self.n_evaluated} evaluated, "
                 f"{len(self.full_trials)} full-fidelity"
                 + (f", {failed} failed" if failed else "")
                 + f") in {self.elapsed:.2f}s"]
        if b is not None:
            obj = ", ".join(f"{k}={v:.4g}" for k, v in b.objectives.items())
            lines.append(f"  best #{b.index}: {b.config} -> {obj}")
        if len(self.objective_names) > 1:
            front = self.pareto_trials()
            lines.append(f"  pareto front: {len(front)} configs")
        return "\n".join(lines)


def _json_cfg(config: Dict) -> Dict:
    return {k: dse.json_value(v) for k, v in config.items()}


def read_checkpoint(path: str):
    """Parse a checkpoint JSONL -> (header, trial records, dirty flag) —
    the one reader shared by ``SearchRun`` resume and the CLI's ``front``.

    A torn final line (killed mid-write) is dropped and reported dirty; a
    corrupt interior line or an unsupported format version raises.  Header
    is None for an empty/headerless file."""
    with open(path) as f:
        raw = f.read()
    rows = raw.splitlines()
    dirty = bool(raw) and not raw.endswith("\n")
    lines = []
    for i, ln in enumerate(rows):
        if not ln.strip():
            continue
        try:
            lines.append(json.loads(ln))
        except json.JSONDecodeError:
            if i == len(rows) - 1:
                dirty = True
                break                    # torn tail from a kill — drop it
            raise ValueError(f"{path}:{i + 1}: corrupt checkpoint line")
    if not lines:
        return None, [], dirty
    head = lines[0]
    if not isinstance(head, dict) or "search" not in head:
        raise ValueError(f"{path}: not a search checkpoint "
                         "(missing header line)")
    if head["search"] != CHECKPOINT_VERSION:
        raise ValueError(f"{path}: checkpoint format version "
                         f"{head['search']} != supported "
                         f"{CHECKPOINT_VERSION}")
    return head, lines[1:], dirty


class SearchRun:
    """Drive one strategy over one space against one workload.

    `space` is a ``SearchSpace`` or a ``dse.Knob`` list; `graph_for(config)`
    returns the captured workload graph (cached per distinct workload-knob
    assignment, exactly like ``dse.explore``).  `objectives` are minimized;
    with several, trials are scalarized for the strategy (weighted sum
    normalized by the first trial's values) and the Pareto front is
    extracted from the full vectors.  `budget` caps total evaluations
    (any fidelity, resumed trials included), `wall_clock` caps seconds
    spent in ``run()``.  `checkpoint` names a JSONL file to append trials
    to and resume from.  `system`/`compute_derate`/`topo` accept a
    trace-calibrated model (``repro.trace.calibrate`` /
    ``load_system_json``) so searches price against fitted hardware.

    `jobs=N` evaluates each generation of up to N pending asks on a fork
    process pool (``repro.core.pool``): the strategy is asked until it
    has no suggestion or the generation is full, the batch fans out, and
    tells happen in ask order — deterministic and checkpoint-replayable
    (see the ``gen`` record field).  Tell-independent strategies (grid,
    random) produce the exact serial trial sequence; tell-dependent ones
    (bayesian, evolutionary) become batch-suggestion searches, the
    standard parallel-BO trade of model freshness for throughput."""

    def __init__(self, graph_for: Callable[[Dict], chakra.Graph], system,
                 space, strategy: str = "random",
                 objectives: Sequence[str] = objmod.DEFAULT_OBJECTIVES,
                 weights: Optional[Sequence[float]] = None,
                 budget: Optional[int] = 64,
                 wall_clock: Optional[float] = None,
                 seed: int = 0, checkpoint: Optional[str] = None,
                 compute_derate: float = 0.6,
                 topo: Optional[Topology] = None,
                 strategy_opts: Optional[Dict] = None,
                 jobs: int = 1,
                 progress: Optional[Callable[[Dict], None]] = None,
                 progress_interval: float = 1.0):
        self.graph_for = graph_for
        self.system = system
        self.space = space if isinstance(space, SearchSpace) \
            else SearchSpace.from_knobs(space)
        self.objective_names = tuple(objectives)
        if not self.objective_names:
            raise ValueError("need at least one objective")
        objmod.validate_objectives(self.objective_names)
        self.weights = list(weights) if weights is not None \
            else objmod.default_weights(self.objective_names)
        if len(self.weights) != len(self.objective_names):
            raise ValueError(f"{len(self.weights)} weights for "
                             f"{len(self.objective_names)} objectives")
        self.budget = budget
        self.wall_clock = wall_clock
        self.jobs = max(1, int(jobs or 1))
        # optional observer of search progress: called with a summary dict
        # after a generation's tells land, rate-limited to one call per
        # `progress_interval` seconds (plus always one final call when the
        # loop ends).  Progress is advisory — exceptions in the callback
        # propagate (a broken observer should be loud, not silent).
        self.progress = progress
        self.progress_interval = float(progress_interval)
        self.seed = int(seed)
        self.checkpoint = checkpoint
        self.compute_derate = compute_derate
        self.topo = topo
        self.strategy_name = strategy
        self.strategy = get_strategy(strategy, self.space, seed=self.seed,
                                     budget=budget, **(strategy_opts or {}))
        # capture + software-pass memoization shared with dse.explore /
        # greedy_descent — all strategies price identical configs against
        # identical graphs
        self._memo = dse.GraphMemo(graph_for,
                                   [d.name for d in self.space.dims
                                    if d.layer == "workload"])
        self._ref: Optional[Dict] = None   # scalarization reference point

    # -- evaluation ----------------------------------------------------------
    def _evaluate(self, cfg: Dict, fidelity: float):
        """(result, objective-values) for one config at one fidelity."""
        g2 = self._memo.transformed(cfg)
        if fidelity >= FIDELITY_FULL:
            res = dse._simulate_cfg(g2, self.system, cfg,
                                    self.compute_derate, self.topo)
        else:
            sys2 = dse._system_for(self.system, cfg)
            topo = self.topo
            if topo is None or any(k in cfg for k in dse._TOPO_KNOBS):
                topo = build_topology(sys2)
            sim = simulate if fidelity >= FIDELITY_SYMMETRIC \
                else simulate_analytic
            res = sim(g2, sys2, topo, algo=sys2.collective_algo,
                      compute_derate=self.compute_derate)
            if any(cfg.get(k) is not None for k in dse._FAULT_KNOBS):
                # proxy-fidelity fault metrics: Young/Daly closed form on
                # the proxy step time — keeps halving rungs cheap while
                # preserving the gross ordering of reliability configs
                from repro.faults.montecarlo import analytic_fault_metrics
                res = analytic_fault_metrics(
                    res, cfg, int(cfg.get("cluster_ranks") or topo.n_ranks))
        vals = objmod.trial_objectives(res, self.objective_names, graph=g2)
        return res, vals

    def _evaluate_batch(self, gen) -> List[Tuple]:
        """``[(result, objectives, error)]`` for one generation of asks, in
        ask order.  A multi-trial generation fans out on the fork pool
        when the platform has one: the parent captures/transforms/lowers
        every config serially first, so workers inherit the warm caches
        copy-on-write and pay only their own event loops.  The serial
        path (jobs=1, single-trial generations, no fork) produces
        identical triples, including the error-string format."""
        if len(gen) > 1:
            from repro.core import pool as _pool
            if _pool.pool_available():
                from repro.core.costmodel.compiled import compile_graph
                for cfg, _ in gen:
                    try:
                        compile_graph(self._memo.transformed(cfg))
                    except Exception:  # noqa: BLE001 — surfaced per-trial
                        pass           # by the worker below
                out = []
                for val, err in _pool.map_fork(
                        lambda s: self._evaluate(s[0], s[1]), gen,
                        jobs=len(gen)):
                    out.append((None, {}, err) if err is not None
                               else (val[0], val[1], None))
                return out
        out = []
        for cfg, fid in gen:
            try:
                res, vals = self._evaluate(cfg, fid)
                out.append((res, vals, None))
            except Exception as e:  # noqa: BLE001 — any bad config
                out.append((None, {}, f"{type(e).__name__}: {e}"))
        return out

    def _scalarize(self, vals: Dict) -> float:
        if self._ref is None:
            self._ref = dict(vals)
        return objmod.scalarize(vals, self.objective_names, self.weights,
                                self._ref)

    # -- checkpoint ----------------------------------------------------------
    def _header(self) -> Dict:
        # budget is part of the identity: it sizes bayesian init designs,
        # evolutionary populations and halving brackets, so a different
        # budget would change the ask sequence and break replay
        return {"search": CHECKPOINT_VERSION,
                "strategy": self.strategy_name, "seed": self.seed,
                "budget": self.budget,
                "objectives": list(self.objective_names),
                "weights": self.weights,
                "space": self.space.signature()}

    def _load_checkpoint(self) -> Tuple[List[Dict], bool]:
        """``read_checkpoint`` + header-identity validation.  The dirty flag
        (torn final line from a kill) makes ``run()`` rewrite the file
        before appending — otherwise the next trial would merge into the
        torn fragment and corrupt the line for every later resume."""
        head, records, dirty = read_checkpoint(self.checkpoint)
        if head is None:
            return [], dirty
        mine = self._header()
        for field in ("strategy", "seed", "budget", "objectives", "weights",
                      "space"):
            if head.get(field) != mine[field]:
                raise ValueError(
                    f"{self.checkpoint}: header {field!r} mismatch — "
                    f"checkpoint has {head.get(field)!r}, this run has "
                    f"{mine[field]!r}; refusing to resume a different "
                    "search (resume needs the same strategy, seed, budget, "
                    "objectives and space)")
        return records, dirty

    def _check_record(self, rec, i: int) -> None:
        """Validate one checkpoint trial record's shape up front — a clear
        diagnostic naming the missing field and line beats a KeyError deep
        in replay.  (Line i+2: line 1 is the header.)"""
        if not isinstance(rec, dict):
            raise ValueError(f"{self.checkpoint}:{i + 2}: trial record is "
                             f"{type(rec).__name__}, expected an object")
        for field in ("config", "objective"):
            if field not in rec:
                raise ValueError(f"{self.checkpoint}:{i + 2}: trial record "
                                 f"missing field {field!r}")
        if "objectives" not in rec and "error" not in rec:
            raise ValueError(f"{self.checkpoint}:{i + 2}: trial record "
                             "missing field 'objectives' (and carries no "
                             "'error' marking it failed)")

    def _replay(self, records: List[Dict]) -> List[SearchTrial]:
        """Re-ask the strategy through the recorded trials (no simulation):
        determinism of ask() given the tell history makes this land in the
        exact state an uninterrupted run would be in.  Failed records
        (``error`` set) replay their recorded penalty objective — the same
        tell the live loop issued.

        Records sharing a ``gen`` tag were one pool generation: the live
        loop asked them all before telling any, so replay reproduces that
        ask/tell interleaving (it matters for tell-dependent strategies
        — a bayesian ask after the tells would propose different
        configs).  Records without the tag are singleton generations, the
        serial format — old checkpoints replay unchanged."""
        out = []
        i = 0
        while i < len(records):
            gtag = records[i].get("gen") \
                if isinstance(records[i], dict) else None
            j = i + 1
            while (gtag is not None and j < len(records)
                   and isinstance(records[j], dict)
                   and records[j].get("gen") == gtag):
                j += 1
            batch = records[i:j]
            sugs = []
            for k, rec in enumerate(batch):
                self._check_record(rec, i + k)
                sug = self.strategy.ask()
                if sug is None:
                    raise ValueError(
                        f"{self.checkpoint}: strategy exhausted after "
                        f"{len(out) + len(sugs)} trials but checkpoint has "
                        f"{len(records)} — space or strategy code changed?")
                cfg, fid = sug
                if _json_cfg(cfg) != rec["config"] or \
                        abs(fid - rec.get("fidelity", FIDELITY_FULL)) > 1e-12:
                    raise ValueError(
                        f"{self.checkpoint}: replay diverged at trial "
                        f"{len(out) + len(sugs)}: strategy proposed "
                        f"{_json_cfg(cfg)}@{fid}, checkpoint recorded "
                        f"{rec['config']}@{rec.get('fidelity')} — seed, "
                        "space or strategy code changed since the "
                        "checkpoint was written")
                sugs.append(sug)
            for (cfg, fid), rec in zip(sugs, batch):
                err = rec.get("error")
                vals = rec.get("objectives") or {}
                if self._ref is None and err is None:
                    # the reference point is the first *successful* trial,
                    # both live and on replay — failed trials never set it
                    self._ref = dict(vals)
                self.strategy.tell(cfg, rec["objective"], vals, fid)
                out.append(SearchTrial(index=len(out), config=dict(cfg),
                                       objectives=dict(vals),
                                       objective=rec["objective"],
                                       fidelity=fid, result=None, error=err,
                                       gen=gtag))
            i = j
        return out

    # -- driver --------------------------------------------------------------
    def _progress_payload(self, trials: List[SearchTrial], t0: float,
                          n_new: int, n_resumed: int,
                          done: bool) -> Dict:
        best = None
        for t in trials:
            if t.is_full and t.ok and (best is None
                                       or t.objective < best.objective):
                best = t
        return {"trials": len(trials), "budget": self.budget,
                "evaluated": n_new, "resumed": n_resumed,
                "failed": sum(1 for t in trials if not t.ok),
                "best": best.objective if best is not None else None,
                "best_index": best.index if best is not None else None,
                "elapsed": time.monotonic() - t0, "done": done}

    def run(self) -> SearchResult:
        t0 = time.monotonic()
        trials: List[SearchTrial] = []
        dirty = False
        if self.checkpoint and os.path.exists(self.checkpoint):
            records, dirty = self._load_checkpoint()
            trials = self._replay(records)
        n_resumed = len(trials)

        ckpt = None
        if self.checkpoint:
            if dirty:
                # rewrite header + surviving trials so the torn fragment
                # can't merge with the next appended line
                with open(self.checkpoint, "w") as f:
                    f.write(json.dumps(self._header(), sort_keys=True)
                            + "\n")
                    for t in trials:
                        f.write(json.dumps(t.as_dict(), sort_keys=True)
                                + "\n")
            fresh = not (os.path.exists(self.checkpoint)
                         and os.path.getsize(self.checkpoint) > 0)
            ckpt = open(self.checkpoint, "a")
            if fresh:
                ckpt.write(json.dumps(self._header(), sort_keys=True) + "\n")
                ckpt.flush()

        n_new = 0
        deadline = (t0 + self.wall_clock) if self.wall_clock is not None \
            else None
        last_prog = t0
        try:
            while self.budget is None or len(trials) < self.budget:
                if deadline is not None and time.monotonic() >= deadline:
                    break
                # one generation: up to `jobs` pending asks.  ask() may
                # return None mid-generation with tells outstanding (a
                # halving rung waiting on its own results) — that only
                # ends the generation; exhaustion is None on an *empty*
                # generation.
                cap = self.jobs
                if self.budget is not None:
                    cap = min(cap, self.budget - len(trials))
                gen: List[Tuple[Dict, float]] = []
                with obs.span("search.ask"):
                    while len(gen) < cap:
                        sug = self.strategy.ask()
                        if sug is None:
                            break
                        gen.append(sug)
                if not gen:
                    break
                obs.counter("search.generations")
                obs.counter("search.gen_trials", len(gen))
                gen_tag = len(trials) if len(gen) > 1 else None
                with obs.span("search.evaluate"):
                    evaluated = self._evaluate_batch(gen)
                with obs.span("search.tell"):
                    for (cfg, fid), (res, vals, err) in zip(gen, evaluated):
                        scal = self._scalarize(vals) if err is None \
                            else FAILED_OBJECTIVE
                        if err is not None:
                            obs.counter("search.failed_trials")
                        trial = SearchTrial(index=len(trials),
                                            config=dict(cfg),
                                            objectives=vals, objective=scal,
                                            fidelity=fid, result=res,
                                            error=err, gen=gen_tag)
                        self.strategy.tell(cfg, scal, vals, fid)
                        trials.append(trial)
                        n_new += 1
                        if ckpt is not None:
                            ckpt.write(json.dumps(trial.as_dict(),
                                                  sort_keys=True) + "\n")
                            ckpt.flush()
                if self.progress is not None:
                    now = time.monotonic()
                    if now - last_prog >= self.progress_interval:
                        last_prog = now
                        self.progress(self._progress_payload(
                            trials, t0, n_new, n_resumed, done=False))
        finally:
            if ckpt is not None:
                ckpt.close()
        if self.progress is not None:
            self.progress(self._progress_payload(trials, t0, n_new,
                                                 n_resumed, done=True))
        return SearchResult(trials=trials,
                            objective_names=self.objective_names,
                            strategy=self.strategy_name,
                            n_evaluated=n_new, n_resumed=n_resumed,
                            elapsed=time.monotonic() - t0)
