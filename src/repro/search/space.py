"""Search-space model: knobs across the paper's three layers, encodable.

A ``SearchSpace`` is an ordered list of ``Dim``s.  Each dim covers one knob
— workload (recapture on change), software (graph passes), or hardware
(topology / bandwidths / hetero cluster shape) — and knows how to

  * enumerate itself (finite dims) for grid search,
  * sample a value from a seeded RNG,
  * encode a value into [0, 1] (the coordinate the Gaussian-process
    surrogate and distance-based operators see),
  * mutate a value (the evolutionary strategy's unit move).

``SearchSpace.from_knobs`` lifts the existing ``dse.Knob`` list unchanged:
dim order and value order are preserved, so ``grid_configs()`` enumerates
configs in exactly the order ``dse.explore(strategy="grid")`` always has
(itertools.product over knobs in declaration order) — the bit-identity
contract of the adapter.

Kinds
-----
``ordinal``     values form a scale (all numeric): encoded by rank, mutation
                prefers adjacent values — the common case for prefetch
                depths, bucket sizes, bandwidths, degraded fractions.
``categorical`` unordered values (strings, bools, mixed None): encoded by
                index (a pragmatic 1-D embedding for the GP; fine at the
                cardinalities DSE knobs have), mutation resamples uniformly.
``continuous``  a [lo, hi] float interval (optionally log-scaled); has no
                grid enumeration — grid search over a space containing one
                raises, model-guided strategies handle it natively.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, Iterable, Iterator, List, Optional, Sequence, Tuple

import numpy as np

ORDINAL = "ordinal"
CATEGORICAL = "categorical"
CONTINUOUS = "continuous"


def _is_number(v) -> bool:
    return isinstance(v, (int, float)) and not isinstance(v, bool)


@dataclasses.dataclass(frozen=True)
class Dim:
    """One knob of the search space.  Finite dims carry ``values`` (order
    preserved — it is the grid enumeration order); continuous dims carry
    ``lo``/``hi`` bounds instead."""
    name: str
    kind: str
    values: tuple = ()
    layer: str = "software"          # workload | software | hardware
    lo: float = 0.0
    hi: float = 1.0
    log: bool = False                # continuous: sample/encode in log space

    def __post_init__(self):
        if self.kind not in (ORDINAL, CATEGORICAL, CONTINUOUS):
            raise ValueError(f"unknown dim kind {self.kind!r}")
        if self.kind == CONTINUOUS:
            if not self.hi > self.lo:
                raise ValueError(f"{self.name}: need hi > lo, got "
                                 f"[{self.lo}, {self.hi}]")
            if self.log and self.lo <= 0:
                raise ValueError(f"{self.name}: log scale needs lo > 0")
        elif not self.values:
            raise ValueError(f"{self.name}: finite dim needs values")

    # -- constructors --------------------------------------------------------
    @classmethod
    def finite(cls, name: str, values: Sequence, layer: str = "software",
               kind: Optional[str] = None) -> "Dim":
        """Finite dim with kind inferred: all-numeric values are ordinal
        (rank-encoded, adjacent-step mutation), anything else categorical."""
        vals = tuple(values)
        if kind is None:
            kind = ORDINAL if vals and all(_is_number(v) for v in vals) \
                else CATEGORICAL
        return cls(name=name, kind=kind, values=vals, layer=layer)

    @classmethod
    def continuous(cls, name: str, lo: float, hi: float,
                   layer: str = "software", log: bool = False) -> "Dim":
        return cls(name=name, kind=CONTINUOUS, lo=float(lo), hi=float(hi),
                   layer=layer, log=log)

    # -- geometry ------------------------------------------------------------
    @property
    def n_choices(self) -> Optional[int]:
        return None if self.kind == CONTINUOUS else len(self.values)

    def _rank(self, v) -> int:
        """Index of `v` in values (ordinal dims compare by rank order, so
        encode() is monotone in the declared value order)."""
        try:
            return self.values.index(v)
        except ValueError:
            raise ValueError(f"{self.name}: value {v!r} not in "
                             f"{self.values!r}") from None

    def encode(self, v) -> float:
        """Value -> [0, 1] coordinate."""
        if self.kind == CONTINUOUS:
            if self.log:
                return (math.log(v) - math.log(self.lo)) \
                    / (math.log(self.hi) - math.log(self.lo))
            return (float(v) - self.lo) / (self.hi - self.lo)
        k = len(self.values)
        return self._rank(v) / (k - 1) if k > 1 else 0.5

    def sample(self, rng: np.random.Generator):
        if self.kind == CONTINUOUS:
            u = float(rng.random())
            if self.log:
                return math.exp(math.log(self.lo)
                                + u * (math.log(self.hi) - math.log(self.lo)))
            return self.lo + u * (self.hi - self.lo)
        return self.values[int(rng.integers(len(self.values)))]

    def mutate(self, v, rng: np.random.Generator):
        """One local move away from `v` (never returns `v` itself when the
        dim has more than one choice)."""
        if self.kind == CONTINUOUS:
            x = self.encode(v)
            x = min(1.0, max(0.0, x + float(rng.normal(0.0, 0.2))))
            if self.log:
                return math.exp(math.log(self.lo)
                                + x * (math.log(self.hi) - math.log(self.lo)))
            return self.lo + x * (self.hi - self.lo)
        k = len(self.values)
        if k <= 1:
            return v
        i = self._rank(v)
        if self.kind == ORDINAL:
            # prefer an adjacent rank; fall back over the boundary
            step = 1 if rng.random() < 0.5 else -1
            j = i + step
            if not 0 <= j < k:
                j = i - step
            return self.values[j]
        j = int(rng.integers(k - 1))
        return self.values[j if j < i else j + 1]


class SearchSpace:
    """Ordered collection of ``Dim``s over the joint workload / software /
    hardware knob space."""

    def __init__(self, dims: Iterable[Dim]):
        self.dims: List[Dim] = list(dims)
        names = [d.name for d in self.dims]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate dim names in {names}")

    @classmethod
    def from_knobs(cls, knobs) -> "SearchSpace":
        """Lift a ``dse.Knob`` list, preserving knob and value order."""
        return cls(Dim.finite(k.name, k.values, layer=k.layer)
                   for k in knobs)

    def __len__(self) -> int:
        return len(self.dims)

    def __repr__(self) -> str:
        return f"SearchSpace({[d.name for d in self.dims]})"

    @property
    def names(self) -> List[str]:
        return [d.name for d in self.dims]

    def dim(self, name: str) -> Dim:
        for d in self.dims:
            if d.name == name:
                return d
        raise KeyError(name)

    # -- enumeration ---------------------------------------------------------
    @property
    def grid_size(self) -> Optional[int]:
        """Number of grid configs, or None if any dim is continuous."""
        n = 1
        for d in self.dims:
            if d.kind == CONTINUOUS:
                return None
            n *= len(d.values)
        return n

    def grid_configs(self, limit: Optional[int] = None) -> Iterator[Dict]:
        """Enumerate the full cartesian grid in declaration order — the
        exact historical ``dse.explore(strategy='grid')`` order
        (itertools.product over knobs, value order preserved)."""
        if any(d.kind == CONTINUOUS for d in self.dims):
            cont = [d.name for d in self.dims if d.kind == CONTINUOUS]
            raise ValueError(f"grid enumeration undefined over continuous "
                             f"dims {cont}; use a sampling strategy")
        combos = itertools.product(*[[(d.name, v) for v in d.values]
                                     for d in self.dims]) \
            if self.dims else iter([()])
        if limit is not None:
            combos = itertools.islice(combos, limit)
        for c in combos:
            yield dict(c)

    # -- sampling / encoding -------------------------------------------------
    def sample(self, rng: np.random.Generator) -> Dict:
        return {d.name: d.sample(rng) for d in self.dims}

    def encode(self, config: Dict) -> np.ndarray:
        """Config -> point in [0, 1]^d (dims in declaration order)."""
        return np.array([d.encode(config[d.name]) for d in self.dims],
                        dtype=np.float64)

    def config_key(self, config: Dict) -> tuple:
        """Hashable identity of a config (dedup / memo key)."""
        return tuple((d.name, repr(config.get(d.name))) for d in self.dims)

    def mutate(self, config: Dict, rng: np.random.Generator,
               rate: Optional[float] = None) -> Dict:
        """Mutate each movable dim with probability `rate` (default
        1/#movable); always mutates at least one, so the child differs from
        the parent whenever any dim has > 1 choice.  Single-choice dims are
        never picked — they can only return the parent value and would
        silently burn the dedup retries of the strategies built on this."""
        movable = [d for d in self.dims
                   if d.kind == CONTINUOUS or len(d.values) > 1]
        if not movable:
            return dict(config)
        rate = rate if rate is not None else 1.0 / len(movable)
        out = dict(config)
        hit = False
        flips = rng.random(len(movable))
        for dim, f in zip(movable, flips):
            if f < rate:
                out[dim.name] = dim.mutate(config[dim.name], rng)
                hit = True
        if not hit:
            dim = movable[int(rng.integers(len(movable)))]
            out[dim.name] = dim.mutate(config[dim.name], rng)
        return out

    def crossover(self, a: Dict, b: Dict,
                  rng: np.random.Generator) -> Dict:
        """Uniform crossover: each dim from either parent with p=0.5."""
        picks = rng.random(len(self.dims))
        return {d.name: (a if p < 0.5 else b)[d.name]
                for d, p in zip(self.dims, picks)}

    # -- (de)serialization (checkpoint header compatibility check) -----------
    def signature(self) -> List:
        """JSON-able identity: a resumed run must search the same space."""
        out = []
        for d in self.dims:
            if d.kind == CONTINUOUS:
                out.append([d.name, d.kind, d.layer,
                            [d.lo, d.hi, bool(d.log)]])
            else:
                out.append([d.name, d.kind, d.layer,
                            [repr(v) for v in d.values]])
        return out
