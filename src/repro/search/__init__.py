"""Search subsystem: pluggable strategies over the joint DSE knob space.

The layer that turns the fast simulator (compiled event-loop replay,
per-config memoization, calibrated cost models) into an exploration engine:

  * ``SearchSpace`` / ``Dim`` — encode categorical / ordinal / continuous
    knobs across all three paper layers, hetero cluster knobs included
    (``space``).
  * ``Strategy`` protocol + registry — ``grid``, ``random``, ``bayesian``
    (GP + expected improvement, pure numpy), ``evolutionary``, ``halving``
    (successive halving over proxy fidelities) — ``strategies``.
  * multi-objective support — step time / exposed comm / analytical
    peak-memory proxy, scalarization + Pareto-front extraction
    (``objectives``).
  * ``SearchRun`` — trial + wall-clock budgets, JSONL checkpoint/resume
    (``run``), and a ``python -m repro.search`` CLI (``cli``) that accepts
    ``--system cal.json`` from the trace calibrator.

``dse.explore(strategy=...)`` is a thin adapter over this package.
"""
from repro.search.objectives import (DEFAULT_OBJECTIVES, default_weights,
                                     dominates, pareto_front, scalarize,
                                     trial_objectives)
from repro.search.run import SearchResult, SearchRun, SearchTrial
from repro.search.space import (CATEGORICAL, CONTINUOUS, ORDINAL, Dim,
                                SearchSpace)
from repro.search.strategies import (FIDELITY_ANALYTIC, FIDELITY_FULL,
                                     FIDELITY_SYMMETRIC, STRATEGIES,
                                     Strategy, available_strategies,
                                     get_strategy, register_strategy)

__all__ = ["SearchSpace", "Dim", "ORDINAL", "CATEGORICAL", "CONTINUOUS",
           "Strategy", "STRATEGIES", "register_strategy", "get_strategy",
           "available_strategies", "FIDELITY_ANALYTIC", "FIDELITY_SYMMETRIC",
           "FIDELITY_FULL", "SearchRun", "SearchResult", "SearchTrial",
           "DEFAULT_OBJECTIVES", "trial_objectives", "scalarize",
           "default_weights", "dominates", "pareto_front"]
