"""RG-LRU linear-recurrence Pallas TPU kernel.

h_t = a_t * h_{t-1} + b_t  (elementwise over the channel dim).

TPU adaptation: the GPU version of this scan is a warp-parallel chunked scan;
on TPU the natural form is a *sequential* grid walk over time blocks with the
carry state resident in VMEM scratch (the VPU processes the full channel
block per step, so sequential-in-time costs S/bt grid steps of vectorized
work).  Grid (batch, channel_blocks, time_blocks), time innermost; inside a
block a fori_loop advances bt steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_ref, b_ref, o_ref, h_scr, *, block_t):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0]                       # (bt, bc) f32
    b = b_ref[0]

    def step(t, h):
        h = a[t] * h + b[t]
        o_ref[0, t] = h
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_scr[0], unroll=8)
    h_scr[0] = h


def rglru_scan_tpu(a, b, *, block_t=256, block_c=512, interpret=False):
    """a, b (B, S, C) f32 -> h (B, S, C)."""
    B, S, C = a.shape
    block_t = min(block_t, S)
    block_c = min(block_c, C)
    pt, pc = (-S) % block_t, (-C) % block_c
    if pt or pc:
        a = jnp.pad(a, ((0, 0), (0, pt), (0, pc)))
        b = jnp.pad(b, ((0, 0), (0, pt), (0, pc)))
    nt, nc = (S + pt) // block_t, (C + pc) // block_c

    kernel = functools.partial(_kernel, block_t=block_t)
    out = pl.pallas_call(
        kernel,
        grid=(B, nc, nt),
        in_specs=[
            pl.BlockSpec((1, block_t, block_c), lambda bi, ci, ti: (bi, ti, ci)),
            pl.BlockSpec((1, block_t, block_c), lambda bi, ci, ti: (bi, ti, ci)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_c),
                               lambda bi, ci, ti: (bi, ti, ci)),
        out_shape=jax.ShapeDtypeStruct((B, S + pt, C + pc), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_c), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
    return out[:, :S, :C]
