"""Flash attention Pallas TPU kernel (causal / sliding-window / GQA).

TPU adaptation of the FlashAttention online-softmax algorithm:
  * grid (batch*heads, q_blocks, kv_blocks); the kv dim is innermost and TPU
    executes grid steps sequentially, so running (m, l, acc) live in VMEM
    scratch across kv steps and the output block is written once at the last
    kv step.
  * BlockSpec tiling keeps each (block_q x head_dim) q tile and
    (block_k x head_dim) k/v tile resident in VMEM; block sizes default to
    MXU-aligned 512/512 with head_dim a multiple of 128 handled by the
    caller's padding.
  * GQA: the kv-head index for a given q-head is computed inside the
    index_map (no repeated k/v materialization in HBM).

Validated against ref.py in interpret mode (CPU); targeted at TPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale, causal, window, block_q, block_k, seq_k):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0]                                   # (bq, hd)
    k = k_ref[0]                                   # (bk, hd)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
    kpos = ki * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]                            # (bq, 1)
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new)
    l_new = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    m_scr[...] = m_new
    l_scr[...] = l_new

    @pl.when(ki == pl.num_programs(2) - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] / jnp.maximum(l_scr[...], 1e-37)).astype(o_ref.dtype)


def flash_attention_tpu(q, k, v, *, scale=None, causal=True, window=0,
                        block_q=512, block_k=512, interpret=False):
    """q (BH, Sq, hd); k/v (BKV, Sk, hd) with BH = BKV * G.

    Returns (BH, Sq, hd)."""
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    assert BH % BKV == 0
    G = BH // BKV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pq, pk = (-Sq) % block_q, (-Sk) % block_k
    qp = jnp.pad(q, ((0, 0), (0, pq), (0, 0))) if pq else q
    kp = jnp.pad(k, ((0, 0), (0, pk), (0, 0))) if pk else k
    vp = jnp.pad(v, ((0, 0), (0, pk), (0, 0))) if pk else v
    nq, nk = (Sq + pq) // block_q, (Sk + pk) // block_k

    kernel = functools.partial(_kernel, scale=scale, causal=causal,
                               window=window, block_q=block_q,
                               block_k=block_k, seq_k=Sk)
    out = pl.pallas_call(
        kernel,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
            pl.BlockSpec((1, block_k, hd), lambda bh, qi, ki, G=G: (bh // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, Sq + pq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :Sq]
