"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

Chunked algorithm (arXiv:2405.21060): per chunk the output is
  y = (tril(C B^T * decay) * dt) x   [intra, quadratic in chunk -> MXU]
    + (C . S_prev) * exp(cum)        [inter, recurrent state]
and the running state S (n x p per head) advances chunk to chunk.

TPU adaptation: grid (batch*heads, chunks) with the chunk dim innermost;
S lives in VMEM scratch across chunk steps (sequential TPU grid), all three
contractions are MXU matmuls on (chunk x n/p) tiles.  One (batch, head) pair
per outer grid step keeps every operand in VMEM for typical sizes
(chunk<=256, n=128, p=64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, sfin_ref, s_scr, *,
            chunk):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        s_scr[...] = jnp.zeros_like(s_scr)

    x = x_ref[0]                                  # (Q, p) f32
    dt = dt_ref[0]                                # (Q, 1)
    A = a_ref[0, 0]                               # scalar
    Bm = b_ref[0]                                 # (Q, n)
    Cm = c_ref[0]                                 # (Q, n)

    a = dt * A                                    # (Q,1) log decay
    cum = jnp.cumsum(a, axis=0)                   # (Q,1)
    seg = cum - cum.T                             # (Q,Q) cum_i - cum_j
    causal = jax.lax.broadcasted_iota(jnp.int32, seg.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, seg.shape, 1)
    L = jnp.where(causal, jnp.exp(seg), 0.0)
    cb = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)   # (Q,Q)
    scores = cb * L * dt.T                        # * dt_j
    y = jax.lax.dot_general(scores, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)    # (Q,p)

    s_prev = s_scr[...]                           # (n,p)
    y += jax.lax.dot_general(Cm, s_prev, (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32) * jnp.exp(cum)

    decay_end = jnp.exp(cum[-1:] - cum)           # (Q,1)
    wB = Bm * (dt * decay_end)                    # (Q,n)
    s_new = jax.lax.dot_general(wB, x, (((0,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)  # (n,p)
    s_scr[...] = jnp.exp(cum[-1]) * s_prev + s_new
    y_ref[0] = y

    @pl.when(ci == pl.num_programs(1) - 1)
    def _fin():
        sfin_ref[0] = s_scr[...]


def ssd_tpu(x, dt, A, B, C, *, chunk=256, interpret=False):
    """x (b,s,h,p) f32; dt (b,s,h); A (h,); B,C (b,s,n).

    Returns (y (b,s,h,p), S_final (b,h,n,p)) — matches models.ssm.ssd_chunked.
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    chunk = min(chunk, s)
    pad = (-s) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))
    S = s + pad
    nc = S // chunk

    # flatten (b,h): x -> (b*h, S, p); dt -> (b*h, S, 1); B/C shared per b
    xf = jnp.moveaxis(x, 2, 1).reshape(b * h, S, p)
    dtf = jnp.moveaxis(dt, 2, 1).reshape(b * h, S, 1)
    af = jnp.broadcast_to(A[None, :], (b, h)).reshape(b * h, 1)
    Bf = B
    Cf = C

    kernel = functools.partial(_kernel, chunk=chunk)
    y, sfin = pl.pallas_call(
        kernel,
        grid=(b * h, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, 1), lambda bh, ci: (bh, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci, h=h: (bh // h, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda bh, ci, h=h: (bh // h, ci, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda bh, ci: (bh, ci, 0)),
            pl.BlockSpec((1, n, p), lambda bh, ci: (bh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * h, S, p), jnp.float32),
            jax.ShapeDtypeStruct((b * h, n, p), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, p), jnp.float32)],
        interpret=interpret,
    )(xf.astype(jnp.float32), dtf.astype(jnp.float32), af.astype(jnp.float32),
      Bf.astype(jnp.float32), Cf.astype(jnp.float32))

    y = jnp.moveaxis(y.reshape(b, h, S, p), 1, 2)[:, :s]
    sfin = sfin.reshape(b, h, n, p)
    return y, sfin
