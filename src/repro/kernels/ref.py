"""Pure-jnp oracles for every Pallas kernel (the ref.py contract).

These are the definitions of correctness: kernels/tests assert_allclose
against them across shape/dtype sweeps.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_oracle(q, k, v, *, scale=None, causal=True, window=0):
    """q (BH, Sq, hd); k/v (BKV, Sk, hd), BH = BKV*G.  Materialized softmax."""
    BH, Sq, hd = q.shape
    BKV, Sk, _ = k.shape
    G = BH // BKV
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    kx = jnp.repeat(k, G, axis=0)
    vx = jnp.repeat(v, G, axis=0)
    s = jnp.einsum("bqh,bsh->bqs", q, kx).astype(jnp.float32) * scale
    qpos = jnp.arange(Sq)[:, None]
    kpos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None], s, -2.0e38)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqs,bsh->bqh", w.astype(vx.dtype), vx)


def rglru_scan_oracle(a, b):
    """Sequential linear recurrence h_t = a_t h_{t-1} + b_t.  (B,S,C) f32."""
    def step(h, ab):
        at, bt = ab
        h = at * h + bt
        return h, h
    a32, b32 = a.astype(jnp.float32), b.astype(jnp.float32)
    _, h = jax.lax.scan(step, jnp.zeros_like(a32[:, 0]),
                        (jnp.moveaxis(a32, 1, 0), jnp.moveaxis(b32, 1, 0)))
    return jnp.moveaxis(h, 0, 1)


def ssd_oracle(x, dt, A, B, C):
    """Fully sequential SSD recurrence (the definition).

    x (b,s,h,p); dt (b,s,h); A (h,); B,C (b,s,n).
    Returns (y (b,s,h,p), S_final (b,h,n,p))."""
    b, s, h, p = x.shape
    n = B.shape[-1]

    def step(S_prev, inp):
        xt, dtt, Bt, Ct = inp                     # (b,h,p),(b,h),(b,n),(b,n)
        decay = jnp.exp(dtt * A[None, :])         # (b,h)
        dBx = jnp.einsum("bn,bhp->bhnp", Bt, xt) * dtt[:, :, None, None]
        S = S_prev * decay[:, :, None, None] + dBx
        y = jnp.einsum("bn,bhnp->bhp", Ct, S)
        return S, y

    S0 = jnp.zeros((b, h, n, p), jnp.float32)
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(B, 1, 0), jnp.moveaxis(C, 1, 0))
    S_fin, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 1), S_fin
