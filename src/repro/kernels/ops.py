"""jit'd wrappers around the Pallas kernels (the ops.py contract).

These adapt model-layer layouts to kernel layouts and expose the
interpret=True escape hatch used for CPU validation.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention_tpu
from repro.kernels.rglru import rglru_scan_tpu
from repro.kernels.ssd import ssd_tpu


@partial(jax.jit, static_argnames=("causal", "window", "scale", "interpret",
                                   "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, scale=None,
                    interpret=False, block_q=512, block_k=512):
    """Model layout q (B,S,KV,G,hd); k/v (B,S,KV,hd) -> (B,S,KV,G,hd)."""
    B, S, KV, G, hd = q.shape
    Sk = k.shape[1]
    qf = jnp.moveaxis(q, 1, 3).reshape(B * KV * G, S, hd)
    kf = jnp.moveaxis(k, 1, 2).reshape(B * KV, Sk, hd)
    vf = jnp.moveaxis(v, 1, 2).reshape(B * KV, Sk, hd)
    o = flash_attention_tpu(qf, kf, vf, scale=scale, causal=causal,
                            window=window, interpret=interpret,
                            block_q=block_q, block_k=block_k)
    return jnp.moveaxis(o.reshape(B, KV, G, S, hd), 3, 1)


@partial(jax.jit, static_argnames=("interpret", "block_t", "block_c"))
def rglru_scan(a, b, *, interpret=False, block_t=256, block_c=512):
    """(B,S,C) f32 recurrence coefficients -> h (B,S,C)."""
    return rglru_scan_tpu(a, b, block_t=block_t, block_c=block_c,
                          interpret=interpret)


@partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(x, dt, A, B, C, *, chunk=256, interpret=False):
    """Mamba2 SSD; returns (y, S_final)."""
    return ssd_tpu(x, dt, A, B, C, chunk=chunk, interpret=interpret)
