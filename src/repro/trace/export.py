"""Chrome-trace export: render simulated timelines as Chrome trace event
format JSON (viewable in Perfetto / chrome://tracing).

Layout: one *process* per rank, one *thread* per stream (0 = compute,
1 = comm), complete events (``ph: "X"``, microsecond ``ts``/``dur``) per
scheduled node, plus a per-rank ``exposed_comm`` counter track that is
nonzero exactly while the comm stream is busy and the compute stream is
idle — the visual form of ``SimResult.exposed_comm``.

Event ``args`` carry the node id and its chakra fingerprint so
``repro.trace.align`` can re-identify nodes exactly on round-trip; external
consumers can ignore them.  ``simulate(..., keep_timeline=True)`` /
``simulate_cluster(..., keep_timeline=True)`` produce the required spans.
"""
from __future__ import annotations

import json
from typing import Dict, List, Optional, Tuple

from repro.core import chakra
from repro.core.costmodel.simulator import (ClusterSimResult, SimResult,
                                            Span)

TRACE_SCHEMA = "flint-trace-v1"
_TID = {"comp": 0, "comm": 1}
_THREAD_NAMES = {0: "compute", 1: "comm"}


def graph_for_rank(graph, rank: int) -> Optional[chakra.Graph]:
    """Resolve the workload graph of one rank: a plain ``chakra.Graph``
    (SPMD — every rank shares it), an ``MPMDProgram`` (anything with a
    ``graph_for`` method) or a ``{rank: Graph}`` dict (per-rank distinct
    graphs).  Shared by the exporter and the validator."""
    if graph is None or isinstance(graph, chakra.Graph):
        return graph
    gf = getattr(graph, "graph_for", None)
    if gf is not None:
        return gf(rank)
    return graph.get(rank)


def _per_rank_spans(result) -> List[Tuple[int, List[Span]]]:
    """[(rank, spans)] for either result flavor; classes are expanded so
    every rank gets its own process in the trace."""
    if isinstance(result, ClusterSimResult):
        return [(r, result.rank_spans(r)) for r in range(result.n_ranks)]
    if isinstance(result, SimResult):
        return [(0, result.spans())]
    raise TypeError(f"expected SimResult or ClusterSimResult, "
                    f"got {type(result).__name__}")


def _subtract(lo: float, hi: float,
              intervals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    """[lo, hi) minus a sorted, disjoint interval list."""
    out = []
    cur = lo
    for a, b in intervals:
        if b <= cur:
            continue
        if a >= hi:
            break
        if a > cur:
            out.append((cur, min(a, hi)))
        cur = max(cur, b)
        if cur >= hi:
            break
    if cur < hi:
        out.append((cur, hi))
    return out


def _merged(intervals: List[Tuple[float, float]]) -> List[Tuple[float, float]]:
    out: List[Tuple[float, float]] = []
    for a, b in sorted(intervals):
        if out and a <= out[-1][1]:
            out[-1] = (out[-1][0], max(out[-1][1], b))
        else:
            out.append((a, b))
    return out


def _exposed_counters(rank: int, spans: List[Span],
                      graph: Optional[chakra.Graph],
                      scale: float) -> List[Dict]:
    """Counter events: comm-stream busy intervals not covered by compute."""
    comp = _merged([(s.start, s.end) for s in spans
                    if s.stream == "comp" and s.end > s.start])
    events: List[Dict] = []
    for s in sorted((s for s in spans if s.stream == "comm"),
                    key=lambda s: s.start):
        if s.end <= s.start:
            continue
        val = 1.0
        if graph is not None:
            val = graph.node(s.nid).attrs.get("comm_bytes", 0.0) or 1.0
        for a, b in _subtract(s.start, s.end, comp):
            events.append({"ph": "C", "pid": rank, "name": "exposed_comm",
                           "ts": a * scale, "args": {"bytes": val}})
            events.append({"ph": "C", "pid": rank, "name": "exposed_comm",
                           "ts": b * scale, "args": {"bytes": 0.0}})
    return events


def _p2p_flow_events(channels: Dict[Tuple[int, ...], Dict[str, List]],
                     scale: float) -> List[Dict]:
    """Flow ("s"/"f") events binding each matched p2p send slice to its
    recv slice.  Channels key on the pair's rank group plus the nodes'
    ``p2p_channel`` id (microbatched pipelines run several logical
    channels — forward activations, gradients, virtual-stage chunks —
    over one rank pair); within a channel the k-th send pairs with the
    k-th recv in commit order — the FIFO discipline
    ``convert.split_pipeline_stages`` enforces with ctrl-edge chains and
    the MPMD engine's (group, channel, occurrence) barrier keying."""
    events: List[Dict] = []
    fid = 0
    for key in sorted(channels, key=repr):
        ch = channels[key]
        for send, recv in zip(ch.get("send", []), ch.get("recv", [])):
            srank, ss = send
            rrank, rs = recv
            fid += 1
            events.append({"ph": "s", "pid": srank, "tid": _TID[ss.stream],
                           "ts": ss.start * scale, "id": fid,
                           "name": "p2p", "cat": "p2p"})
            events.append({"ph": "f", "bp": "e", "pid": rrank,
                           "tid": _TID[rs.stream], "ts": rs.start * scale,
                           "id": fid, "name": "p2p", "cat": "p2p"})
    return events


def to_chrome_trace(result, graph: Optional[chakra.Graph] = None,
                    meta: Optional[Dict] = None) -> Dict:
    """Render a timeline-carrying sim result as a Chrome-trace dict.

    `graph` (the simulated workload graph) enriches event args with node
    fingerprints, op classes and payload bytes — pass it whenever you have
    it; round-trip validation relies on the fingerprints.  For MPMD runs
    pass the ``MPMDProgram`` (or a ``{rank: Graph}`` dict) and each rank's
    process is annotated from its *own* graph.  Matched p2p send/recv
    pairs (``comm_kind="p2p"``, from ``split_pipeline_stages``) get Chrome
    flow events so Perfetto draws the cross-rank arrow.

    Event ordering is deterministic: all process/thread metadata first
    (sorted by pid, with ``process_sort_index`` pinning rank order in the
    viewer), then per-rank slices, counters and flows."""
    scale = 1e6                        # seconds -> Chrome microseconds
    meta_events: List[Dict] = []
    events: List[Dict] = []
    # (src_rank, dst_rank) channel -> {"send": [(rank, span)], "recv": ...}
    channels: Dict[Tuple[int, ...], Dict[str, List]] = {}
    for rank, spans in _per_rank_spans(result):
        g_r = graph_for_rank(graph, rank)
        meta_events.append({"ph": "M", "pid": rank, "name": "process_name",
                            "args": {"name": f"rank {rank}"}})
        meta_events.append({"ph": "M", "pid": rank,
                            "name": "process_sort_index",
                            "args": {"sort_index": rank}})
        for tid, tname in _THREAD_NAMES.items():
            meta_events.append({"ph": "M", "pid": rank, "tid": tid,
                                "name": "thread_name",
                                "args": {"name": tname}})
        for s in sorted(spans, key=lambda s: (s.start, _TID[s.stream])):
            args: Dict = {"nid": s.nid}
            cat = s.stream
            if g_r is not None:
                n = g_r.node(s.nid)
                args["fingerprint"] = n.fingerprint()
                cat = n.type
                cb = n.attrs.get("comm_bytes", 0.0)
                if cb:
                    args["comm_bytes"] = cb
                if n.attrs.get("comm_kind") == "p2p":
                    pg = tuple(n.attrs.get("group") or ())
                    # graph-sharing replicas (schedule.lower_microbatched):
                    # the group attr is replica 0's literal pair — resolve
                    # this rank's pair from the relative stage addressing
                    rel_R = int(g_r.meta.get("p2p_replicas") or 0)
                    if rel_R > 1 and "p2p_src_stage" in n.attrs:
                        d = rank % rel_R
                        pg = (int(n.attrs["p2p_src_stage"]) * rel_R + d,
                              int(n.attrs["p2p_dst_stage"]) * rel_R + d)
                    if len(pg) == 2 and rank in pg:
                        side = "send" if rank == pg[0] else "recv"
                        ch = n.attrs.get("p2p_channel")
                        key = pg + (tuple(ch) if isinstance(
                            ch, (list, tuple)) else (ch,))
                        channels.setdefault(key, {}) \
                            .setdefault(side, []).append((rank, s))
            events.append({"ph": "X", "pid": rank, "tid": _TID[s.stream],
                           "ts": s.start * scale,
                           "dur": (s.end - s.start) * scale,
                           "name": s.name, "cat": cat, "args": args})
        events.extend(_exposed_counters(rank, spans, g_r, scale))
    events.extend(_p2p_flow_events(channels, scale))
    meta_events.sort(key=lambda e: (e["pid"], e.get("tid", -1), e["name"]))
    events = meta_events + events
    md = {"schema": TRACE_SCHEMA, "time_unit": "us"}
    if isinstance(graph, chakra.Graph):
        md["n_nodes"] = len(graph)
        md.update(graph.meta)
    elif graph is not None:            # MPMD program / per-rank dict
        md["mpmd"] = True
        md.update(getattr(graph, "meta", None) or {})
    if meta:
        md.update(meta)
    return {"traceEvents": events, "displayTimeUnit": "ms", "metadata": md}


def export_chrome_trace(result, path: str,
                        graph: Optional[chakra.Graph] = None,
                        meta: Optional[Dict] = None) -> Dict:
    """Write the Chrome-trace JSON for `result` to `path`; returns the
    trace dict.  Open the file in https://ui.perfetto.dev or
    chrome://tracing to inspect the timeline."""
    trace = to_chrome_trace(result, graph, meta)
    with open(path, "w") as f:
        json.dump(trace, f)
        f.write("\n")
    return trace


def obs_chrome_trace(recorder, meta: Optional[Dict] = None) -> Dict:
    """Render a ``repro.obs`` recorder's self-spans as a Chrome-trace dict:
    one process per OS pid (the parent plus any pool workers), spans as
    complete events on a single thread, counters/gauges in the metadata.
    Same event layout conventions as ``to_chrome_trace`` (metadata first,
    sorted, with ``process_sort_index``)."""
    scale = 1e6
    spans = list(recorder.spans)
    pids = sorted({p for _, _, _, p in spans})
    t0 = min((start for _, start, _, _ in spans), default=recorder.t0)
    meta_events: List[Dict] = []
    for i, p in enumerate(pids):
        label = "main" if i == 0 else f"worker {p}"
        meta_events.append({"ph": "M", "pid": p, "name": "process_name",
                            "args": {"name": f"{label} (pid {p})"}})
        meta_events.append({"ph": "M", "pid": p, "name": "process_sort_index",
                            "args": {"sort_index": i}})
    meta_events.sort(key=lambda e: (e["pid"], e["name"]))
    events = meta_events + [
        {"ph": "X", "pid": p, "tid": 0, "ts": (start - t0) * scale,
         "dur": (end - start) * scale, "name": name, "cat": "obs"}
        for name, start, end, p in sorted(spans, key=lambda s: s[1])]
    md = {"schema": TRACE_SCHEMA, "time_unit": "us", "obs": True,
          "counters": dict(sorted(recorder.counters.items())),
          "gauges": dict(sorted(recorder.gauges.items())),
          "dropped_spans": recorder.dropped_spans}
    if meta:
        md.update(meta)
    return {"traceEvents": events, "displayTimeUnit": "ms", "metadata": md}
