"""``python -m repro.trace`` — export / validate / calibrate from the shell.

    # simulate a captured graph and emit a Chrome trace (view in Perfetto)
    python -m repro.trace export graph.json -o trace.json --ranks 8

    # score the graph's predictions against a measured trace
    python -m repro.trace validate graph.json trace.json --ranks 8

    # fit hardware parameters from the trace, write them back out
    python -m repro.trace calibrate graph.json trace.json -o calibrated.json

Hardware flags (--chips/--topology/--peak-flops/--hbm-bw/--link-bw/
--link-latency/--derate/--algo) override the TPU-v5e SystemConfig defaults;
``--system calibrated.json`` loads a previous calibrate run instead.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import sys

from repro.core import chakra
from repro.core.costmodel.simulator import simulate, simulate_cluster
from repro.core.costmodel.topology import build_topology
from repro.trace.calibrate import calibrate, system_from_flags
from repro.trace.export import export_chrome_trace
from repro.trace.ingest import ingest_chrome_trace
from repro.trace.validate import validate


def _add_system_flags(ap: argparse.ArgumentParser) -> None:
    ap.add_argument("--system", default=None, metavar="JSON",
                    help="load SystemConfig+derate from a calibrate -o file")
    ap.add_argument("--chips", type=int, default=None)
    ap.add_argument("--topology", default=None,
                    help="switch | ring | torus2d | torus3d | wafer2d")
    ap.add_argument("--peak-flops", type=float, default=None)
    ap.add_argument("--hbm-bw", type=float, default=None)
    ap.add_argument("--link-bw", type=float, default=None)
    ap.add_argument("--link-latency", type=float, default=None)
    ap.add_argument("--derate", type=float, default=None,
                    help="compute derate / flops efficiency (default 0.6)")
    ap.add_argument("--algo", default="auto",
                    help="collective algorithm (auto | ring | hd | 2d_synth)")
    ap.add_argument("--no-overlap", action="store_true",
                    help="serialize comm onto the compute stream")
    ap.add_argument("--ranks", type=int, default=0,
                    help="simulate a K-rank cluster (0 = single timeline)")


def _system_from_args(args):
    return system_from_flags(args)


def _cmd_export(args) -> int:
    g = chakra.Graph.load(args.graph)
    sysc, derate = _system_from_args(args)
    # size the fabric to the simulated cluster (benchmarks' convention);
    # without --ranks the system's chip count stands
    topo = build_topology(sysc, args.ranks if args.ranks > 1 else None)
    overlap = not args.no_overlap
    if args.ranks and args.ranks > 1:
        res = simulate_cluster(g, sysc, topo, n_ranks=args.ranks,
                               algo=args.algo, overlap=overlap,
                               compute_derate=derate, keep_timeline=True)
        total, n_proc = res.step_time, res.n_ranks
    else:
        res = simulate(g, sysc, topo, algo=args.algo, overlap=overlap,
                       compute_derate=derate, keep_timeline=True)
        total, n_proc = res.total_time, 1
    export_chrome_trace(res, args.out, graph=g)
    print(f"wrote {args.out}: {n_proc} rank(s), {len(g)} nodes/rank, "
          f"step {total * 1e3:.3f} ms — open in https://ui.perfetto.dev "
          "or chrome://tracing")
    return 0


def _cmd_validate(args) -> int:
    g = chakra.Graph.load(args.graph)
    tl = ingest_chrome_trace(args.trace)
    sysc, derate = _system_from_args(args)
    K = args.ranks or len(tl.ranks())
    rep = validate(g, tl, sysc, build_topology(sysc, K if K > 1 else None),
                   n_ranks=args.ranks or None, algo=args.algo,
                   overlap=not args.no_overlap, compute_derate=derate)
    print(rep.summary())
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rep.as_dict(), f, indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.json}")
    if args.max_error is not None and rep.e2e_error > args.max_error:
        print(f"FAIL: e2e error {rep.e2e_error * 100:.2f}% exceeds "
              f"--max-error {args.max_error * 100:.2f}%")
        return 1
    return 0


def _cmd_calibrate(args) -> int:
    g = chakra.Graph.load(args.graph)
    tl = ingest_chrome_trace(args.trace)
    sysc, derate = _system_from_args(args)
    K = args.ranks or len(tl.ranks())
    cal = calibrate(g, tl, sysc,
                    build_topology(sysc, K if K > 1 else None),
                    algo=args.algo, compute_derate=derate)
    print(cal.summary())
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"system": dataclasses.asdict(cal.system),
                       "compute_derate": cal.compute_derate,
                       "params": cal.params,
                       "rms_rel_error": cal.fitted_error}, f,
                      indent=2, sort_keys=True)
            f.write("\n")
        print(f"wrote {args.out} (reuse via --system {args.out}, here or "
              f"in `python -m repro.search` to explore on the calibrated "
              "cost model)")
    if args.validate:
        before = validate(g, tl, sysc,
                          build_topology(sysc, K if K > 1 else None),
                          n_ranks=args.ranks or None,
                          algo=args.algo, overlap=not args.no_overlap,
                          compute_derate=derate)
        after = validate(g, tl, cal.system, cal.topology,
                         n_ranks=args.ranks or None, algo=args.algo,
                         overlap=not args.no_overlap,
                         compute_derate=cal.compute_derate)
        print(f"validation e2e error: {before.e2e_error * 100:.2f}% -> "
              f"{after.e2e_error * 100:.2f}% after calibration")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.trace",
        description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    sub = ap.add_subparsers(dest="cmd", required=True)

    ex = sub.add_parser("export", help="simulate a graph, emit Chrome trace")
    ex.add_argument("graph", help="chakra graph JSON (Graph.save output)")
    ex.add_argument("-o", "--out", required=True, help="trace JSON path")
    _add_system_flags(ex)
    ex.set_defaults(fn=_cmd_export)

    va = sub.add_parser("validate", help="score graph vs measured trace")
    va.add_argument("graph")
    va.add_argument("trace", help="Chrome-trace JSON to validate against")
    va.add_argument("--json", default=None, help="write full report JSON")
    va.add_argument("--max-error", type=float, default=None,
                    help="exit 1 if worst-rank e2e error exceeds this "
                         "fraction (CI gate)")
    _add_system_flags(va)
    va.set_defaults(fn=_cmd_validate)

    ca = sub.add_parser("calibrate", help="fit hardware params from trace")
    ca.add_argument("graph")
    ca.add_argument("trace")
    ca.add_argument("-o", "--out", default=None,
                    help="write calibrated system JSON")
    ca.add_argument("--validate", action="store_true",
                    help="print validation error before/after the fit")
    _add_system_flags(ca)
    ca.set_defaults(fn=_cmd_calibrate)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
