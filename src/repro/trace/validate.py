"""Graph <-> trace validation: quantify how well the simulated workload
graph predicts a measured (or re-ingested) timeline.

``validate()`` simulates the graph under the given hardware model, aligns
the measured timeline to the graph (``repro.trace.align``), and produces a
``ValidationReport``:

  * per-op-class duration error (COMP / COMM_COLL / ... mean + max relative)
  * end-to-end step-time error, per rank and worst-rank overall
  * critical-path overlap: how much of the *measured* critical path the
    simulated critical path also covers (duration-weighted Jaccard-style)
  * a worst-offenders table — the nodes contributing the largest absolute
    prediction error, the starting point of any calibration session.

The exact-round-trip property (export a simulated trace, re-ingest,
validate => ~0 error, 100% match) is enforced by tests/test_trace.py and
gated by benchmarks/trace_roundtrip.py.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.core import chakra
from repro.core.costmodel.simulator import simulate, simulate_cluster
from repro.core.costmodel.topology import Topology, build_topology
from repro.trace.align import align_rank
from repro.trace.ingest import Timeline

_EPS = 1e-12


def _rel_err(sim: float, meas: float) -> float:
    d = abs(sim - meas)
    if d <= _EPS:
        return 0.0
    return d / max(meas, _EPS)


def _critical_path(g: chakra.Graph, dur: Dict[int, float]) -> List[int]:
    """Longest-duration dependency chain under the `dur` assignment."""
    best: Dict[int, float] = {}
    pred: Dict[int, Optional[int]] = {}
    for nid in g.topo_order():
        n = g.node(nid)
        t0, p = 0.0, None
        for d in set(n.all_deps):
            if best[d] > t0:
                t0, p = best[d], d
        best[nid] = t0 + dur.get(nid, 0.0)
        pred[nid] = p
    if not best:
        return []
    end: Optional[int] = max(best, key=lambda i: best[i])
    path: List[int] = []
    while end is not None:
        path.append(end)
        end = pred[end]
    return path


@dataclasses.dataclass
class ValidationReport:
    n_ranks: int
    n_nodes: int                       # critical-path rank's graph size
    n_node_spans: int                  # sum of graph sizes over traced ranks
    n_matched: int
    match_fraction: float
    sim_total_s: float
    trace_total_s: float
    e2e_error: float                   # worst rank's relative step error
    per_class: Dict[str, Dict]         # op class -> count/sim_s/trace_s/errs
    critical_path_overlap: float       # duration-weighted, in [0, 1]
    worst: List[Dict]                  # top offenders by absolute error
    per_rank: List[Dict]

    def as_dict(self) -> Dict:
        return dataclasses.asdict(self)

    def summary(self) -> str:
        lines = [
            f"trace validation: {self.n_ranks} rank(s), "
            f"{self.n_matched}/{self.n_node_spans} node spans "
            f"matched ({self.match_fraction * 100:.1f}%)",
            f"end-to-end: sim {self.sim_total_s * 1e3:.3f} ms vs trace "
            f"{self.trace_total_s * 1e3:.3f} ms "
            f"({self.e2e_error * 100:.2f}% worst-rank error); "
            f"critical-path overlap {self.critical_path_overlap * 100:.1f}%",
            "per-op-class:",
        ]
        for cls, row in sorted(self.per_class.items()):
            lines.append(
                f"  {cls:<10} {row['count']:>5} spans  "
                f"sim {row['sim_s'] * 1e3:9.3f} ms  "
                f"trace {row['trace_s'] * 1e3:9.3f} ms  "
                f"mean|err| {row['mean_rel_err'] * 100:6.2f}%  "
                f"max {row['max_rel_err'] * 100:6.2f}%")
        if self.worst:
            lines.append("worst offenders:")
            for w in self.worst:
                sign = "+" if w["sim_s"] >= w["trace_s"] else "-"
                lines.append(
                    f"  rank {w['rank']} {w['name']} ({w['type']}): "
                    f"sim {w['sim_s'] * 1e6:.1f} us vs trace "
                    f"{w['trace_s'] * 1e6:.1f} us "
                    f"({sign}{w['rel_err'] * 100:.1f}%)")
        return "\n".join(lines)


def validate(g, tl: Timeline, system,
             topo: Optional[Topology] = None, *,
             n_ranks: Optional[int] = None, rank_profiles=None,
             algo: str = "auto", overlap: bool = True,
             compute_derate: float = 0.6, top_k: int = 8) -> ValidationReport:
    """Validate workload `g` against measured timeline `tl` under a hardware
    model (system/topo/derate — pass a calibrated set to measure the fit).

    `g` is a ``chakra.Graph`` (rank-symmetric SPMD view) or a per-rank
    workload — ``MPMDProgram`` / ``{rank: Graph}`` dict — in which case
    every traced pid is aligned and scored against *that* rank's own graph.
    Multi-rank traces are simulated with ``simulate_cluster`` (pids map to
    ranks in sorted order); single-process SPMD traces with ``simulate``."""
    from repro.trace.export import graph_for_rank

    topo = topo or build_topology(system)
    pids = tl.ranks()
    is_program = not isinstance(g, chakra.Graph)
    if is_program:
        K = int(getattr(g, "n_ranks", None) or len(g))
    else:
        K = int(n_ranks if n_ranks is not None else max(len(pids), 1))
    if K > 1 or is_program:
        cr = simulate_cluster(g, system, topo, n_ranks=K,
                              rank_profiles=rank_profiles, algo=algo,
                              overlap=overlap, compute_derate=compute_derate,
                              keep_timeline=True)
        sim_total = cr.step_time
        # a pid that is itself a valid rank id addresses that simulated
        # rank (partial traces keep their identity); foreign pids (host
        # process ids) map positionally
        sim_ranks = [pid if 0 <= pid < K else i
                     for i, pid in enumerate(pids[:K])]
        rank_view = [(sr, pid, cr.rank_spans(sr),
                      cr.rank_result(sr).total_time)
                     for sr, pid in zip(sim_ranks, pids)]
        cp_rank = sim_ranks[0] if sim_ranks \
            and cr.slowest_rank not in sim_ranks else cr.slowest_rank
    else:
        res = simulate(g, system, topo, algo=algo, overlap=overlap,
                       compute_derate=compute_derate, keep_timeline=True)
        sim_total = res.total_time
        rank_view = [(0, pids[0] if pids else 0, res.spans(),
                      res.total_time)]
        cp_rank = 0

    per_class: Dict[str, Dict] = {}
    worst: List[Dict] = []
    per_rank: List[Dict] = []
    n_matched = 0
    n_nodes_total = 0
    e2e_error = 0.0
    cp_meas: Dict[int, float] = {}
    cp_sim: Dict[int, float] = {}
    cp_g = graph_for_rank(g, cp_rank)

    for sr, pid, spans, sim_rank_total in rank_view:
        g_r = graph_for_rank(g, sr)
        sim_dur = {sp.nid: sp.duration for sp in spans}
        al = align_rank(g_r, tl, pid)
        meas = al.measured()
        n_matched += al.n_matched
        n_nodes_total += len(g_r)
        for nid, m in meas.items():
            n = g_r.node(nid)
            s = sim_dur.get(nid, 0.0)
            row = per_class.setdefault(
                n.type, {"count": 0, "sim_s": 0.0, "trace_s": 0.0,
                         "mean_rel_err": 0.0, "max_rel_err": 0.0})
            err = _rel_err(s, m)
            row["count"] += 1
            row["sim_s"] += s
            row["trace_s"] += m
            row["mean_rel_err"] += err          # sum; normalized below
            row["max_rel_err"] = max(row["max_rel_err"], err)
            if abs(s - m) > _EPS:
                worst.append({"rank": pid, "nid": nid, "name": n.name,
                              "type": n.type, "sim_s": s, "trace_s": m,
                              "abs_err": abs(s - m), "rel_err": err})
        trace_total = tl.total_time(pid)
        rank_err = _rel_err(sim_rank_total, trace_total)
        e2e_error = max(e2e_error, rank_err)
        per_rank.append({"rank": pid, "sim_s": sim_rank_total,
                         "trace_s": trace_total, "e2e_error": rank_err,
                         "match_fraction": al.match_fraction})
        if sr == cp_rank:
            cp_sim = sim_dur
            # measured durations, sim fallback for unmatched nodes
            cp_meas = dict(sim_dur)
            cp_meas.update(meas)

    for row in per_class.values():
        row["mean_rel_err"] /= max(row["count"], 1)
    worst.sort(key=lambda w: -w["abs_err"])

    sim_path = set(_critical_path(cp_g, cp_sim))
    meas_path = _critical_path(cp_g, cp_meas)
    meas_total_cp = sum(cp_meas.get(n, 0.0) for n in meas_path)
    shared = sum(cp_meas.get(n, 0.0) for n in meas_path if n in sim_path)
    cp_overlap = shared / meas_total_cp if meas_total_cp > 0 else 1.0

    n_traced = max(len(rank_view), 1)
    return ValidationReport(
        n_ranks=n_traced, n_nodes=len(cp_g),
        n_node_spans=(n_nodes_total or len(cp_g)), n_matched=n_matched,
        match_fraction=n_matched / max(n_nodes_total, 1),
        sim_total_s=sim_total, trace_total_s=tl.total_time(),
        e2e_error=e2e_error, per_class=per_class,
        critical_path_overlap=cp_overlap, worst=worst[:top_k],
        per_rank=per_rank)
