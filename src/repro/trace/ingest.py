"""Measured-trace ingestion: parse Chrome trace event JSON — ours or an
external profiler's — into a normalized per-rank timeline.

Handles both container forms (``{"traceEvents": [...]}`` and a bare event
list), complete events (``ph: "X"``) and begin/end pairs (``B``/``E``),
process/thread ``M`` metadata, and ``C`` counter samples.  Timestamps are
Chrome-convention microseconds unless ``time_unit`` says otherwise, and the
whole timeline is shifted so the earliest event starts at t=0 (real traces
carry epoch offsets).

Stream classification (compute vs comm) prefers the thread_name metadata,
falls back to the event category, then to the tid convention of our own
exporter (0 = compute, 1 = comm).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass
class TraceEvent:
    """One normalized timeline event (times in seconds, start-shifted)."""
    name: str
    rank: int
    tid: int
    stream: str                   # "comp" | "comm"
    start: float
    dur: float
    cat: str = ""
    args: Dict = dataclasses.field(default_factory=dict)

    @property
    def end(self) -> float:
        return self.start + self.dur


@dataclasses.dataclass
class Timeline:
    """Normalized measured trace: events plus raw counter samples."""
    events: List[TraceEvent]
    counters: List[Dict] = dataclasses.field(default_factory=list)
    meta: Dict = dataclasses.field(default_factory=dict)

    def ranks(self) -> List[int]:
        return sorted({e.rank for e in self.events})

    def rank_events(self, rank: int) -> List[TraceEvent]:
        return sorted((e for e in self.events if e.rank == rank),
                      key=lambda e: (e.start, e.tid, e.name))

    def span(self, rank: Optional[int] = None) -> Tuple[float, float]:
        evs = self.events if rank is None else \
            [e for e in self.events if e.rank == rank]
        if not evs:
            return (0.0, 0.0)
        return (min(e.start for e in evs), max(e.end for e in evs))

    def total_time(self, rank: Optional[int] = None) -> float:
        t0, t1 = self.span(rank)
        return t1 - t0


def _classify_stream(tname: str, cat: str, tid: int) -> str:
    if tname:
        return "comm" if "comm" in tname.lower() else "comp"
    if cat and "COMM" in cat.upper():
        return "comm"
    return "comm" if tid == 1 else "comp"


def ingest_chrome_trace(src, time_unit: float = 1e-6,
                        normalize: bool = True) -> Timeline:
    """Parse Chrome-trace JSON into a ``Timeline``.

    `src` is a file path, an already-parsed trace dict, or a bare event
    list; `time_unit` is seconds per timestamp unit (Chrome default: 1e-6).
    """
    if isinstance(src, str):
        with open(src) as f:
            obj = json.load(f)
    else:
        obj = src
    if isinstance(obj, dict):
        raw = obj.get("traceEvents", [])
        meta = dict(obj.get("metadata", {}))
    else:
        raw, meta = obj, {}

    thread_names: Dict[Tuple[int, int], str] = {}
    open_begins: Dict[Tuple[int, int, str], List[float]] = {}
    rows: List[Tuple] = []            # (name, pid, tid, ts, dur, cat, args)
    counters: List[Dict] = []
    for e in raw:
        ph = e.get("ph", "X")
        pid = int(e.get("pid", 0))
        tid = int(e.get("tid", 0))
        if ph == "M":
            if e.get("name") == "thread_name":
                thread_names[(pid, tid)] = e.get("args", {}).get("name", "")
            continue
        if ph == "C":
            counters.append(dict(e))
            continue
        name = e.get("name", "")
        ts = float(e.get("ts", 0.0))
        if ph == "X":
            rows.append((name, pid, tid, ts, float(e.get("dur", 0.0)),
                         e.get("cat", ""), e.get("args", {}) or {}))
        elif ph == "B":
            open_begins.setdefault((pid, tid, name), []).append(ts)
        elif ph == "E":
            stack = open_begins.get((pid, tid, name))
            if stack:
                t0 = stack.pop()
                rows.append((name, pid, tid, t0, ts - t0,
                             e.get("cat", ""), e.get("args", {}) or {}))
        # other phases (flow, instant, ...) carry no durations — skip

    t0 = min((ts for _, _, _, ts, _, _, _ in rows), default=0.0) \
        if normalize else 0.0
    events = [TraceEvent(name=name, rank=pid, tid=tid,
                         stream=_classify_stream(
                             thread_names.get((pid, tid), ""), cat, tid),
                         start=(ts - t0) * time_unit,
                         dur=dur * time_unit, cat=cat, args=args)
              for name, pid, tid, ts, dur, cat, args in rows]
    events.sort(key=lambda e: (e.rank, e.start, e.tid, e.name))
    return Timeline(events=events, counters=counters, meta=meta)
