"""Graph <-> trace alignment: match measured timeline events to workload
graph nodes.

Three passes per rank, strictest first:

  1. exact node-id hints — our own exporter stamps ``args.nid``; accepted
     only when the named node agrees (a foreign trace can't fool it);
  2. fingerprint + program order — events and nodes that share a chakra
     fingerprint (``name|type``) are zipped k-th-to-k-th, events in start
     order, nodes in construction (= program) order;
  3. bare name + program order — same, for traces without op-class info.

Everything left over is reported unmatched; ``match_fraction`` is the
denominator of every downstream validation/calibration claim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

from repro.core import chakra
from repro.trace.ingest import Timeline, TraceEvent


@dataclasses.dataclass
class Alignment:
    """Node->event matching for one rank of a measured trace."""
    rank: int
    pairs: List[Tuple[int, TraceEvent]]
    unmatched_nodes: List[int]
    unmatched_events: List[TraceEvent]

    @property
    def n_matched(self) -> int:
        return len(self.pairs)

    @property
    def match_fraction(self) -> float:
        total = self.n_matched + len(self.unmatched_nodes)
        return self.n_matched / total if total else 1.0

    def measured(self) -> Dict[int, float]:
        """nid -> measured duration (seconds)."""
        return {nid: ev.dur for nid, ev in self.pairs}


def _event_fingerprint(ev: TraceEvent) -> Optional[str]:
    return ev.args.get("fingerprint")


def align_rank(g: chakra.Graph, tl: Timeline, rank: int) -> Alignment:
    events = tl.rank_events(rank)
    nodes = g.nodes
    taken_node = [False] * len(nodes)
    taken_ev = [False] * len(events)
    pairs: List[Tuple[int, TraceEvent]] = []

    # pass 1: exporter-stamped node ids, verified by name
    for i, ev in enumerate(events):
        nid = ev.args.get("nid")
        if isinstance(nid, int) and 0 <= nid < len(nodes) \
                and not taken_node[nid] and nodes[nid].name == ev.name:
            pairs.append((nid, ev))
            taken_node[nid] = True
            taken_ev[i] = True

    # passes 2 + 3: fingerprint then bare name, k-th occurrence to k-th
    for keyer_n, keyer_e in (
            (lambda n: n.fingerprint(), _event_fingerprint),
            (lambda n: n.name, lambda ev: ev.name)):
        by_key: Dict[str, List[int]] = {}
        for n in nodes:                    # construction order == program order
            if not taken_node[n.id]:
                by_key.setdefault(keyer_n(n), []).append(n.id)
        for i, ev in enumerate(events):    # rank_events is start-sorted
            if taken_ev[i]:
                continue
            key = keyer_e(ev)
            cands = by_key.get(key)
            if cands:
                nid = cands.pop(0)
                pairs.append((nid, ev))
                taken_node[nid] = True
                taken_ev[i] = True

    pairs.sort(key=lambda p: p[0])
    return Alignment(
        rank=rank, pairs=pairs,
        unmatched_nodes=[n.id for n in nodes if not taken_node[n.id]],
        unmatched_events=[ev for i, ev in enumerate(events)
                          if not taken_ev[i]])


def align(g: chakra.Graph, tl: Timeline) -> Dict[int, Alignment]:
    """Per-rank alignments for every rank present in the timeline."""
    return {r: align_rank(g, tl, r) for r in tl.ranks()}
