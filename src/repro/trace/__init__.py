# Trace subsystem: Chrome-trace export, measured-trace ingestion,
# graph<->trace validation and cost-model calibration — the paper's
# "validate the workload graph against post-execution traces" loop.
from repro.trace.align import Alignment, align, align_rank
from repro.trace.calibrate import (PARAM_NAMES, CalibrationResult,
                                   calibrate)
from repro.trace.export import (TRACE_SCHEMA, export_chrome_trace,
                                to_chrome_trace)
from repro.trace.ingest import Timeline, TraceEvent, ingest_chrome_trace
from repro.trace.validate import ValidationReport, validate

__all__ = ["Alignment", "align", "align_rank", "PARAM_NAMES",
           "CalibrationResult", "calibrate", "TRACE_SCHEMA",
           "export_chrome_trace", "to_chrome_trace", "Timeline",
           "TraceEvent", "ingest_chrome_trace", "ValidationReport",
           "validate"]
