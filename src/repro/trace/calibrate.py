"""Cost-model calibration: fit hardware parameters from an aligned trace.

Turns validation into calibration (the cross-architecture StableHLO
performance-modeling recipe): given a workload graph and a measured
timeline, fit the parameters the analytical node-duration model depends on

  compute_derate   achieved / peak flops efficiency
  hbm_bw           effective HBM bandwidth (bytes/s)
  link_bw_scale    multiplier on every interconnect link's bandwidth
  coll_latency     per-hop collective base latency (alpha, seconds)

by coordinate-descent least squares on per-node relative duration error:
each round scans one parameter over a log-spaced grid (holding the others
fixed), keeps the argmin, and halves the grid span — 4 rounds resolve a
parameter to ~2%, inside the 5% recovery bound the benchmarks gate.

Per-node measured durations are taken as the *minimum* across ranks: in a
barriered trace the slowest-arriving rank's span is pure collective cost,
while faster ranks' spans include attributable wait — the min strips the
skew without needing the simulator in the loop.

The result plugs straight back into the stack: ``CalibrationResult.system``
/ ``.topology`` / ``.compute_derate`` feed ``simulate``,
``simulate_cluster``, ``repro.trace.validate`` and ``dse.explore`` (which
accepts ``compute_derate=...`` and ``topo=...``).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Sequence

from repro.core import chakra
from repro.core.costmodel.simulator import node_duration
from repro.core.costmodel.topology import (MultiPod, Topology,
                                           build_topology)
from repro.trace.align import align
from repro.trace.ingest import Timeline

PARAM_NAMES = ("compute_derate", "hbm_bw", "link_bw_scale", "coll_latency")
_COMP_PARAMS = {"compute_derate", "hbm_bw"}
_COMM_PARAMS = {"link_bw_scale", "coll_latency"}
_COMM_TYPES = (chakra.COMM_COLL, chakra.COMM_SEND, chakra.COMM_RECV)


def _scaled_topo(topo: Topology, link_scale: float,
                 latency: float) -> Topology:
    """Copy of `topo` with link bandwidth scaled and base latency replaced
    (recursing into a MultiPod's inner fabric)."""
    t2 = dataclasses.replace(topo, link_bw=topo.link_bw * link_scale,
                             link_latency=latency)
    if isinstance(t2, MultiPod) and t2.inner is not None:
        t2.inner = _scaled_topo(t2.inner, link_scale, latency)
    return t2


@dataclasses.dataclass
class CalibrationResult:
    """Fitted hardware model + fit quality.

    ``system``/``topology``/``compute_derate`` are ready-to-use calibrated
    objects (system.link_bw/link_latency are kept consistent with the
    topology, so ``build_topology(cal.system)`` agrees with
    ``cal.topology``)."""
    system: object                     # calibrated SystemConfig
    topology: Topology
    compute_derate: float
    params: Dict[str, float]           # fitted values by PARAM_NAMES
    initial: Dict[str, float]          # starting values
    initial_error: float               # rms relative span error before fit
    fitted_error: float                # ... and after
    n_spans: int
    history: List[Dict] = dataclasses.field(default_factory=list)

    def summary(self) -> str:
        lines = [f"calibration over {self.n_spans} matched spans: "
                 f"rms rel error {self.initial_error * 100:.2f}% -> "
                 f"{self.fitted_error * 100:.2f}%"]
        for k in PARAM_NAMES:
            v0, v1 = self.initial[k], self.params[k]
            ratio = v1 / v0 if v0 else float("inf")
            lines.append(f"  {k:<15} {v0:.4g} -> {v1:.4g} ({ratio:.3f}x)")
        return "\n".join(lines)


def load_system_json(path: str):
    """Load a ``calibrate -o`` JSON file -> (SystemConfig, compute_derate).

    The hand-off format between the trace calibrator and every consumer of
    a calibrated cost model: ``python -m repro.trace --system cal.json``,
    ``python -m repro.search --system cal.json``, or directly in Python
    before a ``dse.explore`` / ``SearchRun``."""
    import json

    from repro.configs.base import SystemConfig
    with open(path) as f:
        saved = json.load(f)
    return (SystemConfig(**saved.get("system", {})),
            float(saved.get("compute_derate", 0.6)))


def system_from_flags(args, flags: Sequence[str] = (
        "chips", "topology", "peak_flops", "hbm_bw", "link_bw",
        "link_latency")):
    """Assemble (SystemConfig, compute_derate) from CLI args: ``--system``
    JSON (if given) overlaid with any explicitly-set hardware flags named
    in `flags` (argparse dest names == SystemConfig fields), plus
    ``--derate``.  Shared by the trace and search CLIs so their override
    semantics can't drift."""
    from repro.configs.base import SystemConfig
    sysc, derate = SystemConfig(), 0.6
    if getattr(args, "system", None):
        sysc, derate = load_system_json(args.system)
    over = {k: getattr(args, k) for k in flags
            if getattr(args, k, None) is not None}
    if over:
        sysc = sysc.replace(**over)
    d = getattr(args, "derate", None)
    if d is not None:
        derate = d
    return sysc, derate


def _measured_min(g: chakra.Graph, tl: Timeline) -> Dict[int, float]:
    """nid -> min measured duration across ranks (strips barrier wait)."""
    meas: Dict[int, float] = {}
    for al in align(g, tl).values():
        for nid, dur in al.measured().items():
            if nid not in meas or dur < meas[nid]:
                meas[nid] = dur
    return meas


def calibrate(g: chakra.Graph, tl: Timeline, system,
              topo: Optional[Topology] = None, *,
              params: Sequence[str] = PARAM_NAMES, algo: str = "auto",
              compute_derate: float = 0.6, rounds: int = 4,
              grid: int = 17, span: float = 4.0) -> CalibrationResult:
    """Fit `params` so the analytical durations match the measured trace.

    `span` bounds the multiplicative search window around each starting
    value in the first round (shrinking by sqrt each round); `grid` is the
    number of log-spaced candidates per scan."""
    topo = topo or build_topology(system)
    for k in params:
        if k not in PARAM_NAMES:
            raise ValueError(f"unknown calibration param {k!r}: "
                             f"expected one of {PARAM_NAMES}")
    meas = _measured_min(g, tl)
    comp_nids = [nid for nid, m in meas.items()
                 if m > 0 and g.node(nid).type == chakra.COMP]
    comm_nids = [nid for nid, m in meas.items()
                 if m > 0 and g.node(nid).type in _COMM_TYPES]
    nids = comp_nids + comm_nids
    if not nids:
        raise ValueError("no positive-duration matched spans to fit "
                         "(is the trace aligned to this graph?)")
    # a parameter with no spans of its kind is unidentifiable — freeze it
    active = [k for k in params
              if (comp_nids if k in _COMP_PARAMS else comm_nids)]

    initial = {"compute_derate": compute_derate, "hbm_bw": system.hbm_bw,
               "link_bw_scale": 1.0,
               "coll_latency": topo.link_latency or 1e-9}
    p = dict(initial)

    def objective(pv: Dict[str, float]) -> float:
        sys2 = system.replace(hbm_bw=pv["hbm_bw"])
        topo2 = _scaled_topo(topo, pv["link_bw_scale"], pv["coll_latency"])
        err = 0.0
        for nid in nids:
            pred = node_duration(g.node(nid), sys2, topo2, algo,
                                 pv["compute_derate"])
            r = (pred - meas[nid]) / meas[nid]
            err += r * r
        return err / len(nids)

    history: List[Dict] = []
    best = objective(p)
    initial_error = math.sqrt(best)
    sp = span
    for rnd in range(rounds):
        for k in active:
            v0 = p[k]
            for i in range(grid):
                v = v0 * math.exp(math.log(sp) * (2.0 * i / (grid - 1) - 1.0))
                cand = dict(p)
                cand[k] = v
                e = objective(cand)
                if e < best:
                    best, p = e, cand
            history.append({"round": rnd, "param": k, "value": p[k],
                            "rms": math.sqrt(best)})
        sp = math.sqrt(sp)

    sys2 = system.replace(
        hbm_bw=p["hbm_bw"], link_bw=system.link_bw * p["link_bw_scale"],
        link_latency=p["coll_latency"])
    topo2 = _scaled_topo(topo, p["link_bw_scale"], p["coll_latency"])
    return CalibrationResult(
        system=sys2, topology=topo2, compute_derate=p["compute_derate"],
        params=dict(p), initial=initial, initial_error=initial_error,
        fitted_error=math.sqrt(best), n_spans=len(nids), history=history)
