"""Deterministic synthetic data pipeline.

Stateless-resumable: batch(step) is a pure function of (seed, step, shape),
so restarting from a checkpoint at step k replays the exact token stream —
a fault-tolerance requirement (DESIGN.md SS7).

The stream is a mixture of structured sequences (so a ~100M model's loss
visibly decreases within a few hundred steps) rather than uniform noise:
  * Markov-chain tokens with a banded transition structure
  * repeated motifs (copy task segments)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    memory_len: int = 0          # stub frontend tokens (vlm/audio)
    d_model: int = 0


def _markov_tokens(key, batch, seq, vocab):
    """Banded-transition Markov chain: next ~ prev + small learned-able jump."""
    k1, k2 = jax.random.split(key)
    start = jax.random.randint(k1, (batch, 1), 0, vocab)
    jumps = jax.random.categorical(
        k2, jnp.log(jnp.array([0.55, 0.2, 0.15, 0.1])), shape=(batch, seq))
    jump_vals = jnp.array([1, 2, 3, 5])[jumps]
    toks = (start + jnp.cumsum(jump_vals, axis=1)) % vocab
    return toks.astype(jnp.int32)


def make_batch(cfg: DataConfig, step: int):
    """Pure function of (cfg, step) -> batch dict (host or device arrays)."""
    key = jax.random.fold_in(jax.random.PRNGKey(cfg.seed), step)
    k_tok, k_mem, k_motif = jax.random.split(key, 3)
    toks = _markov_tokens(k_tok, cfg.global_batch, cfg.seq_len + 1,
                          cfg.vocab_size)
    # splice a repeated motif into the second half (copy structure)
    motif_len = min(32, cfg.seq_len // 4)
    if motif_len >= 4:
        motif = toks[:, :motif_len]
        mid = cfg.seq_len // 2
        toks = jax.lax.dynamic_update_slice(toks, motif, (0, mid))
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
    if cfg.memory_len:
        batch["memory"] = jax.random.normal(
            k_mem, (cfg.global_batch, cfg.memory_len, cfg.d_model),
            jnp.bfloat16) * 0.02
    return batch


class DataIterator:
    """Step-indexed iterator with exact resume."""

    def __init__(self, cfg: DataConfig, start_step: int = 0):
        self.cfg = cfg
        self.step = start_step
        self._fn = jax.jit(lambda s: make_batch(cfg, s))

    def __next__(self):
        b = self._fn(self.step)
        self.step += 1
        return b

    def __iter__(self):
        return self
