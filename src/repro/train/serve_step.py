"""Serving step builders: prefill + batched single-token decode.

decode shapes of the assignment lower `serve_step` = one decode_step call
(one new token against a filled KV/state cache of cache_len tokens).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.models.model import Ctx, Model
from repro.train.train_step import make_ctx


def make_prefill_step(model: Model, parallel: ParallelConfig, mesh=None,
                      cache_len: int = 0):
    ctx = make_ctx(parallel, mesh)

    def prefill_step(params, tokens, memory=None):
        return model.prefill(params, tokens, ctx, cache_len, memory=memory)

    return prefill_step


def make_forward_step(model: Model, parallel: ParallelConfig, mesh=None):
    """Full-sequence forward (the prefill_* dry-run shape)."""
    ctx = make_ctx(parallel, mesh)

    def forward(params, tokens, memory=None):
        logits, _ = model.apply(params, tokens, ctx, memory=memory)
        return logits[:, -1]

    return forward


def make_decode_step(model: Model, parallel: ParallelConfig, mesh=None):
    ctx = make_ctx(parallel, mesh)

    def decode_step(params, token, cache):
        return model.decode_step(params, token, cache, ctx)

    return decode_step


def sample_token(logits, rng, temperature: float = 0.0):
    """logits (B, V) -> (B, 1) int32."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
    return jax.random.categorical(
        rng, logits / temperature, axis=-1)[:, None].astype(jnp.int32)


def generate(model: Model, params, prompt, steps: int, parallel: ParallelConfig,
             mesh=None, cache_len: int = 0, memory=None, temperature: float = 0.0,
             rng=None):
    """Greedy/temperature generation loop (example/serving driver)."""
    rng = jax.random.PRNGKey(0) if rng is None else rng
    cache_len = cache_len or (prompt.shape[1] + steps)
    prefill = jax.jit(make_prefill_step(model, parallel, mesh, cache_len))
    decode = jax.jit(make_decode_step(model, parallel, mesh))
    logits, cache = prefill(params, prompt, memory)
    toks = []
    tok = sample_token(logits, rng, temperature)
    toks.append(tok)
    for i in range(steps - 1):
        rng, k = jax.random.split(rng)
        logits, cache = decode(params, tok, cache)
        tok = sample_token(logits, k, temperature)
        toks.append(tok)
    return jnp.concatenate(toks, axis=1)
