"""Checkpointing: atomic, keep-last-k, async-capable, elastic restore.

Format: one directory per step containing
  * arrays.npz  -- flattened pytree leaves keyed by path string
  * meta.json   -- step, timestamp, user metadata

Elastic remesh: leaves are stored as full (unsharded) host arrays; restore
device_puts them with whatever shardings the *new* mesh dictates, so a run
checkpointed on N devices resumes on M devices unchanged (tested 4 -> 8).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
import time
from typing import Optional

import jax
import numpy as np


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        elif hasattr(p, "name"):
            parts.append(str(p.name))
        else:
            parts.append(str(p))
    return "/".join(parts)


def flatten_state(state):
    leaves = jax.tree_util.tree_flatten_with_path(state)[0]
    return {_path_str(path): leaf for path, leaf in leaves}


def state_nbytes(state) -> int:
    """Total bytes a checkpoint of `state` writes (sum of leaf nbytes)."""
    total = 0
    for leaf in flatten_state(state).values():
        nb = getattr(leaf, "nbytes", None)
        total += int(nb) if nb is not None else np.asarray(leaf).nbytes
    return total


def checkpoint_policy_for_state(state, interval: int = 32,
                                write_bw: float = 1e9,
                                restore_bw: Optional[float] = None):
    """Price a real pytree into a faults.CheckpointPolicy.

    write/restore costs are state_nbytes / bandwidth (bytes/s), so the
    fault simulator charges what this state would actually cost to
    persist; restore_bw defaults to write_bw.
    """
    from repro.faults.scenario import CheckpointPolicy
    nb = state_nbytes(state)
    return CheckpointPolicy(interval=interval,
                            write_cost=nb / float(write_bw),
                            restore_cost=nb / float(restore_bw or write_bw))


def save_checkpoint(ckpt_dir: str, step: int, state, meta: Optional[dict] = None,
                    keep: int = 3, async_save: bool = False):
    """Atomically persist `state` under ckpt_dir/step_<step>."""
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = flatten_state(state)
    host = {k: np.asarray(jax.device_get(v)) for k, v in flat.items()}
    meta = dict(meta or {})
    meta.update({"step": int(step), "time": time.time()})

    def write():
        tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
        try:
            np.savez(os.path.join(tmp, "arrays.npz"), **host)
            with open(os.path.join(tmp, "meta.json"), "w") as f:
                json.dump(meta, f)
            final = os.path.join(ckpt_dir, f"step_{step:08d}")
            if os.path.exists(final):
                shutil.rmtree(final)
            os.replace(tmp, final)
        except Exception:
            shutil.rmtree(tmp, ignore_errors=True)
            raise
        _cleanup(ckpt_dir, keep)

    if async_save:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _cleanup(ckpt_dir: str, keep: int):
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    for d in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(ckpt_dir, d), ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = sorted(d for d in os.listdir(ckpt_dir) if d.startswith("step_"))
    return int(steps[-1].split("_")[1]) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, template,
                       shardings=None):
    """Restore into the structure of `template` (pytree of arrays or
    ShapeDtypeStructs).  `shardings`: optional matching pytree of NamedShardings
    for elastic re-placement on the current mesh."""
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(d, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    paths, treedef = jax.tree_util.tree_flatten_with_path(template)
    shard_leaves = (jax.tree_util.tree_leaves(shardings)
                    if shardings is not None else [None] * len(paths))
    out = []
    for (path, leaf), sh in zip(paths, shard_leaves):
        key = _path_str(path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = data[key]
        want_dtype = getattr(leaf, "dtype", None)
        if want_dtype is not None:
            if arr.dtype.kind == "V":
                # npz stores ml_dtypes (bfloat16, ...) as raw void bytes;
                # reinterpret instead of casting
                arr = arr.view(want_dtype)
            else:
                arr = arr.astype(want_dtype)
        out.append(jax.device_put(arr, sh) if sh is not None else jax.device_put(arr))
    meta = json.load(open(os.path.join(d, "meta.json")))
    return jax.tree_util.tree_unflatten(treedef, out), meta
