"""Fault tolerance: failure injection, retry, straggler detection, preemption.

On a real multi-pod deployment failures surface as (a) raised exceptions from
the runtime (XLA halts, DCN timeouts), (b) SIGTERM preemptions, and (c)
silent stragglers.  The train loop composes:
  * run_with_retry      -- transient failures: re-run the step
  * checkpoint + resume -- fatal failures: restart from latest (exact data
                           replay via the step-indexed pipeline)
  * StragglerMonitor    -- per-step wall-time outlier detection
  * PreemptionHandler   -- SIGTERM -> save + clean exit
"""
from __future__ import annotations

import collections
import signal
import time
from typing import Callable, Optional, Tuple, Type, Union

import numpy as np


class SimulatedFault(RuntimeError):
    pass


class FaultInjector:
    """Deterministic failure injection for tests (seeded)."""

    def __init__(self, fail_steps=(), transient: bool = True):
        self.fail_steps = set(fail_steps)
        self.transient = transient
        self._fired: set = set()

    def check(self, step: int):
        if step in self.fail_steps and (not self.transient or step not in self._fired):
            self._fired.add(step)
            raise SimulatedFault(f"injected fault at step {step}")


Retryable = Union[Type[BaseException], Tuple[Type[BaseException], ...],
                  Callable[[BaseException], bool]]


def run_with_retry(fn: Callable, *args, retries: int = 2,
                   on_failure: Optional[Callable] = None,
                   backoff: float = 0.0, factor: float = 2.0,
                   max_backoff: float = 60.0, jitter: float = 0.0,
                   seed: int = 0, deadline: Optional[float] = None,
                   retryable: Optional[Retryable] = None,
                   sleep: Callable[[float], None] = time.sleep,
                   clock: Callable[[], float] = time.monotonic):
    """Run fn(*args); on exception retry up to `retries` times.

    backoff > 0 sleeps ``min(backoff * factor**attempt, max_backoff)``
    between attempts, stretched by up to ``jitter`` fraction of seeded
    uniform noise (``np.random.default_rng(seed)``) so co-failing ranks
    de-synchronize.  ``deadline`` bounds total elapsed seconds: a retry
    whose sleep would cross it re-raises instead.  ``retryable`` filters
    which exceptions are worth retrying — an exception class, a tuple of
    classes, or a predicate ``e -> bool``; anything else re-raises
    immediately.  ``sleep``/``clock`` are injectable for tests.
    """
    rng = np.random.default_rng(seed)
    start = clock()
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except Exception as e:          # noqa: BLE001 - deliberate catch-all
            if retryable is not None:
                ok = (retryable(e) if callable(retryable)
                      and not isinstance(retryable, type) else
                      isinstance(e, retryable))
                if not ok:
                    raise
            if attempt == retries:
                raise
            if on_failure:
                on_failure(e, attempt)
            delay = 0.0
            if backoff > 0.0:
                delay = min(backoff * factor ** attempt, max_backoff)
                if jitter > 0.0:
                    delay *= 1.0 + jitter * float(rng.random())
            if deadline is not None and clock() + delay - start > deadline:
                raise
            if delay > 0.0:
                sleep(delay)
    raise AssertionError("unreachable")


class StragglerMonitor:
    """Flags steps slower than `threshold` x rolling median.

    History is bounded at `window` samples so a long-running train loop
    does not accumulate O(steps) memory.
    """

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: collections.deque = collections.deque(maxlen=window)
        self.straggler_steps: list = []

    def record(self, step: int, duration: float):
        self.times.append(duration)
        if len(self.times) >= 5:
            med = float(np.median(self.times))
            if duration > self.threshold * med:
                self.straggler_steps.append((step, duration, med))
                return True
        return False

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


class PreemptionHandler:
    """SIGTERM/SIGINT -> set flag; the train loop checkpoints and exits.

    `install()` remembers whatever handlers were in place; `uninstall()`
    (or leaving the context manager) restores them, so a library user —
    say a pytest run or a notebook — gets its own signal handling back.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self.should_stop = False
        self._signals = signals
        self._previous: dict = {}

    def install(self):
        for s in self._signals:
            self._previous[s] = signal.signal(s, self._handle)
        return self

    def uninstall(self):
        for s, prev in self._previous.items():
            signal.signal(s, prev if prev is not None else signal.SIG_DFL)
        self._previous = {}

    def __enter__(self):
        return self.install()

    def __exit__(self, *exc):
        self.uninstall()

    def _handle(self, signum, frame):
        self.should_stop = True


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.duration = time.perf_counter() - self.t0
