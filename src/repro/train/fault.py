"""Fault tolerance: failure injection, retry, straggler detection, preemption.

On a real multi-pod deployment failures surface as (a) raised exceptions from
the runtime (XLA halts, DCN timeouts), (b) SIGTERM preemptions, and (c)
silent stragglers.  The train loop composes:
  * run_with_retry      -- transient failures: re-run the step
  * checkpoint + resume -- fatal failures: restart from latest (exact data
                           replay via the step-indexed pipeline)
  * StragglerMonitor    -- per-step wall-time outlier detection
  * PreemptionHandler   -- SIGTERM -> save + clean exit
"""
from __future__ import annotations

import signal
import time
from typing import Callable, Optional

import numpy as np


class SimulatedFault(RuntimeError):
    pass


class FaultInjector:
    """Deterministic failure injection for tests (seeded)."""

    def __init__(self, fail_steps=(), transient: bool = True):
        self.fail_steps = set(fail_steps)
        self.transient = transient
        self._fired: set = set()

    def check(self, step: int):
        if step in self.fail_steps and (not self.transient or step not in self._fired):
            self._fired.add(step)
            raise SimulatedFault(f"injected fault at step {step}")


def run_with_retry(fn: Callable, *args, retries: int = 2,
                   on_failure: Optional[Callable] = None):
    """Run fn(*args); on exception retry up to `retries` times."""
    for attempt in range(retries + 1):
        try:
            return fn(*args)
        except Exception as e:          # noqa: BLE001 - deliberate catch-all
            if attempt == retries:
                raise
            if on_failure:
                on_failure(e, attempt)
    raise AssertionError("unreachable")


class StragglerMonitor:
    """Flags steps slower than `threshold` x rolling median."""

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.times: list = []
        self.straggler_steps: list = []

    def record(self, step: int, duration: float):
        self.times.append(duration)
        hist = self.times[-self.window:]
        if len(hist) >= 5:
            med = float(np.median(hist))
            if duration > self.threshold * med:
                self.straggler_steps.append((step, duration, med))
                return True
        return False

    @property
    def median(self) -> float:
        return float(np.median(self.times)) if self.times else 0.0


class PreemptionHandler:
    """SIGTERM/SIGINT -> set flag; the train loop checkpoints and exits."""

    def __init__(self, signals=(signal.SIGTERM,)):
        self.should_stop = False
        self._signals = signals

    def install(self):
        for s in self._signals:
            signal.signal(s, self._handle)
        return self

    def _handle(self, signum, frame):
        self.should_stop = True


class StepTimer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        self.duration = time.perf_counter() - self.t0
