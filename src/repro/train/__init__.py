from repro.train.optimizer import OptConfig, OptState, init_opt_state, adamw_update
from repro.train.train_step import TrainState, make_train_step, make_eval_step, init_train_state, make_ctx
from repro.train.serve_step import make_prefill_step, make_decode_step, make_forward_step, generate
from repro.train.data import DataConfig, DataIterator, make_batch
from repro.train.checkpoint import save_checkpoint, restore_checkpoint, latest_step

__all__ = [
    "OptConfig", "OptState", "init_opt_state", "adamw_update",
    "TrainState", "make_train_step", "make_eval_step", "init_train_state",
    "make_ctx", "make_prefill_step", "make_decode_step", "make_forward_step",
    "generate", "DataConfig", "DataIterator", "make_batch",
    "save_checkpoint", "restore_checkpoint", "latest_step",
]
