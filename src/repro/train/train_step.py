"""Train / eval step builders (pjit-ready pure functions).

make_train_step(model, ...) returns a function
    (TrainState, batch) -> (TrainState, metrics)
with optional microbatched gradient accumulation (overlaps the DP gradient
collective of microbatch i with the backward compute of microbatch i+1 under
XLA's latency-hiding scheduler) and optional int8-compressed DP reduction.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ParallelConfig
from repro.models.model import Ctx, Model
from repro.parallel.collectives import make_compressed_value_and_grad
from repro.parallel.mesh import POD_AXIS, DATA_AXIS
from repro.parallel.sharding import make_shard_fn
from repro.train.optimizer import (OptConfig, OptState, adamw_update,
                                   init_opt_state)


class TrainState(NamedTuple):
    params: object
    opt: OptState
    err: object            # error-feedback state for compressed DP ({} if off)


def make_ctx(parallel: ParallelConfig, mesh) -> Ctx:
    from repro.parallel.mesh import dp_size, model_size
    groups = 1
    if mesh is not None:
        groups = dp_size(mesh)
        if parallel.model_axis == "zero3":
            groups *= model_size(mesh)     # the model axis is DP in zero3
    return Ctx(attn_impl=parallel.attn_impl, remat=parallel.remat,
               shard_fn=make_shard_fn(mesh, parallel),
               moe_groups=groups)


def init_train_state(model: Model, rng, parallel: ParallelConfig,
                     mesh=None) -> TrainState:
    params = model.init(rng)
    return TrainState(params=params, opt=init_opt_state(params), err={})


def _microbatch(batch, m):
    def split(x):
        b = x.shape[0]
        assert b % m == 0, f"batch {b} not divisible by microbatches {m}"
        return x.reshape(m, b // m, *x.shape[1:])
    return jax.tree_util.tree_map(split, batch)


def make_train_step(model: Model, opt_cfg: OptConfig,
                    parallel: ParallelConfig, mesh=None):
    ctx = make_ctx(parallel, mesh)

    def loss_fn(params, batch):
        return model.loss(params, batch, ctx)

    use_comp = parallel.grad_compression and mesh is not None
    if use_comp:
        dp_axes = tuple(a for a in (POD_AXIS, DATA_AXIS) if a in mesh.shape)
        comp_vag = make_compressed_value_and_grad(loss_fn, mesh, dp_axes)

    def train_step(state: TrainState, batch):
        params = state.params
        if use_comp:
            loss, metrics, grads, new_err = comp_vag(params, batch, state.err)
        elif parallel.microbatches > 1:
            m = parallel.microbatches
            mbs = _microbatch(batch, m)

            def body(acc, mb):
                (l, met), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
                acc_g, acc_l = acc
                acc_g = jax.tree_util.tree_map(
                    lambda a, b: a + b.astype(a.dtype), acc_g, g)
                return (acc_g, acc_l + l), met

            zero_g = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, lsum), mets = jax.lax.scan(body, (zero_g, 0.0), mbs)
            grads = jax.tree_util.tree_map(lambda g: g / m, gsum)
            loss = lsum / m
            metrics = jax.tree_util.tree_map(lambda x: x[-1], mets)
            new_err = state.err
        else:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            new_err = state.err

        new_params, new_opt, opt_metrics = adamw_update(
            opt_cfg, params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        metrics["loss"] = loss
        return TrainState(new_params, new_opt, new_err), metrics

    return train_step


def make_eval_step(model: Model, parallel: ParallelConfig, mesh=None):
    ctx = make_ctx(parallel, mesh)

    def eval_step(params, batch):
        loss, metrics = model.loss(params, batch, ctx)
        return {"loss": loss, **metrics}

    return eval_step
