"""AdamW with ZeRO-sharded state, gradient clipping, and LR schedules.

Optimizer state lives in f32 and is sharded with the *same* PartitionSpecs as
the (FSDP-sharded) parameters, which is exactly ZeRO semantics under GSPMD:
each device updates only its parameter shard.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


class OptState(NamedTuple):
    step: jax.Array          # int32 scalar
    mu: object               # first moment (pytree, f32)
    nu: object               # second moment (pytree, f32)


def init_opt_state(params) -> OptState:
    f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree_util.tree_map(f32, params),
                    nu=jax.tree_util.tree_map(f32, params))


def abstract_opt_state(abstract_params) -> OptState:
    f32 = lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32)
    return OptState(step=jax.ShapeDtypeStruct((), jnp.int32),
                    mu=jax.tree_util.tree_map(f32, abstract_params),
                    nu=jax.tree_util.tree_map(f32, abstract_params))


def opt_state_logical_axes(param_axes) -> OptState:
    return OptState(step=(), mu=param_axes, nu=param_axes)


def lr_at(cfg: OptConfig, step):
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree):
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def adamw_update(cfg: OptConfig, params, grads, state: OptState):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * clip
        mu = cfg.b1 * mu + (1 - cfg.b1) * g
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mu, nu

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_mu = treedef.flatten_up_to(state.mu)
    flat_nu = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, n) for p, g, m, n in zip(flat_p, flat_g, flat_mu, flat_nu)]
    newp = jax.tree_util.tree_unflatten(treedef, [o[0] for o in out])
    newmu = jax.tree_util.tree_unflatten(treedef, [o[1] for o in out])
    newnu = jax.tree_util.tree_unflatten(treedef, [o[2] for o in out])
    return newp, OptState(step, newmu, newnu), {"gnorm": gnorm, "lr": lr}
