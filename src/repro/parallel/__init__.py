from repro.parallel.mesh import make_mesh, DATA_AXIS, MODEL_AXIS, POD_AXIS
from repro.parallel.sharding import (activation_rules, param_rules,
                                     resolve_spec, named_sharding,
                                     tree_shardings, make_shard_fn)
from repro.parallel.pipeline import pipeline_apply

__all__ = ["make_mesh", "DATA_AXIS", "MODEL_AXIS", "POD_AXIS",
           "activation_rules", "param_rules", "resolve_spec",
           "named_sharding", "tree_shardings", "make_shard_fn",
           "pipeline_apply"]
