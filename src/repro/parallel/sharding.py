"""Logical-axis -> mesh-axis resolution (GSPMD sharding rules).

Every tensor (param, activation, cache) carries *logical* axis names
(ParamSpec.logical_axes or ctx.shard(...) call sites).  Rules map each
logical name to an ordered list of candidate mesh-axis tuples; resolution
picks the first candidate whose mesh axes (a) exist in the mesh, (b) are not
already used by another dim of the same tensor, and (c) evenly divide the
dim.  Divisibility fallback is what makes one rule set serve all 10 archs
(e.g. "experts"->model gives EP for dbrx's 16 experts but falls through to
ff-tensor-parallelism for mixtral's 8 — see DESIGN.md SS6).
"""
from __future__ import annotations

import math
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ParallelConfig
from repro.models.layers import is_spec


# candidate lists: first match wins.  Dims are resolved in _PRIORITY order
# (not positionally), so e.g. "vocab" claims the model axis before "batch"
# considers a (data, model) combo, and "seq" (sequence parallelism) only
# takes an axis nothing else in the tensor wanted.
_PRIORITY = ("experts", "vocab", "ff", "inner", "heads", "kv_heads",
             "groups", "cache", "batch", "embed", "layers", "seq")


def activation_rules(parallel: ParallelConfig):
    if parallel.model_axis == "zero3":
        # pure DP over (data x model); params ZeRO-3-sharded (param_rules)
        return {
            "batch": [("pod", "data", "model"), ("data", "model"),
                      ("pod", "data"), ("data",)],
            "seq": [],
            "heads": [], "kv_heads": [], "ff": [], "inner": [],
            "vocab": [("model",)],
            "experts": [("model",)],
            "groups": [("pod", "data", "model"), ("data", "model"),
                       ("pod", "data"), ("data",)],
            "embed": [],
            "cache": [("data",)] if parallel.seq_shard_cache else [],
            "layers": [],
        }
    rules = {
        "batch": [("pod", "data"), ("data",)],
        "seq": [("model",)] if parallel.seq_shard else [],
        "heads": [("model",)],
        "kv_heads": [("model",)],
        "ff": [("model",)],
        "vocab": [("model",)],
        "experts": [("model",)],
        "groups": [("pod", "data"), ("data",)],
        "inner": [("model",)],
        "embed": [],
        "cache": [("data",)] if parallel.seq_shard_cache else [],
        "layers": [],
    }
    return rules


def param_rules(parallel: ParallelConfig):
    if parallel.model_axis == "zero3":
        # every weight fully sharded over (data x model) on its first
        # shardable dim: GSPMD inserts per-layer weight all-gathers (fwd,
        # remat, bwd) and gradient reduce-scatters — FSDP/ZeRO-3 semantics
        return {
            "batch": [], "seq": [], "layers": [],
            "vocab": [("model",)],
            "embed": [("data", "model"), ("data",)],
            "ff": [("data", "model"), ("data",)],
            "inner": [("data", "model"), ("data",)],
            "heads": [], "kv_heads": [],
            "experts": [("model",)],
            "groups": [],
            "cache": [],
        }
    rules = activation_rules(parallel)
    if parallel.fsdp:
        # FSDP: additionally shard the (usually replicated) embed dim of
        # weight matrices over the data axis; GSPMD inserts the all-gathers
        # whose scheduling is exactly the paper's SS6.1 design space.
        rules = dict(rules)
        rules["embed"] = [("data",)]
    return rules


def resolve_spec(axes, shape, rules, mesh) -> P:
    """axes: tuple of logical names (or None) per dim, resolved in _PRIORITY
    order so high-value dims claim contested mesh axes first."""
    used: set = set()
    out: list = [None] * len(axes)

    def try_assign(i, dim, name):
        for cand in rules.get(name, []) if name else []:
            if any(a not in mesh.shape for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            prod = math.prod(mesh.shape[a] for a in cand)
            if prod > 1 and dim % prod == 0:
                used.update(cand)
                out[i] = (tuple(cand) if len(cand) > 1 else cand[0])
                return

    rank = {n: r for r, n in enumerate(_PRIORITY)}
    order = sorted(range(len(axes)),
                   key=lambda i: rank.get(axes[i], len(_PRIORITY)))
    for i in order:
        if axes[i]:
            try_assign(i, shape[i], axes[i])
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def named_sharding(mesh, axes, shape, rules) -> NamedSharding:
    return NamedSharding(mesh, resolve_spec(axes, shape, rules, mesh))


def tree_shardings(mesh, specs_tree, rules):
    """ParamSpec tree -> NamedSharding tree."""
    return jax.tree_util.tree_map(
        lambda s: named_sharding(mesh, s.logical_axes, s.shape, rules),
        specs_tree, is_leaf=is_spec)


def make_shard_fn(mesh: Optional[Mesh], parallel: ParallelConfig):
    """ctx.shard hook: annotate activations with sharding constraints."""
    if mesh is None:
        return None
    rules = activation_rules(parallel)

    def f(x, axes):
        if len(axes) != x.ndim:
            axes = tuple(axes) + (None,) * (x.ndim - len(axes))
        spec = resolve_spec(axes, x.shape, rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return f


def batch_specs(cfg, shape, model):
    """ParamSpec tree for one step's data inputs (tokens/labels/memory)."""
    from repro.models.layers import ParamSpec
    import jax.numpy as jnp
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {
            "tokens": ParamSpec((B, S), ("batch", "seq"), dtype=jnp.int32,
                                init="zeros"),
            "labels": ParamSpec((B, S), ("batch", "seq"), dtype=jnp.int32,
                                init="zeros"),
        }
    elif shape.kind == "prefill":
        out = {"tokens": ParamSpec((B, S), ("batch", "seq"), dtype=jnp.int32,
                                   init="zeros")}
    else:  # decode: one new token
        out = {"token": ParamSpec((B, 1), ("batch", None), dtype=jnp.int32,
                                  init="zeros")}
    ml = model.memory_len()
    if ml and shape.kind != "decode":
        out["memory"] = ParamSpec((B, ml, cfg.d_model),
                                  ("batch", None, "embed"), init="normal")
    return out
