"""GPipe-style pipeline parallelism over the `pod` axis (shard_map + ppermute).

Cross-pod DCN bandwidth (~12.5 GB/s/host) is ~50x below ICI, so the classic
multi-pod choice is pipeline stages across pods: only the (batch, seq, d)
activation boundary crosses DCN once per microbatch, instead of per-layer
gradient traffic.

Layout: the scanned layer stack (L, ...) is sharded over the stage axis
(L/S layers per stage).  Schedule: M microbatches, T = M + S - 1 ticks;
each tick every stage processes one in-flight microbatch and the boundary
activation rotates one stage forward via collective_permute.  Bubble
fraction = (S-1)/T, the usual GPipe accounting.

Differentiable end to end: jax.grad flows through ppermute (its transpose
is the reverse permute) and the tick scan, so the same function serves
training.  Exposed as a composable building block + example/test
(tests/test_pipeline.py); the dense archs use it via
ParallelConfig.pipeline_stages > 1 in pipeline_train_step below.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(block_fn: Callable, stacked_params, x_mb, mesh,
                   stage_axis: str = "pod"):
    """Run x_mb (M, mb, ...) through L stacked layers split over
    `stage_axis` as a GPipe pipeline.

    block_fn(params_slice, h) -> h applies ONE layer.
    stacked_params: pytree with leading layer dim L (L % n_stages == 0).
    Returns (M, mb, ...) outputs (from the last stage, broadcast to all).
    """
    n_stages = mesh.shape[stage_axis]
    M = x_mb.shape[0]

    def stage_body(params_local, x_local):
        stage = jax.lax.axis_index(stage_axis)
        L_local = jax.tree_util.tree_leaves(params_local)[0].shape[0]
        T = M + n_stages - 1
        mb_shape = x_local.shape[1:]

        def run_local(h):
            def layer(h, p):
                return block_fn(p, h), None
            h, _ = jax.lax.scan(layer, h, params_local)
            return h

        def tick(carry, t):
            boundary, outs = carry
            # stage 0 injects microbatch t (if within range)
            inject = jnp.where(t < M, t, M - 1)
            h_in = jnp.where(stage == 0,
                             jax.lax.dynamic_index_in_dim(
                                 x_local, inject, 0, keepdims=False),
                             boundary)
            h_out = run_local(h_in)
            # collect at the last stage: tick t finishes microbatch t-(S-1)
            out_idx = t - (n_stages - 1)
            do_collect = (stage == n_stages - 1) & (out_idx >= 0)
            outs = jax.lax.cond(
                do_collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, h_out, jnp.maximum(out_idx, 0), 0),
                lambda o: o, outs)
            # rotate boundary forward one stage
            boundary = jax.lax.ppermute(
                h_out, stage_axis,
                [(i, (i + 1) % n_stages) for i in range(n_stages)])
            return (boundary, outs), None

        outs0 = jnp.zeros((M,) + mb_shape, x_local.dtype)
        boundary0 = jnp.zeros(mb_shape, x_local.dtype)
        (boundary, outs), _ = jax.lax.scan(
            tick, (boundary0, outs0), jnp.arange(T))
        # rotate the completed buffer (held by the last stage) to stage 0 and
        # expose a per-stage leading dim; the caller reads index 0
        outs = jax.lax.ppermute(
            outs, stage_axis,
            [(i, (i + 1) % n_stages) for i in range(n_stages)])  # last -> 0
        return outs[None]

    axis_names = frozenset({stage_axis})
    pspec_params = jax.tree_util.tree_map(lambda _: P(stage_axis),
                                          stacked_params)
    f = jax.shard_map(stage_body, mesh=mesh,
                      in_specs=(pspec_params, P()),
                      out_specs=P(stage_axis), check_vma=False,
                      axis_names=axis_names)
    # partial-manual shard_map (manual pod, auto data/model) requires a jit
    # context in jax 0.8; jit-in-jit composes fine for callers already jitted
    return jax.jit(f)(stacked_params, x_mb)[0]


def pipeline_loss(block_fn, stacked_params, x_mb, loss_fn, mesh,
                  stage_axis: str = "pod"):
    """Pipelined forward + scalar loss (differentiable wrt stacked_params)."""
    y = pipeline_apply(block_fn, stacked_params, x_mb, mesh, stage_axis)
    return loss_fn(y)
