"""Mesh axis conventions.

Production meshes (defined in launch/mesh.py as required):
  single-pod: (16, 16)    axes ("data", "model")
  multi-pod:  (2, 16, 16) axes ("pod", "data", "model")

"pod" is the cross-DCN axis: plain DP (gradient all-reduce over DCN) or the
pipeline axis when ParallelConfig.pipeline_stages > 1.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes),
                         axis_types=(AxisType.Auto,) * len(axes))


def axis_size(mesh, name: str) -> int:
    return mesh.shape.get(name, 1) if mesh is not None else 1


def dp_size(mesh) -> int:
    return axis_size(mesh, DATA_AXIS) * axis_size(mesh, POD_AXIS)


def model_size(mesh) -> int:
    return axis_size(mesh, MODEL_AXIS)
