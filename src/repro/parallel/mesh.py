"""Mesh axis conventions.

Production meshes (defined in launch/mesh.py as required):
  single-pod: (16, 16)    axes ("data", "model")
  multi-pod:  (2, 16, 16) axes ("pod", "data", "model")

"pod" is the cross-DCN axis: plain DP (gradient all-reduce over DCN) or the
pipeline axis when ParallelConfig.pipeline_stages > 1.
"""
from __future__ import annotations

import contextlib

import jax

try:  # jax >= 0.5 exposes explicit axis types; older jax has neither the
    # enum nor the ``axis_types=`` kwarg on jax.make_mesh.
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - depends on installed jax
    AxisType = None

DATA_AXIS = "data"
MODEL_AXIS = "model"
POD_AXIS = "pod"


def make_mesh(shape, axes):
    if AxisType is not None:
        try:
            return jax.make_mesh(tuple(shape), tuple(axes),
                                 axis_types=(AxisType.Auto,) * len(axes))
        except TypeError:  # AxisType exists but make_mesh predates the kwarg
            pass
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_context(mesh):
    """``jax.set_mesh(mesh)`` where available (newer jax explicit-sharding
    API); a no-op context on older jax, where NamedSharding-driven
    jit/lowering needs no ambient mesh."""
    set_mesh = getattr(jax, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return contextlib.nullcontext()


def axis_size(mesh, name: str) -> int:
    return mesh.shape.get(name, 1) if mesh is not None else 1


def dp_size(mesh) -> int:
    return axis_size(mesh, DATA_AXIS) * axis_size(mesh, POD_AXIS)


def model_size(mesh) -> int:
    return axis_size(mesh, MODEL_AXIS)
