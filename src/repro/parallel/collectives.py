"""Hand-rolled collectives: int8-compressed data-parallel gradient reduction.

GSPMD emits the standard bf16/f32 collectives automatically; this module
implements *compressed* DP gradient all-reduce (a distributed-optimization
trick + a DSE knob for the cost model: ~2x fewer DP collective bytes).

Algorithm (inside shard_map, manual over the DP axes, GSPMD-auto over the
model axis):
  1. quantize the local gradient to int8 with a per-tensor scale
  2. all_to_all the chunks (device i owns chunk i)      [S*(n-1)/n int8 wire]
  3. dequantize + sum the owned chunk in f32, requantize
  4. all_gather the reduced chunks                      [S*(n-1)/n int8 wire]
Total wire ~ 2*S bytes vs ~4*S for a bf16 ring all-reduce.

Error feedback: each device keeps (g_local - dequant(q)) and adds it to its
next-step gradient, so the quantization bias vanishes over steps.  The error
state is a per-device tensor, surfaced as a global array with a leading
device axis (sharded over the DP axes).

Constraint: params must be replicated over the DP axes (fsdp=False);
model-axis tensor parallelism composes fine (auto).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def _quantize(x):
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-20) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def compressed_allreduce_mean(g, axis_name):
    """Mean-all-reduce over `axis_name` (str or tuple) with int8 wire format.

    Runs inside shard_map manual over `axis_name`.
    Returns (mean_g, local_quantization_error).
    """
    n = jax.lax.axis_size(axis_name)
    shape, dtype = g.shape, g.dtype
    flat = g.reshape(-1).astype(jnp.float32)
    pad = (-flat.size) % n
    if pad:
        flat = jnp.pad(flat, (0, pad))
    chunks = flat.reshape(n, -1)

    q, scale = _quantize(chunks)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)
    err = (flat - deq)[:flat.size - pad if pad else None]
    err = err.reshape(shape).astype(dtype)

    # exchange: device j receives chunk j from everyone
    qx = jax.lax.all_to_all(q, axis_name, split_axis=0, concat_axis=0, tiled=True)
    qx = qx.reshape(n, -1)                               # (n, c)
    scales = jax.lax.all_gather(scale, axis_name, tiled=False).reshape(n)
    part = (qx.astype(jnp.float32) * scales[:, None]).sum(axis=0)   # (c,)

    q2, scale2 = _quantize(part)
    q2g = jax.lax.all_gather(q2, axis_name, tiled=False).reshape(n, -1)
    s2g = jax.lax.all_gather(scale2, axis_name, tiled=False).reshape(n)
    out = (q2g.astype(jnp.float32) * s2g[:, None]).reshape(-1)
    if pad:
        out = out[:-pad]
    return (out / n).reshape(shape).astype(dtype), err


def make_compressed_value_and_grad(loss_fn, mesh, dp_axes=("data",)):
    """Build a (params, batch, err_state) -> (loss, metrics, grads, err_state)
    function whose DP gradient reduction uses int8 compression.

    loss_fn(params, batch) -> (loss, metrics).  Batch dim 0 must be sharded
    over dp_axes; params replicated over dp_axes (model axis stays auto).
    err_state: pytree like grads with a leading per-device axis
    (init with zeros via `init_error_state`).
    """
    dp_axes = tuple(dp_axes)
    axis = dp_axes if len(dp_axes) > 1 else dp_axes[0]

    def body(params, batch, err):
        (loss, metrics), g = jax.value_and_grad(loss_fn, has_aux=True)(params, batch)
        g = jax.tree_util.tree_map(lambda gi, ei: gi + ei[0].astype(gi.dtype),
                                   g, err)
        pairs = jax.tree_util.tree_map(
            lambda gi: compressed_allreduce_mean(gi, axis), g)
        gout = jax.tree_util.tree_map(lambda pr: pr[0], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))
        eout = jax.tree_util.tree_map(lambda pr: pr[1][None], pairs,
                                      is_leaf=lambda x: isinstance(x, tuple))
        n = jax.lax.axis_size(axis)
        loss = jax.lax.psum(loss, axis) / n
        metrics = jax.tree_util.tree_map(lambda m: jax.lax.psum(m, axis) / n,
                                         metrics)
        return loss, metrics, gout, eout

    def run(params, batch, err_state):
        in_specs = (P(), P(dp_axes), P(dp_axes))
        out_specs = (P(), P(), P(), P(dp_axes))
        f = jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                          out_specs=out_specs, check_vma=False,
                          axis_names=frozenset(dp_axes))
        return f(params, batch, err_state)

    return run


def init_error_state(grads_like, n_dp: int):
    """Zero error-feedback state: grads shapes with a leading device axis."""
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros((n_dp,) + tuple(g.shape), g.dtype), grads_like)
