"""Cluster-free workload capture (Flint's runtime, paper SS4).

capture_step() is the JAX analogue of registering Flint as a torch.compile
backend: `.lower()` on ShapeDtypeStructs traces the program without touching
device memory (the meta-device illusion comes for free), `.compile()` runs
GSPMD + XLA passes for the *target* mesh — which can be any size thanks to
--xla_force_host_platform_device_count — and the resulting per-partition HLO
is parsed into a Chakra graph.

Capture levels (paper SS3.2 tradeoff):
  * "lowered"  = StableHLO before SPMD/fusion (source-faithful op counts)
  * "compiled" = scheduled per-device HLO with real collectives (default)
"""
from __future__ import annotations

import dataclasses
import json
import re
import time
from typing import Dict, Optional

import jax

from repro.core import chakra
from repro.core.convert import hlo_to_chakra
from repro.core.hlo_parse import (HloModule, instruction_flops, parse_hlo,
                                  walk_instructions)


@dataclasses.dataclass
class CaptureResult:
    meta: Dict
    lowered_text: str
    compiled_text: str
    cost_analysis: Dict
    memory_analysis: Dict
    summary: Dict                       # Flint-parsed totals (trip-count aware)
    graph: chakra.Graph

    def save_summary(self, path: str):
        with open(path, "w") as f:
            json.dump({"meta": self.meta, "cost_analysis": self.cost_analysis,
                       "memory_analysis": self.memory_analysis,
                       "summary": self.summary}, f, indent=1)


_SKIP_BYTES_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
                   "bitcast", "while", "conditional", "after-all",
                   "opt-barrier", "partition-id", "replica-id", "iota"}


def _fusion_param_read_bytes(mod: HloModule, ins) -> dict:
    """For a fusion, map parameter index -> (bytes, tpu_bytes) actually read.

    When a parameter is consumed only through a dynamic-slice inside the
    fusion (XLA fuses cache/stack slicing into consumer fusions), the read is
    the slice, not the whole buffer."""
    called = ins.attrs.get("calls", "").lstrip("%")
    sub = mod.computations.get(called)
    out = {}
    if sub is None:
        return out
    params = [i for i in sub.instructions if i.opcode == "parameter"]
    for idx, p in enumerate(params):
        consumers = [i for i in sub.instructions if p.name in i.operands]
        if consumers and all(i.opcode in ("dynamic-slice", "bitcast", "copy")
                             for i in consumers):
            ds = [i for i in consumers if i.opcode == "dynamic-slice"]
            if ds:
                out[idx] = (max(d.out_bytes for d in ds),
                            max(d.out_tpu_bytes for d in ds))
    return out


def summarize_module(mod: HloModule) -> Dict:
    """Trip-count-aware per-device totals from the parsed HLO.

    *_tpu fields normalize float tensors to bf16 (XLA:CPU upcasts bf16 GEMM
    operands to f32; on the TPU target these collectives/buffers stay bf16 —
    see DESIGN.md SS4)."""
    # computations dominated by *_vmem-scoped ops are Pallas-kernel inner
    # bodies on the TPU target: in the fused view only their block I/O
    # (dynamic-slice / dynamic-update-slice) touches HBM.  XLA rewrites strip
    # metadata from some interior dots/fusions, so a computation where >=50%
    # of substantial instructions carry the scope is flagged wholesale; ops
    # with the scope metadata are excluded wherever they appear (inline
    # kernels like local attention / RG-LRU live inside layer bodies).
    # Two-level VMEM flagging.  Level 1: a *fusion body* is VMEM-resident if
    # the majority of its metadata-carrying ops come from a *_vmem scope
    # (the fusion ROOT's metadata is often a fused-in dynamic_update_slice).
    # Level 2: a while-body computation is VMEM-resident if the majority of
    # its substantial instructions are vmem-tagged or call vmem fusions
    # (this catches interior dots whose metadata XLA rewrites stripped).
    vmem_fusion_comps = set()
    for cname, comp in mod.computations.items():
        tagged = [i for i in comp.instructions
                  if i.opcode not in _SKIP_BYTES_OPS and i.metadata_op]
        if tagged and sum(1 for i in tagged if "_vmem" in i.metadata_op) \
                >= max(1, (len(tagged) + 1) // 2):
            vmem_fusion_comps.add(cname)

    def _ins_vmem(i) -> bool:
        if "_vmem" in i.metadata_op:
            return True
        if i.opcode == "fusion":
            return i.attrs.get("calls", "").lstrip("%") in vmem_fusion_comps
        return False

    vmem_comps = set()
    for cname, comp in mod.computations.items():
        subst = [i for i in comp.instructions
                 if i.opcode not in _SKIP_BYTES_OPS]
        scored = [i for i in subst if i.metadata_op or _ins_vmem(i)]
        if not scored:
            continue
        marked = sum(1 for i in scored if _ins_vmem(i))
        if marked >= max(1, (len(scored) + 1) // 2):
            vmem_comps.add(cname)
    flops = 0.0
    hbm = 0.0
    hbm_tpu = 0.0
    hbm_tpu_fused = 0.0   # Pallas-kernel view: *_vmem scopes don't touch HBM
    comm: Dict[str, Dict] = {}
    colls = []
    for ins, mult, comp in walk_instructions(mod):
        flops += instruction_flops(mod, ins, comp) * mult
        comp_obj = mod.computations[comp]
        # copy-rooted fusions are loop double-buffering that TPU copy
        # elision/donation removes; convert-rooted fusions are the CPU
        # backend's bf16<->f32 shims that don't exist on the TPU target.
        _artifact = (ins.opcode == "copy" or
                     ins.name.split(".")[0].rstrip("0123456789")
                     in ("copy_bitcast_fusion", "wrapped_copy", "copy_fusion",
                         "wrapped_convert", "convert_bitcast_fusion",
                         "convert_fusion", "bitcast_copy_fusion",
                         "convert_copy_fusion", "copy"))
        if ins.opcode not in _SKIP_BYTES_OPS and not _artifact:
            name_op = ins.name + "|" + ins.opcode
            # ops inside a *_vmem named_scope, vmem fusions, or kernel-body
            # computations are resident in the Pallas kernels' VMEM on the
            # TPU target: the fused view counts only block reads/writes
            in_vmem_scope = _ins_vmem(ins) or comp in vmem_comps
            if "dynamic-update-slice" in name_op:
                # in-place aliased update: traffic = the touched slice (2x),
                # not the whole carried buffer.  The update is the smallest
                # non-scalar operand (the largest is the aliased buffer).
                ops_b = sorted((src.out_bytes, src.out_tpu_bytes)
                               for o in ins.operands
                               if (src := comp_obj.find(o)) is not None
                               and src.out_bytes > 64)
                upd_b, upd_bt = ops_b[0] if len(ops_b) > 1 else (0, 0)
                hbm += 2 * upd_b * mult
                hbm_tpu += 2 * upd_bt * mult
                if not in_vmem_scope:        # carry updates inside kernel
                    hbm_tpu_fused += 2 * upd_bt * mult  # bodies live in VMEM
            elif "dynamic-slice" in name_op:
                hbm += 2 * ins.out_bytes * mult
                hbm_tpu += 2 * ins.out_tpu_bytes * mult
                if not in_vmem_scope:
                    hbm_tpu_fused += 2 * ins.out_tpu_bytes * mult
            else:
                sliced = (_fusion_param_read_bytes(mod, ins)
                          if ins.opcode == "fusion" else {})
                in_b = in_bt = 0
                for oi, o in enumerate(ins.operands):
                    src = comp_obj.find(o)
                    if src is None or src.opcode == "constant":
                        continue
                    b, bt = sliced.get(oi, (src.out_bytes, src.out_tpu_bytes))
                    in_b += b
                    in_bt += bt
                hbm += (in_b + ins.out_bytes) * mult
                hbm_tpu += (in_bt + ins.out_tpu_bytes) * mult
                if not in_vmem_scope:
                    hbm_tpu_fused += (in_bt + ins.out_tpu_bytes) * mult
        if ins.is_collective and not ins.opcode.endswith("-done"):
            kind = ins.collective_kind
            # payload: operand bytes (all-gather: gathered output)
            in_bytes = sum(comp_obj.find(o).out_bytes for o in ins.operands
                           if comp_obj.find(o) is not None)
            in_tpu = sum(comp_obj.find(o).out_tpu_bytes for o in ins.operands
                         if comp_obj.find(o) is not None)
            payload = ins.out_bytes if kind == "all-gather" else in_bytes
            payload_tpu = (ins.out_tpu_bytes if kind == "all-gather"
                           else in_tpu)
            c = comm.setdefault(kind, {"count": 0, "bytes": 0.0,
                                       "bytes_tpu": 0.0})
            c["count"] += mult
            c["bytes"] += payload * mult
            c["bytes_tpu"] += payload_tpu * mult
            colls.append({"name": ins.name, "kind": kind, "bytes": payload,
                          "bytes_tpu": payload_tpu, "mult": mult,
                          "replica_groups": ins.attrs.get("replica_groups", "")})
    return {"parsed_flops": flops,
            "parsed_hbm_bytes": hbm,
            "parsed_hbm_bytes_tpu": hbm_tpu,
            "parsed_hbm_bytes_tpu_fused": hbm_tpu_fused,
            "comm": comm,
            "comm_bytes": sum(c["bytes"] for c in comm.values()),
            "comm_bytes_tpu": sum(c["bytes_tpu"] for c in comm.values()),
            "collectives": colls}


def _memory_dict(compiled) -> Dict:
    try:
        ma = compiled.memory_analysis()
        return {k: getattr(ma, k) for k in (
            "argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes") if hasattr(ma, k)}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def _cost_dict(compiled) -> Dict:
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return {k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float))}
    except Exception as e:  # noqa: BLE001
        return {"error": str(e)}


def capture_step(step_fn, abstract_args, in_shardings, mesh,
                 meta: Optional[Dict] = None, donate_argnums=(),
                 out_shardings=None, build_graph: bool = True) -> CaptureResult:
    """Lower + compile a step function on a (possibly fake) mesh and parse the
    artifacts into a Chakra graph + roofline summary.  No device allocation.
    """
    t0 = time.time()
    kw = {}
    if in_shardings is not None:
        kw["in_shardings"] = in_shardings
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    from repro.parallel.mesh import mesh_context
    jitted = jax.jit(step_fn, donate_argnums=donate_argnums, **kw)
    with mesh_context(mesh):
        lowered = jitted.lower(*abstract_args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    compiled_text = compiled.as_text()
    mod = parse_hlo(compiled_text)
    summary = summarize_module(mod)
    graph = hlo_to_chakra(mod, meta) if build_graph else chakra.Graph()
    meta = dict(meta or {})
    meta.update({"mesh_shape": dict(mesh.shape), "t_lower_s": t_lower,
                 "t_compile_s": t_compile,
                 "num_partitions": mod.num_partitions})
    return CaptureResult(
        meta=meta,
        lowered_text=lowered.as_text(),
        compiled_text=compiled_text,
        cost_analysis=_cost_dict(compiled),
        memory_analysis=_memory_dict(compiled),
        summary=summary,
        graph=graph,
    )


def stablehlo_op_counts(lowered_text: str) -> Dict[str, int]:
    """Op histogram of the pre-SPMD StableHLO (source-level counts for the
    paper's SS5.2 validation)."""
    counts: Dict[str, int] = {}
    for m in re.finditer(r"=\s+(?:stablehlo|mhlo|func)\.([\w.]+)",
                         lowered_text):
        counts[m.group(1)] = counts.get(m.group(1), 0) + 1
    return counts
