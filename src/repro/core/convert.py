"""HLO -> Chakra conversion (Flint's Graph Converter, paper SS4.3).

Walks the scheduled post-SPMD HLO module and emits a Chakra graph whose
edges are the SSA operands — the true data dependencies.  Bookkeeping ops
(tuple/GTE/parameter/bitcast/constant) are aliased through to their
producers, matching how the paper drops FX input nodes from Chakra.

While loops (jax.lax.scan):
  * bodies containing collectives are *expanded* trip_count times, chaining
    loop-carried deps — the per-iteration collectives then appear explicitly
    (a post-execution trace would show exactly these);
  * collective-free bodies (e.g. flash-attention kv scans) are *collapsed*
    into one COMP node with flops/bytes scaled by trip count, keeping graphs
    compact without losing cost.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import chakra
from repro.core.hlo_parse import (COLLECTIVE_OPS, HloModule, Instruction,
                                  instruction_flops, parse_permute_pairs,
                                  parse_replica_groups, while_trip_count)

# ops that never become nodes: forward deps through them
_ALIAS_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast",
              "constant", "iota", "partition-id", "replica-id",
              "after-all", "opt-barrier"}

_MAX_EXPAND = 128


def _computation_has_collective(mod: HloModule, comp_name: str,
                                _seen=None) -> bool:
    _seen = _seen if _seen is not None else set()
    if comp_name in _seen:
        return False
    _seen.add(comp_name)
    comp = mod.computations.get(comp_name)
    if comp is None:
        return False
    for ins in comp.instructions:
        if ins.is_collective:
            return True
        for key in ("body", "condition", "calls"):
            sub = ins.attrs.get(key, "").lstrip("%")
            if sub and _computation_has_collective(mod, sub, _seen):
                return True
    return False


def _comp_cost(mod: HloModule, comp_name: str, mult: int = 1):
    """(flops, bytes) of a computation incl. nested whiles (for collapse)."""
    comp = mod.computations.get(comp_name)
    flops = 0.0
    bytes_ = 0.0
    if comp is None:
        return flops, bytes_
    for ins in comp.instructions:
        if ins.opcode in _ALIAS_OPS:
            continue
        if ins.opcode == "while":
            body = ins.attrs.get("body", "").lstrip("%")
            cond = ins.attrs.get("condition", "").lstrip("%")
            trips = while_trip_count(mod, cond)
            f, b = _comp_cost(mod, body, 1)
            flops += f * trips
            bytes_ += b * trips
            continue
        flops += instruction_flops(mod, ins, comp_name)
        bytes_ += ins.out_bytes
        for op in ins.operands:
            src = comp.find(op)
            if src is not None:
                bytes_ += src.out_bytes
    return flops * mult, bytes_ * mult


class _Tuple:
    """Per-element dependency sets for HLO tuple values.

    Tracking tuple elements separately through while loops is what keeps
    loop-*invariant* inputs (e.g. the stacked weight tensors feeding FSDP
    all-gathers) free of false cross-iteration dependencies — the exact
    failure mode of CUDA-API-level capture the paper calls out (SS2.2)."""

    def __init__(self, elements: List[List[int]]):
        self.elements = [list(e) for e in elements]

    def flat(self) -> List[int]:
        out: List[int] = []
        for e in self.elements:
            out.extend(e)
        return list(dict.fromkeys(out))


def _flat(v) -> List[int]:
    if isinstance(v, _Tuple):
        return v.flat()
    return list(v)


class _Builder:
    def __init__(self, mod: HloModule, graph: chakra.Graph):
        self.mod = mod
        self.g = graph

    def build_computation(self, comp_name: str, param_vals=None,
                          prefix: str = ""):
        """Emit nodes for one computation instance.

        param_vals[i]: value (_Tuple or id list) backing parameter i.
        Returns the value backing the ROOT instruction."""
        comp = self.mod.computations[comp_name]
        env: Dict[str, object] = {}
        param_idx = 0
        root_val = []
        for ins in comp.instructions:
            operand_vals = [env.get(op, []) for op in ins.operands]
            dep_ids: List[int] = []
            for v in operand_vals:
                dep_ids.extend(_flat(v))
            dep_ids = list(dict.fromkeys(dep_ids))

            if ins.opcode == "parameter":
                env[ins.name] = (param_vals[param_idx]
                                 if param_vals and param_idx < len(param_vals)
                                 else [])
                param_idx += 1
            elif ins.opcode == "tuple":
                env[ins.name] = _Tuple([_flat(v) for v in operand_vals])
            elif ins.opcode == "get-tuple-element":
                idx = int(ins.attrs.get("index", "0"))
                src = operand_vals[0] if operand_vals else []
                if isinstance(src, _Tuple) and idx < len(src.elements):
                    env[ins.name] = src.elements[idx]
                else:
                    env[ins.name] = _flat(src)
            elif ins.opcode == "while":
                env[ins.name] = self._emit_while(ins, operand_vals, dep_ids,
                                                 prefix)
            elif ins.opcode in _ALIAS_OPS:
                env[ins.name] = dep_ids
            elif ins.is_collective:
                env[ins.name] = [self._emit_collective(ins, dep_ids, prefix)]
            else:
                env[ins.name] = [self._emit_comp(ins, dep_ids, prefix,
                                                 comp_name)]
            if ins.raw.strip().startswith("ROOT") or ins is comp.instructions[-1]:
                root_val = env[ins.name]
        return root_val

    def _emit_comp(self, ins: Instruction, deps, prefix, comp_name) -> int:
        flops = instruction_flops(self.mod, ins, comp_name)
        in_bytes = 0
        comp = self.mod.computations[comp_name]
        for op in ins.operands:
            src = comp.find(op)
            if src is not None:
                in_bytes += src.out_bytes
        return self.g.add(prefix + ins.name, chakra.COMP, deps=deps,
                          flops=flops, bytes=float(in_bytes + ins.out_bytes),
                          out_bytes=float(ins.out_bytes), op=ins.opcode,
                          src_op=ins.metadata_op)

    def _emit_collective(self, ins: Instruction, deps, prefix) -> int:
        kind = ins.collective_kind
        groups = parse_replica_groups(ins.attrs.get("replica_groups", ""),
                                      self.mod.num_partitions)
        comp = None
        in_bytes = 0
        for cn, c in self.mod.computations.items():
            if c.find(ins.name) is ins:
                comp = c
                break
        if comp:
            for op in ins.operands:
                src = comp.find(op)
                if src is not None:
                    in_bytes += src.out_bytes
        # comm_bytes: per-device payload (operand size; the roofline spec's
        # "sum operand sizes").  all-gather's operand is the pre-gather shard.
        payload = float(in_bytes if kind != "all-gather" else ins.out_bytes)
        attrs = dict(comm_kind=kind, comm_bytes=payload,
                     in_bytes=float(in_bytes), out_bytes=float(ins.out_bytes),
                     group_size=len(groups[0]) if groups else 1,
                     n_groups=len(groups), group=list(groups[0]) if groups else [],
                     src_op=ins.metadata_op)
        if kind == "collective-permute":
            attrs["pairs"] = parse_permute_pairs(
                ins.attrs.get("source_target_pairs", ""))
            attrs["comm_bytes"] = float(ins.out_bytes)
        return self.g.add(prefix + ins.name, chakra.COMM_COLL, deps=deps,
                          **attrs)

    def _emit_while(self, ins: Instruction, operand_vals, deps, prefix):
        body = ins.attrs.get("body", "").lstrip("%")
        cond = ins.attrs.get("condition", "").lstrip("%")
        trips = while_trip_count(self.mod, cond)
        if not _computation_has_collective(self.mod, body) or trips > _MAX_EXPAND:
            f, b = _comp_cost(self.mod, body, trips)
            nid = self.g.add(prefix + ins.name, chakra.COMP, deps=deps,
                             flops=f, bytes=b, op="while.collapsed",
                             trips=trips, src_op=ins.metadata_op)
            return [nid]
        # the loop state is a single tuple parameter; thread per-element deps
        # so loop-invariant elements don't serialize across iterations
        state = operand_vals[0] if operand_vals else []
        for t in range(trips):
            state = self.build_computation(body, [state],
                                           prefix=f"{prefix}{ins.name}.it{t}/")
        return state


def hlo_to_chakra(mod: HloModule, meta: Optional[dict] = None) -> chakra.Graph:
    g = chakra.Graph(meta={"source": "flint-jax", "entry": mod.entry,
                           "num_partitions": mod.num_partitions,
                           **(meta or {})})
    b = _Builder(mod, g)
    b.build_computation(mod.entry)
    return g


def _stage_assignment(g: chakra.Graph, order: List[int], num_stages: int,
                      assignment, allow_backward: bool = False) -> List[int]:
    """nid -> stage index.  ``assignment`` is a balancing policy ("flops":
    contiguous topo segments balanced by compute flops; "nodes": balanced by
    node count) or an explicit per-node map (list/dict nid -> stage).
    Explicit maps are validated: every stage non-empty, every dependency
    pointing to the same or an earlier stage (a pipeline never sends
    activations backwards inside one step's dataflow).  ``allow_backward``
    lifts the direction check for the microbatched lowering, which turns
    backward cross-stage edges (an explicit backward pass) into gradient
    data channels instead of rejecting them."""
    n = len(g.nodes)
    S = num_stages
    if not isinstance(assignment, str):
        if not isinstance(assignment, dict) and len(assignment) != n:
            raise ValueError(f"stage_assignment covers {len(assignment)} "
                             f"nodes, graph has {n}")
        get = (assignment.get if isinstance(assignment, dict)
               else lambda nid: assignment[nid])
        stage_of = []
        for nid in range(n):
            s = get(nid)
            if s is None:
                raise ValueError(f"stage_assignment omits node {nid} "
                                 f"({g.node(nid).name!r}) — explicit maps "
                                 "must cover every node")
            stage_of.append(int(s))
        for nid, s in enumerate(stage_of):
            if not 0 <= s < S:
                raise ValueError(f"stage_assignment maps node {nid} to "
                                 f"stage {s} outside 0..{S - 1}")
        missing = set(range(S)) - set(stage_of)
        if missing:
            raise ValueError(f"stage_assignment leaves stage(s) "
                             f"{sorted(missing)} empty")
        if not allow_backward:
            for node in g.nodes:
                for d in node.all_deps:
                    if stage_of[d] > stage_of[node.id]:
                        raise ValueError(
                            f"stage_assignment creates a backward "
                            f"cross-stage dependency: node {node.id} (stage "
                            f"{stage_of[node.id]}) depends on node {d} "
                            f"(stage {stage_of[d]})")
        return stage_of
    if assignment not in ("flops", "nodes"):
        raise ValueError(f"unknown stage assignment policy {assignment!r}: "
                         "expected 'flops', 'nodes' or an explicit map")
    if assignment == "flops":
        # +1 keeps zero-flops (comm/mem) nodes from collapsing a stage
        w = [g.node(nid).attrs.get("flops", 0.0) + 1.0 for nid in range(n)]
    else:
        w = [1.0] * n
    total = sum(w)
    stage_of = [0] * n
    s = 0
    cum = 0.0
    for idx, nid in enumerate(order):
        stage_of[nid] = s
        cum += w[nid]
        left = n - idx - 1
        if s < S - 1 and (cum >= total * (s + 1) / S
                          or left == S - 1 - s):
            s += 1
    return stage_of


def split_pipeline_stages(g: chakra.Graph, num_stages: int,
                          assignment="flops", replicas: int = 1,
                          num_microbatches: int = 1,
                          schedule: str = "gpipe",
                          virtual_stages: Optional[int] = None,
                          share_replica_graphs: Optional[bool] = None):
    """Split one workload graph into an S-stage pipeline ``MPMDProgram``.

    The graph is partitioned into `num_stages` contiguous topological
    segments (see ``_stage_assignment``); each cross-stage dependency
    u(stage i) -> v(stage j) becomes a matched **send/recv P2P-collective
    pair**: a ``COMM_COLL`` node of ``comm_kind="p2p"`` with
    ``group=[rank(i), rank(j)]`` on each side, so the MPMD engine's
    (group, program-order) barrier keying synchronizes the stages exactly
    like a FIFO channel (one pair per (producer, destination stage); the
    recv materializes the producer's ``out_bytes`` on the consumer stage).

    `replicas` data-parallel replicas of the pipeline run side by side:
    rank = stage * replicas + replica (stage-major), and every original
    collective's group is rewritten to its stage's rank set — the DP
    all-reduce of a stage spans that stage's replicas (with ``replicas=1``
    collectives become stage-local and free, modeling the repartition of
    the cluster into stages).  Returns an ``MPMDProgram`` over
    ``num_stages * replicas`` ranks whose meta records the split
    (``stage_of``, ``p2p_pairs``, ``num_stages``, ``replicas``).

    ``num_microbatches`` > 1 lowers a *microbatched* pipeline instead:
    each stage's work is replayed m times at 1/m scale under the chosen
    ``schedule`` ("gpipe", "1f1b" or "interleaved" with
    ``virtual_stages`` chunks per rank), with schedule-dependent
    send/recv ordering and synthesized backward gradient channels — see
    ``repro.core.costmodel.schedule``.  ``share_replica_graphs`` (default
    on when replicas > 1 and m > 1) makes all replicas of a stage share
    one graph via relative p2p addressing.  With m == 1 every schedule is
    equivalent (one wave) and this function emits the classic split above,
    bit-identically to previous releases.  Knob values are validated up
    front: bad ``num_microbatches``/``schedule``/``virtual_stages`` raise
    ``schedule.PipelineConfigError`` listing the valid choices.
    """
    from repro.core.costmodel.mpmd import MPMDProgram
    from repro.core.costmodel.schedule import (lower_microbatched,
                                               validate_pipeline_schedule)

    S = int(num_stages)
    R = int(replicas)
    n = len(g.nodes)
    if S < 1 or R < 1:
        raise ValueError(f"num_stages={S} / replicas={R} must be >= 1")
    if n == 0 or S > n:
        raise ValueError(f"cannot split a {n}-node graph into {S} stages")
    m, sched, v = validate_pipeline_schedule(S, num_microbatches, schedule,
                                             virtual_stages)
    if m > 1:
        return lower_microbatched(g, S, assignment, R, m, sched,
                                  virtual_stages=v,
                                  share_replica_graphs=share_replica_graphs)
    order = g.topo_order()
    stage_of = _stage_assignment(g, order, S, assignment)
    stage_ranks = {s: list(range(s * R, (s + 1) * R)) for s in range(S)}

    rank_graphs: List[Optional[chakra.Graph]] = [None] * (S * R)
    n_pairs = 0
    for d in range(R):
        sgs = [chakra.Graph(meta={**g.meta, "pipeline_stage": s,
                                  "num_stages": S, "pipeline_replica": d})
               for s in range(S)]
        local: Dict[int, tuple] = {}       # orig nid -> (stage, local nid)
        xfer: Dict[tuple, int] = {}        # (orig nid, dst stage) -> recv id
        chan: Dict[tuple, tuple] = {}      # (src, dst) -> (last send, last recv)

        def cross(dd: int, dst: int) -> int:
            key = (dd, dst)
            rv = xfer.get(key)
            if rv is None:
                src, lsrc = local[dd]
                name = g.node(dd).name
                payload = float(g.node(dd).attrs.get("out_bytes", 0.0))
                pg = [src * R + d, dst * R + d]
                # FIFO channel discipline: chain same-channel sends (and
                # recvs) with ctrl edges so both sides commit their p2p
                # collectives in creation order — the MPMD engine pairs the
                # k-th send with the k-th recv of a group, and without the
                # chain a cheap late-created send could overtake an
                # expensive earlier one and cross the wires (a consumer
                # would start before its real producer finished).  A real
                # single-channel p2p stream serializes exactly like this.
                prev_s, prev_r = chan.get((src, dst), (None, None))
                snid = sgs[src].add(
                    f"send[{name}>s{dst}]", chakra.COMM_COLL,
                    deps=[lsrc],
                    ctrl_deps=[prev_s] if prev_s is not None else [],
                    comm_kind="p2p", comm_bytes=payload, out_bytes=0.0,
                    group=pg, group_size=2, p2p_src_stage=src,
                    p2p_dst_stage=dst)
                rv = xfer[key] = sgs[dst].add(
                    f"recv[{name}<s{src}]", chakra.COMM_COLL,
                    ctrl_deps=[prev_r] if prev_r is not None else [],
                    comm_kind="p2p", comm_bytes=payload, out_bytes=payload,
                    group=pg, group_size=2, p2p_src_stage=src,
                    p2p_dst_stage=dst)
                chan[(src, dst)] = (snid, rv)
            return rv

        for nid in order:
            node = g.node(nid)
            s = stage_of[nid]
            deps_l: List[int] = []
            ctrl_l: List[int] = []
            for src_deps, out in ((node.deps, deps_l),
                                  (node.ctrl_deps, ctrl_l)):
                for dd in src_deps:
                    ds, dl = local[dd]
                    out.append(dl if ds == s else cross(dd, s))
            attrs = dict(node.attrs)
            if node.type == chakra.COMM_COLL:
                # the collective now spans this stage's replica pool
                attrs["group"] = list(stage_ranks[s])
                attrs["group_size"] = R
            local[nid] = (s, sgs[s].add(node.name, node.type,
                                        deps=list(dict.fromkeys(deps_l)),
                                        ctrl_deps=list(dict.fromkeys(ctrl_l)),
                                        **attrs))
        if d == 0:
            n_pairs = len(xfer)
        for s in range(S):
            rank_graphs[s * R + d] = sgs[s]

    return MPMDProgram(rank_graphs,
                       meta={"num_stages": S, "replicas": R,
                             "assignment": (assignment if isinstance(
                                 assignment, str) else "explicit"),
                             "stage_of": list(stage_of),
                             "p2p_pairs": n_pairs,
                             "source_nodes": n})


def expand_collective_p2p(kind: str, payload: int, group: List[int],
                          algo: str = "ring"):
    """Expand one collective into point-to-point (src, dst, bytes, round)
    messages — the Chakra representation used for custom-collective studies
    (paper SS6.2) and network emulation (SS6.3)."""
    n = len(group)
    msgs = []
    if n <= 1:
        return msgs
    if algo == "ring":
        rounds = {"all-gather": n - 1, "reduce-scatter": n - 1,
                  "all-reduce": 2 * (n - 1)}.get(kind, n - 1)
        chunk = payload / n
        for r in range(rounds):
            for i in range(n):
                msgs.append((group[i], group[(i + 1) % n], chunk, r))
    elif algo == "hd":  # recursive halving/doubling
        import math
        steps = int(math.log2(n)) if n & (n - 1) == 0 else None
        if steps is None:
            return expand_collective_p2p(kind, payload, group, "ring")
        size = payload / 2
        for s in range(steps):
            stride = 2 ** s
            for i in range(n):
                msgs.append((group[i], group[i ^ stride], size, s))
            size /= 2
    elif algo == "a2a_direct":
        chunk = payload / n
        for i in range(n):
            for j in range(n):
                if i != j:
                    msgs.append((group[i], group[j], chunk, 0))
    return msgs
