"""HLO -> Chakra conversion (Flint's Graph Converter, paper SS4.3).

Walks the scheduled post-SPMD HLO module and emits a Chakra graph whose
edges are the SSA operands — the true data dependencies.  Bookkeeping ops
(tuple/GTE/parameter/bitcast/constant) are aliased through to their
producers, matching how the paper drops FX input nodes from Chakra.

While loops (jax.lax.scan):
  * bodies containing collectives are *expanded* trip_count times, chaining
    loop-carried deps — the per-iteration collectives then appear explicitly
    (a post-execution trace would show exactly these);
  * collective-free bodies (e.g. flash-attention kv scans) are *collapsed*
    into one COMP node with flops/bytes scaled by trip count, keeping graphs
    compact without losing cost.
"""
from __future__ import annotations

from typing import Dict, List, Optional

from repro.core import chakra
from repro.core.hlo_parse import (COLLECTIVE_OPS, HloModule, Instruction,
                                  instruction_flops, parse_permute_pairs,
                                  parse_replica_groups, while_trip_count)

# ops that never become nodes: forward deps through them
_ALIAS_OPS = {"tuple", "get-tuple-element", "parameter", "bitcast",
              "constant", "iota", "partition-id", "replica-id",
              "after-all", "opt-barrier"}

_MAX_EXPAND = 128


def _computation_has_collective(mod: HloModule, comp_name: str,
                                _seen=None) -> bool:
    _seen = _seen if _seen is not None else set()
    if comp_name in _seen:
        return False
    _seen.add(comp_name)
    comp = mod.computations.get(comp_name)
    if comp is None:
        return False
    for ins in comp.instructions:
        if ins.is_collective:
            return True
        for key in ("body", "condition", "calls"):
            sub = ins.attrs.get(key, "").lstrip("%")
            if sub and _computation_has_collective(mod, sub, _seen):
                return True
    return False


def _comp_cost(mod: HloModule, comp_name: str, mult: int = 1):
    """(flops, bytes) of a computation incl. nested whiles (for collapse)."""
    comp = mod.computations.get(comp_name)
    flops = 0.0
    bytes_ = 0.0
    if comp is None:
        return flops, bytes_
    for ins in comp.instructions:
        if ins.opcode in _ALIAS_OPS:
            continue
        if ins.opcode == "while":
            body = ins.attrs.get("body", "").lstrip("%")
            cond = ins.attrs.get("condition", "").lstrip("%")
            trips = while_trip_count(mod, cond)
            f, b = _comp_cost(mod, body, 1)
            flops += f * trips
            bytes_ += b * trips
            continue
        flops += instruction_flops(mod, ins, comp_name)
        bytes_ += ins.out_bytes
        for op in ins.operands:
            src = comp.find(op)
            if src is not None:
                bytes_ += src.out_bytes
    return flops * mult, bytes_ * mult


class _Tuple:
    """Per-element dependency sets for HLO tuple values.

    Tracking tuple elements separately through while loops is what keeps
    loop-*invariant* inputs (e.g. the stacked weight tensors feeding FSDP
    all-gathers) free of false cross-iteration dependencies — the exact
    failure mode of CUDA-API-level capture the paper calls out (SS2.2)."""

    def __init__(self, elements: List[List[int]]):
        self.elements = [list(e) for e in elements]

    def flat(self) -> List[int]:
        out: List[int] = []
        for e in self.elements:
            out.extend(e)
        return list(dict.fromkeys(out))


def _flat(v) -> List[int]:
    if isinstance(v, _Tuple):
        return v.flat()
    return list(v)


class _Builder:
    def __init__(self, mod: HloModule, graph: chakra.Graph):
        self.mod = mod
        self.g = graph

    def build_computation(self, comp_name: str, param_vals=None,
                          prefix: str = ""):
        """Emit nodes for one computation instance.

        param_vals[i]: value (_Tuple or id list) backing parameter i.
        Returns the value backing the ROOT instruction."""
        comp = self.mod.computations[comp_name]
        env: Dict[str, object] = {}
        param_idx = 0
        root_val = []
        for ins in comp.instructions:
            operand_vals = [env.get(op, []) for op in ins.operands]
            dep_ids: List[int] = []
            for v in operand_vals:
                dep_ids.extend(_flat(v))
            dep_ids = list(dict.fromkeys(dep_ids))

            if ins.opcode == "parameter":
                env[ins.name] = (param_vals[param_idx]
                                 if param_vals and param_idx < len(param_vals)
                                 else [])
                param_idx += 1
            elif ins.opcode == "tuple":
                env[ins.name] = _Tuple([_flat(v) for v in operand_vals])
            elif ins.opcode == "get-tuple-element":
                idx = int(ins.attrs.get("index", "0"))
                src = operand_vals[0] if operand_vals else []
                if isinstance(src, _Tuple) and idx < len(src.elements):
                    env[ins.name] = src.elements[idx]
                else:
                    env[ins.name] = _flat(src)
            elif ins.opcode == "while":
                env[ins.name] = self._emit_while(ins, operand_vals, dep_ids,
                                                 prefix)
            elif ins.opcode in _ALIAS_OPS:
                env[ins.name] = dep_ids
            elif ins.is_collective:
                env[ins.name] = [self._emit_collective(ins, dep_ids, prefix)]
            else:
                env[ins.name] = [self._emit_comp(ins, dep_ids, prefix,
                                                 comp_name)]
            if ins.raw.strip().startswith("ROOT") or ins is comp.instructions[-1]:
                root_val = env[ins.name]
        return root_val

    def _emit_comp(self, ins: Instruction, deps, prefix, comp_name) -> int:
        flops = instruction_flops(self.mod, ins, comp_name)
        in_bytes = 0
        comp = self.mod.computations[comp_name]
        for op in ins.operands:
            src = comp.find(op)
            if src is not None:
                in_bytes += src.out_bytes
        return self.g.add(prefix + ins.name, chakra.COMP, deps=deps,
                          flops=flops, bytes=float(in_bytes + ins.out_bytes),
                          out_bytes=float(ins.out_bytes), op=ins.opcode,
                          src_op=ins.metadata_op)

    def _emit_collective(self, ins: Instruction, deps, prefix) -> int:
        kind = ins.collective_kind
        groups = parse_replica_groups(ins.attrs.get("replica_groups", ""),
                                      self.mod.num_partitions)
        comp = None
        in_bytes = 0
        for cn, c in self.mod.computations.items():
            if c.find(ins.name) is ins:
                comp = c
                break
        if comp:
            for op in ins.operands:
                src = comp.find(op)
                if src is not None:
                    in_bytes += src.out_bytes
        # comm_bytes: per-device payload (operand size; the roofline spec's
        # "sum operand sizes").  all-gather's operand is the pre-gather shard.
        payload = float(in_bytes if kind != "all-gather" else ins.out_bytes)
        attrs = dict(comm_kind=kind, comm_bytes=payload,
                     in_bytes=float(in_bytes), out_bytes=float(ins.out_bytes),
                     group_size=len(groups[0]) if groups else 1,
                     n_groups=len(groups), group=list(groups[0]) if groups else [],
                     src_op=ins.metadata_op)
        if kind == "collective-permute":
            attrs["pairs"] = parse_permute_pairs(
                ins.attrs.get("source_target_pairs", ""))
            attrs["comm_bytes"] = float(ins.out_bytes)
        return self.g.add(prefix + ins.name, chakra.COMM_COLL, deps=deps,
                          **attrs)

    def _emit_while(self, ins: Instruction, operand_vals, deps, prefix):
        body = ins.attrs.get("body", "").lstrip("%")
        cond = ins.attrs.get("condition", "").lstrip("%")
        trips = while_trip_count(self.mod, cond)
        if not _computation_has_collective(self.mod, body) or trips > _MAX_EXPAND:
            f, b = _comp_cost(self.mod, body, trips)
            nid = self.g.add(prefix + ins.name, chakra.COMP, deps=deps,
                             flops=f, bytes=b, op="while.collapsed",
                             trips=trips, src_op=ins.metadata_op)
            return [nid]
        # the loop state is a single tuple parameter; thread per-element deps
        # so loop-invariant elements don't serialize across iterations
        state = operand_vals[0] if operand_vals else []
        for t in range(trips):
            state = self.build_computation(body, [state],
                                           prefix=f"{prefix}{ins.name}.it{t}/")
        return state


def hlo_to_chakra(mod: HloModule, meta: Optional[dict] = None) -> chakra.Graph:
    g = chakra.Graph(meta={"source": "flint-jax", "entry": mod.entry,
                           "num_partitions": mod.num_partitions,
                           **(meta or {})})
    b = _Builder(mod, g)
    b.build_computation(mod.entry)
    return g


def expand_collective_p2p(kind: str, payload: int, group: List[int],
                          algo: str = "ring"):
    """Expand one collective into point-to-point (src, dst, bytes, round)
    messages — the Chakra representation used for custom-collective studies
    (paper SS6.2) and network emulation (SS6.3)."""
    n = len(group)
    msgs = []
    if n <= 1:
        return msgs
    if algo == "ring":
        rounds = {"all-gather": n - 1, "reduce-scatter": n - 1,
                  "all-reduce": 2 * (n - 1)}.get(kind, n - 1)
        chunk = payload / n
        for r in range(rounds):
            for i in range(n):
                msgs.append((group[i], group[(i + 1) % n], chunk, r))
    elif algo == "hd":  # recursive halving/doubling
        import math
        steps = int(math.log2(n)) if n & (n - 1) == 0 else None
        if steps is None:
            return expand_collective_p2p(kind, payload, group, "ring")
        size = payload / 2
        for s in range(steps):
            stride = 2 ** s
            for i in range(n):
                msgs.append((group[i], group[i ^ stride], size, s))
            size /= 2
    elif algo == "a2a_direct":
        chunk = payload / n
        for i in range(n):
            for j in range(n):
                if i != j:
                    msgs.append((group[i], group[j], chunk, 0))
    return msgs
