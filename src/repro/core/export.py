"""Per-rank Chakra ET export (paper P1: feed *external* cost models).

The SPMD capture yields one rank-symmetric graph; Chakra consumers
(ASTRA-sim, Genie, KAIDCB) want one execution trace per rank with
rank-specific collective peers.  expand_ranks() rewrites each COMM_COLL
node's group to the group containing that rank (from the compiled replica
groups) and stamps rank metadata; write_et() emits one JSON file per rank
plus a workload manifest.

Collectives can optionally be expanded to point-to-point COMM_SEND/RECV
nodes (algo="ring"/"hd") — the representation the paper uses for custom
collectives (SS6.2) and network emulation (SS6.3).
"""
from __future__ import annotations

import json
import os
from typing import List, Optional

from repro.core import chakra
from repro.core.convert import expand_collective_p2p


def _group_for_rank(node: chakra.Node, rank: int, num_ranks: int) -> List[int]:
    """Shift the canonical replica group to the one containing `rank`.

    Rank-symmetric SPMD replica groups partition the ranks with a uniform
    (stride, size) shape, so the group of `rank` preserves the canonical
    group's offsets modulo the group period."""
    g = node.attrs.get("group") or list(range(num_ranks))
    if rank in g or len(g) < 2:
        return g if rank in g else [rank]
    stride = g[1] - g[0]
    if stride == 1:
        # contiguous blocks: the group is rank's block of len(g)
        anchor = (rank // len(g)) * len(g)
        return [anchor + (m - g[0]) for m in g]
    # strided groups: members congruent to rank modulo the stride
    delta = (rank - g[0]) % stride
    return [m + delta for m in g]


def expand_ranks(g: chakra.Graph, ranks: Optional[List[int]] = None,
                 p2p_algo: Optional[str] = None) -> List[chakra.Graph]:
    """One Graph per rank with rank-local collective groups (optionally
    expanded to send/recv chains)."""
    num_ranks = int(g.meta.get("num_partitions", 1))
    ranks = ranks if ranks is not None else list(range(num_ranks))
    out = []
    for rank in ranks:
        gr = chakra.Graph(meta={**g.meta, "rank": rank})
        remap = {}
        for n in g.nodes:
            deps = [remap[d] for d in n.deps if d in remap]
            ctrl = [remap[d] for d in n.ctrl_deps if d in remap]
            if n.type == chakra.COMM_COLL:
                group = _group_for_rank(n, rank, num_ranks)
                if p2p_algo:
                    msgs = expand_collective_p2p(
                        n.attrs.get("comm_kind", "all-reduce"),
                        n.attrs.get("comm_bytes", 0.0), group, p2p_algo)
                    last = None
                    for (src, dst, size, rnd) in msgs:
                        if src != rank and dst != rank:
                            continue
                        t = chakra.COMM_SEND if src == rank else chakra.COMM_RECV
                        nid = gr.add(f"{n.name}.r{rnd}.{src}->{dst}", t,
                                     deps=deps if last is None else [last],
                                     comm_bytes=size, peer=(dst if src == rank
                                                            else src),
                                     round=rnd, parent=n.name)
                        last = nid
                    remap[n.id] = last if last is not None else gr.add(
                        n.name, chakra.MEM, deps=deps)
                    continue
                nid = gr.add(n.name, n.type, deps=deps, ctrl_deps=ctrl,
                             **{**n.attrs, "group": group})
            else:
                nid = gr.add(n.name, n.type, deps=deps, ctrl_deps=ctrl,
                             **n.attrs)
            remap[n.id] = nid
        gr.validate()
        out.append(gr)
    return out


def write_et(g: chakra.Graph, out_dir: str,
             ranks: Optional[List[int]] = None,
             p2p_algo: Optional[str] = None) -> List[str]:
    """Write one <out_dir>/rank_<r>.et.json per rank + manifest.json."""
    os.makedirs(out_dir, exist_ok=True)
    paths = []
    graphs = expand_ranks(g, ranks, p2p_algo)
    for gr in graphs:
        p = os.path.join(out_dir, f"rank_{gr.meta['rank']:05d}.et.json")
        gr.save(p)
        paths.append(p)
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump({"schema": "flint-chakra-et-v1",
                   "num_partitions": g.meta.get("num_partitions", 1),
                   "ranks": [gr.meta["rank"] for gr in graphs],
                   "totals": g.totals()}, f, indent=1)
    return paths
