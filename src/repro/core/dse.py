"""Design-space exploration loop (paper Fig 5's purple feedback arrow).

The knob space spans the paper's three layers:
  workload  -- arch, shape, parallelization (needs *recapture*)
  software  -- graph passes (reorder/bucketing), collective algorithm
  hardware  -- topology, bandwidths, chip count

explore() walks a knob grid; captures are cached by workload key (changing
only system knobs reuses the captured graph — the paper's SS4.4 workflow
distinction), cost-model evaluations are cheap.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Callable, Dict, List, Optional

from repro.core import chakra, passes
from repro.core.costmodel.simulator import SimResult, simulate
from repro.core.costmodel.topology import build_topology


@dataclasses.dataclass
class Knob:
    name: str
    values: list
    layer: str = "software"       # workload | software | hardware


@dataclasses.dataclass
class Trial:
    config: Dict
    result: SimResult
    objective: float

    def as_dict(self):
        return {"config": {k: str(v) for k, v in self.config.items()},
                "objective": self.objective, **self.result.as_dict()}


def apply_software_knobs(g: chakra.Graph, config: Dict) -> chakra.Graph:
    """Standard software-layer knobs understood by the explorer."""
    if config.get("fsdp_sync"):
        g = passes.inject_fsdp_sync(g)
    pf = config.get("prefetch")
    if pf is not None:
        g = passes.reorder_prefetch(g, prefetch=pf)
    bb = config.get("bucket_bytes")
    if bb:
        g = passes.bucket_allreduce(g, bucket_bytes=bb)
    return g


def evaluate(g: chakra.Graph, system, config: Dict) -> SimResult:
    sys2 = system
    for k in ("topology", "collective_algo", "link_bw", "dcn_bw", "chips"):
        if k in config:
            sys2 = sys2.replace(**{k: config[k]})
    g2 = apply_software_knobs(g, config)
    topo = build_topology(sys2)
    return simulate(g2, sys2, topo, algo=sys2.collective_algo)


def explore(graph_for: Callable[[Dict], chakra.Graph], system,
            knobs: List[Knob], objective: str = "total_time",
            strategy: str = "grid", budget: int = 256) -> List[Trial]:
    """graph_for(workload_config) -> Chakra graph (cached by key).

    Returns trials sorted by objective (ascending)."""
    wl_knobs = [k for k in knobs if k.layer == "workload"]
    other = [k for k in knobs if k.layer != "workload"]
    cache: Dict = {}
    trials: List[Trial] = []

    def wl_key(cfg):
        return tuple(sorted((k.name, str(cfg.get(k.name))) for k in wl_knobs))

    combos = itertools.product(*[[(k.name, v) for v in k.values]
                                 for k in knobs]) if knobs else [()]
    for combo in itertools.islice(combos, budget):
        cfg = dict(combo)
        key = wl_key(cfg)
        if key not in cache:
            cache[key] = graph_for(cfg)            # recapture only on workload change
        res = evaluate(cache[key], system, cfg)
        obj = getattr(res, objective)
        trials.append(Trial(cfg, res, obj))
    trials.sort(key=lambda t: t.objective)
    return trials


def greedy_descent(graph_for, system, knobs: List[Knob],
                   objective: str = "total_time", rounds: int = 3) -> Trial:
    """Coordinate-descent search: sweep one knob at a time, keep the best."""
    current = {k.name: k.values[0] for k in knobs}
    cache: Dict = {}

    def eval_cfg(cfg):
        key = tuple(sorted((k.name, str(cfg.get(k.name))) for k in knobs
                           if k.layer == "workload"))
        if key not in cache:
            cache[key] = graph_for(cfg)
        res = evaluate(cache[key], system, cfg)
        return Trial(dict(cfg), res, getattr(res, objective))

    best = eval_cfg(current)
    for _ in range(rounds):
        improved = False
        for k in knobs:
            for v in k.values:
                if v == current[k.name]:
                    continue
                cand = dict(current)
                cand[k.name] = v
                t = eval_cfg(cand)
                if t.objective < best.objective:
                    best, current, improved = t, cand, True
        if not improved:
            break
    return best
