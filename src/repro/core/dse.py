"""Design-space exploration loop (paper Fig 5's purple feedback arrow).

The knob space spans the paper's three layers:
  workload  -- arch, shape, parallelization (needs *recapture*)
  software  -- graph passes (reorder/bucketing), collective algorithm
  hardware  -- topology, bandwidths, chip count

explore() walks a knob grid; work is reused at every layer of the stack:

  * captures are cached by workload key (changing only system knobs reuses
    the captured graph — the paper's SS4.4 workflow distinction);
  * software-pass application is memoized by (workload key, software-knob
    tuple), so inject_fsdp_sync/reorder_prefetch/bucket_allreduce copy the
    graph once per distinct software config instead of once per trial;
  * each transformed graph is lowered once by the compiled simulator
    substrate (costmodel.compiled), so hardware-knob sweeps over one graph
    recompile nothing — per-trial cost is one event-loop replay;
  * ``explore(..., parallel=N)`` evaluates independent trials on a
    concurrent.futures thread pool (trial evaluation releases no locks and
    the caches are GIL-safe dict ops; results are identical to serial).
"""
from __future__ import annotations

import dataclasses
import itertools
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional

from repro.core import chakra, passes
from repro.core.costmodel.simulator import SimResult, simulate
from repro.core.costmodel.topology import build_topology


@dataclasses.dataclass
class Knob:
    name: str
    values: list
    layer: str = "software"       # workload | software | hardware


@dataclasses.dataclass
class Trial:
    config: Dict
    result: SimResult
    objective: float

    def as_dict(self):
        return {"config": {k: str(v) for k, v in self.config.items()},
                "objective": self.objective, **self.result.as_dict()}


_SOFTWARE_KNOBS = ("fsdp_sync", "prefetch", "bucket_bytes")
_SYSTEM_KNOBS = ("topology", "collective_algo", "link_bw", "dcn_bw", "chips")


def apply_software_knobs(g: chakra.Graph, config: Dict) -> chakra.Graph:
    """Standard software-layer knobs understood by the explorer."""
    if config.get("fsdp_sync"):
        g = passes.inject_fsdp_sync(g)
    pf = config.get("prefetch")
    if pf is not None:
        g = passes.reorder_prefetch(g, prefetch=pf)
    bb = config.get("bucket_bytes")
    if bb:
        g = passes.bucket_allreduce(g, bucket_bytes=bb)
    return g


def _sw_key(cfg: Dict) -> tuple:
    return tuple((k, str(cfg.get(k))) for k in _SOFTWARE_KNOBS)


def _system_for(system, cfg: Dict):
    for k in _SYSTEM_KNOBS:
        if k in cfg:
            system = system.replace(**{k: cfg[k]})
    return system


def _simulate_cfg(g2: chakra.Graph, system, config: Dict) -> SimResult:
    """Simulate an already-transformed graph under config's system knobs —
    the shared tail of evaluate/explore/greedy_descent."""
    sys2 = _system_for(system, config)
    topo = build_topology(sys2)
    return simulate(g2, sys2, topo, algo=sys2.collective_algo)


def evaluate(g: chakra.Graph, system, config: Dict) -> SimResult:
    return _simulate_cfg(apply_software_knobs(g, config), system, config)


def explore(graph_for: Callable[[Dict], chakra.Graph], system,
            knobs: List[Knob], objective: str = "total_time",
            strategy: str = "grid", budget: int = 256,
            parallel: Optional[int] = None) -> List[Trial]:
    """graph_for(workload_config) -> Chakra graph (cached by key).

    `parallel=N` evaluates trials on N threads (identical results, sorted
    the same; capture and pass application stay serial so graph mutation
    never races).  Returns trials sorted by objective (ascending)."""
    wl_knobs = [k for k in knobs if k.layer == "workload"]
    graph_cache: Dict = {}
    sw_cache: Dict = {}

    def wl_key(cfg):
        return tuple(sorted((k.name, str(cfg.get(k.name))) for k in wl_knobs))

    combos = itertools.product(*[[(k.name, v) for v in k.values]
                                 for k in knobs]) if knobs else [()]
    cfgs = [dict(c) for c in itertools.islice(combos, budget)]

    # serial phase: capture per distinct workload, transform per distinct
    # (workload, software) pair — both memoized
    for cfg in cfgs:
        key = wl_key(cfg)
        if key not in graph_cache:
            graph_cache[key] = graph_for(cfg)  # recapture only on wl change
        skey = (key, _sw_key(cfg))
        if skey not in sw_cache:
            sw_cache[skey] = apply_software_knobs(graph_cache[key], cfg)

    def run_trial(cfg: Dict) -> Trial:
        g2 = sw_cache[(wl_key(cfg), _sw_key(cfg))]
        res = _simulate_cfg(g2, system, cfg)
        return Trial(cfg, res, getattr(res, objective))

    if parallel and parallel > 1:
        with ThreadPoolExecutor(max_workers=parallel) as ex:
            trials = list(ex.map(run_trial, cfgs))
    else:
        trials = [run_trial(cfg) for cfg in cfgs]
    trials.sort(key=lambda t: t.objective)
    return trials


def greedy_descent(graph_for, system, knobs: List[Knob],
                   objective: str = "total_time", rounds: int = 3) -> Trial:
    """Coordinate-descent search: sweep one knob at a time, keep the best.

    Captures, software-pass applications AND full-config evaluations are
    memoized, so revisiting a config while sweeping other knobs is free."""
    current = {k.name: k.values[0] for k in knobs}
    graph_cache: Dict = {}
    sw_cache: Dict = {}
    trial_cache: Dict = {}

    def wl_key(cfg):
        return tuple(sorted((k.name, str(cfg.get(k.name))) for k in knobs
                            if k.layer == "workload"))

    def eval_cfg(cfg):
        ckey = tuple(sorted((k, str(v)) for k, v in cfg.items()))
        hit = trial_cache.get(ckey)
        if hit is not None:
            return hit
        key = wl_key(cfg)
        if key not in graph_cache:
            graph_cache[key] = graph_for(cfg)
        skey = (key, _sw_key(cfg))
        if skey not in sw_cache:
            sw_cache[skey] = apply_software_knobs(graph_cache[key], cfg)
        res = _simulate_cfg(sw_cache[skey], system, cfg)
        t = Trial(dict(cfg), res, getattr(res, objective))
        trial_cache[ckey] = t
        return t

    best = eval_cfg(current)
    for _ in range(rounds):
        improved = False
        for k in knobs:
            for v in k.values:
                if v == current[k.name]:
                    continue
                cand = dict(current)
                cand[k.name] = v
                t = eval_cfg(cand)
                if t.objective < best.objective:
                    best, current, improved = t, cand, True
        if not improved:
            break
    return best
