"""Design-space exploration loop (paper Fig 5's purple feedback arrow).

The knob space spans the paper's three layers:
  workload  -- arch, shape, parallelization (needs *recapture*)
  software  -- graph passes (reorder/bucketing), collective algorithm
  hardware  -- topology, bandwidths, chip count

explore() walks a knob grid; work is reused at every layer of the stack:

  * captures are cached by workload key (changing only system knobs reuses
    the captured graph — the paper's SS4.4 workflow distinction);
  * software-pass application is memoized by (workload key, software-knob
    tuple), so inject_fsdp_sync/reorder_prefetch/bucket_allreduce copy the
    graph once per distinct software config instead of once per trial;
  * each transformed graph is lowered once by the compiled simulator
    substrate (costmodel.compiled), so hardware-knob sweeps over one graph
    recompile nothing — per-trial cost is one event-loop replay;
  * ``explore(..., parallel=N)`` evaluates independent trials on a
    fork-based process pool (``repro.core.pool``): capture, pass
    application and graph lowering happen serially in the parent, so
    every forked worker inherits the warm caches copy-on-write and pays
    only its own event loops.  Results are bit-identical to serial and
    ordered deterministically (by trial index, never completion order).
    Platforms without fork fall back to the old GIL-bound thread pool
    with a one-shot RuntimeWarning.

``explore(strategy=...)`` is a thin adapter over the search subsystem
(``repro.search``): "grid" keeps the exhaustive walk above bit-identically,
while "random" / "bayesian" / "evolutionary" / "halving" route through
``SearchRun`` — model-guided, budgeted, seeded.  Multi-objective Pareto
searches, wall-clock budgets and JSONL checkpoint/resume live on
``SearchRun`` directly.

Heterogeneous-cluster knobs (hardware layer): ``degraded_fraction`` /
``degraded_link_scale`` (a fraction of ranks with degraded NICs),
``slow_chip_ratio`` / ``slow_chip_scale`` (a fraction of ranks from an
older/derated chip generation), ``pod_link_scale`` (the second half of the
cluster behind a degraded pod uplink) and ``cluster_ranks`` (K).  Any of
them switches the trial onto ``simulate_cluster``: the knob values build
per-rank ``RankProfile``s and the objective reads the slowest rank's step
time, so ``explore``/``greedy_descent`` sweep mixed-generation or
partially-degraded clusters exactly like any other hardware knob.

Memory-capacity knob: ``hbm_bytes`` sets the per-rank HBM capacity.  A
trial whose schedule-aware ``peak_bytes`` exceeds it raises
``OOMInfeasible`` — ``SearchRun`` records the trial as failed (excluded
from ``best`` and the Pareto front) instead of crashing the sweep, so
memory-constrained searches are just one more knob (see
``check_memory_feasible`` and ``RankProfile.hbm_bytes``).

Pipeline knobs: ``num_stages`` / ``stage_assignment`` split the
software-transformed graph into an S-stage MPMD pipeline program
(``convert.split_pipeline_stages``, memoized per graph) with
``ranks // num_stages`` data-parallel replicas per stage, evaluated on the
true-MPMD cluster engine — so stage count and stage balancing are just
more knobs on the grid, composable with the hetero hardware knobs above.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional

from repro.core import chakra, passes
from repro.core.costmodel.simulator import (SimResult, simulate,
                                            simulate_cluster)
from repro.core.costmodel.topology import (RankProfile, Topology,
                                           build_topology)


@dataclasses.dataclass
class Knob:
    name: str
    values: list
    layer: str = "software"       # workload | software | hardware


def json_value(v):
    """JSON-native view of a knob value: scalars (None/bool/int/float/str)
    pass through unchanged (numpy scalars unwrap, non-finite floats
    stringify), sequences recurse, anything else falls back to ``str`` —
    so Trial/search-checkpoint artifacts round-trip through JSON without
    the type loss the old ``str(v)`` blanket caused (``"None"``, ``"64000000.0"``)."""
    item = getattr(v, "item", None)
    if item is not None and callable(item) and not isinstance(
            v, (bool, int, float, str)):
        try:
            v = item()                   # numpy scalar -> python scalar
        except (TypeError, ValueError):
            pass
    if v is None or isinstance(v, (bool, int, str)):
        return v
    if isinstance(v, float):
        return v if math.isfinite(v) else str(v)
    if isinstance(v, (list, tuple)):
        return [json_value(x) for x in v]
    return str(v)


@dataclasses.dataclass
class Trial:
    config: Dict
    result: SimResult
    objective: float

    def as_dict(self):
        return {"config": {k: json_value(v) for k, v in self.config.items()},
                "objective": self.objective, **self.result.as_dict()}


_SOFTWARE_KNOBS = ("fsdp_sync", "prefetch", "bucket_bytes")
# pipeline knobs route the trial through the MPMD cluster engine: the
# transformed graph is split into num_stages stages (stage_assignment
# picks the balancing policy, see convert.split_pipeline_stages) with the
# cluster's ranks divided into num_stages * (ranks // num_stages);
# num_microbatches/schedule/virtual_stages pick the microbatched pipeline
# schedule (gpipe / 1f1b / interleaved, costmodel.schedule) — validated up
# front so a bad value is a diagnosable failed trial, not a crashed sweep
_PIPELINE_KNOBS = ("num_stages", "stage_assignment", "num_microbatches",
                   "schedule", "virtual_stages")
_SYSTEM_KNOBS = ("topology", "collective_algo", "link_bw", "dcn_bw", "chips")
# knobs that change the Topology object itself — a trial sweeping one of
# these must rebuild it even when the caller passed a calibrated instance
_TOPO_KNOBS = ("topology", "link_bw", "dcn_bw", "chips")
_HETERO_KNOBS = ("degraded_fraction", "degraded_link_scale",
                 "slow_chip_ratio", "slow_chip_scale", "pod_link_scale",
                 "cluster_ranks")
# reliability knobs (repro.faults): any of these present (non-None) wraps
# the trial's nominal result in a FaultSimResult carrying expected_goodput /
# p99_step_time_under_faults / makespan_inflation from a small seeded
# Monte-Carlo — composable with the hetero and pipeline knobs above
_FAULT_KNOBS = ("checkpoint_interval", "fault_rate", "spare_ranks")


class OOMInfeasible(RuntimeError):
    """A trial whose schedule-aware peak occupancy exceeds the per-rank HBM
    capacity (``hbm_bytes`` config key, cf. ``RankProfile.hbm_bytes``).

    Deliberately an *exception*, not a penalty value: ``SearchRun``'s
    failed-trial machinery records it (error string + ``FAILED_OBJECTIVE``)
    without killing the sweep, and the trial is excluded from ``best`` /
    ``full_trials`` / the Pareto front — exactly how a real cluster job
    that OOMs burns its allocation without producing a measurement."""

    def __init__(self, peak_bytes: float, capacity: float):
        self.peak_bytes = peak_bytes
        self.capacity = capacity
        super().__init__(
            f"peak occupancy {peak_bytes:.6g} B exceeds hbm_bytes "
            f"capacity {capacity:.6g} B "
            f"({peak_bytes / capacity:.2%} of HBM)")


def check_memory_feasible(res, config: Dict) -> None:
    """Raise ``OOMInfeasible`` when the trial's ``peak_bytes`` (schedule-
    aware: exact occupancy-curve max incl. transient comm buffers) exceeds
    the ``hbm_bytes`` capacity in `config`.  No capacity -> no check."""
    cap = config.get("hbm_bytes")
    if cap is not None and res.peak_bytes > cap:
        raise OOMInfeasible(res.peak_bytes, float(cap))


def rank_profiles_for(n_ranks: int, config: Dict) -> Optional[Dict]:
    """Hetero hardware knobs -> {rank: RankProfile} for simulate_cluster.

    ``slow_chip_ratio`` puts the *first* ceil(ratio*K) ranks on an older
    generation (``compute_scale = slow_chip_scale``, default 0.7);
    ``degraded_fraction`` puts the *last* ceil(fraction*K) ranks behind
    degraded links (``link_scale = degraded_link_scale``, default 0.5);
    ``pod_link_scale`` multiplies the link scale of the second half of the
    cluster (a degraded pod uplink).  Returns None when every rank is
    nominal."""
    profs: Dict[int, RankProfile] = {}

    def merge(r: int, **kw):
        p = profs.get(r, RankProfile())
        profs[r] = dataclasses.replace(p, **kw)

    ratio = config.get("slow_chip_ratio") or 0.0
    if ratio > 0.0:
        scale = config.get("slow_chip_scale", 0.7)
        for r in range(min(n_ranks, int(math.ceil(ratio * n_ranks)))):
            merge(r, compute_scale=scale)
    frac = config.get("degraded_fraction") or 0.0
    if frac > 0.0:
        scale = config.get("degraded_link_scale", 0.5)
        for r in range(max(0, n_ranks - int(math.ceil(frac * n_ranks))),
                       n_ranks):
            merge(r, link_scale=scale)
    pod = config.get("pod_link_scale")
    if pod is not None and pod != 1.0:
        for r in range(n_ranks // 2, n_ranks):
            merge(r, link_scale=profs.get(r, RankProfile()).link_scale * pod)
    return {r: p for r, p in profs.items() if not p.is_default()} or None


def _is_hetero(config: Dict) -> bool:
    """True when the config actually deviates from a homogeneous cluster —
    only then is the (un-memoized) cluster engine worth paying for.  Nominal
    values of the scale knobs (pod_link_scale=1.0, or *_scale set without
    its activating fraction/ratio) stay on the plain simulate() path, which
    is bit-identical for a symmetric cluster anyway.  An explicit
    ``cluster_ranks`` forces the cluster engine (uniform result types for a
    sweep that wants per-rank attribution on every trial)."""
    if config.get("degraded_fraction") or config.get("slow_chip_ratio"):
        return True
    pod = config.get("pod_link_scale")
    if pod is not None and pod != 1.0:
        return True
    return config.get("cluster_ranks") is not None


def apply_software_knobs(g: chakra.Graph, config: Dict) -> chakra.Graph:
    """Standard software-layer knobs understood by the explorer."""
    if config.get("fsdp_sync"):
        g = passes.inject_fsdp_sync(g)
    pf = config.get("prefetch")
    if pf is not None:
        g = passes.reorder_prefetch(g, prefetch=pf)
    bb = config.get("bucket_bytes")
    if bb:
        g = passes.bucket_allreduce(g, bucket_bytes=bb)
    return g


def _sw_key(cfg: Dict) -> tuple:
    return tuple((k, str(cfg.get(k))) for k in _SOFTWARE_KNOBS)


class GraphMemo:
    """Capture + software-pass memoization — THE shared evaluator plumbing
    of ``explore``, ``greedy_descent`` and ``repro.search.SearchRun``: one
    ``graph_for`` call per distinct workload-knob assignment, one pass
    application per distinct (workload, software-knob) pair, so every
    consumer prices identical configs against identical graphs."""

    def __init__(self, graph_for: Callable[[Dict], chakra.Graph],
                 wl_names) -> None:
        self.graph_for = graph_for
        self.wl_names = list(wl_names)
        self._graphs: Dict = {}
        self._transformed: Dict = {}

    def wl_key(self, cfg: Dict) -> tuple:
        return tuple(sorted((n, str(cfg.get(n))) for n in self.wl_names))

    def transformed(self, cfg: Dict) -> chakra.Graph:
        key = self.wl_key(cfg)
        g = self._graphs.get(key)
        if g is None:
            g = self._graphs[key] = self.graph_for(cfg)
        skey = (key, _sw_key(cfg))
        g2 = self._transformed.get(skey)
        if g2 is None:
            g2 = self._transformed[skey] = apply_software_knobs(g, cfg)
        return g2


def _system_for(system, cfg: Dict):
    for k in _SYSTEM_KNOBS:
        if k in cfg:
            system = system.replace(**{k: cfg[k]})
    return system


def _simulate_cfg(g2: chakra.Graph, system, config: Dict,
                  compute_derate: float = 0.6,
                  topo: Optional[Topology] = None) -> SimResult:
    """Simulate an already-transformed graph under config's system knobs —
    the shared tail of evaluate/explore/greedy_descent.  Hetero knobs route
    the trial to the cluster engine (objective = slowest rank's step time);
    a symmetric hetero config is bit-identical to the plain path.

    `topo` is a pre-built (e.g. trace-calibrated, see repro.trace.calibrate)
    Topology used verbatim unless the trial's config sweeps a knob that
    changes the topology itself; `compute_derate` is the calibrated flops
    efficiency."""
    sys2 = _system_for(system, config)
    if topo is None or any(k in config for k in _TOPO_KNOBS):
        topo = build_topology(sys2)
    ns = config.get("num_stages")
    if ns is not None and int(ns) > 1:
        from repro.core.convert import split_pipeline_stages
        from repro.core.costmodel.schedule import validate_pipeline_schedule
        S = int(ns)
        assign = config.get("stage_assignment") or "flops"
        # reject bad microbatch/schedule values before any splitting so a
        # sweep records a diagnostic failed trial instead of crashing
        m, sched, v = validate_pipeline_schedule(
            S, config.get("num_microbatches"), config.get("schedule"),
            config.get("virtual_stages"))
        T = int(config.get("cluster_ranks") or topo.n_ranks)
        if S > T:
            # a 16-stage pipeline on 4 chips would be priced as 16 ranks —
            # phantom hardware that would unfairly win any sweep
            raise ValueError(
                f"num_stages={S} exceeds the cluster's {T} ranks; cap the "
                "knob's values at cluster_ranks (or chips)")
        # floor division: T % S leftover ranks idle (documented; an uneven
        # split never inflates the modeled hardware)
        replicas = max(1, T // S)
        key = ("pipeline", S, str(assign), replicas, m, sched, v)
        prog = g2._cached(key, lambda: split_pipeline_stages(
            g2, S, assignment=assign, replicas=replicas,
            num_microbatches=m, schedule=sched, virtual_stages=v))
        n_ranks = prog.n_ranks
        workload = prog
        res = simulate_cluster(prog, sys2, topo, n_ranks=n_ranks,
                               rank_profiles=rank_profiles_for(n_ranks,
                                                               config),
                               algo=sys2.collective_algo,
                               compute_derate=compute_derate)
    elif _is_hetero(config):
        n_ranks = int(config.get("cluster_ranks") or topo.n_ranks)
        workload = g2
        res = simulate_cluster(g2, sys2, topo, n_ranks=n_ranks,
                               rank_profiles=rank_profiles_for(n_ranks,
                                                               config),
                               algo=sys2.collective_algo,
                               compute_derate=compute_derate)
    else:
        n_ranks = int(config.get("cluster_ranks") or topo.n_ranks)
        workload = g2
        res = simulate(g2, sys2, topo, algo=sys2.collective_algo,
                       compute_derate=compute_derate)
    # OOM feasibility gate before the (expensive) fault Monte-Carlo: an
    # infeasible trial raises, and SearchRun records it as failed
    check_memory_feasible(res, config)
    if any(config.get(k) is not None for k in _FAULT_KNOBS):
        from repro.faults.montecarlo import fault_metrics
        res = fault_metrics(workload, sys2, topo, config, res,
                            n_ranks=n_ranks,
                            rank_profiles=rank_profiles_for(n_ranks, config),
                            algo=sys2.collective_algo,
                            compute_derate=compute_derate)
    return res


def evaluate(g: chakra.Graph, system, config: Dict,
             compute_derate: float = 0.6,
             topo: Optional[Topology] = None) -> SimResult:
    return _simulate_cfg(apply_software_knobs(g, config), system, config,
                         compute_derate, topo)


_gil_pool_warned = False


def reset_pool_warning():
    """Re-arm the one-shot thread-fallback warning (test hook)."""
    global _gil_pool_warned
    _gil_pool_warned = False


def _warn_gil_fallback():
    global _gil_pool_warned
    if _gil_pool_warned:
        return
    warnings.warn(
        "explore(parallel=N): no usable fork start method on this "
        "platform — falling back to a thread pool, and trial evaluation "
        "is pure Python, so the GIL serializes it; expect no speedup "
        "over parallel=None.", RuntimeWarning, stacklevel=3)
    _gil_pool_warned = True


def explore(graph_for: Callable[[Dict], chakra.Graph], system,
            knobs: List[Knob], objective: str = "total_time",
            strategy: str = "grid", budget: int = 256,
            parallel: Optional[int] = None,
            compute_derate: float = 0.6,
            topo: Optional[Topology] = None, seed: int = 0) -> List[Trial]:
    """graph_for(workload_config) -> Chakra graph (cached by key).

    `strategy` names a registered search strategy (``repro.search``:
    "grid", "random", "bayesian", "evolutionary", "halving"); an unknown
    name raises listing the registry.  "grid" walks the exhaustive knob
    grid in declaration order exactly as it always has; every other
    strategy routes through ``repro.search.SearchRun`` with `seed` and
    returns its full-fidelity trials, budgeted to `budget` evaluations.

    `parallel=N` evaluates trials on an N-worker fork process pool
    (bit-identical results, sorted the same; capture, pass application
    and lowering stay serial in the parent so workers inherit warm
    caches and graph mutation never races).  For non-grid strategies it
    becomes ``SearchRun(jobs=N)`` — one generation of pending asks
    dispatched per pool batch.  `compute_derate`/`topo` accept
    trace-calibrated parameters (repro.trace.calibrate): pass
    ``cal.compute_derate`` and ``cal.topology`` so every trial prices
    against the fitted hardware.  Returns trials sorted by objective
    (ascending)."""
    from repro.search.space import SearchSpace
    from repro.search.strategies import STRATEGIES, available_strategies
    if strategy not in STRATEGIES:
        raise ValueError(
            f"unknown search strategy {strategy!r}: available strategies "
            f"are {available_strategies()}")
    from repro.search.objectives import sense
    s = sense(objective)             # -1 for goodput-style maximized metrics
    if strategy != "grid":
        from repro.search.run import SearchRun
        run = SearchRun(graph_for, system, knobs, strategy=strategy,
                        objectives=(objective,), budget=budget, seed=seed,
                        compute_derate=compute_derate, topo=topo,
                        jobs=parallel or 1)
        sr = run.run()
        trials = [Trial(t.config, t.result, t.objectives[objective])
                  for t in sr.full_trials]
        trials.sort(key=lambda t: s * t.objective)
        return trials

    memo = GraphMemo(graph_for,
                     [k.name for k in knobs if k.layer == "workload"])
    cfgs = list(SearchSpace.from_knobs(knobs).grid_configs(limit=budget))

    # serial phase: capture per distinct workload, transform per distinct
    # (workload, software) pair, lower each transformed graph — all
    # memoized, so pool workers fork with warm caches (copy-on-write) and
    # graph mutation never races
    from repro.core.costmodel.compiled import compile_graph
    from repro.obs import record as obs
    with obs.span("dse.precompile"):
        for cfg in cfgs:
            compile_graph(memo.transformed(cfg))

    def run_trial(cfg: Dict) -> Trial:
        obs.counter("dse.trials")
        with obs.span("dse.trial"):
            res = _simulate_cfg(memo.transformed(cfg), system, cfg,
                                compute_derate, topo)
            return Trial(cfg, res, getattr(res, objective))

    if parallel and parallel > 1:
        from repro.core import pool as _pool
        if _pool.pool_available():
            trials = []
            for cfg, (t, err) in zip(cfgs, _pool.map_fork(run_trial, cfgs,
                                                          jobs=parallel)):
                if err is not None:
                    raise RuntimeError(
                        f"explore trial {cfg!r} failed in worker: {err}")
                trials.append(t)
        else:
            _warn_gil_fallback()
            with ThreadPoolExecutor(max_workers=parallel) as ex:
                trials = list(ex.map(run_trial, cfgs))
    else:
        trials = [run_trial(cfg) for cfg in cfgs]
    trials.sort(key=lambda t: s * t.objective)
    return trials


def greedy_descent(graph_for, system, knobs: List[Knob],
                   objective: str = "total_time", rounds: int = 3,
                   compute_derate: float = 0.6,
                   topo: Optional[Topology] = None) -> Trial:
    """Coordinate-descent search: sweep one knob at a time, keep the best.

    Captures, software-pass applications AND full-config evaluations are
    memoized, so revisiting a config while sweeping other knobs is free."""
    from repro.search.objectives import sense
    s = sense(objective)
    current = {k.name: k.values[0] for k in knobs}
    memo = GraphMemo(graph_for,
                     [k.name for k in knobs if k.layer == "workload"])
    trial_cache: Dict = {}

    def eval_cfg(cfg):
        ckey = tuple(sorted((k, str(v)) for k, v in cfg.items()))
        hit = trial_cache.get(ckey)
        if hit is not None:
            return hit
        res = _simulate_cfg(memo.transformed(cfg), system, cfg,
                            compute_derate, topo)
        t = Trial(dict(cfg), res, getattr(res, objective))
        trial_cache[ckey] = t
        return t

    best = eval_cfg(current)
    for _ in range(rounds):
        improved = False
        for k in knobs:
            for v in k.values:
                if v == current[k.name]:
                    continue
                cand = dict(current)
                cand[k.name] = v
                t = eval_cfg(cand)
                if s * t.objective < s * best.objective:
                    best, current, improved = t, cand, True
        if not improved:
            break
    return best
