"""Post-SPMD HLO text parser — Flint's workload-capture substrate.

Parses `compiled.as_text()` into typed instructions with:
  * shapes/dtypes (incl. tuples), SSA operand edges (the *true* data deps)
  * collective attributes (replica groups, permute pairs, channel ids)
  * the computation call graph (while bodies/conditions, fusions, conds)
  * while-loop trip counts (XLA's cost_analysis does NOT multiply loop
    bodies by trip count — we must, or a scanned 48-layer model reports
    1 layer of FLOPs)

This is deliberately a *text* parser: it needs nothing but what
`.lower().compile()` already produced, keeping capture cluster-free (paper
P4) and independent of XLA's Python bindings.
"""
from __future__ import annotations

import dataclasses
import math
import re
from typing import Dict, List, Optional, Tuple

import numpy as np

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute", "collective-broadcast")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


_FLOAT_TYPES = {"f64", "f32", "bf16", "f16", "f8e4m3fn", "f8e5m2"}


@dataclasses.dataclass
class Shape:
    dtype: str
    dims: tuple

    @property
    def bytes(self) -> int:
        return DTYPE_BYTES.get(self.dtype, 4) * int(np.prod(self.dims)) \
            if self.dims else DTYPE_BYTES.get(self.dtype, 4)

    @property
    def tpu_bytes(self) -> int:
        """Bytes with float dtypes normalized to bf16.

        XLA:CPU upcasts bf16 GEMMs to f32 and sinks the converts *before*
        the SPMD collectives, doubling apparent wire/HBM traffic vs the TPU
        compilation of the same program (DESIGN.md SS4).  Roofline terms use
        this normalization; raw bytes are reported alongside."""
        per = DTYPE_BYTES.get(self.dtype, 4)
        if self.dtype in _FLOAT_TYPES:
            per = min(per, 2)
        return per * self.elems

    @property
    def elems(self) -> int:
        return int(np.prod(self.dims)) if self.dims else 1


def parse_shape_str(s: str) -> List[Shape]:
    """'(f32[2,3]{1,0}, bf16[4])' or 'f32[2,3]{1,0}' -> list of Shape."""
    out = []
    for m in _SHAPE_RE.finditer(s):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in DTYPE_BYTES:
            continue
        d = tuple(int(x) for x in dims.split(",")) if dims else ()
        out.append(Shape(dtype, d))
    if not out and s.strip().startswith(("f", "b", "s", "u", "p")):
        # scalar like 'f32[]'
        mm = re.match(r"(\w+)\[\]", s.strip())
        if mm:
            out.append(Shape(mm.group(1), ()))
    return out


@dataclasses.dataclass
class Instruction:
    name: str
    opcode: str
    shapes: List[Shape]            # output shape(s); tuples flattened
    operands: List[str]            # operand instruction names
    attrs: Dict[str, str]
    metadata_op: str = ""
    raw: str = ""

    @property
    def out_bytes(self) -> int:
        return sum(s.bytes for s in self.shapes)

    @property
    def out_tpu_bytes(self) -> int:
        return sum(s.tpu_bytes for s in self.shapes)

    @property
    def is_collective(self) -> bool:
        base = self.opcode.replace("-start", "").replace("-done", "")
        return base in COLLECTIVE_OPS

    @property
    def collective_kind(self) -> str:
        return self.opcode.replace("-start", "").replace("-done", "")


@dataclasses.dataclass
class Computation:
    name: str
    instructions: List[Instruction]
    is_entry: bool = False

    def find(self, name: str) -> Optional[Instruction]:
        return self._by_name.get(name)

    def __post_init__(self):
        self._by_name = {i.name: i for i in self.instructions}


@dataclasses.dataclass
class HloModule:
    name: str
    computations: Dict[str, Computation]
    entry: str
    num_partitions: int = 1

    @property
    def entry_computation(self) -> Computation:
        return self.computations[self.entry]


# instruction line:  %name = TYPE opcode(...operands...), attr=..., ...
# TYPE may be a tuple '(f32[..], ..)'; the opcode is the last word before the
# first call-paren, so match the type lazily.
_INSTR_RE = re.compile(
    r"^\s*(ROOT\s+)?%?([\w.-]+)\s*=\s*(.*?)([\w-]+)\((.*)$")


def _parse_operands(argstr: str) -> List[str]:
    """Extract %operand names from the call-args portion (up to balanced ')')."""
    out = []
    depth = 1
    i = 0
    cur = ""
    while i < len(argstr) and depth > 0:
        c = argstr[i]
        if c == "(":
            depth += 1
        elif c == ")":
            depth -= 1
            if depth == 0:
                cur += ""
                break
        cur += c
        i += 1
    for m in re.finditer(r"%([\w.-]+)", cur):
        out.append(m.group(1))
    return out, argstr[i + 1:]


def parse_hlo(text: str) -> HloModule:
    mod_name = "unknown"
    num_partitions = 1
    m = re.search(r"HloModule\s+([\w.-]+)", text)
    if m:
        mod_name = m.group(1)
    m = re.search(r"num_partitions=(\d+)", text)
    if m:
        num_partitions = int(m.group(1))

    computations: Dict[str, Computation] = {}
    entry = None
    cur_name = None
    cur_entry = False
    cur_instrs: List[Instruction] = []

    for line in text.splitlines():
        s = line.rstrip()
        st = s.strip()
        # computation header: [ENTRY] %name (args) -> type {
        hm = re.match(r"^(ENTRY\s+)?%?([\w.-]+)\s*\((.*)\)\s*->\s*.*\{\s*$", st)
        if hm and not st.startswith("%param") and "= " not in st:
            if cur_name is not None:
                computations[cur_name] = Computation(cur_name, cur_instrs,
                                                     cur_entry)
            cur_name = hm.group(2)
            cur_entry = bool(hm.group(1))
            if cur_entry:
                entry = cur_name
            cur_instrs = []
            continue
        if st == "}":
            if cur_name is not None:
                computations[cur_name] = Computation(cur_name, cur_instrs,
                                                     cur_entry)
                cur_name = None
                cur_instrs = []
            continue
        im = _INSTR_RE.match(st)
        if im and cur_name is not None:
            _, name, typestr, opcode, rest = im.groups()
            operands, tail = _parse_operands(rest)
            attrs: Dict[str, str] = {}
            for am in re.finditer(
                    r"(\w+)=((?:\{\{[^=]*?\}\})|(?:\{[^{}=]*\})|"
                    r"(?:\[[^\]=]*\](?:<=\[[^\]]*\](?:T\([\d,]+\))?)?)|"
                    r"[^,\s]+)", tail):
                attrs[am.group(1)] = am.group(2)
            meta = ""
            mm = re.search(r'op_name="([^"]*)"', tail)
            if mm:
                meta = mm.group(1)
            cur_instrs.append(Instruction(
                name=name, opcode=opcode, shapes=parse_shape_str(typestr),
                operands=operands, attrs=attrs, metadata_op=meta, raw=st))
    if cur_name is not None:
        computations[cur_name] = Computation(cur_name, cur_instrs, cur_entry)
    if entry is None:
        # fall back: the computation whose name contains 'main' or the largest
        entry = max(computations, key=lambda k: len(computations[k].instructions))
    return HloModule(mod_name, computations, entry, num_partitions)


# ---------------------------------------------------------------------------
# replica groups
# ---------------------------------------------------------------------------

def parse_replica_groups(attr: str, num_partitions: int) -> List[List[int]]:
    """'{{0,1},{2,3}}' or '[4,4]<=[16]' or '[4,4]<=[4,4]T(1,0)'."""
    if not attr:
        return [list(range(num_partitions))]
    attr = attr.strip()
    if attr.startswith("{"):
        groups = []
        for g in re.finditer(r"\{([\d,\s]+)\}", attr):
            groups.append([int(x) for x in g.group(1).split(",")])
        return groups or [list(range(num_partitions))]
    m = re.match(r"\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", attr)
    if m:
        out_shape = [int(x) for x in m.group(1).split(",")]
        in_shape = [int(x) for x in m.group(2).split(",")]
        ids = np.arange(int(np.prod(in_shape))).reshape(in_shape)
        if m.group(3):
            perm = [int(x) for x in m.group(3).split(",")]
            ids = ids.transpose(perm)
        ids = ids.reshape(out_shape)
        return [list(map(int, row)) for row in ids]
    return [list(range(num_partitions))]


def parse_permute_pairs(attr: str) -> List[Tuple[int, int]]:
    return [(int(a), int(b))
            for a, b in re.findall(r"\{(\d+),(\d+)\}", attr or "")]


# ---------------------------------------------------------------------------
# while trip counts + walking
# ---------------------------------------------------------------------------

def while_trip_count(mod: HloModule, cond_name: str) -> int:
    """Heuristic: the loop bound is the max s32 constant in the condition."""
    comp = mod.computations.get(cond_name)
    if comp is None:
        return 1
    best = 1
    for ins in comp.instructions:
        if ins.opcode == "constant" and ins.shapes and \
                ins.shapes[0].dtype in ("s32", "u32", "s64"):
            m = re.search(r"constant\((-?\d+)\)", ins.raw)
            if m:
                best = max(best, int(m.group(1)))
    return best


def walk_instructions(mod: HloModule, comp_name: Optional[str] = None,
                      multiplier: int = 1, _seen=None):
    """Yield (Instruction, multiplier, computation_name) over the entry
    computation and (recursively) while bodies, scaling by trip counts.

    Fusions are treated as leaf units (their internals never touch HBM);
    conditionals contribute each branch once (upper bound)."""
    comp_name = comp_name or mod.entry
    comp = mod.computations.get(comp_name)
    if comp is None:
        return
    for ins in comp.instructions:
        yield ins, multiplier, comp_name
        if ins.opcode == "while":
            body = ins.attrs.get("body", "").lstrip("%")
            cond = ins.attrs.get("condition", "").lstrip("%")
            trips = while_trip_count(mod, cond)
            yield from walk_instructions(mod, body, multiplier * trips)
        elif ins.opcode == "conditional":
            for key in ("true_computation", "false_computation"):
                b = ins.attrs.get(key, "").lstrip("%")
                if b:
                    yield from walk_instructions(mod, b, multiplier)
            bm = re.search(r"branch_computations=\{([^}]*)\}", ins.raw)
            if bm:
                for b in bm.group(1).split(","):
                    yield from walk_instructions(mod, b.strip().lstrip("%"),
                                                 multiplier)


# ---------------------------------------------------------------------------
# dot FLOPs
# ---------------------------------------------------------------------------

def _operand_shape(mod, comp_name, op_name) -> Optional[Shape]:
    comp = mod.computations.get(comp_name)
    ins = comp.find(op_name) if comp else None
    if ins and ins.shapes:
        return ins.shapes[0]
    return None


def dot_flops(mod: HloModule, ins: Instruction, comp_name: str) -> float:
    """2 * prod(batch) * M * N * K from operand shapes + contracting dims."""
    if not ins.shapes:
        return 0.0
    out = ins.shapes[0]
    lhs = _operand_shape(mod, comp_name, ins.operands[0]) if ins.operands else None
    if lhs is None:
        return 0.0
    lc = [int(x) for x in re.findall(
        r"\d+", ins.attrs.get("lhs_contracting_dims", ""))]
    k = int(np.prod([lhs.dims[i] for i in lc])) if lc else 1
    return 2.0 * out.elems * k


def instruction_flops(mod: HloModule, ins: Instruction, comp_name: str) -> float:
    if ins.opcode == "dot":
        return dot_flops(mod, ins, comp_name)
    if ins.opcode == "fusion":
        # dots are never fused into loop fusions by XLA:CPU/TPU at the top
        # level except as output fusions named *dot*; approximate via name
        if "dot" in ins.name or "matmul" in ins.name or "conv" in ins.name:
            called = ins.attrs.get("calls", "").lstrip("%")
            sub = mod.computations.get(called)
            if sub:
                return sum(dot_flops(mod, i, called)
                           for i in sub.instructions if i.opcode == "dot")
        return 0.0
    if ins.opcode == "convolution":
        out = ins.shapes[0] if ins.shapes else None
        return 2.0 * out.elems if out else 0.0
    return 0.0
