"""Chakra graph passes — the DSE transforms of paper SS2.2/SS6.1.

All passes preserve data deps (`deps`); they only add/remove/retarget
control deps (`ctrl_deps`) or merge COMM nodes whose data deps allow it.
That invariant is what compiler-IR capture buys us: CUDA-API traces can't
tell which edges are droppable (paper Fig 3b).

  inject_fsdp_sync   -- model the *original* FSDP schedule: each weight
                        all-gather waits for the previous layer's compute
                        (bounds live memory, exposes communication).
  reorder_prefetch   -- SimpleFSDP-style reordering: retarget each
                        all-gather's ctrl dep k layers earlier so it overlaps
                        with earlier compute (costs memory: weights live
                        longer).
  bucket_allreduce   -- DDP gradient bucketing: merge small all-reduces into
                        fewer, larger ones (latency amortization).
"""
from __future__ import annotations

import bisect
from typing import List, Optional, Tuple

from repro.core import chakra


def _comm_nodes(g: chakra.Graph, kind: str) -> List[chakra.Node]:
    return [n for n in g.by_type(chakra.COMM_COLL)
            if n.attrs.get("comm_kind") == kind]


def _scan_indices(g: chakra.Graph,
                  kind: str) -> Tuple[List[chakra.Node], List[int]]:
    """One pass over g.nodes: (`kind` collectives in id order, COMP-node ids
    in program order).  Replaces the per-pass by_type + comprehension
    rescans; both outputs are ascending in id by construction."""
    comms: List[chakra.Node] = []
    comps: List[int] = []
    for n in g.nodes:
        t = n.type
        if t == chakra.COMM_COLL:
            if n.attrs.get("comm_kind") == kind:
                comms.append(n)
        elif t == chakra.COMP and n.attrs.get("flops", 0) > 0:
            comps.append(n.id)
    return comms, comps


def _last_comp_before(comps: List[int], nid: int) -> Optional[int]:
    """Last compute id < nid (comps ascending), or None."""
    i = bisect.bisect_left(comps, nid)
    return comps[i - 1] if i else None


def inject_fsdp_sync(g: chakra.Graph, kind: str = "all-gather") -> chakra.Graph:
    """Serialize each `kind` collective after the previous one's consumers'
    compute — the sync edges the original FSDP runtime adds (Fig 3b top)."""
    g = g.copy()
    comms, comps = _scan_indices(g, kind)
    for i, c in enumerate(comms):
        if i == 0:
            continue
        # the last compute node that appears before this collective
        prior = _last_comp_before(comps, c.id)
        if prior is not None:
            c.ctrl_deps.append(prior)
    g.meta["pass.fsdp_sync"] = True
    g.validate()
    return g


def reorder_prefetch(g: chakra.Graph, prefetch: int = 2,
                     kind: str = "all-gather") -> chakra.Graph:
    """Retarget each `kind` collective's ctrl deps `prefetch` collectives
    earlier (Fig 3b bottom).  prefetch >= len(comms) removes all sync edges."""
    g = g.copy()
    comms, comps = _scan_indices(g, kind)
    for i, c in enumerate(comms):
        c.ctrl_deps = []
        j = i - prefetch
        if j >= 0:
            prior = _last_comp_before(comps, comms[j].id)
            if prior is not None:
                c.ctrl_deps.append(prior)
    g.meta["pass.reorder_prefetch"] = prefetch
    g.invalidate_caches()        # ctrl retargeting can preserve edge counts
    g.validate()
    return g


def bucket_allreduce(g: chakra.Graph, bucket_bytes: float = 32e6,
                     kind: str = "all-reduce") -> chakra.Graph:
    """Merge consecutive small `kind` collectives into buckets.

    The merged node depends on the union of member data deps; members'
    consumers are redirected to the bucket (correct because all members'
    payloads become available together)."""
    g2 = g.copy()
    order = g2.topo_order()
    pos = {nid: i for i, nid in enumerate(order)}
    comms = sorted((n for n in _comm_nodes(g2, kind)), key=lambda n: pos[n.id])
    if not comms:
        return g2

    # ancestry among candidate collectives: merging A and B where A is an
    # ancestor of B would create a cycle (A -> ... -> B's dep -> bucket -> A)
    member_ids = {n.id for n in comms}
    anc: dict = {}
    for nid in order:
        s = set()
        for d in g2.node(nid).all_deps:
            s |= anc.get(d, set())
            if d in member_ids:
                s.add(d)
        anc[nid] = s

    buckets: List[List[chakra.Node]] = [[]]
    acc = 0.0
    for c in comms:
        b = c.attrs.get("comm_bytes", 0.0)
        conflict = any(m.id in anc[c.id] for m in buckets[-1])
        if buckets[-1] and (acc + b > bucket_bytes or conflict):
            buckets.append([])
            acc = 0.0
        buckets[-1].append(c)
        acc += b

    replaced = {}
    for bucket in buckets:
        if len(bucket) <= 1:
            continue
        deps = sorted({d for n in bucket for d in n.deps})
        ctrl = sorted({d for n in bucket for d in n.ctrl_deps})
        payload = sum(n.attrs.get("comm_bytes", 0.0) for n in bucket)
        nid = g2.add(f"bucket[{len(bucket)}]{kind}", chakra.COMM_COLL,
                     deps=deps, ctrl_deps=ctrl, comm_kind=kind,
                     comm_bytes=payload,
                     group_size=bucket[0].attrs.get("group_size", 1),
                     n_groups=bucket[0].attrs.get("n_groups", 1),
                     bucketed=len(bucket))
        for n in bucket:
            replaced[n.id] = nid

    if not replaced:
        return g2
    # redirect consumers, neutralize replaced nodes
    for n in g2.nodes:
        if n.id in replaced or n.id in set(replaced.values()):
            continue
        n.deps = sorted({replaced.get(d, d) for d in n.deps})
        n.ctrl_deps = sorted({replaced.get(d, d) for d in n.ctrl_deps
                              if replaced.get(d, d) != n.id})
    for old in replaced:
        n = g2.node(old)
        n.type = chakra.MEM
        n.attrs = {"merged_into": replaced[old], "comm_bytes": 0.0,
                   "bytes": 0.0, "flops": 0.0}
        n.deps, n.ctrl_deps = [], []
    g2.meta["pass.bucket_allreduce"] = bucket_bytes
    g2.validate()
    return g2


def strip_ctrl_deps(g: chakra.Graph) -> chakra.Graph:
    """Pure data-dependency view (what compiler-IR capture uniquely gives)."""
    g = g.copy()
    for n in g.nodes:
        n.ctrl_deps = []
    g.meta["pass.strip_ctrl"] = True
    return g
