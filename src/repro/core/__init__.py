# Flint core: compiler-IR workload capture -> Chakra graphs -> cost models -> DSE.
from repro.core import chakra, passes
from repro.core.capture import (capture_step, CaptureResult, summarize_module,
                                stablehlo_op_counts)
from repro.core.convert import hlo_to_chakra, expand_collective_p2p
from repro.core.export import expand_ranks, write_et
from repro.core.hlo_parse import parse_hlo, HloModule

__all__ = ["chakra", "passes", "capture_step", "CaptureResult",
           "summarize_module", "stablehlo_op_counts", "hlo_to_chakra",
           "expand_collective_p2p", "expand_ranks", "write_et",
           "parse_hlo", "HloModule"]
