"""Microbatched pipeline schedules: GPipe / 1F1B / interleaved lowering.

``convert.split_pipeline_stages`` historically emitted one forward/backward
wave per step, so the fill/drain bubble that dominates real pipelines was
invisible to the DSE — ``num_stages`` traded comm for stage imbalance only.
This module lowers any SPMD graph into per-stage, per-microbatch graph
segments with schedule-dependent send/recv ordering, emitted as a plain
``MPMDProgram`` the PR-5 cluster engine prices with no special casing.

The lowering
------------
The graph is partitioned into ``num_stages * virtual_stages`` contiguous
topological segments (``convert._stage_assignment``).  Each segment is cut
into a forward part (the topo prefix through the last node with a
cross-segment consumer, extended until it holds at least ``fwd_fraction``
of the segment's flops) and a backward part (the remaining suffix — by
construction it has no cross-segment consumers, so replaying it late never
violates a data dependency).  Each (virtual stage, microbatch, phase)
becomes a *task*: a copy of the part with flops/bytes/payloads scaled by
1/m, so total work is conserved exactly for every schedule.

Per rank, tasks are serialized by a chain of zero-cost ``sched[...]`` join
nodes in the order ``schedule_tasks`` dictates — that chain IS the
schedule.  Cross-stage forward dependencies become per-microbatch p2p
send/recv pairs (sends are fire-and-forget: they don't hold the join, so a
stage can run ahead like a real buffered channel; recvs post when the rank
reaches the task, giving rendezvous semantics).  For a *forward-only*
graph (no consumer in a lower stage), every forward channel gets a
synthesized backward *gradient* channel in the opposite direction
(payload = the channel's per-microbatch forward bytes): B(s, j) cannot
start before B(s+1, j)'s grad arrives, which is exactly what creates the
drain bubble.  A graph with explicit backward edges models its own grad
flow and gets no synthesized channels — its backward cross-stage edges
become ordinary data channels.  With zero-cost comm the simulated aggregate bubble fraction
is the textbook (p-1)/(m+p-1) for GPipe and 1F1B, and 1F1B's peak
activation stash is min(m, p-s) per-microbatch activations vs GPipe's m —
both verified by tests/test_schedule_analytics.py against the engine and
the PR-9 memory timeline.

Channel identity
----------------
Several p2p channels can share one rank pair (forward and grad between the
same stages; multiple virtual-stage chunks).  Each p2p node therefore
carries a ``p2p_channel`` attr and the MPMD engine keys its FIFO barrier
sequences on (group, channel), so the k-th send always meets the k-th recv
*of its own channel* — without this, a grad send could silently pair with
a forward send under 1F1B's interleaved orders.

Cross-replica graph sharing
---------------------------
Replicas of a stage differ only in their p2p partner ranks.  With
``share_replica_graphs`` (the default when replicas > 1) each stage is
built ONCE and shared by all its replicas: p2p nodes carry relative stage
addressing (``p2p_src_stage``/``p2p_dst_stage`` + the program-level
``p2p_replicas`` meta) that ``simulate_mpmd`` expands into per-replica
barrier instances — so an R-replica pipeline costs ``num_stages`` compiled
graphs and (when symmetric) ``num_stages`` event-loop rows instead of
``num_stages * R``.
"""
from __future__ import annotations

import math
from typing import Dict, List, Optional, Tuple

from repro.core import chakra

SCHEDULES = ("gpipe", "1f1b", "interleaved")

#: node attrs scaled by 1/num_microbatches when a node is replicated into
#: per-microbatch task copies (total work conservation)
_SCALED_ATTRS = ("flops", "bytes", "in_bytes", "out_bytes", "comm_bytes")


class PipelineConfigError(ValueError):
    """Invalid pipeline-schedule knob values (bad ``num_microbatches`` /
    ``schedule`` / ``virtual_stages``).  A ``ValueError`` so DSE sweeps
    record it as a failed trial instead of crashing."""


def validate_pipeline_schedule(num_stages, num_microbatches=None,
                               schedule=None, virtual_stages=None
                               ) -> Tuple[int, str, int]:
    """Validate and normalize the schedule knobs; returns ``(m, schedule,
    virtual_stages)``.

    Raises ``PipelineConfigError`` (a ``ValueError``) listing the valid
    choices for: non-integer or < 1 microbatch counts, unknown schedule
    names, ``interleaved`` microbatch counts not divisible by the stage
    count, and virtual-stage counts on non-interleaved schedules.  A
    single microbatch (m=1) is scheduling-free, so every schedule is
    accepted there and lowers to the classic one-wave split."""
    p = int(num_stages)
    m = 1 if num_microbatches is None else num_microbatches
    try:
        mi = int(m)
    except (TypeError, ValueError):
        mi = -1
    if mi != m or mi < 1:
        raise PipelineConfigError(
            f"num_microbatches={m!r} is invalid: expected an integer >= 1")
    m = mi
    sched = "gpipe" if schedule is None else str(schedule).lower()
    if sched not in SCHEDULES:
        raise PipelineConfigError(
            f"unknown schedule {schedule!r}: valid schedules are "
            f"{list(SCHEDULES)}")
    if virtual_stages is None:
        v = 2 if (sched == "interleaved" and m > 1) else 1
    else:
        v = int(virtual_stages)
        if v < 1:
            raise PipelineConfigError(
                f"virtual_stages={virtual_stages!r} must be an integer >= 1")
        if v > 1 and sched != "interleaved":
            raise PipelineConfigError(
                f"virtual_stages={v} needs schedule='interleaved': "
                f"{sched!r} runs one chunk per stage rank")
    if sched == "interleaved" and m > 1 and p > 1 and m % p != 0:
        raise PipelineConfigError(
            f"schedule='interleaved' needs num_microbatches divisible by "
            f"num_stages: {m} % {p} != 0 (valid counts: "
            f"{p}, {2 * p}, {3 * p}, ...)")
    return m, sched, v


def schedule_tasks(schedule: str, p: int, s: int, m: int,
                   v: int = 1) -> List[Tuple[str, int, int]]:
    """Execution order of stage rank ``s``'s tasks as ``(phase, chunk, j)``
    triples, phase in {"F", "B"}, chunk the virtual-stage index on this
    rank (virtual stage ``chunk * p + s``), ``j`` the microbatch.

    * ``gpipe``: all m forwards, then all m backwards.
    * ``1f1b``: ``min(m, p - s)`` warmup forwards, then strictly
      alternating B(j)/F(·) (backwards in ascending j), then cooldown
      backwards — the steady state keeps at most ``p - s`` live stashes.
    * ``interleaved``: looped GPipe over ``v`` chunks — forwards chunk-
      ascending, backwards chunk-descending (matching grad flow), each
      j-ascending so per-channel FIFO order is schedule-independent.
    """
    if schedule == "interleaved":
        tasks = [("F", c, j) for c in range(v) for j in range(m)]
        tasks += [("B", c, j) for c in range(v - 1, -1, -1)
                  for j in range(m)]
        return tasks
    if schedule == "1f1b":
        w = min(m, max(1, p - s))
        tasks = [("F", 0, j) for j in range(w)]
        nb = 0
        for j in range(w, m):
            tasks.append(("B", 0, nb))
            nb += 1
            tasks.append(("F", 0, j))
        while nb < m:
            tasks.append(("B", 0, nb))
            nb += 1
        return tasks
    # gpipe
    return ([("F", 0, j) for j in range(m)]
            + [("B", 0, j) for j in range(m)])


def analytic_bubble_fraction(p: int, m: int) -> float:
    """The textbook GPipe/1F1B pipeline bubble fraction (p-1)/(m+p-1)."""
    return (p - 1) / (m + p - 1) if (m + p - 1) > 0 else 0.0


def bubble_fraction(result) -> float:
    """Aggregate pipeline-bubble fraction of a sim result: the fraction of
    cluster rank-seconds not spent in compute, ``1 - sum(rank compute
    busy) / (K * makespan)``.  With zero-cost comm this equals the
    analytic (p-1)/(m+p-1) for GPipe and 1F1B; with real comm it also
    absorbs exposed communication (an upper bound on the pure schedule
    bubble).  Accepts ``ClusterSimResult`` or a single-rank ``SimResult``.
    """
    rank_times = getattr(result, "rank_times", None)
    step = float(result.step_time if rank_times is not None
                 else result.total_time)
    if step <= 0.0:
        return 0.0
    if rank_times is None:
        return max(0.0, 1.0 - result.compute_time / step)
    K = len(rank_times)
    busy = math.fsum(result.rank_result(r).compute_time for r in range(K))
    return max(0.0, 1.0 - busy / (K * step))


def _fb_cut(g: chakra.Graph, nodes_k: List[int], ext: List[bool],
            fwd_fraction: float) -> int:
    """Forward/backward cut index of one stage segment (local topo order):
    after the last node with a cross-segment consumer (those must replay
    in the forward task — a backward part never feeds a later stage), then
    extended until the forward part holds >= ``fwd_fraction`` of segment
    flops.  Always >= 1; == len(nodes) means an empty backward part."""
    last_ext = -1
    total = 0.0
    for i, u in enumerate(nodes_k):
        total += float(g.node(u).attrs.get("flops", 0.0))
        if ext[u]:
            last_ext = i
    cut = max(1, last_ext + 1)
    cum = 0.0
    for i, u in enumerate(nodes_k):
        cum += float(g.node(u).attrs.get("flops", 0.0))
        if i + 1 >= cut and cum >= fwd_fraction * total:
            return i + 1
    return len(nodes_k)


def lower_microbatched(g: chakra.Graph, num_stages: int, assignment,
                       replicas: int, num_microbatches: int, schedule: str,
                       virtual_stages: int = 1,
                       share_replica_graphs: Optional[bool] = None,
                       fwd_fraction: float = 1.0 / 3.0):
    """Lower one SPMD graph into a microbatched pipeline ``MPMDProgram``
    (see module docstring).  Called by ``convert.split_pipeline_stages``
    when ``num_microbatches > 1``; knob values must already be validated
    (``validate_pipeline_schedule``)."""
    from repro.core.convert import _stage_assignment
    from repro.core.costmodel.mpmd import MPMDProgram

    p = int(num_stages)
    R = int(replicas)
    m = int(num_microbatches)
    v = int(virtual_stages)
    P = p * v
    n = len(g.nodes)
    if p < 1 or R < 1:
        raise ValueError(f"num_stages={p} / replicas={R} must be >= 1")
    if n == 0 or P > n:
        raise ValueError(f"cannot split a {n}-node graph into {P} "
                         f"(num_stages * virtual_stages) segments")
    share = (R > 1) if share_replica_graphs is None else \
        bool(share_replica_graphs)
    rel = share and R > 1

    order = g.topo_order()
    vstage_of = _stage_assignment(g, order, P, assignment,
                                  allow_backward=True)
    seg: List[List[int]] = [[] for _ in range(P)]
    for nid in order:
        seg[vstage_of[nid]].append(nid)
    cons: List[List[int]] = [[] for _ in range(n)]
    for node in g.nodes:
        for dd in node.all_deps:
            cons[dd].append(node.id)
    ext = [any(vstage_of[c] != vstage_of[u] for c in cons[u])
           for u in range(n)]
    # only consumers in HIGHER vstages force a node into the forward part:
    # a node consumed by a lower vstage is backward-pass structure the
    # source graph models explicitly, and belongs in the backward part
    ext_fwd = [any(vstage_of[c] > vstage_of[u] for c in cons[u])
               for u in range(n)]

    part_of: List[Tuple[List[int], List[int]]] = []
    phase_of: Dict[int, str] = {}
    for k in range(P):
        cut = _fb_cut(g, seg[k], ext_fwd, fwd_fraction)
        fp, bp = seg[k][:cut], seg[k][cut:]
        part_of.append((fp, bp))
        for u in fp:
            phase_of[u] = "F"
        for u in bp:
            phase_of[u] = "B"
    has_bwd = any(bp for _fp, bp in part_of)

    # cross-rank data transfers, grouped per directed channel (src vstage,
    # src phase, dst vstage, dst phase) in topo order of the producer —
    # the one FIFO order both endpoints emit their p2p ops in.  The recv
    # posts in the dst vstage's earliest consuming phase (F before B on
    # every schedule, so "F wins"); later same-vstage consumers reference
    # that one recv.  Keying on the src phase too keeps a channel's sends
    # inside same-phase tasks, whose j-ascending order matches the recvs'.
    xfers: Dict[Tuple[int, str, int, str], List[int]] = {}
    for k in range(P):
        for u in seg[k]:
            if not ext[u]:
                continue
            dst_phase: Dict[int, str] = {}
            for c in cons[u]:
                kc = vstage_of[c]
                if kc == k:
                    continue
                if dst_phase.get(kc) != "F":   # F consumer wins (runs first)
                    dst_phase[kc] = phase_of[c]
            for kc in sorted(dst_phase):
                if kc % p == k % p:            # same rank: direct reference
                    continue
                xfers.setdefault((k, phase_of[u], kc, dst_phase[kc]),
                                 []).append(u)

    # synthesized backward grad channels — one per cross-rank forward
    # vstage pair, payload = the pair's per-microbatch forward bytes —
    # model the missing backward pass of forward-only graphs.  A graph
    # with any backward cross-stage edge (a consumer in a LOWER vstage)
    # models its own backward pass: synthesizing a second grad wave on
    # top would manufacture a dependency cycle, so trust the graph.
    has_explicit_bwd = any(a > b for (a, _sp, b, _dp) in xfers)
    synth_grads = has_bwd and not has_explicit_bwd
    grad_payload: Dict[Tuple[int, int], float] = {}
    if synth_grads:
        for (a, _sp, b, _dp), us in xfers.items():
            grad_payload[(a, b)] = grad_payload.get((a, b), 0.0) + math.fsum(
                float(g.node(u).attrs.get("out_bytes", 0.0)) / m for u in us)
    fwd_pairs = sorted(grad_payload)

    # per-(vstage, phase) sink nodes (no consumer inside the same part):
    # the dependency anchor of the part's grad send
    sinks: Dict[Tuple[int, str], List[int]] = {}
    for k in range(P):
        for ph, nodes_ in (("F", part_of[k][0]), ("B", part_of[k][1])):
            sinks[(k, ph)] = [
                u for u in nodes_
                if not any(vstage_of[c] == k and phase_of[c] == ph
                           for c in cons[u])]

    stage_ranks = {st: list(range(st * R, (st + 1) * R)) for st in range(p)}
    chan_keys = sorted(xfers, key=repr)
    n_pairs = 0

    def build_rank_graph(s: int, d: int) -> chakra.Graph:
        nonlocal n_pairs
        sg = chakra.Graph(meta={**g.meta, "pipeline_stage": s,
                                "num_stages": p, "pipeline_replica": d,
                                "num_microbatches": m, "schedule": schedule,
                                "virtual_stages": v,
                                **({"p2p_replicas": R} if rel else {})})
        local: Dict[Tuple[int, int], int] = {}    # (orig nid, j) -> local id
        recv_of: Dict[Tuple[int, int, int], int] = {}
        chain: Dict[Tuple, int] = {}              # (channel, side) -> last id
        prev_join: Optional[int] = None
        n_sends = 0

        def p2p_attrs(src_vs: int, dst_vs: int, channel: tuple,
                      payload: float, out_b: float) -> dict:
            return dict(comm_kind="p2p", comm_bytes=payload,
                        out_bytes=out_b,
                        group=[(src_vs % p) * R + d, (dst_vs % p) * R + d],
                        group_size=2, p2p_src_stage=src_vs % p,
                        p2p_dst_stage=dst_vs % p, p2p_channel=list(channel))

        for phase, c, j in schedule_tasks(schedule, p, s, m, v):
            k = c * p + s
            part = part_of[k][0] if phase == "F" else part_of[k][1]
            members: set = set()
            grad_recvs: List[int] = []

            # task-entry recvs, in the channel's canonical xfer order
            for ck in chan_keys:
                a, sph, b, dph = ck
                if b != k or dph != phase:
                    continue
                channel = ("d",) + ck
                for u in xfers[ck]:
                    payload = float(
                        g.node(u).attrs.get("out_bytes", 0.0)) / m
                    prev_r = chain.get((channel, "r"))
                    ctrl = [x for x in (prev_r, prev_join) if x is not None]
                    rv = sg.add(
                        f"recv[{g.node(u).name}@{phase.lower()}{j}<v{a}]",
                        chakra.COMM_COLL, ctrl_deps=ctrl,
                        **p2p_attrs(a, b, channel, payload, payload))
                    chain[(channel, "r")] = rv
                    recv_of[(u, k, j)] = rv
                    members.add(rv)
            if phase == "B" and synth_grads:
                for a, b in fwd_pairs:
                    if a != k:
                        continue
                    channel = ("g", b, a)
                    prev_r = chain.get((channel, "r"))
                    ctrl = [x for x in (prev_r, prev_join) if x is not None]
                    payload = grad_payload[(a, b)]
                    rv = sg.add(f"grad_recv[v{a}@b{j}<v{b}]",
                                chakra.COMM_COLL, ctrl_deps=ctrl,
                                **p2p_attrs(b, a, channel, payload, payload))
                    chain[(channel, "r")] = rv
                    members.add(rv)
                    grad_recvs.append(rv)

            # the part's nodes, scaled 1/m
            for u in part:
                node = g.node(u)
                deps_l: List[int] = []
                ctrl_l: List[int] = []
                for src_list, out in ((node.deps, deps_l),
                                      (node.ctrl_deps, ctrl_l)):
                    for dd in src_list:
                        kd = vstage_of[dd]
                        try:
                            if kd == k or kd % p == s:
                                out.append(local[(dd, j)])
                            else:
                                out.append(recv_of[(dd, k, j)])
                        except KeyError:
                            raise ValueError(
                                f"graph is not pipelineable under schedule="
                                f"{schedule!r}: node {node.name!r} (vstage "
                                f"{k}) consumes {g.node(dd).name!r} (vstage "
                                f"{kd}) before any task of this rank "
                                f"produced it — a dependency against the "
                                f"stage/chunk execution order") from None
                in_task = any(x in members for x in deps_l + ctrl_l)
                if not in_task:
                    # a task root: gate on the grads this stage is owed
                    # (the drain wave) and on the schedule's task chain
                    deps_l.extend(grad_recvs)
                    if not grad_recvs and prev_join is not None:
                        ctrl_l.append(prev_join)
                attrs = dict(node.attrs)
                for f_ in _SCALED_ATTRS:
                    if f_ in attrs:
                        attrs[f_] = float(attrs[f_]) / m
                if node.type == chakra.COMM_COLL:
                    attrs["group"] = list(stage_ranks[s])
                    attrs["group_size"] = R
                lid = sg.add(f"{node.name}@{phase.lower()}{j}", node.type,
                             deps=list(dict.fromkeys(deps_l)),
                             ctrl_deps=list(dict.fromkeys(ctrl_l)), **attrs)
                local[(u, j)] = lid
                members.add(lid)

            # task-exit sends (eager / fire-and-forget: not joined)
            for ck in chan_keys:
                a, sph, b, dph = ck
                if a != k or sph != phase:
                    continue
                channel = ("d",) + ck
                for u in xfers[ck]:
                    payload = float(
                        g.node(u).attrs.get("out_bytes", 0.0)) / m
                    prev_s = chain.get((channel, "s"))
                    sn = sg.add(
                        f"send[{g.node(u).name}@{phase.lower()}{j}>v{b}]",
                        chakra.COMM_COLL, deps=[local[(u, j)]],
                        ctrl_deps=[prev_s] if prev_s is not None else [],
                        p2p_eager=True,
                        **p2p_attrs(a, b, channel, payload, 0.0))
                    chain[(channel, "s")] = sn
                    n_sends += 1
            if phase == "B" and synth_grads:
                for a, b in fwd_pairs:
                    if b != k:
                        continue
                    channel = ("g", b, a)
                    anchor = ([local[(u, j)] for u in sinks[(k, "B")]]
                              or grad_recvs)
                    prev_s = chain.get((channel, "s"))
                    ctrl = [prev_s] if prev_s is not None else []
                    if not anchor and prev_join is not None:
                        ctrl.append(prev_join)
                    sn = sg.add(f"grad_send[v{k}@b{j}>v{a}]",
                                chakra.COMM_COLL, deps=anchor,
                                ctrl_deps=ctrl, p2p_eager=True,
                                **p2p_attrs(b, a, channel,
                                            grad_payload[(a, b)], 0.0))
                    chain[(channel, "s")] = sn
                    n_sends += 1

            # the schedule join: the rank leaves this task only when all
            # its (non-send) work and recvs have completed
            prev_join = sg.add(
                f"sched[s{s}:{phase}{c}.{j}]", chakra.COMP,
                deps=sorted(members),
                ctrl_deps=[prev_join] if prev_join is not None else [],
                flops=0.0, bytes=0.0, out_bytes=0.0, sched_join=True)
        if d == 0:
            n_pairs += n_sends
        return sg

    rank_graphs: List[Optional[chakra.Graph]] = [None] * (p * R)
    if rel:
        for s in range(p):
            sg = build_rank_graph(s, 0)
            for d in range(R):
                rank_graphs[s * R + d] = sg
    else:
        for d in range(R):
            for s in range(p):
                rank_graphs[s * R + d] = build_rank_graph(s, d)

    meta = {"num_stages": p, "replicas": R,
            "assignment": (assignment if isinstance(assignment, str)
                           else "explicit"),
            "stage_of": list(vstage_of), "p2p_pairs": n_pairs,
            "source_nodes": n, "num_microbatches": m,
            "schedule": schedule, "virtual_stages": v}
    if rel:
        meta["p2p_replicas"] = R
    return MPMDProgram(rank_graphs, meta=meta)
