"""Compiled graph substrate: lower a chakra.Graph once, simulate many times.

``CompiledGraph`` flattens the Python ``Node`` objects into flat columns —
NumPy attribute arrays plus adjacency with both a NumPy CSR view and
Python-list mirrors.  The event loop runs on the list mirrors (element-wise
indexing of small Python lists beats NumPy scalar indexing by ~5x); the CSR
arrays are materialized lazily on first access for exporters/array-level
consumers:

  type_code[n]     0=COMP 1=COMM_COLL 2=COMM_SEND 3=COMM_RECV 4=MEM
  is_comm[n]       1 for the three COMM_* codes (busy-time accounting key)
  pos[n]           position of node n in the cached topological order
  flops/bytes/comm_bytes/out_bytes[n]
                   float64 attribute columns (absent attr -> 0.0)
  dep_indptr/dep_indices        dedup'd union of deps+ctrl_deps, CSR (lazy)
  ddep_indptr/ddep_indices      dedup'd *data* deps only, CSR (lazy)
  cons_indptr/cons_indices      dedup'd consumers (reverse adjacency, lazy)

Per-node durations depend on (system, topology, algo, derate), so they are
memoized separately in ``durations()`` keyed by the reprs of those frozen /
dataclass objects — a hardware sweep over one graph recompiles nothing and a
duration-only sweep (stragglers) reuses both structure and base durations.

``run()`` replays *exactly* the reference event-driven list-scheduling
algorithm in ``simulator._simulate_reference`` (same priorities, same
tie-breaking, same float accumulation order), so its ``SimResult`` is
bit-identical — equivalence is enforced by tests/test_compiled_sim.py on
randomized DAGs.

Cluster model (``run_cluster``)
-------------------------------
``run_cluster()`` generalizes the event loop from one SPMD timeline to K
ranks: a per-rank duration *matrix* (one row per simulated rank class), 2K
streams (each row keeps its own compute+comm stream pair), and cross-rank
barrier semantics for ``COMM_COLL`` nodes.  A collective instance completes
only when its slowest participating row has *arrived* (deps done + comm
stream free); its cost is then charged from that arrival, so faster ranks
accumulate attributable barrier-wait time while their compute streams keep
running ahead.  Each row is the unmodified ``run()`` scheduler — a row whose
comm stream commits a barrier'd collective suspends until every co-member
arrives, which preserves the single-rank float-accumulation order exactly:
with symmetric rows all arrivals are equal, every barrier resolves to
``arrival + cost``, and the per-row results are bit-identical to ``run()``
(``run()`` itself is kept as the tuned K=1 special case).  Rows whose comm
streams commit two collectives in *opposite* orders model a real SPMD hang
and raise a deadlock error naming the blocked collectives.

``simulator.simulate_cluster`` sits on top: it coalesces ranks into
equivalence classes (profile + collective-group environment) so a
symmetric 1024-rank cluster still costs one event loop, and only distinct
rank behaviors pay for extra rows.

The engine itself lives in the module-level ``run_rows``: each ``RowSpec``
carries its *own* compiled graph, so rows need not share a program —
``costmodel.mpmd`` builds per-rank-graph (true MPMD) clusters on the same
loop, with barriers carrying per-row node ids.  ``run_cluster`` is the
K-rows-over-one-graph wrapper and stays bit-identical to its historical
behavior.

Use ``compile_graph(g)`` to get the per-Graph cached instance; the cache key
is the Graph's edit token (see chakra.Graph docstring for the invalidation
contract).
"""
from __future__ import annotations

import heapq
import math
from typing import Dict, List, Optional

import numpy as np

from repro.core import chakra
from repro.core.costmodel.collectives import collective_time
from repro.core.costmodel.topology import Topology, build_topology
from repro.obs import record as obs

_TYPE_CODES = {chakra.COMP: 0, chakra.COMM_COLL: 1, chakra.COMM_SEND: 2,
               chakra.COMM_RECV: 3, chakra.MEM: 4}


class ExactSum:
    """Incremental exact float accumulator (Shewchuk partials).

    ``add(x)`` folds x into a list of non-overlapping partials;
    ``value()`` returns ``math.fsum(partials)``, which equals the
    correctly-rounded sum of *every* value added so far — i.e. the same
    double ``math.fsum`` would produce over the full prefix.  This gives
    O(n·k) exact prefix sums (k = partial count, tiny in practice)
    instead of O(n²) repeated fsum, and it is what makes the engines'
    ``peak_bytes`` agree bit-exactly with ``obs.memory``'s occupancy
    curve: both are correctly-rounded sums of the same event deltas."""
    __slots__ = ("partials",)

    def __init__(self):
        self.partials: List[float] = []

    def add(self, x: float) -> None:
        ps = self.partials
        i = 0
        for y in ps:
            if abs(x) < abs(y):
                x, y = y, x
            hi = x + y
            lo = y - (hi - x)
            if lo:
                ps[i] = lo
                i += 1
            x = hi
        ps[i:] = [x]

    def value(self) -> float:
        return math.fsum(self.partials)


def exact_peak(mem_events: List, integral: Optional[bool] = None) -> float:
    """Exact scheduled peak occupancy (bytes) from ``(t, delta, nid)``
    liveness events: the max over elementary-interval breakpoints of the
    correctly-rounded running occupancy.  Within a timestamp group the
    sort order puts frees (negative deltas) first, so the running value
    dips then rises and per-event maxima equal per-breakpoint maxima —
    the same argument the historical float scan relied on.  The floor of
    0.0 also matches the historical scan.

    Runs in the engine hot path, so there are two exact strategies:

    * **integral fast path** — when every delta is an integer-valued
      float and the total allocation stays below 2**53, every running
      partial sum is an integer that a double represents exactly, so the
      plain ``live += d`` scan *is* the exact scan at pre-instrumentation
      cost.  ``integral=True`` is a caller-side certificate of that
      precondition (``CompiledGraph._mem_integral`` checks its byte
      arrays once, vectorised); ``integral=None`` derives it from the
      events themselves (one cheap pass).

    * **integer-scaled fallback** — otherwise every delta is still a
      dyadic rational (``float.as_integer_ratio``), so scaling by the
      largest denominator makes the running sum an exact Python int.
      The final ``int / 2**shift`` division is correctly rounded, and
      rounding is monotone, so the rounded max equals the max of the
      per-breakpoint correctly-rounded sums.

    Either way the result is bit-identical to what ``obs.memory``'s
    ``ExactSum`` curve reports (property-tested in tests/test_memory.py).
    """
    if not mem_events:
        return 0.0
    events = sorted(mem_events)
    if integral is None:
        tot = 0.0
        integral = True
        for e in events:
            d = e[1]
            if not d.is_integer():
                integral = False
                break
            tot += d if d >= 0.0 else -d
        # conservative: naive |d| sum may itself round, so demand a
        # whole factor-of-2 margin below the 2**53 exactness bound
        integral = integral and tot < 2.0 ** 52
    if integral:
        live = 0.0
        peak = 0.0
        for e in events:
            live += e[1]
            if live > peak:
                peak = live
        return peak
    shift = 0
    scaled = []
    for e in events:
        num, den = e[1].as_integer_ratio()
        b = den.bit_length() - 1
        if b > shift:
            shift = b
        scaled.append((e[0], num, b))
    acc = 0
    peak = 0
    i, m = 0, len(scaled)
    while i < m:
        t = scaled[i][0]
        while i < m and scaled[i][0] == t:
            acc += scaled[i][1] << (shift - scaled[i][2])
            i += 1
        if acc > peak:
            peak = acc
    return peak / (1 << shift) if peak else 0.0


def _csr(adj: List, n: int):
    indptr = np.zeros(n + 1, dtype=np.int64)
    for i, row in enumerate(adj):
        indptr[i + 1] = indptr[i] + len(row)
    indices = np.empty(int(indptr[-1]), dtype=np.int64)
    for i, row in enumerate(adj):
        indices[int(indptr[i]):int(indptr[i + 1])] = row
    return indptr, indices


class CompiledGraph:
    def __init__(self, g: chakra.Graph):
        nodes = g.nodes
        n = len(nodes)
        self.n = n
        if any(nd.id != i for i, nd in enumerate(nodes)):
            raise ValueError("CompiledGraph requires contiguous node ids")

        order = g.topo_order()
        pos = [0] * n
        for i, nid in enumerate(order):
            pos[nid] = i

        self.type_code = np.array([_TYPE_CODES.get(nd.type, 4)
                                   for nd in nodes], dtype=np.int8)
        self.is_comm = ((self.type_code >= 1) & (self.type_code <= 3))
        self.flops = np.array([nd.attrs.get("flops", 0.0) for nd in nodes],
                              dtype=np.float64)
        self.bytes = np.array([nd.attrs.get("bytes", 0.0) for nd in nodes],
                              dtype=np.float64)
        self.comm_bytes = np.array([nd.attrs.get("comm_bytes", 0.0)
                                    for nd in nodes], dtype=np.float64)
        self.out_bytes = np.array([nd.attrs.get("out_bytes", 0.0)
                                   for nd in nodes], dtype=np.float64)
        # exact_peak fast-path certificate: byte sizes integer-valued and
        # total allocation comfortably below 2**53 means every running
        # occupancy is an exactly-representable integer, so the plain
        # float scan is already exact (NaN/inf fail the checks -> fallback)
        _ob, _cb = np.abs(self.out_bytes), np.abs(self.comm_bytes)
        self._mem_integral = bool(
            np.all(np.floor(self.out_bytes) == self.out_bytes)
            and np.all(np.floor(self.comm_bytes) == self.comm_bytes)
            and float(_ob.sum() + _cb.sum()) * 2.0 < 2.0 ** 53)

        deps_l, ddeps_l, cons_l = [], [], [[] for _ in range(n)]
        for nd in nodes:
            ad = nd.deps + nd.ctrl_deps
            dd = sorted(set(ad)) if len(ad) > 1 else list(ad)
            deps_l.append(tuple(dd))
            dds = nd.deps if len(nd.deps) <= 1 else sorted(set(nd.deps))
            ddeps_l.append(tuple(dds))
            for d in dd:
                cons_l[d].append(nd.id)
        self._csr_cache: Dict = {}             # built lazily, see csr()

        # hot-loop mirrors (plain Python containers)
        self._pos = pos
        self._order = list(order)              # pos -> nid
        self._zeros = [0] * n
        self._is_comm = self.is_comm.astype(np.int64).tolist()
        self._is_coll = (self.type_code == 1).astype(np.int64).tolist()
        # eager (buffered) p2p sends: arrive at their cluster barrier but
        # never suspend their row — see run_rows
        self._eager = [1 if nd.attrs.get("p2p_eager") else 0
                       for nd in nodes]
        self._out_bytes = self.out_bytes.tolist()
        self._comm_bytes = self.comm_bytes.tolist()
        self._deps = deps_l
        self._ddeps = ddeps_l
        self._cons = [tuple(c) for c in cons_l]
        self._indeg0 = [len(d) for d in deps_l]
        dcount = [0] * n
        for dds in ddeps_l:
            for d in dds:
                dcount[d] += 1
        self._dcount0 = dcount
        self._roots = [i for i in range(n) if self._indeg0[i] == 0]
        self._names = [nd.name for nd in nodes]

        # duration metadata for COMM_COLL nodes; the hashable group tuple
        # keys the per-config memo in durations() (layer stacks repeat the
        # same (kind, payload, group) hundreds of times)
        self._coll_ids = [nd.id for nd in nodes
                          if nd.type == chakra.COMM_COLL]
        self._coll_meta = []
        for nd in nodes:
            if nd.type != chakra.COMM_COLL:
                continue
            group = (nd.attrs.get("group")
                     or list(range(nd.attrs.get("group_size", 1))))
            # p2p channel identity + relative stage pair (microbatched
            # pipeline lowering, costmodel.schedule): several p2p channels
            # can share one rank pair, and replica-shared stage graphs
            # address partners by stage — the MPMD engine keys its FIFO
            # barrier sequences on these, never on the group alone
            ch = nd.attrs.get("p2p_channel")
            chan = tuple(ch) if isinstance(ch, (list, tuple)) else ch
            srel = nd.attrs.get("p2p_src_stage")
            drel = nd.attrs.get("p2p_dst_stage")
            rel = ((int(srel), int(drel))
                   if srel is not None and drel is not None else None)
            self._coll_meta.append((nd.attrs.get("comm_kind", "all-reduce"),
                                    group, tuple(group), chan, rel))

        self._dur_cache: Dict = {}
        self._result_cache: Dict = {}
        self._canon_cache: Dict = {}           # canonical collective order
        self._delta_cache: Dict = {}           # DeltaBase per config (delta.py)
        self._mem_proxy: Optional[float] = None

    # -- pickling ------------------------------------------------------------
    def __getstate__(self):
        """Process-pool support: a CompiledGraph is flat arrays + plain
        Python mirrors, so it pickles naturally — but the volatile memo
        caches are dropped (each worker re-fills its own).  Memo-key
        semantics survive: ``config_key`` is built from reprs, not object
        identities, and ``_canon_cache``'s id()-keyed entries are guarded
        by an identity check that simply misses after unpickling."""
        state = self.__dict__.copy()
        for k in ("_dur_cache", "_result_cache", "_canon_cache",
                  "_delta_cache", "_csr_cache"):
            state[k] = {}
        return state

    # -- CSR views -----------------------------------------------------------
    def csr(self, kind: str):
        """(indptr, indices) int64 CSR arrays for `kind` in {"deps" (dedup'd
        deps+ctrl union), "ddeps" (dedup'd data deps), "cons" (dedup'd
        consumers)}.  Built lazily: the event loop runs on the Python-list
        mirrors, so the arrays cost nothing until an exporter or an
        array-level consumer (e.g. future multi-rank simulation) asks."""
        hit = self._csr_cache.get(kind)
        if hit is None:
            adj = {"deps": self._deps, "ddeps": self._ddeps,
                   "cons": self._cons}[kind]
            hit = self._csr_cache[kind] = _csr(adj, self.n)
        return hit

    @property
    def pos(self):
        return np.asarray(self._pos, dtype=np.int64)

    @property
    def dep_indptr(self):
        return self.csr("deps")[0]

    @property
    def dep_indices(self):
        return self.csr("deps")[1]

    @property
    def ddep_indptr(self):
        return self.csr("ddeps")[0]

    @property
    def ddep_indices(self):
        return self.csr("ddeps")[1]

    @property
    def cons_indptr(self):
        return self.csr("cons")[0]

    @property
    def cons_indices(self):
        return self.csr("cons")[1]

    @staticmethod
    def config_key(system, topo, algo: str, compute_derate: float):
        """Hashable identity of everything durations depend on.  reprs of
        the (frozen/field-only) dataclasses are deterministic and cheap."""
        return (repr(system), type(topo).__name__, repr(topo), algo,
                compute_derate)

    # -- durations -----------------------------------------------------------
    def priced_colls(self, topo, algo: str = "auto",
                     bw_scale: Optional[float] = None) -> Dict[int, float]:
        """{nid: seconds} for every COMM_COLL node, memoized per distinct
        (kind, payload, group) — THE collective-pricing loop, shared by
        ``durations()``, ``comm_overrides()`` and the cluster row builder so
        a pricing change lands everywhere at once.  ``bw_scale=None`` lets
        ``collective_time`` derive each group's weakest-member scale from
        the topology's link overrides; an explicit scale overrides that."""
        out: Dict[int, float] = {}
        memo: Dict = {}
        cb = self.comm_bytes
        for nid, (kind, group, group_t, _chan, _rel) in zip(self._coll_ids,
                                                            self._coll_meta):
            payload = float(cb[nid])
            ck = (kind, payload, group_t)
            t = memo.get(ck)
            if t is None:
                t = collective_time(kind, payload, group, topo, algo,
                                    bw_scale=bw_scale)
                memo[ck] = t
            out[nid] = t
        return out

    def durations(self, system, topo: Optional[Topology] = None,
                  algo: str = "auto",
                  compute_derate: float = 0.6) -> List[float]:
        """Per-node base durations, memoized by (system, topo, algo, derate).

        Matches simulator.node_duration element-wise (bit-identical: plain
        IEEE-double ops either way).  When the topology carries per-link
        overrides, the rank-symmetric view prices every link-bound node by
        the weakest link in the cluster (collectives via group_link_scale,
        p2p via the min override) — the conservative single-rank proxy;
        ``simulate_cluster`` prices each rank at its own links.  Returns a
        read-only list — callers that override entries must copy first.
        """
        topo = topo or build_topology(system)
        key = self.config_key(system, topo, algo, compute_derate)
        hit = self._dur_cache.get(key)
        if hit is not None:
            obs.counter("compile.durations.hit")
            return hit
        obs.counter("compile.durations.miss")
        dur = np.zeros(self.n, dtype=np.float64)
        comp = self.type_code == 0
        if comp.any():
            t_f = self.flops[comp] / (system.peak_flops * compute_derate)
            t_b = self.bytes[comp] / system.hbm_bw
            dur[comp] = np.maximum(t_f, t_b)
        p2p = (self.type_code == 2) | (self.type_code == 3)
        if p2p.any():
            link_bw = topo.link_bw
            ls = getattr(topo, "link_scales", None)
            if ls:
                link_bw = link_bw * min(ls.values())
            dur[p2p] = (self.comm_bytes[p2p] / link_bw
                        + topo.link_latency)
        dur_l = dur.tolist()
        for nid, t in self.priced_colls(topo, algo).items():
            dur_l[nid] = t
        self._dur_cache[key] = dur_l
        return dur_l

    # -- analytical proxies (search subsystem's cheap fidelities) ------------
    def peak_memory_proxy(self) -> float:
        """Durations-free per-rank peak-memory estimate (bytes): the liveness
        scan of ``run()`` (allocate ``out_bytes`` at the producer, free after
        the last data consumer) replayed over the canonical topological order
        instead of a scheduled timeline.  Independent of (system, topology),
        so it prices the memory axis of a multi-objective search without an
        event loop — graph passes that move allocations (prefetch hoisting,
        bucketing) change it exactly as they change the scheduled peak.
        Memoized per compiled graph."""
        if self._mem_proxy is not None:
            return self._mem_proxy
        out_b = self._out_bytes
        ddeps = self._ddeps
        dcount = self._dcount0[:]
        live = peak = 0.0
        for nid in self._order:
            ob = out_b[nid]
            if ob:
                live += ob
                if live > peak:
                    peak = live
            for dd in ddeps[nid]:
                r = dcount[dd] - 1
                dcount[dd] = r
                if r <= 0:
                    ob = out_b[dd]
                    if ob:
                        live -= ob
        self._mem_proxy = peak
        return peak

    def analytic_estimate(self, dur: List[float], overlap: bool = True):
        """Roofline-style step-time bound from a duration vector, no event
        loop: busy time per stream is a plain sum, the step can take no less
        than the busier stream (overlap) or their sum (no overlap).  Returns
        ``(total, compute_busy, comm_busy)`` — the proxy fidelity the search
        subsystem's successive-halving rungs price candidates with before
        promoting survivors to a full ``run()``."""
        d = np.asarray(dur, dtype=np.float64)
        comm = float(d[self.is_comm].sum())
        comp = float(d.sum()) - comm
        total = max(comp, comm) if overlap else comp + comm
        return total, comp, comm

    # -- event loop ----------------------------------------------------------
    def run(self, dur: List[float], overlap: bool = True,
            keep_timeline: bool = False):
        """Replay of the reference two-stream list scheduler over the flat
        arrays.  `dur` is a full per-node duration list (see durations()).

        Differences from the reference are representational only: heaps hold
        bare topo positions (nid = order[pos]; pos is unique so priorities
        are unchanged), and a ready node whose dep time has already passed
        goes straight to the avail heap — the reference would move it there
        in the drain step of the very next scheduling decision, before any
        candidate comparison, so every decision sees identical heap state.

        Internally the loop is segmented: ``_fresh_state`` builds a
        ``_RunState``, ``_run_span`` advances it a bounded number of
        scheduling decisions, ``_finalize`` assembles the ``SimResult``.
        Durations are read only at the instant a node is scheduled, so a
        mid-run state snapshot is a sound resume point for any duration
        vector agreeing with the original on all nodes scheduled so far —
        the delta re-simulation contract (``costmodel.delta``).
        """
        obs.counter("engine.runs")
        with obs.span("engine.run"):
            st = self._fresh_state(overlap, keep_timeline)
            self._run_span(st, dur, overlap, self.n)
            return self._finalize(st)

    def _fresh_state(self, overlap: bool = True,
                     keep_timeline: bool = False) -> "_RunState":
        """Pristine engine state: roots on their avail heaps, clocks at 0."""
        n_total = self.n
        pos = self._pos
        scode = self._is_comm if overlap else self._zeros
        st = _RunState.__new__(_RunState)
        st.remaining = self._indeg0[:]
        st.dcount = self._dcount0[:]
        # dmax[c] = max finish time over c's already-finished deps: every
        # (dedup'd) dep decrements remaining[c] exactly once, so by the time
        # remaining[c] hits 0 this equals max(finish[d] for d in deps[c]).
        st.dmax = [0.0] * n_total
        st.total = 0.0                         # running max finish time
        st.sf0 = st.sf1 = 0.0                  # stream clocks
        st.busy0 = st.busy1 = 0.0              # busy time by *node type*
        avail0: List[int] = []                 # heaps of topo positions
        avail1: List[int] = []
        for nid in self._roots:
            (avail1 if scode[nid] else avail0).append(pos[nid])
        heapq.heapify(avail0)
        heapq.heapify(avail1)
        st.avail0, st.avail1 = avail0, avail1
        st.future0, st.future1 = [], []        # heaps of (dep_t, pos)
        st.mem_events = []
        st.timeline = [] if keep_timeline else None
        st.scheduled = 0
        return st

    def _run_span(self, st: "_RunState", dur: List[float], overlap: bool,
                  stop: int, record: Optional[List] = None) -> None:
        """Advance `st` until `stop` scheduling decisions have been made in
        total (stop = self.n runs to completion).  `record`, when given,
        collects ``(nid, end)`` per decision — the base-run trace delta
        re-simulation checkpoints."""
        from repro.core.costmodel.simulator import Span

        n_total = stop
        pos = self._pos
        order = self._order
        ddeps = self._ddeps
        cons = self._cons
        out_b = self._out_bytes
        comm_b = self._comm_bytes
        is_comm = self._is_comm
        scode = is_comm if overlap else self._zeros
        remaining = st.remaining
        dcount = st.dcount
        dmax = st.dmax
        total = st.total
        sf0, sf1 = st.sf0, st.sf1
        busy0, busy1 = st.busy0, st.busy1
        avail0, avail1 = st.avail0, st.avail1
        future0, future1 = st.future0, st.future1
        timeline = st.timeline
        mem_events = st.mem_events
        scheduled = st.scheduled
        push, pop = heapq.heappush, heapq.heappop

        while scheduled < n_total:
            while future0 and future0[0][0] <= sf0:
                push(avail0, pop(future0)[1])
            while future1 and future1[0][0] <= sf1:
                push(avail1, pop(future1)[1])
            if avail0:
                est0, p0, a0 = sf0, avail0[0], True
            elif future0:
                dt, p0 = future0[0]
                est0, a0 = (dt if dt > sf0 else sf0), False
            else:
                p0 = -1
            if avail1:
                est1, p1, a1 = sf1, avail1[0], True
            elif future1:
                dt, p1 = future1[0]
                est1, a1 = (dt if dt > sf1 else sf1), False
            else:
                p1 = -1
            if p0 >= 0 and (p1 < 0 or est0 < est1
                            or (est0 == est1 and p0 < p1)):
                s = 0
                p = pop(avail0) if a0 else pop(future0)[1]
                start = est0
            elif p1 >= 0:
                s = 1
                p = pop(avail1) if a1 else pop(future1)[1]
                start = est1
            else:
                raise ValueError("deadlock: no ready nodes but graph "
                                 "unfinished")
            nid = order[p]
            d = dur[nid]
            end = start + d
            if s:
                sf1 = end
            else:
                sf0 = end
            if is_comm[nid]:
                busy1 += d
            else:
                busy0 += d
            if end > total:
                total = end
            scheduled += 1
            if record is not None:
                record.append((nid, end))
            if timeline is not None:
                timeline.append(Span(nid, self._names[nid],
                                     "comm" if s else "comp", start, end))
            ob = out_b[nid]
            if ob:
                mem_events.append((start, ob, nid))
            if is_comm[nid]:
                cb = comm_b[nid]
                if cb:
                    # transient comm buffer: live only for the span; the
                    # bitwise-complement id tags it as node ~nid's buffer
                    mem_events.append((start, cb, ~nid))
                    mem_events.append((end, -cb, ~nid))
            for c in cons[nid]:
                r = remaining[c] - 1
                remaining[c] = r
                dep_t = dmax[c]
                if end > dep_t:
                    dmax[c] = dep_t = end
                if r == 0:
                    pc = pos[c]
                    if scode[c]:
                        if dep_t <= sf1:
                            push(avail1, pc)
                        else:
                            push(future1, (dep_t, pc))
                    else:
                        if dep_t <= sf0:
                            push(avail0, pc)
                        else:
                            push(future0, (dep_t, pc))
            for dd in ddeps[nid]:
                r = dcount[dd] - 1
                dcount[dd] = r
                if r <= 0:
                    ob = out_b[dd]
                    if ob:
                        mem_events.append((end, -ob, dd))

        st.total = total
        st.sf0, st.sf1 = sf0, sf1
        st.busy0, st.busy1 = busy0, busy1
        st.scheduled = scheduled

    def _finalize(self, st: "_RunState", peak_bytes: Optional[float] = None):
        """SimResult from a fully-run state (st.scheduled == self.n).
        ``peak_bytes`` short-circuits the event scan when the caller
        already holds the exact peak (delta re-simulation's incremental
        prefix/tail split, costmodel.delta)."""
        from repro.core.costmodel.simulator import SimResult

        exposed = st.total - st.busy0
        if exposed < 0.0:
            exposed = 0.0
        if peak_bytes is None:
            peak_bytes = exact_peak(st.mem_events, self._mem_integral)
        return SimResult(total_time=st.total, compute_time=st.busy0,
                         comm_time=st.busy1, exposed_comm=exposed,
                         peak_bytes=peak_bytes,
                         n_nodes=self.n, timeline=st.timeline,
                         mem_events=(st.mem_events
                                     if st.timeline is not None else None))

    def canonical_coll_order(self, dur: List[float],
                             overlap: bool = True) -> List[int]:
        """COMM_COLL node ids in the order the nominal (rank-symmetric)
        schedule commits them — the cluster engine's stand-in for the
        compiled SPMD binary's fixed collective launch order.  Memoized per
        (duration vector, overlap)."""
        key = (id(dur), overlap)
        hit = self._canon_cache.get(key)
        if hit is None or hit[0] is not dur:   # id() can be reused; verify
            is_coll = self._is_coll
            tl = self.run(dur, overlap=overlap, keep_timeline=True).timeline
            hit = (dur, [sp[0] for sp in tl if is_coll[sp[0]]])
            self._canon_cache[key] = hit
        return hit[1]

    # -- K-rank event loop ---------------------------------------------------
    def run_cluster(self, dur_rows: List[List[float]],
                    barrier_map: List[Dict[int, list]],
                    coll_order: Optional[List[int]] = None,
                    overlap: bool = True, keep_timeline: bool = False):
        """K-row generalization of ``run()`` with cross-rank collective
        barriers (see the module docstring's cluster-model section).

        `dur_rows[j]` is row j's full per-node duration list; `barrier_map[j]`
        maps a COMM_COLL node id to the shared mutable barrier
        ``[remaining, max_arrival, rows_tuple, cost, arrivals_dict,
        nid_by_row]`` that row participates in (only collectives whose
        participant set spans >= 2 rows appear — a single-row collective runs
        on the plain ``run()`` path, which is what keeps the
        symmetric/coalesced case bit-identical).  The barrier's `cost` is
        fixed up front as the max over member rows' own durations for that
        node: each row prices the collective at its own link speed, so the
        max IS the weakest-member price.

        `coll_order` (required when any barrier exists) is the canonical
        program order of collectives: each row issues its barrier'd
        collectives in exactly this order, deferring one whose turn has not
        come.  A compiled SPMD binary launches collectives in one global
        order, and without the discipline two rows with skewed timing can
        commit two collectives in opposite orders and hang — with it the
        cluster is provably deadlock-free.  In the symmetric case rows
        already commit in canonical order, so the discipline never fires and
        the per-row loop stays bit-identical to ``run()``.

        All K rows replay the *same* compiled graph here; the engine itself
        (``run_rows``) also accepts one graph per row — the true-MPMD mode
        ``costmodel.mpmd`` builds rows for (per-rank graphs, shared
        collective barriers keyed by group + per-group program order).

        Returns ``(results, waits)``: per-row ``SimResult`` plus per-row
        total comm-stream barrier-wait seconds (time between a row's arrival
        at a collective and the slowest member's arrival).
        """
        rows = []
        for j, (dur, bmap) in enumerate(zip(dur_rows, barrier_map)):
            for nid, b in bmap.items():
                if len(b) == 5:        # legacy 5-slot barrier: add nid map
                    b.append({})
                b[5][j] = nid
            rows.append(RowSpec(self, dur, bmap, coll_order))
        return run_rows(rows, overlap=overlap, keep_timeline=keep_timeline)

    # -- duration-override helpers ------------------------------------------
    def comm_overrides(self, system, topo, bw_scale: float,
                       algo: str = "auto") -> Dict[int, float]:
        """{nid: seconds} repricing every COMM node at `bw_scale`-scaled link
        bandwidth (the explicit scale, ignoring any per-link overrides) —
        the shape of a per-NIC degradation sweep: one compiled graph, one
        override dict per degradation level, one simulate_batch."""
        out = self.priced_colls(topo, algo, bw_scale=bw_scale)
        cb = self.comm_bytes
        link_bw = topo.link_bw * bw_scale
        for nid in np.nonzero((self.type_code == 2)
                              | (self.type_code == 3))[0]:
            out[int(nid)] = (float(cb[nid]) / link_bw + topo.link_latency)
        return out


class _RunState:
    """Resumable state of one single-row ``run()``: everything the event
    loop reads or writes between two scheduling decisions.  ``copy()`` is
    the checkpoint primitive of delta re-simulation (``costmodel.delta``) —
    heap lists copy shallowly (ints / immutable tuples), so a snapshot is
    O(n) and restoring one re-creates the exact mid-run engine state."""
    __slots__ = ("remaining", "dcount", "dmax", "total", "sf0", "sf1",
                 "busy0", "busy1", "avail0", "avail1", "future0", "future1",
                 "mem_events", "timeline", "scheduled")

    def copy(self) -> "_RunState":
        st = _RunState.__new__(_RunState)
        st.remaining = self.remaining[:]
        st.dcount = self.dcount[:]
        st.dmax = self.dmax[:]
        st.total = self.total
        st.sf0, st.sf1 = self.sf0, self.sf1
        st.busy0, st.busy1 = self.busy0, self.busy1
        st.avail0, st.avail1 = self.avail0[:], self.avail1[:]
        st.future0, st.future1 = self.future0[:], self.future1[:]
        st.mem_events = self.mem_events[:]
        st.timeline = None if self.timeline is None else self.timeline[:]
        st.scheduled = self.scheduled
        return st


class RowSpec:
    """One rank-class row of a (possibly MPMD) cluster run: the compiled
    graph the row executes, its full per-node duration list, its barrier map
    ``{nid: barrier}`` and its collective program order (``None`` when the
    row has no barriers).  ``CompiledGraph.run_cluster`` builds K rows over
    one graph; ``costmodel.mpmd`` builds one row per rank equivalence class,
    each over its own graph."""
    __slots__ = ("cg", "dur", "bmap", "coll_order")

    def __init__(self, cg: "CompiledGraph", dur: List[float],
                 bmap: Optional[Dict[int, list]] = None,
                 coll_order: Optional[List[int]] = None):
        self.cg = cg
        self.dur = dur
        self.bmap = bmap if bmap is not None else {}
        self.coll_order = coll_order


def run_rows(rows: List[RowSpec], overlap: bool = True,
             keep_timeline: bool = False):
    """Multi-row cluster event loop: each row replays ``run()`` over its own
    compiled graph, suspending on shared cross-row collective barriers.

    This is ``CompiledGraph.run_cluster`` generalized from "K duration rows
    over one graph" to "K (graph, durations) programs" — the MPMD substrate.
    A barrier is the shared mutable list ``[remaining, max_arrival,
    rows_tuple, cost, arrivals_dict, nid_by_row]``; because node ids are
    row-local in the multi-graph case, the barrier carries each member row's
    own node id (``nid_by_row``).  Rows whose graphs are the same object are
    bit-identical to the historical single-graph engine (the delegation is
    exercised by every existing cluster test).

    Returns ``(results, waits)`` exactly like ``run_cluster``.
    """
    from repro.core.costmodel.simulator import SimResult, Span

    push, pop = heapq.heappush, heapq.heappop
    J = len(rows)

    for spec in rows:
        if spec.bmap and spec.coll_order is None:
            raise ValueError("run_rows needs coll_order when barriers "
                             "are present (see canonical_coll_order)")

    class _Row:
        __slots__ = ("remaining", "dcount", "dmax", "sf0", "sf1",
                     "busy0", "busy1", "total", "wait", "avail0",
                     "avail1", "future0", "future1", "mem_events",
                     "timeline", "scheduled", "done",
                     "exp_list", "exp_i", "deferred")

    states = []
    for spec in rows:
        cg = spec.cg
        scode = cg._is_comm if overlap else cg._zeros
        pos = cg._pos
        st = _Row()
        st.remaining = cg._indeg0[:]
        st.dcount = cg._dcount0[:]
        st.dmax = [0.0] * cg.n
        st.sf0 = st.sf1 = 0.0
        st.busy0 = st.busy1 = 0.0
        st.total = 0.0
        st.wait = 0.0
        st.avail0, st.avail1 = [], []
        for nid in cg._roots:
            (st.avail1 if scode[nid] else st.avail0).append(pos[nid])
        heapq.heapify(st.avail0)
        heapq.heapify(st.avail1)
        st.future0, st.future1 = [], []
        st.mem_events = []
        st.timeline = [] if keep_timeline else None
        st.scheduled = 0
        st.done = False
        # program-order discipline covers EVERY collective (not just
        # barrier'd ones) so commit order — and float accumulation
        # order — is identical whatever the rank coalescing chose
        st.exp_list = spec.coll_order or ()
        st.exp_i = 0
        st.deferred = {}
        states.append(st)

    def _deliver(st, spec, nid, end):
        """Post-duration commit tail shared by barrier resolution and the
        normal path of a suspended row: consumer wakeups + ddep frees,
        identical bookkeeping to run()."""
        cg = spec.cg
        cons = cg._cons
        ddeps = cg._ddeps
        out_b = cg._out_bytes
        pos = cg._pos
        scode = cg._is_comm if overlap else cg._zeros
        for c in cons[nid]:
            r = st.remaining[c] - 1
            st.remaining[c] = r
            dep_t = st.dmax[c]
            if end > dep_t:
                st.dmax[c] = dep_t = end
            if r == 0:
                pc = pos[c]
                if scode[c]:
                    if dep_t <= st.sf1:
                        push(st.avail1, pc)
                    else:
                        push(st.future1, (dep_t, pc))
                else:
                    if dep_t <= st.sf0:
                        push(st.avail0, pc)
                    else:
                        push(st.future0, (dep_t, pc))
        for dd in ddeps[nid]:
            r = st.dcount[dd] - 1
            st.dcount[dd] = r
            if r <= 0:
                ob = out_b[dd]
                if ob:
                    st.mem_events.append((end, -ob, dd))

    def _complete_suspended(w, b, end):
        """Finish the commit a suspended row w started when it arrived at
        barrier b: charge cost from its own arrival, attribute the wait,
        then release it."""
        st = states[w]
        spec = rows[w]
        nid = b[5][w]                  # node ids are row-local (MPMD)
        arr, sw = b[4][w]
        cost = b[3]
        if sw:
            st.sf1 = end
        else:                      # overlap=False: comm runs on stream 0
            st.sf0 = end
        st.busy1 += cost           # busy accounting is by node *type*
        st.wait += b[1] - arr
        if end > st.total:
            st.total = end
        st.scheduled += 1
        if st.timeline is not None:
            st.timeline.append(Span(nid, spec.cg._names[nid],
                                    "comm" if sw else "comp", arr, end,
                                    b[1] - arr))
        ob = spec.cg._out_bytes[nid]
        if ob:
            st.mem_events.append((arr, ob, nid))
        cb = spec.cg._comm_bytes[nid]
        if cb:
            st.mem_events.append((arr, cb, ~nid))
            st.mem_events.append((end, -cb, ~nid))
        _deliver(st, spec, nid, end)

    ready = list(range(J))
    finished = 0

    def advance(j):
        """Run row j until it finishes the graph (returns 1) or suspends
        on a collective barrier (returns 0).  Body replicates run()."""
        st = states[j]
        spec = rows[j]
        cg = spec.cg
        n_total = cg.n
        pos = cg._pos
        order = cg._order
        ddeps = cg._ddeps
        cons = cg._cons
        out_b = cg._out_bytes
        comm_b = cg._comm_bytes
        is_comm = cg._is_comm
        names = cg._names
        scode = is_comm if overlap else cg._zeros
        is_coll = cg._is_coll
        dur = spec.dur
        bmap = spec.bmap
        remaining = st.remaining
        dcount = st.dcount
        dmax = st.dmax
        sf0, sf1 = st.sf0, st.sf1
        busy0, busy1 = st.busy0, st.busy1
        total = st.total
        avail0, avail1 = st.avail0, st.avail1
        future0, future1 = st.future0, st.future1
        mem_events = st.mem_events
        timeline = st.timeline
        scheduled = st.scheduled

        while scheduled < n_total:
            while future0 and future0[0][0] <= sf0:
                push(avail0, pop(future0)[1])
            while future1 and future1[0][0] <= sf1:
                push(avail1, pop(future1)[1])
            if avail0:
                est0, p0, a0 = sf0, avail0[0], True
            elif future0:
                dt, p0 = future0[0]
                est0, a0 = (dt if dt > sf0 else sf0), False
            else:
                p0 = -1
            if avail1:
                est1, p1, a1 = sf1, avail1[0], True
            elif future1:
                dt, p1 = future1[0]
                est1, a1 = (dt if dt > sf1 else sf1), False
            else:
                p1 = -1
            if p0 >= 0 and (p1 < 0 or est0 < est1
                            or (est0 == est1 and p0 < p1)):
                s = 0
                p = pop(avail0) if a0 else pop(future0)[1]
                start = est0
            elif p1 >= 0:
                s = 1
                p = pop(avail1) if a1 else pop(future1)[1]
                start = est1
            else:
                raise ValueError("deadlock: no ready nodes but graph "
                                 "unfinished")
            nid = order[p]
            if is_coll[nid] and st.exp_list:
                if nid != st.exp_list[st.exp_i]:
                    # program-order discipline: this collective's turn
                    # hasn't come — park it and pick again
                    st.deferred[nid] = dmax[nid]
                    continue
                st.exp_i += 1
                if st.exp_i < len(st.exp_list):
                    dt = st.deferred.pop(st.exp_list[st.exp_i], None)
                    if dt is not None:
                        nxt = st.exp_list[st.exp_i]
                        if scode[nxt]:
                            push(future1, (dt, pos[nxt]))
                        else:
                            push(future0, (dt, pos[nxt]))
                b = bmap.get(nid)
                if b is not None and cg._eager[nid]:
                    # eager (buffered) p2p send: arrive at the barrier —
                    # releasing suspended peers if we are last — but never
                    # suspend; the send itself runs locally below at its
                    # own priced duration (the local buffer copy).  Eager
                    # arrivals are deliberately NOT recorded in b[4], so
                    # the resolver and the deadlock diagnostic only ever
                    # see suspended rows there.
                    b[0] -= 1
                    if start > b[1]:
                        b[1] = start
                    if not b[0]:
                        endb = b[1] + b[3]
                        for w in b[2]:
                            if w != j and w in b[4]:
                                _complete_suspended(w, b, endb)
                                ready.append(w)
                    b = None
                if b is not None:
                    # barrier'd collective: record arrival (+ committing
                    # stream); resolve if we are the last member to
                    # arrive in driver order, else suspend
                    b[0] -= 1
                    b[4][j] = (start, s)
                    if start > b[1]:
                        b[1] = start
                    if b[0]:
                        st.sf0, st.sf1 = sf0, sf1
                        st.busy0, st.busy1 = busy0, busy1
                        st.total = total
                        st.scheduled = scheduled
                        return 0
                    cost = b[3]
                    end = b[1] + cost
                    for w in b[2]:
                        # eager members arrived without suspending (absent
                        # from b[4]); only suspended rows need completion
                        if w != j and w in b[4]:
                            _complete_suspended(w, b, end)
                            ready.append(w)
                    if s:
                        sf1 = end
                    else:          # overlap=False: comm on stream 0
                        sf0 = end
                    busy1 += cost  # busy accounting is by node *type*
                    st.wait += b[1] - start
                    if end > total:
                        total = end
                    scheduled += 1
                    if timeline is not None:
                        timeline.append(Span(nid, names[nid],
                                             "comm" if s else "comp",
                                             start, end, b[1] - start))
                    ob = out_b[nid]
                    if ob:
                        mem_events.append((start, ob, nid))
                    cb = comm_b[nid]
                    if cb:
                        mem_events.append((start, cb, ~nid))
                        mem_events.append((end, -cb, ~nid))
                    # consumer/ddep bookkeeping reads the stream clocks
                    st.sf0, st.sf1 = sf0, sf1
                    _deliver(st, spec, nid, end)
                    continue
            d = dur[nid]
            end = start + d
            if s:
                sf1 = end
            else:
                sf0 = end
            if is_comm[nid]:
                busy1 += d
            else:
                busy0 += d
            if end > total:
                total = end
            scheduled += 1
            if timeline is not None:
                timeline.append(Span(nid, names[nid],
                                     "comm" if s else "comp", start, end))
            ob = out_b[nid]
            if ob:
                mem_events.append((start, ob, nid))
            if is_comm[nid]:
                cb = comm_b[nid]
                if cb:
                    mem_events.append((start, cb, ~nid))
                    mem_events.append((end, -cb, ~nid))
            for c in cons[nid]:
                r = remaining[c] - 1
                remaining[c] = r
                dep_t = dmax[c]
                if end > dep_t:
                    dmax[c] = dep_t = end
                if r == 0:
                    pc = pos[c]
                    if scode[c]:
                        if dep_t <= sf1:
                            push(avail1, pc)
                        else:
                            push(future1, (dep_t, pc))
                    else:
                        if dep_t <= sf0:
                            push(avail0, pc)
                        else:
                            push(future0, (dep_t, pc))
            for dd in ddeps[nid]:
                r = dcount[dd] - 1
                dcount[dd] = r
                if r <= 0:
                    ob = out_b[dd]
                    if ob:
                        mem_events.append((end, -ob, dd))

        st.sf0, st.sf1 = sf0, sf1
        st.busy0, st.busy1 = busy0, busy1
        st.total = total
        st.scheduled = scheduled
        st.done = True
        return 1

    while finished < J:
        if not ready:
            pend = [(j, nid) for j, spec in enumerate(rows)
                    for nid, b in spec.bmap.items()
                    if b[0] and j in b[4]]
            raise ValueError(
                "cluster deadlock: ranks issued collectives in "
                f"conflicting orders (pending arrivals: {pend[:8]}) — "
                "a real SPMD/MPMD program would hang here")
        j = ready.pop()
        st = states[j]
        if st.done:
            continue
        finished += advance(j)

    out, waits = [], []
    for spec, st in zip(rows, states):
        exposed = st.total - st.busy0
        if exposed < 0.0:
            exposed = 0.0
        out.append(SimResult(total_time=st.total, compute_time=st.busy0,
                             comm_time=st.busy1, exposed_comm=exposed,
                             peak_bytes=exact_peak(st.mem_events,
                                                   spec.cg._mem_integral),
                             n_nodes=spec.cg.n, timeline=st.timeline,
                             mem_events=(st.mem_events
                                         if st.timeline is not None
                                         else None)))
        waits.append(st.wait)
    return out, waits


RESULT_CACHE_CAP = 512


def result_cache_put(cache: Dict, key, value, cap: int = RESULT_CACHE_CAP):
    """Insert into a per-graph result memo with FIFO eviction.

    Fault-horizon Monte-Carlo sweeps can visit thousands of distinct
    (profile-set, K) signatures per compiled graph over a long run; an
    unbounded memo would grow without limit.  Dict insertion order gives a
    cheap FIFO: evict the oldest entries once `cap` is reached.  Eviction
    only costs a re-simulation — results stay bit-identical either way."""
    if key not in cache:
        while len(cache) >= cap:
            cache.pop(next(iter(cache)))
    cache[key] = value


def _build_compiled(g: chakra.Graph) -> CompiledGraph:
    obs.counter("compile.graphs")
    with obs.span("compile.graph"):
        return CompiledGraph(g)


def compile_graph(g: chakra.Graph) -> CompiledGraph:
    """Lower `g` to a CompiledGraph, memoized on the Graph's edit token."""
    cached = getattr(g, "_cached", None)
    if cached is not None:                     # chakra.Graph (has cache infra)
        return g._cached("compiled", lambda: _build_compiled(g))
    return _build_compiled(g)
