"""Closed-form roofline model (the deliverable of EXPERIMENTS.md SSRoofline).

Per (arch x shape x mesh) cell, from the compiled dry-run artifacts:
  compute term    = HLO_FLOPs / peak_FLOP/s            (per chip)
  memory term     = HLO_bytes / HBM_bw                 (per chip)
  collective term = collective_bytes / link_bw         (per chip)

HLO_FLOPs come from Flint's trip-count-aware parser (parsed_flops), with
XLA's cost_analysis as a cross-check.  collective_bytes = sum of operand
sizes of every collective op (the assignment's definition), also reported
as an algorithm-aware wire estimate used by the simulator.
"""
from __future__ import annotations

import dataclasses
from typing import Dict


@dataclasses.dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float
    flops: float
    hbm_bytes: float
    coll_bytes: float
    model_flops: float            # 6*N*D (or 6*N_active*D) per device
    bound: str
    useful_ratio: float           # MODEL_FLOPS / HLO_FLOPs

    def as_dict(self):
        return dataclasses.asdict(self)

    @property
    def step_time_lb(self) -> float:
        """Lower-bound step time if the dominant term perfectly hides the rest."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful-compute time / achievable step time (the perf score)."""
        t_useful = self.model_flops and self.model_flops or 0.0
        return 0.0 if self.step_time_lb == 0 else \
            min(1.0, (self.model_flops / max(self.flops, 1e-9))
                * self.compute_s / self.step_time_lb)


def roofline(summary: Dict, cost_analysis: Dict, system,
             model_flops_per_device: float,
             fused_kernels: bool = False) -> RooflineTerms:
    """summary: capture.summarize_module output; cost_analysis: XLA dict.

    Uses Flint's trip-count-aware, bf16-normalized byte accounting (XLA's
    cost_analysis neither multiplies while bodies nor targets TPU dtypes).
    fused_kernels=True uses the Pallas-kernel HBM view (attention/SSD/RG-LRU
    inner loops VMEM-resident; see kernels/)."""
    flops = max(summary.get("parsed_flops", 0.0),
                cost_analysis.get("flops", 0.0) or 0.0)
    key = ("parsed_hbm_bytes_tpu_fused" if fused_kernels
           else "parsed_hbm_bytes_tpu")
    hbm = summary.get(key, 0.0) or \
        cost_analysis.get("bytes accessed", 0.0) or 0.0
    coll = summary.get("comm_bytes_tpu", summary.get("comm_bytes", 0.0))
    c_s = flops / system.peak_flops
    m_s = hbm / system.hbm_bw
    l_s = coll / system.link_bw
    terms = {"compute": c_s, "memory": m_s, "collective": l_s}
    bound = max(terms, key=terms.get)
    return RooflineTerms(
        compute_s=c_s, memory_s=m_s, collective_s=l_s, flops=flops,
        hbm_bytes=hbm, coll_bytes=coll,
        model_flops=model_flops_per_device, bound=bound,
        useful_ratio=(model_flops_per_device / flops) if flops else 0.0)


def model_flops_per_step(cfg, shape, n_devices: int) -> float:
    """6*N*D per device (N_active for MoE); decode counts one token/seq."""
    n_params = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        mult = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        mult = 2.0
    else:  # decode: one new token per sequence
        tokens = shape.global_batch
        mult = 2.0
    return mult * n_params * tokens / n_devices
