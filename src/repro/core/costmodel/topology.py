"""Topology models for the cost models (paper Fig 2 bottom box).

Each topology answers: per-link bandwidth/latency, hop distance between
ranks, and the effective ring bandwidth available to a group (used by the
collective-time models).  TPU-native topologies (torus) and the paper's
SS6.2 wafer-scale 2-D mesh are the same object modulo wraparound links.

Heterogeneity hooks (cluster-level asymmetric simulation):

  * ``RankProfile`` describes one rank's hardware deviation from the
    SystemConfig baseline — absolute ``peak_flops``/``hbm_bw`` overrides
    (mixed chip generations), a multiplicative ``compute_scale`` (thermal /
    degraded-host derate), and a ``link_scale`` on its NIC/ICI bandwidth.
    Consumed by ``simulator.simulate_cluster`` and the DSE hardware knobs.
  * ``Topology.link_scales`` maps rank -> per-link bandwidth multiplier
    (flapping NIC, degraded pod uplink).  ``group_link_scale`` returns the
    weakest member's multiplier, which ``collectives.collective_time`` uses
    to price a collective by its slowest participant.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Optional, Tuple


@dataclasses.dataclass(frozen=True)
class RankProfile:
    """Per-rank hardware profile; the all-defaults instance is the baseline
    rank (bit-identical to the rank-symmetric model).

    ``peak_flops``/``hbm_bw`` are absolute overrides (None -> SystemConfig
    value); ``compute_scale`` multiplies both (a 1.5x-slower degraded host is
    ``compute_scale=1/1.5``); ``link_scale`` multiplies this rank's link
    bandwidth in every collective/p2p it participates in.

    ``hbm_bytes`` is this rank's memory *capacity* (for OOM feasibility
    checks against the schedule-aware ``peak_bytes``, see ``core.dse`` and
    ``obs.memory``).  Like ``tag`` it does not affect timing, so it is
    excluded from ``is_default()`` — a capacity-only profile must stay on
    the symmetric/coalesced simulation path."""
    peak_flops: Optional[float] = None
    hbm_bw: Optional[float] = None
    compute_scale: float = 1.0
    link_scale: float = 1.0
    tag: str = ""
    hbm_bytes: Optional[float] = None

    def is_default(self) -> bool:
        return (self.peak_flops is None and self.hbm_bw is None
                and self.compute_scale == 1.0 and self.link_scale == 1.0)

    def effective_flops(self, system) -> float:
        base = self.peak_flops if self.peak_flops is not None \
            else system.peak_flops
        return base * self.compute_scale

    def effective_hbm(self, system) -> float:
        base = self.hbm_bw if self.hbm_bw is not None else system.hbm_bw
        return base * self.compute_scale

    def scaled(self, compute_scale: float = 1.0,
               link_scale: float = 1.0) -> "RankProfile":
        """Compose multiplicative derates onto this profile (absolute
        overrides are preserved).  Fault windows stack: two concurrent 2x
        slowdowns yield ``compute_scale=0.25``."""
        if compute_scale == 1.0 and link_scale == 1.0:
            return self
        return dataclasses.replace(
            self, compute_scale=self.compute_scale * compute_scale,
            link_scale=self.link_scale * link_scale)


@dataclasses.dataclass
class Topology:
    n_ranks: int
    link_bw: float            # bytes/s per link per direction
    link_latency: float       # seconds per hop
    # rank -> bandwidth multiplier for that rank's links (<1 = degraded);
    # absent ranks are 1.0.  Priced into collectives via group_link_scale.
    link_scales: Optional[Dict[int, float]] = None

    name = "abstract"

    def rank_link_scale(self, r: int) -> float:
        """Per-link bandwidth multiplier of rank r (1.0 = nominal)."""
        if not self.link_scales:
            return 1.0
        return self.link_scales.get(r, 1.0)

    def group_link_scale(self, group: List[int]) -> float:
        """Weakest member's link multiplier — a collective over `group` runs
        no faster than its slowest participant's links allow."""
        if not self.link_scales:
            return 1.0
        return min((self.link_scales.get(r, 1.0) for r in group),
                   default=1.0)

    def hop_distance(self, a: int, b: int) -> int:
        raise NotImplementedError

    def ring_bw(self, group: List[int]) -> float:
        """Effective per-rank ring bandwidth for a collective over `group`."""
        raise NotImplementedError

    def bisection_bw(self) -> float:
        raise NotImplementedError


@dataclasses.dataclass
class Switch(Topology):
    """Non-blocking switch / fat tree: every rank has one NIC of link_bw."""
    name = "switch"

    def hop_distance(self, a, b):
        return 2 if a != b else 0

    def ring_bw(self, group):
        return self.link_bw

    def bisection_bw(self):
        return self.link_bw * self.n_ranks / 2


@dataclasses.dataclass
class Ring(Topology):
    name = "ring"

    def hop_distance(self, a, b):
        d = abs(a - b)
        return min(d, self.n_ranks - d)

    def ring_bw(self, group):
        # contiguous group -> full link bw; strided group shares links
        if len(group) < 2:
            return self.link_bw
        stride = abs(group[1] - group[0])
        return self.link_bw / max(1, stride) if stride else self.link_bw

    def bisection_bw(self):
        return 2 * self.link_bw


@dataclasses.dataclass
class Torus2D(Topology):
    """TPU-pod-style 2-D torus (wrap links); dims x*y == n_ranks.

    Each rank has 4 links (2 per dimension).  A group that maps onto one
    torus dimension gets a native ring; otherwise bw is derated by the
    stride congestion."""
    dims: Tuple[int, int] = (0, 0)
    wrap: bool = True
    name = "torus2d"

    def __post_init__(self):
        if self.dims == (0, 0):
            side = int(math.sqrt(self.n_ranks))
            self.dims = (side, self.n_ranks // side)

    def _coord(self, r):
        return divmod(r, self.dims[1])

    def hop_distance(self, a, b):
        (ax, ay), (bx, by) = self._coord(a), self._coord(b)
        dx, dy = abs(ax - bx), abs(ay - by)
        if self.wrap:
            dx = min(dx, self.dims[0] - dx)
            dy = min(dy, self.dims[1] - dy)
        return dx + dy

    def group_is_axis(self, group) -> bool:
        xs = {self._coord(r)[0] for r in group}
        ys = {self._coord(r)[1] for r in group}
        return len(xs) == 1 or len(ys) == 1

    def ring_bw(self, group):
        # a group aligned with a torus axis rides the native ring links
        # (both directions, wrap); unaligned groups get derated bw.
        base = self.link_bw * (2.0 if self.wrap else 1.0)
        if len(group) < 2 or self.group_is_axis(group):
            return base
        return base / 2.0

    def bisection_bw(self):
        mult = 2 if self.wrap else 1
        return mult * min(self.dims) * self.link_bw


@dataclasses.dataclass
class Wafer2D(Torus2D):
    """Wafer-scale 2-D mesh: same fabric, no wraparound (paper SS6.2)."""
    wrap: bool = False
    name = "wafer2d"


@dataclasses.dataclass
class MultiPod(Topology):
    """Pods with an inner topology, connected by DCN (per-pod aggregate bw)."""
    inner: Topology = None
    n_pods: int = 2
    dcn_bw: float = 12.5e9
    dcn_latency: float = 10e-6
    name = "multipod"

    @property
    def pod_size(self):
        return self.n_ranks // self.n_pods

    def pod_of(self, r):
        return r // self.pod_size

    def hop_distance(self, a, b):
        if self.pod_of(a) == self.pod_of(b):
            return self.inner.hop_distance(a % self.pod_size, b % self.pod_size)
        return 4  # host -> DCN -> host

    def ring_bw(self, group):
        pods = {self.pod_of(r) for r in group}
        if len(pods) == 1:
            return self.inner.ring_bw([r % self.pod_size for r in group])
        # cross-pod ring is limited by DCN
        return self.dcn_bw

    def bisection_bw(self):
        return self.dcn_bw * self.n_pods / 2


def build_topology(system, n_ranks: int = None) -> Topology:
    """SystemConfig -> Topology."""
    n = n_ranks or system.chips
    kw = dict(n_ranks=n, link_bw=system.link_bw,
              link_latency=system.link_latency)
    t = system.topology
    if t == "switch":
        return Switch(**kw)
    if t == "ring":
        return Ring(**kw)
    if t == "wafer2d":
        return Wafer2D(**kw)
    if t == "torus3d":
        side = round(n ** (1 / 3))
        return Torus2D(dims=(side, n // side), **kw)   # folded 3d approx
    if t == "multipod":
        side = int(math.sqrt(n // 2))
        inner = Torus2D(n_ranks=n // 2, link_bw=system.link_bw,
                        link_latency=system.link_latency)
        return MultiPod(inner=inner, n_pods=2, dcn_bw=system.dcn_bw,
                        dcn_latency=system.dcn_latency, **kw)
    return Torus2D(**kw)
