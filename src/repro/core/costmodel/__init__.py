from repro.core.costmodel.topology import (Topology, Switch, Ring, Torus2D,
                                           Wafer2D, MultiPod, RankProfile,
                                           build_topology)
from repro.core.costmodel.collectives import (collective_time,
                                              synthesize_2d_time,
                                              synthesize_2d_p2p)
from repro.core.costmodel.compiled import CompiledGraph, compile_graph
from repro.core.costmodel.simulator import (simulate, simulate_analytic,
                                            simulate_batch, simulate_cluster,
                                            straggler_analysis, SimResult,
                                            ClusterSimResult, node_duration,
                                            peak_memory_proxy)
from repro.core.costmodel.delta import DeltaBase, delta_base
from repro.core.costmodel.mpmd import (MPMDProgram, ClusterProgramError,
                                       simulate_mpmd, collective_fingerprint)
from repro.core.costmodel.analytical import (roofline, RooflineTerms,
                                             model_flops_per_step)

__all__ = ["Topology", "Switch", "Ring", "Torus2D", "Wafer2D", "MultiPod",
           "RankProfile", "build_topology", "collective_time",
           "synthesize_2d_time", "synthesize_2d_p2p", "CompiledGraph",
           "compile_graph", "simulate", "simulate_analytic", "simulate_batch",
           "simulate_cluster", "straggler_analysis", "SimResult",
           "ClusterSimResult", "node_duration", "peak_memory_proxy",
           "DeltaBase", "delta_base",
           "MPMDProgram", "ClusterProgramError", "simulate_mpmd",
           "collective_fingerprint",
           "roofline", "RooflineTerms", "model_flops_per_step"]
