from repro.core.costmodel.topology import (Topology, Switch, Ring, Torus2D,
                                           Wafer2D, MultiPod, build_topology)
from repro.core.costmodel.collectives import (collective_time,
                                              synthesize_2d_time,
                                              synthesize_2d_p2p)
from repro.core.costmodel.simulator import simulate, SimResult, node_duration
from repro.core.costmodel.analytical import (roofline, RooflineTerms,
                                             model_flops_per_step)

__all__ = ["Topology", "Switch", "Ring", "Torus2D", "Wafer2D", "MultiPod",
           "build_topology", "collective_time", "synthesize_2d_time",
           "synthesize_2d_p2p", "simulate", "SimResult", "node_duration",
           "roofline", "RooflineTerms", "model_flops_per_step"]
