"""True MPMD cluster model: per-rank workload *graphs* under shared
collective barriers.

The SPMD engine (``simulator.simulate_cluster`` on one graph) models a
cluster as "one graph, K duration rows" — every rank runs the same program.
Pipeline stages, expert-parallel MoE ranks and asymmetric training/serving
colocations break that assumption: each rank (or pool of ranks) runs its
*own* graph, and only the collectives stitch the timelines together.  This
module supplies the missing substrate:

  * ``MPMDProgram`` — rank -> Graph mapping (a dense list or ``{rank: g}``
    dict).  Graphs shared by several ranks (by object identity) are stored
    once; "ranks sharing a graph and profile" is the unit the coalescer
    works at, so a 64-rank program made of two 32-rank pools costs two
    event loops.
  * ``simulate_mpmd`` — the K-graph cluster engine, built on
    ``compiled.run_rows``.  Group attrs are read *literally*: a collective
    with ``group=[2, 5]`` synchronizes cluster ranks 2 and 5, full stop
    (no SPMD instance tiling).  A collective whose group omits a rank
    never blocks that rank (ragged participation); a rank outside a
    collective's group that still carries the node runs it locally.
    Group members outside 0..K-1 are clipped to the cluster — the SPMD
    whole-world idiom (a graph captured for a larger cluster still runs
    on a prefix; this is what keeps K identical ``group=range(16)``
    graphs bit-identical to ``simulate()`` on 4 ranks).  A group left
    with fewer than two in-cluster members is barrier-free.
  * ``ClusterProgramError`` — raised when per-rank programs disagree about
    a shared collective: a member rank whose graph omits an instance its
    group claims, or ranks issuing different collective kinds at the same
    per-group program index.  Both are real-cluster hangs; the error names
    the rank, the collective fingerprint and the program index instead of
    deadlocking silently.

Barrier identity
----------------
Node ids are rank-local in MPMD, so cross-rank barriers cannot key on them.
Instead a barrier is keyed by ``(group, k)``: the k-th collective with that
participant group in each member rank's *canonical program order* (the
commit order of the rank's nominal schedule, ``canonical_coll_order`` — the
same order the engine's program-order discipline enforces, so barriers
always resolve in issue order and the engine is deadlock-free by
construction).  Kinds are validated pairwise across members at each index;
payloads may differ and the barrier is priced at the weakest member
(max over member rows' own durations), exactly like the SPMD engine.

Equivalence contracts (property-tested by tests/test_mpmd_properties.py):
identical graphs on every rank are bit-identical to the single-graph
``simulate_cluster`` *and* to ``simulate()``; coalesced == naive
(``coalesce=False``) per-rank results; a barrier never starts before its
slowest participant arrives.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence

from repro.core import chakra
from repro.core.costmodel.compiled import (RowSpec, compile_graph,
                                           result_cache_put, run_rows)
from repro.core.costmodel.simulator import (ClusterSimResult,
                                            _assemble_cluster_result,
                                            _copy_cluster_result, _override,
                                            _parse_rank_durations,
                                            _parse_rank_profiles, _rank_row)
from repro.core.costmodel.topology import RankProfile, Topology, build_topology
from repro.obs import record as obs


class ClusterProgramError(ValueError):
    """Per-rank programs disagree about a shared collective — a mismatch
    that would hang a real cluster.  Carries the offending ``rank``, the
    collective ``fingerprint`` (``kind|r0,r1,...``) and the per-group
    program ``index`` for tooling."""

    def __init__(self, msg: str, rank: Optional[int] = None,
                 fingerprint: Optional[str] = None,
                 index: Optional[int] = None):
        super().__init__(msg)
        self.rank = rank
        self.fingerprint = fingerprint
        self.index = index


def collective_fingerprint(kind: str, group: Sequence[int]) -> str:
    """Stable cross-rank identity of a collective: kind + sorted member
    ranks.  Node ids and names are rank-local in MPMD; this string is what
    diagnostics (and the barrier planner conceptually) key on."""
    return f"{kind}|{','.join(str(r) for r in sorted({int(x) for x in group}))}"


class MPMDProgram:
    """A cluster-wide MPMD workload: rank r runs ``graph_for(r)``.

    Accepts a dense sequence of Graphs (rank = position) or a ``{rank:
    Graph}`` dict covering ranks 0..K-1.  Graphs repeated across ranks (by
    object identity) are deduplicated — pass the *same* Graph object for
    every rank of a symmetric pool so the engine can coalesce the pool into
    one event-loop row.
    """

    def __init__(self, rank_graphs, meta: Optional[Dict] = None):
        if isinstance(rank_graphs, dict):
            K = len(rank_graphs)
            if sorted(rank_graphs) != list(range(K)):
                raise ValueError(
                    "rank->graph mapping must cover ranks 0..K-1 densely; "
                    f"got ranks {sorted(rank_graphs)[:8]}...")
            seq = [rank_graphs[r] for r in range(K)]
        else:
            seq = list(rank_graphs)
        if not seq:
            raise ValueError("MPMDProgram needs >= 1 rank")
        self.graphs: List[chakra.Graph] = []
        self.graph_of: List[int] = []
        index: Dict[int, int] = {}
        for g in seq:
            if not isinstance(g, chakra.Graph):
                raise TypeError(f"MPMDProgram wants chakra.Graph per rank, "
                                f"got {type(g).__name__}")
            gi = index.get(id(g))
            if gi is None:
                gi = index[id(g)] = len(self.graphs)
                self.graphs.append(g)
            self.graph_of.append(gi)
        self.meta: Dict = dict(meta or {})
        # per-program result memo (mirrors CompiledGraph._result_cache);
        # entries are keyed on the member graphs' edit tokens, so in-place
        # graph edits invalidate naturally
        self._result_cache: Dict = {}

    @property
    def n_ranks(self) -> int:
        return len(self.graph_of)

    @property
    def n_graphs(self) -> int:
        return len(self.graphs)

    def graph_for(self, rank: int) -> chakra.Graph:
        return self.graphs[self.graph_of[rank]]

    def __getstate__(self):
        """Process-pool support: graphs + rank map pickle naturally (graph
        dedup survives — pickle preserves shared references), but the
        volatile result memo is dropped to keep payloads small; each
        worker re-fills its own.  Memo keys are content-derived edit
        tokens, so semantics are unchanged either way."""
        state = self.__dict__.copy()
        state["_result_cache"] = {}
        return state

    def __repr__(self) -> str:
        return (f"MPMDProgram(n_ranks={self.n_ranks}, "
                f"n_graphs={self.n_graphs})")


def _group_key(group) -> tuple:
    return tuple(sorted({int(x) for x in group}))


def simulate_mpmd(prog: MPMDProgram, system,
                  topo: Optional[Topology] = None,
                  n_ranks: Optional[int] = None,
                  rank_profiles=None, rank_durations: Optional[Dict] = None,
                  algo: str = "auto", overlap: bool = True,
                  compute_derate: float = 0.6,
                  keep_timeline: bool = False,
                  coalesce: bool = True,
                  memoize: bool = True) -> ClusterSimResult:
    """Simulate one step of an MPMD program on a K-rank cluster.

    Same contract as ``simulator.simulate_cluster`` (which dispatches here
    for non-Graph workloads): `rank_profiles`/`rank_durations` skew
    individual ranks, per-link overrides come from ``topo.link_scales``,
    `coalesce=False` runs one row per rank as the executable spec of the
    class coalescing.  `n_ranks`, when given, must agree with the
    program's rank count.  Timeline-free results are memoized on the
    *program* (keyed by the member graphs' edit tokens plus the cluster
    config, so in-place graph edits invalidate); `memoize=False` bypasses
    the memo both ways — the fault-horizon benchmark's naive baseline.

    Raises ``ClusterProgramError`` for mismatched per-rank collective
    sequences (see module docstring) rather than hanging.
    """
    topo = topo or build_topology(system)
    K = prog.n_ranks
    if n_ranks is not None and int(n_ranks) != K:
        raise ValueError(f"n_ranks={n_ranks} disagrees with the MPMD "
                         f"program's {K} ranks")
    cgs = [compile_graph(g) for g in prog.graphs]

    default_prof = RankProfile()
    profs = _parse_rank_profiles(rank_profiles, K)
    rdur = _parse_rank_durations(rank_durations, K)
    tls = getattr(topo, "link_scales", None) or {}

    rel_R = int(prog.meta.get("p2p_replicas") or 0)

    ckey = None
    if not keep_timeline and memoize:
        ckey = (tuple(g._token() for g in prog.graphs),
                tuple(prog.graph_of), rel_R,
                cgs[0].config_key(system, topo, algo, compute_derate),
                overlap, coalesce, tuple(sorted(profs.items())),
                tuple(sorted((r, tuple(sorted(od.items())))
                             for r, od in rdur.items())))
        hit = prog._result_cache.get(ckey)
        if hit is not None:
            obs.counter("mpmd.memo.hit")
            return _copy_cluster_result(hit)
        obs.counter("mpmd.memo.miss")

    bases = [cg.durations(system, topo, algo, compute_derate) for cg in cgs]

    # canonical per-graph collective program: (nid, kind, sequence-key) in
    # the order the rank issues them (= the nominal schedule's commit
    # order, which the engine's program-order discipline also enforces).
    # A sequence key identifies one FIFO channel, not just a rank group:
    # literal groups key on (group, p2p channel) — several pipeline
    # channels (forward vs grad, multiple virtual-stage chunks) can share
    # one rank pair and must never pair across channels — and
    # replica-shared stage graphs (``prog.meta["p2p_replicas"]``,
    # costmodel.schedule) key their p2p nodes on the *relative* (src
    # stage, dst stage, channel), expanded into per-replica barrier
    # instances below (the group-indirection layer that lets all replicas
    # of a stage share one compiled graph).
    # microbatched pipeline graphs (costmodel.schedule) emit their nodes in
    # schedule order, so ascending node id IS the rank's collective launch
    # order; the isolated-run canonical order would instead defer the
    # dangling (fire-and-forget) sends past later recvs and deadlock the
    # program-order discipline
    orders = [sorted(cg._coll_ids)
              if int(g.meta.get("num_microbatches") or 1) > 1
              else cg.canonical_coll_order(base, overlap=overlap)
              for g, cg, base in zip(prog.graphs, cgs, bases)]
    colls: List[List[tuple]] = []
    for cg, order in zip(cgs, orders):
        meta = {nid: m for nid, m in zip(cg._coll_ids, cg._coll_meta)}
        seq = []
        for nid in order:
            kind, group, _gt, chan, rel = meta[nid]
            if rel_R > 1 and kind == "p2p" and rel is not None:
                key = ("rel", rel[0], rel[1], chan)
            else:
                key = ("lit", _group_key(group), chan)
            seq.append((nid, kind, key))
        colls.append(seq)

    def _rank_in(key: tuple, r: int) -> bool:
        if key[0] == "lit":
            return r in key[1]
        return r // rel_R in (key[1], key[2])

    def _members_of(key: tuple) -> List[int]:
        if key[0] == "lit":
            return [r for r in key[1] if 0 <= r < K]
        return [r for st in (key[1], key[2])
                for r in range(st * rel_R, (st + 1) * rel_R) if 0 <= r < K]

    # relative p2p instance pricing: the shared stage graph's literal
    # ``group`` attr (and hence its base duration) is replica 0's pair, but
    # replica d's pair (a*R+d, b*R+d) can sit at a different hop distance /
    # link scale on a structured topology.  Price each replica's instances
    # through the same ``collective_time`` path literal per-replica graphs
    # take, so sharing stays bit-identical to ``share_replica_graphs=False``
    # — the signature also feeds the class key below, splitting replicas
    # whose links genuinely differ.
    rel_price_memo: Dict = {}

    def _rel_prices(gi: int, d: int, lscale: Optional[float] = None):
        key = (gi, d, lscale)
        hit = rel_price_memo.get(key)
        if hit is None:
            from repro.core.costmodel.collectives import collective_time
            cg = cgs[gi]
            out = []
            for nid, (kind, _grp, _gt, _chan, rel) in zip(cg._coll_ids,
                                                          cg._coll_meta):
                if kind != "p2p" or rel is None:
                    continue
                inst = [rel[0] * rel_R + d, rel[1] * rel_R + d]
                out.append((nid, collective_time(
                    "p2p", float(cg.comm_bytes[nid]), inst, topo, algo,
                    bw_scale=lscale)))
            hit = rel_price_memo[key] = tuple(out)
        return hit

    # rank equivalence classes: ranks sharing (graph, hardware behavior,
    # collective membership) are one behavioral class.  Literal groups
    # put two same-class ranks in the *same* barrier instance, so a class
    # row's arrival represents all of its members at once with no
    # partition-refinement fixpoint (unlike the SPMD tiling).
    init_keys = []
    for r in range(K):
        gi = prog.graph_of[r]
        od = rdur.get(r)
        okey = tuple(sorted(od.items())) if od else None
        mem = tuple(sorted({skey for (_, _, skey) in colls[gi]
                            if _rank_in(skey, r)}, key=repr))
        rel_sig = _rel_prices(gi, r % rel_R) if rel_R > 1 else None
        init_keys.append((gi, profs.get(r, default_prof),
                          tls.get(r, 1.0), okey, mem, rel_sig))
    if coalesce:
        seen: Dict = {}
        colors = [seen.setdefault(k, len(seen)) for k in init_keys]
    else:
        colors = list(range(K))
    if coalesce and rel_R > 1:
        # relative p2p instances DO need a refinement fixpoint: replicas
        # of a stage share a class only while their per-replica partners
        # share one too (a slow replica on the far side must split its
        # partners off, or one barrier instance would mis-represent them).
        # Signature = own color + partner colors across relative pairs;
        # iterate to the coarsest stable partition (splits only, so it
        # terminates; symmetric replicas stay coalesced).
        rel_pairs = sorted({(skey[1], skey[2]) for seq in colls
                            for (_n, _k, skey) in seq if skey[0] == "rel"})
        while rel_pairs:
            sigs = []
            for r in range(K):
                st, d = r // rel_R, r % rel_R
                sig = []
                for a, b in rel_pairs:
                    if st == a:
                        q = b * rel_R + d
                    elif st == b:
                        q = a * rel_R + d
                    else:
                        continue
                    sig.append((a, b, colors[q] if 0 <= q < K else -1))
                sigs.append((colors[r], tuple(sig)))
            seen_r: Dict = {}
            refined = [seen_r.setdefault(sg, len(seen_r)) for sg in sigs]
            if refined == colors:
                break
            colors = refined
    n_classes = max(colors) + 1
    # coalescing effectiveness: event-loop rows actually paid vs ranks
    obs.counter("mpmd.coalesce.classes", n_classes)
    obs.counter("mpmd.coalesce.ranks", K)
    reps: List[Optional[int]] = [None] * n_classes
    for r in range(K):
        if reps[colors[r]] is None:
            reps[colors[r]] = r
    class_graph = [prog.graph_of[rep] for rep in reps]

    # per-class duration rows (shared across classes with the same
    # (graph, hardware) key; rank_durations overrides applied on a copy)
    reprice = bool(tls)
    row_memo: Dict = {}
    rows_dur: List[List[float]] = []
    for rep in reps:
        gi = prog.graph_of[rep]
        p = profs.get(rep, default_prof)
        ls = p.link_scale * tls.get(rep, 1.0)
        rkey = (gi, p, ls)
        row = row_memo.get(rkey)
        if row is None:
            row = _rank_row(cgs[gi], system, topo, algo, compute_derate,
                            bases[gi], p, ls, reprice)
            row_memo[rkey] = row
        if rel_R > 1:
            # replica-d instance prices (mirrors _rank_row's repricing
            # semantics: rank's own link scale when one is in force,
            # else the instance group's weakest-member default)
            ov = _rel_prices(gi, rep % rel_R,
                             ls if (ls != 1.0 or reprice) else None)
            if any(row[nid] != pr for nid, pr in ov):
                row = list(row)
                for nid, pr in ov:
                    row[nid] = pr
        od = rdur.get(rep)
        if od:
            row = _override(row, od)
        rows_dur.append(row)

    # per-graph, per-channel collective sequences (canonical order), the
    # substrate of barrier keying AND of the ragged-sequence validation
    gseq: List[Dict[tuple, List[tuple]]] = []
    for seq in colls:
        d: Dict[tuple, List[tuple]] = {}
        for nid, kind, skey in seq:
            if skey[0] == "rel" or len(skey[1]) >= 2:
                d.setdefault(skey, []).append((nid, kind))
        gseq.append(d)

    barrier_maps: List[Dict[int, list]] = [dict() for _ in range(n_classes)]
    any_barrier = False
    for skey in sorted({g for d in gseq for g in d}, key=repr):
        members = _members_of(skey)
        if len(members) < 2:
            continue
        gdesc = skey[1] if skey[0] == "lit" else tuple(members)
        mclasses: List[int] = []
        for r in members:
            c = colors[r]
            if c not in mclasses:
                mclasses.append(c)
        seqs = {c: gseq[class_graph[c]].get(skey, []) for c in mclasses}
        want = max(len(s) for s in seqs.values())
        for k in range(want):
            kinds: Dict[int, str] = {}
            for c in mclasses:
                s = seqs[c]
                if len(s) <= k:
                    r_bad = next(r for r in members if colors[r] == c)
                    c_ok = next(c2 for c2 in mclasses if len(seqs[c2]) > k)
                    fp = collective_fingerprint(seqs[c_ok][k][1], gdesc)
                    raise ClusterProgramError(
                        f"rank {r_bad}'s graph omits instance {k} of "
                        f"collective {fp}: the group claims its "
                        f"participation but the rank's program only issues "
                        f"{len(s)} instance(s) — a real cluster would hang "
                        f"at this barrier", rank=r_bad, fingerprint=fp,
                        index=k)
                kinds[c] = s[k][1]
            if len(set(kinds.values())) > 1:
                c_a = mclasses[0]
                c_b = next(c for c in mclasses if kinds[c] != kinds[c_a])
                r_bad = next(r for r in members if colors[r] == c_b)
                fp = collective_fingerprint(kinds[c_b], gdesc)
                raise ClusterProgramError(
                    f"mismatched collective sequences: at group program "
                    f"index {k} rank {r_bad} issues {fp} where its peers "
                    f"issue {collective_fingerprint(kinds[c_a], gdesc)}",
                    rank=r_bad, fingerprint=fp, index=k)
        if len(mclasses) < 2:
            continue           # one behavioral class: resolves at arrival
        # a literal group is one barrier instance spanning all member
        # classes; a relative p2p channel is one instance per replica,
        # deduplicated by class signature (at the refinement fixpoint all
        # instances touching a class share its partner classes, so one
        # barrier per distinct signature represents them exactly)
        if skey[0] == "lit":
            instances = [members]
        else:
            a, b = skey[1], skey[2]
            instances = [[x for x in (a * rel_R + d, b * rel_R + d)
                          if 0 <= x < K] for d in range(rel_R)]
        for k in range(want):
            seen_w = set()
            for inst in instances:
                W = tuple(sorted({colors[r] for r in inst}))
                if len(W) < 2 or W in seen_w:
                    continue
                seen_w.add(W)
                nid_by_row = {c: seqs[c][k][0] for c in W}
                b = [len(W), 0.0, W,
                     max(rows_dur[c][nid_by_row[c]] for c in W),
                     {}, nid_by_row]
                for c in W:
                    barrier_maps[c][nid_by_row[c]] = b
                any_barrier = True

    specs = []
    for c in range(n_classes):
        gi = class_graph[c]
        specs.append(RowSpec(cgs[gi], rows_dur[c], barrier_maps[c],
                             orders[gi] if any_barrier else None))
    results, waits = run_rows(specs, overlap=overlap,
                              keep_timeline=keep_timeline)
    res = _assemble_cluster_result(K, colors, reps, results, waits)
    if ckey is not None:
        # fresh copies both ways: callers may post-process in place
        result_cache_put(prog._result_cache, ckey,
                         _copy_cluster_result(res))
    return res
