"""Delta (incremental) re-simulation: re-run only the schedule suffix a
duration change can reach.

Most DSE neighbors share a compiled graph and differ in a handful of
duration rows — a repriced collective, a straggler's compute rows, a
fault window late in the step.  A full ``run()`` replays every scheduling
decision anyway.  ``DeltaBase`` runs the base duration vector *once*,
checkpointing the engine state (``compiled._RunState``) every
``n / n_checkpoints`` scheduling decisions plus the commit order and
per-node finish times; a delta run then restores the last checkpoint at
or before the first decision that can observe a changed duration and
replays only the remaining suffix.

Soundness (why this is bit-identical, not approximate)
------------------------------------------------------
The event loop reads ``dur[nid]`` at exactly one instant: the decision
that schedules ``nid``.  Every decision before the first scheduling of a
changed node therefore evolves the engine state identically under the
base and the perturbed vector — same heap layouts, same stream clocks,
same float accumulation order.  Restoring a checkpoint taken at decision
``t* = min(schedule position of changed nodes)`` or earlier and running
the *same* loop forward is indistinguishable from a full run with the
perturbed vector.  There is no fixed-order approximation and no fallback
condition: the suffix replay re-makes every scheduling decision, so
schedule changes caused by the perturbation are handled exactly.  In a
two-stream machine the cone of influence of a changed row is conservatively
the entire schedule suffix from its first occurrence (stream serialization
couples everything scheduled later); the win is skipping the prefix.

When it pays
------------
Speedup is ``n / (n - snap)`` where ``snap`` is the restored decision
index — large when changes sit late in the base schedule (straggler
tails, fault windows, optimizer-phase calibration), ~1x (plus an O(n)
restore) when a changed row is scheduled early.  Worst case is a full
replay plus one state copy; results are bit-identical either way
(property-tested on randomized DAGs, tests/test_delta.py).

``simulate_batch(..., delta=...)`` and the cluster engine's single-class
path route through the per-graph ``delta_base`` memo; zero-changed
overrides return a copy of the base result without touching the engine.
"""
from __future__ import annotations

import dataclasses
from bisect import bisect_right
from typing import Dict, List, Optional

from repro.core.costmodel.compiled import CompiledGraph, result_cache_put
from repro.obs import record as obs

# per-CompiledGraph cap on memoized DeltaBase instances (each holds
# n_checkpoints O(n) snapshots — a handful of configs is plenty)
DELTA_CACHE_CAP = 8
DEFAULT_CHECKPOINTS = 16


class DeltaBase:
    """One checkpointed base run of ``cg`` under ``dur``; ``run(overrides)``
    re-simulates any per-node override dict bit-identically to a full
    ``cg.run``.

    Attributes: ``result`` (the base ``SimResult``), ``schedule`` (node ids
    in commit order), ``finish`` (per-node finish times of the base run —
    the checkpointed quantities delta runs resume from).
    """

    def __init__(self, cg: CompiledGraph, dur: List[float],
                 overlap: bool = True, keep_timeline: bool = False,
                 n_checkpoints: int = DEFAULT_CHECKPOINTS):
        if len(dur) != cg.n:
            raise ValueError(f"duration vector has {len(dur)} entries for "
                             f"a {cg.n}-node graph")
        self.cg = cg
        self._src = dur                   # identity guard for the id() memo
        self.dur = list(dur)
        self.overlap = bool(overlap)
        self.keep_timeline = bool(keep_timeline)
        obs.counter("delta.base_builds")
        n = cg.n
        record: List = []
        snaps = []
        st = cg._fresh_state(self.overlap, self.keep_timeline)
        step = max(1, -(-n // max(1, int(n_checkpoints)))) if n else 1
        while st.scheduled < n:
            snaps.append((st.scheduled, st.copy()))
            cg._run_span(st, self.dur, self.overlap,
                         min(n, st.scheduled + step), record=record)
        self.result = cg._finalize(st)
        self._snaps = snaps
        self._snap_idx = [i for i, _ in snaps]
        self._peak_cache: Dict[int, tuple] = {}   # lazy, see _prefix_peak
        self.schedule: List[int] = [nid for nid, _ in record]
        self.finish: List[float] = [0.0] * n
        pos_of = [0] * n
        for i, (nid, end) in enumerate(record):
            pos_of[nid] = i
            self.finish[nid] = end
        self._pos_of = pos_of

    @property
    def n_checkpoints(self) -> int:
        return len(self._snaps)

    def earliest_decision(self, overrides: Optional[Dict]) -> int:
        """Base-schedule position of the first decision that can observe
        `overrides` (= position of the earliest-scheduled genuinely-changed
        node); ``cg.n`` when nothing changes.  Ids outside the graph are
        ignored and an override equal to the base value is not a change —
        matching ``simulator._override`` semantics."""
        n = self.cg.n
        t = n
        if overrides:
            base = self.dur
            pos_of = self._pos_of
            for nid, v in overrides.items():
                if 0 <= nid < n and base[nid] != v:
                    p = pos_of[nid]
                    if p < t:
                        t = p
        return t

    def _prefix_peak(self, k: int):
        """Lazy per-checkpoint summary for incremental exact peaks:
        ``(n_prefix_events, live_at_T, peak_low, high_events)``.

        ``T = min(sf0, sf1)`` at the checkpoint: every event a suffix
        replay appends has ``t >= T`` (a replayed node starts at or after
        its stream's clock, and frees/transients carry times at or after
        that start), so the liveness events split cleanly into the fixed
        prefix strictly below ``T`` — scanned once here — and a tail
        (``high_events`` + whatever the replay appends) that each delta
        run re-scans from the carried-over occupancy ``live_at_T``.  No
        timestamp group straddles the split, so per-breakpoint maxima
        compose exactly.  Only used under the ``_mem_integral``
        certificate, where every running sum is exact (see exact_peak)."""
        hit = self._peak_cache.get(k)
        if hit is None:
            snap = self._snaps[k][1]
            t_split = snap.sf0 if snap.sf0 < snap.sf1 else snap.sf1
            low, high = [], []
            for e in snap.mem_events:
                (low if e[0] < t_split else high).append(e)
            low.sort()
            live = peak = 0.0
            for e in low:
                live += e[1]
                if live > peak:
                    peak = live
            hit = self._peak_cache[k] = (len(snap.mem_events), live, peak,
                                         high)
        return hit

    def run(self, overrides: Optional[Dict] = None):
        """SimResult under ``base durations + overrides``, bit-identical to
        ``cg.run(_override(base, overrides), overlap, keep_timeline)``."""
        cg = self.cg
        n = cg.n
        t_star = self.earliest_decision(overrides)
        if t_star >= n:
            # nothing changed: the base result, as a fresh copy (callers may
            # post-process in place, mirroring simulate()'s memo contract)
            obs.counter("delta.zero_change")
            res = dataclasses.replace(self.result)
            if res.timeline is not None:
                res.timeline = list(res.timeline)
            if res.mem_events is not None:
                res.mem_events = list(res.mem_events)
            return res
        k = bisect_right(self._snap_idx, t_star) - 1
        st = self._snaps[k][1].copy()
        # replay fraction: (n - resumed-at) / n of the schedule re-decided
        obs.counter("delta.replays")
        obs.counter("delta.replayed_decisions", n - st.scheduled)
        obs.counter("delta.total_decisions", n)
        dur = self.dur[:]
        for nid, v in overrides.items():
            if 0 <= nid < n:
                dur[nid] = v
        cg._run_span(st, dur, self.overlap, n)
        if not cg._mem_integral:
            return cg._finalize(st)
        # incremental exact peak: scan only the checkpoint's boundary
        # events + the replayed suffix instead of the whole event list
        n_prefix, live, peak, high = self._prefix_peak(k)
        tail = high + st.mem_events[n_prefix:]
        tail.sort()
        for e in tail:
            live += e[1]
            if live > peak:
                peak = live
        return cg._finalize(st, peak_bytes=peak)


def delta_base(cg: CompiledGraph, dur: List[float], overlap: bool = True,
               keep_timeline: bool = False,
               n_checkpoints: int = DEFAULT_CHECKPOINTS,
               key=None, build: bool = True) -> Optional[DeltaBase]:
    """Memoized ``DeltaBase`` per (config, overlap, keep_timeline) on the
    compiled graph.

    `key` should be a hashable config identity (e.g. ``(config_key,)``);
    without one the memo keys on ``id(dur)`` with an identity guard, which
    works for the memoized read-only lists ``durations()`` returns.
    ``build=False`` only peeks: it returns an existing base or None — the
    opportunistic hook ``simulate``/``simulate_cluster`` use so cold paths
    pay nothing."""
    ck = ((key if key is not None else id(dur)),
          bool(overlap), bool(keep_timeline))
    hit = cg._delta_cache.get(ck)
    if hit is not None and (key is not None or hit._src is dur):
        obs.counter("delta.memo.hit")
        return hit
    if not build:
        return None
    obs.counter("delta.memo.miss")
    db = DeltaBase(cg, dur, overlap=overlap, keep_timeline=keep_timeline,
                   n_checkpoints=n_checkpoints)
    result_cache_put(cg._delta_cache, ck, db, cap=DELTA_CACHE_CAP)
    return db
