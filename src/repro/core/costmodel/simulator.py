"""Dependency-driven discrete-event simulator (ASTRA-sim-class cost model).

Consumes a Chakra graph (rank-symmetric SPMD view), a SystemConfig and a
Topology; produces per-step duration, compute/comm busy times, exposed
(non-overlapped) communication, and peak memory via liveness.

Model: two in-order streams per rank — compute and communication — matching
TPU async collectives (and GPU comm streams).  A node starts when (a) all its
deps (data + ctrl) have finished and (b) its stream is free.  Durations:
  COMP      max(flops / (derate * peak_flops), bytes / hbm_bw)
  COMM_COLL collective_time(kind, payload, group, topo, algo)

Engines
-------
``simulate()`` is a thin wrapper over two interchangeable engines:

  * ``engine="compiled"`` (default) lowers the graph once into flat CSR
    arrays (``costmodel.compiled.CompiledGraph``), memoized on the Graph and
    keyed by its edit token, with per-(system, topo, algo, derate) duration
    vectors memoized on the compiled form.  Repeated calls — DSE sweeps,
    straggler batches — skip all O(N+E) set/dict rebuilding.
  * ``engine="reference"`` is the original object-walking loop, kept as the
    executable spec: the compiled engine must return bit-identical
    ``SimResult``s (enforced by tests/test_compiled_sim.py).

Busy-time accounting is by *node type*, not by stream: with
``overlap=False`` every node runs on the compute stream, but
``compute_time``/``comm_time``/``exposed_comm`` still mean what they say
(previously exposed_comm degenerated to 0 because comm time was counted as
compute-stream busy time).

``simulate_batch()`` amortizes compilation across many duration-override
runs (straggler sweeps, sensitivity analyses).

Cluster model (``simulate_cluster``)
------------------------------------
``simulate_cluster()`` drops the rank-symmetric assumption: K ranks each
replay the SPMD graph on their own compute+comm stream pair, with per-rank
durations derived from ``RankProfile``s (mixed chip generations, degraded
hosts) and per-link bandwidth overrides (flapping NICs, degraded pods), and
COMM_COLL nodes acting as cross-rank barriers — a collective completes only
when its slowest participating rank arrives, and its cost (priced by the
weakest member's links) is charged from that arrival.  Ranks are first
coalesced into behavioral equivalence classes (same profile, isomorphic
collective-group environment), so the engine cost scales with the number of
*distinct* rank behaviors, not the cluster size: a fully symmetric K-rank
cluster costs exactly one event loop and is bit-identical to ``simulate()``
for every K (the cluster-free property, enforced by
tests/test_cluster_sim.py).  Collective participant instances are mapped
from the node's group attr: consecutive groups tile the cluster in blocks
of the group size (the standard mesh ordering), constant-stride and
explicitly-listed groups map their own interleaved/translated instances
(``_group_instances``).  Timeline-free results are memoized per
(config, profile-set) on the compiled graph, mirroring ``simulate()``.

``straggler_analysis`` is built on it: a straggler is one slowed rank
gating barriers — fast ranks accumulate attributable barrier wait while
their own compute runs ahead — rather than the old single-timeline proxy.
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, NamedTuple, Optional, Sequence

import numpy as np

from repro.core import chakra
from repro.core.costmodel.collectives import collective_time
from repro.obs import record as obs
from repro.core.costmodel.compiled import (CompiledGraph, compile_graph,
                                           exact_peak, result_cache_put)
from repro.core.costmodel.topology import (RankProfile, Topology,
                                           build_topology)


class Span(NamedTuple):
    """One scheduled node occurrence — the unit the trace subsystem
    (repro.trace) exports.  Tuple-compatible with the historical timeline
    entries ``(nid, name, stream, start, end)``; ``wait`` is the barrier
    wait included in ``[start, end)`` (nonzero only for collectives gated
    by a cross-rank barrier in cluster runs — ``repro.obs.explain`` uses
    it to split waited time from transfer cost)."""
    nid: int
    name: str
    stream: str                   # "comp" | "comm"
    start: float                  # seconds
    end: float                    # seconds
    wait: float = 0.0             # seconds blocked at a barrier, in-span

    @property
    def duration(self) -> float:
        return self.end - self.start


@dataclasses.dataclass
class SimResult:
    total_time: float
    compute_time: float           # busy time of COMP/MEM nodes
    comm_time: float              # busy time of COMM_* nodes
    exposed_comm: float           # comm time not hidden by compute
    peak_bytes: float             # schedule-aware peak occupancy (bytes):
                                  # exact max of the liveness curve over the
                                  # *scheduled* timeline, incl. transient
                                  # comm buffers (analytic engines report
                                  # the topo-order proxy instead)
    n_nodes: int
    timeline: Optional[List] = None
    # (t, delta_bytes, nid) liveness events behind peak_bytes; nid >= 0 is
    # the producing node's out_bytes tensor, nid < 0 a transient comm
    # buffer of node ~nid.  Kept only with keep_timeline=True — the raw
    # material of ``repro.obs.memory``'s occupancy curves.
    mem_events: Optional[List] = None

    def as_dict(self):
        d = dataclasses.asdict(self)
        d.pop("timeline")
        d.pop("mem_events")
        return d

    def spans(self) -> List[Span]:
        """Timeline as ``Span`` records; requires ``keep_timeline=True``."""
        if self.timeline is None:
            raise ValueError("no timeline recorded: re-run simulate() with "
                             "keep_timeline=True")
        return [e if isinstance(e, Span) else Span(*e)
                for e in self.timeline]


def node_duration(n: chakra.Node, system, topo: Topology,
                  algo: str = "auto", compute_derate: float = 0.6) -> float:
    if n.type == chakra.COMP:
        t_f = n.attrs.get("flops", 0.0) / (system.peak_flops * compute_derate)
        t_b = n.attrs.get("bytes", 0.0) / system.hbm_bw
        return max(t_f, t_b)
    if n.type == chakra.COMM_COLL:
        payload = n.attrs.get("comm_bytes", 0.0)
        group = n.attrs.get("group") or list(range(
            n.attrs.get("group_size", 1)))
        return collective_time(n.attrs.get("comm_kind", "all-reduce"),
                               payload, group, topo, algo)
    if n.type in (chakra.COMM_SEND, chakra.COMM_RECV):
        link_bw = topo.link_bw
        ls = getattr(topo, "link_scales", None)
        if ls:                      # weakest-link proxy, like collectives
            link_bw = link_bw * min(ls.values())
        return (n.attrs.get("comm_bytes", 0.0) / link_bw
                + topo.link_latency)
    return 0.0


_COMM_TYPES = (chakra.COMM_COLL, chakra.COMM_SEND, chakra.COMM_RECV)


def simulate(g: chakra.Graph, system, topo: Optional[Topology] = None,
             algo: str = "auto", overlap: bool = True,
             compute_derate: float = 0.6, durations: Optional[Dict] = None,
             keep_timeline: bool = False,
             engine: str = "compiled", delta: object = "auto") -> SimResult:
    """Time-ordered event-driven list scheduling: when a stream goes idle it
    picks the lowest-topo-position node among those whose deps have finished
    *by then* (a later-positioned ready node fills idle gaps — no artificial
    serialization).

    `durations` optionally overrides per-node durations ({nid: seconds});
    `engine` selects the compiled fast path or the reference loop.

    `delta` controls incremental re-simulation of override runs (see
    ``costmodel.delta``): ``"auto"`` reuses a checkpointed base run if one
    is already memoized for this config (e.g. by an earlier
    ``simulate_batch``) — zero cost when cold; ``True`` builds the base on
    first use; ``False`` forces plain full replays.  Results are
    bit-identical in every mode.
    """
    if engine == "reference":
        return _simulate_reference(g, system, topo, algo, overlap,
                                   compute_derate, durations, keep_timeline)
    if engine != "compiled":
        raise ValueError(f"unknown engine {engine!r}: "
                         "expected 'compiled' or 'reference'")
    topo = topo or build_topology(system)
    cg = compile_graph(g)
    # override-free, timeline-free runs are pure in (graph, config): memoize
    # the SimResult itself so repeated identical calls (DSE inner loop,
    # straggler nominal) are O(1)
    rkey = None
    if not durations and not keep_timeline:
        rkey = (cg.config_key(system, topo, algo, compute_derate), overlap)
        hit = cg._result_cache.get(rkey)
        if hit is not None:
            # fresh instance per call: SimResult is mutable and callers may
            # post-process in place — never hand out the cached object
            obs.counter("sim.result_cache.hit")
            return dataclasses.replace(hit)
        obs.counter("sim.result_cache.miss")
    dur = cg.durations(system, topo, algo, compute_derate)
    if durations:
        # the memoized base-duration list is the delta memo's identity key,
        # so bases built here, by simulate_batch, or by the cluster engine
        # are shared across all three entry points
        if delta is not False and engine == "compiled":
            from repro.core.costmodel import delta as _delta
            db = _delta.delta_base(cg, dur, overlap=overlap,
                                   keep_timeline=keep_timeline,
                                   build=(delta is True))
            if db is not None:
                return db.run(durations)
        dur = _override(dur, durations)
    res = cg.run(dur, overlap=overlap, keep_timeline=keep_timeline)
    if rkey is not None:
        result_cache_put(cg._result_cache, rkey, dataclasses.replace(res))
    return res


def _override(base: List[float], durations: Dict) -> List[float]:
    """Copy of `base` with per-node overrides; ids outside the graph are
    ignored, matching the reference engine's membership check."""
    n = len(base)
    dur = base[:]
    for nid, t in durations.items():
        if 0 <= nid < n:
            dur[nid] = t
    return dur


def simulate_analytic(g: chakra.Graph, system,
                      topo: Optional[Topology] = None, algo: str = "auto",
                      overlap: bool = True,
                      compute_derate: float = 0.6) -> SimResult:
    """Event-loop-free proxy fidelity: the same per-node durations as
    ``simulate()`` reduced to a roofline bound (step >= busier stream's busy
    time with overlap, >= their sum without), and ``peak_bytes`` from the
    topo-order liveness proxy instead of the scheduled timeline.

    The proxy/scheduled relation (property-tested in tests/test_memory.py):
    ``peak_bytes`` here equals ``peak_memory_proxy(g)`` exactly.  Under
    ``overlap=False`` the event engines visit exactly the canonical topo
    order (one stream, greedy lowest-position), so their out_bytes-only
    peak equals the proxy and their full ``peak_bytes`` — which adds
    transient comm buffers — is ``>=`` it.  Under ``overlap=True`` the
    two-stream schedule may reorder allocations, so the proxy is a
    *schedule-independent estimate*, not a bound.

    A strict lower bound on ``simulate()``'s ``total_time`` for the same
    config (dependencies can only add idle gaps), ~10-100x cheaper, and it
    preserves the gross ordering of configs — which is all a
    successive-halving rung needs to cull the losing 3/4 of a candidate pool
    before paying for full event-loop replays (see ``repro.search``)."""
    topo = topo or build_topology(system)
    cg = compile_graph(g)
    rkey = ("analytic", cg.config_key(system, topo, algo, compute_derate),
            overlap)
    hit = cg._result_cache.get(rkey)
    if hit is not None:
        return dataclasses.replace(hit)
    dur = cg.durations(system, topo, algo, compute_derate)
    total, comp, comm = cg.analytic_estimate(dur, overlap=overlap)
    res = SimResult(total_time=total, compute_time=comp, comm_time=comm,
                    exposed_comm=max(0.0, total - comp),
                    peak_bytes=cg.peak_memory_proxy(), n_nodes=cg.n,
                    timeline=None)
    result_cache_put(cg._result_cache, rkey, dataclasses.replace(res))
    return res


def peak_memory_proxy(g: chakra.Graph) -> float:
    """Analytical per-rank peak-memory proxy (bytes) — see
    ``CompiledGraph.peak_memory_proxy``.  The memory axis of a
    multi-objective DSE, priced without running the simulator."""
    return compile_graph(g).peak_memory_proxy()


def simulate_batch(g: chakra.Graph, system,
                   durations_list: Sequence[Optional[Dict]],
                   topo: Optional[Topology] = None, algo: str = "auto",
                   overlap: bool = True, compute_derate: float = 0.6,
                   delta: object = "auto") -> List[SimResult]:
    """Run one compiled graph under many duration-override dicts.

    Compiles once and reuses the cached base-duration vector, so a K-entry
    batch costs K event loops — no recompilation, no per-entry duration
    recomputation.  Each entry of `durations_list` is a {nid: seconds}
    override (or None for the base durations).

    `delta="auto"` (default) routes batches with >= 2 override entries
    through ``costmodel.delta``: a single checkpointed base run lets each
    entry replay only the schedule suffix its changed rows can reach —
    bit-identical to full replays (property-tested), and the base is
    memoized on the compiled graph so later batches and ``simulate(...,
    durations=...)`` calls reuse it.  ``True`` forces delta even for one
    entry; ``False`` disables it."""
    topo = topo or build_topology(system)
    cg = compile_graph(g)
    base = cg.durations(system, topo, algo, compute_derate)
    if delta == "auto":
        use_delta = sum(1 for ov in durations_list if ov) >= 2
    else:
        use_delta = bool(delta)
    if use_delta and cg.n:
        from repro.core.costmodel import delta as _delta
        db = _delta.delta_base(cg, base, overlap=overlap)
        return [db.run(ov) for ov in durations_list]
    out = []
    for overrides in durations_list:
        dur = _override(base, overrides) if overrides else base
        out.append(cg.run(dur, overlap=overlap))
    return out


def _simulate_reference(g: chakra.Graph, system,
                        topo: Optional[Topology] = None, algo: str = "auto",
                        overlap: bool = True, compute_derate: float = 0.6,
                        durations: Optional[Dict] = None,
                        keep_timeline: bool = False) -> SimResult:
    """Original object-walking engine — the executable spec the compiled
    engine is tested against, and the baseline benchmarks compare with."""
    topo = topo or build_topology(system)
    order = g.topo_order()
    pos = {nid: i for i, nid in enumerate(order)}
    dur = {n.id: (durations.get(n.id) if durations and n.id in durations
                  else node_duration(n, system, topo, algo, compute_derate))
           for n in g.nodes}

    def stream_of(n: chakra.Node) -> str:
        if not overlap:
            return "comp"
        return "comm" if n.type in _COMM_TYPES else "comp"

    finish: Dict[int, float] = {}
    stream_free = {"comp": 0.0, "comm": 0.0}
    busy = {"comp": 0.0, "comm": 0.0}          # keyed by node *type*
    consumers = g.consumers()
    remaining = {n.id: len(set(n.all_deps)) for n in g.nodes}
    timeline = [] if keep_timeline else None

    # per stream: `future` heap keyed (dep_time, pos): deps done at dep_time;
    # `avail` heap keyed (pos): dep_time <= stream clock, start immediately.
    future = {"comp": [], "comm": []}
    avail = {"comp": [], "comm": []}
    for n in g.nodes:
        if remaining[n.id] == 0:
            heapq.heappush(avail[stream_of(n)], (pos[n.id], n.id))

    data_consumers: Dict[int, int] = {n.id: 0 for n in g.nodes}
    for n in g.nodes:
        for d in set(n.deps):
            data_consumers[d] += 1
    mem_events = []
    scheduled = 0
    n_total = len(g.nodes)

    def drain(s):
        while future[s] and future[s][0][0] <= stream_free[s]:
            dep_t, p, nid = heapq.heappop(future[s])
            heapq.heappush(avail[s], (p, nid))

    while scheduled < n_total:
        best = None                      # (est, pos, stream, nid, from_avail)
        for s in ("comp", "comm"):
            drain(s)
            if avail[s]:
                p, nid = avail[s][0]
                cand = (stream_free[s], p, s, nid, True)
                if best is None or cand[:2] < best[:2]:
                    best = cand
            elif future[s]:
                dep_t, p, nid = future[s][0]
                cand = (max(stream_free[s], dep_t), p, s, nid, False)
                if best is None or cand[:2] < best[:2]:
                    best = cand
        if best is None:
            raise ValueError("deadlock: no ready nodes but graph unfinished")
        est, _, s, nid, from_avail = best
        if from_avail:
            heapq.heappop(avail[s])
        else:
            heapq.heappop(future[s])
        n = g.node(nid)
        start = est
        end = start + dur[nid]
        stream_free[s] = end
        busy["comm" if n.type in _COMM_TYPES else "comp"] += dur[nid]
        finish[nid] = end
        scheduled += 1
        if keep_timeline:
            timeline.append(Span(n.id, n.name, s, start, end))
        out_b = n.attrs.get("out_bytes", 0.0)
        if out_b:
            mem_events.append((start, out_b, nid))
        if n.type in _COMM_TYPES:
            cb = n.attrs.get("comm_bytes", 0.0)
            if cb:
                # transient comm buffer, tagged by the complement node id
                mem_events.append((start, cb, ~nid))
                mem_events.append((end, -cb, ~nid))
        for c in set(consumers[nid]):
            remaining[c] -= 1
            if remaining[c] == 0:
                cn = g.node(c)
                cs = stream_of(cn)
                dep_t = max((finish[d] for d in set(cn.all_deps)), default=0.0)
                heapq.heappush(future[cs], (dep_t, pos[c], c))
        for d in set(n.deps):
            data_consumers[d] -= 1
            if data_consumers[d] <= 0:
                ob = g.node(d).attrs.get("out_bytes", 0.0)
                if ob:
                    mem_events.append((end, -ob, d))

    total = max(finish.values(), default=0.0)
    exposed = max(0.0, total - busy["comp"])
    return SimResult(total_time=total, compute_time=busy["comp"],
                     comm_time=busy["comm"], exposed_comm=exposed,
                     peak_bytes=exact_peak(mem_events), n_nodes=len(g.nodes),
                     timeline=timeline,
                     mem_events=mem_events if keep_timeline else None)


# ---------------------------------------------------------------------------
# Cluster-level asymmetric simulation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ClusterSimResult:
    """Per-rank view of one cluster step.

    Ranks are grouped into behavioral classes (``class_of_rank`` maps rank ->
    class index); each class carries one ``SimResult`` plus its total
    comm-stream barrier wait (seconds a member spent arrived-but-blocked at
    collectives, i.e. straggler-attributable time).  Duck-types the scalar
    ``SimResult`` fields (total_time et al. = the slowest rank's view) so DSE
    objectives work unchanged."""
    n_ranks: int
    class_of_rank: List[int]
    class_reps: List[int]              # class -> lowest member rank id
    results: List[SimResult]           # class -> per-rank SimResult
    class_barrier_wait: List[float]    # class -> total barrier wait (s)
    step_time: float                   # max over ranks of total_time
    slowest_rank: int                  # lowest rank id attaining step_time

    @property
    def n_classes(self) -> int:
        return len(self.results)

    @property
    def total_time(self) -> float:
        return self.step_time

    def rank_result(self, r: int) -> SimResult:
        return self.results[self.class_of_rank[r]]

    def rank_spans(self, r: int) -> List[Span]:
        """Rank r's timeline as ``Span`` records (keep_timeline=True)."""
        return self.rank_result(r).spans()

    def spans(self) -> List:
        """Flat (rank, Span) pairs over all K ranks, classes expanded —
        the whole-cluster counterpart of ``SimResult.spans()`` (the
        exporter walks ranks itself via ``rank_spans``)."""
        out = []
        for r in range(self.n_ranks):
            out.extend((r, sp) for sp in self.rank_spans(r))
        return out

    @property
    def rank_times(self) -> List[float]:
        return [self.results[c].total_time for c in self.class_of_rank]

    @property
    def barrier_wait(self) -> List[float]:
        """Per-rank total barrier wait, expanded over all K ranks."""
        return [self.class_barrier_wait[c] for c in self.class_of_rank]

    @property
    def max_barrier_wait(self) -> float:
        return max(self.class_barrier_wait)

    @property
    def compute_time(self) -> float:
        return self.rank_result(self.slowest_rank).compute_time

    @property
    def comm_time(self) -> float:
        return self.rank_result(self.slowest_rank).comm_time

    @property
    def exposed_comm(self) -> float:
        return self.rank_result(self.slowest_rank).exposed_comm

    @property
    def peak_bytes(self) -> float:
        return max(r.peak_bytes for r in self.results)

    @property
    def n_nodes(self) -> int:
        return self.results[0].n_nodes

    def as_dict(self):
        waits = self.class_barrier_wait
        counts = [0] * len(self.results)
        for c in self.class_of_rank:
            counts[c] += 1
        mean_wait = sum(w * k for w, k in zip(waits, counts)) / self.n_ranks
        return {"total_time": self.step_time, "step_time": self.step_time,
                "compute_time": self.compute_time,
                "comm_time": self.comm_time,
                "exposed_comm": self.exposed_comm,
                "peak_bytes": self.peak_bytes, "n_nodes": self.n_nodes,
                "n_ranks": self.n_ranks, "n_classes": self.n_classes,
                "slowest_rank": self.slowest_rank,
                "max_barrier_wait": self.max_barrier_wait,
                "mean_barrier_wait": mean_wait}


def _copy_cluster_result(cr: ClusterSimResult) -> ClusterSimResult:
    """Fresh ClusterSimResult sharing no mutable state with `cr` (timelines
    are absent on the cached path, so per-class results copy shallowly)."""
    return dataclasses.replace(
        cr, class_of_rank=list(cr.class_of_rank),
        class_reps=list(cr.class_reps),
        results=[dataclasses.replace(r) for r in cr.results],
        class_barrier_wait=list(cr.class_barrier_wait))


def _group_instances(group: Sequence[int], K: int) -> List[Optional[tuple]]:
    """Participant instances of one collective on a K-rank cluster, derived
    from its chakra ``group`` attr.

    Returns ``inst_of``: a length-K list mapping rank -> the member tuple of
    its instance (None = the rank participates alone, no cross-rank
    barrier).  Layouts understood:

      * consecutive ranks (the standard mesh ordering) tile the cluster in
        consecutive blocks of the group size — the historical model;
      * constant-stride lists (e.g. a cross-pod DP group [0, 4, 8, 12])
        tile each span of ``size * stride`` ranks with ``stride``
        interleaved instances;
      * arbitrary explicit lists are translated by their span; ranks no
        translate covers stay instance-free.
    """
    inst_of: List[Optional[tuple]] = [None] * K
    g = sorted({int(r) for r in group})
    s = len(g)
    if s <= 1 or K <= 1:
        return inst_of
    if s >= K:
        whole = tuple(range(K))
        return [whole] * K

    def place(members):
        mt = tuple(m for m in members if 0 <= m < K)
        if len(mt) >= 2:
            for m in mt:
                inst_of[m] = mt

    if g[-1] - g[0] == s - 1:          # consecutive -> tile by block
        for i0 in range(0, K, s):
            place(range(i0, min(i0 + s, K)))
        return inst_of
    strides = {b - a for a, b in zip(g, g[1:])}
    if len(strides) == 1:              # constant stride -> interleaved
        st = strides.pop()             # lattice anchored at the listed group
        span = s * st
        g0 = g[0]
        for r in range(K):
            if inst_of[r] is not None:
                continue
            # unique (phase, block) translate of the pattern containing r,
            # with the listed group itself as the identity translate
            e = r - g0
            dj = e % st
            delta = dj + ((e - dj) // st // s) * span
            place(x + delta for x in g)
        return inst_of
    span = g[-1] - g[0] + 1            # arbitrary -> translate by span
    for t in range(g[0] % span - span, K, span):
        place(t + (x - g[0]) for x in g)
    return inst_of


def _refine_colors(K: int, inst_maps: Sequence[List],
                   init_keys: List) -> List[int]:
    """Partition ranks into behavioral equivalence classes.

    Two ranks share a class iff they have the same hardware key and,
    recursively, their collective-group instances (one ``inst_of`` map per
    distinct group pattern, see ``_group_instances``) carry the same class
    multiset — the standard partition-refinement fixpoint.  Class ids are
    dense, assigned in first-seen (= lowest-rank) order."""
    seen: Dict = {}
    colors = [seen.setdefault(k, len(seen)) for k in init_keys]
    n_colors = len(seen)
    while True:
        per_rank: List[List] = [[] for _ in range(K)]
        for inst_of in inst_maps:
            keyed: Dict[tuple, tuple] = {}
            for r in range(K):
                mem = inst_of[r]
                if mem is None:
                    per_rank[r].append(None)
                    continue
                key = keyed.get(mem)
                if key is None:
                    cnt: Dict[int, int] = {}
                    for m in mem:
                        c = colors[m]
                        cnt[c] = cnt.get(c, 0) + 1
                    key = keyed[mem] = tuple(sorted(cnt.items()))
                per_rank[r].append(key)
        seen = {}
        new = [seen.setdefault((colors[r], tuple(per_rank[r])), len(seen))
               for r in range(K)]
        if len(seen) == n_colors:      # refinement stalled -> fixpoint
            return new
        colors, n_colors = new, len(seen)


def _parse_rank_profiles(rank_profiles, K: int) -> Dict[int, RankProfile]:
    """{rank: non-default RankProfile} from a dict or length-K sequence,
    range-checked — shared by the SPMD and MPMD cluster engines."""
    profs: Dict[int, RankProfile] = {}
    if rank_profiles:
        items = (rank_profiles.items() if isinstance(rank_profiles, dict)
                 else enumerate(rank_profiles))
        for r, p in items:
            if p is None or p.is_default():
                continue
            if not 0 <= r < K:
                raise ValueError(f"rank_profiles rank {r} outside "
                                 f"cluster of {K}")
            profs[int(r)] = p
    return profs


def _parse_rank_durations(rank_durations, K: int) -> Dict[int, Dict]:
    """{rank: {nid: seconds}} non-empty per-rank overrides, range-checked —
    shared by the SPMD and MPMD cluster engines."""
    rdur: Dict[int, Dict] = {}
    if rank_durations:
        for r, od in rank_durations.items():
            if not od:
                continue
            if not 0 <= r < K:
                raise ValueError(f"rank_durations rank {r} outside "
                                 f"cluster of {K}")
            rdur[int(r)] = od
    return rdur


def _assemble_cluster_result(K: int, colors: List[int], reps: List[int],
                             results: List[SimResult],
                             waits: List[float]) -> ClusterSimResult:
    """Step time + slowest-rank attribution over per-class engine rows —
    shared tail of both cluster engines (ties break to the lowest rank)."""
    step = max(r.total_time for r in results)
    slowest = next(r for r in range(K)
                   if results[colors[r]].total_time == step)
    return ClusterSimResult(n_ranks=K, class_of_rank=colors,
                            class_reps=[int(r) for r in reps],
                            results=results, class_barrier_wait=waits,
                            step_time=step, slowest_rank=slowest)


def _rank_row(cg: CompiledGraph, system, topo, algo: str,
              compute_derate: float, base: List[float], prof: RankProfile,
              lscale: float, reprice_colls: bool) -> List[float]:
    """Per-node duration list for one rank class.  Returns `base` itself
    (no copy) for a fully nominal rank; otherwise recomputes only the node
    kinds the profile touches."""
    if prof.is_default() and lscale == 1.0 and not reprice_colls:
        return base
    row = list(base)
    eff_pf = prof.effective_flops(system)
    eff_hbm = prof.effective_hbm(system)
    if eff_pf != system.peak_flops or eff_hbm != system.hbm_bw:
        comp = cg.type_code == 0
        if comp.any():
            t_f = cg.flops[comp] / (eff_pf * compute_derate)
            t_b = cg.bytes[comp] / eff_hbm
            vals = np.maximum(t_f, t_b).tolist()
            for nid, v in zip(np.nonzero(comp)[0].tolist(), vals):
                row[nid] = v
    if lscale != 1.0 or reprice_colls:
        p2p = (cg.type_code == 2) | (cg.type_code == 3)
        if p2p.any():
            link_bw = topo.link_bw * lscale
            for nid in np.nonzero(p2p)[0].tolist():
                row[nid] = (float(cg.comm_bytes[nid]) / link_bw
                            + topo.link_latency)
        for nid, t in cg.priced_colls(topo, algo, bw_scale=lscale).items():
            row[nid] = t
    return row


def simulate_cluster(g: chakra.Graph, system, topo: Optional[Topology] = None,
                     n_ranks: Optional[int] = None,
                     rank_profiles=None, rank_durations: Optional[Dict] = None,
                     algo: str = "auto", overlap: bool = True,
                     compute_derate: float = 0.6,
                     keep_timeline: bool = False,
                     coalesce: bool = True,
                     memoize: bool = True,
                     delta: object = "auto") -> ClusterSimResult:
    """Simulate one SPMD step on a (possibly heterogeneous) K-rank cluster.

    `rank_profiles` is a {rank: RankProfile} dict or a length-K sequence
    (absent/default entries are baseline ranks); `rank_durations` maps
    rank -> {nid: seconds} per-node duration overrides for that rank (the
    straggler-injection hook).  Per-link overrides come from
    ``topo.link_scales`` and each profile's ``link_scale``; a collective is
    priced by its weakest participant.

    `coalesce=True` (default) simulates one representative per rank
    equivalence class — the symmetric case runs exactly one event loop
    regardless of K, and is bit-identical to ``simulate()`` (its K=1 special
    case).  `coalesce=False` simulates every rank individually; both paths
    produce identical results (property-tested) — the naive path exists as
    the executable spec for the coalescing.

    `memoize=False` bypasses the per-(config, profile-set) result memo in
    both directions — every call pays the full engine.  The fault-horizon
    benchmark uses it as the "naive per-segment rebuild" baseline; results
    are bit-identical either way.

    `g` may also be a per-rank workload — an ``MPMDProgram``, a dense list
    of Graphs, or a ``{rank: Graph}`` dict — in which case the call routes
    to the true-MPMD engine (``costmodel.mpmd.simulate_mpmd``): group attrs
    are read literally, barriers are keyed by (group, per-group program
    order), and mismatched per-rank collective sequences raise
    ``ClusterProgramError``.  K identical graphs are bit-identical to this
    single-graph path (property-tested).

    `delta` enables incremental re-simulation (``costmodel.delta``) on the
    single-class, barrier-free case whose row is base-plus-overrides —
    exactly the shape of uniform-override sweeps.  ``"auto"`` reuses an
    already-memoized checkpointed base (zero cold cost), ``True`` builds
    one, ``False`` disables.  Bit-identical either way; multi-class runs
    always take the engine (not forwarded to the MPMD engine).
    """
    if not isinstance(g, chakra.Graph):
        from repro.core.costmodel import mpmd as _mpmd
        prog = g if isinstance(g, _mpmd.MPMDProgram) else _mpmd.MPMDProgram(g)
        return _mpmd.simulate_mpmd(
            prog, system, topo=topo, n_ranks=n_ranks,
            rank_profiles=rank_profiles, rank_durations=rank_durations,
            algo=algo, overlap=overlap, compute_derate=compute_derate,
            keep_timeline=keep_timeline, coalesce=coalesce, memoize=memoize)
    topo = topo or build_topology(system)
    K = int(n_ranks if n_ranks is not None else topo.n_ranks)
    if K < 1:
        raise ValueError(f"cluster needs >= 1 rank, got {K}")
    cg = compile_graph(g)
    base = cg.durations(system, topo, algo, compute_derate)

    default_prof = RankProfile()
    profs = _parse_rank_profiles(rank_profiles, K)
    rdur = _parse_rank_durations(rank_durations, K)
    tls = getattr(topo, "link_scales", None) or {}

    # per-(config, profile-set) memo on the compiled graph, mirroring
    # simulate()'s result cache: hetero DSE sweeps revisit identical
    # cluster configs, and a timeline-free run is pure in these inputs
    ckey = None
    if not keep_timeline and memoize:
        ckey = ("cluster", cg.config_key(system, topo, algo, compute_derate),
                overlap, K, coalesce, tuple(sorted(profs.items())),
                tuple(sorted((r, tuple(sorted(od.items())))
                             for r, od in rdur.items())))
        hit = cg._result_cache.get(ckey)
        if hit is not None:
            obs.counter("sim.cluster_cache.hit")
            return _copy_cluster_result(hit)
        obs.counter("sim.cluster_cache.miss")

    init_keys = []
    for r in range(K):
        od = rdur.get(r)
        okey = tuple(sorted(od.items())) if od else None
        init_keys.append((profs.get(r, default_prof), tls.get(r, 1.0), okey))

    # one instance map per distinct group pattern: explicit/strided group
    # attrs map their own participant instances; consecutive groups keep
    # the historical block tiling
    inst_maps = {p: _group_instances(p, K)
                 for p in sorted({meta[2] for meta in cg._coll_meta})}
    colors = (_refine_colors(K, list(inst_maps.values()), init_keys)
              if coalesce else list(range(K)))
    n_classes = max(colors) + 1
    reps: List[Optional[int]] = [None] * n_classes
    for r in range(K):
        if reps[colors[r]] is None:
            reps[colors[r]] = r

    # per-class duration rows (shared across classes with the same hardware
    # key; rank_durations overrides applied on a copy)
    reprice = bool(tls)                # per-link overrides: every row must be
    row_memo: Dict = {}                # priced at its own rank's link scale
    rows: List[List[float]] = []
    for rep in reps:
        p = profs.get(rep, default_prof)
        ls = p.link_scale * tls.get(rep, 1.0)
        rkey = (p, ls)
        row = row_memo.get(rkey)
        if row is None:
            row = _rank_row(cg, system, topo, algo, compute_derate, base,
                            p, ls, reprice)
            row_memo[rkey] = row
        od = rdur.get(rep)
        if od:
            row = _override(row, od)
        rows.append(row)

    # cross-rank barriers: one per (collective, participant-class clique);
    # collectives whose instance maps to a single class stay on the plain
    # run() path (trivially resolved at arrival).  Membership comes from
    # the group attr's instance map — at the refinement fixpoint two
    # same-class ranks sit in identically-colored instances, so one
    # barrier per class set is exact.
    barrier_map: List[Dict[int, list]] = [dict() for _ in range(n_classes)]
    for nid, (kind, group, group_t, _chan, _rel) in zip(cg._coll_ids,
                                                        cg._coll_meta):
        inst_of = inst_maps[group_t]
        for j, rep in enumerate(reps):
            if nid in barrier_map[j]:
                continue
            members = inst_of[rep]
            if members is None:
                continue
            W = sorted({colors[m] for m in members})
            if len(W) == 1:
                continue
            b = [len(W), 0.0, tuple(W),
                 max(rows[w][nid] for w in W), {}]
            for w in W:
                barrier_map[w][nid] = b

    # delta fast path (costmodel.delta): a single-class cluster never has
    # cross-rank barriers (every instance maps to one class), and when its
    # row is `base` itself (nominal hardware, see _rank_row) the run is
    # exactly simulate()'s override path — resume from the checkpointed
    # base run instead of replaying the whole schedule
    results = None
    if (delta is not False and n_classes == 1 and not keep_timeline
            and not reprice
            and profs.get(reps[0], default_prof).is_default()):
        from repro.core.costmodel import delta as _delta
        db = _delta.delta_base(cg, base, overlap=overlap,
                               build=(delta is True))
        if db is not None:
            results, waits = [db.run(rdur.get(reps[0]) or {})], [0.0]

    if results is None:
        # canonical program order of collectives (the compiled binary's
        # launch order, taken from the nominal symmetric schedule) — only
        # needed when some barrier actually spans classes
        coll_order = (cg.canonical_coll_order(base, overlap=overlap)
                      if any(barrier_map) else None)
        results, waits = cg.run_cluster(rows, barrier_map,
                                        coll_order=coll_order,
                                        overlap=overlap,
                                        keep_timeline=keep_timeline)

    res = _assemble_cluster_result(K, colors, reps, results, waits)
    if ckey is not None:
        # fresh copies both ways: callers may post-process in place
        result_cache_put(cg._result_cache, ckey, _copy_cluster_result(res))
    return res


def straggler_analysis(g: chakra.Graph, system, topo: Optional[Topology] = None,
                       slowdowns=(1.0, 1.1, 1.25, 1.5, 2.0),
                       backup_overhead: float = 0.05,
                       n_ranks: Optional[int] = None,
                       straggler_rank: int = 0):
    """Quantify straggler impact + backup-rank mitigation (DESIGN.md SS7).

    A straggler is modeled as *one slowed rank gating collective barriers*
    (``simulate_cluster`` with COMP durations of `straggler_rank` scaled by
    f): collectives complete only when the straggler arrives, so fast ranks
    accumulate barrier wait while compute ahead of the barrier still
    overlaps — step-time inflation lands strictly between 1x and fx instead
    of the old single-timeline proxy's whole-step scaling.  A hot backup
    that replaces the straggler returns the step to nominal at
    `backup_overhead` cost (state replication).

    The nominal (f=1) row reuses the compiled graph's cached symmetric
    result — no separate simulate() recompute; thanks to rank coalescing
    each slowed factor costs a handful of event loops regardless of K.

    Returns a list of dicts: slowdown, step_time, slowdown_realized,
    backup_step_time, backup_wins, slowest_rank, victim_wait, n_ranks.
    """
    topo = topo or build_topology(system)
    K = int(n_ranks if n_ranks is not None else topo.n_ranks)
    cg = compile_graph(g)
    base = cg.durations(system, topo)
    comp_ids = np.nonzero(cg.type_code == 0)[0].tolist()
    nominal_res = simulate(g, system, topo)    # memoized on the compiled graph
    nominal = nominal_res.total_time
    out = []
    for f in slowdowns:
        if f == 1.0:
            # symmetric cluster == the cached nominal timeline on every rank
            t, wait, slowest = nominal, 0.0, 0
        else:
            rd = {straggler_rank: {nid: base[nid] * f for nid in comp_ids}}
            cr = simulate_cluster(g, system, topo, n_ranks=K,
                                  rank_durations=rd)
            t, wait, slowest = (cr.step_time, cr.max_barrier_wait,
                                cr.slowest_rank)
        backup_t = nominal * (1.0 + backup_overhead)
        out.append({
            "slowdown": f,
            "step_time": t,
            "slowdown_realized": t / nominal,
            "backup_step_time": backup_t,
            "backup_wins": backup_t < t,
            "slowest_rank": slowest,
            "victim_wait": wait,
            "n_ranks": K,
        })
    return out
