"""Dependency-driven discrete-event simulator (ASTRA-sim-class cost model).

Consumes a Chakra graph (rank-symmetric SPMD view), a SystemConfig and a
Topology; produces per-step duration, compute/comm busy times, exposed
(non-overlapped) communication, and peak memory via liveness.

Model: two in-order streams per rank — compute and communication — matching
TPU async collectives (and GPU comm streams).  A node starts when (a) all its
deps (data + ctrl) have finished and (b) its stream is free.  Durations:
  COMP      max(flops / (derate * peak_flops), bytes / hbm_bw)
  COMM_COLL collective_time(kind, payload, group, topo, algo)
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional

from repro.core import chakra
from repro.core.costmodel.collectives import collective_time
from repro.core.costmodel.topology import Topology, build_topology


@dataclasses.dataclass
class SimResult:
    total_time: float
    compute_time: float           # compute-stream busy time
    comm_time: float              # comm-stream busy time
    exposed_comm: float           # comm time not hidden by compute
    peak_bytes: float             # activations + comm buffers (no params)
    n_nodes: int
    timeline: Optional[List] = None

    def as_dict(self):
        d = dataclasses.asdict(self)
        d.pop("timeline")
        return d


def node_duration(n: chakra.Node, system, topo: Topology,
                  algo: str = "auto", compute_derate: float = 0.6) -> float:
    if n.type == chakra.COMP:
        t_f = n.attrs.get("flops", 0.0) / (system.peak_flops * compute_derate)
        t_b = n.attrs.get("bytes", 0.0) / system.hbm_bw
        return max(t_f, t_b)
    if n.type == chakra.COMM_COLL:
        payload = n.attrs.get("comm_bytes", 0.0)
        group = n.attrs.get("group") or list(range(
            n.attrs.get("group_size", 1)))
        return collective_time(n.attrs.get("comm_kind", "all-reduce"),
                               payload, group, topo, algo)
    if n.type in (chakra.COMM_SEND, chakra.COMM_RECV):
        return (n.attrs.get("comm_bytes", 0.0) / topo.link_bw
                + topo.link_latency)
    return 0.0


def simulate(g: chakra.Graph, system, topo: Optional[Topology] = None,
             algo: str = "auto", overlap: bool = True,
             compute_derate: float = 0.6, durations: Optional[Dict] = None,
             keep_timeline: bool = False) -> SimResult:
    """Time-ordered event-driven list scheduling: when a stream goes idle it
    picks the lowest-topo-position node among those whose deps have finished
    *by then* (a later-positioned ready node fills idle gaps — no artificial
    serialization)."""
    topo = topo or build_topology(system)
    order = g.topo_order()
    pos = {nid: i for i, nid in enumerate(order)}
    dur = {n.id: (durations.get(n.id) if durations and n.id in durations
                  else node_duration(n, system, topo, algo, compute_derate))
           for n in g.nodes}

    def stream_of(n: chakra.Node) -> str:
        if not overlap:
            return "comp"
        return "comm" if n.type in (chakra.COMM_COLL, chakra.COMM_SEND,
                                    chakra.COMM_RECV) else "comp"

    finish: Dict[int, float] = {}
    stream_free = {"comp": 0.0, "comm": 0.0}
    busy = {"comp": 0.0, "comm": 0.0}
    consumers = g.consumers()
    remaining = {n.id: len(set(n.all_deps)) for n in g.nodes}
    timeline = [] if keep_timeline else None

    # per stream: `future` heap keyed (dep_time, pos): deps done at dep_time;
    # `avail` heap keyed (pos): dep_time <= stream clock, start immediately.
    future = {"comp": [], "comm": []}
    avail = {"comp": [], "comm": []}
    for n in g.nodes:
        if remaining[n.id] == 0:
            heapq.heappush(avail[stream_of(n)], (pos[n.id], n.id))

    data_consumers: Dict[int, int] = {n.id: 0 for n in g.nodes}
    for n in g.nodes:
        for d in set(n.deps):
            data_consumers[d] += 1
    mem_events = []
    scheduled = 0
    n_total = len(g.nodes)

    def drain(s):
        while future[s] and future[s][0][0] <= stream_free[s]:
            dep_t, p, nid = heapq.heappop(future[s])
            heapq.heappush(avail[s], (p, nid))

    while scheduled < n_total:
        best = None                      # (est, pos, stream, nid, from_avail)
        for s in ("comp", "comm"):
            drain(s)
            if avail[s]:
                p, nid = avail[s][0]
                cand = (stream_free[s], p, s, nid, True)
                if best is None or cand[:2] < best[:2]:
                    best = cand
            elif future[s]:
                dep_t, p, nid = future[s][0]
                cand = (max(stream_free[s], dep_t), p, s, nid, False)
                if best is None or cand[:2] < best[:2]:
                    best = cand
        if best is None:
            raise ValueError("deadlock: no ready nodes but graph unfinished")
        est, _, s, nid, from_avail = best
        if from_avail:
            heapq.heappop(avail[s])
        else:
            heapq.heappop(future[s])
        n = g.node(nid)
        start = est
        end = start + dur[nid]
        stream_free[s] = end
        busy[s] += dur[nid]
        finish[nid] = end
        scheduled += 1
        if keep_timeline:
            timeline.append((n.id, n.name, s, start, end))
        out_b = n.attrs.get("out_bytes", 0.0)
        if out_b:
            mem_events.append((start, out_b))
        for c in set(consumers[nid]):
            remaining[c] -= 1
            if remaining[c] == 0:
                cn = g.node(c)
                cs = stream_of(cn)
                dep_t = max((finish[d] for d in set(cn.all_deps)), default=0.0)
                heapq.heappush(future[cs], (dep_t, pos[c], c))
        for d in set(n.deps):
            data_consumers[d] -= 1
            if data_consumers[d] <= 0:
                ob = g.node(d).attrs.get("out_bytes", 0.0)
                if ob:
                    mem_events.append((end, -ob))

    total = max(finish.values(), default=0.0)
    live = peak = 0.0
    for t, delta in sorted(mem_events):
        live += delta
        peak = max(peak, live)
    exposed = max(0.0, total - busy["comp"])
    return SimResult(total_time=total, compute_time=busy["comp"],
                     comm_time=busy["comm"], exposed_comm=exposed,
                     peak_bytes=peak, n_nodes=len(g.nodes), timeline=timeline)


def straggler_analysis(g: chakra.Graph, system, topo: Optional[Topology] = None,
                       slowdowns=(1.0, 1.1, 1.25, 1.5, 2.0),
                       backup_overhead: float = 0.05):
    """Quantify straggler impact + backup-rank mitigation (DESIGN.md SS7).

    In a synchronous SPMD step every collective gates on the slowest
    participant, so a straggler whose compute runs `f`x slower sets the
    cluster's step time: simulate the straggler's own timeline with COMP
    durations scaled by f.  A hot backup that replaces the straggler returns
    the step to nominal at `backup_overhead` cost (state replication).

    Returns a list of dicts: slowdown, step_time, slowdown_realized,
    backup_step_time, backup_wins.
    """
    topo = topo or build_topology(system)
    nominal = simulate(g, system, topo).total_time
    out = []
    for f in slowdowns:
        dur = {n.id: node_duration(n, system, topo) * f
               for n in g.nodes if n.type == chakra.COMP}
        t = simulate(g, system, topo, durations=dur).total_time
        backup_t = nominal * (1.0 + backup_overhead)
        out.append({
            "slowdown": f,
            "step_time": t,
            "slowdown_realized": t / nominal,
            "backup_step_time": backup_t,
            "backup_wins": backup_t < t,
        })
    return out
