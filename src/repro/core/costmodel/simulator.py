"""Dependency-driven discrete-event simulator (ASTRA-sim-class cost model).

Consumes a Chakra graph (rank-symmetric SPMD view), a SystemConfig and a
Topology; produces per-step duration, compute/comm busy times, exposed
(non-overlapped) communication, and peak memory via liveness.

Model: two in-order streams per rank — compute and communication — matching
TPU async collectives (and GPU comm streams).  A node starts when (a) all its
deps (data + ctrl) have finished and (b) its stream is free.  Durations:
  COMP      max(flops / (derate * peak_flops), bytes / hbm_bw)
  COMM_COLL collective_time(kind, payload, group, topo, algo)

Engines
-------
``simulate()`` is a thin wrapper over two interchangeable engines:

  * ``engine="compiled"`` (default) lowers the graph once into flat CSR
    arrays (``costmodel.compiled.CompiledGraph``), memoized on the Graph and
    keyed by its edit token, with per-(system, topo, algo, derate) duration
    vectors memoized on the compiled form.  Repeated calls — DSE sweeps,
    straggler batches — skip all O(N+E) set/dict rebuilding.
  * ``engine="reference"`` is the original object-walking loop, kept as the
    executable spec: the compiled engine must return bit-identical
    ``SimResult``s (enforced by tests/test_compiled_sim.py).

Busy-time accounting is by *node type*, not by stream: with
``overlap=False`` every node runs on the compute stream, but
``compute_time``/``comm_time``/``exposed_comm`` still mean what they say
(previously exposed_comm degenerated to 0 because comm time was counted as
compute-stream busy time).

``simulate_batch()`` amortizes compilation across many duration-override
runs (straggler sweeps, sensitivity analyses).
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Sequence

from repro.core import chakra
from repro.core.costmodel.collectives import collective_time
from repro.core.costmodel.compiled import CompiledGraph, compile_graph
from repro.core.costmodel.topology import Topology, build_topology


@dataclasses.dataclass
class SimResult:
    total_time: float
    compute_time: float           # busy time of COMP/MEM nodes
    comm_time: float              # busy time of COMM_* nodes
    exposed_comm: float           # comm time not hidden by compute
    peak_bytes: float             # activations + comm buffers (no params)
    n_nodes: int
    timeline: Optional[List] = None

    def as_dict(self):
        d = dataclasses.asdict(self)
        d.pop("timeline")
        return d


def node_duration(n: chakra.Node, system, topo: Topology,
                  algo: str = "auto", compute_derate: float = 0.6) -> float:
    if n.type == chakra.COMP:
        t_f = n.attrs.get("flops", 0.0) / (system.peak_flops * compute_derate)
        t_b = n.attrs.get("bytes", 0.0) / system.hbm_bw
        return max(t_f, t_b)
    if n.type == chakra.COMM_COLL:
        payload = n.attrs.get("comm_bytes", 0.0)
        group = n.attrs.get("group") or list(range(
            n.attrs.get("group_size", 1)))
        return collective_time(n.attrs.get("comm_kind", "all-reduce"),
                               payload, group, topo, algo)
    if n.type in (chakra.COMM_SEND, chakra.COMM_RECV):
        return (n.attrs.get("comm_bytes", 0.0) / topo.link_bw
                + topo.link_latency)
    return 0.0


_COMM_TYPES = (chakra.COMM_COLL, chakra.COMM_SEND, chakra.COMM_RECV)


def simulate(g: chakra.Graph, system, topo: Optional[Topology] = None,
             algo: str = "auto", overlap: bool = True,
             compute_derate: float = 0.6, durations: Optional[Dict] = None,
             keep_timeline: bool = False,
             engine: str = "compiled") -> SimResult:
    """Time-ordered event-driven list scheduling: when a stream goes idle it
    picks the lowest-topo-position node among those whose deps have finished
    *by then* (a later-positioned ready node fills idle gaps — no artificial
    serialization).

    `durations` optionally overrides per-node durations ({nid: seconds});
    `engine` selects the compiled fast path or the reference loop.
    """
    if engine == "reference":
        return _simulate_reference(g, system, topo, algo, overlap,
                                   compute_derate, durations, keep_timeline)
    if engine != "compiled":
        raise ValueError(f"unknown engine {engine!r}: "
                         "expected 'compiled' or 'reference'")
    topo = topo or build_topology(system)
    cg = compile_graph(g)
    # override-free, timeline-free runs are pure in (graph, config): memoize
    # the SimResult itself so repeated identical calls (DSE inner loop,
    # straggler nominal) are O(1)
    rkey = None
    if not durations and not keep_timeline:
        rkey = (cg.config_key(system, topo, algo, compute_derate), overlap)
        hit = cg._result_cache.get(rkey)
        if hit is not None:
            # fresh instance per call: SimResult is mutable and callers may
            # post-process in place — never hand out the cached object
            return dataclasses.replace(hit)
    dur = cg.durations(system, topo, algo, compute_derate)
    if durations:
        dur = _override(dur, durations)
    res = cg.run(dur, overlap=overlap, keep_timeline=keep_timeline)
    if rkey is not None:
        cg._result_cache[rkey] = dataclasses.replace(res)
    return res


def _override(base: List[float], durations: Dict) -> List[float]:
    """Copy of `base` with per-node overrides; ids outside the graph are
    ignored, matching the reference engine's membership check."""
    n = len(base)
    dur = base[:]
    for nid, t in durations.items():
        if 0 <= nid < n:
            dur[nid] = t
    return dur


def simulate_batch(g: chakra.Graph, system,
                   durations_list: Sequence[Optional[Dict]],
                   topo: Optional[Topology] = None, algo: str = "auto",
                   overlap: bool = True,
                   compute_derate: float = 0.6) -> List[SimResult]:
    """Run one compiled graph under many duration-override dicts.

    Compiles once and reuses the cached base-duration vector, so a K-entry
    batch costs K event loops — no recompilation, no per-entry duration
    recomputation.  Each entry of `durations_list` is a {nid: seconds}
    override (or None for the base durations)."""
    topo = topo or build_topology(system)
    cg = compile_graph(g)
    base = cg.durations(system, topo, algo, compute_derate)
    out = []
    for overrides in durations_list:
        dur = _override(base, overrides) if overrides else base
        out.append(cg.run(dur, overlap=overlap))
    return out


def _simulate_reference(g: chakra.Graph, system,
                        topo: Optional[Topology] = None, algo: str = "auto",
                        overlap: bool = True, compute_derate: float = 0.6,
                        durations: Optional[Dict] = None,
                        keep_timeline: bool = False) -> SimResult:
    """Original object-walking engine — the executable spec the compiled
    engine is tested against, and the baseline benchmarks compare with."""
    topo = topo or build_topology(system)
    order = g.topo_order()
    pos = {nid: i for i, nid in enumerate(order)}
    dur = {n.id: (durations.get(n.id) if durations and n.id in durations
                  else node_duration(n, system, topo, algo, compute_derate))
           for n in g.nodes}

    def stream_of(n: chakra.Node) -> str:
        if not overlap:
            return "comp"
        return "comm" if n.type in _COMM_TYPES else "comp"

    finish: Dict[int, float] = {}
    stream_free = {"comp": 0.0, "comm": 0.0}
    busy = {"comp": 0.0, "comm": 0.0}          # keyed by node *type*
    consumers = g.consumers()
    remaining = {n.id: len(set(n.all_deps)) for n in g.nodes}
    timeline = [] if keep_timeline else None

    # per stream: `future` heap keyed (dep_time, pos): deps done at dep_time;
    # `avail` heap keyed (pos): dep_time <= stream clock, start immediately.
    future = {"comp": [], "comm": []}
    avail = {"comp": [], "comm": []}
    for n in g.nodes:
        if remaining[n.id] == 0:
            heapq.heappush(avail[stream_of(n)], (pos[n.id], n.id))

    data_consumers: Dict[int, int] = {n.id: 0 for n in g.nodes}
    for n in g.nodes:
        for d in set(n.deps):
            data_consumers[d] += 1
    mem_events = []
    scheduled = 0
    n_total = len(g.nodes)

    def drain(s):
        while future[s] and future[s][0][0] <= stream_free[s]:
            dep_t, p, nid = heapq.heappop(future[s])
            heapq.heappush(avail[s], (p, nid))

    while scheduled < n_total:
        best = None                      # (est, pos, stream, nid, from_avail)
        for s in ("comp", "comm"):
            drain(s)
            if avail[s]:
                p, nid = avail[s][0]
                cand = (stream_free[s], p, s, nid, True)
                if best is None or cand[:2] < best[:2]:
                    best = cand
            elif future[s]:
                dep_t, p, nid = future[s][0]
                cand = (max(stream_free[s], dep_t), p, s, nid, False)
                if best is None or cand[:2] < best[:2]:
                    best = cand
        if best is None:
            raise ValueError("deadlock: no ready nodes but graph unfinished")
        est, _, s, nid, from_avail = best
        if from_avail:
            heapq.heappop(avail[s])
        else:
            heapq.heappop(future[s])
        n = g.node(nid)
        start = est
        end = start + dur[nid]
        stream_free[s] = end
        busy["comm" if n.type in _COMM_TYPES else "comp"] += dur[nid]
        finish[nid] = end
        scheduled += 1
        if keep_timeline:
            timeline.append((n.id, n.name, s, start, end))
        out_b = n.attrs.get("out_bytes", 0.0)
        if out_b:
            mem_events.append((start, out_b))
        for c in set(consumers[nid]):
            remaining[c] -= 1
            if remaining[c] == 0:
                cn = g.node(c)
                cs = stream_of(cn)
                dep_t = max((finish[d] for d in set(cn.all_deps)), default=0.0)
                heapq.heappush(future[cs], (dep_t, pos[c], c))
        for d in set(n.deps):
            data_consumers[d] -= 1
            if data_consumers[d] <= 0:
                ob = g.node(d).attrs.get("out_bytes", 0.0)
                if ob:
                    mem_events.append((end, -ob))

    total = max(finish.values(), default=0.0)
    live = peak = 0.0
    for t, delta in sorted(mem_events):
        live += delta
        peak = max(peak, live)
    exposed = max(0.0, total - busy["comp"])
    return SimResult(total_time=total, compute_time=busy["comp"],
                     comm_time=busy["comm"], exposed_comm=exposed,
                     peak_bytes=peak, n_nodes=len(g.nodes), timeline=timeline)


def straggler_analysis(g: chakra.Graph, system, topo: Optional[Topology] = None,
                       slowdowns=(1.0, 1.1, 1.25, 1.5, 2.0),
                       backup_overhead: float = 0.05):
    """Quantify straggler impact + backup-rank mitigation (DESIGN.md SS7).

    In a synchronous SPMD step every collective gates on the slowest
    participant, so a straggler whose compute runs `f`x slower sets the
    cluster's step time: simulate the straggler's own timeline with COMP
    durations scaled by f.  A hot backup that replaces the straggler returns
    the step to nominal at `backup_overhead` cost (state replication).

    Implemented over the compiled substrate: the graph is lowered once and
    every slowdown factor is a duration-override replay (simulate_batch).

    Returns a list of dicts: slowdown, step_time, slowdown_realized,
    backup_step_time, backup_wins.
    """
    topo = topo or build_topology(system)
    cg = compile_graph(g)
    base = cg.durations(system, topo)
    comp_ids = [n.id for n in g.nodes if n.type == chakra.COMP]
    nominal = simulate(g, system, topo).total_time
    overrides = [{nid: base[nid] * f for nid in comp_ids} for f in slowdowns]
    results = simulate_batch(g, system, overrides, topo=topo)
    out = []
    for f, r in zip(slowdowns, results):
        t = r.total_time
        backup_t = nominal * (1.0 + backup_overhead)
        out.append({
            "slowdown": f,
            "step_time": t,
            "slowdown_realized": t / nominal,
            "backup_step_time": backup_t,
            "backup_wins": backup_t < t,
        })
    return out
