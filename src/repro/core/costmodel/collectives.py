"""Collective-algorithm time models + synthesis (paper SS6.2).

Times follow the standard alpha-beta model on top of the Topology's
effective ring bandwidth:
  ring all-reduce      2(n-1)/n * S / bw + 2(n-1) * alpha
  ring all-gather/RS    (n-1)/n * S / bw +  (n-1) * alpha
  halving-doubling     log2(n) rounds (latency-optimal, needs pow2)
  2-D synthesized      dimension-ordered rings (TACOS-like): RS along x,
                       RS along y, AG along y, AG along x — each leg rides a
                       native torus axis at full link bw, avoiding the
                       congestion a single long ring suffers on a mesh.

`synthesize_2d` also emits the per-round p2p message list (the separate
Chakra graph representation the paper feeds to the simulator).
"""
from __future__ import annotations

import math
from typing import List, Tuple

from repro.core.costmodel.topology import MultiPod, Topology, Torus2D


def _ring_time(payload: float, n: int, bw: float, alpha: float,
               rounds_factor: float) -> float:
    if n <= 1 or payload <= 0:
        return 0.0
    steps = rounds_factor * (n - 1)
    return steps / n * payload / bw + steps * alpha


def collective_time(kind: str, payload: float, group: List[int],
                    topo: Topology, algo: str = "auto",
                    bw_scale: float = None) -> float:
    """Seconds for one collective of `payload` bytes per rank over `group`.

    payload semantics: all-gather/reduce-scatter -> full (gathered) size;
    all-reduce -> full tensor size; all-to-all -> per-rank send total;
    collective-permute -> message size.

    `bw_scale` multiplies every bandwidth term (latency is unaffected) —
    the hook the cluster simulator uses to price a collective at one rank's
    degraded link speed.  When None it defaults to the topology's
    ``group_link_scale(group)``: a group is priced by its weakest member's
    per-link override (1.0 when no overrides are configured, keeping the
    homogeneous path bit-identical)."""
    n = len(group)
    if n <= 1 or payload <= 0:
        return 0.0
    if bw_scale is None:
        bw_scale = topo.group_link_scale(group)
    alpha = topo.link_latency
    bw = topo.ring_bw(group)
    if bw_scale != 1.0:
        bw *= bw_scale

    if algo == "auto":
        if isinstance(topo, Torus2D) and not topo.group_is_axis(group) \
                and kind in ("all-reduce", "all-gather", "reduce-scatter"):
            algo = "2d_synth"
        else:
            algo = "ring"

    if kind == "collective-permute":
        link_bw = topo.link_bw
        if bw_scale != 1.0:
            link_bw *= bw_scale
        hops = max((topo.hop_distance(a, b) for a, b in
                    zip(group, group[1:] + group[:1])), default=1)
        return payload / link_bw + hops * alpha

    if kind in ("p2p", "send-recv"):
        # pipeline send/recv-as-collective (convert.split_pipeline_stages):
        # the full payload crosses one link between the two group members
        link_bw = topo.link_bw
        if bw_scale != 1.0:
            link_bw *= bw_scale
        hops = max((topo.hop_distance(a, b)
                    for a, b in zip(group, group[1:])), default=1)
        return payload / link_bw + hops * alpha

    if kind == "all-to-all":
        # bisection-limited
        bis = topo.bisection_bw()
        if bw_scale != 1.0:
            bis *= bw_scale
        t_bis = payload * n / 2 / max(bis, 1e-9) / n
        return max(payload / bw, t_bis) + (n - 1) * alpha

    if algo == "2d_synth" and isinstance(topo, Torus2D):
        return synthesize_2d_time(kind, payload, group, topo,
                                  bw_scale=bw_scale)

    if algo == "hd" and n & (n - 1) == 0:
        steps = int(math.log2(n))
        if kind == "all-reduce":
            return 2 * (payload * (n - 1) / n / bw) + 2 * steps * alpha
        return payload * (n - 1) / n / bw + steps * alpha

    rounds = 2.0 if kind == "all-reduce" else 1.0
    return _ring_time(payload, n, bw, alpha, rounds)


# ---------------------------------------------------------------------------
# 2-D synthesized collectives (TACOS-like, for torus/wafer)
# ---------------------------------------------------------------------------

def _axis_groups(group: List[int], topo: Torus2D):
    """Split a 2-D-embedded group into its x-rings and y-rings."""
    coords = {r: topo._coord(r) for r in group}
    rows = {}
    cols = {}
    for r, (x, y) in coords.items():
        rows.setdefault(x, []).append(r)
        cols.setdefault(y, []).append(r)
    return list(rows.values()), list(cols.values())


def synthesize_2d_time(kind: str, payload: float, group: List[int],
                       topo: Torus2D, bw_scale: float = 1.0) -> float:
    """Dimension-ordered collective on a 2-D torus/mesh."""
    rows, cols = _axis_groups(group, topo)
    nr = max(len(r) for r in rows)
    ncl = max(len(c) for c in cols)
    alpha = topo.link_latency
    bw = topo.link_bw * (2.0 if topo.wrap else 1.0)
    if bw_scale != 1.0:
        bw *= bw_scale

    if kind == "all-reduce":
        # RS along rows, AR along cols on 1/nr of data, AG along rows
        t = _ring_time(payload, nr, bw, alpha, 1.0)            # RS rows
        t += _ring_time(payload / nr, ncl, bw, alpha, 2.0)     # AR cols
        t += _ring_time(payload, nr, bw, alpha, 1.0)           # AG rows
        return t
    if kind in ("all-gather", "reduce-scatter"):
        t = _ring_time(payload / ncl, nr, bw, alpha, 1.0)
        t += _ring_time(payload, ncl, bw, alpha, 1.0)
        return t
    return _ring_time(payload, len(group), topo.ring_bw(group) * bw_scale,
                      alpha, 1.0)


def synthesize_2d_p2p(kind: str, payload: float, group: List[int],
                      topo: Torus2D) -> List[Tuple[int, int, float, int]]:
    """Per-round (src, dst, bytes, round) messages of the 2-D synthesized
    algorithm — a Chakra-graph-of-p2p representation (paper SS6.2)."""
    rows, cols = _axis_groups(group, topo)
    msgs = []
    rnd = 0
    for ring_set, frac in ((rows, 1.0), (cols, 1.0 / max(len(r) for r in rows))):
        max_len = max(len(r) for r in ring_set)
        for step in range(max_len - 1):
            for ring in ring_set:
                n = len(ring)
                if n <= 1:
                    continue
                chunk = payload * frac / n
                for i in range(n):
                    msgs.append((ring[i], ring[(i + 1) % n], chunk, rnd + step))
        rnd += max_len - 1
    return msgs
