"""Fork-based process pool for DSE trial evaluation.

Why fork and not spawn: trial evaluators close over ``graph_for``
callables, memoized graphs and system configs — lambdas and
capture-derived closures that cannot cross a pickle boundary.  A forked
child inherits the parent's whole heap copy-on-write, so the work table
is published in a module global immediately before the pool starts and
workers index into it; only ``(index, result, error)`` tuples cross the
process boundary.  Results *are* pickled on the way back — SimResult /
ClusterSimResult / Trial / CompiledGraph are plain data
(tests/test_pickle.py keeps them that way).

``map_fork`` degrades to an in-process serial map — same results, same
ordering — when ``jobs <= 1``, the platform lacks a fork start method,
or the caller is already a daemonic pool worker (nested pools are not a
thing in ``multiprocessing``).  Output order is by item index, never by
completion order, so parallel evaluation is deterministic.

Caveat: forking a process whose threads hold locks is unsafe in
general, and jax warns at fork time when it is loaded (its runtime is
multithreaded).  The simulator/DSE workers forked here run pure-Python
cost-model code and never touch jax, so the fork is benign in this
package's entry points — but don't route jax-calling evaluators through
``map_fork``; run those trials serially or in spawned processes.
"""
from __future__ import annotations

import multiprocessing as mp
import os
import time
from typing import Callable, List, Optional, Sequence, Tuple

from repro.obs import record as obs

# (fn, items) published for fork children; set only for the lifetime of
# one map_fork call in the parent
_WORK = None


def pool_available() -> bool:
    """True when this platform can run the fork pool (Linux/macOS CPython;
    spawn-only platforms would need picklable callables, which graph_for
    lambdas are not)."""
    return hasattr(os, "fork") and "fork" in mp.get_all_start_methods()


def cpu_count() -> int:
    """Usable CPUs (affinity-aware where the OS exposes it)."""
    try:
        return len(os.sched_getaffinity(0))
    except (AttributeError, OSError):
        return os.cpu_count() or 1


def _run_chunk(bounds: Tuple[int, int]):
    """Worker body: ``([(i, result, error)], obs_payload)``.

    If the parent was recording (the forked child inherits its live
    recorder), a fresh per-chunk recorder captures the chunk's counters,
    spans and busy time; the payload rides the result tuple back and the
    parent merges it — counters stay additive, so pooled totals match
    serial ones."""
    lo, hi = bounds
    fn, items = _WORK
    rec = obs.fork_child_begin()
    t0 = time.perf_counter()
    out = []
    for i in range(lo, hi):
        try:
            out.append((i, fn(items[i]), None))
        except Exception as e:  # stringified: worker exceptions may not pickle
            out.append((i, None, f"{type(e).__name__}: {e}"))
    payload = None
    if rec is not None:
        payload = obs.fork_child_payload(rec, time.perf_counter() - t0,
                                         hi - lo)
    return out, payload


def map_fork(fn: Callable, items: Sequence, jobs: Optional[int] = None,
             chunks_per_worker: int = 4) -> List[Tuple[object, Optional[str]]]:
    """``[(result, error)]`` for ``fn`` over ``items``, in item order.

    ``error`` is None on success; on an exception the slot carries
    ``"ExcType: message"`` and result is None — the caller decides whether
    to raise or record (SearchRun records failed trials, explore raises).
    Items are dispatched as contiguous chunks (``chunks_per_worker`` per
    worker) so per-task IPC amortizes; chunk completion order does not
    affect output order.
    """
    items = list(items)
    n = len(items)
    serial = (jobs is None or jobs <= 1 or n <= 1 or not pool_available()
              or mp.current_process().daemon)
    if serial:
        out = []
        for it in items:
            try:
                out.append((fn(it), None))
            except Exception as e:
                out.append((None, f"{type(e).__name__}: {e}"))
        return out
    global _WORK
    workers = min(int(jobs), n)
    step = max(1, -(-n // (workers * max(1, chunks_per_worker))))
    bounds = [(lo, min(n, lo + step)) for lo in range(0, n, step)]
    results: List = [None] * n
    _WORK = (fn, items)
    t0 = time.perf_counter()
    try:
        ctx = mp.get_context("fork")
        with ctx.Pool(processes=workers) as p:
            for part, payload in p.imap_unordered(_run_chunk, bounds):
                for i, val, err in part:
                    results[i] = (val, err)
                obs.merge_child(payload)
    finally:
        _WORK = None
        obs.pool_stats(time.perf_counter() - t0, workers)
    return results
